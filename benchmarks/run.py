"""Benchmark harness: one function per paper claim. Prints
``name,us_per_call,derived`` CSV, then the roofline table if dry-run
artifacts exist.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import bench_core, roofline

    print("name,us_per_call,derived")
    for bench in bench_core.ALL:
        for row in bench():
            print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")

    rows = roofline.load_all()
    if rows:
        print()
        print("# roofline (from dry-run artifacts; see EXPERIMENTS.md)")
        roofline.main()


if __name__ == "__main__":
    main()
