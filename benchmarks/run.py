"""Benchmark harness: one function per paper claim. Prints
``name,us_per_call,derived`` CSV plus the sweep-cost table, writes the
machine-readable ``BENCH_core.json`` at the repo root (the perf trajectory
artifact — one snapshot per PR), then the roofline table if dry-run
artifacts exist.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--out PATH]
  --quick: kernel smoke + reduced sweep-cost only (CI smoke; still writes
           BENCH_core.json, flagged quick=true).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_core.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(_DEFAULT_OUT))
    args = ap.parse_args()

    # previous record = the regression baseline for the online-path gate
    baseline = {}
    if os.path.exists(args.out):
        try:
            with open(args.out) as f:
                baseline = json.load(f)
        except (ValueError, OSError):
            baseline = {}

    from benchmarks import bench_core, roofline

    rows = []
    print("name,us_per_call,derived")
    for bench in (bench_core.QUICK if args.quick else bench_core.ALL):
        for row in bench():
            rows.append(row)
            print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")

    print()
    print("# kernel roofline (analytic arithmetic intensity, v5e projection)")
    roofline.print_kernel_rows(rows)

    sweep = bench_core.bench_sweep_cost(quick=args.quick)
    print()
    print("# sweep cost per panel (windowed vs full-width trailing update)")
    print("k,us_windowed,us_full,flops_windowed,flops_full")
    for p in sweep["per_panel"]:
        print(f"{p['k']},{p['us_windowed']:.1f},{p['us_full']:.1f},"
              f"{p['flops_windowed']:.3e},{p['flops_full']:.3e}")
    t = sweep["totals"]
    print(f"# sweep totals: windowed {t['us_windowed_sweep']:.0f}us, "
          f"full {t['us_full_sweep']:.0f}us, scan {t['us_scan_sweep']:.0f}us, "
          f"trailing-flop ratio {t['trailing_flop_ratio']:.2f}x")

    from benchmarks import bench_recovery

    recovery = bench_recovery.suite(quick=args.quick)
    ff = recovery["failure_free"]
    print()
    print("# recovery: failure-free overhead + REBUILD latency")
    print(f"# bundle maintenance: {ff['bundle_overhead']:.2f}x "
          f"({ff['us_sweep_no_bundles']:.0f}us -> "
          f"{ff['us_sweep_with_bundles']:.0f}us); "
          f"driver harness: {ff['driver_overhead']:.2f}x")
    print("point,us_rebuild,fetches,sources")
    for row in recovery["latency"]["by_level"] + recovery["latency"]["by_panel"]:
        pt = "-".join(str(x) for x in row["point"])
        print(f"{pt},{row['us_rebuild']:.0f},{row['fetches']},{row['sources']}")

    general = bench_core.bench_general_shapes(quick=args.quick)
    print()
    print("# general shapes: ragged (zero-padded) vs aligned sweep, same padded compute")
    print(f"# aligned {tuple(general['aligned']['shape'])}: "
          f"{general['aligned']['us']:.0f}us; "
          f"ragged {tuple(general['ragged']['shape'])} -> padded "
          f"{tuple(general['ragged']['padded_shape'])}: "
          f"{general['ragged']['us']:.0f}us; "
          f"overhead {general['overhead']:.2f}x")

    from benchmarks import bench_spmd

    spmd = bench_spmd.suite(quick=args.quick)
    print()
    print("# SPMD path (shard_map over a forced host-device mesh) vs SimComm")
    print(f"# P={spmd['P']} m_loc={spmd['m_loc']} n={spmd['n']} b={spmd['b']}: "
          f"SimComm {spmd['us_simcomm_sweep']:.0f}us/sweep (eager), "
          f"shard_map {spmd['us_spmd_sweep']:.0f}us/sweep "
          f"(+{spmd['s_spmd_compile']:.1f}s compile); "
          f"1-kill REBUILD adds {spmd['us_spmd_rebuild_delta']:.0f}us/sweep")

    from benchmarks import bench_online

    online = bench_online.suite(quick=args.quick)
    st = online["stepped"]
    print()
    print("# online path: host-orchestrated stepped sweep vs monolithic")
    print(f"# P={st['config']['P']} m_loc={st['config']['m_loc']} "
          f"n={st['config']['n']} b={st['config']['b']}: "
          f"monolithic jit {st['us_monolithic_jit']:.0f}us, "
          f"eager driver {st['us_driver_eager']:.0f}us")
    print("segment,points,us_sweep")
    for name, row in st["by_segment"].items():
        print(f"{name},{row['segment_points']},{row['us']:.0f}")
    det = online["detection"]
    print(f"# stepped(1) overhead {st['overhead_vs_driver']:.2f}x vs driver, "
          f"{st['overhead_vs_jit']:.2f}x vs jit; detect-to-recovered "
          f"{det['us_detect_to_recovered']:.0f}us "
          f"(poll {det['us_poll_avg']:.0f}us/boundary, "
          f"{det['fetches']} fetches)")

    from benchmarks import bench_elastic

    elastic = bench_elastic.suite(quick=args.quick)
    sh, sp = elastic["shrink"], elastic["speculation"]
    print()
    print("# elastic path: SHRINK continuation vs REBUILD, straggler race")
    print(f"# P={sh['config']['P']} m_loc={sh['config']['m_loc']} "
          f"n={sh['config']['n']} b={sh['config']['b']}: "
          f"REBUILD {sh['us_rebuild_mid_kill']:.0f}us, "
          f"SHRINK {sh['us_shrink_mid_kill']:.0f}us "
          f"({sh['shrink_vs_rebuild']:.2f}x); "
          f"P-1 world {sh['p_minus_1_vs_free']:.2f}x vs failure-free")
    print(f"# speculation: {sp['speculations']} races, "
          f"{sp['us_per_speculation']:.0f}us each, "
          f"{sp['speculative_vs_blocking']:.2f}x vs blocking "
          f"(straggler excess {sp['config']['excess_us_per_boundary']:.0f}"
          f"us/boundary)")

    from benchmarks import bench_serve

    serve = bench_serve.suite(quick=args.quick)
    tr, kl = serve["traffic"], serve["kill"]
    print()
    print("# serve path: continuous sweep batching (QR-as-a-service)")
    print(f"# {serve['config']['requests']} ragged requests, "
          f"{tr['resident_peak']} resident, "
          f"{tr['compiled_programs']} compiled segments: "
          f"{tr['req_per_s']:.1f} req/s "
          f"(p50 {tr['p50_ms']:.0f}ms p99 {tr['p99_ms']:.0f}ms); "
          f"mid-batch kill: {kl['req_per_s']:.1f} req/s, "
          f"{kl['tenant_rebuilds']} tenant REBUILDs, "
          f"{kl['kill_vs_free']:.2f}x; "
          f"continuous vs batched {serve['continuous_vs_batched']:.2f}x")

    from benchmarks import bench_coding

    coding = bench_coding.suite(quick=args.quick)
    ov = coding["overhead"]
    print()
    print("# coded checksum lanes: overhead-vs-f + joint-decode latency")
    print("P,f,us_sweep,overhead_vs_xor")
    for P_, world in ov["by_world"].items():
        for f_, row in world["by_f"].items():
            print(f"{P_},{f_},{row['us']:.0f},{row['overhead_vs_xor']:.2f}")
    dec = coding["decode"]
    print(f"# f=2 overhead {ov['overhead_f2_vs_xor']:.2f}x vs XOR floor; "
          f"buddy-pair joint decode {dec['us_detect_to_recovered']:.0f}us "
          f"({dec['reads']} reads)")

    from benchmarks import bench_train

    train = bench_train.suite(quick=args.quick)
    bd, pl, stp = train["boundary"], train["poll"], train["step"]
    print()
    print("# train path: optimizer-internal FT-QR inside the training step")
    print(f"# boundary ({bd['config']['boundaries']} per sweep): "
          f"sync {bd['us_sync_per_boundary']:.0f}us, "
          f"async {bd['us_async_per_boundary']:.0f}us "
          f"({bd['async_vs_sync']:.2f}x); poll: eager "
          f"{pl['us_poll_eager']:.0f}us, probe {pl['us_poll_probe']:.0f}us "
          f"({pl['probe_vs_poll']:.2f}x)")
    print(f"# step: free {stp['us_step_free']/1e3:.0f}ms, killed "
          f"{stp['us_step_killed']/1e3:.0f}ms "
          f"(REBUILD adds {stp['us_rebuild_delta']/1e3:.0f}ms, "
          f"{stp['kill_vs_free']:.2f}x), bitwise-identical losses")

    # gate BEFORE recording: a regressed measurement must not become the
    # next run's baseline (the gate would otherwise fail exactly once),
    # and a passing one is recorded with the damped-baseline floor so a
    # lucky-fast outlier cannot set a bar ordinary runs miss by noise
    ok, msg = bench_online.check_regression(online, baseline.get("online"))
    elastic_ok, elastic_msg = bench_elastic.check_regression(
        elastic, baseline.get("elastic"))
    serve_ok, serve_msg = bench_serve.check_regression(
        serve, baseline.get("serve"))
    coding_ok, coding_msg = bench_coding.check_regression(
        coding, baseline.get("coding"))
    train_ok, train_msg = bench_train.check_regression(
        train, baseline.get("train"))
    # kernels-beat-oracle gate: intra-run (compiled rows vs their oracles),
    # no baseline needed — but the verdict is recorded alongside the rows
    kernel_ok, kernel_msg = bench_core.check_kernel_regression(rows)
    record = {"schema": 1, "quick": args.quick, "rows": rows,
              "kernel_gate": {"ok": kernel_ok, "msg": kernel_msg},
              "sweep_cost": sweep, "recovery": recovery,
              "general_shapes": general, "spmd": spmd,
              "online": bench_online.baseline_to_record(
                  online, baseline.get("online")),
              "elastic": bench_elastic.baseline_to_record(
                  elastic, baseline.get("elastic")),
              "serve": bench_serve.baseline_to_record(
                  serve, baseline.get("serve")),
              "coding": bench_coding.baseline_to_record(
                  coding, baseline.get("coding")),
              "train": bench_train.baseline_to_record(
                  train, baseline.get("train"))}
    if not ok:
        record["online"] = baseline.get("online")   # keep the old baseline
        record["online_rejected"] = online          # the failing numbers
    if not elastic_ok:
        record["elastic"] = baseline.get("elastic")
        record["elastic_rejected"] = elastic
    if not serve_ok:
        record["serve"] = baseline.get("serve")
        record["serve_rejected"] = serve
    if not coding_ok:
        record["coding"] = baseline.get("coding")
        record["coding_rejected"] = coding
    if not train_ok:
        record["train"] = baseline.get("train")
        record["train_rejected"] = train
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"# wrote {args.out}")
    print(f"# online regression gate: {msg}")
    print(f"# elastic regression gate: {elastic_msg}")
    print(f"# serve regression gate: {serve_msg}")
    print(f"# coding regression gate: {coding_msg}")
    print(f"# train regression gate: {train_msg}")
    print(f"# kernel gate: {kernel_msg}")
    if not ok or not kernel_ok or not elastic_ok or not serve_ok \
            or not coding_ok or not train_ok:
        raise SystemExit(2)

    if not args.quick:
        rl = roofline.load_all()
        if rl:
            print()
            print("# roofline (from dry-run artifacts; see EXPERIMENTS.md)")
            roofline.main()


if __name__ == "__main__":
    main()
