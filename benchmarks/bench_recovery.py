"""Recovery-path benchmarks for the FT sweep driver (paper's two cost claims).

(a) *Failure-free overhead*: maintaining the recovery bundles must not
    significantly lengthen the critical path — measured as the jitted
    windowed sweep with vs. without bundle collection, plus the level-stepped
    driver's orchestration overhead on top of the jitted sweep (the driver is
    the eager failure-injection harness, not the production hot path — the
    gap quantifies what the level checkpoints cost in the simulator).

(b) *Recovery latency*: wall time of one REBUILD as a function of (i) the
    tree level the lane died at (deeper trailing levels mirror more bundle
    rows) and (ii) the panel it died at (later panels replay more completed
    panels from the re-read initial slice).

Standalone: ``PYTHONPATH=src python -m benchmarks.bench_recovery``;
``benchmarks/run.py`` appends the record to ``BENCH_core.json`` under the
``"recovery"`` key.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_core import _time
from repro.core import SimComm, caqr_factorize
from repro.ft import FailureSchedule, ft_caqr_sweep, sweep_point


def _config(quick: bool):
    return (4, 32, 128, 16) if quick else (8, 64, 256, 32)


def bench_failure_free(quick: bool = False) -> Dict:
    """(a) bundle maintenance + driver orchestration overhead, failure-free."""
    P, m_loc, n, b = _config(quick)
    comm = SimComm(P)
    rng = np.random.default_rng(11)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)

    plain = jax.jit(
        lambda a: caqr_factorize(a, comm, b, use_scan=False).R
    )
    bundled = jax.jit(
        lambda a: caqr_factorize(a, comm, b, use_scan=False,
                                 collect_bundles=True)[:3]
    )
    us_plain = _time(plain, A, iters=3)
    us_bundled = _time(bundled, A, iters=3)
    us_driver = _time(lambda a: ft_caqr_sweep(a, comm, b).R, A, iters=3)
    return {
        "config": {"P": P, "m_loc": m_loc, "n": n, "b": b, "quick": quick},
        "us_sweep_no_bundles": us_plain,
        "us_sweep_with_bundles": us_bundled,
        "bundle_overhead": us_bundled / max(us_plain, 1e-9),
        "us_driver_failure_free": us_driver,
        "driver_overhead": us_driver / max(us_plain, 1e-9),
    }


def bench_latency(quick: bool = False) -> Dict:
    """(b) REBUILD latency vs. tree level (fixed mid panel) and vs. panel
    (fixed last trailing level). ``elapsed_s`` comes from the driver's own
    per-event clock (blocks on the patched state)."""
    P, m_loc, n, b = _config(quick)
    comm = SimComm(P)
    levels = P.bit_length() - 1
    n_panels = n // b
    rng = np.random.default_rng(12)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    lane = P - 1  # active at every panel of a square/tall sweep
    k_mid = n_panels // 2

    def one(point) -> Dict:
        # two runs: the first pays the jit compiles of the recovery shapes,
        # the second measures the steady-state REBUILD
        for _ in range(2):
            res = ft_caqr_sweep(
                A, comm, b, schedule=FailureSchedule(events={point: [lane]})
            )
        (event,) = res.events
        return {
            "point": list(point),
            "us_rebuild": event.elapsed_s * 1e6,
            "fetches": len(event.reads),
            "sources": len(event.sources),
        }

    by_level = [one(sweep_point(k_mid, ph, s))
                for ph in ("tsqr", "trailing") for s in range(levels)]
    ks = sorted({0, k_mid, n_panels - 1})
    by_panel = [one(sweep_point(k, "trailing", levels - 1)) for k in ks]
    return {
        "config": {"P": P, "m_loc": m_loc, "n": n, "b": b, "lane": lane,
                   "quick": quick},
        "by_level": by_level,
        "by_panel": by_panel,
    }


def suite(quick: bool = False) -> Dict:
    return {
        "failure_free": bench_failure_free(quick),
        "latency": bench_latency(quick),
    }


def main() -> None:
    import json

    print(json.dumps(suite(quick=False), indent=1))


if __name__ == "__main__":
    main()
