"""SPMD-path benchmark: SimComm vs shard_map FT sweep + REBUILD cost.

The production path needs a multi-device platform, and jax locks the device
count at first init — so the measurement runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=P`` and reports JSON on
stdout; this module spawns it and folds the result into the ``spmd``
section of ``BENCH_core.json``.

What is measured (per geometry):

* ``us_simcomm_sweep``  — eager SimComm ``ft_caqr_sweep`` wall time (the
  simulator's level-stepped dispatch, what tests pay);
* ``us_spmd_sweep``     — one post-compile call of the jitted shard_map
  sweep (the production execution: whole sweep one program);
* ``s_spmd_compile``    — trace+compile time of that program (paid once);
* ``us_spmd_rebuild_delta`` — extra per-call time of the same compiled
  sweep with one mid-sweep kill + REBUILD traced in, vs failure-free: the
  SPMD REBUILD cost (the paper's recovery-overhead claim on the real path).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from typing import Dict

_SUBPROCESS = """
    import json, time
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import AxisComm, SimComm
    from repro.dist import compat
    from repro.ft import FailureSchedule, ft_caqr_sweep, sweep_point
    from repro.ft.driver import FTSweepDriver
    from repro.launch.spmd_qr import ft_caqr_sweep_spmd, make_lane_mesh

    P_, m_loc, n, b, reps = {P}, {m_loc}, {n}, {b}, {reps}
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((P_ * m_loc, n)), jnp.float32)
    A_sim = A.reshape(P_, m_loc, n)
    mesh = make_lane_mesh(P_)
    kill = FailureSchedule(
        events={{sweep_point(1, "trailing", 0): [P_ - 1]}})

    def timed_spmd(sched):
        # build the compiled whole-sweep program once (the wrapper re-jits
        # per call so events stay fresh; here we time the compiled function)
        def body(A_local):
            res = FTSweepDriver(
                A_local, AxisComm("qr"), b, sched).run()
            return res.R
        mapped = compat.shard_map(
            body, mesh, in_specs=P("qr", None), out_specs=P(None))
        t0 = time.perf_counter()
        with compat.set_mesh(mesh):
            fn = jax.jit(mapped)
            fn(A).block_until_ready()
            compile_s = time.perf_counter() - t0
            times = []
            for _ in range(reps):
                t0 = time.perf_counter()
                fn(A).block_until_ready()
                times.append(time.perf_counter() - t0)
        # median: the REBUILD delta is small vs whole-sweep jitter
        times.sort()
        return compile_s, times[len(times) // 2] * 1e6

    # eager SimComm sweep (warm once for kernel jits)
    ft_caqr_sweep(A_sim, SimComm(P_), b).R.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        ft_caqr_sweep(A_sim, SimComm(P_), b).R.block_until_ready()
    us_sim = (time.perf_counter() - t0) / reps * 1e6

    compile_free, us_free = timed_spmd(None)
    compile_kill, us_kill = timed_spmd(kill)

    print("BENCH_JSON " + json.dumps({{
        "P": P_, "m_loc": m_loc, "n": n, "b": b, "reps": reps,
        "us_simcomm_sweep": us_sim,
        "us_spmd_sweep": us_free,
        "s_spmd_compile": compile_free,
        "us_spmd_sweep_with_rebuild": us_kill,
        "us_spmd_rebuild_delta": us_kill - us_free,
        "s_spmd_compile_with_rebuild": compile_kill,
    }}))
"""


def suite(quick: bool = False) -> Dict:
    """Run the SPMD benchmark subprocess; returns the ``spmd`` record."""
    P, m_loc, n, b, reps = (4, 16, 32, 4, 15) if quick else (4, 32, 64, 8, 25)
    code = textwrap.dedent(_SUBPROCESS).format(
        P=P, m_loc=m_loc, n=n, b=b, reps=reps)
    env = {**os.environ,
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={P}",
           "PYTHONPATH": "src"}
    r = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=1800,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    if r.returncode != 0:
        raise RuntimeError(
            f"spmd benchmark subprocess failed:\n{r.stdout}\n{r.stderr}")
    for line in r.stdout.splitlines():
        if line.startswith("BENCH_JSON "):
            rec = json.loads(line[len("BENCH_JSON "):])
            rec["quick"] = quick
            return rec
    raise RuntimeError(f"no BENCH_JSON line in output:\n{r.stdout}")


if __name__ == "__main__":
    print(json.dumps(suite(quick="--quick" in sys.argv), indent=1))
