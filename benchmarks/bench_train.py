"""Training-runtime benchmarks: what optimizer-internal FT-QR costs a
training step, and what the async double-buffered segment path buys back.

(a) *Boundary cost, sync vs async*: the engine's sweep boundaries each pay
    a detector poll plus segment dispatch. With ``async_segments=True`` the
    orchestrator dispatches the NEXT segment speculatively before the
    boundary's poll result arrives, overlapping dispatch with detection;
    the non-blocking probe collapses the poll itself to one compiled
    dispatch. Measured as per-boundary wall time over a full
    ``orthonormalize`` sweep, interleaved sync/async so box drift cancels.
    The gate demands async strictly cheaper than sync per boundary.

(b) *Poll cost, eager vs probe*: the eager ``NaNSentinelDetector.poll``
    (one host sync per per-lane sentinel read) vs the compiled
    ``probe``/``collect`` pair (a single fused reduction dispatch).

(c) *Step cost, free vs killed*: an FT training step whose optimizer-
    internal sweep loses a lane pays one REBUILD; measured as the killed
    step's wall time against the same step of a failure-free run.

``benchmarks/run.py`` stores the record under ``BENCH_core.json``'s
``"train"`` key, gates BEFORE recording (a regressed run never becomes the
next baseline), and floors the recorded gated ratio at 90% of the previous
baseline so one lucky-fast run cannot ratchet the bar below noise.
``CI_ALLOW_TRAIN_REGRESSION=1`` acknowledges a known regression.
"""
from __future__ import annotations

import os
import statistics
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# gated ratios may regress this much over the recorded baseline before CI
# fails (the async-vs-sync and probe-vs-poll gates are intra-run and
# absolute: async/probe must simply win)
REGRESSION_TOLERANCE = 1.25
# measurement methodology version: bump when the meaning of a gated number
# changes, so the gate re-records instead of comparing incomparables
_METHOD = 1


def _wall_once(fn) -> float:
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(jax.tree_util.tree_leaves(out))
    return (time.perf_counter() - t0) * 1e6


def _wall(fn, reps: int) -> float:
    return min(_wall_once(fn) for _ in range(reps))


def bench_boundary_cost(quick: bool = False) -> Dict:
    """(a): per-boundary sweep cost, sync vs async double-buffered."""
    from repro.train.ftrun import QREngine

    P, pw, (m, n) = (4, 16, (128, 64)) if quick else (4, 16, (256, 128))
    rng = np.random.default_rng(31)
    M = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    reps = 3 if quick else 5

    def sweep(asynch: bool):
        eng = QREngine(n_lanes=P, panel_width=pw, async_segments=asynch)
        eng.orthonormalize(M)
        return eng

    # compile both paths (segment programs are cached process-wide)
    eng_s, eng_a = sweep(False), sweep(True)
    boundaries = eng_s.boundaries
    assert eng_a.boundaries == boundaries, "async ran a different sweep"

    us_sync = _wall(lambda: sweep(False), reps)
    us_async = _wall(lambda: sweep(True), reps)
    # interleaved ratio: each rep measures async and sync back to back, so
    # slow drift of the box inflates both sides and cancels
    ratio = statistics.median(
        _wall_once(lambda: sweep(True)) / max(_wall_once(lambda: sweep(False)), 1e-9)
        for _ in range(reps)
    )
    return {
        "method": _METHOD,
        "config": {"P": P, "panel_width": pw, "m": m, "n": n, "quick": quick,
                   "boundaries": boundaries},
        "us_sync_sweep": us_sync,
        "us_async_sweep": us_async,
        "us_sync_per_boundary": us_sync / boundaries,
        "us_async_per_boundary": us_async / boundaries,
        "async_vs_sync": ratio,
    }


def bench_poll_cost(quick: bool = False) -> Dict:
    """(b): one detector check, eager poll vs compiled probe/collect."""
    from repro.core import SimComm
    from repro.core.caqr import block_row_layout
    from repro.ft.online.detect import NaNSentinelDetector
    from repro.ft.online.state import initial_sweep_state

    P, pw, (m, n) = (4, 16, (128, 64)) if quick else (8, 16, (256, 128))
    comm = SimComm(P)
    rng = np.random.default_rng(32)
    M = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    st = initial_sweep_state(comm, block_row_layout(M, P), pw)
    reps = 20 if quick else 50

    det_poll, det_probe = NaNSentinelDetector(), NaNSentinelDetector()
    det_poll.poll(comm, st)                                    # warm
    det_probe.collect(comm, det_probe.probe(comm, st))         # compile
    us_poll = _wall(lambda: det_poll.poll(comm, st), reps)
    us_probe = _wall(
        lambda: det_probe.collect(comm, det_probe.probe(comm, st)), reps)
    return {
        "config": {"P": P, "panel_width": pw, "m": m, "n": n, "quick": quick},
        "us_poll_eager": us_poll,
        "us_poll_probe": us_probe,
        "probe_vs_poll": us_probe / max(us_poll, 1e-9),
    }


def bench_step_cost(quick: bool = False) -> Dict:
    """(c): FT training step wall time, failure-free vs a lane killed
    inside the step's optimizer-internal sweep (one REBUILD)."""
    from repro.configs import get_smoke
    from repro.data.pipeline import DataConfig
    from repro.ft.semantics import Semantics
    from repro.train.loop import TrainConfig
    from repro.train.ftrun import FTTrainer, StepSweepKiller

    steps = 3 if quick else 4
    kill_step = 1
    cfg = get_smoke("tinyllama-1.1b")
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=1)
    tcfg = TrainConfig(steps=steps, lr=1e-2, warmup=2, n_lanes=4,
                       diskless_every=steps + 1, log_every=10_000,
                       semantics=Semantics.REBUILD, optimizer="caqr_muon")

    free = FTTrainer(cfg, tcfg, dcfg)
    hist_free = free.run()
    killer = StepSweepKiller(at_step=kill_step, lane=2)
    killed = FTTrainer(cfg, tcfg, dcfg, qr_fault_hooks=[killer])
    hist_kill = killed.run()
    assert killer.fired, "the kill never landed inside the optimizer sweep"
    assert [h["loss"] for h in hist_free] == [h["loss"] for h in hist_kill], \
        "killed run is not bitwise-identical to failure-free"

    us_free = hist_free[kill_step]["dt"] * 1e6
    us_kill = hist_kill[kill_step]["dt"] * 1e6
    # steady-state floor: the cheapest post-compile step of the free run
    us_steady = min(h["dt"] for h in hist_free[1:]) * 1e6
    return {
        "config": {"steps": steps, "kill_step": kill_step, "quick": quick},
        "us_step_free": us_free,
        "us_step_killed": us_kill,
        "us_step_steady": us_steady,
        "us_rebuild_delta": us_kill - us_free,
        "kill_vs_free": us_kill / max(us_free, 1e-9),
    }


def suite(quick: bool = False) -> Dict:
    return {
        "boundary": bench_boundary_cost(quick),
        "poll": bench_poll_cost(quick),
        "step": bench_step_cost(quick),
    }


def check_regression(train: Dict, baseline: Optional[Dict]) -> Tuple[bool, str]:
    """Gate for ``run.py``/``ci.sh``. Two intra-run absolutes — the async
    double-buffered path must be strictly cheaper per boundary than sync,
    and the compiled probe must beat the eager poll — plus a baseline gate
    on the per-boundary sync cost (same quick-tier only).
    ``CI_ALLOW_TRAIN_REGRESSION=1`` acknowledges a failure without
    greening it."""
    allow = os.environ.get("CI_ALLOW_TRAIN_REGRESSION") == "1"
    av = train["boundary"]["async_vs_sync"]
    pv = train["poll"]["probe_vs_poll"]
    if av >= 1.0:
        msg = (f"async segments are NOT cheaper than sync per boundary "
               f"({av:.2f}x, must be < 1.0)")
        return (True, msg + " — acknowledged via CI_ALLOW_TRAIN_REGRESSION=1"
                ) if allow else (False, msg)
    if pv >= 1.0:
        msg = (f"compiled probe is NOT cheaper than the eager poll "
               f"({pv:.2f}x, must be < 1.0)")
        return (True, msg + " — acknowledged via CI_ALLOW_TRAIN_REGRESSION=1"
                ) if allow else (False, msg)
    got = train["boundary"]["us_sync_per_boundary"]
    if not baseline:
        return True, (f"train async {av:.2f}x, probe {pv:.2f}x, boundary "
                      f"{got:.0f}us (no baseline recorded yet)")
    base_b = baseline.get("boundary", {})
    comparable = (base_b.get("config", {}).get("quick")
                  == train["boundary"]["config"]["quick"]
                  and base_b.get("method") == train["boundary"]["method"])
    if not comparable:
        return True, (f"train async {av:.2f}x, probe {pv:.2f}x (baseline "
                      "from the other tier/method; not comparable)")
    base = base_b["us_sync_per_boundary"]
    if got <= base * REGRESSION_TOLERANCE:
        return True, (f"train async {av:.2f}x, probe {pv:.2f}x, boundary "
                      f"{got:.0f}us vs baseline {base:.0f}us: OK")
    msg = (f"train per-boundary cost REGRESSED: {got:.0f}us vs baseline "
           f"{base:.0f}us (> {REGRESSION_TOLERANCE:.2f}x tolerance)")
    if allow:
        return True, msg + " — acknowledged via CI_ALLOW_TRAIN_REGRESSION=1"
    return False, msg


def baseline_to_record(train: Dict, baseline: Optional[Dict]) -> Dict:
    """What a passing run persists: the fresh measurement with the gated
    per-boundary cost floored at 90% of the previous comparable baseline
    (one lucky-fast run cannot set a bar ordinary runs miss by noise)."""
    import copy

    rec = copy.deepcopy(train)
    if not baseline:
        return rec
    base_b = baseline.get("boundary", {})
    comparable = (base_b.get("config", {}).get("quick")
                  == train["boundary"]["config"]["quick"]
                  and base_b.get("method") == train["boundary"]["method"])
    if comparable:
        rec["boundary"]["us_sync_per_boundary"] = max(
            train["boundary"]["us_sync_per_boundary"],
            base_b["us_sync_per_boundary"] * 0.9,
        )
    return rec


def main() -> None:
    import json

    print(json.dumps(suite(quick=False), indent=1))


if __name__ == "__main__":
    main()
