"""Serve-path benchmarks: what multi-tenant continuous sweep batching
costs, with and without a mid-batch lane kill.

A seeded synthetic heavy-traffic generator drives ragged factorization /
least-squares requests through ``repro.serve.qr_service.QRService`` — a
resident batch of >= 8 concurrent tenants multiplexed through the ONE
resident compiled ``sweep_step`` segment runner. Reported:

(a) *Sustained traffic*: requests/sec and per-request latency p50/p99 over
    a full drain (submission -> retirement, queue wait included).
(b) *Kill under load*: the same traffic with a lane killed mid-batch —
    every resident tenant REBUILDs from its XOR buddies and still retires
    the bitwise failure-free R (asserted here, not just claimed). The
    kill:free wall ratio is the recovery-under-load overhead.
(c) *Continuous vs static batching*: the gated headline — continuous
    (per-panel slots, admission machinery, per-boundary detector polls)
    vs the express ``drain_batched`` path (one vmapped sweep per bucket).
    Measured as a median of interleaved ratios so box drift cancels
    (the ``bench_online`` methodology).

``benchmarks/run.py`` stores the record under ``BENCH_core.json``'s
``"serve"`` key and fails CI loudly (``check_regression``) if the
continuous-batching overhead regresses more than 25% over the recorded
baseline. ``CI_ALLOW_SERVE_REGRESSION=1`` acknowledges a known regression
without greening it.
"""
from __future__ import annotations

import os
import statistics
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import SimComm, block_row_layout, caqr_factorize
from repro.serve.qr_service import QRService

REGRESSION_TOLERANCE = 1.25
_METHOD = 1


def _config(quick: bool) -> Dict:
    return {
        "P": 4, "b": 4, "quick": quick,
        "bucket": (8, 12) if quick else (16, 20),
        "requests": 8 if quick else 24,
        "slots": 8,
        "lstsq_frac": 0.25,
        "kill_lane": 2,
        "kill_tick": 2,
    }


def _traffic(cfg: Dict) -> List[Tuple[np.ndarray, Optional[np.ndarray]]]:
    rng = np.random.default_rng(31)
    m_loc, n_b = cfg["bucket"]
    reqs = []
    for i in range(cfg["requests"]):
        m = int(rng.integers(cfg["b"], cfg["P"] * m_loc + 1))
        n = int(rng.integers(cfg["b"], n_b - 1))
        A = rng.standard_normal((m, n)).astype(np.float32)
        rhs = None
        if m >= n and rng.random() < cfg["lstsq_frac"]:
            rhs = rng.standard_normal((m, 2)).astype(np.float32)
        reqs.append((A, rhs))
    return reqs


def _service(comm, cfg: Dict) -> QRService:
    return QRService(comm, panel_width=cfg["b"], buckets=[cfg["bucket"]],
                     max_slots=cfg["slots"])


def _drive(comm, cfg: Dict, traffic, kill: bool) -> Tuple[float, QRService, int]:
    """One full traffic drain; returns (wall_s, service, peak_resident)."""
    svc = _service(comm, cfg)
    t0 = time.perf_counter()
    for A, rhs in traffic:
        svc.submit(A, rhs)
    peak = 0
    killed = False
    while svc.queue or svc.resident:
        if kill and not killed and svc.tick_count == cfg["kill_tick"]:
            svc.kill_lane(cfg["kill_lane"])
            killed = True
        svc.tick()
        peak = max(peak, svc.resident)
    return time.perf_counter() - t0, svc, peak


def _percentiles(svc: QRService) -> Dict:
    lat = np.sort([r.latency_s for r in svc.results.values()])
    return {
        "p50_ms": float(lat[len(lat) // 2] * 1e3),
        "p99_ms": float(lat[min(len(lat) - 1, int(len(lat) * 0.99))] * 1e3),
    }


def _assert_bitwise_solo(comm, cfg: Dict, svc: QRService, traffic) -> None:
    """The acceptance criterion, enforced in-bench: every tenant's R is
    bitwise-identical to its failure-free solo factorization (same
    bucket-padded matrix)."""
    import jax.numpy as jnp

    # rids are assigned in submission order by the service's own counter
    for rid, (A, rhs) in zip(
            (f"req{i}" for i in range(len(traffic))), traffic):
        A_aug = A if rhs is None else np.concatenate([A, rhs], axis=1)
        A0 = block_row_layout(jnp.asarray(A_aug), cfg["P"], *cfg["bucket"])
        solo = caqr_factorize(A0, comm, cfg["b"], use_scan=False,
                              collect_bundles=True)
        k, n = min(A.shape), A.shape[1]
        got, ref = svc.results[rid].R, np.asarray(solo.R[0])[:k, :n]
        assert np.array_equal(got, ref), (
            f"{rid}: served R diverged from the solo factorization "
            f"(max err {np.abs(got - ref).max():.2e})")


def suite(quick: bool = False) -> Dict:
    cfg = _config(quick)
    comm = SimComm(cfg["P"])
    traffic = _traffic(cfg)
    reps = 2 if quick else 3

    # warmup: one drain compiles every (bucket, cursor) segment + the
    # rebuild shapes of the kill path; steady-state traffic compiles nothing
    _drive(comm, cfg, traffic, kill=True)
    warm_programs = _service(comm, cfg).compiled_programs

    best = None
    for _ in range(reps):
        w, svc, pk = _drive(comm, cfg, traffic, kill=False)
        if best is None or w < best[0]:
            best = (w, svc, pk)
    wall_free, svc_free, peak = best
    assert peak >= min(cfg["requests"], cfg["slots"]), (
        f"resident batch never reached {cfg['slots']} ({peak})")
    assert _service(comm, cfg).compiled_programs == warm_programs, (
        "steady-state traffic recompiled the resident segment runner")
    _assert_bitwise_solo(comm, cfg, svc_free, traffic)

    best_k = None
    for _ in range(reps):
        w, svc, _pk = _drive(comm, cfg, traffic, kill=True)
        if best_k is None or w < best_k[0]:
            best_k = (w, svc)
    wall_kill, svc_kill = best_k
    heals = sum(len(r.events) for r in svc_kill.results.values())
    assert heals >= 1, "the mid-batch kill was never detected/healed"
    _assert_bitwise_solo(comm, cfg, svc_kill, traffic)

    def batched_drain() -> float:
        svc = _service(comm, cfg)
        t0 = time.perf_counter()
        for A, rhs in traffic:
            svc.submit(A, rhs)
        svc.drain_batched()
        return time.perf_counter() - t0

    batched_drain()  # compile the vmapped bucket program
    # the gated ratio: continuous machinery vs the express static batch,
    # interleaved so box drift inflates both sides of a pair and cancels
    ratios = []
    for _ in range(reps):
        w_c, _svc, _pk = _drive(comm, cfg, traffic, kill=False)
        ratios.append(w_c / max(batched_drain(), 1e-9))
    overhead = statistics.median(ratios)

    n_req = cfg["requests"]
    return {
        "method": _METHOD,
        "config": cfg,
        "traffic": {
            "req_per_s": n_req / wall_free,
            "resident_peak": peak,
            "ticks": svc_free.tick_count,
            "compiled_programs": warm_programs,
            **_percentiles(svc_free),
        },
        "kill": {
            "req_per_s": n_req / wall_kill,
            "tenant_rebuilds": heals,
            "kill_vs_free": wall_kill / max(wall_free, 1e-9),
            **_percentiles(svc_kill),
        },
        "continuous_vs_batched": overhead,
    }


def check_regression(serve: Dict, baseline: Optional[Dict]) -> Tuple[bool, str]:
    """Gate for ``run.py``/``ci.sh``: the continuous-batching overhead must
    stay within ``REGRESSION_TOLERANCE`` of the recorded baseline (same
    quick tier + method only). First run records and passes.
    ``CI_ALLOW_SERVE_REGRESSION=1`` acknowledges without greening."""
    got = serve["continuous_vs_batched"]
    if not baseline:
        return True, f"serve overhead {got:.2f}x (no baseline recorded yet)"
    if baseline.get("config", {}).get("quick") != serve["config"]["quick"]:
        return True, (f"serve overhead {got:.2f}x (baseline is from the "
                      "other tier; not comparable)")
    if baseline.get("method") != serve["method"]:
        return True, (f"serve overhead {got:.2f}x (baseline predates the "
                      "current measurement methodology; re-recording)")
    base = baseline["continuous_vs_batched"]
    if got <= base * REGRESSION_TOLERANCE:
        return True, f"serve overhead {got:.2f}x vs baseline {base:.2f}x: OK"
    msg = (f"serve continuous-batching overhead REGRESSED: {got:.2f}x vs "
           f"baseline {base:.2f}x (> {REGRESSION_TOLERANCE:.2f}x tolerance)")
    if os.environ.get("CI_ALLOW_SERVE_REGRESSION") == "1":
        return True, msg + " — acknowledged via CI_ALLOW_SERVE_REGRESSION=1"
    return False, msg


def baseline_to_record(serve: Dict, baseline: Optional[Dict]) -> Dict:
    """What a passing run persists: the fresh measurement with the gated
    ratio floored at 90% of the previous comparable baseline (the damped
    walk-down of ``bench_online``)."""
    import copy

    rec = copy.deepcopy(serve)
    if not baseline:
        return rec
    comparable = (
        baseline.get("config", {}).get("quick") == serve["config"]["quick"]
        and baseline.get("method") == serve["method"]
    )
    if comparable:
        rec["continuous_vs_batched"] = max(
            serve["continuous_vs_batched"],
            baseline["continuous_vs_batched"] * 0.9,
        )
    return rec


def main() -> None:
    import json

    print(json.dumps(suite(quick=False), indent=1))


if __name__ == "__main__":
    main()
