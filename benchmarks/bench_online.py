"""Online-path benchmarks: what host-controlled stepped execution costs.

(a) *Stepped overhead*: the orchestrator runs the sweep as compiled
    ``sweep_step`` segments with a detector poll at every boundary, instead
    of one monolithic program. Measured against two floors — the fully
    jitted windowed sweep (one compiled program, no host in the loop) and
    the eager scheduled driver (the previous execution model, a host loop
    without segment compilation or polling).

(b) *Segment-size sensitivity*: boundaries per compiled segment trade
    dispatch/poll overhead against detection latency; the sweep is timed at
    segment sizes 1 (poll every point), one tree phase, one whole panel,
    and the entire sweep (a single segment — no mid-sweep detection).

(c) *Detection-to-recovered latency*: wall time from the NaN-sentinel poll
    that discovers a mid-sweep death to the fully rebuilt state (the
    orchestrator's per-event clock), plus the steady-state cost of one
    detector poll.

``benchmarks/run.py`` stores the record under ``BENCH_core.json``'s
``"online"`` key and fails CI loudly (``check_regression``) if the
segment-1 stepped overhead regresses more than 25% over the previously
recorded baseline — the stepped path is the north-star execution model and
must not silently rot.
"""
from __future__ import annotations

import os
import statistics
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SimComm, caqr_factorize
from repro.ft import FailureSchedule, SweepOrchestrator, ft_caqr_sweep, sweep_point
from repro.ft.online.detect import ScriptedKiller

# stepped-vs-driver overhead may regress this much before CI fails
REGRESSION_TOLERANCE = 1.25
# measurement methodology version (see bench_stepped_overhead)
_METHOD = 2


def _config(quick: bool) -> Tuple[int, int, int, int]:
    return (4, 16, 64, 8) if quick else (8, 32, 128, 16)


def _wall_once(fn) -> float:
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(jax.tree_util.tree_leaves(out))
    return (time.perf_counter() - t0) * 1e6


def _wall(fn, reps: int) -> float:
    """Min wall-clock microseconds of ``fn()`` over ``reps`` runs. The
    measured loops are host-driven, so a wall clock is the honest meter —
    and the minimum is the contention-robust statistic."""
    return min(_wall_once(fn) for _ in range(reps))


def _ratio(fn_num, fn_den, reps: int) -> float:
    """Median of per-rep ratios with *interleaved* measurement: num and den
    run back to back each rep, so slow drift of the box (load, frequency
    scaling) inflates both sides of a pair and cancels in the ratio —
    the gated overhead stays comparable across CI runs even when absolute
    wall times are not."""
    return statistics.median(
        _wall_once(fn_num) / max(_wall_once(fn_den), 1e-9)
        for _ in range(reps)
    )


def bench_stepped_overhead(quick: bool = False) -> Dict:
    """(a) + (b): orchestrator wall time vs the monolithic floors, across
    segment sizes."""
    P, m_loc, n, b = _config(quick)
    comm = SimComm(P)
    levels = P.bit_length() - 1
    n_panels = n // b
    points_total = n_panels * (1 + 2 * levels)
    rng = np.random.default_rng(21)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    reps = 5 if quick else 7

    mono = jax.jit(lambda a: caqr_factorize(
        a, comm, b, use_scan=False, collect_bundles=True)[:3])
    jax.block_until_ready(jax.tree_util.tree_leaves(mono(A)))  # compile
    us_mono_jit = _wall(lambda: mono(A), reps)
    driver = lambda: ft_caqr_sweep(A, comm, b)
    us_driver = _wall(driver, max(reps - 2, 3))

    seg_sizes = {
        "1": 1,
        "phase": levels,               # one tree phase per segment
        "panel": 1 + 2 * levels,       # one whole panel per segment
        "sweep": points_total,         # a single segment: no mid-sweep polls
    }
    by_segment = {}
    for name, sz in seg_sizes.items():
        run = lambda: SweepOrchestrator(A, comm, b, segment_points=sz).run()
        jax.block_until_ready(jax.tree_util.tree_leaves(run()))  # compile
        by_segment[name] = {"segment_points": sz, "us": _wall(run, reps)}

    stepped1 = lambda: SweepOrchestrator(A, comm, b, segment_points=1).run()
    us_seg1 = by_segment["1"]["us"]
    return {
        # bump _METHOD when the measurement methodology changes — the gate
        # then treats older baselines as incomparable instead of comparing
        # numbers that mean different things
        "method": _METHOD,
        "config": {"P": P, "m_loc": m_loc, "n": n, "b": b, "quick": quick,
                   "points": points_total},
        "us_monolithic_jit": us_mono_jit,
        "us_driver_eager": us_driver,
        "by_segment": by_segment,
        # the gated headline: stepped seg-1 vs the eager scheduled driver
        # (both host loops — the ratio isolates segment compilation +
        # polling), measured INTERLEAVED so box drift between CI runs
        # cancels out of the gated number
        "overhead_vs_driver": _ratio(stepped1, driver, max(reps - 2, 3)),
        "overhead_vs_jit": us_seg1 / max(us_mono_jit, 1e-9),
    }


def bench_detection_latency(quick: bool = False) -> Dict:
    """(c): kill a lane mid-sweep at runtime; report the poll cost and the
    detection-to-recovered wall time of the REBUILD the detector triggered."""
    P, m_loc, n, b = _config(quick)
    comm = SimComm(P)
    levels = P.bit_length() - 1
    n_panels = n // b
    rng = np.random.default_rng(22)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    point = sweep_point(n_panels // 2, "trailing", levels - 1)
    lane = P - 1

    stats = []
    for _ in range(2 if quick else 3):
        orch = SweepOrchestrator(
            A, comm, b, fault_hooks=[ScriptedKiller({point: [lane]})])
        res = orch.run()
        (event,) = res.events
        # one poll per loop iteration == one per segment on a fresh run
        boundaries = max(orch.segments_run, 1)
        stats.append({
            "us_rebuild": event.elapsed_s * 1e6,
            "us_poll_avg": orch.poll_s * 1e6 / boundaries,
        })
    # first run pays the rebuild-shape compiles; report the steady state
    steady = stats[-1]
    return {
        "config": {"P": P, "m_loc": m_loc, "n": n, "b": b,
                   "point": list(point), "lane": lane, "quick": quick},
        "us_detect_to_recovered": steady["us_rebuild"],
        "us_poll_avg": steady["us_poll_avg"],
        "fetches": len(res.events[0].reads),
    }


def suite(quick: bool = False) -> Dict:
    return {
        "stepped": bench_stepped_overhead(quick),
        "detection": bench_detection_latency(quick),
    }


def check_regression(online: Dict, baseline: Optional[Dict]) -> Tuple[bool, str]:
    """Gate for ``run.py``/``ci.sh``: the segment-1 stepped overhead must
    stay within ``REGRESSION_TOLERANCE`` of the recorded baseline (same
    quick-tier only — the geometries differ). First run (no baseline)
    records and passes. ``CI_ALLOW_ONLINE_REGRESSION=1`` acknowledges a
    known regression without greening it."""
    got = online["stepped"]["overhead_vs_driver"]
    if not baseline:
        return True, f"online overhead {got:.2f}x (no baseline recorded yet)"
    base_cfg = baseline.get("stepped", {}).get("config", {})
    if base_cfg.get("quick") != online["stepped"]["config"]["quick"]:
        return True, (f"online overhead {got:.2f}x (baseline is from the "
                      "other tier; not comparable)")
    if baseline.get("stepped", {}).get("method") != online["stepped"]["method"]:
        return True, (f"online overhead {got:.2f}x (baseline predates the "
                      "current measurement methodology; re-recording)")
    base = baseline["stepped"]["overhead_vs_driver"]
    if got <= base * REGRESSION_TOLERANCE:
        return True, f"online overhead {got:.2f}x vs baseline {base:.2f}x: OK"
    msg = (f"online stepped overhead REGRESSED: {got:.2f}x vs baseline "
           f"{base:.2f}x (> {REGRESSION_TOLERANCE:.2f}x tolerance)")
    if os.environ.get("CI_ALLOW_ONLINE_REGRESSION") == "1":
        return True, msg + " — acknowledged via CI_ALLOW_ONLINE_REGRESSION=1"
    return False, msg


def baseline_to_record(online: Dict, baseline: Optional[Dict]) -> Dict:
    """What a *passing* run persists as the next baseline: the fresh
    measurement, except the gated ratio is floored at 90% of the previous
    comparable baseline. A single lucky-fast run therefore cannot ratchet
    the bar to a level ordinary runs fail by noise; genuine improvements
    still walk the recorded baseline down, bounded at 10% per run."""
    import copy

    rec = copy.deepcopy(online)
    if not baseline:
        return rec
    base_st = baseline.get("stepped", {})
    comparable = (
        base_st.get("config", {}).get("quick")
        == online["stepped"]["config"]["quick"]
        and base_st.get("method") == online["stepped"]["method"]
    )
    if comparable:
        rec["stepped"]["overhead_vs_driver"] = max(
            online["stepped"]["overhead_vs_driver"],
            base_st["overhead_vs_driver"] * 0.9,
        )
    return rec


def main() -> None:
    import json

    print(json.dumps(suite(quick=False), indent=1))


if __name__ == "__main__":
    main()
