"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh) cell, from the compiled dry-run:

    compute term    = HLO_flops_per_device / peak_flops_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / ICI_link_bw

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Also reports MODEL_FLOPS / HLO_FLOPS (useful-compute ratio; catches remat and
dispatch waste) and names the dominant term with a one-line lever.
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List

PEAK_FLOPS = 197e12     # bf16 / chip (2-flops-per-MAC convention)
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s / link (per direction)

# XLA's HLO cost analysis counts dot flops as MACs (1 per multiply-add);
# the peak constant above uses the 2-flops-per-MAC convention. Calibrated on
# pure-GEMM cells (gemma-7b prefill, caqr): ratio converges to ~1.0 with x2.
HLO_FLOPS_CALIBRATION = 2.0

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def analyze(rec: Dict) -> Dict:
    chips = rec["n_chips"]
    flops_dev = rec["cost"]["flops_per_device"] * HLO_FLOPS_CALIBRATION
    bytes_dev = rec["cost"]["bytes_per_device"]
    coll_dev = rec.get("collectives", {}).get("total_bytes", 0.0)

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    total_hlo_flops = flops_dev * chips
    model_flops = rec.get("model_flops_global", 0.0)
    useful = model_flops / total_hlo_flops if total_hlo_flops else 0.0
    bound = max(terms.values())
    # roofline fraction: useful model flops per second at the bound vs peak
    achievable = model_flops / chips / bound if bound else 0.0
    frac = achievable / PEAK_FLOPS if bound else 0.0

    lever = {
        "compute": "reduce non-useful flops (remat policy, dispatch padding, "
                   "masked attention work)",
        "memory": "increase arithmetic intensity (fuse ops, larger tiles, "
                  "bf16 intermediates, avoid activation round-trips)",
        "collective": "re-shard to cut gathered bytes (2D sharding, "
                      "overlap collectives with compute, compress or "
                      "reduce-scatter instead of all-reduce)",
    }[dominant]
    return {
        "cell": f"{rec['arch']} x {rec['shape']} x {rec['mesh']}",
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "useful_flop_ratio": useful,
        "roofline_fraction": frac,
        "peak_gib": rec["memory"].get("peak_bytes_analytic", rec["memory"]["peak_bytes_est"]) / 2**30,
        "lever": lever,
    }


def load_all(dryrun_dir: str = DRYRUN_DIR) -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(f))
        if rec.get("status") != "ok":
            continue
        rows.append(analyze(rec))
    return rows


def main() -> None:
    rows = load_all(sys.argv[1] if len(sys.argv) > 1 else DRYRUN_DIR)
    if not rows:
        print("no dry-run artifacts found; run python -m repro.launch.dryrun --all")
        return
    hdr = (f"{'cell':52s} {'compute':>9s} {'memory':>9s} {'collect':>9s} "
           f"{'dominant':>10s} {'useful':>7s} {'roofline':>9s} {'GiB':>6s}")
    print(hdr)
    for r in rows:
        print(f"{r['cell']:52s} {r['t_compute_s']:9.4f} {r['t_memory_s']:9.4f} "
              f"{r['t_collective_s']:9.4f} {r['dominant']:>10s} "
              f"{r['useful_flop_ratio']:7.3f} {r['roofline_fraction']:9.3f} "
              f"{r['peak_gib']:6.2f}")


if __name__ == "__main__":
    main()
