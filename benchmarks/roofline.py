"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh) cell, from the compiled dry-run:

    compute term    = HLO_flops_per_device / peak_flops_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / ICI_link_bw

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Also reports MODEL_FLOPS / HLO_FLOPS (useful-compute ratio; catches remat and
dispatch waste) and names the dominant term with a one-line lever.

``kernel_rows`` ingests the structured kernel rows from
``benchmarks.bench_core.bench_kernels`` (an analytic flops/bytes model per
op cell) and projects each cell's arithmetic intensity against the same
roofline — the per-kernel dominant-term lever for the fast path.
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List

PEAK_FLOPS = 197e12     # bf16 / chip (2-flops-per-MAC convention)
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s / link (per direction)

# XLA's HLO cost analysis counts dot flops as MACs (1 per multiply-add);
# the peak constant above uses the 2-flops-per-MAC convention. Calibrated on
# pure-GEMM cells (gemma-7b prefill, caqr): ratio converges to ~1.0 with x2.
HLO_FLOPS_CALIBRATION = 2.0

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

LEVERS = {
    "compute": "reduce non-useful flops (remat policy, dispatch padding, "
               "masked attention work)",
    "memory": "increase arithmetic intensity (fuse ops, larger tiles, "
              "bf16 intermediates, avoid activation round-trips)",
    "collective": "re-shard to cut gathered bytes (2D sharding, "
                  "overlap collectives with compute, compress or "
                  "reduce-scatter instead of all-reduce)",
}


def analyze(rec: Dict) -> Dict:
    chips = rec["n_chips"]
    flops_dev = rec["cost"]["flops_per_device"] * HLO_FLOPS_CALIBRATION
    bytes_dev = rec["cost"]["bytes_per_device"]
    coll_dev = rec.get("collectives", {}).get("total_bytes", 0.0)

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    total_hlo_flops = flops_dev * chips
    model_flops = rec.get("model_flops_global", 0.0)
    useful = model_flops / total_hlo_flops if total_hlo_flops else 0.0
    bound = max(terms.values())
    # roofline fraction: useful model flops per second at the bound vs peak
    achievable = model_flops / chips / bound if bound else 0.0
    frac = achievable / PEAK_FLOPS if bound else 0.0

    lever = LEVERS[dominant]
    return {
        "cell": f"{rec['arch']} x {rec['shape']} x {rec['mesh']}",
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "useful_flop_ratio": useful,
        "roofline_fraction": frac,
        "peak_gib": rec["memory"].get("peak_bytes_analytic", rec["memory"]["peak_bytes_est"]) / 2**30,
        "lever": lever,
    }


# -- kernel-bench ingestion ---------------------------------------------------
#
# The structured kernel rows from benchmarks/bench_core.bench_kernels carry
# (op, shape, dtype, measured us). Per cell we attach an analytic cost model
# (2-flops-per-MAC convention, minimal HBM traffic: operands in + results
# out once — the fused kernels' whole point) and project onto the TPU v5e
# roofline above: arithmetic intensity vs the ridge names the dominant term
# and its lever. The measured rate is the *host* microbenchmark rate — it
# validates the algorithm, not the TPU projection.


def _kernel_cost(op: str, shape, dtype: str):
    """(flops, min_bytes) for one kernel cell. Shapes are the bench
    geometries: panel_qr (m, b); stacked_qr (b,); wy_apply (m, b, n);
    stacked_apply (b, n); fused_sweep (P, m_loc, n, b)."""
    s = 2 if dtype == "bfloat16" else 4
    if op == "panel_qr":
        m, b = shape
        # column loop 4mb^2 + Gram 2mb^2 + T recurrence 2b^3
        return 6.0 * m * b * b + 2.0 * b ** 3, s * (2.0 * m * b + 2.0 * b * b)
    if op == "stacked_qr":
        (b,) = shape
        # panel_qr cost at (2b, b)
        return 14.0 * b ** 3, s * 5.0 * b * b
    if op == "wy_apply":
        m, b, n = shape
        return 4.0 * m * b * n + 2.0 * b * b * n, \
            s * (2.0 * m * n + m * b + b * b)
    if op == "stacked_apply":
        b, n = shape
        return 6.0 * b * b * n, s * (5.0 * b * n + 2.0 * b * b)
    if op == "fused_sweep":
        P, m_loc, n, b = shape
        levels = max(P.bit_length() - 1, 1)
        leaf = 6.0 * m_loc * b * b + 2.0 * b ** 3          # panel QR
        apply_ = 4.0 * m_loc * b * n + 2.0 * b * b * n     # WY window apply
        tree = levels * (14.0 * b ** 3 + 6.0 * b * b * n)  # combines
        # one window pass in + out is the fused path's traffic floor
        return P * (leaf + apply_ + tree), s * P * 2.0 * m_loc * n
    return 0.0, 0.0


def kernel_rows(bench_rows: List[Dict]) -> List[Dict]:
    """Roofline view of the structured kernel bench rows (rows whose name
    starts with ``kernel_``); rows without a known cost model are skipped."""
    out = []
    for r in bench_rows:
        if not r.get("name", "").startswith("kernel_"):
            continue
        op = r["name"][len("kernel_"):].replace("_bfloat16", "")
        flops, bytes_ = _kernel_cost(op, tuple(r.get("shape", ())),
                                     r.get("dtype", "float32"))
        if not flops:
            continue
        ai = flops / bytes_
        t_compute = flops / PEAK_FLOPS
        t_memory = bytes_ / HBM_BW
        dominant = "compute" if t_compute >= t_memory else "memory"
        out.append({
            "name": r["name"],
            "engine": r.get("engine"),
            "flops": flops,
            "bytes": bytes_,
            "intensity": ai,
            "ridge": PEAK_FLOPS / HBM_BW,
            "dominant": dominant,
            "host_gflops": flops / max(r["us_per_call"], 1e-9) * 1e-3,
            "speedup_vs_ref": r.get("speedup_vs_ref"),
            "lever": LEVERS[dominant],
        })
    return out


def print_kernel_rows(bench_rows: List[Dict]) -> None:
    rows = kernel_rows(bench_rows)
    if not rows:
        return
    print(f"{'cell':28s} {'engine':>9s} {'AI f/B':>8s} {'dominant':>9s} "
          f"{'host GF/s':>10s} {'vs ref':>7s}")
    for r in rows:
        print(f"{r['name']:28s} {r['engine']:>9s} {r['intensity']:8.1f} "
              f"{r['dominant']:>9s} {r['host_gflops']:10.1f} "
              f"{r['speedup_vs_ref']:6.2f}x")
    dom = max(rows, key=lambda r: r["flops"])
    print(f"# dominant cell {dom['name']}: {dom['dominant']}-bound at "
          f"AI {dom['intensity']:.1f} f/B (v5e ridge "
          f"{dom['ridge']:.0f}) — lever: {dom['lever']}")


def load_all(dryrun_dir: str = DRYRUN_DIR) -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(f))
        if rec.get("status") != "ok":
            continue
        rows.append(analyze(rec))
    return rows


def main() -> None:
    rows = load_all(sys.argv[1] if len(sys.argv) > 1 else DRYRUN_DIR)
    if not rows:
        print("no dry-run artifacts found; run python -m repro.launch.dryrun --all")
        return
    hdr = (f"{'cell':52s} {'compute':>9s} {'memory':>9s} {'collect':>9s} "
           f"{'dominant':>10s} {'useful':>7s} {'roofline':>9s} {'GiB':>6s}")
    print(hdr)
    for r in rows:
        print(f"{r['cell']:52s} {r['t_compute_s']:9.4f} {r['t_memory_s']:9.4f} "
              f"{r['t_collective_s']:9.4f} {r['dominant']:>10s} "
              f"{r['useful_flop_ratio']:7.3f} {r['roofline_fraction']:9.3f} "
              f"{r['peak_gib']:6.2f}")


if __name__ == "__main__":
    main()
