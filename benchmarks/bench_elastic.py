"""Elastic-execution benchmarks: what continuing on a shrunken world costs.

(a) *SHRINK continuation vs REBUILD*: the same mid-sweep kill, handled two
    ways — REBUILD reconstructs the dead lane and finishes on the original
    P-lane world (one compiled shape throughout), SHRINK heals, re-owns the
    rows onto a survivor at the boundary, and finishes the trailing
    submatrix as a new epoch on P-1 live lanes (harvest + re-scatter +
    fresh compiles for the adopted-row shapes). The gated headline is the
    interleaved SHRINK/REBUILD wall-time ratio.

(b) *P-1 throughput delta*: a kill at the first sweep point makes almost
    the whole factorization run post-shrink — the ratio against the
    failure-free P-lane sweep prices the lost lane plus the adoption work.

(c) *Speculative recompute vs blocking*: a persistently slow lane, two
    ways — blocking stalls every boundary by the straggler's excess
    (simulated with a host sleep), SPECULATE pays the measured cost of the
    buddy recompute race instead and never waits. Reports the win ratio at
    a declared synthetic excess.

``benchmarks/run.py`` stores the record under ``BENCH_core.json``'s
``"elastic"`` key and fails CI (``check_regression``) if the SHRINK-vs-
REBUILD continuation ratio regresses more than 25% over the recorded
baseline; ``CI_ALLOW_ELASTIC_REGRESSION=1`` acknowledges a known
regression without greening it.
"""
from __future__ import annotations

import os
import statistics
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SimComm
from repro.ft import (
    FailureSchedule,
    Semantics,
    StragglerConfig,
    StragglerMonitor,
    StragglerPolicy,
    SweepOrchestrator,
    ft_caqr_sweep,
    ft_caqr_sweep_elastic,
    sweep_point,
)
from repro.ft.online.detect import ScriptedKiller

# the SHRINK/REBUILD continuation ratio may regress this much before CI fails
REGRESSION_TOLERANCE = 1.25
_METHOD = 1


def _config(quick: bool) -> Tuple[int, int, int, int]:
    # b=4 tiles (the bitwise-stable envelope the elastic tests run at)
    return (4, 8, 32, 4) if quick else (8, 16, 64, 4)


def _wall_once(fn) -> float:
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(jax.tree_util.tree_leaves(out))
    return (time.perf_counter() - t0) * 1e6


def _wall(fn, reps: int) -> float:
    return min(_wall_once(fn) for _ in range(reps))


def _ratio(fn_num, fn_den, reps: int) -> float:
    """Median of interleaved per-rep ratios — box drift inflates both
    sides of a pair and cancels (same methodology as bench_online)."""
    return statistics.median(
        _wall_once(fn_num) / max(_wall_once(fn_den), 1e-9)
        for _ in range(reps)
    )


def bench_shrink_vs_rebuild(quick: bool = False) -> Dict:
    """(a) + (b): continuation latency of SHRINK vs REBUILD for the same
    mid-sweep kill, and the near-whole-sweep P-1 throughput delta."""
    P, m_loc, n, b = _config(quick)
    levels = P.bit_length() - 1
    n_panels = n // b
    rng = np.random.default_rng(31)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    reps = 3 if quick else 5

    mid = sweep_point(n_panels // 2, "trailing", levels - 1)
    first = sweep_point(0, "leaf")
    lane = P - 1

    def rebuild():
        return ft_caqr_sweep(A, SimComm(P), b, schedule=FailureSchedule(
            events={mid: [lane]}))

    def shrink():
        return ft_caqr_sweep_elastic(A, SimComm(P), b, schedule=FailureSchedule(
            events={mid: [lane]}), semantics=Semantics.SHRINK)

    def shrink_first():
        return ft_caqr_sweep_elastic(A, SimComm(P), b, schedule=FailureSchedule(
            events={first: [lane]}), semantics=Semantics.SHRINK)

    def free():
        return ft_caqr_sweep(A, SimComm(P), b)

    for fn in (rebuild, shrink, shrink_first, free):  # pay the compiles once
        jax.block_until_ready(jax.tree_util.tree_leaves(fn()))

    return {
        "method": _METHOD,
        "config": {"P": P, "m_loc": m_loc, "n": n, "b": b, "quick": quick,
                   "mid_point": list(mid), "lane": lane},
        "us_rebuild_mid_kill": _wall(rebuild, reps),
        "us_shrink_mid_kill": _wall(shrink, reps),
        "us_shrink_first_kill": _wall(shrink_first, reps),
        "us_failure_free": _wall(free, reps),
        # the gated headline: SHRINK continuation vs REBUILD, interleaved
        "shrink_vs_rebuild": _ratio(shrink, rebuild, reps),
        # (b): almost the whole sweep on P-1 live lanes vs the full world
        "p_minus_1_vs_free": _ratio(shrink_first, free, reps),
    }


def bench_speculation(quick: bool = False) -> Dict:
    """(c): SPECULATE's buddy-recompute race vs blocking on the straggler.

    Both runs use panel-sized segments. *Blocking* stalls every boundary
    by the straggler's declared excess (a host sleep — the cost of waiting
    for the slow lane); *speculative* never waits: the monitor flags the
    lane and pays the measured buddy-recompute cost instead. The recompute
    is a fixed price, so the race wins exactly when the per-flag excess
    exceeds it — the record carries the measured ``us_per_speculation``
    (the break-even excess) alongside the win ratio at the declared
    excess."""
    P, m_loc, n, b = _config(quick)
    levels = P.bit_length() - 1
    rng = np.random.default_rng(32)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    reps = 2 if quick else 3
    seg = 1 + 2 * levels                 # one whole panel per segment
    slow = P - 1
    excess_us = 300_000.0                # straggler trails by 300ms/boundary

    def clock(comm, state):
        P_now = comm.axis_size()
        return {i: (8.0 if i == slow else 1.0) for i in range(P_now)}

    def monitor():
        return StragglerMonitor(P, StragglerConfig(
            threshold=1.4, patience=2, policy=StragglerPolicy.SPECULATE))

    def speculative():
        return SweepOrchestrator(A, SimComm(P), b, segment_points=seg,
                                 straggler_monitor=monitor(),
                                 lane_clock=clock).run()

    def stall(comm, state):
        time.sleep(excess_us / 1e6)  # every boundary waits for the straggler
        return state

    def blocking():
        return SweepOrchestrator(A, SimComm(P), b, segment_points=seg,
                                 fault_hooks=[stall]).run()

    orch = SweepOrchestrator(A, SimComm(P), b, segment_points=seg,
                             straggler_monitor=monitor(), lane_clock=clock)
    jax.block_until_ready(jax.tree_util.tree_leaves(orch.run()))  # compile
    n_spec = len(orch.speculations)
    us_free = _wall(lambda: SweepOrchestrator(
        A, SimComm(P), b, segment_points=seg).run(), reps)
    us_spec = _wall(speculative, reps)
    return {
        "config": {"P": P, "m_loc": m_loc, "n": n, "b": b, "quick": quick,
                   "segment_points": seg, "slow_lane": slow,
                   "excess_us_per_boundary": excess_us},
        "speculations": n_spec,
        "us_plain": us_free,
        "us_speculative": us_spec,
        # the break-even straggler excess: above this, the race wins
        "us_per_speculation": (us_spec - us_free) / max(n_spec, 1),
        # < 1.0 means the speculative race beats waiting for the straggler
        "speculative_vs_blocking": _ratio(speculative, blocking, reps),
    }


def suite(quick: bool = False) -> Dict:
    return {
        "shrink": bench_shrink_vs_rebuild(quick),
        "speculation": bench_speculation(quick),
    }


def check_regression(elastic: Dict, baseline: Optional[Dict]) -> Tuple[bool, str]:
    """Gate for ``run.py``/``ci.sh``: the SHRINK-vs-REBUILD continuation
    ratio must stay within ``REGRESSION_TOLERANCE`` of the recorded
    baseline (same quick-tier and methodology only). First run records and
    passes. ``CI_ALLOW_ELASTIC_REGRESSION=1`` acknowledges a known
    regression without greening it."""
    got = elastic["shrink"]["shrink_vs_rebuild"]
    if not baseline:
        return True, f"elastic shrink {got:.2f}x (no baseline recorded yet)"
    base_sh = baseline.get("shrink", {})
    if base_sh.get("config", {}).get("quick") != \
            elastic["shrink"]["config"]["quick"]:
        return True, (f"elastic shrink {got:.2f}x (baseline is from the "
                      "other tier; not comparable)")
    if base_sh.get("method") != elastic["shrink"]["method"]:
        return True, (f"elastic shrink {got:.2f}x (baseline predates the "
                      "current measurement methodology; re-recording)")
    base = base_sh["shrink_vs_rebuild"]
    if got <= base * REGRESSION_TOLERANCE:
        return True, f"elastic shrink {got:.2f}x vs baseline {base:.2f}x: OK"
    msg = (f"elastic SHRINK continuation REGRESSED: {got:.2f}x vs baseline "
           f"{base:.2f}x (> {REGRESSION_TOLERANCE:.2f}x tolerance)")
    if os.environ.get("CI_ALLOW_ELASTIC_REGRESSION") == "1":
        return True, msg + " — acknowledged via CI_ALLOW_ELASTIC_REGRESSION=1"
    return False, msg


def baseline_to_record(elastic: Dict, baseline: Optional[Dict]) -> Dict:
    """A passing run persists the fresh measurement, with the gated ratio
    floored at 90% of the previous comparable baseline so one lucky-fast
    run cannot ratchet the bar below what ordinary runs hit by noise."""
    import copy

    rec = copy.deepcopy(elastic)
    if not baseline:
        return rec
    base_sh = baseline.get("shrink", {})
    comparable = (
        base_sh.get("config", {}).get("quick")
        == elastic["shrink"]["config"]["quick"]
        and base_sh.get("method") == elastic["shrink"]["method"]
    )
    if comparable:
        rec["shrink"]["shrink_vs_rebuild"] = max(
            elastic["shrink"]["shrink_vs_rebuild"],
            base_sh["shrink_vs_rebuild"] * 0.9,
        )
    return rec


def main() -> None:
    import json

    print(json.dumps(suite(quick=False), indent=1))


if __name__ == "__main__":
    main()
