"""Benchmarks for the paper's algorithmic claims (one per claim).

All timings are CPU microbenchmarks of the jitted SimComm (P-lane) versions —
they measure the *algorithm* (operation counts, redundancy factors, recovery
cost), not TPU wall time; the TPU projection lives in the roofline analysis.
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SimComm, baseline_tsqr, caqr_factorize, ft_tsqr, trailing_update_baseline,
    trailing_update_ft,
)
from repro.core import recovery as rec
from repro.core.comm import SimComm as _Sim


def _time(fn: Callable, *args, iters: int = 5) -> float:
    out = fn(*args)
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
        out,
    )
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
        out,
    )
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_tsqr() -> List[Dict]:
    """Claim (III-B): FT butterfly has the same critical-path length as the
    baseline tree and replicates R on every lane."""
    rows = []
    rng = np.random.default_rng(0)
    for P, m_loc, b in [(8, 256, 32), (16, 128, 32), (32, 64, 16)]:
        comm = SimComm(P)
        A = jnp.asarray(rng.standard_normal((P, m_loc, b)), jnp.float32)
        ft = jax.jit(lambda a: ft_tsqr(a, comm).R)
        bl = jax.jit(lambda a: baseline_tsqr(a, comm).R)
        t_ft = _time(ft, A)
        t_bl = _time(bl, A)
        R = ft(A)
        replicated = bool(np.all(np.asarray(R) == np.asarray(R[0])))
        rows.append({
            "name": f"tsqr_P{P}_m{m_loc}_b{b}",
            "us_per_call": t_ft,
            "derived": f"baseline_us={t_bl:.0f};levels={P.bit_length()-1};"
                       f"R_replicated={replicated}",
        })
    return rows


def bench_trailing() -> List[Dict]:
    """Claim (III-C, Alg 2 vs Alg 1): exchange replaces send+recv, both
    compute W; same result, redundant state created."""
    rows = []
    rng = np.random.default_rng(1)
    for P, m_loc, b, n in [(8, 128, 16, 64), (16, 64, 16, 128)]:
        comm = SimComm(P)
        A = jnp.asarray(rng.standard_normal((P, m_loc, b)), jnp.float32)
        C = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
        fac = ft_tsqr(A, comm, target=0)  # classical survivor-chain stacking
        ft = jax.jit(lambda c: trailing_update_ft(c, fac, comm)[0])
        bl = jax.jit(lambda c: trailing_update_baseline(c, fac, comm))
        t_ft = _time(ft, C)
        t_bl = _time(bl, C)
        rows.append({
            "name": f"trailing_P{P}_n{n}",
            "us_per_call": t_ft,
            "derived": f"alg1_us={t_bl:.0f};ft_overhead={t_ft/max(t_bl,1e-9):.2f}x",
        })
    return rows


def bench_recovery() -> List[Dict]:
    """Claim: a failed lane's state is rebuilt from ONE surviving lane."""
    rows = []
    rng = np.random.default_rng(2)
    for P, m_loc, b, n in [(8, 128, 16, 64), (16, 128, 32, 256)]:
        comm = SimComm(P)
        A = jnp.asarray(rng.standard_normal((P, m_loc, b)), jnp.float32)
        C = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
        fac = ft_tsqr(A, comm)
        state = rec.trailing_begin(C, fac, comm)
        state, bundle = rec.trailing_level(state, fac, comm)

        def recover():
            return rec.recover_cprime(bundle, failed=2, source=2 ^ 1)

        t = _time(jax.jit(recover))
        clean = rec.run_ft_trailing(C, fac, comm)
        faulty = rec.run_ft_trailing(
            C, fac, comm, fail_at_level=1, failed_lane=2, A_stacked=C
        )
        exact = float(np.abs(np.asarray(clean) - np.asarray(faulty)).max())
        rows.append({
            "name": f"recovery_P{P}_b{b}_n{n}",
            "us_per_call": t,
            "derived": f"sources_read=1;recovered_err={exact:.1e}",
        })
    return rows


def bench_caqr() -> List[Dict]:
    """End-to-end FT-CAQR vs LAPACK-style QR (accuracy + time)."""
    rows = []
    rng = np.random.default_rng(3)
    for P, m_loc, n, b in [(8, 64, 128, 16), (16, 32, 256, 16)]:
        comm = SimComm(P)
        A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
        fn = jax.jit(lambda a: caqr_factorize(a, comm, b).R)
        t = _time(fn, A, iters=3)
        R = np.asarray(fn(A)[0])
        Af = np.asarray(A).reshape(-1, n)
        gram_err = np.abs(R.T @ R - Af.T @ Af).max() / np.abs(Af.T @ Af).max()
        t_np = _time(lambda a: jnp.linalg.qr(a.reshape(-1, n), mode="r"), A, iters=3)
        rows.append({
            "name": f"caqr_{P*m_loc}x{n}_b{b}",
            "us_per_call": t,
            "derived": f"lapack_us={t_np:.0f};gram_rel_err={gram_err:.2e}",
        })
    return rows


# Kernel-gate thresholds (check_kernel_regression). Per-row floor is well
# below 1.0 on purpose: the xla engine of the apply ops IS the oracle's
# program (untiled, same dots), so its honest speedup is a tie and measures
# 0.8-1.1 under machine noise; the floor only catches a compiled kernel
# genuinely LOSING to its oracle (an accidental interpret route times ~20x
# slower, a broken rewrite ~2x). The >= 1.0 requirement is enforced on the
# best compiled row — the fast path must beat the oracle somewhere.
KERNEL_GATE_MIN_SPEEDUP = 0.7


def _block(out):
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
        out,
    )
    return out


def _interleaved_min_us(kernel_fn, ref_fn, reps: int):
    """Min-of-reps wall clock for both sides, alternating calls so slow
    machine drift (thermal, noisy neighbors) hits kernel and reference
    equally — the discipline every speedup_vs_ref in this file uses."""
    _block(kernel_fn())
    _block(ref_fn())
    ks, rs = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        _block(kernel_fn())
        ks.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _block(ref_fn())
        rs.append(time.perf_counter() - t0)
    return min(ks) * 1e6, min(rs) * 1e6


def _max_leaf_err(a, b) -> float:
    return max(
        float(np.abs(np.asarray(x, dtype=np.float32)
                     - np.asarray(y, dtype=np.float32)).max())
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b))
    )


def _kernel_row(name: str, op: str, *, us: float, ref_us: float,
                max_err: float, reps: int, dtype, shape, extra=None) -> Dict:
    """One structured kernel-bench row; ``derived`` keeps the human CSV."""
    from repro.kernels import autotune, backend

    mode = backend.kernel_mode(op)
    engine = autotune.current_variant(op)
    speedup = ref_us / max(us, 1e-9)
    row = {
        "name": name,
        "us_per_call": us,
        "backend": jax.default_backend(),
        "mode": mode,
        "engine": engine,
        "compiled": mode == backend.MODE_COMPILED,
        "interpret": engine == backend.MODE_INTERPRET,
        "ref_us": ref_us,
        "speedup_vs_ref": speedup,
        "max_err": max_err,
        "reps": reps,
        "dtype": jnp.dtype(dtype).name,
        "shape": list(shape),
        "derived": f"ref_us={ref_us:.0f};speedup={speedup:.2f}x;mode={mode};"
                   f"engine={engine};max_err={max_err:.1e}",
    }
    if extra:
        row.update(extra)
    return row


def bench_kernels(quick: bool = False) -> List[Dict]:
    """Kernel fast path vs jnp oracle, per op: the dispatch seam's resolved
    route (compiled pallas / compiled xla / interpret / oracle — whatever
    the active policy says) against the ``ref.py`` oracle, timed jitted on
    both sides. The bf16 wy_apply cell is where f32-accumulation pays: the
    oracle round-trips every dot through bf16."""
    from repro.kernels import ops, ref

    reps = 5 if quick else 9
    rows = []
    rng = np.random.default_rng(4)
    m, b, n = 256, 64, 512
    cells = []
    dtypes = (jnp.float32,) if quick else (jnp.float32, jnp.bfloat16)
    for dt in dtypes:
        A = jnp.asarray(rng.standard_normal((m, b)), dt)
        Y = jnp.asarray(rng.standard_normal((m, b)), dt) * 0.1
        T = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), dt)) * 0.1
        Ct = jnp.asarray(rng.standard_normal((b, n)), dt)
        Cb = jnp.asarray(rng.standard_normal((b, n)), dt)
        C = jnp.asarray(rng.standard_normal((m, n)), dt)
        R1 = jnp.asarray(np.linalg.qr(rng.standard_normal((m, b)))[1], dt)
        R2 = jnp.asarray(np.linalg.qr(rng.standard_normal((m, b)))[1], dt)
        suffix = "" if dt == jnp.float32 else f"_{jnp.dtype(dt).name}"
        cells += [
            (f"kernel_panel_qr{suffix}", "panel_qr", dt, (m, b),
             jax.jit(lambda A=A: ops.panel_qr(A, 0)),
             jax.jit(lambda A=A: ref.panel_qr(A, 0))),
            (f"kernel_stacked_qr{suffix}", "stacked_qr", dt, (b,),
             jax.jit(lambda R1=R1, R2=R2: ops.stacked_qr(R1, R2)),
             jax.jit(lambda R1=R1, R2=R2: ref.stacked_qr(R1, R2))),
            (f"kernel_wy_apply{suffix}", "wy_apply", dt, (m, b, n),
             jax.jit(lambda Y=Y, T=T, C=C: ops.wy_apply(Y, T, C)),
             jax.jit(lambda Y=Y, T=T, C=C: ref.wy_apply(Y, T, C))),
            (f"kernel_stacked_apply{suffix}", "stacked_apply", dt, (b, n),
             jax.jit(lambda T=T, Ct=Ct, Cb=Cb: ops.stacked_apply(T, T, Ct, Cb)),
             jax.jit(lambda T=T, Ct=Ct, Cb=Cb: ref.stacked_apply(T, T, Ct, Cb))),
        ]
    if quick:
        # quick tier: the f32 matrix above plus the bf16 wy_apply headline
        # (the cell where f32 accumulation beats the oracle outright)
        dt = jnp.bfloat16
        Yb = jnp.asarray(rng.standard_normal((m, b)), dt) * 0.1
        Tb = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), dt)) * 0.1
        Cbig = jnp.asarray(rng.standard_normal((m, n)), dt)
        cells.append(
            ("kernel_wy_apply_bfloat16", "wy_apply", dt, (m, b, n),
             jax.jit(lambda: ops.wy_apply(Yb, Tb, Cbig)),
             jax.jit(lambda: ref.wy_apply(Yb, Tb, Cbig))))
    for name, op, dt, shape, k_fn, r_fn in cells:
        err = _max_leaf_err(k_fn(), r_fn())
        tk, tr = _interleaved_min_us(k_fn, r_fn, reps)
        row = _kernel_row(name, op, us=tk, ref_us=tr, max_err=err,
                          reps=reps, dtype=dt, shape=shape)
        if row["compiled"] and row["speedup_vs_ref"] < KERNEL_GATE_MIN_SPEEDUP:
            # one unbiased re-measure at double reps before a tie-program
            # row can trip the gate on a scheduler-noise spike
            tk, tr = _interleaved_min_us(k_fn, r_fn, 2 * reps)
            row = _kernel_row(name, op, us=tk, ref_us=tr, max_err=err,
                              reps=2 * reps, dtype=dt, shape=shape)
        rows.append(row)
    rows.append(_bench_fused_sweep(quick, reps))
    return rows


def _bench_fused_sweep(quick: bool, reps: int) -> Dict:
    """The megakernel row: one fused whole-panel dispatch vs the unfused
    per-point stepped loop (the orchestrator's segment granularity — the
    O(points)->O(1) launch reduction is the claim). ``stages`` breaks the
    stepped reference down per sweep phase, so the row shows which phase
    the fusion amortizes."""
    from repro.ft.failures import PHASE_LEAF, PHASE_TSQR, PHASE_TRAILING
    from repro.ft.online.state import (
        initial_sweep_state, panel_points, run_panel_fused, sweep_step,
    )

    P, m_loc, n, b = (4, 16, 32, 8) if quick else (4, 64, 128, 16)
    comm = SimComm(P)
    rng = np.random.default_rng(11)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    s0 = initial_sweep_state(comm, A, b)
    pts = panel_points(s0.geom)
    fused_jit = jax.jit(lambda s: run_panel_fused(comm, s))
    step_jit = jax.jit(lambda s: sweep_step(comm, s))

    def stepped(s=s0):
        for _ in range(pts):
            s = step_jit(s)
        return s

    err = _max_leaf_err(fused_jit(s0), stepped())
    tf, ts = _interleaved_min_us(lambda: fused_jit(s0), stepped, reps)

    # per-stage breakdown of the stepped reference: time each point's
    # dispatch at its own cursor, accumulate by phase
    stages = {PHASE_LEAF: 0.0, PHASE_TSQR: 0.0, PHASE_TRAILING: 0.0}
    s = s0
    for _ in range(pts):
        phase = s.cursor[1]
        here = s
        _block(step_jit(here))
        samples = []
        for _ in range(max(3, reps // 2)):
            t0 = time.perf_counter()
            _block(step_jit(here))
            samples.append(time.perf_counter() - t0)
        stages[phase] += min(samples) * 1e6
        s = step_jit(s)

    return _kernel_row(
        "kernel_fused_sweep", "fused_sweep", us=tf, ref_us=ts,
        max_err=err, reps=reps, dtype=jnp.float32, shape=(P, m_loc, n, b),
        extra={
            "launches": {"fused": 1, "stepped": pts},
            "stages_us": {f"{k}_us": round(v, 1) for k, v in stages.items()},
            "bitwise": err == 0.0,
        })


def check_kernel_regression(rows: List[Dict]):
    """Kernels-beat-oracle gate (mirrors the PR 5 online-gate pattern):

    fails when (a) any kernel row executed under ``interpret`` — the policy
    never chooses the interpreter, so a bench seeing it means the fast path
    silently degraded; (b) a compiled row's speedup_vs_ref fell below
    ``KERNEL_GATE_MIN_SPEEDUP`` (a compiled kernel losing outright to its
    jnp oracle); or (c) compiled rows exist but none reaches 1.0x (the
    "fast path" beats the oracle nowhere). ``CI_ALLOW_KERNEL_REGRESSION=1``
    acknowledges a known regression. Returns ``(ok, message)``.
    """
    import os

    kernel_rows = [r for r in rows if r["name"].startswith("kernel_")]
    if not kernel_rows:
        return True, "no kernel rows (nothing to check)"
    problems = []
    for r in kernel_rows:
        if r.get("engine") == "interpret":
            problems.append(f"{r['name']}: silently degraded to interpret")
    compiled = [r for r in kernel_rows if r.get("compiled")]
    if not compiled:
        return True, ("no compiled rows — policy routed every op to the "
                      "oracle on this backend (loud notice, not a failure)")
    for r in compiled:
        if r["speedup_vs_ref"] < KERNEL_GATE_MIN_SPEEDUP:
            problems.append(
                f"{r['name']}: compiled kernel lost to its oracle "
                f"({r['speedup_vs_ref']:.2f}x < {KERNEL_GATE_MIN_SPEEDUP}x)")
    best = max(compiled, key=lambda r: r["speedup_vs_ref"])
    if best["speedup_vs_ref"] < 1.0:
        problems.append(
            f"no compiled row beats the oracle (best {best['name']} at "
            f"{best['speedup_vs_ref']:.2f}x)")
    if problems:
        msg = "; ".join(problems)
        if os.environ.get("CI_ALLOW_KERNEL_REGRESSION") == "1":
            return True, msg + " — acknowledged via CI_ALLOW_KERNEL_REGRESSION=1"
        return False, msg
    return True, (f"{len(compiled)} compiled rows on {best['backend']}, "
                  f"best {best['name']} at {best['speedup_vs_ref']:.2f}x")


def _trailing_flops_per_lane(m_loc: int, b: int, n_cols: int, levels: int) -> float:
    """Per-lane trailing-update flops for one panel over ``n_cols`` columns:
    leaf WY apply (two GEMMs + rank-b update) + per-level W-form combines."""
    leaf = 4.0 * m_loc * b * n_cols + 2.0 * b * b * n_cols
    combines = levels * 6.0 * b * b * n_cols
    return leaf + combines


def bench_sweep_cost(quick: bool = False) -> Dict:
    """Tentpole claim: the windowed right-looking sweep does only live work.

    The seed sweep's trailing update spans all n columns at every panel —
    constant cost per panel, ~2x the trailing flops of a square
    factorization. The windowed sweep restricts panel k to ``A[:, k*b:]``,
    so its per-panel cost *decreases with k* while producing bit-identical
    results. Returns a machine-readable record (per-panel flops + measured
    us, sweep totals) for BENCH_core.json.
    """
    from repro.core.caqr import _panel_step, _panel_step_windowed

    P, m_loc, n, b = (4, 32, 128, 16) if quick else (8, 64, 512, 32)
    comm = SimComm(P)
    levels = P.bit_length() - 1
    n_panels = n // b
    rng = np.random.default_rng(5)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)

    # per-panel cost: measured us + analytic per-lane trailing flops
    ks = sorted({0, n_panels // 2, n_panels - 1})
    per_panel = []
    full_body = _panel_step(comm, b, False)
    for k in ks:
        win_body = _panel_step_windowed(comm, b, False, k, n)
        us_win = _time(jax.jit(lambda a: win_body(a)[0]), A, iters=3)
        us_full = _time(
            jax.jit(lambda a, kk: full_body(a, kk)[0]), A, jnp.asarray(k), iters=3
        )
        per_panel.append({
            "k": k,
            "us_windowed": us_win,
            "us_full": us_full,
            "flops_windowed": _trailing_flops_per_lane(m_loc, b, n - k * b, levels),
            "flops_full": _trailing_flops_per_lane(m_loc, b, n, levels),
        })

    # whole-sweep wall time: windowed vs full-width unrolled vs scan
    t_win = _time(
        jax.jit(lambda a: caqr_factorize(a, comm, b, use_scan=False).R), A, iters=3
    )
    t_full = _time(
        jax.jit(lambda a: caqr_factorize(a, comm, b, use_scan=False,
                                         windowed=False).R), A, iters=3
    )
    t_scan = _time(
        jax.jit(lambda a: caqr_factorize(a, comm, b, use_scan=True).R), A, iters=3
    )
    f_win = sum(
        _trailing_flops_per_lane(m_loc, b, n - k * b, levels)
        for k in range(n_panels)
    )
    f_full = n_panels * _trailing_flops_per_lane(m_loc, b, n, levels)
    return {
        "config": {"P": P, "m_loc": m_loc, "n": n, "b": b,
                   "n_panels": n_panels, "quick": quick},
        "per_panel": per_panel,
        "totals": {
            "us_windowed_sweep": t_win,
            "us_full_sweep": t_full,
            "us_scan_sweep": t_scan,
            "trailing_flops_windowed": f_win,
            "trailing_flops_full": f_full,
            "trailing_flop_ratio": f_full / f_win,
        },
    }


def bench_general_shapes(quick: bool = False) -> Dict:
    """Satellite claim: ragged/unaligned shapes run at near-aligned cost.

    The general-shape sweep zero-pads to the aligned ``sweep_geometry`` and
    runs the seed's code path, so the only overhead is the one-time pad
    copy + the final slice. Measured here as ragged-vs-aligned wall time at
    the *same padded compute*: the ragged case is chosen to pad up exactly
    to the aligned case's shape. Written to BENCH_core.json under
    ``general_shapes``.
    """
    from repro.core import sweep_geometry

    P = 4
    if quick:
        aligned = (16, 32, 8)       # (m_loc, n, b)
        ragged = (14, 27, 8)        # pads up to exactly (16, 32)
    else:
        aligned = (64, 128, 16)
        ragged = (61, 115, 16)
    comm = SimComm(P)
    rng = np.random.default_rng(7)

    def run_case(m_loc, n, b):
        A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
        fn = jax.jit(lambda a: caqr_factorize(a, comm, b, use_scan=False).R)
        return _time(fn, A, iters=3)

    g = sweep_geometry(P, *ragged[:2], ragged[2])
    assert (g.m_loc_pad, g.n_work) == aligned[:2], "cases must share padded compute"
    us_aligned = run_case(*aligned)
    us_ragged = run_case(*ragged)
    return {
        "config": {"P": P, "quick": quick},
        "aligned": {"shape": list(aligned), "us": us_aligned},
        "ragged": {
            "shape": list(ragged),
            "padded_shape": [g.m_loc_pad, g.n_work],
            "n_panels": g.n_panels,
            "us": us_ragged,
        },
        "overhead": us_ragged / us_aligned,
    }


ALL = [bench_tsqr, bench_trailing, bench_recovery, bench_caqr, bench_kernels]
QUICK = [functools.partial(bench_kernels, quick=True)]
