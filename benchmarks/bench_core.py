"""Benchmarks for the paper's algorithmic claims (one per claim).

All timings are CPU microbenchmarks of the jitted SimComm (P-lane) versions —
they measure the *algorithm* (operation counts, redundancy factors, recovery
cost), not TPU wall time; the TPU projection lives in the roofline analysis.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SimComm, baseline_tsqr, caqr_factorize, ft_tsqr, trailing_update_baseline,
    trailing_update_ft,
)
from repro.core import recovery as rec
from repro.core.comm import SimComm as _Sim


def _time(fn: Callable, *args, iters: int = 5) -> float:
    out = fn(*args)
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
        out,
    )
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
        out,
    )
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_tsqr() -> List[Dict]:
    """Claim (III-B): FT butterfly has the same critical-path length as the
    baseline tree and replicates R on every lane."""
    rows = []
    rng = np.random.default_rng(0)
    for P, m_loc, b in [(8, 256, 32), (16, 128, 32), (32, 64, 16)]:
        comm = SimComm(P)
        A = jnp.asarray(rng.standard_normal((P, m_loc, b)), jnp.float32)
        ft = jax.jit(lambda a: ft_tsqr(a, comm).R)
        bl = jax.jit(lambda a: baseline_tsqr(a, comm).R)
        t_ft = _time(ft, A)
        t_bl = _time(bl, A)
        R = ft(A)
        replicated = bool(np.all(np.asarray(R) == np.asarray(R[0])))
        rows.append({
            "name": f"tsqr_P{P}_m{m_loc}_b{b}",
            "us_per_call": t_ft,
            "derived": f"baseline_us={t_bl:.0f};levels={P.bit_length()-1};"
                       f"R_replicated={replicated}",
        })
    return rows


def bench_trailing() -> List[Dict]:
    """Claim (III-C, Alg 2 vs Alg 1): exchange replaces send+recv, both
    compute W; same result, redundant state created."""
    rows = []
    rng = np.random.default_rng(1)
    for P, m_loc, b, n in [(8, 128, 16, 64), (16, 64, 16, 128)]:
        comm = SimComm(P)
        A = jnp.asarray(rng.standard_normal((P, m_loc, b)), jnp.float32)
        C = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
        fac = ft_tsqr(A, comm, target=0)  # classical survivor-chain stacking
        ft = jax.jit(lambda c: trailing_update_ft(c, fac, comm)[0])
        bl = jax.jit(lambda c: trailing_update_baseline(c, fac, comm))
        t_ft = _time(ft, C)
        t_bl = _time(bl, C)
        rows.append({
            "name": f"trailing_P{P}_n{n}",
            "us_per_call": t_ft,
            "derived": f"alg1_us={t_bl:.0f};ft_overhead={t_ft/max(t_bl,1e-9):.2f}x",
        })
    return rows


def bench_recovery() -> List[Dict]:
    """Claim: a failed lane's state is rebuilt from ONE surviving lane."""
    rows = []
    rng = np.random.default_rng(2)
    for P, m_loc, b, n in [(8, 128, 16, 64), (16, 128, 32, 256)]:
        comm = SimComm(P)
        A = jnp.asarray(rng.standard_normal((P, m_loc, b)), jnp.float32)
        C = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
        fac = ft_tsqr(A, comm)
        state = rec.trailing_begin(C, fac, comm)
        state, bundle = rec.trailing_level(state, fac, comm)

        def recover():
            return rec.recover_cprime(bundle, failed=2, source=2 ^ 1)

        t = _time(jax.jit(recover))
        clean = rec.run_ft_trailing(C, fac, comm)
        faulty = rec.run_ft_trailing(
            C, fac, comm, fail_at_level=1, failed_lane=2, A_stacked=C
        )
        exact = float(np.abs(np.asarray(clean) - np.asarray(faulty)).max())
        rows.append({
            "name": f"recovery_P{P}_b{b}_n{n}",
            "us_per_call": t,
            "derived": f"sources_read=1;recovered_err={exact:.1e}",
        })
    return rows


def bench_caqr() -> List[Dict]:
    """End-to-end FT-CAQR vs LAPACK-style QR (accuracy + time)."""
    rows = []
    rng = np.random.default_rng(3)
    for P, m_loc, n, b in [(8, 64, 128, 16), (16, 32, 256, 16)]:
        comm = SimComm(P)
        A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
        fn = jax.jit(lambda a: caqr_factorize(a, comm, b).R)
        t = _time(fn, A, iters=3)
        R = np.asarray(fn(A)[0])
        Af = np.asarray(A).reshape(-1, n)
        gram_err = np.abs(R.T @ R - Af.T @ Af).max() / np.abs(Af.T @ Af).max()
        t_np = _time(lambda a: jnp.linalg.qr(a.reshape(-1, n), mode="r"), A, iters=3)
        rows.append({
            "name": f"caqr_{P*m_loc}x{n}_b{b}",
            "us_per_call": t,
            "derived": f"lapack_us={t_np:.0f};gram_rel_err={gram_err:.2e}",
        })
    return rows


def bench_kernels() -> List[Dict]:
    """Pallas kernels (interpret mode) vs jnp oracle."""
    from repro.kernels import ops, ref

    rows = []
    rng = np.random.default_rng(4)
    m, b, n = 256, 64, 512
    A = jnp.asarray(rng.standard_normal((m, b)), jnp.float32)
    Y = jnp.asarray(rng.standard_normal((m, b)), jnp.float32) * 0.1
    T = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), jnp.float32)) * 0.1
    C = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    for name, k_fn, r_fn, args in [
        ("panel_qr", lambda: ops.panel_qr(A, 0), lambda: ref.panel_qr(A, 0), ()),
        ("wy_apply", lambda: ops.wy_apply(Y, T, C), lambda: ref.wy_apply(Y, T, C), ()),
    ]:
        tk = _time(lambda *_: k_fn(), iters=3)
        tr = _time(lambda *_: r_fn(), iters=3)
        ko, ro = k_fn(), r_fn()
        err = max(
            float(np.abs(np.asarray(a) - np.asarray(c)).max())
            for a, c in zip(jax.tree_util.tree_leaves(ko), jax.tree_util.tree_leaves(ro))
        )
        rows.append({
            "name": f"kernel_{name}",
            "us_per_call": tk,
            "derived": f"ref_us={tr:.0f};max_err={err:.1e};interpret=True",
        })
    return rows


def _trailing_flops_per_lane(m_loc: int, b: int, n_cols: int, levels: int) -> float:
    """Per-lane trailing-update flops for one panel over ``n_cols`` columns:
    leaf WY apply (two GEMMs + rank-b update) + per-level W-form combines."""
    leaf = 4.0 * m_loc * b * n_cols + 2.0 * b * b * n_cols
    combines = levels * 6.0 * b * b * n_cols
    return leaf + combines


def bench_sweep_cost(quick: bool = False) -> Dict:
    """Tentpole claim: the windowed right-looking sweep does only live work.

    The seed sweep's trailing update spans all n columns at every panel —
    constant cost per panel, ~2x the trailing flops of a square
    factorization. The windowed sweep restricts panel k to ``A[:, k*b:]``,
    so its per-panel cost *decreases with k* while producing bit-identical
    results. Returns a machine-readable record (per-panel flops + measured
    us, sweep totals) for BENCH_core.json.
    """
    from repro.core.caqr import _panel_step, _panel_step_windowed

    P, m_loc, n, b = (4, 32, 128, 16) if quick else (8, 64, 512, 32)
    comm = SimComm(P)
    levels = P.bit_length() - 1
    n_panels = n // b
    rng = np.random.default_rng(5)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)

    # per-panel cost: measured us + analytic per-lane trailing flops
    ks = sorted({0, n_panels // 2, n_panels - 1})
    per_panel = []
    full_body = _panel_step(comm, b, False)
    for k in ks:
        win_body = _panel_step_windowed(comm, b, False, k, n)
        us_win = _time(jax.jit(lambda a: win_body(a)[0]), A, iters=3)
        us_full = _time(
            jax.jit(lambda a, kk: full_body(a, kk)[0]), A, jnp.asarray(k), iters=3
        )
        per_panel.append({
            "k": k,
            "us_windowed": us_win,
            "us_full": us_full,
            "flops_windowed": _trailing_flops_per_lane(m_loc, b, n - k * b, levels),
            "flops_full": _trailing_flops_per_lane(m_loc, b, n, levels),
        })

    # whole-sweep wall time: windowed vs full-width unrolled vs scan
    t_win = _time(
        jax.jit(lambda a: caqr_factorize(a, comm, b, use_scan=False).R), A, iters=3
    )
    t_full = _time(
        jax.jit(lambda a: caqr_factorize(a, comm, b, use_scan=False,
                                         windowed=False).R), A, iters=3
    )
    t_scan = _time(
        jax.jit(lambda a: caqr_factorize(a, comm, b, use_scan=True).R), A, iters=3
    )
    f_win = sum(
        _trailing_flops_per_lane(m_loc, b, n - k * b, levels)
        for k in range(n_panels)
    )
    f_full = n_panels * _trailing_flops_per_lane(m_loc, b, n, levels)
    return {
        "config": {"P": P, "m_loc": m_loc, "n": n, "b": b,
                   "n_panels": n_panels, "quick": quick},
        "per_panel": per_panel,
        "totals": {
            "us_windowed_sweep": t_win,
            "us_full_sweep": t_full,
            "us_scan_sweep": t_scan,
            "trailing_flops_windowed": f_win,
            "trailing_flops_full": f_full,
            "trailing_flop_ratio": f_full / f_win,
        },
    }


def bench_general_shapes(quick: bool = False) -> Dict:
    """Satellite claim: ragged/unaligned shapes run at near-aligned cost.

    The general-shape sweep zero-pads to the aligned ``sweep_geometry`` and
    runs the seed's code path, so the only overhead is the one-time pad
    copy + the final slice. Measured here as ragged-vs-aligned wall time at
    the *same padded compute*: the ragged case is chosen to pad up exactly
    to the aligned case's shape. Written to BENCH_core.json under
    ``general_shapes``.
    """
    from repro.core import sweep_geometry

    P = 4
    if quick:
        aligned = (16, 32, 8)       # (m_loc, n, b)
        ragged = (14, 27, 8)        # pads up to exactly (16, 32)
    else:
        aligned = (64, 128, 16)
        ragged = (61, 115, 16)
    comm = SimComm(P)
    rng = np.random.default_rng(7)

    def run_case(m_loc, n, b):
        A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
        fn = jax.jit(lambda a: caqr_factorize(a, comm, b, use_scan=False).R)
        return _time(fn, A, iters=3)

    g = sweep_geometry(P, *ragged[:2], ragged[2])
    assert (g.m_loc_pad, g.n_work) == aligned[:2], "cases must share padded compute"
    us_aligned = run_case(*aligned)
    us_ragged = run_case(*ragged)
    return {
        "config": {"P": P, "quick": quick},
        "aligned": {"shape": list(aligned), "us": us_aligned},
        "ragged": {
            "shape": list(ragged),
            "padded_shape": [g.m_loc_pad, g.n_work],
            "n_panels": g.n_panels,
            "us": us_ragged,
        },
        "overhead": us_ragged / us_aligned,
    }


ALL = [bench_tsqr, bench_trailing, bench_recovery, bench_caqr, bench_kernels]
QUICK = [bench_kernels]
