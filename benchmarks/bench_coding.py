"""Coded-checksum-lane benchmarks: what surviving f simultaneous failures
costs (``repro.ft.coding``).

(a) *Overhead-vs-f curve*: the failure-free online sweep with
    ``MDSScheme(f)`` re-encoding f GF(2^8) parity slots at every boundary,
    for f = 1, 2, 3, against the XOR-scheme floor (whose refresh is a
    no-op). Measured at P=8 (quick) and P=8 + P=16 (full). The gated
    headline is the f=2 ratio at P=8 — the scheme the multi-failure test
    tier runs — measured interleaved so box drift cancels.

(b) *Joint-decode latency*: kill a former XOR-buddy pair mid-sweep (the
    schedule that is UNRECOVERABLE under the XOR scheme) and report the
    detection-to-recovered wall time of the joint GF decode plus its
    multi-source read count.

``benchmarks/run.py`` stores the record under ``BENCH_core.json``'s
``"coding"`` key and fails CI (``check_regression``) if the f=2 encode
overhead regresses more than 25% over the recorded baseline —
``CI_ALLOW_CODING_REGRESSION=1`` acknowledges a known regression without
greening it.
"""
from __future__ import annotations

import os
import statistics
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SimComm
from repro.ft import MDSScheme, SweepOrchestrator, sweep_point
from repro.ft.online.detect import ScriptedKiller

# f=2 encode overhead may regress this much before CI fails
REGRESSION_TOLERANCE = 1.25
# measurement methodology version (baselines across bumps are incomparable)
_METHOD = 1

_FS = (1, 2, 3)


def _geoms(quick: bool):
    # (P, m_loc, n, b): 2 panels, every phase class, enough bytes per lane
    # that the encode cost is not pure dispatch noise
    if quick:
        return [(8, 8, 16, 8)]
    return [(8, 8, 16, 8), (16, 8, 16, 8)]


def _wall_once(fn) -> float:
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(jax.tree_util.tree_leaves(out))
    return (time.perf_counter() - t0) * 1e6


def _wall(fn, reps: int) -> float:
    return min(_wall_once(fn) for _ in range(reps))


def _ratio(fn_num, fn_den, reps: int) -> float:
    """Median of per-rep interleaved ratios (see bench_online._ratio): box
    drift inflates both sides of a pair and cancels in the gated number."""
    return statistics.median(
        _wall_once(fn_num) / max(_wall_once(fn_den), 1e-9)
        for _ in range(reps)
    )


def bench_overhead(quick: bool = False) -> Dict:
    """(a): the failure-free stepped sweep with f parity slots re-encoded
    at every boundary, against the XOR floor, for f in {1, 2, 3}."""
    reps = 5 if quick else 7
    by_world = {}
    gated = None
    for P, m_loc, n, b in _geoms(quick):
        comm = SimComm(P)
        rng = np.random.default_rng(31)
        A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)

        xor_run = lambda: SweepOrchestrator(A, comm, b).run()
        jax.block_until_ready(jax.tree_util.tree_leaves(xor_run()))
        us_xor = _wall(xor_run, reps)

        curve = {}
        for f in _FS:
            scheme = MDSScheme(f=f)
            run = lambda: SweepOrchestrator(A, comm, b, scheme=scheme).run()
            jax.block_until_ready(jax.tree_util.tree_leaves(run()))
            curve[str(f)] = {
                "us": _wall(run, reps),
                "overhead_vs_xor": _ratio(run, xor_run, max(reps - 2, 3)),
            }
        by_world[str(P)] = {
            "config": {"P": P, "m_loc": m_loc, "n": n, "b": b},
            "us_xor": us_xor,
            "by_f": curve,
        }
        if P == 8:
            gated = curve["2"]["overhead_vs_xor"]
    return {
        "method": _METHOD,
        "quick": quick,
        "by_world": by_world,
        # the gated headline: f=2 encode overhead at P=8
        "overhead_f2_vs_xor": gated,
    }


def bench_decode_latency(quick: bool = False) -> Dict:
    """(b): a buddy-pair double kill — the XOR scheme's wall — healed by
    the joint GF decode at runtime; detection-to-recovered per lane."""
    P, m_loc, n, b = _geoms(quick)[0]
    comm = SimComm(P)
    rng = np.random.default_rng(32)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    levels = P.bit_length() - 1
    point = sweep_point(1, "trailing", levels - 1)
    pair = [2, 3]  # level-0 XOR buddies: unrecoverable without the code

    stats = []
    for _ in range(2 if quick else 3):
        orch = SweepOrchestrator(
            A, comm, b, scheme=MDSScheme(f=2),
            fault_hooks=[ScriptedKiller({point: list(pair)})])
        res = orch.run()
        assert len(res.events) == len(pair)
        stats.append({
            "us_decode": res.events[0].elapsed_s * 1e6,
            "reads": len(res.events[0].reads),
        })
    steady = stats[-1]  # first run pays the decode compile
    return {
        "config": {"P": P, "m_loc": m_loc, "n": n, "b": b,
                   "point": list(point), "pair": pair, "quick": quick},
        "us_detect_to_recovered": steady["us_decode"],
        "reads": steady["reads"],
    }


def suite(quick: bool = False) -> Dict:
    return {
        "overhead": bench_overhead(quick),
        "decode": bench_decode_latency(quick),
    }


def check_regression(coding: Dict, baseline: Optional[Dict]) -> Tuple[bool, str]:
    """Gate for ``run.py``/``ci.sh``: the f=2 encode overhead must stay
    within ``REGRESSION_TOLERANCE`` of the recorded baseline (same quick
    tier and methodology only). First run records and passes.
    ``CI_ALLOW_CODING_REGRESSION=1`` acknowledges a known regression."""
    got = coding["overhead"]["overhead_f2_vs_xor"]
    if not baseline:
        return True, f"coding f=2 overhead {got:.2f}x (no baseline yet)"
    base_ov = baseline.get("overhead", {})
    if base_ov.get("quick") != coding["overhead"]["quick"]:
        return True, (f"coding f=2 overhead {got:.2f}x (baseline is from "
                      "the other tier; not comparable)")
    if base_ov.get("method") != coding["overhead"]["method"]:
        return True, (f"coding f=2 overhead {got:.2f}x (baseline predates "
                      "the current methodology; re-recording)")
    base = base_ov["overhead_f2_vs_xor"]
    if got <= base * REGRESSION_TOLERANCE:
        return True, f"coding f=2 overhead {got:.2f}x vs baseline {base:.2f}x: OK"
    msg = (f"coding encode overhead REGRESSED: {got:.2f}x vs baseline "
           f"{base:.2f}x (> {REGRESSION_TOLERANCE:.2f}x tolerance)")
    if os.environ.get("CI_ALLOW_CODING_REGRESSION") == "1":
        return True, msg + " — acknowledged via CI_ALLOW_CODING_REGRESSION=1"
    return False, msg


def baseline_to_record(coding: Dict, baseline: Optional[Dict]) -> Dict:
    """A passing run persists the fresh curve with the gated ratio floored
    at 90% of the previous comparable baseline (the same damped-ratchet
    rule as the online gate: lucky-fast outliers cannot set a bar ordinary
    runs miss by noise)."""
    import copy

    rec = copy.deepcopy(coding)
    if not baseline:
        return rec
    base_ov = baseline.get("overhead", {})
    comparable = (
        base_ov.get("quick") == coding["overhead"]["quick"]
        and base_ov.get("method") == coding["overhead"]["method"]
    )
    if comparable:
        rec["overhead"]["overhead_f2_vs_xor"] = max(
            coding["overhead"]["overhead_f2_vs_xor"],
            base_ov["overhead_f2_vs_xor"] * 0.9,
        )
    return rec


def main() -> None:
    import json

    print(json.dumps(suite(quick=False), indent=1))


if __name__ == "__main__":
    main()
