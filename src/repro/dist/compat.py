"""Version-compat shims for the jax sharding / SPMD API surface.

The SPMD entrypoints target the modern spelling — ``jax.make_mesh(...,
axis_types=...)``, ``jax.set_mesh``, ``jax.shard_map(..., check_vma=...)``
— but the pinned image may carry an older jax (0.4.x) where those live
under different names (``jax.experimental.shard_map.shard_map`` with
``check_rep``/``auto``, the ``Mesh`` object itself as the ambient-mesh
context manager) or do not exist (``jax.sharding.AxisType``). Everything
that builds meshes or shard_maps goes through this module so the rest of
the tree is version-agnostic; the subprocess SPMD tests
(``tests/test_spmd_subprocess.py``, ``tests/test_spmd_ft_driver.py``) run
against exactly these shims.

No behavior differences are papered over: on every supported version a mesh
axis is *manual* inside the mapped body unless listed in the modern API's
``axis_names`` (translated to the legacy ``auto`` complement), and
replication checking is off by default, matching the repo's explicit-spec
style.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Set

import jax

# ``jax.sharding.AxisType`` appeared well after 0.4.x; its absence is the
# marker for the whole legacy surface.
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)
HAS_MODERN_SHARDING = _AXIS_TYPE is not None


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None):
    """``jax.make_mesh`` with explicit-Auto axis types where supported."""
    if HAS_MODERN_SHARDING:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(_AXIS_TYPE.Auto,) * len(tuple(axis_names)),
            devices=devices,
        )
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                         devices=devices)


@contextlib.contextmanager
def set_mesh(mesh):
    """Ambient-mesh context: ``jax.set_mesh`` where it exists, else the
    ``Mesh`` object's own context manager (which binds the 0.4.x resource
    env that ``with_sharding_constraint(x, PartitionSpec)`` reads)."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield
    else:
        with mesh:
            yield


def shard_map(f, mesh, in_specs, out_specs, *, check: bool = False,
              axis_names: Optional[Set[str]] = None):
    """``jax.shard_map`` / legacy ``jax.experimental.shard_map.shard_map``.

    ``check`` maps to ``check_vma`` (modern) / ``check_rep`` (legacy).
    ``axis_names`` is the modern "manual axes" set; axes outside it stay
    automatic (XLA-sharded inside the body). On legacy jax the partial-auto
    translation (``auto =`` the complement) trips an XLA partitioner check
    (``IsManualSubgroup`` failure), so there we degrade to fully-manual:
    unmentioned axes replicate instead of auto-sharding — identical results
    for bodies that only use collectives on the manual axes (ours), less
    intra-body parallelism on the old runtime.
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check)
