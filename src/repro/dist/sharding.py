"""Logical-axis sharding annotations.

Model code never names mesh axes directly: it annotates activations with
*logical* axis names (``ax(x, "batch", None, "heads", None)``) and a rule
table maps each logical name to a mesh axis (a string), a tuple of mesh axes
(e.g. batch over ``("pod", "data")``), or ``None`` (replicated / unsharded).

Outside a ``use_rules`` context ``ax`` is the identity, so the same model
code runs on a single device (smoke tests) and under ``jax.jit`` on a
production mesh (dry-run / train) unchanged. Rules are applied via
``jax.lax.with_sharding_constraint`` against the ambient mesh set with
``jax.set_mesh``.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Iterator, Optional

import jax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


def current_rules() -> Optional[Dict[str, Any]]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Dict[str, Any]) -> Iterator[None]:
    """Activate a logical-axis -> mesh-axis rule table for the enclosed
    trace. Must nest inside ``jax.set_mesh(mesh)`` so the constraints bind."""
    prev = current_rules()
    _STATE.rules = dict(rules)
    try:
        yield
    finally:
        _STATE.rules = prev


def ax(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (one per dim).

    ``None`` entries (and logical names a rule table maps to ``None``) leave
    the dim unsharded. Identity when no rule table is active.
    """
    rules = current_rules()
    if rules is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    spec = P(*(rules.get(name) if name is not None else None
               for name in logical_axes))
    return jax.lax.with_sharding_constraint(x, spec)


def single_pod_rules() -> Dict[str, Any]:
    """16x16 (data x model) pod: batch over data, width dims over model."""
    return {
        "batch": "data",
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "experts": "model",
        "ssm_heads": "model",
        "lru": "model",
        "seq_shard": None,
        "kv_seq_shard": None,
    }


def multi_pod_rules() -> Dict[str, Any]:
    """2x16x16 (pod x data x model): batch spans both pod and data."""
    rules = single_pod_rules()
    rules["batch"] = ("pod", "data")
    return rules


def long_decode_overrides(rules: Dict[str, Any]) -> Dict[str, Any]:
    """long_500k decode: the KV/state cache's sequence dim dominates HBM, so
    it shards over every available axis and the (small) decode batch stays
    replicated — the inverse of the training layout."""
    rules = dict(rules)
    rules["batch"] = None
    rules["kv_seq_shard"] = ("data", "model")
    return rules
