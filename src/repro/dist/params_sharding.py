"""NamedSharding trees for parameters, optimizer state, batches and caches.

Parameters/optimizer state use an FSDP layout: each leaf is sharded along
the largest dim divisible by the FSDP axis size (replicated when nothing
divides — small norms/scalars). Batches shard their leading (batch) dim.
Decode caches shard batch and, optionally, the KV sequence dim.

All functions take abstract trees (``ShapeDtypeStruct`` leaves from
``jax.eval_shape``) and return matching trees of ``NamedSharding``.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Union

import numpy as np

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

Axes = Union[None, str, Sequence[str]]


def _axis_size(mesh, axes: Axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([dict(mesh.shape)[a] for a in axes]))


def _fsdp_spec(shape, mesh, axes: Axes) -> P:
    """Shard the largest divisible dim over ``axes``; replicate otherwise."""
    size = _axis_size(mesh, axes)
    if size == 1 or not shape:
        return P()
    order = sorted(range(len(shape)), key=lambda i: shape[i], reverse=True)
    for i in order:
        if shape[i] % size == 0 and shape[i] >= size:
            spec = [None] * len(shape)
            spec[i] = tuple(axes) if not isinstance(axes, str) else axes
            return P(*spec)
    return P()


def tree_shardings(tree: Any, mesh, fsdp: Axes) -> Any:
    """FSDP NamedSharding for every leaf of an abstract tree."""
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, _fsdp_spec(leaf.shape, mesh, fsdp)),
        tree,
    )


def _batch_spec(shape, mesh, axes: Axes, dim: int = 0) -> P:
    size = _axis_size(mesh, axes)
    if size == 1 or len(shape) <= dim or shape[dim] % size != 0:
        return P()
    spec = [None] * len(shape)
    spec[dim] = tuple(axes) if not isinstance(axes, str) else axes
    return P(*spec)


def batch_shardings(tree: Any, mesh, batch_axes: Axes) -> Any:
    """Shard the leading (batch) dim of every leaf over ``batch_axes``."""
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, _batch_spec(leaf.shape, mesh, batch_axes)),
        tree,
    )


def cache_shardings(caches: Any, mesh, batch_axes: Axes,
                    kv_seq_axes: Axes = None) -> Any:
    """Decode-cache shardings.

    Cache leaves come in two layouts (see ``transformer.init_caches``):
    KV caches ``k``/``v`` of shape (B, S, KV, Dh) and recurrent states
    ``h``/``conv`` with batch leading. Leaves under the scanned ``groups``
    subtree carry one extra leading (n_groups) axis. The batch dim shards
    over ``batch_axes``; the KV sequence dim (dim batch+1 on k/v leaves)
    over ``kv_seq_axes`` when divisible.
    """
    def spec_for(path, leaf) -> NamedSharding:
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        offset = 1 if "groups" in keys else 0
        shape = leaf.shape
        spec = [None] * len(shape)
        bsize = _axis_size(mesh, batch_axes)
        if bsize > 1 and len(shape) > offset and shape[offset] % bsize == 0:
            spec[offset] = tuple(batch_axes) if not isinstance(batch_axes, str) \
                else batch_axes
        is_kv = keys and keys[-1] in ("k", "v")
        ssize = _axis_size(mesh, kv_seq_axes)
        if (is_kv and ssize > 1 and len(shape) > offset + 1
                and shape[offset + 1] % ssize == 0):
            spec[offset + 1] = tuple(kv_seq_axes) \
                if not isinstance(kv_seq_axes, str) else kv_seq_axes
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(spec_for, caches)
