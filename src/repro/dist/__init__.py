"""Distributed-execution helpers: logical-axis sharding rules + param/batch
sharding construction + jax version compatibility.

``sharding``        - the logical-axis annotation layer (``ax`` + rule tables)
``params_sharding`` - NamedSharding trees for params / optimizer state /
                      batches / decode caches (FSDP + batch sharding)
``compat``          - version shims for mesh construction / ``shard_map`` /
                      ambient-mesh contexts (modern vs 0.4.x jax)
"""
from repro.dist import compat, params_sharding, sharding

__all__ = ["compat", "params_sharding", "sharding"]
