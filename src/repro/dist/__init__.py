"""Distributed-execution helpers: logical-axis sharding rules + param/batch
sharding construction.

``sharding``        - the logical-axis annotation layer (``ax`` + rule tables)
``params_sharding`` - NamedSharding trees for params / optimizer state /
                      batches / decode caches (FSDP + batch sharding)
"""
from repro.dist import params_sharding, sharding

__all__ = ["params_sharding", "sharding"]
