"""Sharded on-disk checkpointing: npz per pytree-leaf group + json manifest.

Supports async save (background thread snapshotting host copies first, so
the training loop never blocks on disk) and exact restore, including the
data-pipeline step for deterministic replay.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(directory: str, step: int, params, opt_state, extra: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    tag = f"step_{step:08d}"
    path = os.path.join(directory, tag)
    np.savez(path + ".params.npz", **_flatten(params))
    np.savez(path + ".opt.npz", **_flatten(opt_state))
    manifest = {"step": step, "extra": extra or {}}
    with open(path + ".json", "w") as f:
        json.dump(manifest, f)
    # atomic-ish publish
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(tag)
    os.replace(os.path.join(directory, "LATEST.tmp"), os.path.join(directory, "LATEST"))
    return tag


def save_async(directory: str, step: int, params, opt_state, extra=None) -> threading.Thread:
    """Snapshot to host memory synchronously, write in the background."""
    params_host = jax.tree_util.tree_map(np.asarray, params)
    opt_host = jax.tree_util.tree_map(np.asarray, opt_state)
    t = threading.Thread(
        target=save, args=(directory, step, params_host, opt_host, extra), daemon=True
    )
    t.start()
    return t


def latest_step(directory: str) -> Optional[int]:
    latest = os.path.join(directory, "LATEST")
    if not os.path.exists(latest):
        return None
    tag = open(latest).read().strip()
    return int(tag.split("_")[1])


def restore(directory: str, params_like, opt_like, step: Optional[int] = None) -> Tuple[Any, Any, Dict]:
    """Restore into the structure of the provided templates."""
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoint in {directory}"
    tag = f"step_{step:08d}"
    path = os.path.join(directory, tag)
    pz = np.load(path + ".params.npz")
    oz = np.load(path + ".opt.npz")
    manifest = json.load(open(path + ".json"))

    def fill(tree, npz):
        paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
        leaves = []
        for path_, leaf in paths:
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path_)
            arr = npz[key]
            leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    return fill(params_like, pz), fill(opt_like, oz), manifest


def restore_params(directory: str, params_like, step: Optional[int] = None) -> Tuple[Any, Dict]:
    """Restore only the parameter tree (+ manifest) from a checkpoint.

    The params-only path for serving/evaluation: no optimizer skeleton is
    needed (and none is loaded — ``restore`` would otherwise demand an
    ``opt_like`` template matching the saved optimizer structure, which a
    serving process does not have)."""
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoint in {directory}"
    tag = f"step_{step:08d}"
    path = os.path.join(directory, tag)
    pz = np.load(path + ".params.npz")
    manifest = json.load(open(path + ".json"))

    paths, treedef = jax.tree_util.tree_flatten_with_path(params_like)
    leaves = []
    for path_, leaf in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path_)
        arr = pz[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest
