"""Checkpointing: sharded disk checkpoints + diskless buddy/parity stores."""
from repro.ckpt import diskless, save
__all__ = ["diskless", "save"]
