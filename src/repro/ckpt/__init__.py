"""Checkpointing: sharded disk checkpoints, diskless buddy/parity stores,
and suspend/restore of in-flight FT-CAQR sweeps (``repro.ckpt.sweep``)."""
from repro.ckpt import diskless, save, sweep
from repro.ckpt.sweep import load_sweep_state, save_sweep_state
__all__ = ["diskless", "save", "sweep", "load_sweep_state",
           "save_sweep_state"]
