"""Suspend/persist/restore of in-flight factorizations (DESIGN.md §9).

A ``SweepState`` (``repro.ft.online.state``) is the *complete* loop state of
the windowed FT-CAQR sweep at a recoverable boundary, so writing it to disk
suspends the factorization and loading it in a fresh process resumes it —
iterating ``sweep_step`` from the restored state finishes bit-identically
to the uninterrupted run (regression-gated by
``tests/test_online_recovery.py``).

Wire format: one ``.npz`` holding the flattened named arrays plus a
``__meta__`` JSON record (geometry, cursor, tuple arities) — see
``sweep_state_to_host``. Everything is plain numpy: a state can be saved,
inspected, or shipped with no live jax devices.
"""
from __future__ import annotations

import os

import numpy as np

from repro.ft.online.state import (
    SweepState,
    WIRE_VERSION,
    sweep_state_from_host,
    sweep_state_to_host,
)


def save_sweep_state(path: str, state: SweepState,
                     version: int = WIRE_VERSION) -> str:
    """Suspend: write a mid-sweep state to ``path`` (``.npz`` appended if
    missing). Atomic-ish: writes ``path + '.tmp'`` then renames.
    ``version=2`` (default) persists the coded parity slots; ``version=1``
    writes the PR-9 format (still loadable, minus the parity)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    arrays = sweep_state_to_host(state, version=version)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)
    return path


def load_sweep_state(path: str, to_device: bool = True) -> SweepState:
    """Resume: load a saved sweep state. ``to_device=False`` keeps numpy
    leaves (pure-host inspection)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    return sweep_state_from_host(arrays, to_device=to_device)
