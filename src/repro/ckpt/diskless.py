"""Diskless checkpointing (paper §II, [PLP98]/[CFG+05] lineage).

Two schemes over logical lanes (data-parallel ranks):

* ``BuddyStore``  — each lane keeps a full host-memory replica of its
  XOR-buddy's state shard. Recovery of one failed lane = one fetch from its
  buddy — the training-loop mirror of the paper's "recover from ONE process".

* ``ParityStore`` — groups of g lanes keep an XOR parity of the bitwise
  float representations; any single loss inside a group is rebuilt from the
  g-1 survivors + parity (classic diskless checksum, [CFG+05]). Denser
  (1/g memory overhead vs 1x) but needs g-1 reads to rebuild.

States are numpy pytrees (host memory — on a real pod this is the neighbor
chip's HBM reachable via ICI; here host RAM stands in).
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import numpy as np


def _to_host(tree) -> Any:
    return jax.tree_util.tree_map(np.asarray, tree)


def _xor_trees(a, b):
    def x(u, v):
        ub = u.view(np.uint8) if u.dtype != np.uint8 else u
        vb = v.view(np.uint8) if v.dtype != np.uint8 else v
        return (ub ^ vb).view(u.dtype)

    return jax.tree_util.tree_map(x, a, b)


class BuddyStore:
    """Full replica on the XOR(1)-buddy lane."""

    def __init__(self, n_lanes: int):
        assert n_lanes % 2 == 0
        self.n = n_lanes
        self._store: Dict[int, Any] = {}

    def buddy(self, lane: int) -> int:
        return lane ^ 1

    def push(self, lane: int, state) -> None:
        """Lane ``lane`` ships its state to its buddy's memory."""
        self._store[self.buddy(lane)] = _to_host(state)

    def recover(self, failed: int) -> Any:
        """Rebuild the failed lane's state; reads ONE surviving store —
        the replica sitting in its buddy's memory."""
        holder = self.buddy(failed)
        assert holder in self._store, f"lane {holder} holds no replica"
        return self._store[holder]


class SweepStateStore:
    """Diskless host-memory snapshots of an in-flight FT-CAQR sweep.

    The online orchestrator (``repro.ft.online.orchestrator``) pushes the
    live ``SweepState`` here every ``persist_every`` segment boundaries; if
    the orchestrating host itself dies, a successor restores the last
    boundary state and resumes — the sweep-level analogue of the training
    loop's buddy checkpointing above (on a real pod this memory is a
    neighbor host's RAM; here it stands in). Keeps ``keep`` most-recent
    snapshots (the previous one guards against dying mid-push).

    ``version`` selects the sweep-state wire format (default: current).
    v2 snapshots carry the coded parity slots, so a restore under
    ``MDSScheme`` can joint-decode deaths at the resume boundary without a
    re-encode vulnerability window; ``version=1`` reproduces the old
    parity-less snapshots.
    """

    def __init__(self, keep: int = 2, version: int = None):
        assert keep >= 1
        self.keep = keep
        if version is None:
            from repro.ft.online.state import WIRE_VERSION

            version = WIRE_VERSION
        self.version = version
        self._snaps: List[Dict[str, np.ndarray]] = []

    def push(self, state) -> None:
        from repro.ft.online.state import sweep_state_to_host

        self._snaps.append(sweep_state_to_host(state, version=self.version))
        del self._snaps[: -self.keep]

    def __len__(self) -> int:
        return len(self._snaps)

    def restore(self, back: int = 0):
        """Rebuild the ``back``-th most recent snapshot (0 = latest)."""
        from repro.ft.online.state import sweep_state_from_host

        assert self._snaps, "no snapshot pushed"
        return sweep_state_from_host(self._snaps[-1 - back])


class ParityStore:
    """XOR parity per group of ``group`` lanes."""

    def __init__(self, n_lanes: int, group: int = 4):
        assert n_lanes % group == 0
        self.n = n_lanes
        self.g = group
        self._parity: Dict[int, Any] = {}
        self._shards: Dict[int, Any] = {}

    def push_group(self, states: List[Any]) -> None:
        """Checkpoint all lanes (called at a checkpoint step)."""
        assert len(states) == self.n
        self._shards = {i: _to_host(s) for i, s in enumerate(states)}
        for g0 in range(0, self.n, self.g):
            parity = self._shards[g0]
            for i in range(g0 + 1, g0 + self.g):
                parity = _xor_trees(parity, self._shards[i])
            self._parity[g0 // self.g] = parity

    def recover(self, failed: int) -> Any:
        """Rebuild from the g-1 survivors + the group parity."""
        g0 = (failed // self.g) * self.g
        acc = self._parity[failed // self.g]
        for i in range(g0, g0 + self.g):
            if i != failed:
                acc = _xor_trees(acc, self._shards[i])
        return acc
