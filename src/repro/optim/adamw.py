"""AdamW (functional, optax-style but self-contained)."""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


class Optimizer(NamedTuple):
    init: Any
    update: Any


def adamw(
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    def init(params):
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params
        )
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree_util.tree_map(jnp.copy, zeros))

    def update(grads, state: AdamWState, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        tm = jax.tree_util.tree_map
        mu = tm(lambda g, m: b1 * m + (1 - b1) * g.astype(jnp.float32),
                grads, state.mu)
        nu = tm(lambda g, v: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                grads, state.nu)

        def delta(m, v, p):
            m_hat = m / (1 - b1 ** t)
            v_hat = v / (1 - b2 ** t)
            d = m_hat / (jnp.sqrt(v_hat) + eps) + weight_decay * p.astype(jnp.float32)
            return (-lr * d).astype(p.dtype)

        updates = tm(delta, mu, nu, params)
        return updates, AdamWState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)
