"""Optimizers: AdamW, CAQR-Muon (TSQR-orthogonalized momentum), PowerSGD-QR
gradient compression, schedules.

Import the factory functions from their modules (``repro.optim.adamw.adamw``)
— the package namespace exposes only the submodules to avoid shadowing.
"""
from repro.optim import adamw, caqr_muon, powersgd, schedule

__all__ = ["adamw", "caqr_muon", "powersgd", "schedule"]
