"""Adafactor (simplified): factored second moments, no first moment.

The memory-frugal optimizer for the trillion-parameter dry-run cells
(kimi-k2): optimizer state is O(m+n) per (m, n) weight instead of O(2*m*n)
f32 — the difference between fitting and not fitting 1T params on 512
v5e chips (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any   # row second moments (or full v for <2D leaves)
    vc: Any   # col second moments (zeros((0,)) for <2D leaves)


def adafactor(
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
) -> Optimizer:
    def factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1

    def init(params):
        tm = jax.tree_util.tree_map

        def vr0(p):
            if factored(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc0(p):
            if factored(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((0,), jnp.float32)

        return AdafactorState(
            step=jnp.zeros((), jnp.int32), vr=tm(vr0, params), vc=tm(vc0, params)
        )

    def update(grads, state: AdafactorState, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)
        tm = jax.tree_util.tree_map

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if factored(p):
                vr_new = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                vc_new = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr_new[..., None]
                    * vc_new[..., None, :]
                    / jnp.mean(vr_new, axis=-1, keepdims=True)[..., None]
                )
            else:
                vr_new = beta * vr + (1 - beta) * g2
                vc_new = vc
                denom = jnp.sqrt(vr_new)
            u = g / jnp.maximum(denom, eps)
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (-lr * u).astype(p.dtype), vr_new, vc_new

        updates = tm(lambda g, vr, vc, p: upd(g, vr, vc, p)[0],
                     grads, state.vr, state.vc, params)
        vr = tm(lambda g, vr, vc, p: upd(g, vr, vc, p)[1],
                grads, state.vr, state.vc, params)
        vc = tm(lambda g, vr, vc, p: upd(g, vr, vc, p)[2],
                grads, state.vr, state.vc, params)
        return updates, AdafactorState(step=step, vr=vr, vc=vc)

    return Optimizer(init=init, update=update)
