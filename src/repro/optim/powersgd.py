"""PowerSGD-QR: low-rank gradient compression whose orthonormalization is
the paper's TSQR (distributed-optimization trick for cross-pod reduction).

For a gradient matrix G (m, n) reduced across an axis (e.g. pods), instead
of all-reducing m*n values:

    P       = G @ Omega            Omega: fixed random (n, r)
    P_sync  = psum(P)              r*m values on the wire
    Q       = TSQR-orth(P_sync)    the paper's primitive
    R       = G^T @ Q
    R_sync  = psum(R)              r*n values on the wire
    G_hat   = Q @ R_sync^T

with an error-feedback buffer E: compress(G + E), E <- (G + E) - G_hat.
Wire volume drops from m*n to r*(m+n) per matrix. The rank-r subspace is
refreshed every step from the previous Q (power iteration warm start).

``compress_tree`` applies this to every large 2-D leaf of a gradient pytree
inside shard_map over the reduction axis; small/1-D leaves psum directly.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.tsqr import tsqr_orthonormalize


class PowerSGDState(NamedTuple):
    error: Any    # error-feedback buffers (same structure as the 2-D subset)
    sketch: Any   # warm-start sketches ((n, r) per compressible leaf)


def _tile_for(rows: int, cols: int) -> int:
    for cand in (512, 256, 128, 64):
        if rows % cand == 0 and cand >= cols:
            return cand
    return rows


def psgd_project(G: jax.Array, omega: jax.Array,
                 error: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Phase 1 of the split compression: the error-compensated gradient and
    its sketch projection ``(Gc, Gc @ omega)``. The caller reduces the
    projection across lanes and orthonormalizes it — ``compress_reduce``
    does both inline; the FT training runtime routes the orthonormalization
    through a host-driven FT-CAQR sweep instead."""
    Gc = G.astype(jnp.float32) + error
    return Gc, Gc @ omega.astype(jnp.float32)


def psgd_rfactor(Gc: jax.Array, Q: jax.Array) -> jax.Array:
    """Phase 2: this lane's R contribution ``Gc^T @ Q`` (reduce across
    lanes before :func:`psgd_complete`)."""
    return Gc.T @ Q


def psgd_complete(Gc: jax.Array, Q: jax.Array, R: jax.Array,
                  out_dtype) -> Tuple[jax.Array, jax.Array]:
    """Phase 3: reconstruction and error feedback from the reduced R —
    returns ``(G_hat, new_error)``. Same arithmetic whether Q came from the
    inline TSQR or an FT-CAQR sweep."""
    G_hat = Q @ R.T
    return G_hat.astype(out_dtype), Gc - G_hat


def compress_reduce(
    G: jax.Array,          # (m, n) this lane's gradient shard
    omega: jax.Array,      # (n, r) sketch — warm-started with the previous
                           # step's R factor (power iteration), so the rank-r
                           # subspace converges to the top singular space
    error: jax.Array,      # (m, n) error feedback
    axis_name: Optional[str],
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (G_hat averaged over the axis, new error, next sketch). With
    axis_name=None runs the compression locally (rank-r filter only)."""
    m, n = G.shape
    r = omega.shape[1]
    Gc, P = psgd_project(G, omega, error)                  # (m, r)
    if axis_name is not None:
        P = jax.lax.pmean(P, axis_name)
    Q, _ = tsqr_orthonormalize(P, _tile_for(m, r))         # paper's TSQR
    R = psgd_rfactor(Gc, Q)                                # (n, r)
    if axis_name is not None:
        R = jax.lax.pmean(R, axis_name)
    G_hat, new_error = psgd_complete(Gc, Q, R, G.dtype)
    return G_hat, new_error, R


def init_state(key, params, rank: int = 8, min_size: int = 4096):
    """Error buffers (zeros) + random initial sketches per compressible leaf."""

    def buf(p):
        if p.ndim == 2 and p.size >= min_size:
            return jnp.zeros(p.shape, jnp.float32)
        return jnp.zeros((0,), jnp.float32)

    def om(k, p):
        if p.ndim == 2 and p.size >= min_size:
            return jax.random.normal(k, (p.shape[1], rank), jnp.float32) / jnp.sqrt(rank)
        return jnp.zeros((0,), jnp.float32)

    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(key, len(leaves))
    sketch = jax.tree_util.tree_unflatten(
        treedef, [om(k, p) for k, p in zip(keys, leaves)]
    )
    return PowerSGDState(
        error=jax.tree_util.tree_map(buf, params), sketch=sketch
    )


def compress_tree(
    grads, state: PowerSGDState, axis_name: Optional[str],
    rank: int = 8, min_size: int = 4096,
):
    """Compress-reduce every eligible leaf; psum the rest. Returns
    (reduced grads, new state)."""
    tm = jax.tree_util.tree_map

    def one(g, om, e):
        if g.ndim == 2 and g.size >= min_size:
            return compress_reduce(g, om, e, axis_name)
        if axis_name is not None:
            g = jax.lax.pmean(g, axis_name)
        return g, e, om

    new_grads = tm(lambda g, om, e: one(g, om, e)[0], grads, state.sketch, state.error)
    new_err = tm(lambda g, om, e: one(g, om, e)[1], grads, state.sketch, state.error)
    new_sketch = tm(lambda g, om, e: one(g, om, e)[2], grads, state.sketch, state.error)
    return new_grads, PowerSGDState(error=new_err, sketch=new_sketch)
