"""CAQR-Muon: momentum orthogonalized by the paper's TSQR.

Muon-style optimizer for 2-D weights: the momentum matrix is replaced by an
orthonormal matrix with the same column space before the update. Where Muon
uses Newton-Schulz to approximate the polar factor, we use the *thin-QR Q*
computed by the paper's TSQR — the sequential chain on one host (XLA
partitions it under GSPMD), with the FT-butterfly ``dist_orthonormalize``
available for explicit shard_map use (the training framework's first-class
use of the paper's primitive: every model-parallel rank finishes with the
replicated R, so a failed rank's optimizer step is reconstructible from any
buddy).

Embeddings / lm_head / non-2D params fall back to Adam-style scaling, per
standard Muon practice. Stacked layer groups (G, D, F) and MoE expert banks
(E, D, F) are orthogonalized per slice via vmap.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.tsqr import tsqr_orthonormalize
from repro.optim.adamw import Optimizer

_EXCLUDE = ("embed", "lm_head", "enc_pos")


class MuonState(NamedTuple):
    step: jax.Array
    mom: Any   # f32 momentum (all params)
    nu: Any    # adam second moment (used on the non-muon subset)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _is_muon(path, p) -> bool:
    if any(e in _path_str(path) for e in _EXCLUDE):
        return False
    return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1


def _orth2d(M: jax.Array, tile_rows: int = 512) -> jax.Array:
    m, n = M.shape
    tall = m >= n
    A = M if tall else M.T
    rows, cols = A.shape
    tile = rows
    for cand in (tile_rows, 256, 128, 64):
        if rows % cand == 0 and cand >= cols:
            tile = cand
            break
    Q, _ = tsqr_orthonormalize(A, tile)
    return Q if tall else Q.T


def _orth(M: jax.Array) -> jax.Array:
    if M.ndim == 2:
        return _orth2d(M)
    lead = M.shape[:-2]
    flat = M.reshape((-1,) + M.shape[-2:])
    return jax.vmap(_orth2d)(flat).reshape(lead + M.shape[-2:])


def _orth_default(path, m: jax.Array) -> jax.Array:
    return _orth(m)


def muon_moments(grads, state: MuonState, params,
                 *, b1: float = 0.95, adam_b2: float = 0.95):
    """The momentum / second-moment update, as one reusable phase.

    Shared by the monolithic :func:`caqr_muon` update and the FT training
    runtime's grad phase (``repro.train.ftrun``) — ONE floating-point
    program, so the split-phase runtime cannot drift from the optimizer it
    reroutes. Returns ``(mom, nu)``."""
    tmp = jax.tree_util.tree_map_with_path

    def upd_mom(path, g, m, p):
        if _is_muon(path, p):
            return b1 * m + g.astype(jnp.float32)
        return b1 * m + (1 - b1) * g.astype(jnp.float32)

    def upd_nu(path, g, v, p):
        if _is_muon(path, p):
            return v
        return adam_b2 * v + (1 - adam_b2) * jnp.square(g.astype(jnp.float32))

    return (tmp(upd_mom, grads, state.mom, params),
            tmp(upd_nu, grads, state.nu, params))


def muon_deltas(params, mom, nu, lr, t,
                *, b1: float = 0.95, adam_b2: float = 0.95,
                eps: float = 1e-8, weight_decay: float = 0.0,
                adam_scale: float = 0.3, orth=_orth_default):
    """The parameter-delta phase: muon leaves get ``orth(path, mom)``
    (default: the local TSQR chain ``_orth``), everything else the
    Adam-style scaling. ``t`` is the float step count AFTER increment.

    The FT runtime passes an ``orth`` override that substitutes the
    Q factors its FT-CAQR sweeps computed for the routed leaves, so the
    surrounding arithmetic stays this exact program."""
    tmp = jax.tree_util.tree_map_with_path

    def delta(path, p, m, v):
        if _is_muon(path, p):
            O = orth(path, m)
            scale = jnp.sqrt(jnp.maximum(1.0, p.shape[-2] / p.shape[-1]))
            d = O * scale + weight_decay * p.astype(jnp.float32)
            return (-lr * d).astype(p.dtype)
        m_hat = m / (1 - b1 ** t)
        v_hat = v / (1 - adam_b2 ** t)
        d = m_hat / (jnp.sqrt(v_hat) + eps)
        return (-lr * adam_scale * d).astype(p.dtype)

    return tmp(delta, params, mom, nu)


def caqr_muon(
    b1: float = 0.95,
    adam_b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    adam_scale: float = 0.3,
) -> Optimizer:
    def init(params):
        tm = jax.tree_util.tree_map
        mom = tm(lambda p: jnp.zeros_like(p, jnp.float32), params)
        nu = tm(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return MuonState(step=jnp.zeros((), jnp.int32), mom=mom, nu=nu)

    def update(grads, state: MuonState, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        mom, nu = muon_moments(grads, state, params, b1=b1, adam_b2=adam_b2)
        updates = muon_deltas(
            params, mom, nu, lr, t, b1=b1, adam_b2=adam_b2, eps=eps,
            weight_decay=weight_decay, adam_scale=adam_scale)
        return updates, MuonState(step=step, mom=mom, nu=nu)

    return Optimizer(init=init, update=update)
