"""Training loop with the fault-tolerance supervisor.

The loop drives logical data-parallel lanes through deterministic data,
takes diskless (buddy) checkpoints of the full training state every
``diskless_every`` steps plus periodic disk checkpoints, and reacts to
detected lane failures with the configured FT-MPI semantics (paper §II):

  REBUILD — restore params+opt from the buddy store, rewind the data
            pipeline to the checkpointed step and replay: training continues
            *bit-identical* to a failure-free run (the integration test
            asserts exact equality).
  SHRINK  — drop the lane: the global batch loses its rows, survivors
            renumber, training continues on the smaller world.
  BLANK   — keep the hole: the dead lane's rows are masked out of each
            batch (loss renormalized), ranks unchanged.
  ABORT   — re-raise (the non-FT default).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import diskless, save
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, make_batch
from repro.ft.failures import Detector, FailureSchedule
from repro.ft.semantics import Semantics
from repro.models import transformer as tf
import repro.optim.adamw as adamw_mod
from repro.optim.schedule import warmup_cosine
from repro.train.step import TrainState, make_train_step


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    lr: float = 3e-3
    warmup: int = 10
    grad_accum: int = 1
    n_lanes: int = 4                  # logical data-parallel lanes
    diskless_every: int = 5
    ckpt_every: int = 0               # 0 = no disk checkpoints
    ckpt_dir: str = "/tmp/repro_ckpt"
    semantics: Semantics = Semantics.REBUILD
    optimizer: str = "adamw"          # adamw | caqr_muon
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, dcfg: DataConfig):
        self.cfg, self.tcfg, self.dcfg = cfg, tcfg, dcfg
        assert dcfg.global_batch % tcfg.n_lanes == 0
        if tcfg.optimizer == "caqr_muon":
            from repro.optim.caqr_muon import caqr_muon

            self.opt = caqr_muon()
        else:
            self.opt = adamw_mod.adamw()
        self._lr_fn = warmup_cosine(tcfg.lr, tcfg.warmup, tcfg.steps)
        self._step_fn = jax.jit(
            make_train_step(cfg, self.opt, self._lr_fn, tcfg.grad_accum)
        )
        params = tf.init_params(cfg, jax.random.key(tcfg.seed))
        self.state = TrainState(params, self.opt.init(params), jnp.zeros((), jnp.int32))
        self.buddy = diskless.BuddyStore(max(tcfg.n_lanes, 2))
        self.detector = Detector(tcfg.n_lanes)
        self.active_lanes: List[int] = list(range(tcfg.n_lanes))
        self.blanked: List[int] = []
        self._last_diskless_step = -1
        self._start_step = 0          # nonzero when resuming a suspended run
        self.history: List[Dict] = []

    # -- diskless checkpoint of the full training state ---------------------
    def _push_diskless(self, step: int) -> None:
        for lane in self.active_lanes:
            self.buddy.push(lane, {"state": self.state, "step": step})
        self._last_diskless_step = step

    def _restore_diskless(self, failed: int) -> int:
        blob = self.buddy.recover(failed)
        self.state = jax.tree_util.tree_map(jnp.asarray, blob["state"])
        return int(blob["step"])

    # -- failure handling ----------------------------------------------------
    def _handle_failures(self, step: int, lanes: List[int]) -> int:
        """Returns the (possibly rewound) step to continue from."""
        sem = self.tcfg.semantics
        if sem == Semantics.ABORT:
            raise RuntimeError(f"lanes {lanes} failed at step {step}; ABORT")
        if sem == Semantics.REBUILD:
            resume = step
            for lane in lanes:
                ck_step = self._restore_diskless(lane)
                resume = min(resume, ck_step)
                self.detector.revive(lane)
            return resume  # deterministic data replay from the ckpt step
        if sem == Semantics.SHRINK:
            for lane in lanes:
                self.active_lanes.remove(lane)
            assert self.active_lanes, "all lanes dead"
            return step
        if sem == Semantics.BLANK:
            self.blanked.extend(lanes)
            return step
        raise ValueError(sem)

    def _lane_batch(self, step: int) -> Dict[str, jnp.ndarray]:
        """Assemble the global batch from the rows of live lanes."""
        per = self.dcfg.global_batch // self.tcfg.n_lanes
        full = make_batch(self.dcfg, step)
        rows = []
        for lane in range(self.tcfg.n_lanes):
            if lane in self.blanked or lane not in self.active_lanes:
                continue
            rows.append(slice(lane * per, (lane + 1) * per))
        sel = np.concatenate([np.r_[r] for r in rows])
        return {k: jnp.asarray(v[sel]) for k, v in full.items()}

    # -- step execution (overridden by the FT runtime) ----------------------
    def _execute_step(self, step: int, batch) -> Dict[str, Any]:
        """One optimizer step: advance ``self.state``, return metrics.

        The base trainer runs the monolithic jitted step. The FT runtime
        (``repro.train.ftrun.FTTrainer``) overrides this with the
        split-phase step that routes optimizer-internal factorizations
        through host-driven FT-CAQR sweeps — everything else in ``run``
        (diskless checkpoints, failure semantics, deterministic replay) is
        shared verbatim."""
        self.state, metrics = self._step_fn(self.state, batch)
        return metrics

    # -- main loop -------------------------------------------------------------
    def run(self, schedule: Optional[FailureSchedule] = None) -> List[Dict]:
        self.detector.schedule = schedule or FailureSchedule()
        step = self._start_step
        while step < self.tcfg.steps:
            newly_dead = self.detector.begin_step(step)
            if newly_dead:
                step = self._handle_failures(step, newly_dead)
            if step % self.tcfg.diskless_every == 0:
                self._push_diskless(step)
            if self.tcfg.ckpt_every and step and step % self.tcfg.ckpt_every == 0:
                save.save_async(
                    self.tcfg.ckpt_dir, step, self.state.params,
                    self.state.opt_state, {"data_step": step},
                )
            batch = self._lane_batch(step)
            t0 = time.perf_counter()
            metrics = self._execute_step(step, batch)
            dt = time.perf_counter() - t0
            rec = {
                "step": step,
                "loss": float(metrics["loss"]),
                "lanes": len(self.active_lanes) - len(self.blanked),
                "dt": dt,
            }
            self.history.append(rec)
            if step % self.tcfg.log_every == 0:
                print(
                    f"step {step:5d} loss {rec['loss']:.4f} "
                    f"lanes {rec['lanes']} {dt*1e3:.1f}ms"
                )
            step += 1
        return self.history
