"""The FT-QR engine behind the fault-tolerant training runtime.

One ``QREngine`` instance serves every optimizer-internal factorization of
a training run (DESIGN.md §14): each ``orthonormalize`` call is a full
windowed FT-CAQR sweep driven by the online orchestrator (DESIGN.md §9) —
segment boundaries, runtime failure detection, REBUILD healing (or MDS
joint decode), optional async double-buffered segments — so a lane killed
*inside* an optimizer step is healed inside that step and the returned Q is
bitwise-identical to the failure-free sweep.

Execution backends (both drive the same ``sweep_step`` program):

* default — jitted host segments over ``SimComm`` (lane axis = leading
  array axis);
* ``mesh=`` — ``shard_map`` segments over a 1-D lane mesh
  (``repro.launch.spmd_qr.make_spmd_sweep_step``), the production SPMD
  path; state lives lane-sharded on the mesh between segments.

Q recovery: the sweep produces the replicated R factor; the engine forms
``Q = A R^{-1}`` with one triangular solve. In exact arithmetic
``R^T R = A^T A`` regardless of the zero rows used to pad ``A`` to a
lane-divisible height, so Q is orthonormal with A's column space — and
because R is bitwise-reproducible under failures, so is Q.

Suspension: a boundary hook may raise :class:`SuspendSweep` carrying the
boundary-consistent state; the training runtime persists it
(``repro.ckpt.sweep``, wire v2 keeps the MDS parity slots) and a fresh
process resumes the sweep mid-factorization via the orchestrator's
``from_state``.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.comm import SimComm
from repro.ft.online.detect import NaNSentinelDetector
from repro.ft.online.orchestrator import SweepOrchestrator
from repro.ft.online.state import SweepState
from repro.ft.semantics import Semantics


class SuspendSweep(Exception):
    """Raised by an engine boundary hook to suspend the in-flight sweep.
    Carries the boundary-consistent ``SweepState`` (post-recovery, parity
    refreshed) — persist it with ``repro.ckpt.save_sweep_state`` and resume
    with ``QREngine.orthonormalize(..., resume_state=...)``."""

    def __init__(self, state: SweepState):
        super().__init__("sweep suspended at a segment boundary")
        self.state = state


class SuspendAfter:
    """Boundary hook: raise :class:`SuspendSweep` once ``n`` cumulative
    segment boundaries (across all sweeps of the engine) have run. The
    test/demo lever for "process dies mid-factorization": the sweep state
    at the raise is exactly what a periodic persist would have captured."""

    def __init__(self, n: int):
        assert n > 0
        self.n = n
        self.seen = 0

    def __call__(self, orch: SweepOrchestrator) -> None:
        self.seen += 1
        if self.seen >= self.n and orch.state.cursor is not None:
            raise SuspendSweep(orch.state)


@jax.jit
def _q_from_r(A: jax.Array, R: jax.Array) -> jax.Array:
    # Q = A R^{-1}  via  R^T Q^T = A^T  (one triangular solve, no inverse)
    return jax.scipy.linalg.solve_triangular(R, A.T, trans=1,
                                             lower=False).T


class QREngine:
    """Factorization service for optimizer-internal FT-CAQR sweeps.

    Parameters
    ----------
    n_lanes:
        Sweep lanes (power of two — the butterfly's requirement; see
        ``repro.launch.spmd_qr.pow2_lanes`` for non-pow2 pods).
    panel_width:
        Sweep panel width (clamped per call to the matrix's column count).
    mesh:
        Optional 1-D lane mesh: segments run as shard_map programs over it
        instead of jitted host segments. ``mesh`` lane count must equal
        ``n_lanes``.
    scheme:
        Optional ``CodingScheme`` (e.g. ``MDSScheme(f)``) — parity refresh
        at every boundary, joint decode on multi-death.
    semantics, async_segments, store, persist_every, fault_hooks,
    boundary_hooks:
        Passed to every sweep's ``SweepOrchestrator``. Hooks are shared,
        stateful objects living across sweeps (fault injectors gate on the
        runtime's current step/task; ``SuspendAfter`` counts cumulative
        boundaries). The detector is fresh per sweep (its report-once state
        is per-matrix).

    Stats (cumulative over the engine's lifetime, for the train bench):
    ``sweeps``, ``boundaries``, ``segments``, ``poll_s``, ``sweep_s``.
    """

    def __init__(
        self,
        n_lanes: int = 4,
        panel_width: int = 16,
        mesh=None,
        axis_name: str = "qr",
        scheme=None,
        semantics: Semantics = Semantics.REBUILD,
        async_segments: bool = False,
        detector_factory: Callable[[], object] = NaNSentinelDetector,
        fault_hooks: Sequence = (),
        boundary_hooks: Sequence = (),
        store=None,
        persist_every: Optional[int] = None,
    ):
        assert n_lanes & (n_lanes - 1) == 0, "lanes must be a power of two"
        self.n_lanes = n_lanes
        self.panel_width = panel_width
        self.comm = SimComm(n_lanes)
        if mesh is not None:
            from repro.launch.spmd_qr import make_spmd_sweep_step

            (mesh_lanes,) = mesh.devices.shape
            assert mesh_lanes == n_lanes, (mesh_lanes, n_lanes)
            self.step_fn = make_spmd_sweep_step(mesh, axis_name)
        else:
            self.step_fn = None
        self.scheme = scheme
        self.semantics = semantics
        self.async_segments = async_segments
        self.detector_factory = detector_factory
        self.fault_hooks = list(fault_hooks)
        self.boundary_hooks = list(boundary_hooks)
        self.store = store
        self.persist_every = persist_every
        # cumulative stats
        self.sweeps = 0
        self.boundaries = 0
        self.segments = 0
        self.poll_s = 0.0
        self.sweep_s = 0.0

    # -- one factorization ---------------------------------------------------

    def _orchestrator(self, A0, panel_width: int,
                      resume_state: Optional[SweepState]):
        kw = dict(
            detector=self.detector_factory(),
            step_fn=self.step_fn,
            fault_hooks=self.fault_hooks,
            boundary_hooks=self.boundary_hooks,
            semantics=self.semantics,
            scheme=self.scheme,
            async_segments=self.async_segments,
            store=self.store,
            persist_every=self.persist_every,
        )
        if resume_state is not None:
            return SweepOrchestrator.from_state(resume_state, self.comm, **kw)
        return SweepOrchestrator(A0, self.comm, panel_width, **kw)

    def factorize(self, M: jax.Array,
                  resume_state: Optional[SweepState] = None) -> jax.Array:
        """FT-CAQR sweep of tall-or-square ``M (m, n)``; returns the
        replicated ``(n, n)`` R factor. ``resume_state`` continues a
        suspended sweep instead of starting fresh (``M`` is then only used
        for shape bookkeeping — the state IS the computation)."""
        m, n = M.shape
        assert m >= n, "factorize wants tall input; use orthonormalize"
        P = self.n_lanes
        pad = (-m) % P
        Ap = M if pad == 0 else jnp.concatenate(
            [M, jnp.zeros((pad, n), M.dtype)], axis=0)
        A0 = Ap.reshape(P, (m + pad) // P, n)
        orch = self._orchestrator(A0, min(self.panel_width, n), resume_state)
        t0 = time.perf_counter()
        try:
            res = orch.run()
        finally:
            self.sweeps += 1
            self.boundaries += orch.boundaries
            self.segments += orch.segments_run
            self.poll_s += orch.poll_s
            self.sweep_s += time.perf_counter() - t0
        return res.R[0]

    def orthonormalize(self, M: jax.Array,
                       resume_state: Optional[SweepState] = None) -> jax.Array:
        """Q with ``M``'s column space (row space when ``M`` is wide — the
        Muon convention, matching ``repro.optim.caqr_muon._orth2d``),
        computed as ``A R^{-1}`` from an FT-CAQR sweep's R. Raises
        :class:`SuspendSweep` through from a suspension hook."""
        m, n = M.shape
        tall = m >= n
        A = M if tall else M.T
        R = self.factorize(A.astype(jnp.float32), resume_state=resume_state)
        Q = _q_from_r(A.astype(jnp.float32), R)
        return Q if tall else Q.T
