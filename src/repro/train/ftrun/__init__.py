"""Fault-tolerant training runtime (DESIGN.md §14): the optimizer's own
factorizations run as online FT-CAQR sweeps, healed in place when lanes
die mid-step, suspendable/resumable across process restarts, with optional
async double-buffered segment execution."""
from repro.train.ftrun.engine import QREngine, SuspendAfter, SuspendSweep
from repro.train.ftrun.runtime import (
    FTRunConfig,
    FTTrainer,
    StepSweepKiller,
    TrainingSuspended,
)
from repro.train.ftrun.tasks import (
    QRTask,
    plan_muon_tasks,
    plan_psgd_tasks,
)

__all__ = [
    "QREngine",
    "SuspendAfter",
    "SuspendSweep",
    "FTRunConfig",
    "FTTrainer",
    "StepSweepKiller",
    "TrainingSuspended",
    "QRTask",
    "plan_muon_tasks",
    "plan_psgd_tasks",
]
