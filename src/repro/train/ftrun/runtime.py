"""FT training runtime: optimizer-internal FT-CAQR sweeps (DESIGN.md §14).

``FTTrainer`` embeds the paper's fault-tolerant factorization *inside* the
training step. Instead of the monolithic jitted step, an optimizer step is
split into three phases:

1. **grad phase** (jit) — loss, gradients, and the optimizer's moment
   update, via the SAME builders the monolithic step uses
   (``make_loss_and_grads``, ``muon_moments``) so the arithmetic is the
   identical FP program;
2. **factorization task loop** (host) — each planned :class:`QRTask` runs
   a full online FT-CAQR sweep on the :class:`QREngine`: runtime failure
   detection, REBUILD healing (or MDS joint decode), optionally async
   double-buffered segments or shard_map execution over a lane mesh. A
   lane killed mid-step is healed *inside the step*: the recovered Q is
   bitwise-identical, so the loss curve is bitwise-identical to the
   failure-free run with no training-level rewind;
3. **finish phase** (jit) — ``muon_deltas`` with the engine's Q factors
   substituted for the routed leaves, then the parameter update.

Routings:

* ``optimizer="caqr_muon"`` — the momentum orthogonalization of every
  large Muon leaf goes through the engine (per stacked slice).
* ``optimizer="adamw"`` + ``compression_rank>0`` — the PowerSGD-QR bridge:
  per-lane gradients are compressed through the split
  ``psgd_project``/``psgd_rfactor``/``psgd_complete`` phases with the
  projection's orthonormalization rerouted through the engine.

Checkpoint composition: a boundary hook may suspend training *mid-sweep*
(:class:`SuspendSweep`); the trainer persists the model checkpoint plus the
in-flight sweep state (wire v2 — MDS parity included) and raises
:class:`TrainingSuspended`. ``FTTrainer.resume`` restores both in a fresh
process: the grad phase and earlier tasks replay deterministically, the
suspended sweep continues via the orchestrator's ``from_state``, and the
final parameters are bitwise-identical to the uninterrupted run.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.ckpt import save
from repro.ckpt.sweep import load_sweep_state, save_sweep_state
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig
from repro.ft.coding import MDSScheme
from repro.ft.failures import prev_sweep_point
from repro.ft.online.state import WIRE_VERSION
from repro.ft.semantics import Semantics
import repro.optim.adamw as adamw_mod
from repro.optim import powersgd
from repro.optim.caqr_muon import (
    MuonState,
    _orth,
    _path_str,
    muon_deltas,
    muon_moments,
)
from repro.train.loop import TrainConfig, Trainer
from repro.train.step import TrainState, grad_norm, make_loss_and_grads
from repro.train.ftrun.engine import QREngine, SuspendAfter, SuspendSweep
from repro.train.ftrun.tasks import (
    QRTask,
    assemble_leaves,
    leaf_by_path,
    plan_muon_tasks,
    plan_psgd_tasks,
    task_slice,
)


@dataclasses.dataclass
class FTRunConfig:
    """Knobs of the FT factorization layer (the training knobs stay on
    ``TrainConfig``)."""

    qr_lanes: Optional[int] = None    # None: 4, or pow2_lanes() with a mesh
    panel_width: int = 16
    min_qr_size: int = 8192           # per-slice element floor for routing
    use_mesh: bool = False            # shard_map segments over a lane mesh
    async_segments: bool = False      # double-buffered segment dispatch
    mds_f: int = 0                    # >0: MDSScheme(f) parity lanes
    compression_rank: int = 0         # >0: PowerSGD bridge (adamw only)
    compression_min_size: int = 8192
    suspend_after_boundaries: int = 0  # >0: suspend mid-sweep (muon only)
    sweep_path: str = ""              # default: <ckpt_dir>/sweep.npz
    sweep_wire_version: int = WIRE_VERSION


class TrainingSuspended(Exception):
    """Raised when a sweep suspension hook fires: the model checkpoint and
    the in-flight sweep state are on disk; ``FTTrainer.resume`` continues
    the run bitwise-identically in a fresh process."""

    def __init__(self, step: int, task: str, sweep_path: str):
        super().__init__(
            f"training suspended at step {step} inside sweep task {task!r}")
        self.step = step
        self.task = task
        self.sweep_path = sweep_path


class StepSweepKiller:
    """Engine fault hook: poison ``lane`` during the optimizer-internal
    sweep of training step ``at_step`` — optionally a specific ``task``
    and/or sweep ``point``; by default the first completed point of the
    step's first sweep. Fires once; records where it struck in
    ``.struck`` as ``(step, task, point)``. The kill lands *inside* the
    factorization, so recovery is the sweep's own REBUILD (no
    training-level rewind happens)."""

    def __init__(self, at_step: int, lane: int,
                 task: Optional[str] = None,
                 point: Optional[Tuple[int, str, int]] = None):
        self.at_step = at_step
        self.lane = lane
        self.task = task
        self.point = point
        self.trainer: Optional["FTTrainer"] = None  # bound by FTTrainer
        self.fired = False
        self.struck: Optional[Tuple[int, str, Tuple[int, str, int]]] = None

    def __call__(self, comm, state):
        if self.fired or self.trainer is None:
            return state
        if self.trainer._cur_step != self.at_step:
            return state
        if self.task is not None and self.trainer._cur_task != self.task:
            return state
        pt = prev_sweep_point(state.cursor, state.geom.n_panels,
                              state.geom.levels)
        if pt is None or (self.point is not None and pt != self.point):
            return state
        from repro.ft.driver import obliterate_state

        self.fired = True
        self.struck = (self.trainer._cur_step, self.trainer._cur_task, pt)
        return obliterate_state(comm, state, self.lane)


# Per-slice PowerSGD phases over the lane axis (jit caches per shape).
@jax.jit
def _lane_project(G_l, omega, err_l):
    Gc_l, P_l = jax.vmap(
        lambda g, e: powersgd.psgd_project(g, omega, e))(G_l, err_l)
    return Gc_l, jnp.mean(P_l, axis=0)


@jax.jit
def _lane_complete(Gc_l, Q):
    R = jnp.mean(jax.vmap(
        lambda gc: powersgd.psgd_rfactor(gc, Q))(Gc_l), axis=0)
    G_hat, err_l = jax.vmap(
        lambda gc: powersgd.psgd_complete(gc, Q, R, jnp.float32))(Gc_l)
    return G_hat[0], err_l, R


class FTTrainer(Trainer):
    """``Trainer`` whose optimizer-internal factorizations run on a
    :class:`QREngine` (see module docstring). Everything else — diskless
    buddy checkpoints, lane-failure semantics, deterministic data replay —
    is the base loop, shared verbatim through ``_execute_step``."""

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, dcfg: DataConfig,
                 fcfg: Optional[FTRunConfig] = None,
                 qr_fault_hooks: Sequence = ()):
        super().__init__(cfg, tcfg, dcfg)
        self.fcfg = fcfg = fcfg or FTRunConfig()
        lanes = fcfg.qr_lanes
        mesh = None
        if fcfg.use_mesh:
            from repro.launch.spmd_qr import make_lane_mesh, pow2_lanes

            if lanes is None:
                lanes = pow2_lanes()
            mesh = make_lane_mesh(lanes)
        elif lanes is None:
            lanes = 4
        self._qr_hooks = list(qr_fault_hooks)
        for h in self._qr_hooks:
            if hasattr(h, "trainer"):
                h.trainer = self
        boundary_hooks = []
        if fcfg.suspend_after_boundaries:
            boundary_hooks.append(SuspendAfter(fcfg.suspend_after_boundaries))
        self.engine = QREngine(
            n_lanes=lanes,
            panel_width=fcfg.panel_width,
            mesh=mesh,
            scheme=MDSScheme(fcfg.mds_f) if fcfg.mds_f else None,
            semantics=Semantics.REBUILD,
            async_segments=fcfg.async_segments,
            fault_hooks=self._qr_hooks,
            boundary_hooks=boundary_hooks,
        )
        self._cur_step = -1
        self._cur_task: Optional[str] = None
        self._pending_resume: Optional[Tuple[str, object]] = None
        self._mode = "plain"
        if tcfg.optimizer == "caqr_muon":
            self._mode = "muon"
            self._tasks = plan_muon_tasks(self.state.params, fcfg.min_qr_size)
            assert self._tasks, (
                "no Muon leaf reaches min_qr_size; lower it or use the "
                "plain Trainer")
            self._grad_fn = jax.jit(self._make_muon_grad())
            self._finish_fn = jax.jit(self._make_muon_finish())
        elif fcfg.compression_rank > 0:
            assert tcfg.optimizer == "adamw", (
                "the PowerSGD bridge pairs with adamw")
            self._mode = "psgd"
            self._tasks = plan_psgd_tasks(self.state.params,
                                          fcfg.compression_min_size)
            assert self._tasks, "no leaf reaches compression_min_size"
            self._lane_grad_fn = jax.jit(self._make_lane_grads())
            self._psgd_finish_fn = jax.jit(self._make_psgd_finish())
            self._psgd = self._init_psgd()
        if fcfg.suspend_after_boundaries:
            assert self._mode == "muon", (
                "mid-sweep suspension is supported on the caqr_muon routing "
                "(the PowerSGD bridge's host-side error buffers are not in "
                "the model checkpoint)")

    # -- diskless checkpoints carry the bridge's host-side state ------------

    def _push_diskless(self, step: int) -> None:
        blob = {"state": self.state, "step": step}
        if self._mode == "psgd":
            blob["psgd"] = self._psgd
        for lane in self.active_lanes:
            self.buddy.push(lane, blob)
        self._last_diskless_step = step

    def _restore_diskless(self, failed: int) -> int:
        blob = self.buddy.recover(failed)
        self.state = jax.tree_util.tree_map(jnp.asarray, blob["state"])
        if "psgd" in blob:
            self._psgd = jax.tree_util.tree_map(jnp.asarray, blob["psgd"])
        return int(blob["step"])

    # -- muon phases ---------------------------------------------------------

    def _make_muon_grad(self):
        loss_and_grads = make_loss_and_grads(self.cfg, self.tcfg.grad_accum)
        lr_fn = self._lr_fn

        def grad_phase(state: TrainState, batch):
            loss, grads = loss_and_grads(state.params, batch)
            mom, nu = muon_moments(grads, state.opt_state, state.params)
            return (loss, grad_norm(grads), lr_fn(state.step),
                    state.opt_state.step + 1, mom, nu)

        return grad_phase

    def _make_muon_finish(self):
        def finish(state: TrainState, mom, nu, lr, ostep, qs):
            def orth(path, m):
                q = qs.get(_path_str(path))
                return _orth(m) if q is None else q

            updates = muon_deltas(state.params, mom, nu, lr,
                                  ostep.astype(jnp.float32), orth=orth)
            params = adamw_mod.apply_updates(state.params, updates)
            return TrainState(params, MuonState(ostep, mom, nu),
                              state.step + 1)

        return finish

    def _muon_step(self, step: int, batch) -> Dict:
        loss, gnorm, lr, ostep, mom, nu = self._grad_fn(self.state, batch)
        per_task: Dict[str, jax.Array] = {}
        for task in self._tasks:
            self._cur_task = task.name
            resume = None
            if (self._pending_resume is not None
                    and self._pending_resume[0] == task.name):
                resume = self._pending_resume[1]
                self._pending_resume = None
            M = task_slice(mom, task)
            try:
                per_task[task.name] = self.engine.orthonormalize(
                    M, resume_state=resume)
            except SuspendSweep as s:
                self._suspend(step, task, s.state)
        self._cur_task = None
        qs = assemble_leaves(mom, per_task, self._tasks)
        self.state = self._finish_fn(self.state, mom, nu, lr, ostep, qs)
        return {"loss": loss, "lr": lr, "gnorm": gnorm}

    # -- powersgd bridge -----------------------------------------------------

    def _init_psgd(self):
        key = jax.random.key(self.tcfg.seed + 1)
        r = self.fcfg.compression_rank
        st = {}
        for t in self._tasks:
            key, sub = jax.random.split(key)
            st[t.name] = {
                "omega": jax.random.normal(
                    sub, (t.cols, r), jnp.float32) / jnp.sqrt(r),
                "err": jnp.zeros((self.tcfg.n_lanes, t.rows, t.cols),
                                 jnp.float32),
            }
        return st

    def _make_lane_grads(self):
        loss_and_grads = make_loss_and_grads(self.cfg, self.tcfg.grad_accum)
        L = self.tcfg.n_lanes

        def fn(state: TrainState, batch):
            lanes = jax.tree_util.tree_map(
                lambda x: x.reshape((L, x.shape[0] // L) + x.shape[1:]),
                batch)
            loss_l, grads_l = jax.vmap(
                lambda b: loss_and_grads(state.params, b))(lanes)
            return jnp.mean(loss_l), grads_l

        return fn

    def _make_psgd_finish(self):
        opt, lr_fn = self.opt, self._lr_fn

        def finish(state: TrainState, grads):
            lr = lr_fn(state.step)
            updates, opt_state = opt.update(grads, state.opt_state,
                                            state.params, lr)
            params = adamw_mod.apply_updates(state.params, updates)
            return TrainState(params, opt_state, state.step + 1), lr

        return finish

    def _psgd_step(self, step: int, batch) -> Dict:
        L = self.tcfg.n_lanes
        loss, grads_l = self._lane_grad_fn(self.state, batch)
        per_task: Dict[str, jax.Array] = {}
        for task in self._tasks:
            self._cur_task = task.name
            st = self._psgd[task.name]
            leaf_l = leaf_by_path(grads_l, task.path)
            flat = leaf_l.reshape((L, -1) + leaf_l.shape[-2:])
            G_l = flat[:, task.index if task.index is not None else 0]
            Gc_l, proj = _lane_project(G_l, st["omega"], st["err"])
            Q = self.engine.orthonormalize(proj)
            G_hat, new_err, R = _lane_complete(Gc_l, Q)
            st["omega"], st["err"] = R, new_err  # power-iteration warm start
            per_task[task.name] = G_hat
        self._cur_task = None
        mean_grads = jax.tree_util.tree_map(
            lambda g: jnp.mean(g, axis=0), grads_l)
        comp = assemble_leaves(mean_grads, per_task, self._tasks)
        reduced = jax.tree_util.tree_map_with_path(
            lambda path, g: comp.get(_path_str(path), g), mean_grads)
        self.state, lr = self._psgd_finish_fn(self.state, reduced)
        return {"loss": loss, "lr": lr, "gnorm": grad_norm(reduced)}

    # -- step dispatch -------------------------------------------------------

    def _execute_step(self, step: int, batch) -> Dict:
        self._cur_step = step
        if self._mode == "muon":
            return self._muon_step(step, batch)
        if self._mode == "psgd":
            return self._psgd_step(step, batch)
        return super()._execute_step(step, batch)

    # -- suspend / resume ----------------------------------------------------

    def _sweep_path(self) -> str:
        return self.fcfg.sweep_path or os.path.join(
            self.tcfg.ckpt_dir, "sweep.npz")

    def _suspend(self, step: int, task: QRTask, sweep_state) -> None:
        os.makedirs(self.tcfg.ckpt_dir, exist_ok=True)
        save.save(self.tcfg.ckpt_dir, step, self.state.params,
                  self.state.opt_state,
                  {"data_step": step, "ftrun_task": task.name})
        path = self._sweep_path()
        save_sweep_state(path, sweep_state,
                         version=self.fcfg.sweep_wire_version)
        raise TrainingSuspended(step, task.name, path)

    @classmethod
    def resume(cls, cfg: ModelConfig, tcfg: TrainConfig, dcfg: DataConfig,
               fcfg: Optional[FTRunConfig] = None,
               qr_fault_hooks: Sequence = ()) -> "FTTrainer":
        """Rebuild a trainer from a suspended run's checkpoints: restores
        params/opt state as of entering the suspended step, queues the
        persisted in-flight sweep for ``from_state`` continuation, and sets
        the loop to replay from that step (earlier tasks and the grad phase
        re-run deterministically). Pass a ``fcfg`` without
        ``suspend_after_boundaries`` unless another suspension is wanted."""
        tr = cls(cfg, tcfg, dcfg, fcfg, qr_fault_hooks)
        params, opt_state, manifest = save.restore(
            tcfg.ckpt_dir, tr.state.params, tr.state.opt_state)
        step = int(manifest["step"])
        tr.state = TrainState(params, opt_state,
                              jnp.asarray(step, jnp.int32))
        tr._start_step = step
        task = (manifest.get("extra") or {}).get("ftrun_task")
        if task is not None:
            tr._pending_resume = (task, load_sweep_state(tr._sweep_path()))
        return tr
