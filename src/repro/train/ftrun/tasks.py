"""Task planning: which optimizer-internal factorizations go through the
FT-QR engine, and how pytree leaves map onto 2-D sweeps.

The planner walks the parameter tree once at trainer construction and
emits one :class:`QRTask` per 2-D factorization the optimizer will need
every step. Stacked leaves (layer groups ``(G, m, n)``, expert banks) are
split per leading slice — each slice is an independent sweep, and because
all slices of a leaf share one geometry they share one compiled segment
cache entry. Wide slices are transposed (the Muon convention: orthogonalize
the short side), so a whole smoke-model FFN routes as six ``(128, 64)``
sweeps with a single compile.

Leaves whose 2-D slice is smaller than ``min_qr_size`` elements stay on
the optimizer's in-jit TSQR chain — a sweep's host-loop overhead is only
worth paying on matrices large enough to matter (and where FT matters:
those are also the ones sharded across lanes in production).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.optim.caqr_muon import _is_muon, _path_str


@dataclasses.dataclass(frozen=True)
class QRTask:
    """One optimizer-internal factorization: ``name`` is ``path`` for 2-D
    leaves, ``path#i`` for slice ``i`` of a stacked leaf. ``rows/cols`` is
    the tall orientation actually swept (``transpose`` records whether the
    slice was flipped to get there)."""

    name: str
    path: str
    index: Optional[int]      # leading-slice index, None for 2-D leaves
    rows: int
    cols: int
    transpose: bool


def _leaf_tasks(path: str, leaf) -> List[QRTask]:
    m, n = int(leaf.shape[-2]), int(leaf.shape[-1])
    rows, cols = (m, n) if m >= n else (n, m)
    transpose = m < n
    if leaf.ndim == 2:
        return [QRTask(path, path, None, rows, cols, transpose)]
    lead = int(np.prod(leaf.shape[:-2]))
    return [QRTask(f"{path}#{i}", path, i, rows, cols, transpose)
            for i in range(lead)]


def plan_muon_tasks(params, min_qr_size: int = 8192) -> List[QRTask]:
    """Tasks for ``caqr_muon``: every Muon-eligible leaf (same predicate as
    the optimizer's own routing) whose per-slice size is at least
    ``min_qr_size`` elements."""
    tasks: List[QRTask] = []

    def visit(path, p):
        if not _is_muon(path, p):
            return
        if int(p.shape[-2]) * int(p.shape[-1]) < min_qr_size:
            return
        tasks.extend(_leaf_tasks(_path_str(path), p))

    jax.tree_util.tree_map_with_path(visit, params)
    return tasks


def plan_psgd_tasks(params, min_size: int = 8192) -> List[QRTask]:
    """Tasks for the PowerSGD bridge: 2-D-sliceable leaves big enough to
    compress. No transpose — PowerSGD projects ``G @ omega`` and the
    ``(m, r)`` projection is always tall (the sweep the engine runs is the
    projection's, not the leaf's — rows/cols here describe the slice)."""
    tasks: List[QRTask] = []

    def visit(path, p):
        if p.ndim < 2:
            return
        m, n = int(p.shape[-2]), int(p.shape[-1])
        if m * n < min_size or m < 2 or n < 2:
            return
        ps = _path_str(path)
        if p.ndim == 2:
            tasks.append(QRTask(ps, ps, None, m, n, False))
        else:
            lead = int(np.prod(p.shape[:-2]))
            tasks.extend(QRTask(f"{ps}#{i}", ps, i, m, n, False)
                         for i in range(lead))

    jax.tree_util.tree_map_with_path(visit, params)
    return tasks


def leaf_by_path(tree, path: str):
    """Navigate a pytree by a ``/``-joined key path (the inverse of
    ``repro.optim.caqr_muon._path_str``): dict keys, sequence indices, and
    ``.attr`` components (``GetAttrKey`` renders as ``.name``) for
    NamedTuple/dataclass nodes."""
    node = tree
    for k in path.split("/"):
        if k.startswith("."):
            node = getattr(node, k[1:])
        elif isinstance(node, (list, tuple)):
            node = node[int(k)]
        else:
            node = node[k]
    return node


def task_slice(tree, task: QRTask) -> jax.Array:
    """The 2-D matrix a task factorizes, in its ORIGINAL orientation (the
    engine handles the tall flip)."""
    leaf = leaf_by_path(tree, task.path)
    if task.index is None:
        return leaf
    flat = leaf.reshape((-1,) + leaf.shape[-2:])
    return flat[task.index]


def assemble_leaves(tree, per_task: Dict[str, jax.Array],
                    tasks: List[QRTask]) -> Dict[str, jax.Array]:
    """Reassemble per-task 2-D results into full leaf-shaped arrays keyed
    by leaf path (stacking slice results back into the leading axes)."""
    import jax.numpy as jnp

    by_path: Dict[str, List[Tuple[int, jax.Array]]] = {}
    for t in tasks:
        by_path.setdefault(t.path, []).append(
            (t.index if t.index is not None else 0, per_task[t.name]))
    out: Dict[str, jax.Array] = {}
    for path, pieces in by_path.items():
        leaf = leaf_by_path(tree, path)
        if len(pieces) == 1 and pieces[0][0] == 0 and leaf.ndim == 2:
            out[path] = pieces[0][1]
            continue
        pieces.sort(key=lambda p: p[0])
        out[path] = jnp.stack([q for _, q in pieces]).reshape(leaf.shape)
    return out
