"""Train-step builders.

``make_train_step``      — jit/GSPMD step: loss + grad + optimizer update,
                           optional microbatch gradient accumulation.
``make_pod_train_step``  — the multi-pod variant: shard_map over the 'pod'
                           axis only (everything else stays auto-partitioned
                           inside), so the cross-pod gradient reduction is
                           explicit and can run through PowerSGD-QR
                           compression (rank-r TSQR, r*(m+n) wire bytes
                           instead of m*n) — the paper's primitive on the
                           slowest links of the system.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import compat
from repro.models import api
import repro.optim.adamw as adamw_mod
from repro.optim import powersgd


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


def make_loss_and_grads(cfg: ModelConfig, grad_accum: int = 1):
    """The gradient computation of ``make_train_step`` as its own builder:
    ``(params, batch) -> (loss, grads)`` with the same optional microbatch
    scan. Shared by the monolithic jitted step and the FT runtime's split
    grad phase (``repro.train.ftrun``) so both run the identical FP
    program."""
    loss_fn = api.make_forward_loss(cfg)

    def fn(params, batch):
        def lg(p, b):
            return jax.value_and_grad(loss_fn, has_aux=True)(p, b)

        if grad_accum == 1:
            (loss, _), grads = lg(params, batch)
            return loss, grads
        # microbatch scan over the leading batch dim
        def mb(carry, b):
            (l, g) = carry
            (li, _), gi = lg(params, b)
            return (l + li, jax.tree_util.tree_map(jnp.add, g, gi)), None

        B = batch["tokens"].shape[0]
        assert B % grad_accum == 0
        mbs = jax.tree_util.tree_map(
            lambda x: x.reshape((grad_accum, B // grad_accum) + x.shape[1:]),
            batch,
        )
        zero = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (loss, grads), _ = jax.lax.scan(mb, (jnp.zeros(()), zero), mbs)
        loss = loss / grad_accum
        grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
        return loss, grads

    return fn


def grad_norm(grads) -> jax.Array:
    """Global L2 norm over a gradient pytree (f32 accumulate)."""
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads))
    )


def make_train_step(
    cfg: ModelConfig,
    optimizer,
    lr_fn: Callable,
    grad_accum: int = 1,
):
    loss_and_grads = make_loss_and_grads(cfg, grad_accum)

    def step(state: TrainState, batch):
        loss, grads = loss_and_grads(state.params, batch)
        lr = lr_fn(state.step)
        updates, opt_state = optimizer.update(grads, state.opt_state, state.params, lr)
        params = adamw_mod.apply_updates(state.params, updates)
        return TrainState(params, opt_state, state.step + 1), {
            "loss": loss, "lr": lr, "gnorm": grad_norm(grads),
        }

    return step


class PodTrainState(NamedTuple):
    params: Any
    opt_state: Any
    psgd: Any
    step: jax.Array


def make_pod_train_step(
    cfg: ModelConfig,
    optimizer,
    lr_fn: Callable,
    mesh,
    *,
    compression_rank: int = 0,
):
    """shard_map over 'pod'; per-pod grads reduced explicitly (pmean or
    PowerSGD-QR). Params replicated across pods; inner axes stay automatic."""
    from jax.sharding import PartitionSpec as P

    loss_fn = api.make_forward_loss(cfg)
    compress = compression_rank > 0

    def per_pod(state: PodTrainState, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch
        )
        if compress:
            grads, new_psgd = powersgd.compress_tree(
                grads, state.psgd, "pod", rank=compression_rank
            )
        else:
            grads = jax.tree_util.tree_map(
                lambda g: jax.lax.pmean(g, "pod"), grads
            )
            new_psgd = state.psgd
        loss = jax.lax.pmean(loss, "pod")
        lr = lr_fn(state.step)
        updates, opt_state = optimizer.update(grads, state.opt_state, state.params, lr)
        params = adamw_mod.apply_updates(state.params, updates)
        return PodTrainState(params, opt_state, new_psgd, state.step + 1), {
            "loss": loss, "lr": lr,
        }

    state_specs = PodTrainState(
        params=P(), opt_state=P(), psgd=P(), step=P()
    )
    step = compat.shard_map(
        per_pod,
        mesh,
        in_specs=(state_specs, P("pod")),
        out_specs=(state_specs, P()),
        axis_names={"pod"},
    )
    return step
