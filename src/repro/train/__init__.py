"""Training: step builders + fault-tolerant loop."""
from repro.train.loop import TrainConfig, Trainer
from repro.train.step import TrainState, make_train_step, make_pod_train_step
__all__ = ["TrainConfig", "Trainer", "TrainState", "make_train_step", "make_pod_train_step"]
