"""Householder QR with compact-WY representation.

This is the numerical substrate of the paper: every node of the TSQR tree and
every trailing-matrix update is expressed through (Y, T, R) factors with
``Q = I - Y T Y^T`` (LAPACK ``geqrt`` convention: Y unit-lower-trapezoidal,
T upper-triangular, ``tau = diag(T)``).

Everything here is pure JAX, jit-able, and uses *masked* column loops instead
of dynamic slicing so the same code path serves as the oracle for the Pallas
kernels (``repro.kernels.ref`` re-exports these) and runs unmodified inside
``shard_map``.

Dispatch seam: the public entry points (``householder_qr_masked``,
``stacked_qr``, ``apply_qt``, ``stacked_apply_qt``) route through the fused
Pallas kernels in ``repro.kernels.ops`` when the backend policy says so (TPU
by default; see ``repro.kernels.backend``) and the call is a 2-D f32 one
the kernels cover. Note the 2-D test sees *per-call* rank: under ``vmap``
(SimComm's ``map_local``) per-lane tracers are 2-D, so vmapped call sites
dispatch too and batch through ``pallas_call``'s batching rule (exercised
by the forced-kernel SimComm sweep test). Explicitly batched arrays with a
leading lane axis (e.g. the SimComm trailing ``_combine``), other dtypes,
and explicit ``num_cols`` take the pure-jnp implementations below, which
are also the oracles the kernels are validated against (``ref.py`` binds
the ``_``-prefixed pure forms directly, never the dispatchers).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


def _kernel_dispatch(*arrays) -> bool:
    """Route to repro.kernels.ops? (trace-time decision; lazy import keeps
    core importable without the kernels package and avoids the ops->ref->
    householder import cycle). The rank test is per call: vmapped per-lane
    tracers are 2-D and dispatch; only explicitly lane-stacked arrays are
    filtered out (see module docstring)."""
    if not all(a.ndim == 2 and a.dtype == jnp.float32 for a in arrays):
        return False
    from repro.kernels import backend

    return backend.dispatch_enabled()


class WY(NamedTuple):
    """Compact-WY factorization of an m x n panel: Q = I - Y T Y^T."""

    Y: jax.Array  # (m, n) unit lower trapezoidal (implicit unit diagonal NOT stored: Y[j,j] == 1 stored explicitly)
    T: jax.Array  # (n, n) upper triangular
    R: jax.Array  # (n, n) upper triangular


def _house(x: jax.Array, pivot: jax.Array, mask: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Householder reflector for the masked vector ``x``.

    Returns ``(v, tau)`` with ``v[pivot] == 1``, ``v`` zero outside ``mask``,
    such that ``(I - tau v v^T) x = beta * e_pivot`` and beta = -sign(x0)*||x||.

    ``mask`` selects the active rows (pivot row included). Rows outside the
    mask are ignored entirely, which lets callers express "QR of the rows
    below the current panel" without any dynamic slicing.
    """
    x = jnp.where(mask, x, 0.0)
    x0 = x[pivot]
    sigma = jnp.sum(x * x) - x0 * x0
    norm_x = jnp.sqrt(x0 * x0 + sigma)
    sign = jnp.where(x0 >= 0, 1.0, -1.0).astype(x.dtype)
    beta = -sign * norm_x
    denom = x0 - beta
    # Degenerate column (all zeros below+at pivot): tau = 0, v = e_pivot.
    degenerate = norm_x <= jnp.asarray(1e-30, x.dtype)
    safe_denom = jnp.where(degenerate, 1.0, denom)
    v = x / safe_denom
    v = jnp.where(mask, v, 0.0)
    v = v.at[pivot].set(1.0)
    tau = jnp.where(degenerate, 0.0, (beta - x0) / beta)
    return v.astype(x.dtype), tau.astype(x.dtype)


def householder_qr_masked(
    A: jax.Array, row_start: jax.Array, num_cols: int | None = None
) -> WY:
    """Blocked Householder QR of the active rows of ``A`` (kernel-dispatched;
    see module docstring). ``num_cols`` forces the pure path."""
    if num_cols is None and _kernel_dispatch(A):
        from repro.kernels import ops

        Y, T, R = ops.panel_qr(A, row_start)
        return WY(Y=Y, T=T, R=R)
    return _householder_qr_masked(A, row_start, num_cols)


@functools.partial(jax.jit, static_argnames=("num_cols",))
def _householder_qr_masked(
    A: jax.Array, row_start: jax.Array, num_cols: int | None = None
) -> WY:
    """Blocked Householder QR of the active rows of ``A``.

    A: (m, n). Active rows are ``row_start <= i < m``; rows above ``row_start``
    are treated as frozen (they belong to already-computed R rows in CAQR) and
    are neither read nor written. Column ``j``'s pivot sits at row
    ``row_start + j``.

    Returns WY factors of the active submatrix embedded at their global row
    positions: Y is (m, n) with zeros in frozen rows, R is (n, n) and equals
    rows ``row_start .. row_start+n`` of the transformed matrix.
    """
    m, n = A.shape
    if num_cols is None:
        num_cols = n
    rows = jnp.arange(m)
    dtype = A.dtype

    def body(j, carry):
        A_, Y_, taus_ = carry
        pivot = row_start + j
        mask = rows >= pivot
        v, tau = _house(A_[:, j], pivot, mask)
        # Apply (I - tau v v^T) to every column; finished columns (k < j) have
        # zeros at and below the pivot in the masked region only where v acts,
        # and v^T A on them is ~0, so the full-width update is exact and keeps
        # the loop free of dynamic slices.
        w = v @ A_  # (n,)
        A_ = A_ - tau * jnp.outer(v, w)
        Y_ = Y_.at[:, j].set(v)
        taus_ = taus_.at[j].set(tau)
        return A_, Y_, taus_

    # Carries derive from A (not fresh constants) so their varying-manual-axes
    # match under shard_map (see jax shard_map VMA rules).
    A_out, Y, taus = jax.lax.fori_loop(
        0,
        num_cols,
        body,
        (A, A * jnp.zeros((), dtype), A[0] * jnp.zeros((), dtype)),
    )
    R_rows = jax.lax.dynamic_slice_in_dim(A_out, row_start, n, axis=0)
    R = jnp.triu(R_rows[:n, :n])
    T = build_t(Y, taus)
    return WY(Y=Y, T=T, R=R)


def panel_qr_apply(W: jax.Array, row_start: jax.Array, b: int):
    """Fused leaf step: panel QR of ``W[:, :b]`` + Q^T applied to the whole
    window + C' row extraction. Returns ``(wy, C, C_prime)``.

    This is the sweep's per-lane leaf work as ONE kernel launch
    (kernel-dispatched through the ``fused_sweep`` policy slot); the pure
    path is the unfused composition of the primitives above.
    """
    if _kernel_dispatch(W):
        from repro.kernels import ops

        Y, T, R, C, Cp = ops.panel_qr_apply(W, row_start, b)
        return WY(Y=Y, T=T, R=R), C, Cp
    wy = _householder_qr_masked(W[:, :b], row_start)
    C = _apply_qt(wy.Y, wy.T, W)
    Cp = jax.lax.dynamic_slice_in_dim(C, row_start, b, axis=0)
    return wy, C, Cp


def householder_qr(A: jax.Array) -> WY:
    """QR of the full matrix (row_start = 0)."""
    return householder_qr_masked(A, jnp.asarray(0, jnp.int32))


def _householder_qr(A: jax.Array) -> WY:
    """Pure-jnp QR (no kernel dispatch) — the oracle form."""
    return _householder_qr_masked(A, jnp.asarray(0, jnp.int32))


@jax.jit
def build_t(Y: jax.Array, taus: jax.Array) -> jax.Array:
    """Forward T recurrence: T[:j,j] = -tau_j T[:j,:j] (Y[:,:j]^T y_j).

    Masked formulation over the Gram matrix G = Y^T Y so the loop body is
    static-shaped.
    """
    n = Y.shape[1]
    G = Y.T @ Y  # (n, n)
    idx = jnp.arange(n)

    def body(j, T):
        g = jnp.where(idx < j, G[:, j], 0.0)  # (n,)
        col = -taus[j] * (T @ g)
        col = jnp.where(idx < j, col, 0.0)
        col = col.at[j].set(taus[j])
        return T.at[:, j].set(col)

    T0 = G * jnp.zeros((), Y.dtype)  # derives from Y: VMA-consistent carry
    return jax.lax.fori_loop(0, n, body, T0)


def apply_qt(Y: jax.Array, T: jax.Array, C: jax.Array) -> jax.Array:
    """Q^T C = C - Y (T^T (Y^T C))  for Q = I - Y T Y^T (kernel-dispatched)."""
    if _kernel_dispatch(Y, T, C):
        from repro.kernels import ops

        return ops.wy_apply(Y, T, C)
    return _apply_qt(Y, T, C)


@jax.jit
def _apply_qt(Y: jax.Array, T: jax.Array, C: jax.Array) -> jax.Array:
    W = T.T @ (Y.T @ C)
    return C - Y @ W


@jax.jit
def apply_q(Y: jax.Array, T: jax.Array, C: jax.Array) -> jax.Array:
    """Q C = C - Y (T (Y^T C))."""
    W = T @ (Y.T @ C)
    return C - Y @ W


@jax.jit
def q_dense(Y: jax.Array, T: jax.Array) -> jax.Array:
    """Materialize Q = I - Y T Y^T (testing / small sizes only)."""
    m = Y.shape[0]
    return jnp.eye(m, dtype=Y.dtype) - Y @ (T @ Y.T)


class StackedQR(NamedTuple):
    """Structured QR of two stacked b x b upper triangles [R_top; R_bot].

    The Householder vectors have the form Y = [I_b; Y2] with Y2 upper
    triangular (LAPACK ``tpqrt`` structure), so only Y2 and T are stored.
    Q = I - [I; Y2] T [I; Y2]^T and R is the new upper triangle.
    """

    Y2: jax.Array  # (b, b) upper triangular
    T: jax.Array  # (b, b) upper triangular
    R: jax.Array  # (b, b) upper triangular


def stacked_qr(R_top: jax.Array, R_bot: jax.Array) -> StackedQR:
    """QR of [R_top; R_bot] exploiting the triangular structure.

    This is the TSQR tree-combine operation. Both inputs are b x b upper
    triangular. Kernel-dispatched (LAPACK ``tpqrt`` analogue kernel); the
    pure path runs the generic masked Householder loop on the stacked
    2b x b matrix — it preserves the structure (Y's top block is exactly I,
    bottom block upper triangular) — and slices the structured parts out.
    """
    if _kernel_dispatch(R_top, R_bot):
        from repro.kernels import ops

        Y2, T, R = ops.stacked_qr(R_top, R_bot)
        return StackedQR(Y2=Y2, T=T, R=R)
    return _stacked_qr(R_top, R_bot)


@jax.jit
def _stacked_qr(R_top: jax.Array, R_bot: jax.Array) -> StackedQR:
    b = R_top.shape[0]
    S = jnp.concatenate([jnp.triu(R_top), jnp.triu(R_bot)], axis=0)  # (2b, b)
    wy = _householder_qr_masked(S, jnp.asarray(0, jnp.int32))
    Y2 = jnp.triu(wy.Y[b:, :])
    return StackedQR(Y2=Y2, T=wy.T, R=wy.R)


def stacked_apply_qt(
    sq: StackedQR, C_top: jax.Array, C_bot: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Apply the stacked Q^T to [C_top; C_bot] using the paper's W form.

    W = T^T (C_top + Y2^T C_bot)
    C_top_hat = C_top - W          (paper: \\hat C'_0 = C'_0 - Y_0 W, Y_0 = I)
    C_bot_hat = C_bot - Y2 W       (paper: \\hat C'_1 = C'_1 - Y_1 W)

    Returns (C_top_hat, C_bot_hat, W); W is part of the recovery bundle.
    Kernel-dispatched to the fused trailing-combine kernel.
    """
    if _kernel_dispatch(sq.Y2, sq.T, C_top, C_bot):
        from repro.kernels import ops

        return ops.stacked_apply(sq.Y2, sq.T, C_top, C_bot)
    return _stacked_apply_qt(sq, C_top, C_bot)


@jax.jit
def _stacked_apply_qt(
    sq: StackedQR, C_top: jax.Array, C_bot: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    W = sq.T.T @ (C_top + sq.Y2.T @ C_bot)
    return C_top - W, C_bot - sq.Y2 @ W, W


@jax.jit
def stacked_apply_q(
    sq: StackedQR, C_top: jax.Array, C_bot: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Apply the stacked Q (not transposed) to [C_top; C_bot]."""
    W = sq.T @ (C_top + sq.Y2.T @ C_bot)
    return C_top - W, C_bot - sq.Y2 @ W
