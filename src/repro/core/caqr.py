"""FT-CAQR: fault-tolerant QR of general (2-D) matrices (paper §III-C).

1-D block-row layout, exactly the paper's setting: lane ``i`` owns rows
``[i*m_loc, (i+1)*m_loc)`` of an ``(P*m_loc, n)`` matrix. The factorization
sweeps ``n/b`` panels left to right; each panel is factorized by FT-TSQR
(§III-B) and the trailing matrix updated by Algorithm 2 (§III-C).

Sweep bookkeeping the paper elides (it presents single-panel trees): the tree
of panel ``k`` is oriented so its root — the lane where the new R rows
deposit — is the owner of global rows ``[k*b, (k+1)*b)``. Lanes whose rows
are fully consumed contribute zero leaves and pass-through combines (encoded
as zeroed (Y2, T) factors), so the trailing update inherits the masking with
no extra logic. Requires ``m_loc % b == 0`` and ``n % b == 0``.

Because row permutations do not change the R factor, the final R here equals
(up to row signs) the R of any standard QR — validated against
``jnp.linalg.qr`` and via the Gram identity ``R^T R == A^T A``.

The stored per-panel factors form the implicit Q: ``caqr_apply_qt`` replays
them against any conforming matrix (used by tests to check ``Q^T A == [R;0]``
and by least-squares solves).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.comm import AxisComm, SimComm
from repro.core.householder import householder_qr_masked
from repro.core.tsqr import DistTSQRFactors, ft_tsqr_combine
from repro.core.trailing import RecoveryBundle, trailing_update_ft


class PanelFactors(NamedTuple):
    """Implicit-Q factors of one panel, per lane (leading panel axis after
    the sweep; SimComm adds a lane axis on each leaf)."""

    leaf_Y: jax.Array   # (m_loc, b) masked WY vectors (zero on frozen rows)
    leaf_T: jax.Array   # (b, b)
    level_Y2: jax.Array  # (L, b, b) — zeroed == pass-through
    level_T: jax.Array   # (L, b, b)
    row_start: jax.Array  # () per-lane offset of this lane's C' block
    active: jax.Array     # () per-lane participation flag
    target: jax.Array     # () tree root lane (replicated)


class CAQRResult(NamedTuple):
    R: jax.Array                      # (n, n) upper triangular, replicated
    factors: PanelFactors             # stacked over panels (leading axis)
    bundles: Optional[RecoveryBundle]  # stacked over panels, if requested


def panel_geometry(comm, k: int, b: int, m_loc: int):
    """Sweep bookkeeping of panel ``k`` (static): returns
    ``(col0, t_lane, row_start, active)``.

    ``col0``  — first column of the panel (the live-window start);
    ``t_lane``— owner of global rows [col0, col0+b): the tree root where the
                new R rows deposit;
    ``row_start`` / ``active`` — per-lane offset of the C' block and the
                participation flag (lanes whose rows are fully consumed by
                earlier panels are inactive).
    """
    idx = comm.axis_index()
    col0 = k * b
    t_lane = col0 // m_loc
    row_start_raw = col0 - idx * m_loc
    active = row_start_raw < m_loc
    row_start = jnp.clip(row_start_raw, 0, m_loc - b)
    return col0, t_lane, row_start, active


def lane_geometry(k: int, b: int, m_loc: int, lane: int):
    """``panel_geometry`` for one concrete lane, as Python scalars — the
    REBUILD replay (``repro.ft.driver``) recomputes a respawned lane's
    bookkeeping with this (it is static data, not lost state)."""
    col0 = k * b
    row_start_raw = col0 - lane * m_loc
    active = row_start_raw < m_loc
    row_start = min(max(row_start_raw, 0), m_loc - b)
    return col0, col0 // m_loc, row_start, active


def assemble_R(comm, R_rows: jax.Array, n: int) -> jax.Array:
    """Stack per-panel replicated R row-blocks (n_panels, [P,] b, n) into the
    upper-triangular R (shared by the sweep and the FT driver)."""
    P = comm.axis_size()
    if isinstance(comm, SimComm):
        R = R_rows.swapaxes(0, 1).reshape(P, n, n)
        return jnp.triu(R)
    return jnp.triu(R_rows.reshape(n, n))


def advance_columns(comm, A_cur: jax.Array, window_next: jax.Array, col0: int):
    """Reattach the updated live window to the (untouched) dead columns."""
    return comm.map_local(
        lambda A, W: jnp.concatenate([A[:, :col0], W], axis=1)
    )(A_cur, window_next)


def extract_r_rows(comm, C_final: jax.Array, t_lane: int, col0: int):
    """The new R rows (global rows [col0, col0+b)) live at lane ``t_lane``'s
    final C' block; replicate them (one b x n all-reduce — the FT broadcast)
    and left-zero-pad back to full-width column indices."""
    idx = comm.axis_index()
    R_rows = comm.psum(
        comm.where(idx == t_lane, C_final, jnp.zeros_like(C_final))
    )
    return comm.map_local(lambda r: jnp.pad(r, ((0, 0), (col0, 0))))(R_rows)


def pad_bundle(bundle: RecoveryBundle, col0: int) -> RecoveryBundle:
    """Left-zero-pad a window-width recovery bundle to full width so the
    per-panel bundles stack (dead columns need no recovery)."""
    return RecoveryBundle(
        W=_pad_cols(bundle.W, col0),
        C_self=_pad_cols(bundle.C_self, col0),
        C_buddy=_pad_cols(bundle.C_buddy, col0),
        Y2=bundle.Y2, T=bundle.T, self_was_top=bundle.self_was_top,
    )


def make_panel_factors(
    comm, leaf_Y, leaf_T, level_Y2, level_T, row_start, active, t_lane
) -> PanelFactors:
    idx = comm.axis_index()
    return PanelFactors(
        leaf_Y=leaf_Y,
        leaf_T=leaf_T,
        level_Y2=level_Y2,
        level_T=level_T,
        row_start=row_start,
        active=active,
        target=jnp.broadcast_to(t_lane, jnp.shape(idx)),
    )


def _panel_step_windowed(comm, b: int, collect_bundles: bool, k: int, n: int):
    """One panel of the *windowed* right-looking sweep (static ``k``).

    The trailing update (leaf WY apply, per-level combines, writeback) is
    restricted to the live window ``A[:, k*b:]`` — the panel's own columns
    ride along because their C' rows ARE the R_kk deposit and the recovery
    bundle must cover them; the ``k*b`` already-factored columns to the left
    are dead (their R rows were extracted at their own panel step; what is
    left below the frontier is annihilated garbage) and are not touched.
    Per-column arithmetic is unchanged, so R and the live-window slice of
    every recovery bundle are bit-identical to the full-width sweep; R rows
    and bundles are zero-padded back to width ``n`` so the per-panel outputs
    stack (dead columns need no recovery — their bundle slots are zero).

    Fully-consumed lanes additionally skip their (identity) leaf apply via
    ``skip_consumed`` — the frozen-row skip.
    """
    def body(A_cur):
        m_loc, _n = comm.local_shape(A_cur)
        assert _n == n
        col0, t_lane, row_start, active = panel_geometry(comm, k, b, m_loc)

        window = comm.map_local(lambda A: A[:, col0:])(A_cur)
        panel = comm.map_local(lambda W: W[:, :b])(window)

        wy = comm.map_local(householder_qr_masked)(panel, row_start)
        leaf_Y = comm.where(active, wy.Y, jnp.zeros_like(wy.Y))
        leaf_T = comm.where(active, wy.T, jnp.zeros_like(wy.T))
        R_leaf = comm.where(active, wy.R, jnp.zeros_like(wy.R))

        level_Y2, level_T, _Rtree = ft_tsqr_combine(
            comm, R_leaf, t_lane, active_threshold=t_lane
        )
        factors = DistTSQRFactors(leaf_Y, leaf_T, level_Y2, level_T, R_leaf)

        win_next, bundle, C_final = trailing_update_ft(
            window, factors, comm, target=t_lane, row_start=row_start,
            active=active, dead_threshold=t_lane, skip_consumed=True,
        )
        A_next = advance_columns(comm, A_cur, win_next, col0)
        R_rows = extract_r_rows(comm, C_final, t_lane, col0)
        if collect_bundles:
            bundle = pad_bundle(bundle, col0)

        panel_factors = make_panel_factors(
            comm, leaf_Y, leaf_T, level_Y2, level_T, row_start, active, t_lane
        )
        out = (panel_factors, R_rows, bundle if collect_bundles else None)
        return A_next, out

    return body


def _pad_cols(x: jax.Array, left: int) -> jax.Array:
    """Left-pad the trailing (column) axis with zeros — realigns a windowed
    array with full-width column indices."""
    if left == 0:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(left, 0)]
    return jnp.pad(x, pad)


def _panel_step(comm, b: int, collect_bundles: bool):
    """Returns the scan body for one panel of the sweep."""
    P = comm.axis_size()
    idx = comm.axis_index()

    def body(A_cur, k):
        m_loc, n = comm.local_shape(A_cur)
        col0 = k * b
        t_lane = (k * b) // m_loc  # owner of this panel's diagonal rows
        row_start_raw = k * b - idx * m_loc
        active = row_start_raw < m_loc
        row_start = jnp.clip(row_start_raw, 0, m_loc - b)

        panel = comm.map_local(
            lambda A, c: jax.lax.dynamic_slice_in_dim(A, c, b, axis=1)
        )(A_cur, jnp.broadcast_to(col0, jnp.shape(idx)))

        wy = comm.map_local(householder_qr_masked)(panel, row_start)
        leaf_Y = comm.where(active, wy.Y, jnp.zeros_like(wy.Y))
        leaf_T = comm.where(active, wy.T, jnp.zeros_like(wy.T))
        R_leaf = comm.where(active, wy.R, jnp.zeros_like(wy.R))

        level_Y2, level_T, _Rtree = ft_tsqr_combine(
            comm, R_leaf, t_lane, active_threshold=t_lane
        )
        factors = DistTSQRFactors(leaf_Y, leaf_T, level_Y2, level_T, R_leaf)

        A_next, bundle, C_final = trailing_update_ft(
            A_cur, factors, comm, target=t_lane, row_start=row_start,
            active=active, dead_threshold=t_lane,
        )
        # The new R rows (global rows [k*b, (k+1)*b)) live at lane t_lane's
        # C' block; replicate them (one b x n all-reduce — the FT broadcast).
        R_rows = comm.psum(
            comm.where(idx == t_lane, C_final, jnp.zeros_like(C_final))
        )

        panel_factors = PanelFactors(
            leaf_Y=leaf_Y,
            leaf_T=leaf_T,
            level_Y2=level_Y2,
            level_T=level_T,
            row_start=row_start,
            active=active,
            target=jnp.broadcast_to(t_lane, jnp.shape(idx)),
        )
        out = (panel_factors, R_rows, bundle if collect_bundles else None)
        return A_next, out

    return body


def caqr_factorize(
    A_local: jax.Array,
    comm,
    panel_width: int,
    collect_bundles: bool = False,
    use_scan: bool = True,
    windowed: Optional[bool] = None,
) -> CAQRResult:
    """FT-CAQR sweep. Returns replicated R plus implicit-Q panel factors.

    A_local: (m_loc, n) per lane (SimComm: (P, m_loc, n)).
    panel_width: b; requires m_loc % b == 0, n % b == 0, n <= P*m_loc.
    use_scan: True = lax.scan over panels (uniform per-iteration shapes,
        compile-time friendly; the trailing update spans all n columns every
        panel). False = statically unrolled sweep — the performance variant.
    windowed: restrict panel k's trailing update to the live window
        ``A[:, k*b:]`` with *static* column slices, halving the sweep's
        trailing flops (see ``_panel_step_windowed``; outputs bit-identical
        to the full-width sweep). Requires the unrolled path; defaults to
        ``not use_scan``.
    """
    b = panel_width
    m_loc, n = comm.local_shape(A_local)
    P = comm.axis_size()
    assert m_loc % b == 0 and n % b == 0, (m_loc, n, b)
    assert n <= P * m_loc, "matrix must have at least as many rows as columns"
    if windowed is None:
        windowed = not use_scan
    assert not (windowed and use_scan), \
        "the windowed sweep needs static column slices (use_scan=False)"
    n_panels = n // b

    ks = jnp.arange(n_panels)
    if use_scan:
        body = _panel_step(comm, b, collect_bundles)
        _, (factors, R_rows, bundles) = jax.lax.scan(body, A_local, ks)
    else:
        outs = []
        A_cur = A_local
        body = None if windowed else _panel_step(comm, b, collect_bundles)
        for k in range(n_panels):
            if windowed:
                A_cur, out = _panel_step_windowed(
                    comm, b, collect_bundles, k, n
                )(A_cur)
            else:
                A_cur, out = body(A_cur, jnp.asarray(k))
            outs.append(out)
        factors = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *[o[0] for o in outs])
        R_rows = jnp.stack([o[1] for o in outs])
        bundles = (
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *[o[2] for o in outs])
            if collect_bundles
            else None
        )

    # R_rows: (n_panels, b, n) replicated (SimComm: (n_panels, P, b, n)).
    R = assemble_R(comm, R_rows, n)
    return CAQRResult(R=R, factors=factors, bundles=bundles)


def caqr_apply_qt(
    B_local: jax.Array,
    factors: PanelFactors,
    comm,
    use_scan: bool = True,
) -> jax.Array:
    """Apply the implicit Q^T of a CAQR factorization to B (same row layout).

    Replays every panel's leaf WY + tree combine against B. For B = A this
    reproduces [R; 0] (up to the sweep's row bookkeeping) — the strongest
    internal consistency check of the stored factors.
    """
    n_panels = jax.tree_util.tree_leaves(factors)[0].shape[0]

    def body(B_cur, pf: PanelFactors):
        dist = DistTSQRFactors(
            pf.leaf_Y, pf.leaf_T, pf.level_Y2, pf.level_T, pf.leaf_T
        )
        tgt = pf.target[0] if isinstance(comm, SimComm) else pf.target
        B_next, _, _ = trailing_update_ft(
            B_cur, dist, comm, target=tgt, row_start=pf.row_start,
            active=pf.active, dead_threshold=tgt,
        )
        return B_next, None

    if use_scan:
        B_out, _ = jax.lax.scan(body, B_local, factors)
    else:
        B_out = B_local
        for k in range(n_panels):
            pf = jax.tree_util.tree_map(lambda x: x[k], factors)
            B_out, _ = body(B_out, pf)
    return B_out


# SPMD wrapper ---------------------------------------------------------------


def caqr_factorize_spmd(A_local, axis_name: str, panel_width: int, **kw):
    return caqr_factorize(A_local, AxisComm(axis_name), panel_width, **kw)
