"""FT-CAQR: fault-tolerant QR of general (2-D) matrices (paper §III-C).

1-D block-row layout, exactly the paper's setting: lane ``i`` owns rows
``[i*m_loc, (i+1)*m_loc)`` of an ``(P*m_loc, n)`` matrix. The factorization
sweeps panels left to right; each panel is factorized by FT-TSQR (§III-B)
and the trailing matrix updated by Algorithm 2 (§III-C).

Sweep bookkeeping the paper elides (it presents single-panel trees): the tree
of panel ``k`` is oriented so its root — the lane where the new R rows
deposit — is the owner of global rows ``[k*b, (k+1)*b)``. Lanes whose rows
are fully consumed contribute zero leaves and pass-through combines (encoded
as zeroed (Y2, T) factors), so the trailing update inherits the masking with
no extra logic.

General shapes (the paper's title): arbitrary ``m x n`` float matrices are
accepted. ``sweep_geometry`` computes the *static* padded geometry — per-lane
rows rounded up to a multiple of ``b`` (so every panel's diagonal block lives
whole inside one lane) and a ragged last panel rounded up to width ``b`` —
and the sweep runs on the zero-padded working array. This is the
``kernels/ops.py`` alignment contract applied at the core layer: zero
rows/columns are exact for every op in this family (they yield degenerate
reflectors with ``tau = 0`` and contribute nothing to any inner product), so
``R`` of the padded sweep is the ``R`` of the original matrix. Wide matrices
(``n > m``) factorize only the left ``min(m, n)`` columns into panels; the
remaining columns ride along in every trailing update and finish as the
``R2`` block of ``A = Q [R1 R2]``. Aligned shapes skip the padding entirely
and run the exact seed code path (bit-identical — regression-gated by
``tests/test_general_shapes.py``).

Because row permutations do not change the R factor, the final R here equals
(up to row signs) the R of any standard QR — validated against
``jnp.linalg.qr`` and via the Gram identity ``R^T R == A^T A``.

The stored per-panel factors form the implicit Q: ``caqr_apply_qt`` replays
them against any conforming matrix (used by tests to check ``Q^T A == [R;0]``
and by least-squares solves).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.comm import AxisComm, SimComm
from repro.core.householder import householder_qr_masked
from repro.core.tsqr import DistTSQRFactors, ft_tsqr_combine
from repro.core.trailing import RecoveryBundle, trailing_update_ft


class PanelFactors(NamedTuple):
    """Implicit-Q factors of one panel, per lane (leading panel axis after
    the sweep; SimComm adds a lane axis on each leaf)."""

    leaf_Y: jax.Array   # (m_loc, b) masked WY vectors (zero on frozen rows)
    leaf_T: jax.Array   # (b, b)
    level_Y2: jax.Array  # (L, b, b) — zeroed == pass-through
    level_T: jax.Array   # (L, b, b)
    row_start: jax.Array  # () per-lane offset of this lane's C' block
    active: jax.Array     # () per-lane participation flag
    target: jax.Array     # () tree root lane (replicated)


class CAQRResult(NamedTuple):
    R: jax.Array                      # (min(m, n), n) upper trapezoidal,
                                      # replicated ([R1 R2] when m < n)
    factors: PanelFactors             # stacked over panels (leading axis)
    bundles: Optional[RecoveryBundle]  # stacked over panels, if requested


class SweepGeometry(NamedTuple):
    """Static geometry of a general-shape sweep (all Python ints).

    The sweep itself always runs at the *padded* shape ``(P*m_loc_pad,
    n_work)``: ``m_loc_pad`` is ``m_loc`` rounded up to a multiple of ``b``
    (>= b), so every panel's b diagonal rows live whole inside one lane and
    ``row_start`` clipping never engages; ``n_work`` rounds a ragged last
    panel up to width ``b``. Padding is with zeros — exact for every op in
    this family (see module docstring). ``n_panels`` covers only the left
    ``min(m, n)`` columns; for wide matrices the remaining columns are
    trailing-only riders (the ``R2`` block). ``k`` = ``min(m, n)`` is the
    row count of the returned R (rows beyond ``k`` in the padded assembly
    are rank-overshoot roundoff and are sliced away).
    """

    P: int
    b: int
    m_loc: int       # caller's per-lane rows
    n: int           # caller's columns
    m_loc_pad: int   # per-lane rows the sweep runs at (multiple of b, >= b)
    n_work: int      # column width the sweep runs at (>= n_panels * b)
    n_panels: int
    k: int           # min(P*m_loc, n): rows of the returned R

    @property
    def aligned(self) -> bool:
        """True iff no padding is needed (the seed-exact fast path)."""
        return self.m_loc_pad == self.m_loc and self.n_work == self.n

    @property
    def levels(self) -> int:
        """Tree levels of the P-lane butterfly (= log2 P). The online
        state machine's cursor arithmetic, boundary attribution, and
        REBUILD replay (``repro.ft.online``, ``repro.ft.driver``) all run
        off this."""
        assert self.P & (self.P - 1) == 0, self.P
        return self.P.bit_length() - 1


def _ceil_to(x: int, q: int) -> int:
    return -(-x // q) * q


def sweep_geometry(P: int, m_loc: int, n: int, b: int) -> SweepGeometry:
    """Padded sweep geometry for a general ``(P*m_loc) x n`` factorization."""
    assert m_loc >= 1 and n >= 1 and b >= 1, (m_loc, n, b)
    m_loc_pad = _ceil_to(m_loc, b)
    k = min(P * m_loc, n)
    n_panels = -(-k // b)
    n_work = max(n, n_panels * b)
    # P*m_loc_pad is a multiple of b and >= k, so the panel region fits.
    assert n_panels * b <= P * m_loc_pad
    return SweepGeometry(P=P, b=b, m_loc=m_loc, n=n, m_loc_pad=m_loc_pad,
                         n_work=n_work, n_panels=n_panels, k=k)


def pad_to_geometry(comm, A_local: jax.Array, geom: SweepGeometry) -> jax.Array:
    """Zero-pad each lane's block to the sweep's working shape (a no-op — the
    same array object — when the geometry is aligned)."""
    dr = geom.m_loc_pad - geom.m_loc
    dc = geom.n_work - geom.n
    if dr == 0 and dc == 0:
        return A_local
    return comm.map_local(lambda A: jnp.pad(A, ((0, dr), (0, dc))))(A_local)


def block_row_layout(A: jax.Array, P: int, m_loc: Optional[int] = None,
                     n: Optional[int] = None) -> jax.Array:
    """Distribute a whole ``(m, q)`` matrix into the 1-D block-row SimComm
    layout ``(P, m_loc, n)``: rows are zero-padded to ``P * m_loc`` and
    split contiguously, columns zero-padded to ``n``. Zero padding is exact
    for the sweep (DESIGN.md §7), so this is also the *bucket* embedding of
    the serving layer: pad every ragged request to one of a few compiled
    ``(m_loc, n)`` bucket shapes and batch them through the same program
    (``caqr_factorize_batched`` / ``repro.serve.qr_service``).

    ``m_loc`` defaults to ``ceil(m / P)`` (the tightest layout), ``n`` to
    the matrix's own width."""
    m, q = A.shape
    if m_loc is None:
        m_loc = -(-m // P)
    if n is None:
        n = q
    assert m <= P * m_loc and q <= n, (
        f"matrix ({m}, {q}) exceeds the ({P}x{m_loc}, {n}) bucket")
    A = jnp.pad(A, ((0, P * m_loc - m), (0, n - q)))
    return A.reshape(P, m_loc, n)


def panel_geometry(comm, k: int, b: int, m_loc: int):
    """Sweep bookkeeping of panel ``k`` (static): returns
    ``(col0, t_lane, row_start, active)``.

    ``col0``  — first column of the panel (the live-window start);
    ``t_lane``— owner of global rows [col0, col0+b): the tree root where the
                new R rows deposit;
    ``row_start`` / ``active`` — per-lane offset of the C' block and the
                participation flag (lanes whose rows are fully consumed by
                earlier panels are inactive).
    """
    idx = comm.axis_index()
    col0 = k * b
    t_lane = col0 // m_loc
    row_start_raw = col0 - idx * m_loc
    active = row_start_raw < m_loc
    row_start = jnp.clip(row_start_raw, 0, m_loc - b)
    return col0, t_lane, row_start, active


def lane_geometry(k: int, b: int, m_loc: int, lane: int):
    """``panel_geometry`` for one concrete lane, as Python scalars — the
    REBUILD replay (``repro.ft.driver``) recomputes a respawned lane's
    bookkeeping with this (it is static data, not lost state)."""
    col0 = k * b
    row_start_raw = col0 - lane * m_loc
    active = row_start_raw < m_loc
    row_start = min(max(row_start_raw, 0), m_loc - b)
    return col0, col0 // m_loc, row_start, active


def assemble_R(comm, R_rows: jax.Array, geom: SweepGeometry) -> jax.Array:
    """Stack per-panel replicated R row-blocks (n_panels, [P,] b, n_work)
    into the (k, n) upper-trapezoidal R (shared by the sweep and the FT
    driver). Rows beyond ``geom.k`` (rank overshoot of a padded or wide
    sweep) and zero-padded columns are sliced away; on aligned geometry both
    slices are no-ops and the assembly is bit-identical to the seed's."""
    P = comm.axis_size()
    rows = geom.n_panels * geom.b
    if isinstance(comm, SimComm):
        R = R_rows.swapaxes(0, 1).reshape(P, rows, geom.n_work)
        return jnp.triu(R)[:, :geom.k, :geom.n]
    R = jnp.triu(R_rows.reshape(rows, geom.n_work))
    return R[:geom.k, :geom.n]


def advance_columns(comm, A_cur: jax.Array, window_next: jax.Array, col0: int):
    """Reattach the updated live window to the (untouched) dead columns."""
    return comm.map_local(
        lambda A, W: jnp.concatenate([A[:, :col0], W], axis=1)
    )(A_cur, window_next)


def extract_r_rows(comm, C_final: jax.Array, t_lane: int, col0: int):
    """The new R rows (global rows [col0, col0+b)) live at lane ``t_lane``'s
    final C' block; replicate them (one b x n all-reduce — the FT broadcast)
    and left-zero-pad back to full-width column indices."""
    idx = comm.axis_index()
    R_rows = comm.psum(
        comm.where(idx == t_lane, C_final, jnp.zeros_like(C_final))
    )
    return comm.map_local(lambda r: jnp.pad(r, ((0, 0), (col0, 0))))(R_rows)


def pad_bundle(bundle: RecoveryBundle, col0: int) -> RecoveryBundle:
    """Left-zero-pad a window-width recovery bundle to full width so the
    per-panel bundles stack (dead columns need no recovery)."""
    return RecoveryBundle(
        W=_pad_cols(bundle.W, col0),
        C_self=_pad_cols(bundle.C_self, col0),
        C_buddy=_pad_cols(bundle.C_buddy, col0),
        Y2=bundle.Y2, T=bundle.T, self_was_top=bundle.self_was_top,
    )


def make_panel_factors(
    comm, leaf_Y, leaf_T, level_Y2, level_T, row_start, active, t_lane
) -> PanelFactors:
    idx = comm.axis_index()
    return PanelFactors(
        leaf_Y=leaf_Y,
        leaf_T=leaf_T,
        level_Y2=level_Y2,
        level_T=level_T,
        row_start=row_start,
        active=active,
        target=jnp.broadcast_to(t_lane, jnp.shape(idx)),
    )


def _panel_step_windowed(comm, b: int, collect_bundles: bool, k: int, n: int):
    """One panel of the *windowed* right-looking sweep (static ``k``).

    The trailing update (leaf WY apply, per-level combines, writeback) is
    restricted to the live window ``A[:, k*b:]`` — the panel's own columns
    ride along because their C' rows ARE the R_kk deposit and the recovery
    bundle must cover them; the ``k*b`` already-factored columns to the left
    are dead (their R rows were extracted at their own panel step; what is
    left below the frontier is annihilated garbage) and are not touched.
    Per-column arithmetic is unchanged, so R and the live-window slice of
    every recovery bundle are bit-identical to the full-width sweep; R rows
    and bundles are zero-padded back to width ``n`` so the per-panel outputs
    stack (dead columns need no recovery — their bundle slots are zero).

    Fully-consumed lanes additionally skip their (identity) leaf apply via
    ``skip_consumed`` — the frozen-row skip.
    """
    def body(A_cur):
        m_loc, _n = comm.local_shape(A_cur)
        assert _n == n
        col0, t_lane, row_start, active = panel_geometry(comm, k, b, m_loc)

        window = comm.map_local(lambda A: A[:, col0:])(A_cur)
        panel = comm.map_local(lambda W: W[:, :b])(window)

        wy = comm.map_local(householder_qr_masked)(panel, row_start)
        leaf_Y = comm.where(active, wy.Y, jnp.zeros_like(wy.Y))
        leaf_T = comm.where(active, wy.T, jnp.zeros_like(wy.T))
        R_leaf = comm.where(active, wy.R, jnp.zeros_like(wy.R))

        level_Y2, level_T, _Rtree = ft_tsqr_combine(
            comm, R_leaf, t_lane, active_threshold=t_lane
        )
        factors = DistTSQRFactors(leaf_Y, leaf_T, level_Y2, level_T, R_leaf)

        win_next, bundle, C_final = trailing_update_ft(
            window, factors, comm, target=t_lane, row_start=row_start,
            active=active, dead_threshold=t_lane, skip_consumed=True,
        )
        A_next = advance_columns(comm, A_cur, win_next, col0)
        R_rows = extract_r_rows(comm, C_final, t_lane, col0)
        if collect_bundles:
            bundle = pad_bundle(bundle, col0)

        panel_factors = make_panel_factors(
            comm, leaf_Y, leaf_T, level_Y2, level_T, row_start, active, t_lane
        )
        out = (panel_factors, R_rows, bundle if collect_bundles else None)
        return A_next, out

    return body


def _pad_cols(x: jax.Array, left: int) -> jax.Array:
    """Left-pad the trailing (column) axis with zeros — realigns a windowed
    array with full-width column indices."""
    if left == 0:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(left, 0)]
    return jnp.pad(x, pad)


def _panel_step(comm, b: int, collect_bundles: bool):
    """Returns the scan body for one panel of the sweep."""
    P = comm.axis_size()
    idx = comm.axis_index()

    def body(A_cur, k):
        m_loc, n = comm.local_shape(A_cur)
        col0 = k * b
        t_lane = (k * b) // m_loc  # owner of this panel's diagonal rows
        row_start_raw = k * b - idx * m_loc
        active = row_start_raw < m_loc
        row_start = jnp.clip(row_start_raw, 0, m_loc - b)

        panel = comm.map_local(
            lambda A, c: jax.lax.dynamic_slice_in_dim(A, c, b, axis=1)
        )(A_cur, jnp.broadcast_to(col0, jnp.shape(idx)))

        wy = comm.map_local(householder_qr_masked)(panel, row_start)
        leaf_Y = comm.where(active, wy.Y, jnp.zeros_like(wy.Y))
        leaf_T = comm.where(active, wy.T, jnp.zeros_like(wy.T))
        R_leaf = comm.where(active, wy.R, jnp.zeros_like(wy.R))

        level_Y2, level_T, _Rtree = ft_tsqr_combine(
            comm, R_leaf, t_lane, active_threshold=t_lane
        )
        factors = DistTSQRFactors(leaf_Y, leaf_T, level_Y2, level_T, R_leaf)

        A_next, bundle, C_final = trailing_update_ft(
            A_cur, factors, comm, target=t_lane, row_start=row_start,
            active=active, dead_threshold=t_lane,
        )
        # The new R rows (global rows [k*b, (k+1)*b)) live at lane t_lane's
        # C' block; replicate them (one b x n all-reduce — the FT broadcast).
        R_rows = comm.psum(
            comm.where(idx == t_lane, C_final, jnp.zeros_like(C_final))
        )

        panel_factors = PanelFactors(
            leaf_Y=leaf_Y,
            leaf_T=leaf_T,
            level_Y2=level_Y2,
            level_T=level_T,
            row_start=row_start,
            active=active,
            target=jnp.broadcast_to(t_lane, jnp.shape(idx)),
        )
        out = (panel_factors, R_rows, bundle if collect_bundles else None)
        return A_next, out

    return body


def caqr_factorize(
    A_local: jax.Array,
    comm,
    panel_width: int,
    collect_bundles: bool = False,
    use_scan: bool = True,
    windowed: Optional[bool] = None,
) -> CAQRResult:
    """FT-CAQR sweep of a general matrix. Returns replicated R plus
    implicit-Q panel factors.

    A_local: (m_loc, n) per lane (SimComm: (P, m_loc, n)). Any ``m x n``
        float shape is accepted — tall, wide, ragged (``n % b != 0``) and
        unaligned (``m_loc % b != 0``): the sweep runs at the zero-padded
        ``sweep_geometry`` shape (exact; see module docstring) and the
        returned R is ``(min(m, n), n)`` — square upper triangular when
        tall, ``[R1 R2]`` when wide. Factors and bundles live at the padded
        geometry (``caqr_apply_qt`` pads conforming inputs itself).
    panel_width: b.
    use_scan: True = lax.scan over panels (uniform per-iteration shapes,
        compile-time friendly; the trailing update spans all columns every
        panel). False = statically unrolled sweep — the performance variant.
    windowed: restrict panel k's trailing update to the live window
        ``A[:, k*b:]`` with *static* column slices, halving the sweep's
        trailing flops (see ``_panel_step_windowed``; outputs bit-identical
        to the full-width sweep). Requires the unrolled path; defaults to
        ``not use_scan``.
    """
    b = panel_width
    m_loc, n = comm.local_shape(A_local)
    P = comm.axis_size()
    geom = sweep_geometry(P, m_loc, n, b)
    A_work = pad_to_geometry(comm, A_local, geom)
    if windowed is None:
        windowed = not use_scan
    assert not (windowed and use_scan), \
        "the windowed sweep needs static column slices (use_scan=False)"
    n_panels, n_work = geom.n_panels, geom.n_work

    ks = jnp.arange(n_panels)
    if use_scan:
        body = _panel_step(comm, b, collect_bundles)
        _, (factors, R_rows, bundles) = jax.lax.scan(body, A_work, ks)
    else:
        outs = []
        A_cur = A_work
        body = None if windowed else _panel_step(comm, b, collect_bundles)
        for k in range(n_panels):
            if windowed:
                A_cur, out = _panel_step_windowed(
                    comm, b, collect_bundles, k, n_work
                )(A_cur)
            else:
                A_cur, out = body(A_cur, jnp.asarray(k))
            outs.append(out)
        factors = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *[o[0] for o in outs])
        R_rows = jnp.stack([o[1] for o in outs])
        bundles = (
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *[o[2] for o in outs])
            if collect_bundles
            else None
        )

    # R_rows: (n_panels, b, n_work) replicated (SimComm: (n_panels, P, b, n_work)).
    R = assemble_R(comm, R_rows, geom)
    return CAQRResult(R=R, factors=factors, bundles=bundles)


def caqr_apply_qt(
    B_local: jax.Array,
    factors: PanelFactors,
    comm,
    use_scan: bool = True,
) -> jax.Array:
    """Apply the implicit Q^T of a CAQR factorization to B (same row layout).

    Replays every panel's leaf WY + tree combine against B. For B = A this
    reproduces [R; 0] (up to the sweep's row bookkeeping) — the strongest
    internal consistency check of the stored factors.

    The factors of an unaligned factorization live at the padded
    ``sweep_geometry`` (see module docstring): B is zero-row-padded here to
    conform, and the result keeps the padded layout — R-row deposits of a
    ragged sweep land on pad-row positions, so slicing them off would lose
    them (``lstsq.caqr_lstsq`` collects deposits from exactly this layout).
    Aligned factors leave B untouched.
    """
    n_panels = jax.tree_util.tree_leaves(factors)[0].shape[0]
    m_fac = factors.leaf_Y.shape[-2]  # the factors' (padded) per-lane rows
    m_b = comm.local_shape(B_local)[0]
    if m_b != m_fac:
        assert m_b < m_fac, (m_b, m_fac)
        B_local = comm.map_local(
            lambda x: jnp.pad(x, ((0, m_fac - m_b), (0, 0)))
        )(B_local)

    def body(B_cur, pf: PanelFactors):
        dist = DistTSQRFactors(
            pf.leaf_Y, pf.leaf_T, pf.level_Y2, pf.level_T, pf.leaf_T
        )
        tgt = pf.target[0] if isinstance(comm, SimComm) else pf.target
        B_next, _, _ = trailing_update_ft(
            B_cur, dist, comm, target=tgt, row_start=pf.row_start,
            active=pf.active, dead_threshold=tgt,
        )
        return B_next, None

    if use_scan:
        B_out, _ = jax.lax.scan(body, B_local, factors)
    else:
        B_out = B_local
        for k in range(n_panels):
            pf = jax.tree_util.tree_map(lambda x: x[k], factors)
            B_out, _ = body(B_out, pf)
    return B_out


# Batched (vmap) front-end ---------------------------------------------------


def caqr_factorize_batched(
    A_batch: jax.Array, comm, panel_width: int, **kw
) -> CAQRResult:
    """Factorize a stack of independent same-shape problems in one call.

    A_batch carries a leading batch axis over ``caqr_factorize``'s layout:
    (batch, P, m_loc, n) under SimComm, (batch, m_loc, n) per lane under
    AxisComm. The whole sweep (any geometry — ragged, wide, scan or
    windowed) is ``jax.vmap``-ed, so the batch shares one compiled program;
    every field of the returned ``CAQRResult`` gains the leading batch axis.
    """
    return jax.vmap(
        lambda A: caqr_factorize(A, comm, panel_width, **kw)
    )(A_batch)


def caqr_apply_qt_batched(
    B_batch: jax.Array, factors: PanelFactors, comm, **kw
) -> jax.Array:
    """Batched companion of ``caqr_apply_qt``: replays a stack of
    factorizations (from ``caqr_factorize_batched``) against a conforming
    stack of right-hand sides."""
    return jax.vmap(
        lambda B, f: caqr_apply_qt(B, f, comm, **kw)
    )(B_batch, factors)


# SPMD wrapper ---------------------------------------------------------------


def caqr_factorize_spmd(A_local, axis_name: str, panel_width: int, **kw):
    return caqr_factorize(A_local, AxisComm(axis_name), panel_width, **kw)
