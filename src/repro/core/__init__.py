"""Core FT-CAQR library (the paper's contribution).

Layers:
  householder - WY/T compact representation substrate
  tsqr        - local chain + distributed baseline-tree / FT-butterfly TSQR
  trailing    - trailing-matrix update, Algorithm 1 (baseline) and 2 (FT)
  caqr        - full panel-sweep FT-CAQR of general matrices
  recovery    - failure injection + single-source REBUILD recovery
  comm        - SPMD/simulated communication abstraction
"""
from repro.core.comm import AxisComm, SimComm
from repro.core.householder import (
    WY,
    StackedQR,
    apply_q,
    apply_qt,
    build_t,
    householder_qr,
    householder_qr_masked,
    q_dense,
    stacked_apply_q,
    stacked_apply_qt,
    stacked_qr,
)
from repro.core.tsqr import (
    ChainFactors,
    DistTSQRFactors,
    baseline_tsqr,
    dist_orthonormalize,
    ft_tsqr,
    ft_tsqr_level,
    ft_tsqr_q,
    local_tsqr,
    local_tsqr_q,
    tsqr_orthonormalize,
)
from repro.core.trailing import (
    RecoveryBundle,
    TrailingLevelStep,
    trailing_combine_level,
    trailing_update_baseline,
    trailing_update_ft,
)
from repro.core.caqr import (
    CAQRResult,
    PanelFactors,
    SweepGeometry,
    assemble_R,
    block_row_layout,
    caqr_apply_qt,
    caqr_apply_qt_batched,
    caqr_factorize,
    caqr_factorize_batched,
    caqr_factorize_spmd,
    lane_geometry,
    pad_to_geometry,
    panel_geometry,
    sweep_geometry,
)
from repro.core import lstsq, recovery

__all__ = [
    "AxisComm", "SimComm", "WY", "StackedQR", "apply_q", "apply_qt",
    "build_t", "householder_qr", "householder_qr_masked", "q_dense",
    "stacked_apply_q", "stacked_apply_qt", "stacked_qr", "ChainFactors",
    "DistTSQRFactors", "baseline_tsqr", "dist_orthonormalize", "ft_tsqr",
    "ft_tsqr_level", "ft_tsqr_q", "local_tsqr", "local_tsqr_q",
    "tsqr_orthonormalize", "RecoveryBundle", "TrailingLevelStep",
    "trailing_combine_level", "trailing_update_baseline",
    "trailing_update_ft", "CAQRResult", "PanelFactors", "SweepGeometry",
    "assemble_R", "block_row_layout", "caqr_apply_qt",
    "caqr_apply_qt_batched",
    "caqr_factorize", "caqr_factorize_batched", "caqr_factorize_spmd",
    "lane_geometry", "pad_to_geometry", "panel_geometry", "sweep_geometry",
    "recovery", "lstsq",
]
