"""Failure injection and single-source recovery (paper §II, §III-B/C).

The paper's recovery model (REBUILD semantics): a failed process is respawned
with the same rank and its state is reconstructed from

  * its own slice of the *initial* matrix (re-read from the data source), and
  * the recovery bundle held by exactly ONE surviving process — its buddy at
    the current tree level: {W, T, C'_failed, Y2, role}.

The reconstruction is ``C_hat_failed = C'_failed - Y_failed @ W`` where
``Y_failed = I`` if the failed lane was the top block of its pair and ``Y2``
otherwise (paper §III-C bullet list).

This module executes the FT trailing update level by level in SimComm mode so
tests can kill a lane at any level, run the paper's recovery, resume, and
compare against the failure-free run. The level-stepping code calls the same
``_combine`` the production path uses.

These per-artifact reconstruction primitives (``recompute_leaf``,
``rebuild_cprime_after_level``, ``rebuild_block_row_through_panel``) are the
recompute seams every REBUILD path routes through: the scheduled driver and
the online orchestrator (``repro.ft.driver.rebuild_state``, shared by
``repro.ft.online``) both express a full mid-sweep rebuild as compositions
of exactly these calls plus single-source ``fetch_lane`` reads.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.comm import SimComm
from repro.core.householder import apply_qt, householder_qr_masked
from repro.core.trailing import _combine
from repro.core.tsqr import DistTSQRFactors, _levels, _xor_perm, ft_tsqr


class LaneState(NamedTuple):
    """Per-lane trailing-update state between tree levels (SimComm layout:
    leading lane axis)."""

    C_local: jax.Array  # (P, m_loc, n) full block-rows (leaf-updated)
    C_prime: jax.Array  # (P, b, n) current C' per lane
    level: int


class LevelBundle(NamedTuple):
    """Recovery bundle each lane stores after completing a level (Alg. 2)."""

    W: jax.Array        # (P, b, n)
    C_buddy: jax.Array  # (P, b, n)  the buddy's C' entering the level
    Y2: jax.Array       # (P, b, b)
    T: jax.Array        # (P, b, b)
    buddy_was_top: jax.Array  # (P,) bool


def trailing_begin(
    C_stacked: jax.Array, factors: DistTSQRFactors, comm: SimComm
) -> LaneState:
    """Leaf Q^T apply; C' = top-b rows (single-panel, paper setting)."""
    b = factors.R.shape[-1]
    C_local = jax.vmap(apply_qt)(factors.leaf_Y, factors.leaf_T, C_stacked)
    return LaneState(C_local=C_local, C_prime=C_local[:, :b], level=0)


def trailing_level(
    state: LaneState,
    factors: DistTSQRFactors,
    comm: SimComm,
    target: Optional[int] = None,
) -> Tuple[LaneState, LevelBundle]:
    """Execute one tree level of Algorithm 2 on all lanes."""
    P = comm.axis_size()
    if target is None:
        target = P - 1
    step = state.level
    idx = comm.axis_index()
    C_prime = state.C_prime
    C_buddy = comm.ppermute(C_prime, _xor_perm(P, step))
    tbit = (target >> step) & 1
    is_top = ((idx >> step) & 1) == tbit
    C_top = comm.where(is_top, C_prime, C_buddy)
    C_bot = comm.where(is_top, C_buddy, C_prime)
    Y2 = factors.level_Y2[step]
    T = factors.level_T[step]
    new_top, new_bot, W = _combine(Y2, T, C_top, C_bot)
    C_next = comm.where(is_top, new_top, new_bot)
    bundle = LevelBundle(
        W=W, C_buddy=C_buddy, Y2=Y2, T=T, buddy_was_top=~is_top
    )
    return LaneState(state.C_local, C_next, step + 1), bundle


def trailing_finish(state: LaneState) -> jax.Array:
    b = state.C_prime.shape[-2]
    return state.C_local.at[:, :b].set(state.C_prime)


def kill_lane(state: LaneState, lane: int) -> LaneState:
    """Simulate process death: the lane's state is obliterated."""
    return LaneState(
        C_local=state.C_local.at[lane].set(jnp.nan),
        C_prime=state.C_prime.at[lane].set(jnp.nan),
        level=state.level,
    )


def recover_cprime(
    bundle: LevelBundle, failed: int, source: int
) -> jax.Array:
    """Paper §III-C recovery: rebuild the failed lane's post-level C' from
    the bundle of ONE surviving lane (its buddy at that level).

    C_hat = C'_failed - Y_failed @ W, with Y_failed = I if the failed lane
    was the top block of the pair, Y2 otherwise. Reads ONLY `bundle[source]`.
    """
    W = bundle.W[source]
    C_failed = bundle.C_buddy[source]  # buddy's (== failed lane's) entry C'
    failed_was_top = bundle.buddy_was_top[source]
    Y2 = bundle.Y2[source]
    top_update = C_failed - W
    bot_update = C_failed - Y2 @ W
    return jnp.where(failed_was_top, top_update, bot_update)


def recover_lane_local(
    A_slice: jax.Array, factors_leaf_Y: jax.Array, factors_leaf_T: jax.Array
) -> jax.Array:
    """Rebuild the failed lane's full leaf-updated block-row from its slice
    of the INITIAL matrix (re-read from the data source) + its leaf factors
    (recomputable from the same slice; here we reuse the stored ones)."""
    return apply_qt(factors_leaf_Y, factors_leaf_T, A_slice)


def inject_and_recover(
    state: LaneState,
    bundle: LevelBundle,
    failed: int,
    A_slice: jax.Array,
    factors: DistTSQRFactors,
) -> Tuple[LaneState, int]:
    """Kill `failed` after a level, then run the paper's REBUILD recovery.

    Returns the repaired state and the single source lane that was read.
    The source is the XOR-buddy of the failed lane at the completed level
    (level state.level - 1); by the doubling-redundancy property any of the
    2^level lanes of the failed lane's redundancy group would do — we use
    exactly one, which is the paper's headline claim.
    """
    assert state.level >= 1, "leaf-level failure is handled by recompute"
    dead = kill_lane(state, failed)
    source = failed ^ (1 << (state.level - 1))
    # (1) local rows: re-read input slice, re-apply local reflectors
    C_local_rebuilt = recover_lane_local(
        A_slice, factors.leaf_Y[failed], factors.leaf_T[failed]
    )
    # (2) C': one fetch from the single source lane's bundle
    C_prime_rebuilt = recover_cprime(bundle, failed, source)
    repaired = LaneState(
        C_local=dead.C_local.at[failed].set(C_local_rebuilt),
        C_prime=dead.C_prime.at[failed].set(C_prime_rebuilt),
        level=dead.level,
    )
    return repaired, source


# ---------------------------------------------------------------------------
# Sweep-level single-source reconstruction primitives.
#
# These are the per-artifact REBUILD formulas the FT sweep driver
# (``repro.ft.driver``) applies when a lane dies mid-sweep. Each function
# receives ONLY the respawned lane's own re-read data plus the state of ONE
# surviving lane (its buddy at the relevant tree level) — the single-source
# property is enforced by the signatures, not by convention. All recompute
# routes through the same kernel-dispatch seam as the failure-free path
# (``householder_qr_masked`` / ``apply_qt`` / ``_combine``), so the rebuilt
# values are bit-identical to what the dead lane would have computed.
#
# Ragged/wide geometry: the driver runs (and re-reads) at the *padded*
# ``caqr.sweep_geometry`` shape, so every argument here — rows, col0,
# row_start, panel slices — is already padded-space data. Zero pad
# rows/columns flow through these formulas exactly like any other rows
# (they are plain floats that happen to be zero), which is why recovery
# stays single-source on general shapes with no extra bookkeeping.
# ---------------------------------------------------------------------------


def recompute_leaf(
    rows: jax.Array, col0: int, b: int, row_start: int, active: bool
):
    """Recompute a respawned lane's masked leaf panel factors from its own
    rebuilt block-row (paper: leaf state is never fetched — it is recomputed
    from the re-read initial data). Returns ``(leaf_Y, leaf_T, R_leaf)`` with
    the sweep's inactive-lane masking applied."""
    if not active:
        m_loc = rows.shape[0]
        z = jnp.zeros((b, b), rows.dtype)
        return jnp.zeros((m_loc, b), rows.dtype), z, z
    wy = householder_qr_masked(
        rows[:, col0:col0 + b], jnp.asarray(row_start, jnp.int32)
    )
    return wy.Y, wy.T, wy.R


def rebuild_cprime_after_level(
    C_fail_entering: jax.Array,
    C_source_entering: jax.Array,
    Y2: jax.Array,
    T: jax.Array,
    failed_was_top: bool,
    pair_live: bool,
) -> jax.Array:
    """Paper §III-C REBUILD: the failed lane's C' *after* a tree level, from
    the bundle of its buddy at that level (the single source).

    The source's bundle holds both pair inputs (its own C' and the exchanged
    copy of the failed lane's), so the recovery replays the exact pair
    combine through ``_combine`` — the same seam-routed computation the level
    originally ran — and keeps the failed lane's side. ``pair_live=False``
    (a pair with a fully-consumed member) is the sweep's per-lane
    pass-through. ``failed_was_top`` is static role data (derived from lane
    index and tree target), the paper's ``role`` bundle field.
    """
    if not pair_live:
        return C_fail_entering
    C_top = C_fail_entering if failed_was_top else C_source_entering
    C_bot = C_source_entering if failed_was_top else C_fail_entering
    new_top, new_bot, _W = _combine(Y2, T, C_top, C_bot)
    return new_top if failed_was_top else new_bot


def rebuild_block_row_through_panel(
    rows: jax.Array,
    leaf_Y: jax.Array,
    leaf_T: jax.Array,
    C_prime_final: jax.Array,
    col0: int,
    row_start: int,
    active: bool,
) -> jax.Array:
    """Advance a respawned lane's block-row through one completed panel:
    re-apply the (recomputed) leaf reflectors to the live window and write
    back the recovered final C' — the replay analogue of the sweep's
    leaf-apply + writeback. ``C_prime_final`` comes from ONE survivor via
    ``rebuild_cprime_after_level`` at the tree's last level."""
    window = apply_qt(leaf_Y, leaf_T, rows[:, col0:])
    if active:
        window = window.at[row_start:row_start + C_prime_final.shape[0]].set(
            C_prime_final
        )
    return jnp.concatenate([rows[:, :col0], window], axis=1)


# The XOR pairing moved to the coding seam (repro.ft.coding): XORPairScheme
# is the f=1 instance of the generalized redundancy, and xor_buddy /
# pairing_table are its pairing algebra. Re-exported here for the existing
# import sites (tests, elastic docs); the definitions are identical.
from repro.ft.coding import pairing_table, xor_buddy  # noqa: E402,F401


def tsqr_recover_r(factors: DistTSQRFactors, failed: int, source: int) -> jax.Array:
    """FT-TSQR recovery (§III-B): the restarted lane obtains R from any
    single member of its redundancy group — R is bit-identical there."""
    return factors.R[source]


def run_ft_trailing(
    C_stacked: jax.Array,
    factors: DistTSQRFactors,
    comm: SimComm,
    fail_at_level: Optional[int] = None,
    failed_lane: int = 0,
    A_stacked: Optional[jax.Array] = None,
):
    """Drive the level machine end to end, optionally killing + recovering
    one lane after ``fail_at_level`` completes. Returns the updated matrix."""
    P = comm.axis_size()
    levels = _levels(P)
    state = trailing_begin(C_stacked, factors, comm)
    for lvl in range(levels):
        state, bundle = trailing_level(state, factors, comm)
        if fail_at_level is not None and lvl == fail_at_level:
            assert A_stacked is not None
            state, _src = inject_and_recover(
                state, bundle, failed_lane, A_stacked[failed_lane], factors
            )
    return trailing_finish(state)
