"""Trailing-matrix update trees (paper §III-C, Algorithms 1 and 2).

After a panel's TSQR, the implicit ``Q^T`` is applied to the trailing columns
through the same tree the R factors were reduced on:

* leaf: each lane applies its local WY reflectors to its block-row;
* per level: the buddy pair combines the top-b rows ``C'`` of their active
  blocks through the stacked (Y2, T) factors of that level:
      W      = T^T (C'_top + Y2^T C'_bot)
      C'_top = C'_top - W            (top block's Y is the identity)
      C'_bot = C'_bot - Y2 W

``trailing_update_baseline``  — Algorithm 1: one-directional tree. The odd
lane sends C', the even lane computes T and W, sends W back; each updates its
own block. Half the lanes retire per level; no redundancy is created.

``trailing_update_ft``        — Algorithm 2: the pair *exchanges* C' in a
single sendrecv (ppermute both ways), BOTH compute W redundantly, and both
keep the bundle {W, T, C'_self, C'_buddy, Y2} — the recovery invariant: a
failed lane's output is ``C'_failed - Y_failed @ W``, computable from ONE
surviving buddy (Y_failed = I if the buddy was the top block, Y2 otherwise).

Note: the paper's Algorithm 2 exchanges ``C' + Y`` because it presents the
trailing tree standalone. Under FT-TSQR both lanes of a pair already hold
identical (Y2, T) from the panel reduction, so only C' needs to travel —
a (b x b) per-level saving we record as an enabled-by-FT-TSQR optimization.

Both functions are SPMD programs over a Comm (see ``repro.core.comm``) and
consume the combine factors produced by the matching TSQR variant.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.comm import SimComm
from repro.core.householder import apply_qt
from repro.core.tsqr import DistTSQRFactors, _levels, _xor_perm


class RecoveryBundle(NamedTuple):
    """What each lane retains per tree level under Algorithm 2.

    Enough to rebuild the buddy's update from this lane alone:
    ``C_hat_buddy = C_buddy - Y_buddy @ W`` where ``Y_buddy`` is ``I`` if the
    buddy was the top lane of the pair and ``Y2`` if it was the bottom.
    All arrays carry a leading ``levels`` axis (in SimComm additionally a
    lane axis right after it).
    """

    W: jax.Array        # (L, b, n) the shared W of each level
    C_self: jax.Array   # (L, b, n) this lane's C' entering each level
    C_buddy: jax.Array  # (L, b, n) the buddy's C' received at each level
    Y2: jax.Array       # (L, b, b) the level's structured Householder block
    T: jax.Array        # (L, b, b) the level's T factor
    self_was_top: jax.Array  # (L,) bool: was this lane the top of its pair


def _combine(Y2, T, C_top, C_bot):
    """Paper's W-form combine (batched under SimComm via .mT / matmul).

    Unbatched f32 calls (the AxisComm/shard_map production path) dispatch to
    the fused trailing-combine Pallas kernel via ``stacked_apply_qt``.
    """
    if Y2.ndim == 2:
        from repro.core.householder import StackedQR, stacked_apply_qt

        return stacked_apply_qt(StackedQR(Y2=Y2, T=T, R=T), C_top, C_bot)
    W = T.mT @ (C_top + Y2.mT @ C_bot)
    return C_top - W, C_bot - Y2 @ W, W


class TrailingLevelStep(NamedTuple):
    """Output of one trailing-combine level: the advanced C' plus this
    level's slice of the recovery bundle (what each lane must retain)."""

    C_prime: jax.Array  # (b, n) advanced C' per lane
    W: jax.Array        # (b, n) the level's shared W (pair_live-masked)
    C_self: jax.Array   # (b, n) this lane's C' entering the level
    C_buddy: jax.Array  # (b, n) the buddy's C' received at the level
    is_top: jax.Array   # ()    was this lane the top of its pair


def trailing_combine_level(
    comm,
    C_prime: jax.Array,
    Y2: jax.Array,
    T: jax.Array,
    step: int,
    target,
    dead_threshold,
    paper_semantics: bool = False,
) -> TrailingLevelStep:
    """One tree level of Algorithm 2's trailing update.

    The pair exchanges C' in a single sendrecv, BOTH lanes compute the
    T-dependent W redundantly (paper Alg. 2 lines 5/14 and 9/18), and each
    keeps the level's recovery bundle slice. Zeroed (Y2, T) make the combine
    a pass-through; a pair with a dead member passes through per lane.

    The whole-tree ``trailing_update_ft`` loops over this function, and the
    level-stepped FT sweep driver (``repro.ft.driver``) interleaves it with
    failure checkpoints — both paths run the same floating-point program.
    """
    P = comm.axis_size()
    idx = comm.axis_index()
    # sendrecv: one bidirectional collective-permute — the paper's
    # exchange; on full-duplex links this costs one one-way hop.
    C_buddy = comm.ppermute(C_prime, _xor_perm(P, step))
    tbit = (target >> step) & 1
    is_top = ((idx >> step) & 1) == tbit
    C_top = comm.where(is_top, C_prime, C_buddy)
    C_bot = comm.where(is_top, C_buddy, C_prime)
    new_top, new_bot, W = _combine(Y2, T, C_top, C_bot)
    # Per-lane pass-through: a pair with a dead member does not combine.
    buddy_idx = idx ^ (1 << step)
    pair_live = jnp.logical_and(
        idx >= dead_threshold, buddy_idx >= dead_threshold
    )
    if paper_semantics:
        # Alg. 2 verbatim: only lanes that survived all earlier levels
        # (low bits zero) participate; the top lane retires afterwards.
        participates = (idx % (1 << step)) == 0
        pair_live = jnp.logical_and(pair_live, participates)
    W = comm.where(pair_live, W, jnp.zeros_like(W))
    C_next = comm.where(is_top, new_top, new_bot)
    C_next = comm.where(pair_live, C_next, C_prime)
    return TrailingLevelStep(
        C_prime=C_next, W=W, C_self=C_prime, C_buddy=C_buddy, is_top=is_top
    )


def _leaf_apply(comm, factors: DistTSQRFactors, C_local, row_start,
                active=None, skip_consumed: bool = False):
    """Local Q^T apply + extract the C' block at each lane's row_start.

    ``skip_consumed``: lanes with ``active == False`` are fully consumed by
    the sweep — their leaf Y is all zeros and the apply is the identity.
    Under ``lax.cond`` the SPMD (shard_map) execution skips the dead lanes'
    leaf GEMMs at runtime; the branch outputs are bit-identical to running
    the zero-Y apply, so results do not depend on the flag. (SimComm's vmap
    lowers the cond to a select and computes both — it is a simulator.)
    """
    b = comm.local_shape(factors.R)[-1]

    def leaf(Y, T, C, rs):
        C2 = apply_qt(Y, T, C)
        Cp = jax.lax.dynamic_slice_in_dim(C2, rs, b, axis=0)
        return C2, Cp

    # SimComm's vmap would lower the cond to a select computing BOTH
    # branches — strictly more work in the simulator, identical results —
    # so the skip only engages on real SPMD comms.
    if not skip_consumed or active is None or isinstance(comm, SimComm):
        return comm.map_local(leaf)(
            factors.leaf_Y, factors.leaf_T, C_local, row_start
        )

    def leaf_or_skip(Y, T, C, rs, act):
        return jax.lax.cond(
            act,
            lambda: leaf(Y, T, C, rs),
            lambda: (C, jax.lax.dynamic_slice_in_dim(C, rs, b, axis=0)),
        )

    return comm.map_local(leaf_or_skip)(
        factors.leaf_Y, factors.leaf_T, C_local, row_start, active
    )


def _writeback(comm, C_local, C_prime, row_start, active):
    def wb(C, Cp, rs, act):
        blk = jax.lax.dynamic_slice_in_dim(C, rs, Cp.shape[0], axis=0)
        new = jnp.where(act, Cp, blk)
        return jax.lax.dynamic_update_slice_in_dim(C, new, rs, axis=0)

    return comm.map_local(wb)(C_local, C_prime, row_start, active)


def trailing_update_ft(
    C_local: jax.Array,
    factors: DistTSQRFactors,
    comm,
    target=None,
    row_start=None,
    active=None,
    dead_threshold=None,
    paper_semantics: bool = False,
    skip_consumed: bool = False,
):
    """Algorithm 2: fault-tolerant trailing update.

    C_local: (m_loc, n) this lane's block-row of the trailing matrix.
    factors: the panel's FT-TSQR factors (leaf WY + per-level Y2/T; zeroed
        levels encode pass-throughs, e.g. consumed lanes in a CAQR sweep).
    target: root lane of the tree orientation (default P-1, the paper's
        odd-on-top convention). Must match the TSQR call.
    row_start: per-lane row offset of the C' block (default 0).
    active: per-lane participation flag (default all active).
    dead_threshold: lanes < this are fully consumed (CAQR sweep). A pair
        with a dead member passes through *per lane* — a live lane must not
        mix its residual slot with a dead lane's phantom zeros (the R-side
        group masking is coarser and cannot express this).
    paper_semantics: True = the paper's exact Algorithm 2, where the
        sender lane RETIRES after its level (line 11's ``return``) and
        non-participants idle — per-lane outputs then equal Algorithm 1
        exactly (tested). Use with factors built at target=0 (receiver-on-
        top stacking, the classical survivor chain) and pass target=0 here.
        False (default) = the full-butterfly generalization: every lane
        keeps combining at every level, which leaves every lane a recovery
        bundle for *every* level (strictly more redundancy) and replicated
        tree state — this is the variant the CAQR sweep uses. Both are
        valid orthogonal reductions.
    skip_consumed: skip the leaf apply on inactive lanes via ``lax.cond``
        (see ``_leaf_apply``); bit-identical outputs, fewer flops under
        SPMD. The windowed CAQR sweep sets this.

    Factors built on zero-padded lanes (``ft_tsqr`` with short lanes, or a
    ragged ``sweep_geometry``) carry more leaf rows than a caller's raw
    C_local: C is zero-row-padded here to conform, and the *padded* layout
    is returned — the C' deposit of the tree root may land on pad rows, so
    slicing them off would lose it. Aligned callers are untouched.

    Returns (updated block-row, per-level recovery bundles, final C').
    """
    P = comm.axis_size()
    levels = _levels(P)
    idx = comm.axis_index()
    b = comm.local_shape(factors.R)[-1]
    m_fac = comm.local_shape(factors.leaf_Y)[0]
    m_c = comm.local_shape(C_local)[0]
    if m_c != m_fac:
        assert m_c < m_fac, (m_c, m_fac)
        C_local = comm.map_local(
            lambda x: jnp.pad(x, ((0, m_fac - m_c), (0, 0)))
        )(C_local)
    if target is None:
        target = jnp.asarray(P - 1)
    if row_start is None:
        row_start = idx * 0
    if active is None:
        active = idx >= 0
    if dead_threshold is None:
        dead_threshold = jnp.zeros((), jnp.int32)

    C_local, C_prime = _leaf_apply(
        comm, factors, C_local, row_start,
        active=active, skip_consumed=skip_consumed,
    )
    C_prime = comm.where(active, C_prime, jnp.zeros_like(C_prime))

    Ws, Cs_self, Cs_buddy, tops = [], [], [], []
    for step in range(levels):
        out = trailing_combine_level(
            comm, C_prime, factors.level_Y2[step], factors.level_T[step],
            step, target, dead_threshold, paper_semantics=paper_semantics,
        )
        Ws.append(out.W)
        Cs_self.append(out.C_self)
        Cs_buddy.append(out.C_buddy)
        tops.append(out.is_top)
        C_prime = out.C_prime

    C_out = _writeback(comm, C_local, C_prime, row_start, active)

    if levels:
        bundle = RecoveryBundle(
            W=jnp.stack(Ws),
            C_self=jnp.stack(Cs_self),
            C_buddy=jnp.stack(Cs_buddy),
            Y2=factors.level_Y2,
            T=factors.level_T,
            self_was_top=jnp.stack(tops),
        )
    else:
        zshape = (0,) + tuple(jnp.shape(C_prime))
        zb = (0,) + tuple(jnp.shape(factors.R))
        bundle = RecoveryBundle(
            jnp.zeros(zshape, C_prime.dtype),
            jnp.zeros(zshape, C_prime.dtype),
            jnp.zeros(zshape, C_prime.dtype),
            jnp.zeros(zb, C_prime.dtype),
            jnp.zeros(zb, C_prime.dtype),
            jnp.zeros((0,) + tuple(jnp.shape(idx)), jnp.bool_),
        )
    return C_out, bundle, C_prime


def trailing_update_baseline(
    C_local: jax.Array,
    factors: DistTSQRFactors,
    comm,
) -> jax.Array:
    """Algorithm 1: one-directional trailing update tree (paper baseline).

    At level s the odd lane of each pair sends its C' up, the even lane
    computes W and sends it back; the odd lane then retires from the tree.
    No redundancy is created — a failure loses state that only the dead lane
    held. Kept for overhead comparison against Algorithm 2. Uses the paper's
    fixed odd-on-top orientation (target = P-1); single-panel use.
    """
    P = comm.axis_size()
    levels = _levels(P)
    idx = comm.axis_index()
    row_start = idx * 0

    C_local, C_prime = _leaf_apply(comm, factors, C_local, row_start)

    for step in range(levels):
        stride = 1 << step
        group = 1 << (step + 1)
        # odd -> even: C' travels up the tree (Alg. 1 line 7 / 16)
        up = [(i, i - stride) for i in range(P) if i % group == stride]
        C_from_odd = comm.ppermute(C_prime, up)
        is_even = (idx % group) == 0
        Y2 = factors.level_Y2[step]
        T = factors.level_T[step]
        # Receiver (even, the survivor) is the TOP/identity block: it keeps
        # C'_own - W, so the R-slot content stays with the survivor chain.
        # W = T^T (C'_own + Y2^T C'_odd)   (paper Alg. 1 line 17, with the
        # receiver-on-top stacking that makes the slot bookkeeping close).
        even_new, _, W = _combine(Y2, T, C_prime, C_from_odd)
        # even -> odd: the sender's update product V = Y2 @ W travels back
        # (same b x n wire bytes as the paper's W; the paper has the sender
        # apply its own "Y_0" to W, but the stacked Y2 is not computable from
        # the sender's R alone — shipping V resolves this; adaptation noted
        # in DESIGN.md).
        V = Y2 @ W
        down = [(i - stride, i) for i in range(P) if i % group == stride]
        V_from_even = comm.ppermute(V, down)
        is_odd = (idx % group) == stride
        odd_update = C_prime - V_from_even
        C_prime = comm.where(
            is_even, even_new, comm.where(is_odd, odd_update, C_prime)
        )

    active = idx >= 0
    return _writeback(comm, C_local, C_prime, row_start, active)
