"""TSQR: Tall-Skinny QR via reduction trees (paper §III-A / §III-B).

Two distributed variants over a Comm (see ``repro.core.comm``):

* ``baseline_tsqr``  — the classical binary reduction tree [DGHL08]: at level
  ``s`` the odd-numbered (mod 2^{s+1}) lane ships its R to the even one and
  goes idle. Only lane 0 ends with R. Under SPMD "idle" lanes carry zeros.

* ``ft_tsqr``        — the paper's fault-tolerant butterfly (Fig. 2): the pair
  *exchanges* R factors and BOTH compute the stacked QR. Every lane ends with
  the (bit-identical) final R and the full ladder of (Y2, T) combine factors
  along its own path, so the redundancy of every intermediate doubles per
  level and any lane's state is reconstructible from its XOR-buddy.

Stacking convention (paper Alg. 1/2): within a pair, the lane whose index bit
at the current level *differs from the target root's bit* is the TOP block —
its Y is the identity. With the default target ``P-1`` this makes the odd
lane (the baseline tree's sender) the top block, which is exactly what gives
the paper's "sender needs only W" property. ``caqr`` rotates the target to
the diagonal-owner lane per panel (bookkeeping the paper elides).

Plus a sequential in-device chain (``local_tsqr``) used to keep leaf working
sets VMEM-sized and to orthonormalize tall gradients in the CAQR-Muon
optimizer.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.comm import AxisComm, SimComm
from repro.core.householder import (
    WY,
    StackedQR,
    apply_q,
    householder_qr,
    stacked_apply_q,
    stacked_qr,
)


# ---------------------------------------------------------------------------
# Local (single-device) sequential TSQR chain.
# ---------------------------------------------------------------------------


class ChainFactors(NamedTuple):
    """Factors of a sequential TSQR chain over row tiles.

    leaf: WY of tile 0.
    steps: WY of each stacked [R_prev; tile_t] factorization, t = 1..T-1,
           stacked on a leading axis: Y (T-1, b + tile_rows, b), T (T-1, b, b).
    """

    leaf_Y: jax.Array
    leaf_T: jax.Array
    step_Y: jax.Array
    step_T: jax.Array


@functools.partial(jax.jit, static_argnames=("tile_rows",))
def local_tsqr(A: jax.Array, tile_rows: int) -> Tuple[ChainFactors, jax.Array]:
    """Sequential TSQR of A (m, b) over row tiles of ``tile_rows`` rows.

    Requires tile_rows >= b; ``m`` may be ragged (not a multiple of
    tile_rows): the last tile is zero-padded, which is exact — zero rows
    yield degenerate reflectors with tau = 0 and contribute nothing to any
    inner product (the ``kernels/ops.py`` padding contract at the core
    layer). The chain factors then live at the padded row count
    (``local_tsqr_q`` produces exact zero rows there; callers slice back).
    Returns the chain factors and the final R (b, b).
    """
    m, b = A.shape
    assert tile_rows >= b, (m, b, tile_rows)
    m_pad = -(-m // tile_rows) * tile_rows
    if m_pad != m:
        A = jnp.pad(A, ((0, m_pad - m), (0, 0)))
    n_tiles = m_pad // tile_rows
    tiles = A.reshape(n_tiles, tile_rows, b)

    leaf = householder_qr(tiles[0])
    R = leaf.R

    def step(carry, tile):
        R_prev = carry
        S = jnp.concatenate([R_prev, tile], axis=0)  # (b + tile_rows, b)
        wy = householder_qr(S)
        return wy.R, (wy.Y, wy.T)

    if n_tiles > 1:
        R, (step_Y, step_T) = jax.lax.scan(step, R, tiles[1:])
    else:
        step_Y = jnp.zeros((0, b + tile_rows, b), A.dtype)
        step_T = jnp.zeros((0, b, b), A.dtype)
    return ChainFactors(leaf.Y, leaf.T, step_Y, step_T), R


@functools.partial(jax.jit, static_argnames=("tile_rows",))
def local_tsqr_q(factors: ChainFactors, tile_rows: int) -> jax.Array:
    """Materialize the thin Q (m, b) of a ``local_tsqr`` chain.

    Walks the chain backwards: at each step Q_t [E_t; 0] = [E_{t-1}; F_t]
    where F_t is tile t's block of Q and E feeds the previous step.
    """
    b = factors.leaf_T.shape[-1]
    n_steps = factors.step_Y.shape[0]
    # + 0*leaf_T keeps the scan carry's varying-manual-axes consistent when
    # this runs inside shard_map (e.g. the CAQR-Muon optimizer).
    E = jnp.eye(b, dtype=factors.leaf_Y.dtype) + factors.leaf_T * 0

    def step(carry, wy):
        E_t = carry
        Y, T = wy
        block = jnp.concatenate(
            [E_t, jnp.zeros((tile_rows, b), E_t.dtype)], axis=0
        )
        out = apply_q(Y, T, block)
        return out[:b], out[b:]

    if n_steps > 0:
        # reverse scan: root (last chain step) first; outputs stay aligned
        # with input positions, i.e. forward tile order 1..T-1.
        E, F_tiles = jax.lax.scan(step, E, (factors.step_Y, factors.step_T), reverse=True)
    else:
        F_tiles = jnp.zeros((0, tile_rows, b), E.dtype)

    pad = jnp.concatenate([E, jnp.zeros((tile_rows - b, b), E.dtype)], axis=0)
    F0 = apply_q(factors.leaf_Y, factors.leaf_T, pad)
    return jnp.concatenate([F0[None], F_tiles], axis=0).reshape(-1, b)


@functools.partial(jax.jit, static_argnames=("tile_rows",))
def tsqr_orthonormalize(A: jax.Array, tile_rows: int) -> Tuple[jax.Array, jax.Array]:
    """Convenience: thin Q, R of tall-skinny A via the sequential chain.

    Ragged ``m`` is supported: the chain pads the last tile with zero rows
    (exact) and the corresponding all-zero Q rows are sliced back off here.
    """
    factors, R = local_tsqr(A, tile_rows)
    return local_tsqr_q(factors, tile_rows)[: A.shape[0]], R


# ---------------------------------------------------------------------------
# Distributed TSQR over a Comm.
# ---------------------------------------------------------------------------


class DistTSQRFactors(NamedTuple):
    """Per-lane factors of a distributed TSQR.

    leaf_Y / leaf_T: WY factors of the lane's local QR.
    level_Y2 / level_T: combine factors along this lane's butterfly path
        (FT) or tree path (baseline), stacked on a leading ``levels`` axis.
        Zeroed entries encode pass-through combines (inactive groups in the
        CAQR sweep; idle lanes in the baseline tree).
    R: final R — on every lane for FT, on lane 0 for baseline.
    """

    leaf_Y: jax.Array
    leaf_T: jax.Array
    level_Y2: jax.Array
    level_T: jax.Array
    R: jax.Array


def _xor_perm(P: int, step: int) -> Sequence[Tuple[int, int]]:
    return [(i, i ^ (1 << step)) for i in range(P)]


def _levels(P: int) -> int:
    assert P & (P - 1) == 0, f"TSQR axis must be a power of two, got {P}"
    return P.bit_length() - 1


def ft_tsqr_level(
    comm,
    R: jax.Array,
    step: int,
    target,
    active_threshold,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One level of the FT butterfly (paper Fig. 2) over current R factors.

    The pair ``(i, i ^ 2^step)`` exchanges R in one sendrecv and BOTH lanes
    compute the identical stacked QR — the redundancy doubling that recovery
    exploits. Returns ``(R_next, Y2, T)`` with the group-activity masking
    applied (zeroed factors == pass-through).

    This is the single-level step the level-stepped FT sweep driver
    (``repro.ft.driver``) interleaves with failure checkpoints; the whole-tree
    ``ft_tsqr_combine`` below loops over it, so the two paths are the same
    floating-point program.
    """
    idx = comm.axis_index()
    P = comm.axis_size()
    R_buddy = comm.ppermute(R, _xor_perm(P, step))
    # Orientation: the TOP block of each pair is the lane whose index bit
    # matches the target's bit, so the lane that is top at EVERY level is
    # exactly ``target`` — that is where the R (and the trailing R_12
    # rows) deposit. Default target P-1 == paper's odd-on-top convention.
    tbit = (target >> step) & 1
    is_top = ((idx >> step) & 1) == tbit
    R_top = comm.where(is_top, R, R_buddy)
    R_bot = comm.where(is_top, R_buddy, R)
    sq = comm.map_local(stacked_qr)(R_top, R_bot)
    # Group-activity masking (CAQR sweep): a group of 2^step lanes is
    # fully consumed iff its max lane < active_threshold.
    group = 1 << step
    my_base = idx & ~(group - 1)
    sib_base = (idx ^ group) & ~(group - 1)
    my_dead = my_base + group <= active_threshold
    sib_dead = sib_base + group <= active_threshold
    both_live = jnp.logical_and(~my_dead, ~sib_dead)
    R_next = comm.where(
        both_live,
        sq.R,
        comm.where(my_dead, R_buddy, R),  # adopt / pass-through
    )
    Y2 = comm.where(both_live, sq.Y2, jnp.zeros_like(sq.Y2))
    T = comm.where(both_live, sq.T, jnp.zeros_like(sq.T))
    return R_next, Y2, T


def ft_tsqr_combine(
    comm,
    R: jax.Array,
    target,
    active_threshold=None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The FT butterfly over already-computed leaf R factors.

    ``target`` orients the virtual stacking so the final R_12 deposit of the
    trailing update lands on lane ``target`` (the diagonal-owner in a CAQR
    sweep). ``active_threshold`` (lane index ``t``; lanes < t are fully
    consumed) enables the masked pass-through combines; ``None`` means all
    lanes active.

    Returns (level_Y2, level_T, R_final) with a leading ``levels`` axis on
    the factor stacks.
    """
    P = comm.axis_size()
    levels = _levels(P)
    if active_threshold is None:
        active_threshold = jnp.zeros((), jnp.int32)

    Y2s, Ts = [], []
    for step in range(levels):
        R, Y2, T = ft_tsqr_level(comm, R, step, target, active_threshold)
        Y2s.append(Y2)
        Ts.append(T)

    if levels:
        level_Y2 = jnp.stack(Y2s)
        level_T = jnp.stack(Ts)
    else:
        shape = (0,) + tuple(jnp.shape(R))
        level_Y2 = jnp.zeros(shape, R.dtype)
        level_T = jnp.zeros(shape, R.dtype)
    return level_Y2, level_T, R


def ft_tsqr(A_local: jax.Array, comm, target: int | None = None) -> DistTSQRFactors:
    """The paper's FT-TSQR butterfly (§III-B, Fig. 2).

    Every lane exchanges R with its XOR-buddy at each level and both compute
    the identical stacked QR. After ``log2 P`` levels every lane holds the
    final R, and the set of lanes sharing each intermediate doubles per level
    — that is the redundancy the recovery procedure exploits.
    """
    P = comm.axis_size()
    if target is None:
        target = P - 1  # paper convention: odd lane on top at every level
    m_loc, b = comm.local_shape(A_local)
    if m_loc < b:
        # short lanes (fewer local rows than panel columns): zero-pad the
        # leaf to b rows so the masked QR's R extraction stays in bounds —
        # exact, and the leaf factors then live at the padded row count.
        A_local = comm.map_local(
            lambda x: jnp.pad(x, ((0, b - m_loc), (0, 0)))
        )(A_local)
    leaf = comm.map_local(householder_qr)(A_local)
    level_Y2, level_T, R = ft_tsqr_combine(comm, leaf.R, jnp.asarray(target))
    return DistTSQRFactors(leaf.Y, leaf.T, level_Y2, level_T, R)


def baseline_tsqr(
    A_local: jax.Array, comm, broadcast_r: bool = False
) -> DistTSQRFactors:
    """Classical one-directional reduction tree (paper §III-A baseline).

    At level s only lanes with the low s+1 index bits == 0 receive and
    compute; senders go idle (carry zeros afterwards). Only lane 0 holds the
    final R; ``broadcast_r`` adds the extra broadcast the FT variant gets for
    free.
    """
    P = comm.axis_size()
    levels = _levels(P)
    idx = comm.axis_index()

    leaf = comm.map_local(householder_qr)(A_local)
    R = leaf.R

    Y2s, Ts = [], []
    for step in range(levels):
        stride = 1 << step
        group = 1 << (step + 1)
        # sender i (i % group == stride) ships R to i - stride.
        perm = [(i, i - stride) for i in range(P) if i % group == stride]
        R_from_buddy = jax.tree_util.tree_map(
            lambda x: comm.ppermute(x, perm), R
        )
        is_receiver = (idx % group) == 0
        # RECEIVER's R on top (identity block): the survivor chain then
        # carries the R-slot upward consistently — the stacking that makes
        # the classical trailing tree well-defined (see trailing.py notes).
        sq = comm.map_local(stacked_qr)(R, R_from_buddy)
        R = comm.where(is_receiver, sq.R, jnp.zeros_like(sq.R))
        Y2s.append(comm.where(is_receiver, sq.Y2, jnp.zeros_like(sq.Y2)))
        Ts.append(comm.where(is_receiver, sq.T, jnp.zeros_like(sq.T)))

    if broadcast_r and levels:
        # one-to-all broadcast of lane 0's R (what FT gets structurally)
        R = comm.psum(comm.where(idx == 0, R, jnp.zeros_like(R)))

    if levels:
        level_Y2 = jnp.stack(Y2s)
        level_T = jnp.stack(Ts)
    else:
        shape = (0,) + tuple(jnp.shape(R))
        level_Y2 = jnp.zeros(shape, R.dtype)
        level_T = jnp.zeros(shape, R.dtype)
    return DistTSQRFactors(leaf.Y, leaf.T, level_Y2, level_T, R)


def ft_tsqr_q(
    factors: DistTSQRFactors, comm, target: int | None = None
) -> jax.Array:
    """Materialize this lane's block of the thin Q from FT-TSQR factors.

    Top-down walk of the butterfly: at each level the pair exchanges its
    current E block (b x b) and each computes its own half of
    Q_level [E_top; E_bot]. One b x b ppermute per level — the same
    communication shape as the forward pass.
    """
    P = comm.axis_size()
    levels = _levels(P)
    if target is None:
        target = P - 1
    target = jnp.asarray(target)
    idx = comm.axis_index()
    b = comm.local_shape(factors.R)[-1]
    # E starts as I on the virtual-top lane (= target), 0 elsewhere.
    eye = comm.map_local(lambda r: jnp.eye(b, dtype=r.dtype) + r * 0)(factors.R)
    E = comm.where(idx == target, eye, jnp.zeros_like(eye))

    for step in reversed(range(levels)):
        E_buddy = comm.ppermute(E, _xor_perm(P, step))
        tbit = (target >> step) & 1
        is_top = ((idx >> step) & 1) == tbit
        E_top = comm.where(is_top, E, E_buddy)
        E_bot = comm.where(is_top, E_buddy, E)
        Y2 = factors.level_Y2[step]
        T = factors.level_T[step]
        new_top, new_bot = comm.map_local(
            lambda y2, t, ct, cb: stacked_apply_q(StackedQR(y2, t, t), ct, cb)
        )(Y2, T, E_top, E_bot)
        E = comm.where(is_top, new_top, new_bot)

    m_loc = comm.local_shape(factors.leaf_Y)[0]

    def leaf_apply(Y, T, E_blk):
        pad = jnp.concatenate([E_blk, jnp.zeros((m_loc - b, b), E_blk.dtype)], axis=0)
        return apply_q(Y, T, pad)

    return comm.map_local(leaf_apply)(factors.leaf_Y, factors.leaf_T, E)


def dist_orthonormalize(A_local: jax.Array, comm) -> Tuple[jax.Array, jax.Array]:
    """Distributed thin-QR orthonormalization: returns (Q_local, R).

    R is replicated on every lane (the FT property); Q_local is this lane's
    row block of the thin Q. Short lanes (m_loc < b) are zero-padded inside
    ``ft_tsqr``; the pad rows of Q are exactly zero and are sliced back off.
    """
    m_loc = comm.local_shape(A_local)[0]
    factors = ft_tsqr(A_local, comm)
    Q = ft_tsqr_q(factors, comm)
    if comm.local_shape(Q)[0] != m_loc:
        Q = comm.map_local(lambda q: q[:m_loc])(Q)
    return Q, factors.R


# Convenience SPMD wrappers (call inside shard_map) -------------------------


def ft_tsqr_spmd(A_local: jax.Array, axis_name: str) -> DistTSQRFactors:
    return ft_tsqr(A_local, AxisComm(axis_name))


def dist_orthonormalize_spmd(A_local: jax.Array, axis_name: str):
    return dist_orthonormalize(A_local, AxisComm(axis_name))
