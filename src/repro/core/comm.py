"""Communication abstraction: one SPMD code path, two executions.

The paper's algorithms are written as per-process (per-lane) SPMD programs
with pairwise exchanges. We express them once against this small ``Comm``
interface and run them two ways:

* ``AxisComm``  — inside ``jax.shard_map`` over a named mesh axis; collectives
  lower to real ICI ``collective-permute`` / ``all-reduce`` ops. This is the
  production path (and the dry-run path).

* ``SimComm``   — a P-lane simulator on a single device: every per-lane array
  carries a leading ``P`` axis, local compute is ``vmap``-ed, and ppermute is
  an explicit gather. This is how tests inject failures (blank a lane,
  corrupt a lane) and exercise recovery without killable processes, with
  bit-identical numerics to the SPMD path.

Rules for code written against Comm:
  * use ``x.mT`` (never ``x.T``) so matrices batch under SimComm;
  * use ``comm.where(cond, a, b)`` for lane-dependent selects;
  * wrap per-lane subroutines in ``comm.map_local(fn)``;
  * shapes of local arrays via ``comm.local_shape(x)``.

Death-mask primitives (the FT seam; contract in DESIGN.md §8):
``where_lane`` / ``poison`` / ``fetch_lane`` express process
death and single-source REBUILD as *masked selects keyed by static lane
indices*, so the FT driver (``repro.ft.driver``) is one program that runs on
both comms. Lane arguments are Python ints (failure schedules are static
data); under AxisComm each primitive is a collective the whole axis enters,
under SimComm it is indexing on the lane axis. ``lane_axis`` names which
axis of a SimComm array is the lane axis (stored level-stacked state carries
it at position 1); AxisComm ignores it — local arrays carry no lane axis.
"""
from __future__ import annotations

from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class AxisComm:
    """Comm over a named mesh axis; use inside shard_map."""

    def __init__(self, axis_name: str):
        self.axis_name = axis_name

    def axis_size(self) -> int:
        if hasattr(jax.lax, "axis_size"):
            return jax.lax.axis_size(self.axis_name)
        # legacy jax: psum of a Python 1 over a named axis constant-folds
        # to the axis size as a Python int
        return jax.lax.psum(1, self.axis_name)

    def axis_index(self):
        return jax.lax.axis_index(self.axis_name)

    def ppermute(self, x, perm: Sequence[Tuple[int, int]]):
        return jax.lax.ppermute(x, self.axis_name, perm)

    def psum(self, x):
        return jax.lax.psum(x, self.axis_name)

    def where(self, cond, a, b):
        return jnp.where(cond, a, b)

    def map_local(self, fn: Callable) -> Callable:
        return fn

    def local_shape(self, x) -> Tuple[int, ...]:
        return tuple(x.shape)

    # -- death-mask primitives (DESIGN.md §8) -------------------------------

    def where_lane(self, lane: int, a, b, lane_axis: int = 0):
        """Lane ``lane`` sees ``a``; every other lane sees ``b``. A pure
        select — no communication. ``lane_axis`` is ignored: SPMD-local
        arrays carry no lane axis."""
        del lane_axis
        return jnp.where(self.axis_index() == lane, a, b)

    def poison(self, x, lane: int, lane_axis: int = 0):
        """Mask-based process death: NaN lane ``lane``'s value (float leaves
        only — int/bool bookkeeping is static data a respawn recomputes)."""
        if not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        return self.where_lane(lane, jnp.full_like(x, jnp.nan), x, lane_axis)

    def fetch_lane(self, x, dst: int, src: int, lane_axis: int = 0, into=None):
        """Single-source REBUILD fetch: lane ``dst``'s slot of ``into``
        (default ``x``) becomes lane ``src``'s value of ``x``; every other
        lane keeps ``into``. One point-to-point collective-permute — only
        ``src`` sends, only ``dst``'s result changes."""
        into = x if into is None else into
        got = self.ppermute(x, [(src, dst)])
        return self.where_lane(dst, got, into, lane_axis)

    def xor_reduce(self, x, lane_axis: int = 0):
        """Bitwise-XOR all-reduce of a uint8 array over the lane axis — the
        parity collective of the coded checksum lanes (``repro.ft.coding``).
        XLA has no XOR all-reduce, so it lowers as 8 bit-planes summed with
        ``psum`` mod 2 (exact: integer arithmetic). Every lane holds the
        reduced value; ``lane_axis`` is ignored (local arrays carry no lane
        axis)."""
        del lane_axis
        bits = jnp.stack([(x >> k) & jnp.uint8(1) for k in range(8)])
        bits = self.psum(bits.astype(jnp.int32)) % 2
        out = jnp.zeros(x.shape, jnp.uint8)
        for k in range(8):
            out = out | (bits[k].astype(jnp.uint8) << k)
        return out


class SimComm:
    """P-lane simulator: per-lane arrays carry a leading P axis."""

    def __init__(self, P: int):
        self.P = P

    def axis_size(self) -> int:
        return self.P

    def axis_index(self):
        return jnp.arange(self.P)

    def ppermute(self, x, perm: Sequence[Tuple[int, int]]):
        # lax.ppermute semantics: lanes that receive nothing get zeros.
        out = jnp.zeros_like(x)
        for src, dst in perm:
            out = out.at[dst].set(x[src])
        return out

    def psum(self, x):
        s = jnp.sum(x, axis=0, keepdims=True)
        return jnp.broadcast_to(s, x.shape)

    def where(self, cond, a, b):
        cond = jnp.asarray(cond)
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        ndim = max(a.ndim, b.ndim)
        if cond.ndim < ndim:
            cond = cond.reshape(cond.shape + (1,) * (ndim - cond.ndim))
        return jnp.where(cond, a, b)

    def map_local(self, fn: Callable) -> Callable:
        return jax.vmap(fn)

    def local_shape(self, x) -> Tuple[int, ...]:
        return tuple(x.shape)[1:]

    # -- death-mask primitives (DESIGN.md §8) -------------------------------

    def _lane_index(self, lane: int, lane_axis: int) -> Tuple:
        return (slice(None),) * lane_axis + (lane,)

    def where_lane(self, lane: int, a, b, lane_axis: int = 0):
        """Lane ``lane`` sees ``a``; every other lane sees ``b``.
        ``lane_axis`` locates the lane axis of the (batched) arrays."""
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        ndim = max(a.ndim, b.ndim)
        cond = (jnp.arange(self.P) == lane).reshape(
            (1,) * lane_axis + (self.P,) + (1,) * (ndim - lane_axis - 1)
        )
        return jnp.where(cond, a, b)

    def poison(self, x, lane: int, lane_axis: int = 0):
        """Mask-based process death: NaN lane ``lane``'s slice (float leaves
        only — int/bool bookkeeping is static data a respawn recomputes)."""
        if not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        return x.at[self._lane_index(lane, lane_axis)].set(jnp.nan)

    def fetch_lane(self, x, dst: int, src: int, lane_axis: int = 0, into=None):
        """Single-source REBUILD fetch: lane ``dst``'s slot of ``into``
        (default ``x``) becomes lane ``src``'s slice of ``x``; every other
        lane keeps ``into``."""
        into = x if into is None else into
        return into.at[self._lane_index(dst, lane_axis)].set(
            x[self._lane_index(src, lane_axis)]
        )

    def xor_reduce(self, x, lane_axis: int = 0):
        """Bitwise-XOR reduction over the lane axis (``repro.ft.coding``'s
        parity collective). The lane axis is reduced away: the parity is a
        checksum-lane value with no per-lane copy (the AxisComm counterpart
        returns the reduced value replicated on every lane — the same
        global object in both layouts)."""
        return jax.lax.reduce(x, np.uint8(0), jax.lax.bitwise_xor,
                              (lane_axis,))

    def lane_slice(self, x, lane: int, lane_axis: int = 0):
        """Host-side extraction of one lane's slice of a batched array.
        Simulator-only (the SPMD path has no global view inside the
        program): the orchestrator's speculative straggler recompute uses
        it to bitwise-compare a rebuilt lane slice against the original
        (``repro.ft.stragglers``)."""
        return x[self._lane_index(lane, lane_axis)]
