"""Communication abstraction: one SPMD code path, two executions.

The paper's algorithms are written as per-process (per-lane) SPMD programs
with pairwise exchanges. We express them once against this small ``Comm``
interface and run them two ways:

* ``AxisComm``  — inside ``jax.shard_map`` over a named mesh axis; collectives
  lower to real ICI ``collective-permute`` / ``all-reduce`` ops. This is the
  production path (and the dry-run path).

* ``SimComm``   — a P-lane simulator on a single device: every per-lane array
  carries a leading ``P`` axis, local compute is ``vmap``-ed, and ppermute is
  an explicit gather. This is how tests inject failures (blank a lane,
  corrupt a lane) and exercise recovery without killable processes, with
  bit-identical numerics to the SPMD path.

Rules for code written against Comm:
  * use ``x.mT`` (never ``x.T``) so matrices batch under SimComm;
  * use ``comm.where(cond, a, b)`` for lane-dependent selects;
  * wrap per-lane subroutines in ``comm.map_local(fn)``;
  * shapes of local arrays via ``comm.local_shape(x)``.
"""
from __future__ import annotations

from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp


class AxisComm:
    """Comm over a named mesh axis; use inside shard_map."""

    def __init__(self, axis_name: str):
        self.axis_name = axis_name

    def axis_size(self) -> int:
        return jax.lax.axis_size(self.axis_name)

    def axis_index(self):
        return jax.lax.axis_index(self.axis_name)

    def ppermute(self, x, perm: Sequence[Tuple[int, int]]):
        return jax.lax.ppermute(x, self.axis_name, perm)

    def psum(self, x):
        return jax.lax.psum(x, self.axis_name)

    def where(self, cond, a, b):
        return jnp.where(cond, a, b)

    def map_local(self, fn: Callable) -> Callable:
        return fn

    def local_shape(self, x) -> Tuple[int, ...]:
        return tuple(x.shape)


class SimComm:
    """P-lane simulator: per-lane arrays carry a leading P axis."""

    def __init__(self, P: int):
        self.P = P

    def axis_size(self) -> int:
        return self.P

    def axis_index(self):
        return jnp.arange(self.P)

    def ppermute(self, x, perm: Sequence[Tuple[int, int]]):
        # lax.ppermute semantics: lanes that receive nothing get zeros.
        out = jnp.zeros_like(x)
        for src, dst in perm:
            out = out.at[dst].set(x[src])
        return out

    def psum(self, x):
        s = jnp.sum(x, axis=0, keepdims=True)
        return jnp.broadcast_to(s, x.shape)

    def where(self, cond, a, b):
        cond = jnp.asarray(cond)
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        ndim = max(a.ndim, b.ndim)
        if cond.ndim < ndim:
            cond = cond.reshape(cond.shape + (1,) * (ndim - cond.ndim))
        return jnp.where(cond, a, b)

    def map_local(self, fn: Callable) -> Callable:
        return jax.vmap(fn)

    def local_shape(self, x) -> Tuple[int, ...]:
        return tuple(x.shape)[1:]
