"""Least-squares on top of FT-CAQR: min ||Ax - b||.

x = R1^{-1} (Q^T b)[:k], k = min(m, n) — the implicit Q^T is replayed from
the stored panel factors (the same machinery the trailing update uses), so
the solve inherits the factorization's fault tolerance: a lane lost during
the apply is recoverable from its buddy's bundle exactly as in the
factorization.

General shapes follow the factorization's ``sweep_geometry``:

* tall/ragged (m >= n): the unique least-squares solution (A full rank).
* wide (m < n, A = Q [R1 R2]): the *basic* solution — ``x = [x1; 0]`` with
  ``R1 x1 = (Q^T b)[:m]``. For a full-row-rank A this solves ``A x = b``
  exactly (zero residual), but it is NOT the minimum-norm solution (that
  would need a second factorization of A^T / an LQ); the trailing ``n - m``
  components are pinned to zero. Documented in DESIGN.md §7.

Rank-deficient A is out of contract (the triangular solve would divide by a
~0 diagonal), matching ``caqr_factorize``'s unpivoted Householder sweep.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.caqr import (
    CAQRResult,
    caqr_apply_qt,
    caqr_factorize,
    sweep_geometry,
)
from repro.core.comm import SimComm


def caqr_lstsq(
    A_local: jax.Array,
    b_local: jax.Array,
    comm,
    panel_width: int,
    result: Optional[CAQRResult] = None,
):
    """Solve min ||Ax - b|| for the block-row-distributed (A, b).

    A_local: (m_loc, n) per lane; b_local: (m_loc, k). Returns x (n, k),
    replicated (computed from the replicated R and the gathered Q^T b rows).

    ``result``: optional precomputed ``caqr_factorize(A_local, comm,
    panel_width)`` output — pass it to reuse one factorization across many
    right-hand sides instead of re-factorizing from scratch per solve.
    """
    m_loc, n = comm.local_shape(A_local)
    P = comm.axis_size()
    geom = sweep_geometry(P, m_loc, n, panel_width)
    if result is None:
        result = caqr_factorize(A_local, comm, panel_width)
    assert result.factors.leaf_T.shape[-1] == panel_width, \
        "precomputed result was factorized at a different panel width"
    assert result.factors.leaf_Y.shape[-2] == geom.m_loc_pad and \
        result.R.shape[-2:] == (geom.k, n), \
        "precomputed result was factorized at a different geometry"
    Qtb = caqr_apply_qt(b_local, result.factors, comm)  # padded-row layout

    # The k rows of Q^T b pairing with R deposit at each panel's target lane:
    # R row r lives at lane r // m_loc_pad, local row r % m_loc_pad (padded
    # geometry guarantees row_start is never clipped, so deposits sit at
    # their natural padded global row). One vectorized masked scatter per
    # lane + a single psum collects them all — no per-panel gather loop.
    K, m_pad = geom.k, geom.m_loc_pad
    idx = comm.axis_index()

    def collect(Q, i):
        r_global = i * m_pad + jnp.arange(m_pad)
        in_range = r_global < K
        vals = jnp.where(in_range[:, None], Q, jnp.zeros_like(Q))
        out = jnp.zeros((K, Q.shape[-1]), Q.dtype)
        return out.at[jnp.clip(r_global, 0, K - 1)].add(vals)

    Qtb_top = comm.psum(comm.map_local(collect)(Qtb, idx))  # (K, rhs)
    if isinstance(comm, SimComm):
        Qtb_top = Qtb_top[0]
        R = result.R[0]
    else:
        R = result.R
    # R is (K, n): R1 = leading K x K triangle; for wide problems the R2
    # columns take the basic solution's zero coefficients (see module doc).
    x1 = jax.scipy.linalg.solve_triangular(R[:, :K], Qtb_top, lower=False)
    if n > K:
        x1 = jnp.concatenate(
            [x1, jnp.zeros((n - K, x1.shape[-1]), x1.dtype)], axis=0
        )
    return x1
