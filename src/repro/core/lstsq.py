"""Least-squares on top of FT-CAQR: min ||Ax - b||.

x = R^{-1} (Q^T b)[:n] — the implicit Q^T is replayed from the stored panel
factors (the same machinery the trailing update uses), so the solve inherits
the factorization's fault tolerance: a lane lost during the apply is
recoverable from its buddy's bundle exactly as in the factorization.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.caqr import CAQRResult, caqr_apply_qt, caqr_factorize
from repro.core.comm import SimComm


def caqr_lstsq(A_local: jax.Array, b_local: jax.Array, comm, panel_width: int):
    """Solve min ||Ax - b|| for the block-row-distributed (A, b).

    A_local: (m_loc, n) per lane; b_local: (m_loc, k). Returns x (n, k),
    replicated (computed from the replicated R and the gathered Q^T b rows).
    """
    res: CAQRResult = caqr_factorize(A_local, comm, panel_width)
    Qtb = caqr_apply_qt(b_local, res.factors, comm)
    # The n rows of Q^T b corresponding to R live at each panel's target
    # lane's deposit rows — identical bookkeeping to the R collection: they
    # are the first b rows (per panel) of the virtual result. Re-collect them
    # exactly as caqr_factorize collected R rows: psum of the target lane's
    # deposit block per panel. For simplicity we reuse the replay: the
    # deposits sit at (target lane t, rows [row_start, row_start + b)).
    m_loc = comm.local_shape(A_local)[0]
    n = comm.local_shape(A_local)[1]
    b = panel_width
    n_panels = n // b
    idx = comm.axis_index()

    rows = []
    for kpanel in range(n_panels):
        t = (kpanel * b) // m_loc
        rs = kpanel * b - t * m_loc

        def grab(Q, i):
            blk = jax.lax.dynamic_slice_in_dim(Q, rs, b, axis=0)
            return jnp.where(i == t, blk, jnp.zeros_like(blk))

        blk = comm.map_local(grab)(Qtb, idx)
        rows.append(comm.psum(blk))
    if isinstance(comm, SimComm):
        Qtb_top = jnp.concatenate([r[0] for r in rows], axis=0)  # (n, k)
        R = res.R[0]
    else:
        Qtb_top = jnp.concatenate(rows, axis=0)
        R = res.R
    x = jax.scipy.linalg.solve_triangular(R, Qtb_top, lower=False)
    return x
