"""Fault-tolerant execution driver for the windowed CAQR sweep (paper §II-III).

This is the end-to-end form of the paper's claim: run the *entire* windowed
right-looking FT-CAQR sweep while lanes die at scheduled points — at any
panel, after any TSQR butterfly level or trailing-combine level — and finish
with ``R``, the per-panel implicit-Q factors, and the recovery bundles
**bit-identical** to the failure-free run (the recovery regression oracle).

Execution model (DESIGN.md §8)
------------------------------
The driver is ONE Comm-generic program (``repro.core.comm``) that runs two
ways:

* ``SimComm``  — the P-lane single-device simulator: eager, level-stepped,
  with wall-clock REBUILD latency per event. This is the test/debug path.
* ``AxisComm`` — inside ``jax.shard_map`` over a device mesh: the production
  SPMD path the paper describes, one real process per lane. The entrypoint
  is ``repro.launch.spmd_qr.ft_caqr_sweep_spmd``.

Death and recovery are expressed through the Comm death-mask primitives
(``comm.poison`` / ``comm.fetch_lane`` / ``comm.where_lane``): the schedule
is static Python data, so "kill lane 2 after panel 1's level-0 trailing
combine" compiles to a masked NaN-write on both paths, and every REBUILD
fetch is a point-to-point collective keyed by static lane indices. The
driver calls the *same* single-level primitives the production sweep is
built from: ``ft_tsqr_level`` (core/tsqr), ``trailing_combine_level`` and
``_leaf_apply``/``_writeback`` (core/trailing), and the geometry/assembly
helpers of ``core/caqr``. Failure-free, the two paths are the same
floating-point program, so bit-identity holds by construction; under
failures it is regression-gated by ``tests/test_spmd_ft_driver.py``.

Failure model (paper §II, ULFM REBUILD semantics)
-------------------------------------------------
A ``FailureSchedule`` keyed by ``sweep_point(panel, phase, level)`` kills
lanes at interruptible points; death is *simulated faithfully*: every float
the lane holds — its block-row, leaf/ladder factors, C', stored per-panel
factors and bundles — is overwritten with NaN, so any read of dead state
poisons the result and the bit-identity oracle catches it.

Recovery (paper §III-B/III-C REBUILD)
-------------------------------------
The respawned lane is rebuilt from (a) its own slice of the *initial*
matrix, re-read from the data source, and (b) per lost artifact, the state
of exactly ONE surviving lane — its XOR-buddy at the relevant tree level:

* previous panels — leaf factors are *recomputed* from the re-read rows
  (never fetched; they are lane-private), the final C' of each panel comes
  from the last-level buddy's bundle ``{W, T, C', Y2, role}``, and the
  lane's own bundle rows are mirrors of each level-buddy's
  (``W`` is pair-shared, ``C_self``/``C_buddy`` swap);
* current panel, mid-TSQR — the butterfly ladder ``(Y2, T)`` and the running
  R are identical at the level-0 buddy (lanes ``i`` and ``i^1`` agree at
  every level: same pair at level 0, same ``i >> (s+1)`` group above), so
  one copy restores them;
* current panel, mid-trailing — C' after the last completed level ``s`` is
  rebuilt from the level-``s`` buddy's bundle by replaying the pair combine
  through ``_combine`` (the same kernel-dispatch seam as the failure-free
  path) and keeping the failed side.

Each rebuilt artifact therefore reads ONE survivor (recorded in the event's
ledger — the single-source property is enforced by construction); a full
mid-sweep rebuild touches at most ``log2 P`` distinct survivors across
artifact classes. If a needed buddy is itself dead (e.g. both members of a
pair killed at the same point), ``UnrecoverableFailure`` is raised — that is
the honest limit of one-level redundancy doubling. Under shard_map the
schedule is validated at trace time, so an unrecoverable schedule fails
before any device computes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import recovery as rec
from repro.core.caqr import (
    PanelFactors,
    advance_columns,
    assemble_R,
    extract_r_rows,
    lane_geometry,
    make_panel_factors,
    pad_bundle,
    pad_to_geometry,
    panel_geometry,
    sweep_geometry,
)
from repro.core.comm import SimComm
from repro.core.householder import apply_qt, householder_qr_masked
from repro.core.tsqr import DistTSQRFactors, _levels, ft_tsqr_level
from repro.core.trailing import (
    RecoveryBundle,
    _leaf_apply,
    _writeback,
    trailing_combine_level,
)
from repro.ft.failures import (
    Detector,
    FailureSchedule,
    PHASE_LEAF,
    PHASE_TRAILING,
    PHASE_TSQR,
    UnrecoverableFailure,
    sweep_point,
)


@dataclasses.dataclass
class RecoveryEvent:
    """One REBUILD: which lane died where, and the single-source read ledger
    (artifact name -> the one surviving lane it was fetched from).

    ``elapsed_s`` is wall-clock REBUILD latency under the eager SimComm path;
    under shard_map the whole sweep is one traced program, so it records
    trace time only (use ``benchmarks/bench_spmd.py`` for SPMD REBUILD cost).
    """

    point: Tuple[int, str, int]
    lane: int
    reads: Dict[str, int]
    elapsed_s: float

    @property
    def sources(self) -> List[int]:
        return sorted(set(self.reads.values()))


class FTSweepResult(NamedTuple):
    """Same layout as ``CAQRResult(collect_bundles=True)`` plus the recovery
    event log."""

    R: jax.Array
    factors: PanelFactors
    bundles: RecoveryBundle
    events: List[RecoveryEvent]


class FTSweepDriver:
    """Level-stepped windowed CAQR sweep with failure injection + REBUILD.

    Comm-generic (paper §II execution model; DESIGN.md §8): under ``SimComm``
    lanes are simulator slices of single-device arrays; under ``AxisComm``
    (inside ``shard_map``) each lane is a real device and every kill/fetch
    is a masked collective. The two paths run the same floating-point
    program and produce bit-identical results.

    ``A0`` is the initial matrix — SimComm layout ``(P, m_loc, n)``, per-lane
    ``(m_loc, n)`` under AxisComm — and doubles as the re-readable data
    source of the paper's recovery model. Any shape ``caqr_factorize``
    accepts is accepted here: the driver runs at the same padded
    ``sweep_geometry``, and a respawned lane re-reads its *padded* initial
    slice (re-reading the raw slice and re-padding is the same thing — the
    pad is static zeros, not lost state), so every REBUILD stays
    single-source and the outputs stay bit-identical to the failure-free
    general-shape sweep.
    """

    def __init__(
        self,
        A0: jax.Array,
        comm,
        panel_width: int,
        schedule: Optional[FailureSchedule] = None,
        detector: Optional[Detector] = None,
    ):
        self.comm = comm
        self.P = comm.axis_size()
        # SimComm runs eagerly (lane kills between real dispatches, timed
        # REBUILDs); AxisComm traces the whole sweep into one program, so
        # device syncs / wall clocks are meaningless there.
        self._eager = isinstance(comm, SimComm)
        self.levels = _levels(self.P)
        assert self.levels >= 1, "need at least 2 lanes to tolerate failures"
        self.b = panel_width
        m_loc, n = comm.local_shape(A0)
        self.geom = sweep_geometry(self.P, m_loc, n, self.b)
        # the sweep (and every REBUILD replay) runs at the padded geometry
        self.m_loc, self.n = self.geom.m_loc_pad, self.geom.n_work
        self.n_panels = self.geom.n_panels
        self.A0 = pad_to_geometry(comm, A0, self.geom)
        self.A = self.A0
        self.detector = detector or Detector(self.P, schedule)
        # stored sweep outputs, one entry per completed panel
        self.factors: List[PanelFactors] = []
        self.R_rows: List[jax.Array] = []
        self.bundles: List[RecoveryBundle] = []
        self.events: List[RecoveryEvent] = []

    # -- sweep -------------------------------------------------------------

    def run(self) -> FTSweepResult:
        for k in range(self.n_panels):
            self._run_panel(k)
        factors = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *self.factors)
        bundles = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *self.bundles)
        R = assemble_R(self.comm, jnp.stack(self.R_rows), self.geom)
        return FTSweepResult(R=R, factors=factors, bundles=bundles,
                             events=self.events)

    def _run_panel(self, k: int) -> None:
        comm, b = self.comm, self.b
        col0, t_lane, row_start, active = panel_geometry(comm, k, b, self.m_loc)
        self._k, self._col0, self._t_lane = k, col0, t_lane
        # in-flight per-panel state (what a mid-panel death obliterates)
        self._window = comm.map_local(lambda A: A[:, col0:])(self.A)
        self._R_carry = None
        self._Y2s: List[jax.Array] = []
        self._Ts: List[jax.Array] = []
        self._level_Y2 = self._level_T = None
        self._C_local = self._C_prime = None
        self._Ws: List[jax.Array] = []
        self._Cs_self: List[jax.Array] = []
        self._Cs_buddy: List[jax.Array] = []
        self._tops: List[jax.Array] = []

        # leaf: local masked panel QR
        panel = comm.map_local(lambda W: W[:, :b])(self._window)
        wy = comm.map_local(householder_qr_masked)(panel, row_start)
        self._leaf_Y = comm.where(active, wy.Y, jnp.zeros_like(wy.Y))
        self._leaf_T = comm.where(active, wy.T, jnp.zeros_like(wy.T))
        self._R_leaf = comm.where(active, wy.R, jnp.zeros_like(wy.R))
        self._checkpoint(sweep_point(k, PHASE_LEAF))

        # FT-TSQR butterfly, one checkpoint per level
        self._R_carry = self._R_leaf
        for s in range(self.levels):
            R_next, Y2, T = ft_tsqr_level(comm, self._R_carry, s, t_lane, t_lane)
            self._R_carry = R_next
            self._Y2s.append(Y2)
            self._Ts.append(T)
            self._checkpoint(sweep_point(k, PHASE_TSQR, s))
        self._level_Y2 = jnp.stack(self._Y2s)
        self._level_T = jnp.stack(self._Ts)

        # trailing update (Algorithm 2), one checkpoint per level
        dist = DistTSQRFactors(self._leaf_Y, self._leaf_T, self._level_Y2,
                               self._level_T, self._R_leaf)
        C_local, C_prime = _leaf_apply(comm, dist, self._window, row_start,
                                       active=active, skip_consumed=True)
        self._C_local = C_local
        self._C_prime = comm.where(active, C_prime, jnp.zeros_like(C_prime))
        for s in range(self.levels):
            out = trailing_combine_level(
                comm, self._C_prime, self._level_Y2[s], self._level_T[s],
                s, t_lane, t_lane,
            )
            self._Ws.append(out.W)
            self._Cs_self.append(out.C_self)
            self._Cs_buddy.append(out.C_buddy)
            self._tops.append(out.is_top)
            self._C_prime = out.C_prime
            self._checkpoint(sweep_point(k, PHASE_TRAILING, s))

        # writeback + panel outputs (the windowed sweep's own deposit helpers)
        C_out = _writeback(comm, self._C_local, self._C_prime, row_start, active)
        self.A = advance_columns(comm, self.A, C_out, col0)
        self.R_rows.append(extract_r_rows(comm, self._C_prime, t_lane, col0))
        self.bundles.append(pad_bundle(RecoveryBundle(
            W=jnp.stack(self._Ws),
            C_self=jnp.stack(self._Cs_self),
            C_buddy=jnp.stack(self._Cs_buddy),
            Y2=self._level_Y2,
            T=self._level_T,
            self_was_top=jnp.stack(self._tops),
        ), col0))
        self.factors.append(make_panel_factors(
            comm, self._leaf_Y, self._leaf_T, self._level_Y2, self._level_T,
            row_start, active, t_lane,
        ))

    # -- failure injection + REBUILD ---------------------------------------

    def _checkpoint(self, point: Tuple[int, str, int]) -> None:
        newly = self.detector.begin_step(point)
        for lane in newly:          # all deaths at this point strike first,
            self._obliterate(lane)  # then recovery runs one lane at a time
        for lane in newly:
            # drain the async-dispatched sweep prefix first, so the latency
            # clock covers only the REBUILD itself (then everything the
            # rebuild patched); no-op under tracing
            if self._eager:
                self._sync()
            t0 = time.perf_counter()
            reads = self._rebuild(lane, point)
            if self._eager:
                self._sync()
            self.detector.revive(lane)
            self.events.append(RecoveryEvent(
                point=point, lane=lane, reads=reads,
                elapsed_s=time.perf_counter() - t0,
            ))

    def _sync(self) -> None:
        jax.block_until_ready([
            x for x in (
                self.A, self._window, self._leaf_Y, self._leaf_T,
                self._R_leaf, self._R_carry, self._level_Y2, self._level_T,
                self._C_local, self._C_prime,
                *self._Y2s, *self._Ts, *self._Ws, *self._Cs_self,
                *self._Cs_buddy, *self.factors, *self.bundles, *self.R_rows,
            ) if x is not None
        ])

    def _obliterate(self, lane: int) -> None:
        """Process death, mask-form: NaN every float the lane holds — current
        block-row, in-flight panel state, and its slices of all stored sweep
        outputs (``comm.poison`` — an at-set under SimComm, a masked select
        on the lane's own device under shard_map)."""
        poison = self.comm.poison
        self.A = poison(self.A, lane)
        self._window = poison(self._window, lane)
        self._leaf_Y = poison(self._leaf_Y, lane)
        self._leaf_T = poison(self._leaf_T, lane)
        self._R_leaf = poison(self._R_leaf, lane)
        if self._R_carry is not None:
            self._R_carry = poison(self._R_carry, lane)
        self._Y2s = [poison(x, lane) for x in self._Y2s]
        self._Ts = [poison(x, lane) for x in self._Ts]
        if self._level_Y2 is not None:
            self._level_Y2 = poison(self._level_Y2, lane, lane_axis=1)
            self._level_T = poison(self._level_T, lane, lane_axis=1)
        if self._C_local is not None:
            self._C_local = poison(self._C_local, lane)
            self._C_prime = poison(self._C_prime, lane)
        self._Ws = [poison(x, lane) for x in self._Ws]
        self._Cs_self = [poison(x, lane) for x in self._Cs_self]
        self._Cs_buddy = [poison(x, lane) for x in self._Cs_buddy]
        for j in range(len(self.factors)):
            fj = self.factors[j]
            self.factors[j] = PanelFactors(
                leaf_Y=poison(fj.leaf_Y, lane),
                leaf_T=poison(fj.leaf_T, lane),
                level_Y2=poison(fj.level_Y2, lane, lane_axis=1),
                level_T=poison(fj.level_T, lane, lane_axis=1),
                row_start=fj.row_start, active=fj.active, target=fj.target,
            )
            bj = self.bundles[j]
            self.bundles[j] = RecoveryBundle(
                W=poison(bj.W, lane, lane_axis=1),
                C_self=poison(bj.C_self, lane, lane_axis=1),
                C_buddy=poison(bj.C_buddy, lane, lane_axis=1),
                Y2=poison(bj.Y2, lane, lane_axis=1),
                T=poison(bj.T, lane, lane_axis=1),
                self_was_top=bj.self_was_top,
            )
            self.R_rows[j] = poison(self.R_rows[j], lane)

    def _rebuild(self, lane: int, point: Tuple[int, str, int]) -> Dict[str, int]:
        """The paper's REBUILD: respawn ``lane``, re-read its initial slice,
        replay completed panels, restore the in-flight panel state — each
        lost artifact from exactly one surviving buddy.

        Comm-generic expression: replay arithmetic runs per lane through
        ``comm.map_local`` at the dead lane's *static* geometry (under SPMD
        every lane runs the same program; survivors' replay results are
        discarded by the final ``where_lane`` masks — under SimComm the vmap
        computes the same discarded slots), and every buddy read is a
        ``fetch_lane``/``ppermute`` keyed by static lane indices, so exactly
        one survivor sends per artifact on the production path too."""
        comm = self.comm
        reads: Dict[str, int] = {}

        def fetch(artifact: str, source: int) -> int:
            if source == lane or source in self.detector.dead:
                raise UnrecoverableFailure(
                    f"rebuilding lane {lane} at {point} needs {artifact} "
                    f"from lane {source}, which is not a live survivor"
                )
            reads[artifact] = source
            return source

        k = self._k
        # respawn: every lane re-reads its own slice of the data source; only
        # the dead lane's replay survives the rebuild's masked writes
        rows = self.A0
        for j in range(k):
            rows = self._replay_panel(j, lane, rows, fetch)

        # current panel: recompute the masked leaf from the rebuilt rows
        col0, t_lane, rs, act = lane_geometry(k, self.b, self.m_loc, lane)
        lY, lT, lR = comm.map_local(
            lambda r: rec.recompute_leaf(r, col0, self.b, rs, act)
        )(rows)
        self._leaf_Y = comm.where_lane(lane, lY, self._leaf_Y)
        self._leaf_T = comm.where_lane(lane, lT, self._leaf_T)
        self._R_leaf = comm.where_lane(lane, lR, self._R_leaf)
        self.A = comm.where_lane(lane, rows, self.A)
        self._window = comm.where_lane(
            lane, comm.map_local(lambda r: r[:, col0:])(rows), self._window
        )

        _, phase, lvl = point
        if phase == PHASE_TSQR:
            # ladder + running R: identical at the level-0 buddy (see module
            # docstring) — one copy restores all completed levels
            src = fetch("tsqr.ladder+R", lane ^ 1)
            for i in range(lvl + 1):
                self._Y2s[i] = comm.fetch_lane(self._Y2s[i], lane, src)
                self._Ts[i] = comm.fetch_lane(self._Ts[i], lane, src)
            self._R_carry = comm.fetch_lane(self._R_carry, lane, src)
        elif phase == PHASE_TRAILING:
            src = fetch("tsqr.ladder", lane ^ 1)
            self._level_Y2 = comm.fetch_lane(
                self._level_Y2, lane, src, lane_axis=1)
            self._level_T = comm.fetch_lane(
                self._level_T, lane, src, lane_axis=1)
            # leaf-applied window: local recompute through the same seam
            self._C_local = comm.where_lane(
                lane,
                comm.map_local(
                    lambda Y, T, r: apply_qt(Y, T, r[:, col0:])
                )(lY, lT, rows),
                self._C_local,
            )
            # C' after the last completed level: ONE fetch from that level's
            # buddy, replayed through the seam-routed pair combine
            src_c = fetch(f"trailing.cprime@level{lvl}", lane ^ (1 << lvl))
            failed_was_top = ((lane >> lvl) & 1) == ((t_lane >> lvl) & 1)
            pair_live = lane >= t_lane and src_c >= t_lane
            recv = lambda x: comm.ppermute(x, [(src_c, lane)])
            cp = comm.map_local(
                lambda cb, cs, y2, t: rec.rebuild_cprime_after_level(
                    cb, cs, y2, t, failed_was_top, pair_live)
            )(recv(self._Cs_buddy[lvl]), recv(self._Cs_self[lvl]),
              self._level_Y2[lvl], self._level_T[lvl])
            self._C_prime = comm.where_lane(lane, cp, self._C_prime)
            # the lane's own bundle rows: mirror of each level-buddy's entry
            # (W is pair-shared; C_self/C_buddy swap sides)
            for s in range(lvl + 1):
                src_s = fetch(f"trailing.bundle@level{s}", lane ^ (1 << s))
                new_w = comm.fetch_lane(self._Ws[s], lane, src_s)
                new_cs = comm.fetch_lane(
                    self._Cs_buddy[s], lane, src_s, into=self._Cs_self[s])
                new_cb = comm.fetch_lane(
                    self._Cs_self[s], lane, src_s, into=self._Cs_buddy[s])
                self._Ws[s], self._Cs_self[s], self._Cs_buddy[s] = (
                    new_w, new_cs, new_cb)
        return reads

    def _replay_panel(self, j: int, lane: int, rows: jax.Array, fetch) -> jax.Array:
        """Advance the respawned lane's block-row through completed panel
        ``j`` and restore its slices of that panel's stored outputs."""
        comm, L = self.comm, self.levels
        col0, t_lane, rs, act = lane_geometry(j, self.b, self.m_loc, lane)
        lY, lT, _lR = comm.map_local(
            lambda r: rec.recompute_leaf(r, col0, self.b, rs, act)
        )(rows)

        src_l = fetch(f"panel{j}.tsqr_ladder", lane ^ 1)
        fj = self.factors[j]
        self.factors[j] = PanelFactors(
            leaf_Y=comm.where_lane(lane, lY, fj.leaf_Y),
            leaf_T=comm.where_lane(lane, lT, fj.leaf_T),
            level_Y2=comm.fetch_lane(fj.level_Y2, lane, src_l, lane_axis=1),
            level_T=comm.fetch_lane(fj.level_T, lane, src_l, lane_axis=1),
            row_start=fj.row_start, active=fj.active, target=fj.target,
        )
        src_r = fetch(f"panel{j}.r_rows", lane ^ 1)
        self.R_rows[j] = comm.fetch_lane(self.R_rows[j], lane, src_r)

        # final C' of panel j: one fetch from the last-level buddy's bundle.
        # Indexing the leading LEVEL axis first leaves per-lane layout on
        # both comms (SimComm keeps the lane axis in front, AxisComm is
        # already local), so the replayed combine is one expression.
        bj = self.bundles[j]
        if act:
            src_c = fetch(f"panel{j}.cprime_final", lane ^ (1 << (L - 1)))
            failed_was_top = ((lane >> (L - 1)) & 1) == ((t_lane >> (L - 1)) & 1)
            pair_live = lane >= t_lane and (lane ^ (1 << (L - 1))) >= t_lane
            recv = lambda x: comm.ppermute(x, [(src_c, lane)])
            # stored bundles are zero-padded to full width; slice back to the
            # live window so the replayed combine runs at the original width
            cp = comm.map_local(
                lambda cb, cs, y2, t: rec.rebuild_cprime_after_level(
                    cb, cs, y2, t, failed_was_top, pair_live)
            )(recv(bj.C_buddy[L - 1][..., col0:]),
              recv(bj.C_self[L - 1][..., col0:]),
              recv(bj.Y2[L - 1]), recv(bj.T[L - 1]))
            rows = comm.map_local(
                lambda r, y, t, c: rec.rebuild_block_row_through_panel(
                    r, y, t, c, col0, rs, act)
            )(rows, lY, lT, cp)
        else:
            rows = comm.map_local(
                lambda r, y, t: rec.rebuild_block_row_through_panel(
                    r, y, t, None, col0, rs, act)
            )(rows, lY, lT)

        # the lane's own bundle rows for panel j: per-level mirrors, written
        # level-sliced (leading axis) and re-stacked so the same code drives
        # both comm layouts
        W_lv = [bj.W[s] for s in range(L)]
        Cs_lv = [bj.C_self[s] for s in range(L)]
        Cb_lv = [bj.C_buddy[s] for s in range(L)]
        for s in range(L):
            src_s = fetch(f"panel{j}.bundle@level{s}", lane ^ (1 << s))
            W_lv[s] = comm.fetch_lane(bj.W[s], lane, src_s)
            Cs_lv[s] = comm.fetch_lane(bj.C_buddy[s], lane, src_s, into=Cs_lv[s])
            Cb_lv[s] = comm.fetch_lane(bj.C_self[s], lane, src_s, into=Cb_lv[s])
        self.bundles[j] = RecoveryBundle(
            W=jnp.stack(W_lv), C_self=jnp.stack(Cs_lv), C_buddy=jnp.stack(Cb_lv),
            Y2=comm.fetch_lane(bj.Y2, lane, src_l, lane_axis=1),
            T=comm.fetch_lane(bj.T, lane, src_l, lane_axis=1),
            self_was_top=bj.self_was_top,
        )
        return rows


def ft_caqr_sweep(
    A0: jax.Array,
    comm,
    panel_width: int,
    schedule: Optional[FailureSchedule] = None,
) -> FTSweepResult:
    """Run the full windowed FT-CAQR sweep under a failure schedule
    (paper §II-III end to end).

    Returns ``(R, factors, bundles, events)`` — bit-identical to
    ``caqr_factorize(A0, comm, panel_width, collect_bundles=True,
    use_scan=False)`` regardless of the schedule (the paper's recovery
    guarantee), with one ``RecoveryEvent`` per REBUILD.

    ``comm`` selects the execution: ``SimComm(P)`` for the single-device
    simulator, ``AxisComm(axis)`` inside ``shard_map`` for the production
    SPMD path (use ``repro.launch.spmd_qr.ft_caqr_sweep_spmd`` which wires
    the mesh and output layouts).

    Example (simulator; kill lane 1 after panel 0's level-0 trailing
    combine, recover, and match the failure-free sweep bit for bit):

    >>> import numpy as np, jax.numpy as jnp
    >>> from repro.core import SimComm, caqr_factorize
    >>> from repro.ft import FailureSchedule, ft_caqr_sweep, sweep_point
    >>> A = jnp.asarray(np.random.default_rng(0).standard_normal((2, 4, 4)),
    ...                 jnp.float32)
    >>> sched = FailureSchedule(events={sweep_point(0, "trailing", 0): [1]})
    >>> out = ft_caqr_sweep(A, SimComm(2), 4, schedule=sched)
    >>> ref = caqr_factorize(A, SimComm(2), 4, collect_bundles=True,
    ...                      use_scan=False)
    >>> bool(jnp.array_equal(out.R, ref.R))
    True
    >>> [(e.point, e.lane) for e in out.events]
    [((0, 'trailing', 0), 1)]
    """
    return FTSweepDriver(A0, comm, panel_width, schedule).run()
