"""Fault-tolerant execution driver for the windowed CAQR sweep (paper §II-III).

This is the end-to-end form of the paper's claim: run the *entire* windowed
right-looking FT-CAQR sweep while lanes die at scheduled points — at any
panel, after any TSQR butterfly level or trailing-combine level — and finish
with ``R``, the per-panel implicit-Q factors, and the recovery bundles
**bit-identical** to the failure-free run (the recovery regression oracle).

Execution model
---------------
The driver runs the sweep level-stepped over a ``SimComm`` (the P-lane
single-device simulator — the only place lanes are killable without real
processes), calling the *same* single-level primitives the production sweep
is built from: ``ft_tsqr_level`` (core/tsqr), ``trailing_combine_level`` and
``_leaf_apply``/``_writeback`` (core/trailing), and the geometry/assembly
helpers of ``core/caqr``. Failure-free, the two paths are the same
floating-point program, so bit-identity holds by construction.

Failure model (paper §II, ULFM REBUILD semantics)
-------------------------------------------------
A ``FailureSchedule`` keyed by ``sweep_point(panel, phase, level)`` kills
lanes at interruptible points; death is *simulated faithfully*: every float
the lane holds — its block-row, leaf/ladder factors, C', stored per-panel
factors and bundles — is overwritten with NaN, so any read of dead state
poisons the result and the bit-identity oracle catches it.

Recovery (paper §III-B/III-C REBUILD)
-------------------------------------
The respawned lane is rebuilt from (a) its own slice of the *initial*
matrix, re-read from the data source, and (b) per lost artifact, the state
of exactly ONE surviving lane — its XOR-buddy at the relevant tree level:

* previous panels — leaf factors are *recomputed* from the re-read rows
  (never fetched; they are lane-private), the final C' of each panel comes
  from the last-level buddy's bundle ``{W, T, C', Y2, role}``, and the
  lane's own bundle rows are mirrors of each level-buddy's
  (``W`` is pair-shared, ``C_self``/``C_buddy`` swap);
* current panel, mid-TSQR — the butterfly ladder ``(Y2, T)`` and the running
  R are identical at the level-0 buddy (lanes ``i`` and ``i^1`` agree at
  every level: same pair at level 0, same ``i >> (s+1)`` group above), so
  one copy restores them;
* current panel, mid-trailing — C' after the last completed level ``s`` is
  rebuilt from the level-``s`` buddy's bundle by replaying the pair combine
  through ``_combine`` (the same kernel-dispatch seam as the failure-free
  path) and keeping the failed side.

Each rebuilt artifact therefore reads ONE survivor (recorded in the event's
ledger — the single-source property is enforced by construction); a full
mid-sweep rebuild touches at most ``log2 P`` distinct survivors across
artifact classes. If a needed buddy is itself dead (e.g. both members of a
pair killed at the same point), ``UnrecoverableFailure`` is raised — that is
the honest limit of one-level redundancy doubling.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import recovery as rec
from repro.core.caqr import (
    PanelFactors,
    advance_columns,
    assemble_R,
    extract_r_rows,
    lane_geometry,
    make_panel_factors,
    pad_bundle,
    pad_to_geometry,
    panel_geometry,
    sweep_geometry,
)
from repro.core.comm import SimComm
from repro.core.householder import apply_qt, householder_qr_masked
from repro.core.tsqr import DistTSQRFactors, _levels, ft_tsqr_level
from repro.core.trailing import (
    RecoveryBundle,
    _leaf_apply,
    _writeback,
    trailing_combine_level,
)
from repro.ft.failures import (
    Detector,
    FailureSchedule,
    PHASE_LEAF,
    PHASE_TRAILING,
    PHASE_TSQR,
    UnrecoverableFailure,
    sweep_point,
)


@dataclasses.dataclass
class RecoveryEvent:
    """One REBUILD: which lane died where, and the single-source read ledger
    (artifact name -> the one surviving lane it was fetched from)."""

    point: Tuple[int, str, int]
    lane: int
    reads: Dict[str, int]
    elapsed_s: float

    @property
    def sources(self) -> List[int]:
        return sorted(set(self.reads.values()))


class FTSweepResult(NamedTuple):
    """Same layout as ``CAQRResult(collect_bundles=True)`` plus the recovery
    event log."""

    R: jax.Array
    factors: PanelFactors
    bundles: RecoveryBundle
    events: List[RecoveryEvent]


def _poison(x: jax.Array, lane: int, lane_axis: int = 0) -> jax.Array:
    """NaN out one lane's slice (float leaves only — int/bool bookkeeping is
    index-derived static data a respawned process recomputes trivially)."""
    if not jnp.issubdtype(x.dtype, jnp.floating):
        return x
    index = (slice(None),) * lane_axis + (lane,)
    return x.at[index].set(jnp.nan)


class FTSweepDriver:
    """Level-stepped windowed CAQR sweep with failure injection + REBUILD.

    ``A0`` is the initial matrix in SimComm layout ``(P, m_loc, n)`` — it
    doubles as the re-readable data source of the paper's recovery model.
    Any shape ``caqr_factorize`` accepts is accepted here: the driver runs
    at the same padded ``sweep_geometry``, and a respawned lane re-reads its
    *padded* initial slice (re-reading the raw slice and re-padding is the
    same thing — the pad is static zeros, not lost state), so every REBUILD
    stays single-source and the outputs stay bit-identical to the
    failure-free general-shape sweep.
    """

    def __init__(
        self,
        A0: jax.Array,
        comm: SimComm,
        panel_width: int,
        schedule: Optional[FailureSchedule] = None,
        detector: Optional[Detector] = None,
    ):
        assert isinstance(comm, SimComm), (
            "the FT driver kills lanes; only the SimComm simulator supports "
            "that on a single device (the SPMD path needs real processes)"
        )
        self.comm = comm
        self.P = comm.axis_size()
        self.levels = _levels(self.P)
        assert self.levels >= 1, "need at least 2 lanes to tolerate failures"
        self.b = panel_width
        m_loc, n = comm.local_shape(A0)
        self.geom = sweep_geometry(self.P, m_loc, n, self.b)
        # the sweep (and every REBUILD replay) runs at the padded geometry
        self.m_loc, self.n = self.geom.m_loc_pad, self.geom.n_work
        self.n_panels = self.geom.n_panels
        self.A0 = pad_to_geometry(comm, A0, self.geom)
        self.A = self.A0
        self.detector = detector or Detector(self.P, schedule)
        # stored sweep outputs, one entry per completed panel
        self.factors: List[PanelFactors] = []
        self.R_rows: List[jax.Array] = []
        self.bundles: List[RecoveryBundle] = []
        self.events: List[RecoveryEvent] = []

    # -- sweep -------------------------------------------------------------

    def run(self) -> FTSweepResult:
        for k in range(self.n_panels):
            self._run_panel(k)
        factors = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *self.factors)
        bundles = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *self.bundles)
        R = assemble_R(self.comm, jnp.stack(self.R_rows), self.geom)
        return FTSweepResult(R=R, factors=factors, bundles=bundles,
                             events=self.events)

    def _run_panel(self, k: int) -> None:
        comm, b = self.comm, self.b
        col0, t_lane, row_start, active = panel_geometry(comm, k, b, self.m_loc)
        self._k, self._col0, self._t_lane = k, col0, t_lane
        # in-flight per-panel state (what a mid-panel death obliterates)
        self._window = comm.map_local(lambda A: A[:, col0:])(self.A)
        self._R_carry = None
        self._Y2s: List[jax.Array] = []
        self._Ts: List[jax.Array] = []
        self._level_Y2 = self._level_T = None
        self._C_local = self._C_prime = None
        self._Ws: List[jax.Array] = []
        self._Cs_self: List[jax.Array] = []
        self._Cs_buddy: List[jax.Array] = []
        self._tops: List[jax.Array] = []

        # leaf: local masked panel QR
        panel = comm.map_local(lambda W: W[:, :b])(self._window)
        wy = comm.map_local(householder_qr_masked)(panel, row_start)
        self._leaf_Y = comm.where(active, wy.Y, jnp.zeros_like(wy.Y))
        self._leaf_T = comm.where(active, wy.T, jnp.zeros_like(wy.T))
        self._R_leaf = comm.where(active, wy.R, jnp.zeros_like(wy.R))
        self._checkpoint(sweep_point(k, PHASE_LEAF))

        # FT-TSQR butterfly, one checkpoint per level
        self._R_carry = self._R_leaf
        for s in range(self.levels):
            R_next, Y2, T = ft_tsqr_level(comm, self._R_carry, s, t_lane, t_lane)
            self._R_carry = R_next
            self._Y2s.append(Y2)
            self._Ts.append(T)
            self._checkpoint(sweep_point(k, PHASE_TSQR, s))
        self._level_Y2 = jnp.stack(self._Y2s)
        self._level_T = jnp.stack(self._Ts)

        # trailing update (Algorithm 2), one checkpoint per level
        dist = DistTSQRFactors(self._leaf_Y, self._leaf_T, self._level_Y2,
                               self._level_T, self._R_leaf)
        C_local, C_prime = _leaf_apply(comm, dist, self._window, row_start,
                                       active=active, skip_consumed=True)
        self._C_local = C_local
        self._C_prime = comm.where(active, C_prime, jnp.zeros_like(C_prime))
        for s in range(self.levels):
            out = trailing_combine_level(
                comm, self._C_prime, self._level_Y2[s], self._level_T[s],
                s, t_lane, t_lane,
            )
            self._Ws.append(out.W)
            self._Cs_self.append(out.C_self)
            self._Cs_buddy.append(out.C_buddy)
            self._tops.append(out.is_top)
            self._C_prime = out.C_prime
            self._checkpoint(sweep_point(k, PHASE_TRAILING, s))

        # writeback + panel outputs (the windowed sweep's own deposit helpers)
        C_out = _writeback(comm, self._C_local, self._C_prime, row_start, active)
        self.A = advance_columns(comm, self.A, C_out, col0)
        self.R_rows.append(extract_r_rows(comm, self._C_prime, t_lane, col0))
        self.bundles.append(pad_bundle(RecoveryBundle(
            W=jnp.stack(self._Ws),
            C_self=jnp.stack(self._Cs_self),
            C_buddy=jnp.stack(self._Cs_buddy),
            Y2=self._level_Y2,
            T=self._level_T,
            self_was_top=jnp.stack(self._tops),
        ), col0))
        self.factors.append(make_panel_factors(
            comm, self._leaf_Y, self._leaf_T, self._level_Y2, self._level_T,
            row_start, active, t_lane,
        ))

    # -- failure injection + REBUILD ---------------------------------------

    def _checkpoint(self, point: Tuple[int, str, int]) -> None:
        newly = self.detector.begin_step(point)
        for lane in newly:          # all deaths at this point strike first,
            self._obliterate(lane)  # then recovery runs one lane at a time
        for lane in newly:
            # drain the async-dispatched sweep prefix first, so the latency
            # clock covers only the REBUILD itself (then everything the
            # rebuild patched)
            self._sync()
            t0 = time.perf_counter()
            reads = self._rebuild(lane, point)
            self._sync()
            self.detector.revive(lane)
            self.events.append(RecoveryEvent(
                point=point, lane=lane, reads=reads,
                elapsed_s=time.perf_counter() - t0,
            ))

    def _sync(self) -> None:
        jax.block_until_ready([
            x for x in (
                self.A, self._window, self._leaf_Y, self._leaf_T,
                self._R_leaf, self._R_carry, self._level_Y2, self._level_T,
                self._C_local, self._C_prime,
                *self._Y2s, *self._Ts, *self._Ws, *self._Cs_self,
                *self._Cs_buddy, *self.factors, *self.bundles, *self.R_rows,
            ) if x is not None
        ])

    def _obliterate(self, lane: int) -> None:
        """Process death: NaN every float the lane holds — current block-row,
        in-flight panel state, and its slices of all stored sweep outputs."""
        self.A = _poison(self.A, lane)
        self._window = _poison(self._window, lane)
        self._leaf_Y = _poison(self._leaf_Y, lane)
        self._leaf_T = _poison(self._leaf_T, lane)
        self._R_leaf = _poison(self._R_leaf, lane)
        if self._R_carry is not None:
            self._R_carry = _poison(self._R_carry, lane)
        self._Y2s = [_poison(x, lane) for x in self._Y2s]
        self._Ts = [_poison(x, lane) for x in self._Ts]
        if self._level_Y2 is not None:
            self._level_Y2 = _poison(self._level_Y2, lane, 1)
            self._level_T = _poison(self._level_T, lane, 1)
        if self._C_local is not None:
            self._C_local = _poison(self._C_local, lane)
            self._C_prime = _poison(self._C_prime, lane)
        self._Ws = [_poison(x, lane) for x in self._Ws]
        self._Cs_self = [_poison(x, lane) for x in self._Cs_self]
        self._Cs_buddy = [_poison(x, lane) for x in self._Cs_buddy]
        for j in range(len(self.factors)):
            fj = self.factors[j]
            self.factors[j] = PanelFactors(
                leaf_Y=_poison(fj.leaf_Y, lane),
                leaf_T=_poison(fj.leaf_T, lane),
                level_Y2=_poison(fj.level_Y2, lane, 1),
                level_T=_poison(fj.level_T, lane, 1),
                row_start=fj.row_start, active=fj.active, target=fj.target,
            )
            bj = self.bundles[j]
            self.bundles[j] = RecoveryBundle(
                W=_poison(bj.W, lane, 1),
                C_self=_poison(bj.C_self, lane, 1),
                C_buddy=_poison(bj.C_buddy, lane, 1),
                Y2=_poison(bj.Y2, lane, 1),
                T=_poison(bj.T, lane, 1),
                self_was_top=bj.self_was_top,
            )
            self.R_rows[j] = _poison(self.R_rows[j], lane)

    def _rebuild(self, lane: int, point: Tuple[int, str, int]) -> Dict[str, int]:
        """The paper's REBUILD: respawn ``lane``, re-read its initial slice,
        replay completed panels, restore the in-flight panel state — each
        lost artifact from exactly one surviving buddy."""
        reads: Dict[str, int] = {}

        def fetch(artifact: str, source: int) -> int:
            if source == lane or source in self.detector.dead:
                raise UnrecoverableFailure(
                    f"rebuilding lane {lane} at {point} needs {artifact} "
                    f"from lane {source}, which is not a live survivor"
                )
            reads[artifact] = source
            return source

        k = self._k
        rows = self.A0[lane]  # respawn: re-read from the data source
        for j in range(k):
            rows = self._replay_panel(j, lane, rows, fetch)

        # current panel: recompute the masked leaf from the rebuilt rows
        col0, t_lane, rs, act = lane_geometry(k, self.b, self.m_loc, lane)
        lY, lT, lR = rec.recompute_leaf(rows, col0, self.b, rs, act)
        self._leaf_Y = self._leaf_Y.at[lane].set(lY)
        self._leaf_T = self._leaf_T.at[lane].set(lT)
        self._R_leaf = self._R_leaf.at[lane].set(lR)
        self.A = self.A.at[lane].set(rows)
        self._window = self._window.at[lane].set(rows[:, col0:])

        _, phase, lvl = point
        if phase == PHASE_TSQR:
            # ladder + running R: identical at the level-0 buddy (see module
            # docstring) — one copy restores all completed levels
            src = fetch("tsqr.ladder+R", lane ^ 1)
            for i in range(lvl + 1):
                self._Y2s[i] = self._Y2s[i].at[lane].set(self._Y2s[i][src])
                self._Ts[i] = self._Ts[i].at[lane].set(self._Ts[i][src])
            self._R_carry = self._R_carry.at[lane].set(self._R_carry[src])
        elif phase == PHASE_TRAILING:
            src = fetch("tsqr.ladder", lane ^ 1)
            self._level_Y2 = self._level_Y2.at[:, lane].set(self._level_Y2[:, src])
            self._level_T = self._level_T.at[:, lane].set(self._level_T[:, src])
            # leaf-applied window: local recompute through the same seam
            self._C_local = self._C_local.at[lane].set(
                apply_qt(lY, lT, rows[:, col0:])
            )
            # C' after the last completed level: ONE fetch from that level's
            # buddy, replayed through the seam-routed pair combine
            src_c = fetch(f"trailing.cprime@level{lvl}", lane ^ (1 << lvl))
            failed_was_top = ((lane >> lvl) & 1) == ((t_lane >> lvl) & 1)
            cp = rec.rebuild_cprime_after_level(
                self._Cs_buddy[lvl][src_c], self._Cs_self[lvl][src_c],
                self._level_Y2[lvl, lane], self._level_T[lvl, lane],
                failed_was_top,
                pair_live=(lane >= t_lane and src_c >= t_lane),
            )
            self._C_prime = self._C_prime.at[lane].set(cp)
            # the lane's own bundle rows: mirror of each level-buddy's entry
            # (W is pair-shared; C_self/C_buddy swap sides)
            for s in range(lvl + 1):
                src_s = fetch(f"trailing.bundle@level{s}", lane ^ (1 << s))
                w_s = self._Ws[s][src_s]
                c_self = self._Cs_buddy[s][src_s]
                c_buddy = self._Cs_self[s][src_s]
                self._Ws[s] = self._Ws[s].at[lane].set(w_s)
                self._Cs_self[s] = self._Cs_self[s].at[lane].set(c_self)
                self._Cs_buddy[s] = self._Cs_buddy[s].at[lane].set(c_buddy)
        return reads

    def _replay_panel(self, j: int, lane: int, rows: jax.Array, fetch) -> jax.Array:
        """Advance the respawned lane's block-row through completed panel
        ``j`` and restore its slices of that panel's stored outputs."""
        L = self.levels
        col0, t_lane, rs, act = lane_geometry(j, self.b, self.m_loc, lane)
        lY, lT, _lR = rec.recompute_leaf(rows, col0, self.b, rs, act)

        src_l = fetch(f"panel{j}.tsqr_ladder", lane ^ 1)
        fj = self.factors[j]
        self.factors[j] = PanelFactors(
            leaf_Y=fj.leaf_Y.at[lane].set(lY),
            leaf_T=fj.leaf_T.at[lane].set(lT),
            level_Y2=fj.level_Y2.at[:, lane].set(fj.level_Y2[:, src_l]),
            level_T=fj.level_T.at[:, lane].set(fj.level_T[:, src_l]),
            row_start=fj.row_start, active=fj.active, target=fj.target,
        )
        src_r = fetch(f"panel{j}.r_rows", lane ^ 1)
        self.R_rows[j] = self.R_rows[j].at[lane].set(self.R_rows[j][src_r])

        # final C' of panel j: one fetch from the last-level buddy's bundle
        bj = self.bundles[j]
        cp = None
        if act:
            src_c = fetch(f"panel{j}.cprime_final", lane ^ (1 << (L - 1)))
            failed_was_top = ((lane >> (L - 1)) & 1) == ((t_lane >> (L - 1)) & 1)
            # stored bundles are zero-padded to full width; slice back to the
            # live window so the replayed combine runs at the original width
            cp = rec.rebuild_cprime_after_level(
                bj.C_buddy[L - 1, src_c, :, col0:],
                bj.C_self[L - 1, src_c, :, col0:],
                bj.Y2[L - 1, src_c], bj.T[L - 1, src_c],
                failed_was_top,
                pair_live=(lane >= t_lane and (lane ^ (1 << (L - 1))) >= t_lane),
            )
        rows = rec.rebuild_block_row_through_panel(rows, lY, lT, cp, col0, rs, act)

        # the lane's own bundle rows for panel j: per-level mirrors
        W_new, Cs_new, Cb_new = bj.W, bj.C_self, bj.C_buddy
        for s in range(L):
            src_s = fetch(f"panel{j}.bundle@level{s}", lane ^ (1 << s))
            W_new = W_new.at[s, lane].set(bj.W[s, src_s])
            Cs_new = Cs_new.at[s, lane].set(bj.C_buddy[s, src_s])
            Cb_new = Cb_new.at[s, lane].set(bj.C_self[s, src_s])
        self.bundles[j] = RecoveryBundle(
            W=W_new, C_self=Cs_new, C_buddy=Cb_new,
            Y2=bj.Y2.at[:, lane].set(bj.Y2[:, src_l]),
            T=bj.T.at[:, lane].set(bj.T[:, src_l]),
            self_was_top=bj.self_was_top,
        )
        return rows


def ft_caqr_sweep(
    A0: jax.Array,
    comm: SimComm,
    panel_width: int,
    schedule: Optional[FailureSchedule] = None,
) -> FTSweepResult:
    """Run the full windowed FT-CAQR sweep under a failure schedule.

    Returns ``(R, factors, bundles, events)`` — bit-identical to
    ``caqr_factorize(A0, comm, panel_width, collect_bundles=True,
    use_scan=False)`` regardless of the schedule (the paper's recovery
    guarantee), with one ``RecoveryEvent`` per REBUILD."""
    return FTSweepDriver(A0, comm, panel_width, schedule).run()
