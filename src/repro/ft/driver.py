"""Fault-tolerant execution driver for the windowed CAQR sweep (paper §II-III).

This is the end-to-end form of the paper's claim: run the *entire* windowed
right-looking FT-CAQR sweep while lanes die at scheduled points — at any
panel, after any TSQR butterfly level or trailing-combine level — and finish
with ``R``, the per-panel implicit-Q factors, and the recovery bundles
**bit-identical** to the failure-free run (the recovery regression oracle).

Execution model (DESIGN.md §8-9)
--------------------------------
The sweep itself is the reified state machine of ``repro.ft.online.state``:
an explicit ``SweepState`` pytree advanced one interruptible point at a time
by the pure transition ``sweep_step``. This driver is a thin loop over that
transition that injects *scheduled* (trace-time) failures at each boundary —
the simulation-convenience path, kept as the differential oracle for the
*online* path (``repro.ft.online.orchestrator``, where deaths are discovered
at runtime instead of scripted). Both are ONE Comm-generic program
(``repro.core.comm``) that runs two ways:

* ``SimComm``  — the P-lane single-device simulator: eager, level-stepped,
  with wall-clock REBUILD latency per event. This is the test/debug path.
* ``AxisComm`` — inside ``jax.shard_map`` over a device mesh: the production
  SPMD path the paper describes, one real process per lane. The entrypoint
  is ``repro.launch.spmd_qr.ft_caqr_sweep_spmd``.

Death and recovery are expressed through the Comm death-mask primitives
(``comm.poison`` / ``comm.fetch_lane`` / ``comm.where_lane``) as the two
``SweepState`` transitions ``obliterate_state`` and ``rebuild_state``
defined here, shared verbatim by the scheduled and online paths: "kill lane
2 after panel 1's level-0 trailing combine" compiles to a masked NaN-write
on both paths, and every REBUILD fetch is a point-to-point collective keyed
by static lane indices. ``sweep_step`` calls the *same* single-level
primitives the production sweep is built from: ``ft_tsqr_level``
(core/tsqr), ``trailing_combine_level`` and ``_leaf_apply``/``_writeback``
(core/trailing), and the geometry/assembly helpers of ``core/caqr``.
Failure-free, the paths are the same floating-point program, so bit-identity
holds by construction; under failures it is regression-gated by
``tests/test_spmd_ft_driver.py`` and ``tests/test_online_recovery.py``.

Failure model (paper §II, ULFM REBUILD semantics)
-------------------------------------------------
A ``FailureSchedule`` keyed by ``sweep_point(panel, phase, level)`` kills
lanes at interruptible points; death is *simulated faithfully*: every float
the lane holds — its block-row, leaf/ladder factors, C', stored per-panel
factors and bundles — is overwritten with NaN, so any read of dead state
poisons the result and the bit-identity oracle catches it.

Recovery (paper §III-B/III-C REBUILD)
-------------------------------------
The respawned lane is rebuilt from (a) its own slice of the *initial*
matrix, re-read from the data source, and (b) per lost artifact, the state
of exactly ONE surviving lane — its XOR-buddy at the relevant tree level:

* previous panels — leaf factors are *recomputed* from the re-read rows
  (never fetched; they are lane-private), the final C' of each panel comes
  from the last-level buddy's bundle ``{W, T, C', Y2, role}``, and the
  lane's own bundle rows are mirrors of each level-buddy's
  (``W`` is pair-shared, ``C_self``/``C_buddy`` swap);
* current panel, mid-TSQR — the butterfly ladder ``(Y2, T)`` and the running
  R are identical at the level-0 buddy (lanes ``i`` and ``i^1`` agree at
  every level: same pair at level 0, same ``i >> (s+1)`` group above), so
  one copy restores them;
* current panel, mid-trailing — C' after the last completed level ``s`` is
  rebuilt from the level-``s`` buddy's bundle by replaying the pair combine
  through ``_combine`` (the same kernel-dispatch seam as the failure-free
  path) and keeping the failed side.

Each rebuilt artifact therefore reads ONE survivor (recorded in the event's
ledger — the single-source property is enforced by construction); a full
mid-sweep rebuild touches at most ``log2 P`` distinct survivors across
artifact classes. If a needed buddy is itself dead (e.g. both members of a
pair killed at the same point), ``UnrecoverableFailure`` is raised — that is
the honest limit of one-level redundancy doubling. Under shard_map the
schedule is validated at trace time, so an unrecoverable schedule fails
before any device computes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import AbstractSet, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import recovery as rec
from repro.core.caqr import PanelFactors, lane_geometry
from repro.core.comm import SimComm
from repro.core.householder import apply_qt
from repro.core.trailing import RecoveryBundle
from repro.core.tsqr import _levels
# NOTE: core.recovery re-exports from ft.coding, so by the time the line
# above ran, repro.ft.coding is already in sys.modules — this import is a
# cheap bind, not a cycle.
from repro.ft.coding import CodingScheme, XORPairScheme
from repro.ft.semantics import Semantics
from repro.ft.failures import (
    Detector,
    FailureSchedule,
    PHASE_TSQR,
    PHASE_TRAILING,
    UnrecoverableFailure,
)
from repro.ft.online.state import (
    SweepState,
    finalize,
    initial_sweep_state,
    state_lane_axes,
    sweep_step,
)


@dataclasses.dataclass
class RecoveryEvent:
    """One REBUILD: which lane died where, and the single-source read ledger
    (artifact name -> the one surviving lane it was fetched from).

    ``elapsed_s`` is wall-clock REBUILD latency under the eager SimComm path;
    under shard_map the whole sweep is one traced program, so it records
    trace time only (use ``benchmarks/bench_spmd.py`` for SPMD REBUILD cost).
    """

    point: Tuple[int, str, int]
    lane: int
    reads: Dict[str, int]
    elapsed_s: float

    @property
    def sources(self) -> List[int]:
        return sorted(set(self.reads.values()))


class FTSweepResult(NamedTuple):
    """Same layout as ``CAQRResult(collect_bundles=True)`` plus the recovery
    event log."""

    R: jax.Array
    factors: PanelFactors
    bundles: RecoveryBundle
    events: List[RecoveryEvent]


# -- death + REBUILD as SweepState transitions -------------------------------
#
# Shared by the scheduled driver below and the online orchestrator
# (repro.ft.online.orchestrator): process death and single-source recovery
# are functions of (comm, state), not of the execution mode.


def obliterate_state(comm, state: SweepState, lane: int) -> SweepState:
    """Process death, mask-form: NaN every float the lane holds — current
    block-row, in-flight panel state, and its slices of all stored sweep
    outputs (``comm.poison`` — an at-set under SimComm, a masked select on
    the lane's own device under shard_map). The initial matrix ``A0`` is the
    re-readable data source of the paper's model and survives."""
    # A0 survives: mark its axis with the skip sentinel (keeps the axes
    # pytree structurally identical to the state) so the biggest leaf is
    # not pointlessly poisoned and re-replaced
    axes = state_lane_axes(state).replace(A0=-1)
    return jax.tree_util.tree_map(
        lambda x, ax: x if ax < 0 else comm.poison(x, lane, lane_axis=ax),
        state, axes)


_XOR_SCHEME = XORPairScheme()


def recover_lanes(
    comm,
    state: SweepState,
    newly: List[int],
    point: Tuple[int, str, int],
    dead: AbstractSet[int],
    sync=None,
    on_recovered=None,
    scheme: Optional[CodingScheme] = None,
) -> Tuple[SweepState, List[RecoveryEvent]]:
    """The shared REBUILD protocol: all detected deaths strike first
    (normalize whatever was observed to the full mask-death), then recovery
    runs. Both execution modes — the scheduled driver's checkpoint and the
    online orchestrator's detection handler — call exactly this, so the
    scheduled-vs-online bitwise equivalence cannot drift apart in one copy.

    ``scheme`` (``repro.ft.coding``, default the paper's ``XORPairScheme``)
    selects the redundancy: a SINGLE newly-dead lane always takes the
    paper's single-source XOR REBUILD below (so ``MDSScheme(f=1)`` is
    ledger-identical to XOR); ``2 <= t <= scheme.f`` simultaneous deaths
    take the joint GF decode (``scheme.decode_lanes``, multi-source
    ledger); ``t > scheme.f`` falls back to the per-lane XOR loop, whose
    exhaustion is the honest ``UnrecoverableFailure`` boundary.

    ``sync(state)`` (optional) drains async dispatch before/after each
    rebuild so ``elapsed_s`` covers only the REBUILD itself;
    ``on_recovered(lane)`` (optional) runs after a lane is rebuilt, before
    its event is logged — the callers revive their detectors here (which
    also removes the lane from a live ``dead`` set, keeping later rebuilds'
    single-source checks honest)."""
    scheme = _XOR_SCHEME if scheme is None else scheme
    events: List[RecoveryEvent] = []
    newly = sorted(newly)
    for lane in newly:
        state = obliterate_state(comm, state, lane)
    if (scheme.joint and 2 <= len(newly) <= scheme.f
            and not (set(dead) - set(newly))):
        if sync is not None:
            sync(state)
        t0 = time.perf_counter()
        state, reads = scheme.decode_lanes(comm, state, newly, dead)
        if sync is not None:
            sync(state)
        elapsed = time.perf_counter() - t0
        for lane in newly:
            if on_recovered is not None:
                on_recovered(lane)
            events.append(RecoveryEvent(
                point=point, lane=lane, reads=dict(reads),
                elapsed_s=elapsed,
            ))
        return state, events
    try:
        for lane in newly:
            if sync is not None:
                sync(state)
            t0 = time.perf_counter()
            state, reads = rebuild_state(comm, state, lane, point, dead)
            if sync is not None:
                sync(state)
            if on_recovered is not None:
                on_recovered(lane)
            events.append(RecoveryEvent(
                point=point, lane=lane, reads=reads,
                elapsed_s=time.perf_counter() - t0,
            ))
    except UnrecoverableFailure as e:
        if scheme.joint and len(newly) > scheme.f:
            raise UnrecoverableFailure(
                f"{len(newly)} simultaneous deaths exceed the coding "
                f"scheme's tolerance f={scheme.f}, and the XOR fallback "
                f"found no live source: {e}") from None
        raise
    return state, events


def rebuild_state(
    comm,
    state: SweepState,
    lane: int,
    point: Tuple[int, str, int],
    dead: AbstractSet[int] = frozenset(),
) -> Tuple[SweepState, Dict[str, int]]:
    """The paper's REBUILD as a state transition: respawn ``lane`` at the
    recoverable boundary ``point``, re-read its initial slice, replay
    completed panels, restore the in-flight panel state — each lost artifact
    from exactly one surviving buddy. Returns the repaired state and the
    single-source read ledger. ``dead`` is the set of currently-dead lanes
    (a needed source in it raises ``UnrecoverableFailure``).

    Comm-generic expression: replay arithmetic runs per lane through
    ``comm.map_local`` at the dead lane's *static* geometry (under SPMD
    every lane runs the same program; survivors' replay results are
    discarded by the final ``where_lane`` masks — under SimComm the vmap
    computes the same discarded slots), and every buddy read is a
    ``fetch_lane``/``ppermute`` keyed by static lane indices, so exactly
    one survivor sends per artifact on the production path too."""
    geom = state.geom
    b, m_loc = geom.b, geom.m_loc_pad
    reads: Dict[str, int] = {}

    def fetch(artifact: str, source: int) -> int:
        if source == lane or source in dead:
            raise UnrecoverableFailure(
                f"rebuilding lane {lane} at {point} needs {artifact} "
                f"from lane {source}, which is not a live survivor"
            )
        reads[artifact] = source
        return source

    k = point[0]
    # respawn: every lane re-reads its own slice of the data source; only
    # the dead lane's replay survives the rebuild's masked writes
    rows = state.A0
    for j in range(k):
        state, rows = _replay_panel(comm, state, j, lane, rows, fetch)

    # current panel: recompute the masked leaf from the rebuilt rows
    col0, t_lane, rs, act = lane_geometry(k, b, m_loc, lane)
    lY, lT, lR = comm.map_local(
        lambda r: rec.recompute_leaf(r, col0, b, rs, act)
    )(rows)
    state = state.replace(
        leaf_Y=comm.where_lane(lane, lY, state.leaf_Y),
        leaf_T=comm.where_lane(lane, lT, state.leaf_T),
        R_leaf=comm.where_lane(lane, lR, state.R_leaf),
        A=comm.where_lane(lane, rows, state.A),
        window=comm.where_lane(
            lane, comm.map_local(lambda r: r[:, col0:])(rows), state.window),
    )

    _, phase, lvl = point
    if phase == PHASE_TSQR:
        # ladder + running R: identical at the level-0 buddy (see module
        # docstring) — one copy restores all completed levels
        src = fetch("tsqr.ladder+R", lane ^ 1)
        Y2s, Ts = list(state.Y2s), list(state.Ts)
        for i in range(lvl + 1):
            Y2s[i] = comm.fetch_lane(Y2s[i], lane, src)
            Ts[i] = comm.fetch_lane(Ts[i], lane, src)
        state = state.replace(
            Y2s=tuple(Y2s), Ts=tuple(Ts),
            R_carry=comm.fetch_lane(state.R_carry, lane, src),
        )
    elif phase == PHASE_TRAILING:
        src = fetch("tsqr.ladder", lane ^ 1)
        level_Y2 = comm.fetch_lane(state.level_Y2, lane, src, lane_axis=1)
        level_T = comm.fetch_lane(state.level_T, lane, src, lane_axis=1)
        # the per-level ladder tuple and the running tsqr R ride along from
        # the same survivor: no sweep output reads them after the stacking,
        # but a respawned lane must hold NO stale NaN — the online
        # detectors (sentinel probe, deep scan) rely on a rebuilt lane
        # being indistinguishable from one that never died
        Y2s, Ts = list(state.Y2s), list(state.Ts)
        for i in range(len(Y2s)):
            Y2s[i] = comm.fetch_lane(Y2s[i], lane, src)
            Ts[i] = comm.fetch_lane(Ts[i], lane, src)
        state = state.replace(Y2s=tuple(Y2s), Ts=tuple(Ts))
        if state.R_carry is not None:
            state = state.replace(
                R_carry=comm.fetch_lane(state.R_carry, lane, src))
        # leaf-applied window: local recompute through the same seam
        C_local = comm.where_lane(
            lane,
            comm.map_local(
                lambda Y, T, r: apply_qt(Y, T, r[:, col0:])
            )(lY, lT, rows),
            state.C_local,
        )
        # C' after the last completed level: ONE fetch from that level's
        # buddy, replayed through the seam-routed pair combine
        src_c = fetch(f"trailing.cprime@level{lvl}", lane ^ (1 << lvl))
        failed_was_top = ((lane >> lvl) & 1) == ((t_lane >> lvl) & 1)
        pair_live = lane >= t_lane and src_c >= t_lane
        recv = lambda x: comm.ppermute(x, [(src_c, lane)])
        cp = comm.map_local(
            lambda cb, cs, y2, t: rec.rebuild_cprime_after_level(
                cb, cs, y2, t, failed_was_top, pair_live)
        )(recv(state.Cs_buddy[lvl]), recv(state.Cs_self[lvl]),
          level_Y2[lvl], level_T[lvl])
        C_prime = comm.where_lane(lane, cp, state.C_prime)
        # the lane's own bundle rows: mirror of each level-buddy's entry
        # (W is pair-shared; C_self/C_buddy swap sides)
        Ws = list(state.Ws)
        Cs_self, Cs_buddy = list(state.Cs_self), list(state.Cs_buddy)
        for s in range(lvl + 1):
            src_s = fetch(f"trailing.bundle@level{s}", lane ^ (1 << s))
            new_w = comm.fetch_lane(Ws[s], lane, src_s)
            new_cs = comm.fetch_lane(
                Cs_buddy[s], lane, src_s, into=Cs_self[s])
            new_cb = comm.fetch_lane(
                Cs_self[s], lane, src_s, into=Cs_buddy[s])
            Ws[s], Cs_self[s], Cs_buddy[s] = new_w, new_cs, new_cb
        state = state.replace(
            level_Y2=level_Y2, level_T=level_T, C_local=C_local,
            C_prime=C_prime, Ws=tuple(Ws),
            Cs_self=tuple(Cs_self), Cs_buddy=tuple(Cs_buddy),
        )
    return state, reads


def _replay_panel(
    comm, state: SweepState, j: int, lane: int, rows, fetch
) -> Tuple[SweepState, jax.Array]:
    """Advance the respawned lane's block-row through completed panel ``j``
    and restore its slices of that panel's stored outputs."""
    geom = state.geom
    b, m_loc, L = geom.b, geom.m_loc_pad, geom.levels
    col0, t_lane, rs, act = lane_geometry(j, b, m_loc, lane)
    lY, lT, _lR = comm.map_local(
        lambda r: rec.recompute_leaf(r, col0, b, rs, act)
    )(rows)

    src_l = fetch(f"panel{j}.tsqr_ladder", lane ^ 1)
    factors = list(state.factors)
    fj = factors[j]
    factors[j] = PanelFactors(
        leaf_Y=comm.where_lane(lane, lY, fj.leaf_Y),
        leaf_T=comm.where_lane(lane, lT, fj.leaf_T),
        level_Y2=comm.fetch_lane(fj.level_Y2, lane, src_l, lane_axis=1),
        level_T=comm.fetch_lane(fj.level_T, lane, src_l, lane_axis=1),
        row_start=fj.row_start, active=fj.active, target=fj.target,
    )
    src_r = fetch(f"panel{j}.r_rows", lane ^ 1)
    R_rows = list(state.R_rows)
    R_rows[j] = comm.fetch_lane(R_rows[j], lane, src_r)

    # final C' of panel j: one fetch from the last-level buddy's bundle.
    # Indexing the leading LEVEL axis first leaves per-lane layout on
    # both comms (SimComm keeps the lane axis in front, AxisComm is
    # already local), so the replayed combine is one expression.
    bj = state.bundles[j]
    if act:
        src_c = fetch(f"panel{j}.cprime_final", lane ^ (1 << (L - 1)))
        failed_was_top = ((lane >> (L - 1)) & 1) == ((t_lane >> (L - 1)) & 1)
        pair_live = lane >= t_lane and (lane ^ (1 << (L - 1))) >= t_lane
        recv = lambda x: comm.ppermute(x, [(src_c, lane)])
        # stored bundles are zero-padded to full width; slice back to the
        # live window so the replayed combine runs at the original width
        cp = comm.map_local(
            lambda cb, cs, y2, t: rec.rebuild_cprime_after_level(
                cb, cs, y2, t, failed_was_top, pair_live)
        )(recv(bj.C_buddy[L - 1][..., col0:]),
          recv(bj.C_self[L - 1][..., col0:]),
          recv(bj.Y2[L - 1]), recv(bj.T[L - 1]))
        rows = comm.map_local(
            lambda r, y, t, c: rec.rebuild_block_row_through_panel(
                r, y, t, c, col0, rs, act)
        )(rows, lY, lT, cp)
    else:
        rows = comm.map_local(
            lambda r, y, t: rec.rebuild_block_row_through_panel(
                r, y, t, None, col0, rs, act)
        )(rows, lY, lT)

    # the lane's own bundle rows for panel j: per-level mirrors, written
    # level-sliced (leading axis) and re-stacked so the same code drives
    # both comm layouts
    W_lv = [bj.W[s] for s in range(L)]
    Cs_lv = [bj.C_self[s] for s in range(L)]
    Cb_lv = [bj.C_buddy[s] for s in range(L)]
    for s in range(L):
        src_s = fetch(f"panel{j}.bundle@level{s}", lane ^ (1 << s))
        W_lv[s] = comm.fetch_lane(bj.W[s], lane, src_s)
        Cs_lv[s] = comm.fetch_lane(bj.C_buddy[s], lane, src_s, into=Cs_lv[s])
        Cb_lv[s] = comm.fetch_lane(bj.C_self[s], lane, src_s, into=Cb_lv[s])
    bundles = list(state.bundles)
    bundles[j] = RecoveryBundle(
        W=jnp.stack(W_lv), C_self=jnp.stack(Cs_lv), C_buddy=jnp.stack(Cb_lv),
        Y2=comm.fetch_lane(bj.Y2, lane, src_l, lane_axis=1),
        T=comm.fetch_lane(bj.T, lane, src_l, lane_axis=1),
        self_was_top=bj.self_was_top,
    )
    state = state.replace(
        factors=tuple(factors), R_rows=tuple(R_rows), bundles=tuple(bundles))
    return state, rows


# -- the scheduled (trace-time) driver ---------------------------------------


class FTSweepDriver:
    """Level-stepped windowed CAQR sweep with failure injection + REBUILD.

    A thin loop over the reified state machine: each iteration runs
    ``repro.ft.online.state.sweep_step`` (one sweep point), then fires the
    scheduled deaths of the just-completed point and repairs them with
    ``obliterate_state`` / ``rebuild_state``. Comm-generic (paper §II
    execution model; DESIGN.md §8): under ``SimComm`` lanes are simulator
    slices of single-device arrays; under ``AxisComm`` (inside
    ``shard_map``) each lane is a real device and every kill/fetch is a
    masked collective. The two paths run the same floating-point program
    and produce bit-identical results.

    ``A0`` is the initial matrix — SimComm layout ``(P, m_loc, n)``, per-lane
    ``(m_loc, n)`` under AxisComm — and doubles as the re-readable data
    source of the paper's recovery model. Any shape ``caqr_factorize``
    accepts is accepted here: the driver runs at the same padded
    ``sweep_geometry``, and a respawned lane re-reads its *padded* initial
    slice (re-reading the raw slice and re-padding is the same thing — the
    pad is static zeros, not lost state), so every REBUILD stays
    single-source and the outputs stay bit-identical to the failure-free
    general-shape sweep.
    """

    def __init__(
        self,
        A0: jax.Array,
        comm,
        panel_width: int,
        schedule: Optional[FailureSchedule] = None,
        detector: Optional[Detector] = None,
        scheme: Optional[CodingScheme] = None,
    ):
        self.comm = comm
        self.scheme = _XOR_SCHEME if scheme is None else scheme
        self.P = comm.axis_size()
        # SimComm runs eagerly (lane kills between real dispatches, timed
        # REBUILDs); AxisComm traces the whole sweep into one program, so
        # device syncs / wall clocks are meaningless there.
        self._eager = isinstance(comm, SimComm)
        self.levels = _levels(self.P)
        assert self.levels >= 1, "need at least 2 lanes to tolerate failures"
        self.b = panel_width
        self.state = initial_sweep_state(comm, A0, panel_width)
        self.geom = self.state.geom
        self.detector = detector or Detector(self.P, schedule)
        self.events: List[RecoveryEvent] = []

    # -- sweep -------------------------------------------------------------

    def run(self) -> FTSweepResult:
        while self.state.cursor is not None:
            point = self.state.cursor
            self.state = sweep_step(self.comm, self.state)
            # re-encode the parity slots from live state BEFORE the just-
            # completed point's deaths fire: a boundary decode must see
            # survivors exactly as encoded (identity under XOR pairing)
            self.state = self.scheme.refresh(self.comm, self.state)
            self._checkpoint(point)
        R, factors, bundles = finalize(self.comm, self.state)
        return FTSweepResult(R=R, factors=factors, bundles=bundles,
                             events=self.events)

    # -- failure injection + REBUILD ---------------------------------------

    def _checkpoint(self, point: Tuple[int, str, int]) -> None:
        newly = self.detector.begin_step(point)
        if not newly:
            return
        # the sync drains the async-dispatched sweep prefix so the latency
        # clock covers only each REBUILD itself; no-op under tracing
        sync = _block_on_state if self._eager else None
        self.state, events = recover_lanes(
            self.comm, self.state, newly, point, self.detector.dead,
            sync=sync, on_recovered=self.detector.revive,
            scheme=self.scheme,
        )
        self.events.extend(events)


def _block_on_state(state: SweepState) -> None:
    jax.block_until_ready(jax.tree_util.tree_leaves(state))


def ft_caqr_sweep(
    A0: jax.Array,
    comm,
    panel_width: int,
    schedule: Optional[FailureSchedule] = None,
    semantics: Optional["Semantics"] = None,
    scheme: Optional[CodingScheme] = None,
) -> FTSweepResult:
    """Run the full windowed FT-CAQR sweep under a failure schedule
    (paper §II-III end to end).

    Returns ``(R, factors, bundles, events)`` — bit-identical to
    ``caqr_factorize(A0, comm, panel_width, collect_bundles=True,
    use_scan=False)`` regardless of the schedule (the paper's recovery
    guarantee), with one ``RecoveryEvent`` per REBUILD.

    ``semantics`` selects the FT-MPI continuation policy: REBUILD
    (default) runs this driver; SHRINK/BLANK delegate to the scheduled
    elastic driver (``repro.ft.elastic.ft_caqr_sweep_elastic``), which
    returns an ``ElasticSweepResult`` with a host-spliced R instead.

    ``scheme`` selects the redundancy coding (``repro.ft.coding``):
    ``XORPairScheme`` (default — the paper's pairwise XOR, one death per
    pair) or ``MDSScheme(f=...)``, whose coded checksum slots recover ANY
    ``f`` simultaneous deaths — including a whole former XOR buddy pair —
    still bitwise-identical to the failure-free sweep.

    ``comm`` selects the execution: ``SimComm(P)`` for the single-device
    simulator, ``AxisComm(axis)`` inside ``shard_map`` for the production
    SPMD path (use ``repro.launch.spmd_qr.ft_caqr_sweep_spmd`` which wires
    the mesh and output layouts). For *runtime-detected* (unscripted)
    failures, use the online orchestrator
    (``repro.ft.online.orchestrator.SweepOrchestrator``), which drives the
    same state machine.

    Example (simulator; kill lane 1 after panel 0's level-0 trailing
    combine, recover, and match the failure-free sweep bit for bit):

    >>> import numpy as np, jax.numpy as jnp
    >>> from repro.core import SimComm, caqr_factorize
    >>> from repro.ft import FailureSchedule, ft_caqr_sweep, sweep_point
    >>> A = jnp.asarray(np.random.default_rng(0).standard_normal((2, 4, 4)),
    ...                 jnp.float32)
    >>> sched = FailureSchedule(events={sweep_point(0, "trailing", 0): [1]})
    >>> out = ft_caqr_sweep(A, SimComm(2), 4, schedule=sched)
    >>> ref = caqr_factorize(A, SimComm(2), 4, collect_bundles=True,
    ...                      use_scan=False)
    >>> bool(jnp.array_equal(out.R, ref.R))
    True
    >>> [(e.point, e.lane) for e in out.events]
    [((0, 'trailing', 0), 1)]
    """
    if semantics is not None and semantics is not Semantics.REBUILD:
        from repro.ft.elastic import ft_caqr_sweep_elastic

        return ft_caqr_sweep_elastic(
            A0, comm, panel_width, schedule=schedule, semantics=semantics,
            scheme=scheme)
    return FTSweepDriver(A0, comm, panel_width, schedule,
                         scheme=scheme).run()
