"""Fault-tolerance substrate: semantics, failure injection, elastic re-mesh,
the end-to-end FT-CAQR sweep driver, and the online-recovery subsystem
(``repro.ft.online``: reified sweep state machine + runtime detection +
host orchestrator). All Comm-generic — the SPMD entrypoints that run them
under shard_map live in ``repro.launch.spmd_qr``."""
from repro.ft import driver, elastic, failures, semantics, stragglers
from repro.ft.driver import FTSweepDriver, FTSweepResult, RecoveryEvent, ft_caqr_sweep
from repro.ft.elastic import (
    ElasticController,
    ElasticSweepResult,
    LaneWorld,
    TransitionEvent,
    ft_caqr_sweep_elastic,
)
from repro.ft.stragglers import (
    SpeculationEvent,
    StragglerConfig,
    StragglerMonitor,
    StragglerPolicy,
)
from repro.ft.failures import (
    FailureSchedule,
    UnrecoverableFailure,
    iter_sweep_points,
    next_sweep_point,
    prev_sweep_point,
    sweep_point,
)
from repro.ft.semantics import Semantics
# the online subsystem reuses the driver's REBUILD transitions, so its
# sibling modules load after the driver (repro.ft.online.__init__ is
# state-only; this completes the package)
from repro.ft import online
from repro.ft.online import detect, orchestrator  # noqa: F401  (wires submodules)
from repro.ft.online.orchestrator import SweepOrchestrator, ft_caqr_sweep_online
from repro.ft.online.state import SweepState, initial_sweep_state, sweep_step
from repro.ft import coding
from repro.ft.coding import CodingScheme, MDSScheme, XORPairScheme
__all__ = [
    "coding", "driver", "elastic", "failures", "online", "semantics",
    "stragglers",
    "Semantics",
    "CodingScheme", "MDSScheme", "XORPairScheme",
    "FTSweepDriver", "FTSweepResult", "RecoveryEvent", "ft_caqr_sweep",
    "FailureSchedule", "UnrecoverableFailure", "iter_sweep_points",
    "next_sweep_point", "prev_sweep_point", "sweep_point",
    "SweepOrchestrator", "ft_caqr_sweep_online",
    "SweepState", "initial_sweep_state", "sweep_step",
    "ElasticController", "ElasticSweepResult", "LaneWorld",
    "TransitionEvent", "ft_caqr_sweep_elastic",
    "SpeculationEvent", "StragglerConfig", "StragglerMonitor",
    "StragglerPolicy",
]
