"""Fault-tolerance substrate: semantics, failure injection, elastic re-mesh,
and the end-to-end FT-CAQR sweep driver (Comm-generic — the SPMD entrypoint
that runs it under shard_map lives in ``repro.launch.spmd_qr``)."""
from repro.ft import driver, elastic, failures, semantics, stragglers
from repro.ft.driver import FTSweepDriver, FTSweepResult, RecoveryEvent, ft_caqr_sweep
from repro.ft.failures import (
    FailureSchedule,
    UnrecoverableFailure,
    iter_sweep_points,
    sweep_point,
)
from repro.ft.semantics import Semantics
__all__ = [
    "driver", "elastic", "failures", "semantics", "stragglers", "Semantics",
    "FTSweepDriver", "FTSweepResult", "RecoveryEvent", "ft_caqr_sweep",
    "FailureSchedule", "UnrecoverableFailure", "iter_sweep_points",
    "sweep_point",
]
