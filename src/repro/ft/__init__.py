"""Fault-tolerance substrate: semantics, failure injection, elastic re-mesh."""
from repro.ft import elastic, failures, semantics, stragglers
from repro.ft.semantics import Semantics
__all__ = ["elastic", "failures", "semantics", "stragglers", "Semantics"]
