"""Elastic execution: SHRINK/BLANK continuation, re-grow, and the epoch
splice (DESIGN.md §11).

Until this module, the FT-CAQR sweep treated the lane count as a static
invariant: SHRINK and BLANK (paper §II) were refused mid-factorization.
The observation that unlocks them is that the same single-source
redundancy that makes REBUILD one-fetch cheap also lets a *survivor*
adopt a dead lane's data: on a detected death the dead lane's block-row
and in-flight artifacts are first healed from its XOR buddies via the
existing ``recover_lanes`` protocol (the adopter "hosts" the dead slot
until the panel completes — bitwise the same arithmetic as REBUILD), and
at the next **panel boundary** the world re-meshes:

* the pending panel is deposited (``deposit_boundary``), closing an
  *epoch* whose partial R rows are recorded;
* the unconsumed trailing submatrix — every padded row below the
  ``r*b`` frontier, live columns ``[r*b:]`` — is harvested to the host;
* a transition *plan* re-owns the rows onto the new world (SHRINK:
  survivors renumber, the dead lane's rows are appended to its
  designated adopter's slice; BLANK: the hole keeps a zero-row no-op
  slot; GROW: rows re-scatter evenly over one more live lane) and the
  sweep restarts as a fresh sub-factorization on a widened
  ``sweep_geometry`` — the TSQR ladder pairing remaps implicitly to the
  new world's XOR tree (``repro.core.recovery.pairing_table``).

Correctness: the harvested submatrix ``T`` satisfies ``T^T T =
T_ref^T T_ref`` where ``T_ref`` is the failure-free trailing matrix
(both equal ``R_sub^T R_sub``), so the continued sweep reproduces the
remaining R rows up to row signs — within ``kernels.ref.tolerances`` of
the failure-free run. The scheduled elastic driver
(``ft_caqr_sweep_elastic``) and the online orchestrator share this
controller verbatim, so scheduled-vs-online is **bitwise** — the same
differential-oracle structure the REBUILD path uses.

The butterfly needs a power-of-two slot count, so a shrunken world keeps
pow2 *slots* under one of two policies:

* ``"pad"``  (SimComm default): slots = ceil-pow2(live lanes); trailing
  ghost slots hold zero rows and contribute zero reflectors (exact, the
  §7 padding argument). P=4 minus one lane finishes on 3 live lanes.
* ``"fold"`` (SPMD re-mesh): slots = floor-pow2(live lanes); rows
  re-split evenly so the new ``shard_map`` mesh fits on surviving
  devices (``repro.launch.spmd_qr.make_spmd_step_factory``).

The training-mesh helpers at the bottom (``make_data_model_mesh`` /
``shrink_mesh`` / ``reshard`` / ``rebalance_batch``) are the training
loop's elastic re-mesh path and predate the sweep machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.caqr import SweepGeometry
from repro.ft.failures import PHASE_LEAF
from repro.ft.semantics import Semantics


def ceil_pow2(x: int) -> int:
    assert x >= 1
    return 1 << (x - 1).bit_length()


def floor_pow2(x: int) -> int:
    assert x >= 1
    return 1 << (x.bit_length() - 1)


@dataclasses.dataclass(frozen=True)
class LaneWorld:
    """One epoch's lane world: ``n_slots`` pow2 butterfly slots, of which
    ``live`` marks the lanes that own rows (ghost/hole slots compute on
    zeros — masked no-ops). ``col_base`` is the absolute column of the
    epoch's first panel in the original problem."""

    n_slots: int
    live: Tuple[bool, ...]
    col_base: int = 0

    @property
    def n_live(self) -> int:
        return sum(self.live)

    @property
    def live_lanes(self) -> Tuple[int, ...]:
        return tuple(i for i, ok in enumerate(self.live) if ok)


@dataclasses.dataclass(frozen=True)
class TransitionEvent:
    """One world re-mesh: the boundary it ran at (the just-deposited
    panel frontier ``r`` of the *closing* epoch), what kind, which lanes
    left/joined, and the worlds on both sides."""

    kind: str                      # "shrink" | "blank" | "grow"
    frontier: int                  # panels deposited in the closing epoch
    lanes: Tuple[int, ...]         # dead lanes (old-world ids) or () for grow
    adopter: Optional[int]         # survivor that adopted the rows (old id)
    world_before: LaneWorld
    world_after: LaneWorld


class EpochRecord(NamedTuple):
    """Partial R of one epoch: ``R_part`` holds the epoch's deposited
    rows (``r*b`` of them, epoch column frame) at absolute offset
    ``col_base`` — the splice input of ``ElasticController.result``."""

    col_base: int
    R_part: np.ndarray
    world: LaneWorld


class ElasticSweepResult(NamedTuple):
    """Outcome of an elastic sweep. ``R`` is the spliced ``(k, n)`` upper
    trapezoid (host-assembled, un-replicated — epochs ran at different
    world sizes so there is no single lane layout to return factors in).
    ``events`` are the heal ``RecoveryEvent``s, ``transitions`` the world
    re-meshes, ``world`` the final lane world."""

    R: jax.Array
    events: List[Any]
    transitions: List[TransitionEvent]
    world: LaneWorld


# -- transition plans ---------------------------------------------------------


def _adopter_for(world: LaneWorld, dead: int) -> int:
    """The designated survivor that re-owns a dead lane's rows: its XOR
    buddy at level 0 when live, else the lowest-indexed live lane — the
    same preference order the REBUILD fetches use."""
    buddy = dead ^ 1
    if buddy < world.n_slots and world.live[buddy]:
        return buddy
    for i in world.live_lanes:
        if i != dead:
            return i
    raise AssertionError("no live adopter")


def plan_transition(
    world: LaneWorld,
    kind: str,
    dead: Tuple[int, ...] = (),
    policy: str = "pad",
) -> Tuple[List[List[int]], LaneWorld, Optional[int]]:
    """Row re-ownership plan for one transition.

    Returns ``(sources, world_after, adopter)`` where ``sources[j]`` lists
    the OLD slots whose harvested rows concatenate into NEW slot ``j``
    (order matters: an adopted block is *appended* to the adopter's own
    slice). Every old slot appears exactly once across all new slots —
    residue rows of non-live slots ride with their nearest live
    predecessor, so no row of the padded problem is dropped.
    """
    assert kind in ("shrink", "blank", "grow"), kind
    live_new = list(world.live)
    for d in dead:
        assert world.live[d], f"lane {d} is not live"
        live_new[d] = False
    assert any(live_new), "no survivors"
    adopter = _adopter_for(
        dataclasses.replace(world, live=tuple(live_new)), dead[0]
    ) if dead else None

    # old slots in index order, each tagged with the live slot that owns
    # its rows after the transition (dead -> adopter; non-live residue ->
    # nearest live predecessor, else successor)
    owner: Dict[int, List[int]] = {i: [] for i in range(world.n_slots)
                                   if live_new[i]}
    live_sorted = sorted(owner)
    for i in range(world.n_slots):
        if live_new[i]:
            owner[i].insert(0, i)        # own rows always lead
        elif i in dead:
            owner[adopter].append(i)     # adopted block, appended
        else:
            prev = [j for j in live_sorted if j < i]
            owner[(prev[-1] if prev else live_sorted[0])].append(i)

    if kind == "blank":
        n_slots = world.n_slots
        sources = [owner.get(j, []) for j in range(n_slots)]
        world_after = LaneWorld(n_slots=n_slots, live=tuple(live_new))
    else:
        n_live = sum(live_new) + (1 if kind == "grow" else 0)
        n_slots = max(2, (ceil_pow2 if policy == "pad" else floor_pow2)(n_live))
        if kind == "grow":
            # even re-scatter handled by the caller (single source stream);
            # sources here keep slot order for the concatenation
            sources = [owner[j] for j in live_sorted] + [[]] * (
                n_slots - len(live_sorted))
            world_after = LaneWorld(
                n_slots=n_slots,
                live=tuple(j < n_live for j in range(n_slots)))
            return sources, world_after, adopter
        # shrink: survivors renumber compactly; fold policy re-splits later
        sources = [owner[j] for j in live_sorted]
        sources += [[]] * (n_slots - len(sources))
        sources = sources[:n_slots] if policy == "fold" and \
            len(live_sorted) > n_slots else sources
        if policy == "fold" and len(live_sorted) > n_slots:
            # more survivors than slots: extra survivors fold onto the
            # last slot (their rows re-split evenly at scatter time)
            sources = [owner[j] for j in live_sorted[:n_slots - 1]]
            sources.append([j2 for j in live_sorted[n_slots - 1:]
                            for j2 in owner[j]])
        world_after = LaneWorld(
            n_slots=n_slots,
            live=tuple(j < sum(live_new) if policy == "pad" else True
                       for j in range(n_slots)))
    return sources, world_after, adopter


# -- harvest / scatter --------------------------------------------------------


def harvest_trailing(state, r: int) -> Tuple[List[np.ndarray], int]:
    """Host-side harvest at the deposited frontier ``r``: every slot's
    unconsumed *padded* rows (padded rows can carry real trailing-matrix
    content — writebacks land on them — so all of them ride; see module
    docstring for why the Gram matrix is exactly preserved), live columns
    ``[r*b : n]``. Returns (per-old-slot row blocks, n_remaining_cols)."""
    geom = state.geom
    cut = r * geom.b
    A = np.asarray(state.A)
    out = []
    for i in range(geom.P):
        c = min(max(cut - i * geom.m_loc_pad, 0), geom.m_loc_pad)
        out.append(A[i, c:, cut:geom.n])
    return out, geom.n - cut


def scatter_world(
    blocks: List[np.ndarray], n_cols: int, b: int, even: bool = False,
    n_live: Optional[int] = None,
) -> np.ndarray:
    """Scatter per-new-slot row blocks into the uniform SimComm layout
    ``(n_slots, m_loc_new, n_cols)``, zero-padding each slot to the max
    (``m_loc_new`` a multiple of ``b`` — the widened ``sweep_geometry``
    runs on it directly). ``even=True`` re-splits the concatenation
    evenly over the first ``n_live`` slots instead (grow / fold)."""
    n_slots = len(blocks)
    if even:
        allrows = np.concatenate(
            [blk for blk in blocks if blk.size or len(blk)], axis=0) \
            if any(len(blk) for blk in blocks) else np.zeros((0, n_cols))
        n_live = n_live if n_live is not None else n_slots
        per = -(-len(allrows) // n_live) if len(allrows) else 1
        blocks = [allrows[j * per:(j + 1) * per] if j < n_live
                  else allrows[:0] for j in range(n_slots)]
    m_loc = max(b, -(-max(len(blk) for blk in blocks) // b) * b) \
        if any(len(blk) for blk in blocks) else b
    A = np.zeros((n_slots, m_loc, n_cols), dtype=np.float32)
    for j, blk in enumerate(blocks):
        if len(blk):
            A[j, :len(blk)] = blk
    return A


# -- the controller (shared by the scheduled oracle and the orchestrator) ----


class ElasticController:
    """State machine of the elastic semantics, shared verbatim by the
    scheduled driver (``ft_caqr_sweep_elastic``) and the online
    orchestrator — the reason scheduled-vs-online SHRINK/BLANK cannot
    drift apart bitwise.

    Deaths are *noted* (after the standard buddy heal) and applied at the
    next panel boundary; ``grow`` requests queue the same way. ``result``
    splices the per-epoch partial R blocks into the final ``(k, n)`` R.
    """

    def __init__(self, semantics: Semantics, geom: SweepGeometry,
                 policy: str = "pad"):
        assert semantics in (Semantics.SHRINK, Semantics.BLANK), semantics
        assert policy in ("pad", "fold"), policy
        self.semantics = semantics
        self.policy = policy
        self.k_total = geom.k
        self.n_total = geom.n
        self.b = geom.b
        self.world = LaneWorld(n_slots=geom.P, live=(True,) * geom.P)
        self.epochs: List[EpochRecord] = []
        self.transitions: List[TransitionEvent] = []
        self._pending_dead: List[int] = []
        self._pending_grow = 0
        self._finished = False

    # -- requests ----------------------------------------------------------

    def note_deaths(self, lanes: List[int]) -> None:
        """A healed death awaiting its boundary transition."""
        self._pending_dead.extend(
            l for l in lanes if l not in self._pending_dead)

    def request_grow(self) -> None:
        """A returning lane re-joins at the next panel boundary."""
        self._pending_grow += 1

    @property
    def pending(self) -> bool:
        return bool(self._pending_dead or self._pending_grow)

    def ready(self, cursor) -> bool:
        """Transitions run only at panel boundaries (cursor at a leaf
        point, or past-the-end) — the only states with no in-flight
        tree artifacts once the pending deposit runs."""
        return self.pending and (
            cursor is None or cursor[1] == PHASE_LEAF)

    # -- the transition ----------------------------------------------------

    def _close_epoch(self, comm, state) -> Tuple[Any, int]:
        from repro.ft.online.state import deposit_boundary

        state, r = deposit_boundary(comm, state)
        if r:
            rows = np.concatenate(
                [np.asarray(x)[0] for x in state.R_rows], axis=0)
            n_e = self.n_total - self.world.col_base
            self.epochs.append(EpochRecord(
                col_base=self.world.col_base,
                R_part=np.triu(rows)[:, :n_e],
                world=self.world,
            ))
        return state, r

    def transition(self, comm, state):
        """Apply the pending transition at a panel boundary: deposit,
        record the closing epoch, harvest, re-own, and return the new
        ``(comm, state)`` with the cursor at the sub-sweep's first point
        (``(None, state)`` means the factorization completed during the
        closing epoch — only world bookkeeping changed)."""
        from repro.core.comm import SimComm
        from repro.ft.online.state import initial_sweep_state

        assert self.ready(state.cursor)
        if self._pending_dead:
            kind = ("shrink" if self.semantics is Semantics.SHRINK
                    else "blank")
            dead = tuple(self._pending_dead)
            self._pending_dead = []
        else:
            kind, dead = "grow", ()
            self._pending_grow -= 1

        if self._finished:
            # a prior transition at the final boundary already deposited
            # and recorded the closing epoch; any further pending requests
            # (e.g. a grow drawn past the end) are bookkeeping only
            r = 0
        else:
            state, r = self._close_epoch(comm, state)
        before = self.world
        sources, after, adopter = plan_transition(
            before, kind, dead, policy=self.policy)
        after = dataclasses.replace(
            after, col_base=before.col_base + r * self.b)
        self.transitions.append(TransitionEvent(
            kind=kind, frontier=r, lanes=dead, adopter=adopter,
            world_before=before, world_after=after))
        self.world = after

        if state.cursor is None:
            # the closing epoch already deposited every panel: nothing
            # left to re-mesh over — the transition is bookkeeping only
            self._finished = True
            return None, state

        blocks, n_cols = harvest_trailing(state, r)
        even = kind == "grow" or self.policy == "fold"
        merged = [np.concatenate([blocks[i] for i in srcs], axis=0)
                  if srcs else blocks[0][:0] for srcs in sources]
        A_new = scatter_world(merged, n_cols, self.b, even=even,
                              n_live=after.n_live)
        new_comm = SimComm(after.n_slots)
        return new_comm, initial_sweep_state(
            new_comm, jnp.asarray(A_new), self.b)

    # -- completion --------------------------------------------------------

    def finish(self, comm, state, events) -> ElasticSweepResult:
        """Close the final epoch (cursor past-the-end) and splice every
        epoch's partial R into the original problem's ``(k, n)`` R."""
        if not self._finished:
            assert state.cursor is None, state.cursor
            self._close_epoch(comm, state)
            self._finished = True
        R = np.zeros((self.k_total, self.n_total), dtype=np.float32)
        for ep in self.epochs:
            nrows = min(len(ep.R_part), self.k_total - ep.col_base)
            R[ep.col_base:ep.col_base + nrows, ep.col_base:] = \
                ep.R_part[:nrows]
        return ElasticSweepResult(
            R=jnp.asarray(R), events=list(events),
            transitions=list(self.transitions), world=self.world)


# -- the scheduled elastic driver (the differential oracle) -------------------


def ft_caqr_sweep_elastic(
    A0,
    comm,
    panel_width: int,
    schedule=None,
    semantics: Semantics = Semantics.SHRINK,
    policy: str = "pad",
    grow_at=None,
    scheme=None,
) -> ElasticSweepResult:
    """Scheduled (trace-time) elastic sweep: kills fire at scheduled
    sweep points, each is healed from its buddies (the same
    ``recover_lanes`` as REBUILD), and the world re-meshes at the next
    panel boundary under ``semantics``. This is the **differential
    oracle** for the online elastic path: the orchestrator runs this
    exact controller, so a runtime-detected kill at the same point is
    bitwise-identical. ``grow_at`` (a sweep point of the world it fires
    in) schedules a re-grow.

    Schedule keys address the epoch that is *running* when the point
    comes up — after a transition the sub-sweep's panels restart at 0,
    matching how an online ``ScriptedKiller`` sees boundaries.
    """
    from repro.core.comm import SimComm
    from repro.ft.coding import XORPairScheme
    from repro.ft.driver import recover_lanes
    from repro.ft.failures import Detector
    from repro.ft.online.state import initial_sweep_state, sweep_step

    assert isinstance(comm, SimComm), "the scheduled oracle runs on SimComm"
    scheme = XORPairScheme() if scheme is None else scheme
    state = initial_sweep_state(comm, A0, panel_width)
    ctrl = ElasticController(semantics, state.geom, policy=policy)
    detector = Detector(comm.axis_size(), schedule)
    events: List[Any] = []
    while True:
        while state.cursor is not None:
            point = state.cursor
            state = sweep_step(comm, state)
            # re-encode the parity slots before this point's kills fire;
            # after a transition the generator re-derives at the new world
            # size (the MDS analogue of the XOR pairing remap)
            state = scheme.refresh(comm, state)
            newly = detector.begin_step(point)
            if newly:
                state, evs = recover_lanes(
                    comm, state, newly, point, detector.dead,
                    on_recovered=detector.revive, scheme=scheme)
                events.extend(evs)
                ctrl.note_deaths(newly)
            if point == grow_at:
                ctrl.request_grow()
            if ctrl.ready(state.cursor):
                new_comm, state = ctrl.transition(comm, state)
                if new_comm is None:
                    break
                comm = new_comm
        if not ctrl.pending:
            break
        new_comm, state = ctrl.transition(comm, state)
        if new_comm is None:
            continue  # bookkeeping-only: drain any remaining requests
        comm = new_comm
    return ctrl.finish(comm, state, events)


# -- training-loop elastic re-mesh (mesh-level helpers) ----------------------


def make_data_model_mesh(n_data: int, n_model: int, devices=None):
    devices = devices if devices is not None else jax.devices()
    need = n_data * n_model
    assert len(devices) >= need, (len(devices), need)
    arr = np.asarray(devices[:need]).reshape(n_data, n_model)
    return jax.sharding.Mesh(arr, ("data", "model"))


def shrink_mesh(mesh, dead_data_lane: int):
    """Drop one data-axis row of the mesh (the failed host's chips)."""
    devs = np.asarray(mesh.devices)
    survivors = np.delete(devs, dead_data_lane, axis=0)
    return jax.sharding.Mesh(survivors, mesh.axis_names)


def reshard(tree: Any, mesh, spec_fn=None) -> Any:
    """device_put every leaf onto the new mesh. spec_fn(path_leaf) -> P;
    default: fully replicated (parameters in pure-DP training)."""

    def put(leaf):
        spec = P() if spec_fn is None else spec_fn(leaf)
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, tree)


def rebalance_batch(global_batch: int, n_lanes_old: int, n_lanes_new: int) -> Tuple[int, int]:
    """Keep global batch constant if divisible, else shrink to the nearest
    multiple. Returns (new_global_batch, per_lane)."""
    per = global_batch // n_lanes_new
    return per * n_lanes_new, per
