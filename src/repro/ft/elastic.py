"""Elastic re-meshing: rebuild the mesh after SHRINK/REBUILD and reshard
live state onto it.

On SHRINK the data axis loses lanes: the world goes from (data=N, model=M)
to (data=N-k, model=M); parameters (replicated or model-sharded) reshard
with a device_put; the global batch either shrinks or is re-split over the
survivors. On REBUILD the mesh shape is unchanged — the new device takes the
dead one's coordinates and its state arrives from the diskless buddy store.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def make_data_model_mesh(n_data: int, n_model: int, devices=None):
    devices = devices if devices is not None else jax.devices()
    need = n_data * n_model
    assert len(devices) >= need, (len(devices), need)
    arr = np.asarray(devices[:need]).reshape(n_data, n_model)
    return jax.sharding.Mesh(arr, ("data", "model"))


def shrink_mesh(mesh, dead_data_lane: int):
    """Drop one data-axis row of the mesh (the failed host's chips)."""
    devs = np.asarray(mesh.devices)
    survivors = np.delete(devs, dead_data_lane, axis=0)
    return jax.sharding.Mesh(survivors, mesh.axis_names)


def reshard(tree: Any, mesh, spec_fn=None) -> Any:
    """device_put every leaf onto the new mesh. spec_fn(path_leaf) -> P;
    default: fully replicated (parameters in pure-DP training)."""

    def put(leaf):
        spec = P() if spec_fn is None else spec_fn(leaf)
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, tree)


def rebalance_batch(global_batch: int, n_lanes_old: int, n_lanes_new: int) -> Tuple[int, int]:
    """Keep global batch constant if divisible, else shrink to the nearest
    multiple. Returns (new_global_batch, per_lane)."""
    per = global_batch // n_lanes_new
    return per * n_lanes_new, per
