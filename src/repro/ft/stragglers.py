"""Straggler detection and mitigation.

At pod scale a slow chip/host stretches every synchronous step. The monitor
keeps an EWMA of per-lane step-report times; lanes persistently slower than
``threshold`` x the median are flagged. Policies:

  * REBALANCE — shrink the straggler's microbatch share and grow the
    fastest lanes' (kept normalized); the returned shares feed the data
    pipeline's per-lane row assignment.
  * EVICT     — treat a persistent straggler as failed: hand it to the
    fault-tolerance supervisor (SHRINK/REBUILD semantics do the rest).
  * SPECULATE — mid-sweep only (the orchestrator's segment loop): rather
    than blocking the boundary on the slow lane, recompute its sweep
    point speculatively from its XOR buddy with the proven REBUILD
    arithmetic, bitwise-check the two results, and let the first one win.
    A ``SpeculationEvent`` records each race; ``escalate_after`` races on
    the same lane escalates to EVICT (which under the elastic
    orchestrator becomes a SHRINK transition — ``repro.ft.elastic``).

On this single-host container lane timings are simulated by tests; the
policy logic is exactly what a pod deployment runs on real step reports.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, NamedTuple, Optional

import numpy as np


class StragglerPolicy(enum.Enum):
    REBALANCE = "rebalance"
    EVICT = "evict"
    IGNORE = "ignore"
    SPECULATE = "speculate"


class SpeculationEvent(NamedTuple):
    """One speculative buddy recompute of a straggler's sweep point:
    where it ran, which lane raced, whether the speculative result
    bitwise-matched the straggler's own (it must, when the lane is merely
    slow — a mismatch means corruption and the rebuilt copy wins), and
    the buddy reads the recompute cost."""

    point: tuple
    lane: int
    matched: bool
    reads: Dict[str, int]


@dataclasses.dataclass
class StragglerConfig:
    threshold: float = 1.5       # x median EWMA to flag
    patience: int = 3            # consecutive flagged steps before acting
    ewma: float = 0.5
    min_share: float = 0.25      # floor on a rebalanced lane's share
    policy: StragglerPolicy = StragglerPolicy.REBALANCE
    escalate_after: Optional[int] = None  # SPECULATE races before EVICT


class StragglerMonitor:
    def __init__(self, n_lanes: int, cfg: Optional[StragglerConfig] = None):
        self.cfg = cfg or StragglerConfig()
        self.n = n_lanes
        self.ewma: Dict[int, float] = {}
        self.flags: Dict[int, int] = {i: 0 for i in range(n_lanes)}
        self.shares: Dict[int, float] = {i: 1.0 for i in range(n_lanes)}

    def report(self, lane_times: Dict[int, float]) -> List[int]:
        """Feed one step's per-lane times; returns lanes to act on."""
        a = self.cfg.ewma
        for lane, t in lane_times.items():
            prev = self.ewma.get(lane, t)
            self.ewma[lane] = a * t + (1 - a) * prev
        med = float(np.median(list(self.ewma.values())))
        actions = []
        for lane, e in self.ewma.items():
            if e > self.cfg.threshold * med:
                self.flags[lane] += 1
                if self.flags[lane] >= self.cfg.patience:
                    actions.append(lane)
            else:
                self.flags[lane] = 0
        return actions

    def rebalance(self, straggler: int) -> Dict[int, float]:
        """Shift batch share from the straggler to the others, floor-limited.
        Shares stay normalized to sum to n (1.0 == a fair share)."""
        med = float(np.median(list(self.ewma.values())))
        slow = self.ewma[straggler]
        target = max(self.cfg.min_share, med / slow)
        delta = self.shares[straggler] - target
        self.shares[straggler] = target
        others = [l for l in self.shares if l != straggler]
        for l in others:
            self.shares[l] += delta / len(others)
        self.flags[straggler] = 0
        return dict(self.shares)

    def lane_rows(self, global_batch: int) -> Dict[int, int]:
        """Integer per-lane row counts implied by the current shares."""
        per = global_batch / self.n
        rows = {l: int(round(per * s)) for l, s in self.shares.items()}
        # fix rounding drift on the fastest lane
        drift = global_batch - sum(rows.values())
        fastest = min(self.ewma or {0: 0.0}, key=lambda l: self.ewma.get(l, 0.0))
        rows[fastest] += drift
        return rows
