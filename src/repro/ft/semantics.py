"""FT-MPI / ULFM error-handling semantics (paper §II), as a policy enum.

The paper builds on FT-MPI's communicator-recovery modes: when a process
failure is detected, the surviving world chooses how to continue. The
training supervisor (``repro.train``) executes these policies on detected
failures; the FT-CAQR sweep driver (``repro.ft.driver``) implements REBUILD
— the mode the paper's recovery algorithm (§III-B/III-C) is written for,
where the respawned rank's state is reconstructed from its re-read input
slice plus one surviving buddy per artifact.

The online orchestrator (``repro.ft.online.orchestrator``) takes the policy
as its ``semantics`` argument and applies it to *runtime-detected* deaths:
REBUILD recovers in-flight, ABORT re-raises the detection as
``LaneFailure``; SHRINK and BLANK are refused mid-factorization — every
lane owns irreplaceable rows of A, so a smaller/holed world cannot finish
the same problem (they remain training-loop policies).

>>> Semantics.REBUILD.value
'rebuild'
>>> [s.name for s in Semantics]
['SHRINK', 'BLANK', 'REBUILD', 'ABORT']
"""
from __future__ import annotations

import enum


class Semantics(enum.Enum):
    SHRINK = "shrink"    # drop the lane; survivors renumber; smaller world
    BLANK = "blank"      # keep the hole; rank invalid; survivors keep ranks
    REBUILD = "rebuild"  # respawn the rank; restore its state; same world
    ABORT = "abort"      # terminate everything (non-FT default)
