"""FT-MPI / ULFM error-handling semantics (paper §II), as a policy enum the
training supervisor executes on detected failures."""
from __future__ import annotations

import enum


class Semantics(enum.Enum):
    SHRINK = "shrink"    # drop the lane; survivors renumber; smaller world
    BLANK = "blank"      # keep the hole; rank invalid; survivors keep ranks
    REBUILD = "rebuild"  # respawn the rank; restore its state; same world
    ABORT = "abort"      # terminate everything (non-FT default)
