"""FT-MPI / ULFM error-handling semantics (paper §II), as a policy enum.

The paper builds on FT-MPI's communicator-recovery modes: when a process
failure is detected, the surviving world chooses how to continue. The
training supervisor (``repro.train``) executes these policies on detected
failures; the FT-CAQR sweep driver (``repro.ft.driver``) implements REBUILD
— the mode the paper's recovery algorithm (§III-B/III-C) is written for,
where the respawned rank's state is reconstructed from its re-read input
slice plus one surviving buddy per artifact.

The online orchestrator (``repro.ft.online.orchestrator``) takes the policy
as its ``semantics`` argument and applies it to *runtime-detected* deaths:
REBUILD recovers in-flight, ABORT re-raises the detection as
``LaneFailure``, and SHRINK/BLANK continue *elastically*
(``repro.ft.elastic``): the dead lane's rows are first healed from its XOR
buddy with the REBUILD arithmetic, then at the next panel boundary a
survivor adopts them (SHRINK — survivors renumber into a smaller world) or
the hole stays as a masked no-op lane (BLANK), and the sweep resumes as a
new epoch on the re-owned trailing submatrix. The scheduled driver
(``repro.ft.driver.ft_caqr_sweep``) accepts the same policy and delegates
SHRINK/BLANK to the scheduled elastic driver, the differential oracle of
the online path.

>>> Semantics.REBUILD.value
'rebuild'
>>> [s.name for s in Semantics]
['SHRINK', 'BLANK', 'REBUILD', 'ABORT']
"""
from __future__ import annotations

import enum


class Semantics(enum.Enum):
    SHRINK = "shrink"    # drop the lane; survivors renumber; smaller world
    BLANK = "blank"      # keep the hole; rank invalid; survivors keep ranks
    REBUILD = "rebuild"  # respawn the rank; restore its state; same world
    ABORT = "abort"      # terminate everything (non-FT default)
