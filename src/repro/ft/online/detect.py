"""Runtime failure detection for the online sweep (DESIGN.md §9).

No trace-time schedule: the orchestrator polls a detector at every segment
boundary and deaths are *discovered*, not scripted. Detection is the one
place the simulation meets the paper's §II model — FT-MPI surfaces a death
to survivors at their next collective involving the failed rank; here the
mask-based death representation (``comm.poison`` NaN-floods everything the
lane holds) makes the same information observable in-band: a designated
*sentinel slot* per lane goes NaN.

Detectors (the ``OnlineDetector`` protocol):

* ``NaNSentinelDetector`` — probes sentinel slots of the lane-sharded state
  between segments (element ``[0, 0]`` of each lane's block-row slice, plus
  the in-flight R/C' heads). O(P) scalars transferred per poll; a ``deep``
  mode scans every float leaf for hardening/debugging. Latency bound: a
  death is reported at the first boundary after it happens — one segment.
  Also exposes the split non-blocking form ``probe``/``collect``: ``probe``
  dispatches ONE compiled sentinel reduction and returns a handle,
  ``collect`` materializes it — the async orchestrator dispatches the next
  segment between the two, hiding the transfer behind device work.
* ``FailStopDetector`` — injectable test double: the harness ``declare``-s a
  death and the detector reports it after ``report_delay`` polls (0 = the
  very next boundary; 1 = one segment late, the false-negative case).
* ``DelayedDetector`` — wraps any detector and suppresses each lane's first
  ``miss`` positive reports: models a detector false-negative on an
  otherwise-real probe (used by the one-segment-late regression test).

Fault injectors (the *cause*, distinct from detection): boundary hooks the
orchestrator runs before each poll, poisoning state exactly like a
scheduled death does — ``ScriptedKiller`` (die at a chosen sweep point) and
``WallClockKiller`` (die at the first boundary past a wall-clock deadline,
the genuinely unscripted demo). Both leave discovery entirely to the
detector.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Protocol, \
    Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft.failures import prev_sweep_point
from repro.ft.online.state import SweepState, state_lane_axes


class OnlineDetector(Protocol):
    """Runtime failure detector: polled by the orchestrator at every
    segment boundary; returns the lanes it believes died since the last
    poll (never lanes it already reported — the orchestrator rebuilds them
    immediately, so a repeat report would re-kill a healthy respawn)."""

    def poll(self, comm, state: SweepState) -> List[int]:  # pragma: no cover
        ...

    def revive(self, lane: int) -> None:  # pragma: no cover
        """Optional: the orchestrator announces a completed REBUILD so the
        detector re-arms for ``lane`` immediately — without it, a
        stateful detector needs one clean poll before it can see the same
        lane die again, and back-to-back deaths at consecutive boundaries
        would go unreported."""


def _sentinel_values(comm, state: SweepState) -> np.ndarray:
    """One float per lane: the sum of this lane's sentinel slots (NaN iff
    any probe slot is NaN). Probes the block-row head plus whatever
    in-flight per-lane artifact heads exist at the current cursor."""
    P = comm.axis_size()
    probes = []
    for field in ("A", "window", "R_leaf", "R_carry", "C_prime"):
        x = getattr(state, field)
        if x is not None:
            probes.append(x.reshape(P, -1)[:, 0])
    return np.asarray(jnp.sum(jnp.stack(probes), axis=0))


# One jitted sentinel reduction per lane count; jax's cache specializes per
# state treedef (= per cursor), exactly like the orchestrator's segments.
_SENTINEL_FNS: Dict[int, Callable] = {}


def _sentinel_program(P: int) -> Callable:
    """Compiled form of ``_sentinel_values``: the whole probe (reshape +
    head-gather + sum) is ONE dispatch returning a length-``P`` device
    array, instead of ~7 eager ops per poll. The caller decides when to
    materialize it — that split is what makes the probe non-blocking."""
    fn = _SENTINEL_FNS.get(P)
    if fn is None:
        def sent(state: SweepState):
            probes = []
            for field in ("A", "window", "R_leaf", "R_carry", "C_prime"):
                x = getattr(state, field)
                if x is not None:
                    probes.append(x.reshape(P, -1)[:, 0])
            return jnp.sum(jnp.stack(probes), axis=0)

        fn = jax.jit(sent)
        _SENTINEL_FNS[P] = fn
    return fn


def _deep_nan_lanes(comm, state: SweepState) -> Set[int]:
    """Full scan: any-NaN per lane over every float leaf (lane axis from
    ``state_lane_axes``)."""
    P = comm.axis_size()
    hit: Set[int] = set()
    axes = state_lane_axes(state)
    import jax

    for x, ax in zip(jax.tree_util.tree_leaves(state),
                     jax.tree_util.tree_leaves(axes)):
        if not jnp.issubdtype(x.dtype, jnp.floating):
            continue
        per_lane = jnp.any(jnp.isnan(jnp.moveaxis(x, ax, 0).reshape(P, -1)),
                           axis=1)
        hit.update(int(i) for i in np.flatnonzero(np.asarray(per_lane)))
    return hit


class NaNSentinelDetector:
    """Sentinel-slot NaN probe over the lane-sharded state.

    The mask-based death model NaN-floods everything a dead lane holds, so
    probing one designated slot per live artifact detects any fail-stop
    death at the next boundary. ``deep=True`` scans every float leaf
    instead (O(state) work — debugging / belt-and-braces). Reports each
    lane once per death: after the orchestrator rebuilds it the sentinels
    are finite again and the lane re-arms.

    Caveat (documented, inherent to in-band detection): a workload whose
    *data* legitimately contains NaN would false-positive; the CAQR sweep
    on finite input never produces NaN in a live lane.
    """

    def __init__(self, deep: bool = False):
        self.deep = deep
        self._reported: Set[int] = set()

    def poll(self, comm, state: SweepState) -> List[int]:
        if self.deep:
            hit = _deep_nan_lanes(comm, state)
        else:
            hit = {int(i)
                   for i in np.flatnonzero(np.isnan(_sentinel_values(comm, state)))}
        newly = sorted(hit - self._reported)
        self._reported = hit  # healed lanes re-arm automatically
        return newly

    # -- non-blocking probe (the async orchestrator's poll) -----------------

    def probe(self, comm, state: SweepState) -> Any:
        """Dispatch the sentinel reduction WITHOUT materializing it and
        return an opaque handle for :meth:`collect`. Under jax's async
        dispatch the reduction runs while the host does other work (the
        async orchestrator dispatches the next segment in between) — the
        blocking transfer is deferred to ``collect``. ``deep`` mode has no
        compiled form; its handle just defers the full scan."""
        if self.deep:
            return ("deep", state)
        return ("sent", _sentinel_program(comm.axis_size())(state))

    def collect(self, comm, handle: Any) -> List[int]:
        """Materialize a :meth:`probe` handle into the newly-dead list —
        the blocking half of the split poll. Same report-once semantics as
        ``poll``: a lane is returned at most once per death and re-arms
        after ``revive`` (or automatically once its sentinels are finite)."""
        kind, payload = handle
        if kind == "deep":
            hit = _deep_nan_lanes(comm, payload)
        else:
            hit = {int(i) for i in np.flatnonzero(np.isnan(np.asarray(payload)))}
        newly = sorted(hit - self._reported)
        self._reported = hit
        return newly

    def revive(self, lane: int) -> None:
        self._reported.discard(lane)

    def reset(self) -> None:
        """Re-arm every sentinel. The elastic orchestrator calls this
        after a world transition: lane numbering changed, so per-lane
        report state from the old world is meaningless (the probe itself
        is shape-agnostic and works on the new layout unchanged)."""
        self._reported.clear()


class FailStopDetector:
    """Injectable fail-stop oracle for tests: the harness declares deaths,
    the detector surfaces each one ``report_delay`` polls later (0 = next
    boundary — the fail-fast model; 1 = one segment late — the
    false-negative latency case)."""

    def __init__(self, report_delay: int = 0):
        self.report_delay = report_delay
        self._pending: Dict[int, int] = {}  # lane -> polls still to wait

    def declare(self, lane: int) -> None:
        self._pending.setdefault(lane, self.report_delay)

    def poll(self, comm, state: SweepState) -> List[int]:
        ready = sorted(l for l, d in self._pending.items() if d <= 0)
        for l in list(self._pending):
            if l in ready:
                del self._pending[l]
            else:
                self._pending[l] -= 1
        return ready

    def revive(self, lane: int) -> None:
        pass  # reports are one-shot; a new death needs a new declare()


class DelayedDetector:
    """Suppress each lane's first ``miss`` positive reports from ``inner``
    — a detector false-negative model over a real probe. The suppressed
    death surfaces at a later boundary (the NaN sentinels are still NaN),
    so the one-segment-late recovery path is exercised end to end."""

    def __init__(self, inner: OnlineDetector, miss: int = 1):
        self.inner = inner
        self.miss = miss
        self._suppressed: Dict[int, int] = {}

    def poll(self, comm, state: SweepState) -> List[int]:
        out = []
        for lane in self.inner.poll(comm, state):
            seen = self._suppressed.get(lane, 0)
            if seen < self.miss:
                self._suppressed[lane] = seen + 1
                # re-arm the inner detector so it re-reports next poll
                rearm = getattr(self.inner, "_reported", None)
                if rearm is not None:
                    rearm.discard(lane)
            else:
                self._suppressed.pop(lane, None)
                out.append(lane)
        return out

    def revive(self, lane: int) -> None:
        self._suppressed.pop(lane, None)
        revive = getattr(self.inner, "revive", None)
        if revive is not None:
            revive(lane)


# -- fault injectors (boundary hooks; the cause, not the detection) ----------


def _just_completed(state: SweepState) -> Optional[Tuple[int, str, int]]:
    return prev_sweep_point(state.cursor, state.geom.n_panels,
                            state.geom.levels)


class ScriptedKiller:
    """Boundary hook: poison ``lanes`` when the just-completed sweep point
    matches a key of ``events`` — the runtime enactment of what a
    ``FailureSchedule`` scripts at trace time (each event fires once).
    Discovery is left entirely to the detector."""

    def __init__(self, events: Dict[Tuple[int, str, int], Iterable[int]]):
        self.events = {k: list(v) for k, v in events.items()}
        self._fired: Set[Tuple[Tuple[int, str, int], int]] = set()

    def __call__(self, comm, state: SweepState) -> SweepState:
        from repro.ft.driver import obliterate_state

        point = _just_completed(state)
        for lane in self.events.get(point, []):
            if (point, lane) not in self._fired:
                self._fired.add((point, lane))
                state = obliterate_state(comm, state, lane)
        return state


class WallClockKiller:
    """Boundary hook: poison ``lane`` at the first segment boundary more
    than ``after_s`` wall-clock seconds after the hook's first invocation —
    a death whose sweep position is chosen by the clock, not the trace
    (``examples/online_recovery.py``). Records where it struck in
    ``.struck_at``."""

    def __init__(self, after_s: float, lane: int, clock=time.monotonic):
        self.after_s = after_s
        self.lane = lane
        self.clock = clock  # injectable for deterministic tests (fake clock)
        self._t0: Optional[float] = None
        self.struck_at: Optional[Tuple[int, str, int]] = None

    def __call__(self, comm, state: SweepState) -> SweepState:
        from repro.ft.driver import obliterate_state

        now = self.clock()
        if self._t0 is None:
            self._t0 = now
        if self.struck_at is None and now - self._t0 >= self.after_s \
                and state.cursor is not None:
            self.struck_at = _just_completed(state)
            if self.struck_at is not None:  # not before the first point
                state = obliterate_state(comm, state, self.lane)
        return state
