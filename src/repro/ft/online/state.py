"""The reified sweep state machine: ``SweepState`` + ``sweep_step``.

PRs 2-4 ran the FT-CAQR sweep as ONE monolithic program whose loop state
lived in Python locals of ``FTSweepDriver.run`` — fine for trace-time
``FailureSchedule`` simulation, but the paper's recovery protocol is
*online*: a process dies at an arbitrary wall-clock moment and survivors
discover it at the next collective (Coti 2016 §II). This module extracts
the driver's implicit loop state into an explicit, serializable pytree and
a pure one-point transition so execution can be suspended, persisted,
resumed, and interleaved with *runtime* failure detection
(``repro.ft.online.detect`` / ``repro.ft.online.orchestrator``).

``SweepState``
    Everything the sweep holds between two interruptible points: the
    working matrix and re-readable source, the in-flight panel artifacts
    (leaf WY factors, the TSQR butterfly ladder, C' and the per-level
    trailing bundles), the per-panel stored outputs, and the **cursor** —
    the next ``sweep_point(panel, phase, level)`` to execute. The cursor
    (with the static ``SweepGeometry``) is pytree *aux data*: two states at
    different points are different treedefs, so ``jax.jit(sweep_step)``
    specializes per point with no retrace hazards.

``sweep_step(comm, state) -> state``
    Executes exactly one sweep point — the work between the previous
    recoverable boundary and ``state.cursor`` — and advances the cursor.
    It calls the *same* single-level primitives the monolithic sweep is
    built from (``ft_tsqr_level``, ``trailing_combine_level``,
    ``_leaf_apply``, the ``caqr`` geometry/deposit helpers), in the same
    order, so iterating it to completion is **bit-identical** to the
    monolithic windowed sweep — ``FTSweepDriver.run`` is now literally this
    loop (there is no second floating-point program to drift).

Cursor semantics (DESIGN.md §9): the boundary state after executing point
``p`` is exactly the state the monolithic driver had at ``_checkpoint(p)``.
Work that the monolithic driver ran *between* checkpoints is assigned to
the segment that ENDS at the next point: panel ``k``'s writeback/deposit
(which follows its last trailing checkpoint) runs at the start of the
``(k+1, leaf)`` segment, and the final panel's deposit plus R assembly run
in ``finalize``. A death injected at a boundary therefore corresponds
one-to-one to a ``FailureSchedule`` death at the just-completed point.

Serialization: ``sweep_state_to_host`` / ``sweep_state_from_host`` flatten
a state to named numpy arrays plus a JSON-able meta record (geometry,
cursor, tuple arities) — the wire format behind ``repro.ckpt``'s
``save_sweep_state`` and the diskless mid-sweep snapshots.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.caqr import (
    PanelFactors,
    SweepGeometry,
    advance_columns,
    extract_r_rows,
    make_panel_factors,
    pad_bundle,
    pad_to_geometry,
    panel_geometry,
    sweep_geometry,
)
from repro.core.householder import householder_qr_masked
from repro.core.trailing import (
    RecoveryBundle,
    _leaf_apply,
    _writeback,
    trailing_combine_level,
)
from repro.core.tsqr import DistTSQRFactors, _levels, ft_tsqr_level
from repro.ft.failures import (
    PHASE_LEAF,
    PHASE_TSQR,
    PHASE_TRAILING,
    next_sweep_point,
    sweep_point,
)

Cursor = Optional[Tuple[int, str, int]]

# Dynamic (pytree-children) fields of SweepState, in flattening order.
_ARRAY_FIELDS = (
    "A0", "A",
    "window", "leaf_Y", "leaf_T", "R_leaf", "R_carry",
    "Y2s", "Ts", "level_Y2", "level_T",
    "C_local", "C_prime", "Ws", "Cs_self", "Cs_buddy", "tops",
    "factors", "R_rows", "bundles",
    "code",
)

# The sweep-state wire format version written by default. v1 excluded the
# coded parity slots as derivable state (a resumed sweep re-encodes at its
# first boundary) — but that re-encode is a window of vulnerability: a
# multi-death present AT the resume boundary can only be joint-decoded from
# the parity as persisted (`SweepOrchestrator._resume_boundary_pass`), which
# v1 threw away. v2 serializes `SweepState.code`; v1 stays loadable (the
# regression test writes and reloads both).
WIRE_VERSION = 2

# Fields excluded from wire format v1 (the v2 writer keeps them).
_V1_EXCLUDED_FIELDS = ("code",)


@dataclasses.dataclass(frozen=True)
class SweepState:
    """Explicit loop state of the windowed FT-CAQR sweep (a jax pytree).

    Static aux data: ``geom`` (the padded ``SweepGeometry``) and ``cursor``
    (the next sweep point; ``None`` when every point has executed and only
    ``finalize`` remains). Everything else is per-lane array state in the
    SimComm layout (lane axis per leaf where ``state_lane_axes`` says —
    position 0 for block/leaf arrays, position 1 for level-stacked stacks).

    In-flight fields are ``None`` (empty tuples for the growing ladders)
    outside the phase that defines them — exactly when the monolithic
    driver's corresponding locals were unset.
    """

    geom: SweepGeometry
    cursor: Cursor
    # the re-readable data source (padded; never poisoned) + working matrix
    A0: Any
    A: Any
    # in-flight panel state (what a mid-panel death obliterates)
    window: Any = None
    leaf_Y: Any = None
    leaf_T: Any = None
    R_leaf: Any = None
    R_carry: Any = None
    Y2s: Tuple = ()          # TSQR butterfly ladder, one entry per level
    Ts: Tuple = ()
    level_Y2: Any = None     # stacked ladder (L, [P,] b, b) — trailing phase
    level_T: Any = None
    C_local: Any = None      # leaf-applied live window
    C_prime: Any = None      # running C' between trailing levels
    Ws: Tuple = ()           # per-level trailing bundle slices
    Cs_self: Tuple = ()
    Cs_buddy: Tuple = ()
    tops: Tuple = ()
    # stored outputs, one entry per completed (deposited) panel
    factors: Tuple = ()      # PanelFactors
    R_rows: Tuple = ()
    bundles: Tuple = ()      # RecoveryBundle
    # coded checksum slots (repro.ft.coding): one (f, *byte_shape) uint8
    # parity per protected leaf, re-encoded at every boundary by the
    # scheme's refresh; None under the plain XOR scheme. No lane axis —
    # the parity slots model dedicated checksum lanes outside the compute
    # failure domain (skip-axis -1 in state_lane_axes; never poisoned;
    # serialized since wire format v2 so a resumed MDS run keeps its
    # redundancy across the restart).
    code: Any = None

    @property
    def levels(self) -> int:
        return self.geom.levels

    @property
    def done(self) -> bool:
        return self.cursor is None

    def replace(self, **kw) -> "SweepState":
        return dataclasses.replace(self, **kw)


def _state_flatten(s: SweepState):
    return tuple(getattr(s, f) for f in _ARRAY_FIELDS), (s.geom, s.cursor)


def _state_unflatten(aux, children) -> SweepState:
    geom, cursor = aux
    return SweepState(geom=geom, cursor=cursor,
                      **dict(zip(_ARRAY_FIELDS, children)))


jax.tree_util.register_pytree_node(SweepState, _state_flatten, _state_unflatten)


def initial_sweep_state(comm, A0, panel_width: int) -> SweepState:
    """Entry state: padded source matrix, cursor at the first sweep point.

    Accepts anything ``caqr_factorize`` accepts (tall / ragged / wide); the
    state machine runs at the padded ``sweep_geometry`` like the driver.
    """
    P = comm.axis_size()
    assert _levels(P) >= 1, "need at least 2 lanes to tolerate failures"
    m_loc, n = comm.local_shape(A0)
    geom = sweep_geometry(P, m_loc, n, panel_width)
    A_pad = pad_to_geometry(comm, A0, geom)
    return SweepState(geom=geom, cursor=sweep_point(0, PHASE_LEAF),
                      A0=A_pad, A=A_pad)


# -- the transition ----------------------------------------------------------


def _begin_panel_leaf(comm, s: SweepState, k: int) -> SweepState:
    """Window slice + local masked panel QR of panel ``k``."""
    geom = s.geom
    col0, _t_lane, row_start, active = panel_geometry(
        comm, k, geom.b, geom.m_loc_pad)
    window = comm.map_local(lambda A: A[:, col0:])(s.A)
    panel = comm.map_local(lambda W: W[:, : geom.b])(window)
    wy = comm.map_local(householder_qr_masked)(panel, row_start)
    return s.replace(
        window=window,
        leaf_Y=comm.where(active, wy.Y, jnp.zeros_like(wy.Y)),
        leaf_T=comm.where(active, wy.T, jnp.zeros_like(wy.T)),
        R_leaf=comm.where(active, wy.R, jnp.zeros_like(wy.R)),
    )


def _deposit_panel(comm, s: SweepState, k: int) -> SweepState:
    """Writeback + per-panel output deposit of the just-finished panel
    ``k`` (the work the monolithic driver ran after the panel's last
    trailing checkpoint), then clear the in-flight fields."""
    geom = s.geom
    col0, t_lane, row_start, active = panel_geometry(
        comm, k, geom.b, geom.m_loc_pad)
    C_out = _writeback(comm, s.C_local, s.C_prime, row_start, active)
    A = advance_columns(comm, s.A, C_out, col0)
    r_rows = extract_r_rows(comm, s.C_prime, t_lane, col0)
    bundle = pad_bundle(RecoveryBundle(
        W=jnp.stack(s.Ws),
        C_self=jnp.stack(s.Cs_self),
        C_buddy=jnp.stack(s.Cs_buddy),
        Y2=s.level_Y2,
        T=s.level_T,
        self_was_top=jnp.stack(s.tops),
    ), col0)
    pf = make_panel_factors(
        comm, s.leaf_Y, s.leaf_T, s.level_Y2, s.level_T,
        row_start, active, t_lane,
    )
    return s.replace(
        A=A,
        R_rows=s.R_rows + (r_rows,),
        bundles=s.bundles + (bundle,),
        factors=s.factors + (pf,),
        window=None, leaf_Y=None, leaf_T=None, R_leaf=None, R_carry=None,
        Y2s=(), Ts=(), level_Y2=None, level_T=None,
        C_local=None, C_prime=None, Ws=(), Cs_self=(), Cs_buddy=(), tops=(),
    )


def sweep_step(comm, state: SweepState) -> SweepState:
    """Execute exactly one sweep point (the segment ending at
    ``state.cursor``) and advance the cursor.

    Pure and Comm-generic: under ``SimComm`` it runs eagerly or under
    ``jax.jit`` (the orchestrator compiles it per cursor); under ``AxisComm``
    it is the body a ``shard_map`` segment traces
    (``repro.launch.spmd_qr.make_spmd_sweep_step``). The boundary state is
    bit-identical to the monolithic driver's at ``_checkpoint(cursor)`` —
    the driver *is* a loop over this function.
    """
    point = state.cursor
    assert point is not None, "sweep already complete; call finalize"
    geom = state.geom
    k, phase, lvl = point
    L = state.levels
    col0 = k * geom.b
    t_lane = col0 // geom.m_loc_pad

    if phase == PHASE_LEAF:
        if k > 0:
            state = _deposit_panel(comm, state, k - 1)
        state = _begin_panel_leaf(comm, state, k)
    elif phase == PHASE_TSQR:
        # the monolithic driver seeds the carry with R_leaf after the leaf
        # checkpoint — same value, assigned at the first butterfly level
        carry = state.R_leaf if lvl == 0 else state.R_carry
        R_next, Y2, T = ft_tsqr_level(comm, carry, lvl, t_lane, t_lane)
        state = state.replace(
            R_carry=R_next, Y2s=state.Y2s + (Y2,), Ts=state.Ts + (T,))
    else:  # PHASE_TRAILING
        if lvl == 0:
            # stack the ladder + leaf-apply the live window (the work the
            # monolithic driver ran between the last TSQR checkpoint and
            # the first trailing checkpoint)
            _c0, _t, row_start, active = panel_geometry(
                comm, k, geom.b, geom.m_loc_pad)
            level_Y2 = jnp.stack(state.Y2s)
            level_T = jnp.stack(state.Ts)
            dist = DistTSQRFactors(state.leaf_Y, state.leaf_T, level_Y2,
                                   level_T, state.R_leaf)
            C_local, C_prime = _leaf_apply(
                comm, dist, state.window, row_start,
                active=active, skip_consumed=True)
            state = state.replace(
                level_Y2=level_Y2, level_T=level_T, C_local=C_local,
                C_prime=comm.where(active, C_prime, jnp.zeros_like(C_prime)),
            )
        out = trailing_combine_level(
            comm, state.C_prime, state.level_Y2[lvl], state.level_T[lvl],
            lvl, t_lane, t_lane,
        )
        state = state.replace(
            C_prime=out.C_prime,
            Ws=state.Ws + (out.W,),
            Cs_self=state.Cs_self + (out.C_self,),
            Cs_buddy=state.Cs_buddy + (out.C_buddy,),
            tops=state.tops + (out.is_top,),
        )

    return state.replace(
        cursor=next_sweep_point(point, geom.n_panels, L))


def finalize(comm, state: SweepState):
    """Deposit the last panel and assemble the sweep outputs.

    Returns ``(R, factors, bundles)`` with the same layout as
    ``CAQRResult(collect_bundles=True)`` / ``FTSweepResult`` — the driver
    and the orchestrator both wrap this. Pure: the caller's state is not
    consumed (calling twice double-runs the deposit arithmetic but on the
    same inputs)."""
    from repro.core.caqr import assemble_R  # local import: cycle-free either way

    assert state.cursor is None, f"sweep not complete: at {state.cursor}"
    state = _deposit_panel(comm, state, state.geom.n_panels - 1)
    factors = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *state.factors)
    bundles = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *state.bundles)
    R = assemble_R(comm, jnp.stack(state.R_rows), state.geom)
    return R, factors, bundles


def deposit_boundary(comm, state: SweepState):
    """Flush the pending deposit at a panel boundary and return
    ``(state, r)`` where ``r`` is the number of fully deposited panels —
    the consumed-column frontier sits at ``r * geom.b``.

    Legal only at a boundary: cursor at a leaf point ``(k, leaf, 0)``
    (deposits the deferred panel ``k-1``) or past-the-end ``None``
    (deposits the last panel; do NOT also call :func:`finalize`, which
    would re-run the same deposit). The elastic transitions
    (``repro.ft.elastic``) harvest the trailing submatrix at exactly
    this frontier."""
    if state.cursor is None:
        state = _deposit_panel(comm, state, state.geom.n_panels - 1)
        return state, state.geom.n_panels
    k, phase, _ = state.cursor
    assert phase == PHASE_LEAF, f"not at a panel boundary: {state.cursor}"
    if k > 0:
        state = _deposit_panel(comm, state, k - 1)
    return state, k


def run_steps(comm, state: SweepState, max_points: Optional[int] = None
              ) -> SweepState:
    """Iterate ``sweep_step`` up to ``max_points`` times (or to completion).
    The orchestrator jits this whole call as one compiled segment, so
    ``max_points`` is the segment size."""
    n = 0
    while state.cursor is not None and (max_points is None or n < max_points):
        state = sweep_step(comm, state)
        n += 1
    return state


def panel_points(geom: SweepGeometry) -> int:
    """Sweep points per panel: leaf + L butterfly + L trailing levels."""
    return 1 + 2 * geom.levels


def run_panel_fused(comm, state: SweepState) -> SweepState:
    """Execute ALL of panel ``k``'s points (leaf + L tsqr + L trailing) as
    ONE fused dispatch — the megakernel path (``kernels.fused_sweep``).

    The cursor must sit at a leaf point (panel boundaries are the only
    legal fused boundaries — trailing level 0 needs the complete butterfly
    ladder, so there is no intermediate fusion cut). The resulting state is
    bitwise-identical to ``run_steps(comm, state, panel_points(geom))``:
    the megakernel body runs the same core entry points over the same
    ``SimComm`` program, and the panel-``(k-1)`` deposit stays outside the
    kernel exactly as ``sweep_step`` runs it at the start of the
    ``(k, leaf)`` segment.

    Engine selection follows the ``fused_sweep`` policy slot: the Pallas
    engines (compiled/interpret) embed ``SimComm`` and engage only under a
    ``SimComm``; under ``AxisComm`` (or the ``xla`` engine) the same math
    runs as one directly-traced call — still one dispatch per panel.
    ``oracle`` mode falls back to stepping.
    """
    from repro.core.comm import SimComm
    from repro.kernels import backend as _kbackend
    from repro.kernels import fused_sweep as _fused

    point = state.cursor
    assert point is not None, "sweep already complete; call finalize"
    k, phase, _lvl = point
    assert phase == PHASE_LEAF, (
        f"fused execution starts at a leaf boundary, cursor is at {point}")
    geom = state.geom
    L = state.levels

    mode = _kbackend.kernel_mode("fused_sweep")
    if mode == _kbackend.MODE_ORACLE or L < 1:
        return run_steps(comm, state, panel_points(geom))

    if k > 0:
        state = _deposit_panel(comm, state, k - 1)
    col0 = k * geom.b
    window = comm.map_local(lambda A: A[:, col0:])(state.A)

    use_pallas = isinstance(comm, SimComm) and (
        mode == _kbackend.MODE_INTERPRET
        or _kbackend.compiled_engine("fused_sweep") == _kbackend.ENGINE_PALLAS
    )
    if use_pallas:
        res = _fused.fused_panel_pallas(
            window, k=k, b=geom.b, m_loc_pad=geom.m_loc_pad, levels=L,
            interpret=mode == _kbackend.MODE_INTERPRET,
        )
    else:
        res = _fused.fused_panel_math(
            comm, window, k, b=geom.b, m_loc_pad=geom.m_loc_pad, levels=L)

    last = sweep_point(k, PHASE_TRAILING, L - 1)
    return state.replace(
        window=window,
        leaf_Y=res["leaf_Y"], leaf_T=res["leaf_T"],
        R_leaf=res["R_leaf"], R_carry=res["R_carry"],
        Y2s=tuple(res["level_Y2"][l] for l in range(L)),
        Ts=tuple(res["level_T"][l] for l in range(L)),
        level_Y2=res["level_Y2"], level_T=res["level_T"],
        C_local=res["C_local"], C_prime=res["C_prime"],
        Ws=tuple(res["Ws"][l] for l in range(L)),
        Cs_self=tuple(res["Cs_self"][l] for l in range(L)),
        Cs_buddy=tuple(res["Cs_buddy"][l] for l in range(L)),
        tops=tuple(res["tops"]),
        cursor=next_sweep_point(last, geom.n_panels, L),
    )


# -- lane-axis bookkeeping ---------------------------------------------------

_FACTORS_AXES = PanelFactors(
    leaf_Y=0, leaf_T=0, level_Y2=1, level_T=1,
    row_start=0, active=0, target=0,
)
_BUNDLE_AXES = RecoveryBundle(W=1, C_self=1, C_buddy=1, Y2=1, T=1,
                              self_was_top=1)


def state_lane_axes(state: SweepState) -> SweepState:
    """A ``SweepState``-shaped pytree of ints: the lane-axis position of
    every array leaf (SimComm layout). Drives generic death-masking
    (``repro.ft.driver.obliterate_state``), the NaN-sentinel probes, and the
    per-leaf ``shard_map`` specs of the SPMD segment runner. Structure-only:
    works on ``jax.eval_shape`` structs too."""

    def like(field, ax):
        # mirror the field's structure (None stays None; tuples map per-entry)
        return jax.tree_util.tree_map(lambda _: ax, getattr(state, field))

    axes = {f: like(f, 0) for f in _ARRAY_FIELDS}
    for f in ("level_Y2", "level_T"):
        axes[f] = like(f, 1)
    axes["factors"] = tuple(_FACTORS_AXES for _ in state.factors)
    axes["bundles"] = tuple(_BUNDLE_AXES for _ in state.bundles)
    # parity slots have NO lane axis (checksum lanes live outside the
    # compute failure domain): the -1 sentinel skips them in death
    # masking, NaN scans, and the SPMD specs (replicated)
    axes["code"] = like("code", -1)
    return SweepState(geom=state.geom, cursor=state.cursor, **axes)


# -- host serialization (the SweepState wire format, DESIGN.md §9) -----------


def _wire_excluded(version: int) -> Tuple[str, ...]:
    assert version in (1, 2), f"unknown sweep-state wire version {version}"
    return _V1_EXCLUDED_FIELDS if version == 1 else ()


def _flat_arrays(state: SweepState, version: int) -> Dict[str, Any]:
    flat: Dict[str, Any] = {}
    for f in _ARRAY_FIELDS:
        if f in _wire_excluded(version):
            continue
        v = getattr(state, f)
        if v is None:
            continue
        if isinstance(v, tuple):
            for i, entry in enumerate(v):
                if isinstance(entry, (PanelFactors, RecoveryBundle)):
                    for fld, x in zip(entry._fields, entry):
                        flat[f"{f}/{i}/{fld}"] = x
                else:
                    flat[f"{f}/{i}"] = entry
        else:
            flat[f] = v
    return flat


def sweep_state_to_host(state: SweepState,
                        version: int = WIRE_VERSION) -> Dict[str, np.ndarray]:
    """Flatten a state to named host (numpy) arrays plus a ``__meta__``
    JSON record (geometry, cursor, per-field structure) — the persistable
    wire format. Inverse: ``sweep_state_from_host``.

    ``version=2`` (default) includes the ``code`` parity slots, so a
    suspended ``MDSScheme`` run resumes with its coded redundancy intact;
    ``version=1`` writes the PR-9 format (no parity — a resumed state
    re-encodes at its first boundary and cannot joint-decode deaths present
    at the resume boundary itself)."""
    excluded = _wire_excluded(version)
    arrays = {k: np.asarray(v) for k, v in _flat_arrays(state, version).items()}
    meta = {
        "version": version,
        "geom": list(state.geom),
        "cursor": list(state.cursor) if state.cursor is not None else None,
        "none_fields": [
            f for f in _ARRAY_FIELDS
            if f not in excluded
            and not isinstance(getattr(state, f), tuple)
            and getattr(state, f) is None
        ],
        "tuple_lens": {
            f: len(getattr(state, f)) for f in _ARRAY_FIELDS
            if f not in excluded
            and isinstance(getattr(state, f), tuple)
        },
    }
    arrays["__meta__"] = np.asarray(json.dumps(meta))
    return arrays


def sweep_state_from_host(arrays: Dict[str, np.ndarray],
                          to_device: bool = True) -> SweepState:
    """Rebuild a ``SweepState`` from ``sweep_state_to_host`` output (e.g. a
    loaded ``.npz``). ``to_device=False`` keeps numpy leaves — structural
    inspection with no live jax backend needed."""
    meta = json.loads(str(arrays["__meta__"]))
    version = meta["version"]
    assert version in (1, 2), meta
    geom = SweepGeometry(*meta["geom"])
    cursor = tuple(meta["cursor"]) if meta["cursor"] is not None else None
    conv = jnp.asarray if to_device else np.asarray

    def leaf(key):
        return conv(arrays[key])

    fields: Dict[str, Any] = {}
    for f in _ARRAY_FIELDS:
        if f in _wire_excluded(version):
            fields[f] = None  # v1: parity re-encodes at the first boundary
        elif f in meta["none_fields"]:
            fields[f] = None
        elif f in meta["tuple_lens"]:
            n = meta["tuple_lens"][f]
            if f == "factors":
                fields[f] = tuple(
                    PanelFactors(**{fld: leaf(f"factors/{i}/{fld}")
                                    for fld in PanelFactors._fields})
                    for i in range(n))
            elif f == "bundles":
                fields[f] = tuple(
                    RecoveryBundle(**{fld: leaf(f"bundles/{i}/{fld}")
                                      for fld in RecoveryBundle._fields})
                    for i in range(n))
            else:
                fields[f] = tuple(leaf(f"{f}/{i}") for i in range(n))
        else:
            fields[f] = leaf(f)
    return SweepState(geom=geom, cursor=cursor, **fields)
