"""Host-side orchestrator: compiled sweep segments + runtime recovery.

This inverts the control flow of the scheduled FT path (DESIGN.md §9): the
sweep no longer runs as one traced program with a baked-in
``FailureSchedule`` — the host loops over *compiled segments* of the
reified state machine (``repro.ft.online.state.sweep_step``), and between
segments it

1. runs the registered **fault hooks** (test/demo injectors — in production
   the faults are real and this list is empty),
2. **polls the detector** (``repro.ft.online.detect``) — deaths are
   discovered, never scripted,
3. synthesizes the **REBUILD** for whatever was found, with the same
   ``obliterate_state`` / ``rebuild_state`` transitions the scheduled
   driver uses (one ``RecoveryEvent`` per death, single-source ledger and
   all), attributed to the just-completed sweep point,
4. optionally **persists** the state (diskless snapshot store or any
   ``push(state)`` callable) so an orchestrator killed mid-sweep can be
   resumed from the last boundary (``SweepOrchestrator.from_state``).

Because a boundary state is bit-identical to the monolithic driver's
checkpoint state, a death detected at the boundary after point ``p``
recovers into exactly the state a trace-time ``FailureSchedule({p: [lane]})``
run has after its REBUILD — the scheduled path stays the differential
oracle for the online path (``tests/test_online_recovery.py``).

Detection latency: the NaN-sentinel probe catches a death at the first
boundary after it happens — at most one segment late. A missed poll (a
detector false-negative) is still recoverable as long as the dead lane's
state has not crossed into a survivor through a collective: the intervening
segment must be lane-local for the dead lane (a ``leaf`` segment, or any
segment where the dead lane is not the panel's deposit root). The
one-segment-late case is regression-tested; longer blindness can
contaminate survivors and then honestly fails the NaN oracle.

Execution backends: under ``SimComm`` segments are jitted directly; for the
production SPMD path pass ``step_fn=`` a shard_map segment runner
(``repro.launch.spmd_qr.make_spmd_sweep_step``) — the state then lives as
global lane-sharded arrays between segments and all host-side death/REBUILD
masking runs through the SimComm primitives on the identical global layout.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax

from repro.core.comm import SimComm
from repro.ft.driver import FTSweepResult, RecoveryEvent, recover_lanes
from repro.ft.failures import PHASE_LEAF, LaneFailure, prev_sweep_point
from repro.ft.online.detect import NaNSentinelDetector, OnlineDetector
from repro.ft.online.state import (
    SweepState,
    finalize,
    initial_sweep_state,
    run_panel_fused,
    run_steps,
)
from repro.ft.semantics import Semantics

# One jitted segment runner per (comm, segment size); jax's own cache then
# specializes per state treedef (= per cursor), so every orchestrator over
# the same geometry shares compiled segments.
_SEGMENT_CACHE: Dict[Tuple, Callable] = {}

FaultHook = Callable[[object, SweepState], SweepState]


class SweepOrchestrator:
    """Run the FT-CAQR sweep as host-controlled segments with runtime
    failure detection and REBUILD (the paper's online execution model).

    Parameters
    ----------
    A0, comm, panel_width:
        As ``ft_caqr_sweep`` — any general shape, SimComm layout
        ``(P, m_loc, n)``. (Omit and use :meth:`from_state` to resume a
        persisted mid-sweep state instead.)
    detector:
        ``OnlineDetector`` polled at every boundary (default: the
        NaN-sentinel probe).
    segment_points:
        Sweep points per compiled segment (>= 1). Larger segments amortize
        host/dispatch overhead but widen the detection-latency window —
        ``benchmarks/bench_online.py`` measures the tradeoff.
    fused:
        Run whole-panel fused segments (``run_panel_fused`` — the
        ``kernels.fused_sweep`` megakernel path): O(1) dispatches per
        panel instead of O(points * ops), with boundaries (detector polls,
        hooks, persistence) at panel ends — the only legal fused
        boundaries. Bitwise-identical results. ``segment_points`` is
        ignored except to re-align a state resumed mid-panel. Mutually
        exclusive with ``step_fn``.
    jit_segments:
        Compile segments with ``jax.jit`` (default). ``False`` runs them
        eagerly — slower, handy for debugging.
    step_fn:
        Optional external segment backend, called as ``step_fn(state) ->
        state`` once per sweep point: the SPMD path passes the shard_map
        runner from ``repro.launch.spmd_qr.make_spmd_sweep_step``.
    fault_hooks:
        Callables ``hook(comm, state) -> state`` run at every boundary
        *before* the detector poll — test/demo fault injectors
        (``ScriptedKiller``, ``WallClockKiller``).
    store, persist_every:
        If a store is given, ``store.push(state)`` every ``persist_every``
        boundaries (default 1 = every boundary) and at the final one —
        e.g. ``repro.ckpt.diskless.SweepStateStore``.
    semantics:
        FT-MPI continuation policy on detection (``repro.ft.semantics``).
        REBUILD (default) is the paper's recovery; ABORT re-raises the
        death as ``LaneFailure``; SHRINK/BLANK are not meaningful for an
        in-flight factorization (every lane owns irreplaceable rows) and
        raise ``NotImplementedError``.
    """

    def __init__(
        self,
        A0=None,
        comm=None,
        panel_width: Optional[int] = None,
        detector: Optional[OnlineDetector] = None,
        *,
        segment_points: int = 1,
        fused: bool = False,
        jit_segments: bool = True,
        step_fn: Optional[Callable[[SweepState], SweepState]] = None,
        fault_hooks: Sequence[FaultHook] = (),
        store=None,
        persist_every: Optional[int] = None,
        semantics: Semantics = Semantics.REBUILD,
        state: Optional[SweepState] = None,
    ):
        assert comm is not None, "comm is required"
        self.comm = comm
        if state is None:
            assert A0 is not None and panel_width is not None, \
                "need (A0, panel_width) or a resume state"
            state = initial_sweep_state(comm, A0, panel_width)
        self.state = state
        self.detector = detector if detector is not None else NaNSentinelDetector()
        assert segment_points >= 1
        self.segment_points = segment_points
        assert not (fused and step_fn is not None), (
            "fused segments replace the per-point runner; pass one or the "
            "other")
        self.fused = fused
        self.jit_segments = jit_segments
        self.step_fn = step_fn
        if step_fn is None and jit_segments:
            assert isinstance(comm, SimComm), (
                "jitted host segments need SimComm; pass step_fn= for the "
                "shard_map backend (repro.launch.spmd_qr.make_spmd_sweep_step)"
            )
        self.fault_hooks = list(fault_hooks)
        self.store = store
        if store is not None and persist_every is None:
            persist_every = 1  # a store with no cadence means every boundary
        self.persist_every = persist_every
        self.semantics = semantics
        self.events: List[RecoveryEvent] = []
        # run statistics (benchmarks read these)
        self.segments_run = 0
        self.poll_s = 0.0
        self.recover_s = 0.0

    @classmethod
    def from_state(cls, state: SweepState, comm, **kw) -> "SweepOrchestrator":
        """Resume from a persisted mid-sweep ``SweepState`` (e.g.
        ``repro.ckpt.load_sweep_state`` or a diskless snapshot). The
        recovery-event log of the previous incarnation is not carried
        over."""
        return cls(comm=comm, state=state, **kw)

    # -- segments ----------------------------------------------------------

    def _stepped(self, state: SweepState, n_points: int) -> SweepState:
        if not self.jit_segments:
            return run_steps(self.comm, state, n_points)
        key = (type(self.comm).__name__, self.comm.axis_size(), n_points)
        fn = _SEGMENT_CACHE.get(key)
        if fn is None:
            comm = self.comm
            fn = jax.jit(lambda s: run_steps(comm, s, n_points))
            _SEGMENT_CACHE[key] = fn
        return fn(state)

    def _fused_segment(self, state: SweepState) -> SweepState:
        # a state resumed mid-panel first steps to the next leaf boundary
        # (fused segments only start there), then runs whole panels
        while state.cursor is not None and state.cursor[1] != PHASE_LEAF:
            state = self._stepped(state, 1)
        if state.cursor is None:
            return state
        if not self.jit_segments:
            return run_panel_fused(self.comm, state)
        key = (type(self.comm).__name__, self.comm.axis_size(), "fused")
        fn = _SEGMENT_CACHE.get(key)
        if fn is None:
            comm = self.comm
            fn = jax.jit(lambda s: run_panel_fused(comm, s))
            _SEGMENT_CACHE[key] = fn
        return fn(state)

    def _segment(self, state: SweepState) -> SweepState:
        if self.step_fn is not None:
            for _ in range(self.segment_points):
                if state.cursor is None:
                    break
                state = self.step_fn(state)
            return state
        if self.fused:
            return self._fused_segment(state)
        return self._stepped(state, self.segment_points)

    # -- the host loop -----------------------------------------------------

    def run(self) -> FTSweepResult:
        """Drive the sweep to completion; returns the same ``FTSweepResult``
        as ``ft_caqr_sweep`` (bit-identical to the failure-free sweep no
        matter what the detector found, or ``UnrecoverableFailure``)."""
        geom = self.state.geom
        levels = geom.levels
        boundary = 0
        while True:
            if self.state.cursor is not None:
                self.state = self._segment(self.state)
                self.segments_run += 1
            boundary += 1
            # the just-completed point = the recoverable boundary any death
            # discovered now is attributed to
            point = prev_sweep_point(self.state.cursor, geom.n_panels, levels)
            for hook in self.fault_hooks:
                self.state = hook(self.comm, self.state)
            t0 = time.perf_counter()
            newly = list(self.detector.poll(self.comm, self.state))
            self.poll_s += time.perf_counter() - t0
            if newly:
                self._recover(newly, point)
            if self.store is not None and self.persist_every and (
                    boundary % self.persist_every == 0
                    or self.state.cursor is None):
                self.store.push(self.state)
            if self.state.cursor is None:
                break
        R, factors, bundles = finalize(self.comm, self.state)
        return FTSweepResult(R=R, factors=factors, bundles=bundles,
                             events=self.events)

    # -- recovery ----------------------------------------------------------

    def _recover(self, newly: List[int], point) -> None:
        assert point is not None, "death detected before any sweep point ran"
        if self.semantics is Semantics.ABORT:
            raise LaneFailure(newly[0], point)
        if self.semantics is not Semantics.REBUILD:
            raise NotImplementedError(
                f"{self.semantics} is not meaningful mid-factorization: "
                "every lane owns irreplaceable rows of A (use REBUILD)"
            )
        dead = set(newly)

        def on_recovered(lane: int) -> None:
            dead.discard(lane)
            # announce the respawn so the detector re-arms for this lane
            # immediately (back-to-back deaths at consecutive boundaries
            # must still be seen)
            revive = getattr(self.detector, "revive", None)
            if revive is not None:
                revive(lane)

        # the SAME strike-then-rebuild protocol as the scheduled driver's
        # checkpoint — shared code, so the scheduled-vs-online bitwise
        # equivalence cannot drift apart in one copy
        self.state, events = recover_lanes(
            self.comm, self.state, newly, point, dead,
            sync=lambda s: jax.block_until_ready(
                jax.tree_util.tree_leaves(s)),
            on_recovered=on_recovered,
        )
        self.recover_s += sum(e.elapsed_s for e in events)
        self.events.extend(events)


def ft_caqr_sweep_online(
    A0,
    comm,
    panel_width: int,
    detector: Optional[OnlineDetector] = None,
    **kw,
) -> FTSweepResult:
    """One-call form of the online path: ``SweepOrchestrator(...).run()``.

    The online counterpart of ``ft_caqr_sweep`` — same result layout, but
    failures are discovered by ``detector`` at runtime instead of scripted
    by a ``FailureSchedule``."""
    return SweepOrchestrator(A0, comm, panel_width, detector, **kw).run()
