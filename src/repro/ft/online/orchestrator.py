"""Host-side orchestrator: compiled sweep segments + runtime recovery.

This inverts the control flow of the scheduled FT path (DESIGN.md §9): the
sweep no longer runs as one traced program with a baked-in
``FailureSchedule`` — the host loops over *compiled segments* of the
reified state machine (``repro.ft.online.state.sweep_step``), and between
segments it

1. runs the registered **fault hooks** (test/demo injectors — in production
   the faults are real and this list is empty),
2. **polls the detector** (``repro.ft.online.detect``) — deaths are
   discovered, never scripted,
3. synthesizes the **REBUILD** for whatever was found, with the same
   ``obliterate_state`` / ``rebuild_state`` transitions the scheduled
   driver uses (one ``RecoveryEvent`` per death, single-source ledger and
   all), attributed to the just-completed sweep point,
4. optionally **persists** the state (diskless snapshot store or any
   ``push(state)`` callable) so an orchestrator killed mid-sweep can be
   resumed from the last boundary (``SweepOrchestrator.from_state``).

Because a boundary state is bit-identical to the monolithic driver's
checkpoint state, a death detected at the boundary after point ``p``
recovers into exactly the state a trace-time ``FailureSchedule({p: [lane]})``
run has after its REBUILD — the scheduled path stays the differential
oracle for the online path (``tests/test_online_recovery.py``).

Detection latency: the NaN-sentinel probe catches a death at the first
boundary after it happens — at most one segment late. A missed poll (a
detector false-negative) is still recoverable as long as the dead lane's
state has not crossed into a survivor through a collective: the intervening
segment must be lane-local for the dead lane (a ``leaf`` segment, or any
segment where the dead lane is not the panel's deposit root). The
one-segment-late case is regression-tested; longer blindness can
contaminate survivors and then honestly fails the NaN oracle.

Execution backends: under ``SimComm`` segments are jitted directly; for the
production SPMD path pass ``step_fn=`` a shard_map segment runner
(``repro.launch.spmd_qr.make_spmd_sweep_step``) — the state then lives as
global lane-sharded arrays between segments and all host-side death/REBUILD
masking runs through the SimComm primitives on the identical global layout.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.comm import SimComm
from repro.ft.coding import CodingScheme, XORPairScheme
from repro.ft.driver import (
    FTSweepResult,
    RecoveryEvent,
    obliterate_state,
    recover_lanes,
    rebuild_state,
)
from repro.ft.elastic import ElasticController, ElasticSweepResult
from repro.ft.failures import PHASE_LEAF, LaneFailure, prev_sweep_point
from repro.ft.online.detect import NaNSentinelDetector, OnlineDetector
from repro.ft.online.state import (
    SweepState,
    finalize,
    initial_sweep_state,
    run_panel_fused,
    run_steps,
    state_lane_axes,
)
from repro.ft.semantics import Semantics
from repro.ft.stragglers import (
    SpeculationEvent,
    StragglerMonitor,
    StragglerPolicy,
)

# One jitted segment runner per (comm, segment size); jax's own cache then
# specializes per state treedef (= per cursor), so every orchestrator over
# the same geometry shares compiled segments.
_SEGMENT_CACHE: Dict[Tuple, Callable] = {}

FaultHook = Callable[[object, SweepState], SweepState]
BoundaryHook = Callable[["SweepOrchestrator"], None]


def compiled_segment(comm, n_points: int) -> Callable[[SweepState], SweepState]:
    """The RESIDENT compiled segment runner: a process-wide jitted
    ``run_steps(comm, state, n_points)`` shared by every caller over the
    same ``(comm kind, P, segment size)`` — the orchestrator's segments and
    the multi-tenant ``repro.serve.qr_service`` slots all dispatch through
    the same callable. jax's jit cache then specializes per state treedef
    (= per geometry + cursor), so two tenants at the same bucket and sweep
    point share one compiled program; after one warm sweep per bucket no
    new compilation happens no matter how many requests flow through
    (``fn._cache_size()`` counts the resident specializations)."""
    key = (type(comm).__name__, comm.axis_size(), n_points)
    fn = _SEGMENT_CACHE.get(key)
    if fn is None:
        fn = jax.jit(lambda s: run_steps(comm, s, n_points))
        _SEGMENT_CACHE[key] = fn
    return fn


class SweepOrchestrator:
    """Run the FT-CAQR sweep as host-controlled segments with runtime
    failure detection and REBUILD (the paper's online execution model).

    Parameters
    ----------
    A0, comm, panel_width:
        As ``ft_caqr_sweep`` — any general shape, SimComm layout
        ``(P, m_loc, n)``. (Omit and use :meth:`from_state` to resume a
        persisted mid-sweep state instead.)
    detector:
        ``OnlineDetector`` polled at every boundary (default: the
        NaN-sentinel probe).
    segment_points:
        Sweep points per compiled segment (>= 1). Larger segments amortize
        host/dispatch overhead but widen the detection-latency window —
        ``benchmarks/bench_online.py`` measures the tradeoff.
    fused:
        Run whole-panel fused segments (``run_panel_fused`` — the
        ``kernels.fused_sweep`` megakernel path): O(1) dispatches per
        panel instead of O(points * ops), with boundaries (detector polls,
        hooks, persistence) at panel ends — the only legal fused
        boundaries. Bitwise-identical results. ``segment_points`` is
        ignored except to re-align a state resumed mid-panel. Mutually
        exclusive with ``step_fn``.
    jit_segments:
        Compile segments with ``jax.jit`` (default). ``False`` runs them
        eagerly — slower, handy for debugging.
    step_fn:
        Optional external segment backend, called as ``step_fn(state) ->
        state`` once per sweep point: the SPMD path passes the shard_map
        runner from ``repro.launch.spmd_qr.make_spmd_sweep_step``.
    fault_hooks:
        Callables ``hook(comm, state) -> state`` run at every boundary
        *before* the detector poll — test/demo fault injectors
        (``ScriptedKiller``, ``WallClockKiller``).
    boundary_hooks:
        Callables ``hook(orchestrator)`` run at every boundary *after*
        detection + recovery, when the state is healed and consistent —
        the admission surface: a serving layer can inspect
        ``orch.state.cursor``, swap work in at a panel boundary, or
        harvest per-boundary telemetry. Mutating ``orch.state`` here is
        legal exactly when the cursor sits at a panel boundary
        (``deposit_boundary`` semantics) — ``repro.serve.qr_service``
        builds its continuous-batching admission on this contract.
    store, persist_every:
        If a store is given, ``store.push(state)`` every ``persist_every``
        boundaries (default 1 = every boundary) and at the final one —
        e.g. ``repro.ckpt.diskless.SweepStateStore``.
    semantics:
        FT-MPI continuation policy on detection (``repro.ft.semantics``).
        REBUILD (default) is the paper's recovery; ABORT re-raises the
        death as ``LaneFailure``; SHRINK/BLANK continue elastically
        (``repro.ft.elastic``): the death is healed from its XOR buddies
        like a REBUILD, then at the next panel boundary the world
        re-meshes (survivor adopts the rows / hole stays masked) and the
        sweep resumes as a new epoch. Elastic runs return
        ``ElasticSweepResult`` (host-spliced R) instead of
        ``FTSweepResult``.
    elastic_policy:
        Slot policy of a shrunken world: ``"pad"`` (default — ceil-pow2
        slots with zero-row ghosts) or ``"fold"`` (floor-pow2, rows
        re-split; the SPMD re-mesh uses this so the new mesh fits on
        surviving devices).
    step_factory:
        Required with ``step_fn`` + elastic semantics: called as
        ``step_factory(n_slots)`` after a transition to build the new
        world's segment runner
        (``repro.launch.spmd_qr.make_spmd_step_factory``).
    grow_at:
        Optional sweep point; when it completes, a returning lane re-joins
        at the next panel boundary (``ElasticController.request_grow``).
    straggler_monitor, lane_clock:
        Wire a ``repro.ft.stragglers.StragglerMonitor`` into the segment
        loop: ``lane_clock(comm, state)`` returns per-lane times for the
        just-run segment (tests simulate; a pod reports real step times).
        Policy SPECULATE races a buddy recompute of a flagged lane's
        sweep point against the straggler (first result wins,
        bitwise-checked, logged as ``SpeculationEvent`` in
        ``self.speculations``); EVICT (or ``escalate_after`` exhausted)
        poisons the lane and escalates to a SHRINK transition.
    async_segments:
        Double-buffered segment execution: dispatch segment N+1 before
        collecting the detector probe on segment N's boundary (the probe
        itself is the split non-blocking ``probe``/``collect`` form when
        the detector has one). Results are bitwise-identical to the sync
        loop — a fault-hook mutation or a detected death discards the
        in-flight speculation and re-dispatches from the recovered state.
        REBUILD/ABORT semantics only (no elastic/straggler/fused
        composition). ``benchmarks/bench_train.py`` gates async strictly
        cheaper per boundary than sync.
    """

    def __init__(
        self,
        A0=None,
        comm=None,
        panel_width: Optional[int] = None,
        detector: Optional[OnlineDetector] = None,
        *,
        segment_points: int = 1,
        fused: bool = False,
        jit_segments: bool = True,
        step_fn: Optional[Callable[[SweepState], SweepState]] = None,
        fault_hooks: Sequence[FaultHook] = (),
        boundary_hooks: Sequence[BoundaryHook] = (),
        store=None,
        persist_every: Optional[int] = None,
        semantics: Semantics = Semantics.REBUILD,
        state: Optional[SweepState] = None,
        elastic_policy: str = "pad",
        step_factory: Optional[Callable[[int], Callable]] = None,
        grow_at=None,
        straggler_monitor: Optional[StragglerMonitor] = None,
        lane_clock: Optional[Callable] = None,
        scheme: Optional[CodingScheme] = None,
        async_segments: bool = False,
    ):
        assert comm is not None, "comm is required"
        self.comm = comm
        if state is None:
            assert A0 is not None and panel_width is not None, \
                "need (A0, panel_width) or a resume state"
            state = initial_sweep_state(comm, A0, panel_width)
        self.state = state
        self.detector = detector if detector is not None else NaNSentinelDetector()
        assert segment_points >= 1
        self.segment_points = segment_points
        assert not (fused and step_fn is not None), (
            "fused segments replace the per-point runner; pass one or the "
            "other")
        self.fused = fused
        self.jit_segments = jit_segments
        self.step_fn = step_fn
        if step_fn is None and jit_segments:
            assert isinstance(comm, SimComm), (
                "jitted host segments need SimComm; pass step_fn= for the "
                "shard_map backend (repro.launch.spmd_qr.make_spmd_sweep_step)"
            )
        self.fault_hooks = list(fault_hooks)
        self.boundary_hooks = list(boundary_hooks)
        self.store = store
        if store is not None and persist_every is None:
            persist_every = 1  # a store with no cadence means every boundary
        self.persist_every = persist_every
        self.semantics = semantics
        self.elastic_policy = elastic_policy
        self.step_factory = step_factory
        self.grow_at = grow_at
        self.elastic: Optional[ElasticController] = None
        if semantics in (Semantics.SHRINK, Semantics.BLANK):
            self.elastic = ElasticController(
                semantics, self.state.geom, policy=elastic_policy)
        self.straggler_monitor = straggler_monitor
        self.lane_clock = lane_clock
        self.scheme = XORPairScheme() if scheme is None else scheme
        self.async_segments = async_segments
        if async_segments:
            assert semantics in (Semantics.REBUILD, Semantics.ABORT), (
                "async double-buffered segments compose with REBUILD/ABORT "
                "only; elastic transitions re-mesh the world mid-run and "
                "would invalidate every in-flight speculation")
            assert straggler_monitor is None and grow_at is None and not fused
        # set by from_state: a resumed orchestrator owes the resume boundary
        # a hook/poll pass BEFORE running any segment (deaths that struck
        # while the sweep was suspended are recoverable only from the
        # persisted state — under MDSScheme that needs the persisted parity
        # slots, wire-format v2)
        self._resumed = False
        self.speculations: List[SpeculationEvent] = []
        self._spec_counts: Dict[int, int] = {}
        self.events: List[RecoveryEvent] = []
        # run statistics (benchmarks read these)
        self.segments_run = 0
        self.boundaries = 0
        self.poll_s = 0.0
        self.recover_s = 0.0

    @classmethod
    def from_state(cls, state: SweepState, comm, **kw) -> "SweepOrchestrator":
        """Resume from a persisted mid-sweep ``SweepState`` (e.g.
        ``repro.ckpt.load_sweep_state`` or a diskless snapshot). The
        recovery-event log of the previous incarnation is not carried
        over."""
        orch = cls(comm=comm, state=state, **kw)
        orch._resumed = True
        return orch

    # -- segments ----------------------------------------------------------

    def _stepped(self, state: SweepState, n_points: int) -> SweepState:
        if not self.jit_segments:
            return run_steps(self.comm, state, n_points)
        return compiled_segment(self.comm, n_points)(state)

    def _fused_segment(self, state: SweepState) -> SweepState:
        # a state resumed mid-panel first steps to the next leaf boundary
        # (fused segments only start there), then runs whole panels
        while state.cursor is not None and state.cursor[1] != PHASE_LEAF:
            state = self._stepped(state, 1)
        if state.cursor is None:
            return state
        if not self.jit_segments:
            return run_panel_fused(self.comm, state)
        key = (type(self.comm).__name__, self.comm.axis_size(), "fused")
        fn = _SEGMENT_CACHE.get(key)
        if fn is None:
            comm = self.comm
            fn = jax.jit(lambda s: run_panel_fused(comm, s))
            _SEGMENT_CACHE[key] = fn
        return fn(state)

    def _segment(self, state: SweepState) -> SweepState:
        if self.step_fn is not None:
            for _ in range(self.segment_points):
                if state.cursor is None:
                    break
                state = self.step_fn(state)
            return state
        if self.fused:
            return self._fused_segment(state)
        return self._stepped(state, self.segment_points)

    # -- the host loop -----------------------------------------------------

    def run(self) -> FTSweepResult:
        """Drive the sweep to completion; returns the same ``FTSweepResult``
        as ``ft_caqr_sweep`` (bit-identical to the failure-free sweep no
        matter what the detector found, or ``UnrecoverableFailure``).
        Under SHRINK/BLANK semantics returns ``ElasticSweepResult``
        instead — epochs at different world sizes have no common lane
        layout for factors, so R is host-spliced."""
        if self._resumed:
            self._resumed = False
            self._resume_boundary_pass()
        if self.async_segments:
            return self._run_async()
        boundary = 0
        while True:
            # re-read per iteration: an elastic transition swaps in a new
            # epoch's geometry (and comm) mid-run
            geom = self.state.geom
            levels = geom.levels
            if self.state.cursor is not None:
                self.state = self._segment(self.state)
                self.segments_run += 1
            boundary += 1
            self.boundaries += 1
            # re-encode the parity slots from the (all-live) boundary state
            # BEFORE the fault hooks / detector can observe deaths for this
            # boundary: the decode must see survivors exactly as encoded
            self.state = self.scheme.refresh(self.comm, self.state)
            # the just-completed point = the recoverable boundary any death
            # discovered now is attributed to
            point = prev_sweep_point(self.state.cursor, geom.n_panels, levels)
            for hook in self.fault_hooks:
                self.state = hook(self.comm, self.state)
            t0 = time.perf_counter()
            newly = list(self.detector.poll(self.comm, self.state))
            self.poll_s += time.perf_counter() - t0
            if newly:
                self._recover(newly, point)
            if (self.straggler_monitor is not None
                    and self.lane_clock is not None
                    and self.state.cursor is not None):
                self._check_stragglers(point)
            if self.elastic is not None and point == self.grow_at:
                self.elastic.request_grow()
            self._maybe_transition()
            for hook in self.boundary_hooks:
                hook(self)
            if self.store is not None and self.persist_every and (
                    boundary % self.persist_every == 0
                    or self.state.cursor is None):
                self.store.push(self.state)
            if self.state.cursor is None and (
                    self.elastic is None or not self.elastic.pending):
                break
        if self.elastic is not None:
            return self.elastic.finish(self.comm, self.state, self.events)
        R, factors, bundles = finalize(self.comm, self.state)
        return FTSweepResult(R=R, factors=factors, bundles=bundles,
                             events=self.events)

    def _resume_boundary_pass(self) -> None:
        """Hook/poll pass at the RESUME boundary, before any segment runs.

        A death that struck while the sweep was suspended (or is injected
        at the resume point) must be recovered from the state exactly as
        persisted: the parity slots are NOT re-encoded first — under
        ``MDSScheme`` the joint decode uses the persisted ``state.code``
        (sweep-state wire format v2, ``repro.ft.online.state``). A v1 state
        resumes with ``code=None``, so a multi-death at this boundary that
        exceeds the XOR pairing is honestly ``UnrecoverableFailure`` — the
        re-encode window of vulnerability that v2 closes."""
        if self.state.cursor is None:
            return
        geom = self.state.geom
        point = prev_sweep_point(self.state.cursor, geom.n_panels, geom.levels)
        if point is None:
            return  # resumed at the very first point: nothing completed yet
        for hook in self.fault_hooks:
            self.state = hook(self.comm, self.state)
        t0 = time.perf_counter()
        newly = list(self.detector.poll(self.comm, self.state))
        self.poll_s += time.perf_counter() - t0
        if newly:
            self._recover(newly, point)

    def _poll_async(self, state: SweepState) -> List[int]:
        """One detector poll through the split ``probe``/``collect`` form
        when the detector has it (``NaNSentinelDetector``): the caller
        dispatches device work between probe dispatch and collect. Plain
        ``poll`` is the fallback for protocol-only detectors."""
        probe = getattr(self.detector, "probe", None)
        if probe is None:
            return list(self.detector.poll(self.comm, state))
        return list(self.detector.collect(self.comm, probe(self.comm, state)))

    def _run_async(self) -> FTSweepResult:
        """The double-buffered segment loop (async mode).

        Per boundary the sync loop serializes [segment, refresh, hooks,
        poll, recover]; under jax's async dispatch the poll is the only
        step that *must* materialize device values, so this loop dispatches
        the NEXT segment speculatively before collecting the detector probe
        — the device computes segment N+1 while the host blocks on segment
        N's sentinels. The speculation is kept only when the boundary was
        quiet; a fault-hook mutation (object identity — hooks return the
        same state when they do nothing) or a detected death discards it
        and re-dispatches from the authoritative recovered state, which is
        exactly what the sync loop would have run — results stay bitwise
        identical to sync execution (``tests/test_online_recovery.py``
        gates this differentially)."""
        boundary = 0
        cur = self.state
        if cur.cursor is not None:
            cur = self._segment(cur)
            self.segments_run += 1
        while True:
            geom = cur.geom
            # re-encode parity from the boundary state BEFORE anything can
            # observe this boundary's deaths (same contract as sync)
            cur = self.scheme.refresh(self.comm, cur)
            point = prev_sweep_point(cur.cursor, geom.n_panels, geom.levels)
            pre_hooks = cur
            for hook in self.fault_hooks:
                cur = hook(self.comm, cur)
            spec = None
            if cur is pre_hooks and cur.cursor is not None:
                # quiet so far: dispatch the next segment ahead of the
                # (blocking) detector collect — the double buffer
                spec = self._segment(cur)
            t0 = time.perf_counter()
            newly = self._poll_async(cur)
            self.poll_s += time.perf_counter() - t0
            boundary += 1
            self.boundaries += 1
            self.state = cur
            if newly:
                spec = None  # speculated from a state recovery rewrites
                self._recover(newly, point)
            for hook in self.boundary_hooks:
                hook(self)
            if self.store is not None and self.persist_every and (
                    boundary % self.persist_every == 0
                    or self.state.cursor is None):
                self.store.push(self.state)
            if self.state.cursor is None:
                break
            if spec is not None and self.state is cur:
                cur = spec
                self.segments_run += 1
            else:
                # a hook/recovery rewrote the state: the speculative
                # dispatch is stale — re-dispatch from the real boundary
                cur = self._segment(self.state)
                self.segments_run += 1
        R, factors, bundles = finalize(self.comm, self.state)
        return FTSweepResult(R=R, factors=factors, bundles=bundles,
                             events=self.events)

    # -- elastic transitions -----------------------------------------------

    def _maybe_transition(self) -> None:
        """Apply a pending SHRINK/BLANK/grow at a panel boundary: the
        controller deposits + harvests + re-owns, and the orchestrator
        swaps in the new world's comm, segment runner, and detector
        arming."""
        while self.elastic is not None and \
                self.elastic.ready(self.state.cursor):
            new_comm, new_state = self.elastic.transition(
                self.comm, self.state)
            self.state = new_state
            if new_comm is None:
                # the closing epoch already finished the factorization;
                # keep draining — leftover requests are bookkeeping only
                continue
            break
        else:
            return
        self.comm = new_comm
        if self.step_fn is not None:
            assert self.step_factory is not None, (
                "an elastic transition under step_fn= needs step_factory= "
                "to re-mesh the segment runner over the shrunken lane axis "
                "(repro.launch.spmd_qr.make_spmd_step_factory)")
            self.step_fn = self.step_factory(new_comm.axis_size())
        reset = getattr(self.detector, "reset", None)
        if reset is not None:
            reset()  # re-arm sentinels for the new world's lane numbering
        if self.straggler_monitor is not None:
            # lane ids re-number across a transition: stale EWMAs would
            # mis-attribute slowness in the new world
            self.straggler_monitor.ewma.clear()
            for k in self.straggler_monitor.flags:
                self.straggler_monitor.flags[k] = 0

    # -- stragglers --------------------------------------------------------

    def _check_stragglers(self, point) -> None:
        times = self.lane_clock(self.comm, self.state)
        flagged = self.straggler_monitor.report(times)
        cfg = self.straggler_monitor.cfg
        # clocks may keep reporting lanes of a pre-transition world (or
        # ghost slots): only live current-world lanes can be acted on
        flagged = [
            l for l in flagged
            if l < self.comm.axis_size() and (
                self.elastic is None or self.elastic.world.live[l])]
        for lane in flagged:
            if cfg.policy is StragglerPolicy.SPECULATE:
                self._speculate(lane, point)
                self.straggler_monitor.flags[lane] = 0
                n = self._spec_counts.get(lane, 0) + 1
                self._spec_counts[lane] = n
                if cfg.escalate_after is not None and n >= cfg.escalate_after:
                    self._evict(lane, point)
            elif cfg.policy is StragglerPolicy.EVICT:
                self._evict(lane, point)
            # REBALANCE/IGNORE have no mid-sweep action: row ownership is
            # fixed by the factorization, only the batch pipeline rebalances

    def _speculate(self, lane: int, point) -> None:
        """Speculative buddy recompute of a straggler's sweep point: run
        the proven REBUILD arithmetic for ``lane`` on a copy (sourcing
        from its XOR buddies), bitwise-compare the lane's slice, and let
        the first finished result win — the sweep never blocks on the
        slow lane. A mismatch means the lane was corrupt, not slow; the
        rebuilt copy is authoritative either way."""
        struck = obliterate_state(self.comm, self.state, lane)
        spec, reads = rebuild_state(self.comm, struck, lane, point, {lane})
        axes = state_lane_axes(self.state)
        flat_own = jax.tree_util.tree_leaves(self.state)
        flat_spec = jax.tree_util.tree_leaves(spec)
        flat_ax = jax.tree_util.tree_leaves(axes)
        matched = all(
            np.array_equal(
                np.asarray(self.comm.lane_slice(a, lane, ax)),
                np.asarray(self.comm.lane_slice(b, lane, ax)))
            for a, b, ax in zip(flat_own, flat_spec, flat_ax)
            if ax >= 0)  # ax < 0: no lane axis (checksum-lane parity slots)
        self.state = spec  # first result wins (bitwise-equal when matched)
        self.speculations.append(SpeculationEvent(
            point=tuple(point), lane=lane, matched=matched, reads=reads))

    def _evict(self, lane: int, point) -> None:
        """Persistent straggler: treat it as failed. Poison it, heal from
        its buddies, and hand it to the elastic controller as a SHRINK
        death — the world re-meshes without it at the next boundary."""
        if self.elastic is None:
            self.elastic = ElasticController(
                Semantics.SHRINK, self.state.geom, policy=self.elastic_policy)
        self.state = obliterate_state(self.comm, self.state, lane)
        self._heal([lane], point)
        self.elastic.note_deaths([lane])
        self.straggler_monitor.ewma.pop(lane, None)
        self.straggler_monitor.flags[lane] = 0
        self._spec_counts.pop(lane, None)

    # -- recovery ----------------------------------------------------------

    def _recover(self, newly: List[int], point) -> None:
        assert point is not None, "death detected before any sweep point ran"
        if self.semantics is Semantics.ABORT:
            raise LaneFailure(newly[0], point)
        # SHRINK/BLANK heal exactly like REBUILD (the adopter "hosts" the
        # dead slot until the panel boundary), then note the death for the
        # boundary transition
        self._heal(newly, point)
        if self.elastic is not None and self.semantics in (
                Semantics.SHRINK, Semantics.BLANK):
            self.elastic.note_deaths(newly)

    def _heal(self, newly: List[int], point) -> None:
        dead = set(newly)
        shardings = None
        if self.step_fn is not None:
            # The REBUILD replay must be bitwise-identical to the SimComm
            # oracle, but on the shard_map path the state lives as
            # lane-sharded global arrays: eager replay math on those
            # compiles auto-sharded executables whose reduction order
            # drifts from the single-device programs by ~1 ulp. Gather to
            # one device for the heal and shard back after — both pure
            # data movement.
            shardings = jax.tree_util.tree_map(
                lambda x: x.sharding, self.state)
            dev = jax.devices()[0]
            self.state = jax.tree_util.tree_map(
                lambda x: jax.device_put(x, dev), self.state)

        def on_recovered(lane: int) -> None:
            dead.discard(lane)
            # announce the respawn so the detector re-arms for this lane
            # immediately (back-to-back deaths at consecutive boundaries
            # must still be seen)
            revive = getattr(self.detector, "revive", None)
            if revive is not None:
                revive(lane)

        # the SAME strike-then-rebuild protocol as the scheduled driver's
        # checkpoint — shared code, so the scheduled-vs-online bitwise
        # equivalence cannot drift apart in one copy
        self.state, events = recover_lanes(
            self.comm, self.state, newly, point, dead,
            sync=lambda s: jax.block_until_ready(
                jax.tree_util.tree_leaves(s)),
            on_recovered=on_recovered,
            scheme=self.scheme,
        )
        if shardings is not None:
            self.state = jax.tree_util.tree_map(
                jax.device_put, self.state, shardings)
        self.recover_s += sum(e.elapsed_s for e in events)
        self.events.extend(events)


def ft_caqr_sweep_online(
    A0,
    comm,
    panel_width: int,
    detector: Optional[OnlineDetector] = None,
    **kw,
) -> FTSweepResult:
    """One-call form of the online path: ``SweepOrchestrator(...).run()``.

    The online counterpart of ``ft_caqr_sweep`` — same result layout, but
    failures are discovered by ``detector`` at runtime instead of scripted
    by a ``FailureSchedule``."""
    return SweepOrchestrator(A0, comm, panel_width, detector, **kw).run()
