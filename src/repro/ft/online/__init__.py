"""Online recovery: the reified sweep state machine, runtime failure
detection, and the host-side orchestrator (DESIGN.md §9).

``state``        — ``SweepState`` + the pure ``sweep_step`` transition (and
                   the host wire format used by ``repro.ckpt``).
``detect``       — runtime failure detectors (NaN-sentinel probe, injectable
                   fail-stop doubles) and fault injectors for tests/demos.
``orchestrator`` — the host loop: compiled ``sweep_step`` segments, detector
                   polls between segments, REBUILD synthesis for whatever
                   the detector found, diskless persistence hooks.

Only ``state`` is imported here: ``repro.ft.driver`` is a loop over
``state.sweep_step`` while ``orchestrator`` reuses the driver's
obliterate/REBUILD transitions, so the sibling modules are wired up by
``repro.ft.__init__`` after the driver exists (keeps the import graph
acyclic).
"""
from repro.ft.online import state
from repro.ft.online.state import (
    SweepState,
    WIRE_VERSION,
    finalize,
    initial_sweep_state,
    run_steps,
    state_lane_axes,
    sweep_state_from_host,
    sweep_state_to_host,
    sweep_step,
)

__all__ = [
    "detect", "orchestrator", "state",
    "SweepState", "WIRE_VERSION", "finalize", "initial_sweep_state",
    "run_steps", "state_lane_axes", "sweep_state_from_host",
    "sweep_state_to_host", "sweep_step",
]
