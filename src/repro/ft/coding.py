"""Coded checksum lanes: survive any ``f`` simultaneous lane deaths.

The paper's XOR buddy-pairing (``xor_buddy`` / ``pairing_table``, canonical
home here since the coding seam subsumes them) is one-level redundancy
doubling: every artifact exists on exactly two lanes, so a single death per
pair is recoverable from ONE survivor, but a whole pair dying at the same
sweep point is ``UnrecoverableFailure`` — the hard wall ROADMAP open item 2
names. This module generalizes the redundancy to MDS-coded checksum slots in
the ABFT checksum tradition (Bosilca et al. 2008; "Coded Computing for
Fault-Tolerant Parallel QR Decomposition", 2023): ``f`` parity slots encode
every protected ``SweepState`` leaf over a Vandermonde generator in GF(2^8),
so ANY ``t <= f`` simultaneously-dead lanes are jointly decodable from the
``P - t`` survivors plus the parity slots.

Bitwise exactness
-----------------
Checksums over *float arithmetic* cannot promise the repo's bitwise recovery
oracle (rounding in the encode/decode round trip). We therefore code over
the RAW BYTES: each protected leaf is bitcast to ``uint8``
(``jax.lax.bitcast_convert_type``), parity row ``j`` is
``P_j = XOR_i g[j,i] (x) B_i`` with GF(2^8) constant-multiplies (table
lookups), and decode solves the ``t x t`` GF Vandermonde system exactly
(integer Gaussian elimination on the host). GF arithmetic on bit patterns
is exact, and survivors do not change between the boundary encode and the
boundary decode, so the decoded bytes — hence the floats — are
bit-identical to the dead lanes' pre-death state.

Generator
---------
``g[j, i] = (alpha^i)^j`` for ``j = 0..f-1``, ``i = 0..P-1`` with ``alpha``
the primitive element of GF(2^8) (poly 0x11D) — a Vandermonde matrix on the
distinct nonzero points ``alpha^i`` (so ``P <= 255``). Row 0 is all-ones:
the ``f=1`` parity is the plain XOR checksum lane of the ABFT tradition.
Any ``t`` erased columns against the FIRST ``t`` rows form a standard
Vandermonde submatrix on distinct points, hence invertible — the MDS
property this scheme needs (decode always uses rows ``0..t-1``).

Hybrid rebuild rule (the f=1 == XOR argument)
---------------------------------------------
``MDSScheme`` only *augments* the paper's protocol, it never replaces the
single-death path: exactly one newly-dead lane is rebuilt by the existing
XOR-buddy REBUILD (``repro.ft.driver.rebuild_state``), preserving the
paper's single-source ledger property at every ``f`` — which makes
``MDSScheme(f=1)`` trivially bitwise-identical to ``XORPairScheme``
including the event ledgers (the one parity row is maintained but never
consumed). Only ``2 <= t <= f`` simultaneous deaths route to the joint GF
decode (multi-source ledger: all survivors + the parity slots). ``t > f``
falls back to the per-lane XOR loop — MDS is monotonically stronger than
XOR — and ``UnrecoverableFailure`` moves to the honest ``f+1``-deaths
boundary.

Checksum lifecycle
------------------
``scheme.refresh(comm, state)`` re-encodes the parity slots at every
interruptible boundary, *after* the sweep point executes and *before* fault
injection/detection runs, from live state — so a boundary decode sees
survivors exactly as encoded. Parities live in ``SweepState.code`` (a
pytree child; skip-axis ``-1`` in ``state_lane_axes``; excluded from the
host wire format, which stays version 1 — a resumed sweep re-encodes at its
first boundary). Joint decode of runtime-detected deaths assumes fail-stop
at boundaries: a lane silently poisoned mid-segment would contaminate the
boundary encode (the single-death late-detection path is unaffected — it
never reads parity).

Under ``AxisComm`` (traced scheduled SPMD) encode/decode are expressed with
``comm.xor_reduce`` (a bit-plane psum-mod-2 all-reduce), so the same scheme
object threads through ``repro.launch.spmd_qr``; the online SPMD path
encodes host-side on the global SimComm-layout state between shard_map
segments.
"""
from __future__ import annotations

import dataclasses
from typing import AbstractSet, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import SimComm
from repro.ft.failures import UnrecoverableFailure


# -- GF(2^8) arithmetic (poly 0x11D) -----------------------------------------


_POLY = 0x11D


def _gf_tables():
    exp = np.zeros(510, np.int32)
    log = np.zeros(256, np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & 0x100:
            x ^= _POLY
    exp[255:510] = exp[:255]
    return exp, log


GF_EXP, GF_LOG = _gf_tables()

# full 256x256 product table: one gather per constant-multiply under jit
_MUL = GF_EXP[np.add.outer(GF_LOG, GF_LOG)].astype(np.uint8)
_MUL[0, :] = 0
_MUL[:, 0] = 0


def gf_mul(a: int, b: int) -> int:
    return int(_MUL[a, b])


def gf_inv(a: int) -> int:
    if a == 0:
        raise ZeroDivisionError("0 has no GF(2^8) inverse")
    return int(GF_EXP[255 - GF_LOG[a]])


def generator(f: int, P: int) -> np.ndarray:
    """The (f, P) Vandermonde MDS generator: ``g[j, i] = (alpha^i)^j``.
    Row 0 is all-ones (plain XOR checksum); rows depend only on ``j``, so
    decode with ``t <= f`` rows uses the same coefficients regardless of
    ``f``."""
    if P > 255:
        raise ValueError(f"GF(2^8) coding supports at most 255 lanes, got {P}")
    j = np.arange(f)[:, None]
    i = np.arange(P)[None, :]
    return GF_EXP[(j * i) % 255].astype(np.uint8)


def gf_inv_matrix(M: np.ndarray) -> np.ndarray:
    """Exact GF(2^8) matrix inverse by Gaussian elimination (host side;
    the decode systems are tiny ``t x t`` Vandermonde submatrices, always
    invertible)."""
    M = np.asarray(M)
    t = M.shape[0]
    aug = np.concatenate([M.astype(np.int32),
                          np.eye(t, dtype=np.int32)], axis=1)
    for c in range(t):
        piv = c + int(np.nonzero(aug[c:, c])[0][0])
        aug[[c, piv]] = aug[[piv, c]]
        aug[c] = _MUL[gf_inv(int(aug[c, c])), aug[c]]
        for r in range(t):
            if r != c and aug[r, c]:
                aug[r] ^= _MUL[aug[r, c], aug[c]].astype(np.int32)
    return aug[:, t:].astype(np.uint8)


# -- the XOR pairing (paper SSIII-B/C), canonical home -----------------------


def xor_buddy(lane: int, level: int) -> int:
    """The XOR butterfly partner of ``lane`` at ``level`` — the single
    source every per-level artifact can be refetched from, and the
    designated adopter (level 0) when a SHRINK world re-owns a dead
    lane's rows (``repro.ft.elastic``)."""
    return lane ^ (1 << level)


def pairing_table(P: int):
    """The full ladder pairing of a ``P``-lane world: one ppermute
    permutation per butterfly level. An elastic transition never remaps
    pairs explicitly — it re-enters this table at the new world size, so
    the P-1-lane (padded-pow2) world's ladder is just ``pairing_table``
    of the new slot count (DESIGN.md SS11). The MDS generator remaps the
    same way: ``generator(f, P)`` is a pure function of the slot count,
    so a post-SHRINK world re-encodes over its own column set."""
    from repro.core.tsqr import _levels, _xor_perm

    return [_xor_perm(P, s) for s in range(_levels(P))]


# -- protected-leaf selection -------------------------------------------------


def _protected(state) -> List[Tuple[int, int]]:
    """``(flat_leaf_index, lane_axis)`` of every parity-protected leaf, in
    flattening order: float leaves with a lane axis — exactly what
    ``obliterate_state`` poisons. ``A0`` (the re-readable source, never
    poisoned) and the parity field itself (skip-axis ``-1``) are excluded.
    Works on ``jax.eval_shape`` structs too."""
    from repro.ft.online.state import state_lane_axes

    axes = state_lane_axes(state).replace(A0=-1)
    out = []
    leaves = jax.tree_util.tree_leaves(state)
    ax_leaves = jax.tree_util.tree_leaves(axes)
    for i, (x, ax) in enumerate(zip(leaves, ax_leaves)):
        if ax >= 0 and jnp.issubdtype(x.dtype, jnp.floating):
            out.append((i, ax))
    return out


def _bytes_of(x):
    return jax.lax.bitcast_convert_type(x, jnp.uint8)


def _xor_axis0(x):
    return jax.lax.reduce(x, np.uint8(0), jax.lax.bitwise_xor, (0,))


# -- encode / decode bodies ---------------------------------------------------


def _encode_sim(state, G):
    """Parity tuple over the protected leaves, SimComm (global) layout:
    one ``(f, *byte_shape)`` uint8 array per protected leaf."""
    mul = jnp.asarray(_MUL)
    f, P = G.shape
    leaves = jax.tree_util.tree_leaves(state)
    out = []
    for i, ax in _protected(state):
        bl = jnp.moveaxis(_bytes_of(leaves[i]), ax, 0)  # (P, ...)
        rows = []
        for j in range(f):
            coef = G[j].reshape((P,) + (1,) * (bl.ndim - 1))
            rows.append(_xor_axis0(mul[coef, bl]))
        out.append(jnp.stack(rows))
    return tuple(out)


@jax.jit
def _encode_sim_jit(state, G):
    # cache key = (treedef, shapes): one compile per cursor, shared across
    # every run of the same geometry (the exhaustive kill matrices)
    return _encode_sim(state, G)


def _encode_axis(comm, state, G):
    """The same encode inside a traced AxisComm program: per-lane terms,
    reduced with the bit-plane XOR all-reduce. Every lane holds the
    (replicated) parity — layout-consistent with the no-lane-axis SimComm
    parity slot."""
    mul = jnp.asarray(_MUL)
    f, _P = G.shape
    idx = comm.axis_index()
    leaves = jax.tree_util.tree_leaves(state)
    out = []
    for i, _ax in _protected(state):
        b = _bytes_of(leaves[i])  # local: no lane axis
        rows = []
        for j in range(f):
            rows.append(comm.xor_reduce(mul[jnp.asarray(G[j])[idx], b]))
        out.append(jnp.stack(rows))
    return tuple(out)


def _decode_sim(state, live_mask, dead_idx, inv):
    """Joint reconstruction of ``t = dead_idx.shape[0]`` lanes' slices of
    every protected leaf, from the survivors (``live_mask``) and parity
    rows ``0..t-1`` of ``state.code``. All lane data is traced (the jit
    cache is shared across every dead-set of the same size at a cursor);
    only shapes are static."""
    mul = jnp.asarray(_MUL)
    P = live_mask.shape[0]
    t = dead_idx.shape[0]
    Gt = jnp.asarray(generator(t, P))
    leaves, treedef = jax.tree_util.tree_flatten(state)
    prot = _protected(state)
    code = state.code
    assert code is not None and len(code) == len(prot), (
        "parity slots out of step with the protected leaves")
    new = list(leaves)
    for parity, (i, ax) in zip(code, prot):
        bl = jnp.moveaxis(_bytes_of(leaves[i]), ax, 0)  # (P, ...)
        mask = live_mask.reshape((P,) + (1,) * (bl.ndim - 1))
        synd = []
        for j in range(t):
            coef = Gt[j].reshape((P,) + (1,) * (bl.ndim - 1))
            term = jnp.where(mask, mul[coef, bl], jnp.uint8(0))
            synd.append(parity[j] ^ _xor_axis0(term))
        xs = []
        for r in range(t):
            acc = mul[inv[r, 0], synd[0]]
            for j in range(1, t):
                acc = acc ^ mul[inv[r, j], synd[j]]
            xs.append(acc)
        bl = bl.at[dead_idx].set(jnp.stack(xs))
        new[i] = jax.lax.bitcast_convert_type(
            jnp.moveaxis(bl, 0, ax), leaves[i].dtype)
    return jax.tree_util.tree_unflatten(treedef, new)


_decode_sim_jit = jax.jit(_decode_sim)


def _decode_axis(comm, state, newly: Sequence[int], dead: AbstractSet[int],
                 inv: np.ndarray):
    """The joint decode inside a traced AxisComm program (static dead set:
    schedules are trace-time data on the scheduled SPMD path)."""
    mul = jnp.asarray(_MUL)
    P = comm.axis_size()
    t = len(newly)
    Gt = generator(t, P)
    idx = comm.axis_index()
    own_dead = jnp.zeros_like(idx, dtype=bool)
    for d in sorted(dead):
        own_dead = own_dead | (idx == d)
    leaves, treedef = jax.tree_util.tree_flatten(state)
    prot = _protected(state)
    code = state.code
    assert code is not None and len(code) == len(prot)
    new = list(leaves)
    for parity, (i, _ax) in zip(code, prot):
        b = _bytes_of(leaves[i])
        synd = []
        for j in range(t):
            term = mul[jnp.asarray(Gt[j])[idx], b]
            term = comm.where(own_dead, jnp.zeros_like(term), term)
            synd.append(parity[j] ^ comm.xor_reduce(term))
        for r, d in enumerate(sorted(newly)):
            acc = mul[int(inv[r, 0]), synd[0]]
            for j in range(1, t):
                acc = acc ^ mul[int(inv[r, j]), synd[j]]
            b = comm.where(idx == d, acc, b)
        new[i] = jax.lax.bitcast_convert_type(b, leaves[i].dtype)
    return jax.tree_util.tree_unflatten(treedef, new)


# -- the schemes --------------------------------------------------------------


class CodingScheme:
    """The redundancy seam of the FT stack.

    ``f``        guaranteed number of simultaneous deaths recoverable;
    ``joint``    whether ``decode_lanes`` exists (multi-death GF decode);
    ``refresh``  re-encode the parity slots at an interruptible boundary
                 (identity for pure XOR pairing: its redundancy is the pair
                 mirroring already inside the sweep arithmetic);
    ``decode_lanes``  jointly reconstruct all newly-dead lanes, returning
                 ``(state, reads)`` with the multi-source decode ledger.

    ``recover_lanes`` (``repro.ft.driver``) consults the scheme: one newly
    dead lane always takes the paper's single-source XOR REBUILD; ``2 <= t
    <= f`` takes ``decode_lanes``; ``t > f`` falls back to the per-lane XOR
    loop (best effort) and an exhausted fallback raises
    ``UnrecoverableFailure`` at the f+1-deaths boundary."""

    name = "base"
    f = 0
    joint = False

    def refresh(self, comm, state):
        return state

    def decode_lanes(self, comm, state, newly, dead):
        raise UnrecoverableFailure(
            f"scheme {self.name!r} cannot jointly decode {sorted(newly)}")


class XORPairScheme(CodingScheme):
    """The paper's scheme, as a (stateless) instance of the seam: pairwise
    XOR-buddy redundancy, single-source REBUILD, f=1 per pair. The bitwise
    differential oracle every other scheme is gated against."""

    name = "xor"
    f = 1
    joint = False


@dataclasses.dataclass(frozen=True)
class MDSScheme(CodingScheme):
    """Vandermonde GF(2^8) MDS checksum slots tolerating any ``f``
    simultaneous deaths (module docstring has the construction and the
    exactness argument). ``f`` is the config knob traded against the
    per-boundary encode overhead (``benchmarks/bench_coding.py``)."""

    f: int = 2
    name = "mds"
    joint = True

    def __post_init__(self):
        if not 1 <= self.f <= 8:
            raise ValueError(f"MDS redundancy f={self.f} out of range [1, 8]")

    def refresh(self, comm, state):
        P = comm.axis_size()
        G = jnp.asarray(generator(self.f, P))
        if isinstance(comm, SimComm):
            code = _encode_sim_jit(state.replace(code=None), G)
        else:
            code = _encode_axis(comm, state.replace(code=None), G)
        return state.replace(code=code)

    def decode_lanes(self, comm, state, newly, dead
                     ) -> Tuple[object, Dict[str, int]]:
        newly = sorted(newly)
        t = len(newly)
        P = comm.axis_size()
        if t > self.f:
            raise UnrecoverableFailure(
                f"{t} simultaneous deaths exceed MDS tolerance f={self.f}")
        if state.code is None:
            raise UnrecoverableFailure(
                "no parity slots encoded yet (death before the first "
                "boundary refresh)")
        inv = gf_inv_matrix(generator(t, P)[:, newly])
        if isinstance(comm, SimComm):
            live = np.ones(P, bool)
            live[sorted(dead)] = False
            state = _decode_sim_jit(
                state, jnp.asarray(live), jnp.asarray(newly, jnp.int32),
                jnp.asarray(inv))
        else:
            live = np.ones(P, bool)
            live[sorted(dead)] = False
            state = _decode_axis(comm, state, newly, dead, inv)
        reads: Dict[str, int] = {
            f"coded.parity{j}": P + j for j in range(t)}
        for i in range(P):
            if live[i]:
                reads[f"coded.survivor{i}"] = i
        return state, reads
