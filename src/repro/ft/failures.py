"""Failure injection + detection simulation.

``FailureSchedule`` scripts lane deaths at given steps (tests/examples);
``Detector`` models ULFM semantics: an operation touching a failed lane
raises ``LaneFailure`` — operations not involving it proceed unknowingly
(paper §II last paragraph).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple


class LaneFailure(RuntimeError):
    def __init__(self, lane: int, step: int):
        super().__init__(f"lane {lane} failed at step {step}")
        self.lane = lane
        self.step = step


@dataclasses.dataclass
class FailureSchedule:
    """{step: [lanes that die at the start of that step]}"""

    events: Dict[int, List[int]] = dataclasses.field(default_factory=dict)

    def lanes_failing_at(self, step: int) -> List[int]:
        return self.events.get(step, [])


class Detector:
    def __init__(self, n_lanes: int, schedule: Optional[FailureSchedule] = None):
        self.n = n_lanes
        self.schedule = schedule or FailureSchedule()
        self.dead: Set[int] = set()
        self.fired: Set[Tuple[int, int]] = set()

    def begin_step(self, step: int) -> List[int]:
        """Kill scheduled lanes; return the newly dead (detection event).
        Each scheduled (step, lane) event fires exactly once — a REBUILD
        replay passing the same step does not re-kill the respawned lane."""
        newly = []
        for l in self.schedule.lanes_failing_at(step):
            if l not in self.dead and (step, l) not in self.fired:
                newly.append(l)
                self.fired.add((step, l))
        self.dead.update(newly)
        return newly

    def check(self, lanes: Tuple[int, ...], step: int) -> None:
        """An operation involving these lanes: raises on the first dead one."""
        for l in lanes:
            if l in self.dead:
                raise LaneFailure(l, step)

    def revive(self, lane: int) -> None:
        self.dead.discard(lane)
