"""Failure injection + detection simulation (the trace-time path).

``FailureSchedule`` scripts lane deaths at given steps (tests/examples);
``Detector`` models ULFM semantics: an operation touching a failed lane
raises ``LaneFailure`` — operations not involving it proceed unknowingly
(paper §II last paragraph). Runtime (unscripted) detection lives in
``repro.ft.online.detect``; the sweep-point address arithmetic below
(``next_sweep_point`` / ``prev_sweep_point``) is shared by both paths as
the cursor algebra of the reified state machine.

Steps are arbitrary hashable addresses. The training loop uses plain int
step counters; the FT-CAQR sweep driver (``repro.ft.driver``) uses
``sweep_point(panel, phase, level)`` tuples so a lane can be killed at any
interruptible point of the factorization:

* ``("leaf")``      — after the panel's local leaf QR, before the first
                      butterfly level;
* ``("tsqr", s)``    — after TSQR butterfly level ``s`` completes;
* ``("trailing", s)``— after trailing-combine level ``s`` completes.

A death *during* a level is detected by the survivors at that level's
collective and leaves them at the previous level's state, so the
"after level s, before level s+1" checkpoints cover the full state space of
the paper's failure model (one address per distinct recoverable state).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, List, Optional, Set, Tuple

# Interruptible phases of one panel of the CAQR sweep, in execution order.
PHASE_LEAF = "leaf"
PHASE_TSQR = "tsqr"
PHASE_TRAILING = "trailing"
SWEEP_PHASES = (PHASE_LEAF, PHASE_TSQR, PHASE_TRAILING)


def sweep_point(panel: int, phase: str, level: int = 0) -> Tuple[int, str, int]:
    """Address of an interruptible point in the CAQR sweep (a schedule key).

    The paper's failure model (§II) allows a process to die at any point of
    the factorization; the distinct *recoverable states* are the boundaries
    between tree levels (§III-B for TSQR, §III-C for the trailing update),
    which is exactly this address space. ``level`` is the just-completed
    tree level (ignored for ``leaf``).

    >>> sweep_point(2, "tsqr", 1)
    (2, 'tsqr', 1)
    >>> sweep_point(0, "leaf")
    (0, 'leaf', 0)
    """
    assert phase in SWEEP_PHASES, phase
    return (panel, phase, 0 if phase == PHASE_LEAF else level)


def iter_sweep_points(n_panels: int, levels: int):
    """All interruptible points of an ``n_panels``-panel sweep over a
    ``levels``-level tree, in driver execution order — the kill-matrix
    enumeration (tests, benchmarks). ``n_panels`` comes from the sweep's
    ``caqr.sweep_geometry`` (``ceil(min(m, n) / b)``), so the enumeration
    covers ragged and wide geometries exactly as the driver walks them.

    >>> list(iter_sweep_points(n_panels=1, levels=2))  # 1 panel, P=4 tree
    [(0, 'leaf', 0), (0, 'tsqr', 0), (0, 'tsqr', 1), (0, 'trailing', 0), (0, 'trailing', 1)]
    """
    for k in range(n_panels):
        yield sweep_point(k, PHASE_LEAF)
        for s in range(levels):
            yield sweep_point(k, PHASE_TSQR, s)
        for s in range(levels):
            yield sweep_point(k, PHASE_TRAILING, s)


def next_sweep_point(
    point: Tuple[int, str, int], n_panels: int, levels: int
) -> Optional[Tuple[int, str, int]]:
    """Successor of ``point`` in driver execution order, ``None`` after the
    last point — the cursor advance of the reified sweep state machine
    (``repro.ft.online.state``).

    >>> next_sweep_point((0, "leaf", 0), 2, 2)
    (0, 'tsqr', 0)
    >>> next_sweep_point((0, "trailing", 1), 2, 2)
    (1, 'leaf', 0)
    >>> next_sweep_point((1, "trailing", 1), 2, 2) is None
    True
    """
    k, phase, s = point
    if phase == PHASE_LEAF:
        return sweep_point(k, PHASE_TSQR, 0)
    if phase == PHASE_TSQR:
        if s + 1 < levels:
            return sweep_point(k, PHASE_TSQR, s + 1)
        return sweep_point(k, PHASE_TRAILING, 0)
    if s + 1 < levels:
        return sweep_point(k, PHASE_TRAILING, s + 1)
    if k + 1 < n_panels:
        return sweep_point(k + 1, PHASE_LEAF)
    return None


def prev_sweep_point(
    point: Optional[Tuple[int, str, int]], n_panels: int, levels: int
) -> Optional[Tuple[int, str, int]]:
    """Predecessor of ``point`` (``None`` = past-the-end, i.e. the last
    point); ``None`` for the very first point. The orchestrator uses this to
    name the just-completed recoverable boundary a runtime-detected death is
    attributed to.

    >>> prev_sweep_point((0, "tsqr", 0), 2, 2)
    (0, 'leaf', 0)
    >>> prev_sweep_point(None, 2, 2)
    (1, 'trailing', 1)
    >>> prev_sweep_point((0, "leaf", 0), 2, 2) is None
    True
    """
    if point is None:
        return sweep_point(n_panels - 1, PHASE_TRAILING, max(levels - 1, 0))
    k, phase, s = point
    if phase == PHASE_LEAF:
        if k == 0:
            return None
        return sweep_point(k - 1, PHASE_TRAILING, max(levels - 1, 0))
    if phase == PHASE_TSQR:
        if s == 0:
            return sweep_point(k, PHASE_LEAF)
        return sweep_point(k, PHASE_TSQR, s - 1)
    if s == 0:
        return sweep_point(k, PHASE_TSQR, max(levels - 1, 0))
    return sweep_point(k, PHASE_TRAILING, s - 1)


class LaneFailure(RuntimeError):
    def __init__(self, lane: int, step: Hashable):
        super().__init__(f"lane {lane} failed at step {step}")
        self.lane = lane
        self.step = step


class UnrecoverableFailure(RuntimeError):
    """Raised when a REBUILD cannot proceed: the single-source buddy that
    holds the needed artifact is itself dead (e.g. both members of a pair
    were killed at the same point)."""


@dataclasses.dataclass
class FailureSchedule:
    """{step: [lanes that die at the start of that step]}.

    Keys are ints for the training loop, ``sweep_point(...)`` tuples for the
    CAQR sweep driver. The schedule is *static Python data*: under the SPMD
    path (``repro.launch.spmd_qr``) it is broadcast to every lane at trace
    time — each lane's compiled program contains the full schedule, the
    analogue of the paper's §II assumption that survivors agree on who
    failed and where.

    >>> s = FailureSchedule(events={sweep_point(1, "tsqr", 0): [2, 3]})
    >>> s.lanes_failing_at(sweep_point(1, "tsqr", 0))
    [2, 3]
    >>> s.lanes_failing_at(sweep_point(0, "leaf"))
    []
    """

    events: Dict[Hashable, List[int]] = dataclasses.field(default_factory=dict)

    def lanes_failing_at(self, step: Hashable) -> List[int]:
        return self.events.get(step, [])


class Detector:
    """ULFM-style failure detection (paper §II): deaths scheduled at a step
    fire when the step begins; an operation that *touches* a failed lane
    raises ``LaneFailure``, operations not involving it proceed unknowingly.

    >>> d = Detector(4, FailureSchedule(events={7: [1]}))
    >>> d.begin_step(7)          # the scheduled death fires (once)
    [1]
    >>> d.begin_step(7)          # a replay does not re-kill the respawn
    []
    >>> d.revive(1); sorted(d.dead)
    []
    """

    def __init__(self, n_lanes: int, schedule: Optional[FailureSchedule] = None):
        self.n = n_lanes
        self.schedule = schedule or FailureSchedule()
        self.dead: Set[int] = set()
        self.fired: Set[Tuple[Hashable, int]] = set()

    def begin_step(self, step: Hashable) -> List[int]:
        """Kill scheduled lanes; return the newly dead (detection event).
        Each scheduled (step, lane) event fires exactly once — a REBUILD
        replay passing the same step does not re-kill the respawned lane."""
        newly = []
        for l in self.schedule.lanes_failing_at(step):
            if l not in self.dead and (step, l) not in self.fired:
                newly.append(l)
                self.fired.add((step, l))
        self.dead.update(newly)
        return newly

    def check(self, lanes: Tuple[int, ...], step: Hashable) -> None:
        """An operation involving these lanes: raises on the first dead one."""
        for l in lanes:
            if l in self.dead:
                raise LaneFailure(l, step)

    def revive(self, lane: int) -> None:
        self.dead.discard(lane)
