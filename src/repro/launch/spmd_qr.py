"""Production SPMD entry for the FT-CAQR sweep: ``shard_map`` over a 1-D
lane mesh (paper §II's execution model, one process per lane).

``ft_caqr_sweep_spmd`` runs the same Comm-generic driver the simulator runs
(``repro.ft.driver``), but over ``AxisComm`` inside ``shard_map``: each
device holds one lane's block-row, every exchange lowers to a real
``collective-permute``/``all-reduce``, and the failure schedule — static
Python data — is broadcast to every lane at trace time (each lane's compiled
program contains the full schedule, the SPMD analogue of the paper's
agreed-on failure detection). Death is the Comm death-mask representation
(DESIGN.md §8): the scheduled lane NaN-masks its own state, REBUILD fetches
are point-to-point permutes from the single surviving buddy.

Output layout: the gathered global result is **leaf-for-leaf identical to a
``SimComm`` run** — the body reinserts the lane axis exactly where the
simulator's batching puts it — so the two paths are directly comparable
with ``jax.tree_util`` equality and no reshaping. That equivalence (R,
factors, bundles, post-REBUILD state, bit for bit) is the repo's SPMD
oracle, gated by ``tests/test_spmd_ft_driver.py`` on aligned, ragged, and
wide geometries.

Scheduling caveats inherited from tracing the whole sweep into one program:
``RecoveryEvent.elapsed_s`` records trace time, not device time (use
``benchmarks/bench_spmd.py`` for measured SPMD REBUILD cost), and an
unrecoverable schedule raises ``UnrecoverableFailure`` at trace time,
before any device computes.

The *online* entrypoints below (``make_spmd_sweep_step`` /
``ft_caqr_sweep_online_spmd``) lift both caveats by not tracing the sweep
as one program: the host orchestrator runs shard_map ``sweep_step``
segments and discovers failures at runtime between them (DESIGN.md §9) —
REBUILD latency is then real wall clock and recoverability is judged when
the death actually happens.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.caqr import PanelFactors
from repro.core.comm import AxisComm, SimComm
from repro.core.trailing import RecoveryBundle
from repro.dist import compat
from repro.ft.driver import FTSweepDriver, FTSweepResult
from repro.ft.failures import FailureSchedule
from repro.ft.online.state import state_lane_axes, sweep_step

# Lane-axis position of every per-lane leaf in the SimComm result layout.
# The shard_map body expands a size-1 axis there; with the matching out_spec
# the gathered global arrays are layout-identical to a SimComm run.
_R_LANE_AXIS = 0
_FACTORS_LANE_AXIS = PanelFactors(
    leaf_Y=1, leaf_T=1, level_Y2=2, level_T=2,
    row_start=1, active=1, target=1,
)
_BUNDLE_LANE_AXIS = RecoveryBundle(
    W=2, C_self=2, C_buddy=2, Y2=2, T=2, self_was_top=2,
)


def make_lane_mesh(n_lanes: Optional[int] = None, axis_name: str = "qr"):
    """1-D device mesh, one CAQR lane per device (default: all devices).

    ``n_lanes`` must be a power of two (the butterfly's requirement). On a
    CPU host, force a multi-device platform with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* jax
    initializes (see ``examples/spmd_quickstart.py``).
    """
    if n_lanes is None:
        n_lanes = len(jax.devices())
    return compat.make_mesh((n_lanes,), (axis_name,))


def pow2_lanes(n_devices: Optional[int] = None) -> int:
    """Largest power-of-two lane count usable on ``n_devices`` (default:
    the visible device count). The butterfly needs 2^k lanes, so a non-pow2
    training pod (e.g. P=48 hosts) runs its optimizer-internal sweeps on
    the largest power-of-two prefix (32) and leaves the rest to data
    parallelism — the FT training runtime sizes its lane mesh with this."""
    if n_devices is None:
        n_devices = len(jax.devices())
    assert n_devices >= 1
    return 1 << (n_devices.bit_length() - 1)


def ft_caqr_sweep_spmd(
    A: jax.Array,
    panel_width: int,
    schedule: Optional[FailureSchedule] = None,
    mesh=None,
    axis_name: str = "qr",
    scheme=None,
) -> FTSweepResult:
    """Run the windowed FT-CAQR sweep under ``shard_map`` on a device mesh.

    A: the full ``(m, n)`` matrix; rows are block-sharded over the mesh's
        lane axis (``m`` must divide by the lane count — each lane re-reads
        its own contiguous block-row on REBUILD, the paper's data-source
        model). Any per-lane shape ``ft_caqr_sweep`` accepts works: ragged
        and wide geometries run at the padded ``sweep_geometry`` inside the
        mapped body, identically to the simulator.
    panel_width: b.
    schedule: static lane-death schedule, broadcast to every lane at trace
        time; ``None`` = failure-free.
    mesh: a 1-D mesh from ``make_lane_mesh`` (default: one lane per visible
        device). The lane count must be a power of two.

    Returns ``FTSweepResult`` with the *SimComm layout*: ``R`` is
    ``(P, min(m,n), n)`` (per-lane replicated copies), factors/bundles carry
    the lane axis where the simulator's batching puts it, and ``events``
    holds the trace-time REBUILD ledger (single-source reads per artifact).
    """
    if mesh is None:
        mesh = make_lane_mesh(axis_name=axis_name)
    n_lanes = mesh.shape[axis_name]
    m, n = A.shape
    assert m % n_lanes == 0, (
        f"rows ({m}) must block-shard evenly over {n_lanes} lanes"
    )
    events_log = []

    def body(A_local):
        drv = FTSweepDriver(A_local, AxisComm(axis_name), panel_width, schedule,
                            scheme=scheme)
        res = drv.run()
        events_log.append(res.events)
        factors = jax.tree_util.tree_map(
            jnp.expand_dims, res.factors, _FACTORS_LANE_AXIS)
        bundles = jax.tree_util.tree_map(
            jnp.expand_dims, res.bundles, _BUNDLE_LANE_AXIS)
        return jnp.expand_dims(res.R, _R_LANE_AXIS), factors, bundles

    spec_of = lambda lane_axis: P(
        *([None] * lane_axis + [axis_name]))
    out_specs = (
        spec_of(_R_LANE_AXIS),
        jax.tree_util.tree_map(spec_of, _FACTORS_LANE_AXIS),
        jax.tree_util.tree_map(spec_of, _BUNDLE_LANE_AXIS),
    )
    mapped = compat.shard_map(
        body, mesh, in_specs=P(axis_name, None), out_specs=out_specs)
    with compat.set_mesh(mesh):
        R, factors, bundles = jax.jit(mapped)(A)
    # the trace populated the static event ledger exactly once (fresh jit)
    (events,) = events_log
    return FTSweepResult(R=R, factors=factors, bundles=bundles, events=events)


# -- online (runtime-detected) path ------------------------------------------


def make_spmd_sweep_step(mesh=None, axis_name: str = "qr"):
    """Shard_map segment backend for the online orchestrator.

    Returns ``step(state) -> state`` executing ONE sweep point of the
    reified state machine (``repro.ft.online.state.sweep_step``) under
    ``shard_map`` over the lane mesh. Between calls the ``SweepState``
    lives as *global* lane-sharded arrays in the SimComm layout — the
    host-side orchestrator probes sentinels, injects/obliterates and
    REBUILDs on that global layout with the SimComm mask primitives, while
    every compiled segment runs the AxisComm program on the devices. One
    program is compiled per cursor position (the treedef carries the
    cursor) and cached for the lifetime of the returned callable.

    Per-leaf specs come from ``state_lane_axes``; the body squeezes each
    leaf's size-1 lane axis so the AxisComm step sees true per-lane locals,
    and re-expands on the way out, keeping the gathered global layout
    leaf-for-leaf identical to a SimComm run (the §8 oracle, extended to
    every intermediate boundary state).
    """
    if mesh is None:
        mesh = make_lane_mesh(axis_name=axis_name)
    n_lanes = mesh.shape[axis_name]
    cache = {}

    def spec_of(lane_axis):
        if lane_axis < 0:
            # no lane axis: checksum-lane parity slots (repro.ft.coding)
            # are global values, replicated across the mesh
            return P()
        return P(*([None] * lane_axis + [axis_name]))

    def step(state):
        key = jax.tree_util.tree_structure(state)
        fn = cache.get(key)
        if fn is None:
            in_axes = state_lane_axes(state)
            out_struct = jax.eval_shape(
                lambda s: sweep_step(SimComm(n_lanes), s), state)
            out_axes = state_lane_axes(out_struct)

            def body(s_shard):
                local = jax.tree_util.tree_map(
                    lambda x, ax: x if ax < 0 else jnp.squeeze(x, axis=ax),
                    s_shard, in_axes)
                out = sweep_step(AxisComm(axis_name), local)
                return jax.tree_util.tree_map(
                    lambda x, ax: x if ax < 0 else jnp.expand_dims(x, ax),
                    out, out_axes)

            fn = jax.jit(compat.shard_map(
                body, mesh,
                in_specs=(jax.tree_util.tree_map(spec_of, in_axes),),
                out_specs=jax.tree_util.tree_map(spec_of, out_axes),
            ))
            cache[key] = fn
        with compat.set_mesh(mesh):
            return fn(state)

    return step


def make_spmd_step_factory(axis_name: str = "qr", devices=None):
    """Per-world segment-runner factory for the *elastic* orchestrator.

    An elastic transition (``repro.ft.elastic``) changes the lane count
    mid-run; the orchestrator then calls ``factory(n_slots)`` and gets a
    fresh ``make_spmd_sweep_step`` over a new 1-D mesh of the first
    ``n_slots`` surviving devices — ``shard_map`` re-meshed over the
    shrunken lane axis. Pair it with ``elastic_policy="fold"`` so the new
    slot count is a power of two no larger than the survivor count (a
    SHRINK world must fit on the devices that are left)."""
    devices = list(devices) if devices is not None else list(jax.devices())

    def factory(n_slots: int):
        assert n_slots <= len(devices), (n_slots, len(devices))
        mesh = compat.make_mesh((n_slots,), (axis_name,),
                                devices=devices[:n_slots])
        return make_spmd_sweep_step(mesh, axis_name)

    return factory


def ft_caqr_sweep_elastic_spmd(
    A: jax.Array,
    panel_width: int,
    detector=None,
    mesh=None,
    axis_name: str = "qr",
    semantics=None,
    **orchestrator_kw,
):
    """Elastic online sweep on the SPMD path: like
    ``ft_caqr_sweep_online_spmd`` but with SHRINK/BLANK semantics — a
    detected death is healed from its buddy and the sweep re-meshes over
    the shrunken lane axis at the next panel boundary (fold policy:
    floor-pow2 of the survivor count, so the new mesh fits on surviving
    devices). Returns ``repro.ft.elastic.ElasticSweepResult``."""
    from repro.ft.online.orchestrator import SweepOrchestrator
    from repro.ft.semantics import Semantics

    if mesh is None:
        mesh = make_lane_mesh(axis_name=axis_name)
    n_lanes = mesh.shape[axis_name]
    m, n = A.shape
    assert m % n_lanes == 0, (
        f"rows ({m}) must block-shard evenly over {n_lanes} lanes"
    )
    orch = SweepOrchestrator(
        A.reshape(n_lanes, m // n_lanes, n), SimComm(n_lanes), panel_width,
        detector=detector,
        step_fn=make_spmd_sweep_step(mesh, axis_name),
        step_factory=make_spmd_step_factory(axis_name),
        semantics=semantics if semantics is not None else Semantics.SHRINK,
        elastic_policy="fold",
        **orchestrator_kw,
    )
    return orch.run()


def ft_caqr_sweep_online_spmd(
    A: jax.Array,
    panel_width: int,
    detector=None,
    mesh=None,
    axis_name: str = "qr",
    **orchestrator_kw,
) -> FTSweepResult:
    """Online recovery on the production SPMD path: host-side orchestrator,
    shard_map segments, runtime failure detection — no trace-time schedule.

    ``A`` is the full ``(m, n)`` matrix, row-sharded over the lane mesh like
    ``ft_caqr_sweep_spmd``. Extra keywords (``fault_hooks``,
    ``segment_points``, ``store``/``persist_every``, ...) pass through to
    ``repro.ft.online.orchestrator.SweepOrchestrator``. The result layout is
    the SimComm layout, directly comparable to both the simulator and the
    scheduled SPMD entry — a runtime-detected kill is bitwise-identical to
    the same kill expressed as a trace-time ``FailureSchedule``
    (``tests/test_spmd_ft_driver.py``).
    """
    from repro.ft.online.orchestrator import SweepOrchestrator

    if mesh is None:
        mesh = make_lane_mesh(axis_name=axis_name)
    n_lanes = mesh.shape[axis_name]
    m, n = A.shape
    assert m % n_lanes == 0, (
        f"rows ({m}) must block-shard evenly over {n_lanes} lanes"
    )
    orch = SweepOrchestrator(
        A.reshape(n_lanes, m // n_lanes, n), SimComm(n_lanes), panel_width,
        detector=detector,
        step_fn=make_spmd_sweep_step(mesh, axis_name),
        **orchestrator_kw,
    )
    return orch.run()
