import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import — jax locks the device
count at first init. Everything else in the framework sees the real device
count; only this entrypoint forces 512 host devices so the production
meshes (16x16 and 2x16x16) can be built.

Per cell:
  * build the production mesh and the sharding-rule table;
  * lower the cell's step (train_step for train shapes, serve_step for
    decode shapes, prefill for prefill shapes) against ShapeDtypeStruct
    inputs with explicit in_shardings;
  * compile; record memory_analysis(), cost_analysis(), and the collective
    operand bytes parsed from the post-SPMD HLO;
  * write a JSON artifact to experiments/dryrun/ for §Roofline.

CLI:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both]    # subprocess per cell
  python -m repro.launch.dryrun --arch caqr            # the paper's own workload
"""
import argparse
import json
import re
import subprocess
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config, get_shape
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.dist import compat
from repro.dist import params_sharding as psh
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh, make_qr_mesh
from repro.models import api
from repro.models import transformer as tf

# Per-arch dry-run knobs: optimizer chosen so the training state fits
# 16 GiB/chip (adafactor's factored second moment is what lets the 1T-param
# kimi cell fit; see DESIGN.md §7 and EXPERIMENTS.md §Dry-run). Activations
# are bounded by sequence-parallel residual sharding + per-layer remat, so
# no gradient accumulation is needed.
TRAIN_KNOBS: Dict[str, Dict[str, Any]] = {
    "kimi-k2-1t-a32b": dict(opt="adafactor", remat_group=4),
    "nemotron-4-340b": dict(opt="adafactor", remat_group=4),
    "mixtral-8x22b": dict(opt="adafactor", remat_group=4),
    "mamba2-2.7b": dict(opt="adamw", remat_group=8),
    "recurrentgemma-9b": dict(opt="adamw", remat_group=2),
    "gemma2-2b": dict(opt="adamw", remat_group=2),
}

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _collective_bytes(hlo: str, n_per_group_default: int) -> Dict[str, Any]:
    """Sum ring-model wire bytes per collective kind from post-SPMD HLO."""
    dt_bytes = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "c64": 8,
                "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}
    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    totals = {k: 0.0 for k in kinds}
    counts = {k: 0 for k in kinds}
    op_re = re.compile(
        r"=\s+(?:\()?((?:[a-z0-9]+)\[[0-9,]*\][^)]*?)\)?\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start|-done)?\(", )
    shape_re = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    group_re = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
    group_re2 = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

    for line in hlo.splitlines():
        m = op_re.search(line)
        if not m or "-done(" in line:
            continue
        kind = m.group(2)
        # group size
        gm = group_re.search(line)
        if gm:
            gsize = int(gm.group(2))
        else:
            gm2 = group_re2.search(line)
            gsize = len(gm2.group(1).split(",")) if gm2 else n_per_group_default
        # sum all result shapes on the line (tuples possible)
        nbytes = 0.0
        for sm in shape_re.finditer(m.group(1)):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in dt_bytes:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * dt_bytes[dt]
        if gsize <= 1:
            continue
        ring = (gsize - 1) / gsize
        factor = {"all-gather": ring, "reduce-scatter": ring,
                  "all-to-all": ring, "collective-permute": 1.0,
                  "all-reduce": 2.0 * ring}[kind]
        totals[kind] += nbytes * factor
        counts[kind] += 1
    totals["total_bytes"] = float(sum(totals[k] for k in kinds))
    totals["counts"] = counts
    return totals


def _abstract_opt_state(opt, params_abs):
    return jax.eval_shape(opt.init, params_abs)


def _model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D for training; 2*N*D per
    generated token for decode."""
    n_params = 0
    n_active = 0
    for leaf in jax.tree_util.tree_leaves(api.param_specs(cfg)):
        n = int(np.prod(leaf.shape))
        n_params += n
    if cfg.moe is not None:
        # active = non-expert params + top_k/E of expert params
        expert = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(api.param_specs(cfg))[0]:
            name = str(path)
            if any(w in name for w in ("w_gate", "w_in", "w_out")) and len(leaf.shape) >= 4:
                expert += int(np.prod(leaf.shape))
        n_active = n_params - expert + expert * cfg.moe.top_k / cfg.moe.n_experts
    else:
        n_active = n_params
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    # fwd+bwd for training; fwd only for prefill and per-token decode
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens, n_params, n_active


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, multi_pod: bool,
               rule_overrides: Optional[Dict[str, Any]] = None,
               fsdp_override: Optional[Any] = "unset"):
    """Returns (fn, args_abs, in_shardings, out_shardings, rules)."""
    fsdp = ("pod", "data") if multi_pod else "data"
    if fsdp_override != "unset":
        fsdp = fsdp_override
    rules = shd.multi_pod_rules() if multi_pod else shd.single_pod_rules()
    if rule_overrides:
        rules.update(rule_overrides)
    batch_axes = rules["batch"]

    if shape.kind == "train":
        rules = dict(rules)
        if not (rule_overrides and "seq_shard" in rule_overrides):
            # sequence parallelism on the residual stream (default on) —
            # EXCEPT for recurrent mixers (Mamba2 SSD / RG-LRU): their
            # chunk/associative scans run over the sequence dim, and a
            # sharded scan dim forces the partitioner into per-iteration
            # all-gathers (observed 200 GiB/device blowup).
            kinds = {cfg.mixer_at(i) for i in range(cfg.n_layers)}
            rules["seq_shard"] = None if kinds & {"M", "R"} else "model"
        knobs = TRAIN_KNOBS.get(cfg.name, dict(opt="adamw"))
        if knobs["opt"] == "adafactor":
            from repro.optim.adafactor import adafactor
            opt = adafactor()
        else:
            from repro.optim.adamw import adamw
            opt = adamw()
        from repro.optim.schedule import constant
        from repro.train.step import TrainState, make_train_step
        step = make_train_step(cfg, opt, constant(1e-3))

        params_abs = api.param_specs(cfg)
        opt_abs = _abstract_opt_state(opt, params_abs)
        batch_abs = api.train_input_specs(cfg, shape)
        state_abs = TrainState(params_abs, opt_abs,
                               jax.ShapeDtypeStruct((), jnp.int32))
        p_sh = psh.tree_shardings(params_abs, mesh, fsdp)
        o_sh = psh.tree_shardings(opt_abs, mesh, fsdp)
        b_sh = psh.batch_shardings(batch_abs, mesh, batch_axes)
        state_sh = TrainState(p_sh, o_sh, NamedSharding(mesh, P()))
        return step, (state_abs, batch_abs), (state_sh, b_sh), (state_sh, None), rules

    if shape.kind == "prefill":
        fn = api.make_prefill(cfg)
        params_abs = api.param_specs(cfg)
        batch_abs = api.train_input_specs(cfg, shape)
        batch_abs.pop("labels")
        p_sh = psh.tree_shardings(params_abs, mesh, fsdp)
        b_sh = psh.batch_shardings(batch_abs, mesh, batch_axes)
        return fn, (params_abs, batch_abs), (p_sh, b_sh), None, rules

    # decode
    rules = dict(rules)
    if rule_overrides and "kv_seq_shard" in rule_overrides:
        pass  # caller controls the cache sharding
    elif shape.name == "long_500k":
        rules = shd.long_decode_overrides(rules)
        batch_axes = rules["batch"]
    else:
        # decode_32k: flash-decode over the model axis — the cache seq dim
        # shards 16-way (kv heads often cannot), cutting cache HBM 16x; the
        # partitioner inserts the tiny per-layer softmax all-reduces.
        rules["kv_seq_shard"] = "model"
    serve = api.make_serve_step(cfg)
    params_abs = api.param_specs(cfg)
    specs = api.decode_input_specs(cfg, shape)
    p_sh = psh.tree_shardings(params_abs, mesh, fsdp)
    tok_sh = psh.batch_shardings(
        {"token": specs["token"]}, mesh, batch_axes)["token"]
    cache_sh = psh.cache_shardings(
        specs["caches"], mesh, batch_axes, rules["kv_seq_shard"])
    args = [params_abs, specs["token"], specs["pos"], specs["caches"]]
    shardings = [p_sh, tok_sh, NamedSharding(mesh, P()), cache_sh]
    if cfg.encoder is not None:
        args.append(specs["enc_out"])
        shardings.append(psh.batch_shardings(
            {"e": specs["enc_out"]}, mesh, batch_axes)["e"])
    return serve, tuple(args), tuple(shardings), (None, cache_sh), rules


def _compile_variant(cfg, shape, mesh, multi_pod, rule_overrides=None,
                     fsdp_override="unset"):
    fn, args, in_sh, out_sh, rules = build_cell(
        cfg, shape, mesh, multi_pod, rule_overrides, fsdp_override)
    # donation: the train step donates its TrainState; the serve step donates
    # its caches — in-place update semantics, as a real engine runs.
    if shape.kind == "train":
        donate = (0,)
    elif shape.kind == "decode":
        donate = (3,)
    else:
        donate = ()
    t0 = time.time()
    with compat.set_mesh(mesh), shd.use_rules(rules):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return compiled, time.time() - t0


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             overrides: Optional[Dict[str, Any]] = None,
             rule_overrides: Optional[Dict[str, Any]] = None,
             fsdp_override: Any = "unset",
             tag: str = "") -> Dict:
    import dataclasses

    multi_pod = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = api.supports_shape(cfg, shape)
    if not ok:
        print(f"SKIP {arch} x {shape_name}: {why}")
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}

    # --- memory compile: production form (scanned layers, scan-scheduled ---
    # attention). XLA:CPU's buffer assignment over a fully unrolled graph
    # does not reuse buffers the way the TPU pipeliner does, and it emulates
    # bf16 dots in f32; the scanned module's memory analysis is the faithful
    # one.
    overrides = dict(overrides or {})
    if shape.kind == "train":
        rg = TRAIN_KNOBS.get(arch, {}).get("remat_group", 1)
        overrides.setdefault("remat_group", rg)
    cfg_mem = dataclasses.replace(cfg, attn_schedule="scan", **overrides)
    compiled_mem, t_mem = _compile_variant(
        cfg_mem, shape, mesh, multi_pod, rule_overrides, fsdp_override)
    ma = compiled_mem.memory_analysis()

    # --- cost compiles: two-point depth extrapolation -----------------------
    # XLA's cost_analysis counts while bodies once, and fully unrolling a
    # 96-layer stack does not compile in reasonable time on one CPU core.
    # The layer stack is periodic, so cost(L) = fixed + per_layer * L is
    # exact for flops and an excellent model for bytes/collectives: compile
    # (unrolled) at L1 = period and L2 = 2*period and extrapolate to the
    # full depth. Validated against a full unroll on tinyllama (<2% error).
    n_tokens = shape.global_batch * shape.seq_len
    loss_chunk = cfg.loss_chunk
    if shape.kind == "train":
        for cand in (n_tokens // 8, n_tokens // 16, n_tokens // 4, n_tokens):
            if cand and n_tokens % cand == 0:
                loss_chunk = cand
                break
    period = cfg.pattern_period
    L1, L2, L_full = period, 2 * period, cfg.n_layers
    t0 = time.time()
    if multi_pod:
        # The multi-pod pass proves the 'pod' axis shards (the production-
        # form lower+compile above succeeded); the roofline/cost table is
        # single-pod only, so the cost compiles are skipped here.
        ca = {"flops": 0.0, "bytes accessed": 0.0}
        coll = {"total_bytes": 0.0,
                "skipped": "cost analysis is single-pod only"}
        hlo_len = 0
    else:
        def cost_point(n_layers):
            cfg_c = dataclasses.replace(
                cfg, n_layers=n_layers, scan_unroll=True, loss_chunk=loss_chunk,
                attn_schedule="tri", **(overrides or {}))
            compiled_c, _ = _compile_variant(
                cfg_c, shape, mesh, multi_pod, rule_overrides, fsdp_override)
            ca = compiled_c.cost_analysis() or {}
            try:
                hlo = compiled_c.as_text()
                coll = _collective_bytes(hlo, 16)
                hlo_len = len(hlo)
            except Exception as e:  # pragma: no cover
                coll = {"total_bytes": 0.0, "error": str(e)}
                hlo_len = 0
            return ca, coll, hlo_len

        ca1, coll1, _ = cost_point(L1)
        ca2, coll2, hlo_len = cost_point(L2)

        def extrap(v1, v2):
            per_layer = (v2 - v1) / (L2 - L1)
            return v1 + per_layer * (L_full - L1)

        ca = {
            "flops": extrap(float(ca1.get("flops", 0.0)), float(ca2.get("flops", 0.0))),
            "bytes accessed": extrap(float(ca1.get("bytes accessed", 0.0)),
                                     float(ca2.get("bytes accessed", 0.0))),
        }
        kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                 "collective-permute")
        coll = {k: extrap(float(coll1.get(k, 0.0)), float(coll2.get(k, 0.0)))
                for k in kinds}
        coll["total_bytes"] = float(sum(coll[k] for k in kinds))
        coll["counts_L2"] = coll2.get("counts", {})
        coll["extrapolated_from_layers"] = [L1, L2]
    t_cost = time.time() - t0
    t_lower, t_compile = t_mem, t_cost
    mf, n_params, n_active = _model_flops(cfg, shape)

    # Analytic activation-memory estimate (TPU projection): XLA:CPU's buffer
    # assignment over scanned+rematted graphs does not model the TPU
    # pipeliner's reuse (and counts bf16 emulation in f32), so alongside the
    # CPU temp number we record: args + outputs + scan-carry stashes
    # (n_groups/remat_group x sharded residual) + a 2x working-set factor.
    if shape.kind == "train":
        mesh_axes = dict(mesh.shape)
        batch_div = mesh_axes.get("data", 1) * mesh_axes.get("pod", 1)
        seq_div = 1
        kinds = {cfg.mixer_at(i) for i in range(cfg.n_layers)}
        if not (kinds & {"M", "R"}):
            seq_div = mesh_axes.get("model", 1)
        rg = overrides.get("remat_group", 1)
        period = cfg.pattern_period
        n_groups = cfg.n_layers // period
        n_stash = max(n_groups // max(rg, 1), 1) + cfg.n_layers % period
        stash = (shape.global_batch // batch_div) * (shape.seq_len // seq_div) \
            * cfg.d_model * 2 * n_stash
        analytic = int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                       - ma.alias_size_in_bytes + 3 * stash)
    else:
        analytic = int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                       - ma.alias_size_in_bytes + 2 * 2**30)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "status": "ok",
        "n_chips": int(np.prod(list(mesh.shape.values()))),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_est": int(ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes),
            "peak_bytes_analytic": analytic,
        },
        "cost": {
            "flops_per_device": float(ca.get("flops", 0.0)),
            "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        },
        "collectives": coll,
        "model_flops_global": float(mf),
        "n_params": int(n_params),
        "n_active_params": int(n_active),
        "hlo_chars": hlo_len,
        "t_compile_mem_s": round(t_lower, 1),
        "t_compile_cost_s": round(t_compile, 1),
    }
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    rec["tag"] = tag
    fname = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    peak_gb = rec["memory"]["peak_bytes_analytic"] / 2**30
    print(f"OK {arch} x {shape_name} x {mesh_kind}: "
          f"peak/device ~{peak_gb:.2f} GiB (analytic; "
          f"cpu-assign {rec['memory']['peak_bytes_est']/2**30:.1f}), "
          f"flops/device {rec['cost']['flops_per_device']:.3e}, "
          f"coll {coll.get('total_bytes', 0)/2**30:.3f} GiB "
          f"(compile mem {t_lower:.0f}s + cost {t_compile:.0f}s)")
    return rec


def run_caqr_cell(mesh_kind: str, out_dir: str, m_rows: int = 65536,
                  n_cols: int = 4096, panel: int = 128, tag: str = "") -> Dict:
    """The paper's own workload: FT-CAQR of a general matrix on the full
    pod (one lane per chip)."""
    from repro.core import AxisComm
    from repro.core.caqr import caqr_factorize

    multi_pod = mesh_kind == "multi"
    mesh = make_qr_mesh(multi_pod=multi_pod)
    lanes = 512 if multi_pod else 256

    def qr_fn(a):
        res = caqr_factorize(a, AxisComm("qr"), panel)
        return res.R

    spec = P("qr", None)
    fn = jax.jit(
        compat.shard_map(qr_fn, mesh, in_specs=spec, out_specs=P())
    )
    A = jax.ShapeDtypeStruct((m_rows, n_cols), jnp.float32)
    t0 = time.time()
    with compat.set_mesh(mesh):
        lowered = fn.lower(A)
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = _collective_bytes(hlo, lanes)
    # the panel sweep is a lax.scan: XLA counts the while body once ->
    # multiply by the trip count (n_panels)
    trips = n_cols // panel
    ca = {k: (v * trips if isinstance(v, float) else v) for k, v in ca.items()}
    for k in list(coll):
        if isinstance(coll[k], float):
            coll[k] *= trips
    # CAQR model flops: 2 m n^2 - (2/3) n^3
    mf = 2 * m_rows * n_cols**2 - (2 / 3) * n_cols**3
    rec = {
        "arch": "caqr", "shape": f"qr_{m_rows}x{n_cols}_b{panel}",
        "mesh": mesh_kind, "status": "ok", "n_chips": lanes,
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_bytes_est": int(ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes),
        },
        "cost": {
            "flops_per_device": float(ca.get("flops", 0.0)),
            "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        },
        "collectives": coll,
        "model_flops_global": float(mf),
        "t_total_s": round(time.time() - t0, 1),
    }
    os.makedirs(out_dir, exist_ok=True)
    rec["tag"] = tag
    suffix = f"__{tag}" if tag else ""
    with open(os.path.join(out_dir, f"caqr__{mesh_kind}{suffix}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"OK caqr x {mesh_kind}: flops/dev {rec['cost']['flops_per_device']:.3e} "
          f"coll {coll['total_bytes']/2**30:.3f} GiB ({rec['t_total_s']}s)")
    return rec


def all_cells():
    cells = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if api.supports_shape(cfg, shape)[0]:
                cells.append((arch, shape.name))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.list:
        for a, s in all_cells():
            print(f"{a} x {s}")
        print("caqr x qr_65536x4096")
        return

    if args.all:
        failures = []
        for a, s in all_cells():
            for mk in meshes:
                fname = os.path.join(args.out, f"{a}__{s}__{mk}.json")
                if os.path.exists(fname):
                    print(f"cached {a} x {s} x {mk}")
                    continue
                r = subprocess.run(
                    [sys.executable, "-m", "repro.launch.dryrun",
                     "--arch", a, "--shape", s, "--mesh", mk, "--out", args.out],
                    env={**os.environ, "PYTHONPATH": "src"},
                )
                if r.returncode != 0:
                    failures.append((a, s, mk))
        for mk in meshes:
            if not os.path.exists(os.path.join(args.out, f"caqr__{mk}.json")):
                subprocess.run(
                    [sys.executable, "-m", "repro.launch.dryrun",
                     "--arch", "caqr", "--mesh", mk, "--out", args.out],
                    env={**os.environ, "PYTHONPATH": "src"},
                )
        if failures:
            print("FAILED CELLS:", failures)
            sys.exit(1)
        print("ALL CELLS OK")
        return

    assert args.arch
    for mk in meshes:
        if args.arch == "caqr":
            run_caqr_cell(mk, args.out)
        else:
            assert args.shape
            run_cell(args.arch, args.shape, mk, args.out)


if __name__ == "__main__":
    main()
