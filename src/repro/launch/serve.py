"""Serving driver: ``python -m repro.launch.serve --arch <id>``.

Loads a checkpoint if given (else random init), then serves synthetic
batched requests through the prefill + cached-decode engine.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.ckpt import save as ckpt_save
from repro.configs import ARCHS, get_config, get_smoke
from repro.models import transformer as tf
from repro.serve import Engine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=sorted(ARCHS))
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke(args.arch)
    params = tf.init_params(cfg, jax.random.key(0))
    if args.ckpt:
        # params-only restore: serving has no optimizer skeleton to offer
        # as the opt_like template (and must not pass the params tree as
        # one — the opt npz has a different structure)
        params, _ = ckpt_save.restore_params(args.ckpt, params)
    engine = Engine(cfg, params, ServeConfig(
        max_new_tokens=args.max_new, temperature=args.temperature))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    extras = {}
    if cfg.vlm is not None:
        extras["patch_embeds"] = rng.standard_normal(
            (args.batch, cfg.vlm.n_patches, cfg.d_model)).astype(np.float32)
    if cfg.encoder is not None:
        extras["enc_frames"] = rng.standard_normal(
            (args.batch, cfg.encoder.n_frames, cfg.d_model)).astype(np.float32)
    out = engine.generate(prompts, extras=extras or None)
    print(f"served batch={args.batch}: generated {out.shape}")
    print(out)


if __name__ == "__main__":
    main()
