"""Training driver: ``python -m repro.launch.train --arch <id> [options]``.

Single-host entrypoint (the dry-run proves the production-mesh lowering;
this driver runs real steps on whatever devices exist). Smoke-scale by
default; pass --full to use the published config (requires a real pod).
"""
from __future__ import annotations

import argparse

from repro.configs import ARCHS, get_config, get_smoke
from repro.data.pipeline import DataConfig
from repro.ft.failures import FailureSchedule
from repro.ft.semantics import Semantics
from repro.train import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=sorted(ARCHS))
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (needs a pod)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "caqr_muon"])
    ap.add_argument("--semantics", default="rebuild",
                    choices=[s.value for s in Semantics])
    ap.add_argument("--fail", default="",
                    help="failure schedule, e.g. '17:2,30:1' (step:lane)")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke(args.arch)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                      global_batch=args.global_batch)
    tcfg = TrainConfig(
        steps=args.steps, lr=args.lr, n_lanes=args.lanes,
        optimizer=args.optimizer, semantics=Semantics(args.semantics),
        ckpt_every=50 if args.ckpt_dir else 0,
        ckpt_dir=args.ckpt_dir or "/tmp/repro_ckpt",
    )
    schedule = None
    if args.fail:
        events = {}
        for part in args.fail.split(","):
            s, l = part.split(":")
            events.setdefault(int(s), []).append(int(l))
        schedule = FailureSchedule(events=events)
    Trainer(cfg, tcfg, dcfg).run(schedule)


if __name__ == "__main__":
    main()
