"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real (single) device.
"""
from __future__ import annotations

from repro.dist import compat


def _mk(shape, axes):
    return compat.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_qr_mesh(*, multi_pod: bool = False):
    """1-D lane mesh for the paper's own CAQR workload (one lane per chip;
    the tree spans the whole pod / both pods)."""
    n = 512 if multi_pod else 256
    return _mk((n,), ("qr",))


def make_small_mesh(n_data: int = 4, n_model: int = 2):
    """Test-sized mesh (subprocess tests with 8 host devices)."""
    return _mk((n_data, n_model), ("data", "model"))
