"""Launch: production meshes, multi-pod dry-run, training/serving drivers.

NOTE: do not import repro.launch.dryrun from library code — it force-sets
XLA_FLAGS device count at import time (dry-run entrypoint only).
"""
from repro.launch import mesh, spmd_qr
from repro.launch.spmd_qr import (
    ft_caqr_sweep_online_spmd,
    ft_caqr_sweep_spmd,
    make_lane_mesh,
    make_spmd_sweep_step,
)

__all__ = ["mesh", "spmd_qr", "ft_caqr_sweep_online_spmd",
           "ft_caqr_sweep_spmd", "make_lane_mesh", "make_spmd_sweep_step"]
