"""QR-service driver: ``python -m repro.launch.serve_qr``.

Generates a synthetic burst of ragged factorization / least-squares
requests, streams them through the continuous-batching ``QRService``
(``repro.serve.qr_service``), optionally kills a lane mid-batch, and
reports sustained throughput + latency percentiles. Every retired R is
checked against ``numpy.linalg.qr`` of the tenant's own matrix (sign-fixed
columns), and lstsq solutions against ``numpy.linalg.lstsq`` — so the run
is a correctness smoke as well as a traffic demo (``tools/ci.sh`` runs it
with ``--kill-lane`` as the serve smoke tier).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import SimComm
from repro.serve.qr_service import QRService


def make_requests(rng: np.random.Generator, count: int, b: int,
                  max_m: int, max_n: int, lstsq_frac: float):
    """Ragged synthetic traffic: shapes uniform in [b, max]; a fraction
    carries a right-hand side (the lstsq tenants)."""
    reqs = []
    for _ in range(count):
        m = int(rng.integers(b, max_m + 1))
        n = int(rng.integers(b, max_n + 1))
        A = rng.standard_normal((m, n)).astype(np.float32)
        rhs = None
        if rng.random() < lstsq_frac and m >= n:
            rhs = rng.standard_normal((m, 2)).astype(np.float32)
        reqs.append((A, rhs))
    return reqs


def verify(res, A, rhs) -> None:
    k, n = min(A.shape), A.shape[1]
    Q_ref, R_ref = np.linalg.qr(A.astype(np.float64), mode="reduced")
    # QR is unique up to column signs of Q / row signs of R
    s = np.sign(np.diag(R_ref[:k, :k]))
    s[s == 0] = 1.0
    R_ref = s[:, None] * R_ref[:k, :n]
    s_got = np.sign(np.diag(res.R[:k, :k]))
    s_got[s_got == 0] = 1.0
    R_got = s_got[:, None] * res.R
    assert np.allclose(R_got, R_ref, atol=1e-3), (
        f"{res.rid}: R mismatch, max err "
        f"{np.abs(R_got - R_ref).max():.2e}")
    if rhs is not None:
        x_ref, *_ = np.linalg.lstsq(
            A.astype(np.float64), rhs.astype(np.float64), rcond=None)
        assert np.allclose(res.x, x_ref, atol=1e-2), (
            f"{res.rid}: lstsq mismatch, max err "
            f"{np.abs(res.x - x_ref).max():.2e}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--panel-width", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-m", type=int, default=24)
    ap.add_argument("--max-n", type=int, default=12)
    ap.add_argument("--lstsq-frac", type=float, default=0.3)
    ap.add_argument("--arrive-every", type=int, default=1,
                    help="submit one request per this many ticks (0 = all "
                         "up front)")
    ap.add_argument("--kill-lane", type=int, default=-1,
                    help="kill this lane mid-batch (-1 = failure-free)")
    ap.add_argument("--kill-tick", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    comm = SimComm(args.lanes)
    b = args.panel_width
    m_loc = -(-args.max_m // args.lanes)
    m_loc += (-m_loc) % b
    bucket = (m_loc, args.max_n + 2)   # +2: room for the lstsq rhs columns
    svc = QRService(comm, panel_width=b, buckets=[bucket],
                    max_slots=args.slots)
    reqs = make_requests(rng, args.requests, b, args.max_m, args.max_n,
                         args.lstsq_frac)

    import time
    pending = list(reqs)
    by_rid = {}
    t0 = time.perf_counter()
    killed = False
    while pending or svc.queue or svc.resident:
        if args.arrive_every == 0:
            while pending:
                A, rhs = pending.pop(0)
                by_rid[svc.submit(A, rhs)] = (A, rhs)
        elif pending and svc.tick_count % args.arrive_every == 0:
            A, rhs = pending.pop(0)
            by_rid[svc.submit(A, rhs)] = (A, rhs)
        if (args.kill_lane >= 0 and not killed
                and svc.tick_count == args.kill_tick):
            svc.kill_lane(args.kill_lane)
            killed = True
        svc.tick()
    wall = time.perf_counter() - t0

    lat = np.array(sorted(r.latency_s for r in svc.results.values()))
    heals = sum(len(r.events) for r in svc.results.values())
    for rid, (A, rhs) in by_rid.items():
        verify(svc.results[rid], A, rhs)
    print(f"served {len(svc.results)} requests in {wall:.2f}s "
          f"({len(svc.results) / wall:.1f} req/s) over {svc.tick_count} "
          f"ticks; p50 {lat[len(lat) // 2] * 1e3:.1f}ms "
          f"p99 {lat[min(len(lat) - 1, int(len(lat) * 0.99))] * 1e3:.1f}ms; "
          f"{heals} tenant REBUILDs; "
          f"{svc.compiled_programs} resident compiled segments")
    print("all results verified against numpy QR/lstsq")


if __name__ == "__main__":
    main()
