"""gemma-7b [dense]: 28L d_model=3072 16H (kv=16, head_dim=256) d_ff=24576,
vocab=256000 — GeGLU [arXiv:2403.08295]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b", family="dense",
        n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
        d_ff=24576, vocab=256000, activation="geglu",
        mixer_pattern="G", ffn_pattern="D",
        embed_scale=True, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=256, activation="geglu",
        mixer_pattern="G", ffn_pattern="D",
        embed_scale=True, dtype="float32",
    )
