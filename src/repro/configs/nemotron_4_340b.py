"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728,
vocab=256000 — squared-ReLU MLP [arXiv:2402.16819]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b", family="dense",
        n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, d_ff=73728,
        vocab=256000, activation="sq_relu",
        mixer_pattern="G", ffn_pattern="D",
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="nemotron-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=256,
        vocab=256, activation="sq_relu",
        mixer_pattern="G", ffn_pattern="D",
        tie_embeddings=False, dtype="float32",
    )
