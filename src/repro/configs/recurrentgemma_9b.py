"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1,
head_dim=256) d_ff=12288, vocab=256000 — RG-LRU + local attention,
pattern (R,R,L) [arXiv:2402.19427]."""
from repro.configs.base import ModelConfig, RGLRUConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
        d_ff=12288, vocab=256000, activation="geglu",
        mixer_pattern="RRL", ffn_pattern="D", sliding_window=2048,
        rglru=RGLRUConfig(lru_width=4096),
        embed_scale=True, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke", family="hybrid",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=256, activation="geglu",
        mixer_pattern="RRL", ffn_pattern="D", sliding_window=16,
        rglru=RGLRUConfig(lru_width=64),
        embed_scale=True, dtype="float32",
    )
