"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4, head_dim=256)
d_ff=9216, vocab=256000 — local/global alternating (window 4096), logit
softcap 30, attn softcap 50, sandwich norms [arXiv:2408.00118]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b", family="dense",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=9216, vocab=256000, activation="geglu",
        mixer_pattern="LG", ffn_pattern="D", sliding_window=4096,
        logit_softcap=30.0, attn_softcap=50.0,
        post_norms=True, embed_scale=True, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, activation="geglu",
        mixer_pattern="LG", ffn_pattern="D", sliding_window=16,
        logit_softcap=30.0, attn_softcap=50.0,
        post_norms=True, embed_scale=True, dtype="float32",
    )
