"""mamba2-2.7b [ssm]: 64L d_model=2560, attention-free SSD, vocab=50280,
ssm_state=128 [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1, d_ff=0,
        vocab=50280, activation="silu",
        mixer_pattern="M", ffn_pattern="N",
        ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1, chunk=256),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=4, d_model=64, n_heads=1, n_kv_heads=1, d_ff=0,
        vocab=256, activation="silu",
        mixer_pattern="M", ffn_pattern="N",
        ssm=SSMConfig(d_state=16, expand=2, head_dim=16, n_groups=1, chunk=8),
        dtype="float32",
    )
