"""whisper-base [audio]: 6L enc + 6L dec, d_model=512, 8H MHA, d_ff=2048,
vocab=51865. Conv/mel frontend is a STUB (precomputed frame embeddings).
Adaptation note (DESIGN.md): RoPE replaces whisper's learned positions."""
from repro.configs.base import EncoderConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio",
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
        vocab=51865, activation="gelu",
        mixer_pattern="G", ffn_pattern="D",
        encoder=EncoderConfig(n_layers=6, n_frames=1500),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, activation="gelu",
        mixer_pattern="G", ffn_pattern="D",
        encoder=EncoderConfig(n_layers=2, n_frames=16),
        dtype="float32",
    )
