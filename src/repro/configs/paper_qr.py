"""The paper's own workload configs: FT-CAQR of general matrices.

These parameterize the ``caqr`` dry-run cell and the benchmarks; shapes
follow the communication-avoiding literature's convention of tall panels
(b = 128 keeps the MXU-aligned tile contract of the Pallas kernels).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class QRConfig:
    name: str
    m_rows: int
    n_cols: int
    panel: int


PRODUCTION = QRConfig("caqr-prod", m_rows=65536, n_cols=4096, panel=128)
SMOKE = QRConfig("caqr-smoke", m_rows=512, n_cols=128, panel=16)
