"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8, head_dim=128)
d_ff=14336, vocab=131072 — pixtral-ViT frontend is a STUB (precomputed
patch embeddings) + mistral-nemo backbone [hf:mistralai/Pixtral-12B-2409]."""
from repro.configs.base import ModelConfig, VLMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=131072, activation="swiglu",
        mixer_pattern="G", ffn_pattern="D",
        vlm=VLMConfig(n_patches=1024),
        tie_embeddings=False, rope_theta=1e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="pixtral-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, activation="swiglu",
        mixer_pattern="G", ffn_pattern="D",
        vlm=VLMConfig(n_patches=8),
        tie_embeddings=False, dtype="float32",
    )
