"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) expert_ff=16384,
vocab=32768, 8 experts top-2, SWA window 4096 [arXiv:2401.04088]."""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
        vocab=32768, activation="swiglu",
        mixer_pattern="L", ffn_pattern="E", sliding_window=4096,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384),
        tie_embeddings=False, rope_theta=1e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, activation="swiglu",
        mixer_pattern="L", ffn_pattern="E", sliding_window=16,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128),
        tie_embeddings=False, dtype="float32",
    )
