"""Architecture registry: one module per assigned arch (+ the paper's own
QR problem configs in paper_qr)."""
from repro.configs import (
    gemma2_2b,
    gemma_7b,
    kimi_k2,
    mamba2_2p7b,
    mixtral_8x22b,
    nemotron_4_340b,
    pixtral_12b,
    recurrentgemma_9b,
    tinyllama_1p1b,
    whisper_base,
)
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, get_shape

ARCHS = {
    "mamba2-2.7b": mamba2_2p7b,
    "whisper-base": whisper_base,
    "mixtral-8x22b": mixtral_8x22b,
    "kimi-k2-1t-a32b": kimi_k2,
    "gemma2-2b": gemma2_2b,
    "tinyllama-1.1b": tinyllama_1p1b,
    "gemma-7b": gemma_7b,
    "nemotron-4-340b": nemotron_4_340b,
    "pixtral-12b": pixtral_12b,
    "recurrentgemma-9b": recurrentgemma_9b,
}


def get_config(name: str) -> ModelConfig:
    cfg = ARCHS[name].config()
    cfg.validate()
    return cfg


def get_smoke(name: str) -> ModelConfig:
    cfg = ARCHS[name].smoke()
    cfg.validate()
    return cfg


__all__ = [
    "ARCHS", "SHAPES", "ModelConfig", "ShapeConfig", "get_config",
    "get_smoke", "get_shape",
]
