"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) expert_ff=2048,
vocab=163840, 384 experts top-8 — trillion-param MoE (paper-table)."""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
        vocab=163840, activation="swiglu",
        mixer_pattern="G", ffn_pattern="E",
        moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048,
                      capacity_factor=1.0),
        tie_embeddings=False, rope_theta=5e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="kimi-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
        vocab=256, activation="swiglu",
        mixer_pattern="G", ffn_pattern="E",
        moe=MoEConfig(n_experts=8, top_k=4, d_ff_expert=32, capacity_factor=1.0),
        tie_embeddings=False, dtype="float32",
    )
