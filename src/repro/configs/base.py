"""Architecture / run configuration schema.

One ``ModelConfig`` per assigned architecture lives in
``repro/configs/<arch>.py`` with the exact published numbers, plus a
``smoke()`` reduction of the same family for CPU tests.

Layer structure is described by two repeating pattern strings:
  mixer_pattern : per-layer token mixer
      'G' global (full) attention        'L' local / sliding-window attention
      'M' Mamba2 SSD block               'R' RG-LRU recurrent block
  ffn_pattern   : per-layer channel mixer
      'D' dense MLP                      'E' mixture-of-experts MLP
      'N' none (e.g. Mamba2 blocks carry no separate MLP)
Patterns repeat up to n_layers (e.g. gemma2's 'LG' alternation, or
recurrentgemma's 'RRL').
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: Optional[int] = None  # defaults to d_model
    conv_width: int = 4
    block_width: int = 4  # diagonal-block gating granularity


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder for enc-dec archs (whisper). The conv/mel frontend is a STUB:
    input_specs feed precomputed frame embeddings of length n_frames."""

    n_layers: int
    n_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    """Vision frontend STUB: input_specs feed precomputed patch embeddings
    that replace the first n_patches token positions."""

    n_patches: int = 1024


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    activation: str = "swiglu"      # swiglu | geglu | sq_relu | gelu
    mixer_pattern: str = "G"
    ffn_pattern: str = "D"
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None
    logit_softcap: Optional[float] = None
    attn_softcap: Optional[float] = None
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    post_norms: bool = False        # gemma2-style sandwich norms
    embed_scale: bool = False       # gemma-style sqrt(d_model) embedding scale
    qk_norm: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    vlm: Optional[VLMConfig] = None
    dtype: str = "bfloat16"
    # --- performance knobs (subject of §Perf iterations) -----------------
    attn_chunk: int = 2048          # KV block for streaming-softmax attention
    attn_chunk_threshold: int = 8192  # use chunked attention for S >= this
    attn_schedule: str = "scan"     # scan (production) | tri (cost compile)
    loss_chunk: int = 8192          # token chunk for the CE loss
    moe_shards: int = 1             # MoE dispatch groups (GSPMD: = data
                                    # shards so expert buffers shard; see moe.py)
    remat: str = "layer"            # none | layer (remat policy for bwd)
    remat_group: int = 1            # layer-groups per checkpoint span: the
                                    # bwd stash count is n_groups/remat_group
    scan_layers: bool = True        # scan-over-layers (compact HLO)
    scan_unroll: bool = False       # dry-run: unroll scans so XLA's
                                    # cost_analysis counts every iteration
                                    # (while bodies are counted once)

    @property
    def hdim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def mixer_at(self, layer: int) -> str:
        return self.mixer_pattern[layer % len(self.mixer_pattern)]

    def ffn_at(self, layer: int) -> str:
        return self.ffn_pattern[layer % len(self.ffn_pattern)]

    @property
    def pattern_period(self) -> int:
        import math

        return abs(
            len(self.mixer_pattern) * len(self.ffn_pattern)
        ) // math.gcd(len(self.mixer_pattern), len(self.ffn_pattern))

    @property
    def is_subquadratic(self) -> bool:
        """True when no layer needs an unbounded full-attention cache —
        the assignment's criterion for running long_500k."""
        kinds = {self.mixer_at(i) for i in range(self.n_layers)}
        return "G" not in kinds

    def validate(self) -> None:
        assert self.n_heads % self.n_kv_heads == 0, (self.n_heads, self.n_kv_heads)
        for i in range(self.n_layers):
            if self.mixer_at(i) == "M":
                assert self.ssm is not None
            if self.mixer_at(i) == "R":
                assert self.rglru is not None
            if self.ffn_at(i) == "E":
                assert self.moe is not None
            if self.mixer_at(i) == "L":
                assert self.sliding_window is not None


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell of the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
