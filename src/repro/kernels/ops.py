"""Public jit'd wrappers for the Pallas kernels — the dispatch seam.

``repro.core`` routes its hot operations here (see ``backend.dispatch_enabled``
for when). Each wrapper enforces the kernels' alignment contract
(rows % 8 == 0, panel width % 128 == 0 in f32) by zero-padding up to it and
slicing the result back — padding with zeros is exact in exact arithmetic
for every op in this family (extra zero rows/columns produce degenerate
reflectors with tau = 0 and contribute nothing to any inner product); in
floats the padded result differs from the unpadded kernel only by the
backend regrouping reductions at the larger size (roundoff-level). Aligned
shapes skip the copies entirely.

``interpret`` resolves through ``backend.interpret_default()``: compiled
Mosaic on TPU, interpreter elsewhere — nothing here hardcodes either.

``use_kernels(False)`` (or REPRO_NO_KERNELS=1) routes every call to the
pure-jnp oracle instead — the escape hatch for anything outside the
kernels' envelope (non-f32 dtypes route automatically). The flag state
lives in ``backend`` (shared with the core dispatch, read at trace time),
so the two layers cannot disagree.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import backend
from repro.kernels import ref
from repro.kernels import panel_qr as _panel
from repro.kernels import stacked_qr as _stacked
from repro.kernels import wy_apply as _wy

# shared override: use_kernels(None) restores the automatic policy
use_kernels = backend.use_kernels


def _interpret() -> bool:
    return backend.interpret_default()


def _kernel_ok(*arrays) -> bool:
    return backend.ops_kernels_enabled() and all(
        a.dtype == jnp.float32 for a in arrays
    )


def panel_qr(A: jax.Array, row_start=0) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(Y, T, R) of the masked Householder panel QR of A (m, b).

    ``row_start`` may be traced; padding uses only static shape info
    (rows pad by ``b_pad - b`` extra so the kernel's R extraction at any
    legal row_start <= m - b stays in bounds).
    """
    if not _kernel_ok(A):
        return ref.panel_qr(A, row_start)
    m, b = A.shape
    b_pad = backend.pad_to(b, backend.LANE)
    m_pad = backend.pad_to(m + (b_pad - b), backend.SUBLANE)
    rs = jnp.asarray(row_start, jnp.int32)
    if (m_pad, b_pad) == (m, b):
        return _panel.panel_qr(A, rs, interpret=_interpret())
    A_p = jnp.pad(A, ((0, m_pad - m), (0, b_pad - b)))
    Y, T, R = _panel.panel_qr(A_p, rs, interpret=_interpret())
    return Y[:m, :b], T[:b, :b], R[:b, :b]


def stacked_qr(R_top: jax.Array, R_bot: jax.Array):
    """(Y2, T, R) of the TSQR tree combine."""
    if not _kernel_ok(R_top, R_bot):
        return ref.stacked_qr(R_top, R_bot)
    b = R_top.shape[0]
    b_pad = backend.pad_to(b, backend.LANE)
    if b_pad == b:
        return _stacked.stacked_qr(R_top, R_bot, interpret=_interpret())
    pad = ((0, b_pad - b), (0, b_pad - b))
    Y2, T, R = _stacked.stacked_qr(
        jnp.pad(R_top, pad), jnp.pad(R_bot, pad), interpret=_interpret()
    )
    return Y2[:b, :b], T[:b, :b], R[:b, :b]


def wy_apply(Y: jax.Array, T: jax.Array, C: jax.Array, block_n: int = 256) -> jax.Array:
    """Fused Q^T C. The trailing dim of C is tiled/padded by the kernel."""
    if not _kernel_ok(Y, T, C):
        return ref.wy_apply(Y, T, C)
    m, b = Y.shape
    b_pad = backend.pad_to(b, backend.LANE)
    m_pad = backend.pad_to(m, backend.SUBLANE)
    if (m_pad, b_pad) == (m, b):
        return _wy.wy_apply(Y, T, C, block_n=block_n, interpret=_interpret())
    Y_p = jnp.pad(Y, ((0, m_pad - m), (0, b_pad - b)))
    T_p = jnp.pad(T, ((0, b_pad - b), (0, b_pad - b)))
    C_p = jnp.pad(C, ((0, m_pad - m), (0, 0)))
    out = _wy.wy_apply(Y_p, T_p, C_p, block_n=block_n, interpret=_interpret())
    return out[:m]


def stacked_apply(Y2, T, C_top, C_bot, block_n: int = 512):
    """Fused trailing combine; returns (Ct_hat, Cb_hat, W)."""
    if not _kernel_ok(Y2, T, C_top, C_bot):
        return ref.stacked_apply(Y2, T, C_top, C_bot)
    b = Y2.shape[0]
    b_pad = backend.pad_to(b, backend.LANE)
    if b_pad == b:
        return _stacked.stacked_apply(
            Y2, T, C_top, C_bot, block_n=block_n, interpret=_interpret()
        )
    bb = ((0, b_pad - b), (0, b_pad - b))
    rows = ((0, b_pad - b), (0, 0))
    ot, ob, W = _stacked.stacked_apply(
        jnp.pad(Y2, bb), jnp.pad(T, bb),
        jnp.pad(C_top, rows), jnp.pad(C_bot, rows),
        block_n=block_n, interpret=_interpret(),
    )
    return ot[:b], ob[:b], W[:b]
