"""Public wrappers for the Pallas kernels — the dispatch seam.

``repro.core`` routes its hot operations here (see ``backend.dispatch_enabled``
for when). Each call resolves the per-op policy (``backend.kernel_mode`` —
compiled / interpret / oracle) at trace time and routes accordingly:

* **compiled / pallas** — native non-interpret ``pallas_call`` (Mosaic on
  TPU, Triton on GPU), chosen when the once-per-process capability probe
  says this backend lowers the op.
* **compiled / xla** — the same tile program as plain compiled XLA
  (``*_xla`` in the kernel modules) where Pallas can't lower natively. No
  alignment contract: runs at natural shapes, no padding copies.
* **interpret** — the Pallas interpreter; the validation vehicle, never
  chosen automatically.
* **oracle** — the pure-jnp reference in ``ref.py``; also the automatic
  route for dtypes outside the kernels' envelope (f32 and bf16 are in).

The *pallas* routes enforce the alignment contract (rows in
``backend.sublane(dtype)`` multiples, panel widths in lane-pad multiples)
by zero-padding up to it and slicing back — padding with zeros is exact in
exact arithmetic for every op in this family (extra zero rows/columns
produce degenerate reflectors with tau = 0 and contribute nothing to any
inner product); in floats the padded result differs from the unpadded
kernel only by the backend regrouping reductions at the larger size
(roundoff-level).

Block shapes (``block_n`` column tiles, ``lane_pad`` width padding, the
``xla`` engines' column-loop ``unroll``) default to the autotuner's winner
for the call's (op, geometry, dtype, variant) cell when one was tuned
(``repro.kernels.autotune``), else to the static defaults. Explicit
arguments always win — that is how the tuner itself times candidates.

``use_kernels(False)`` (or REPRO_NO_KERNELS=1) routes every call to the
oracle — the escape hatch for anything outside the kernels' envelope. The
policy state lives in ``backend`` (shared with the core dispatch, read at
trace time), so the two layers cannot disagree.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import autotune, backend, ref
from repro.kernels import panel_qr as _panel
from repro.kernels import stacked_qr as _stacked
from repro.kernels import wy_apply as _wy

# shared override: use_kernels(None) restores the automatic policy
use_kernels = backend.use_kernels

DEFAULT_WY_BLOCK_N = 256
DEFAULT_STACKED_BLOCK_N = 512
DEFAULT_QR_UNROLL = 2

_SUPPORTED_DTYPES = ("float32", "bfloat16")

# per-call routes (the resolved leg of the policy)
_R_ORACLE = "oracle"
_R_INTERPRET = "interpret"
_R_PALLAS = backend.ENGINE_PALLAS
_R_XLA = backend.ENGINE_XLA


def _interpret() -> bool:
    return backend.interpret_default()


def _route(op: str, *arrays) -> str:
    """Resolve policy + dtype envelope to one of oracle/interpret/pallas/xla."""
    if any(a.dtype.name not in _SUPPORTED_DTYPES for a in arrays):
        return _R_ORACLE
    mode = backend.kernel_mode(op)
    if mode == backend.MODE_ORACLE:
        return _R_ORACLE
    if mode == backend.MODE_INTERPRET:
        return _R_INTERPRET
    return backend.compiled_engine(op)


def _lane_pad(op: str, geometry, dtype, route: str, explicit) -> int:
    if explicit is not None:
        return explicit
    tuned = autotune.lookup(op, geometry, dtype, route).get("lane_pad")
    if tuned is not None and not (route == _R_PALLAS and tuned != backend.LANE):
        return tuned
    return backend.LANE


def _block_n(op: str, geometry, dtype, route: str, explicit, default) -> int:
    if explicit is not None:
        return explicit
    return autotune.lookup(op, geometry, dtype, route).get("block_n", default)


def _unroll(op: str, geometry, dtype, route: str, explicit) -> int:
    if explicit is not None:
        return explicit
    return autotune.lookup(op, geometry, dtype, route).get(
        "unroll", DEFAULT_QR_UNROLL)


def panel_qr(A: jax.Array, row_start=0, *,
             lane_pad: Optional[int] = None,
             unroll: Optional[int] = None
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(Y, T, R) of the masked Householder panel QR of A (m, b).

    ``row_start`` may be traced; padding uses only static shape info
    (rows pad by ``b_pad - b`` extra so the kernel's R extraction at any
    legal row_start <= m - b stays in bounds). ``unroll`` is the ``xla``
    engine's column-loop unroll factor (autotuned when not given).
    """
    route = _route("panel_qr", A)
    if route == _R_ORACLE:
        return ref.panel_qr(A, row_start)
    rs = jnp.asarray(row_start, jnp.int32)
    if route == _R_XLA:
        u = _unroll("panel_qr", A.shape, A.dtype, route, unroll)
        return _panel.panel_qr_xla(A, rs, unroll=u)
    m, b = A.shape
    lane = _lane_pad("panel_qr", (m, b), A.dtype, route, lane_pad)
    b_pad = backend.pad_to(b, lane)
    m_pad = backend.pad_to(m + (b_pad - b), backend.sublane(A.dtype))
    interp = route == _R_INTERPRET
    if (m_pad, b_pad) == (m, b):
        return _panel.panel_qr(A, rs, interpret=interp)
    A_p = jnp.pad(A, ((0, m_pad - m), (0, b_pad - b)))
    Y, T, R = _panel.panel_qr(A_p, rs, interpret=interp)
    return Y[:m, :b], T[:b, :b], R[:b, :b]


def stacked_qr(R_top: jax.Array, R_bot: jax.Array, *,
               lane_pad: Optional[int] = None,
               unroll: Optional[int] = None):
    """(Y2, T, R) of the TSQR tree combine."""
    route = _route("stacked_qr", R_top, R_bot)
    if route == _R_ORACLE:
        return ref.stacked_qr(R_top, R_bot)
    if route == _R_XLA:
        u = _unroll("stacked_qr", (R_top.shape[0],), R_top.dtype, route,
                    unroll)
        return _stacked.stacked_qr_xla(R_top, R_bot, unroll=u)
    b = R_top.shape[0]
    lane = _lane_pad("stacked_qr", (b,), R_top.dtype, route, lane_pad)
    b_pad = backend.pad_to(b, lane)
    interp = route == _R_INTERPRET
    if b_pad == b:
        return _stacked.stacked_qr(R_top, R_bot, interpret=interp)
    pad = ((0, b_pad - b), (0, b_pad - b))
    Y2, T, R = _stacked.stacked_qr(
        jnp.pad(R_top, pad), jnp.pad(R_bot, pad), interpret=interp
    )
    return Y2[:b, :b], T[:b, :b], R[:b, :b]


def wy_apply(Y: jax.Array, T: jax.Array, C: jax.Array,
             block_n: Optional[int] = None) -> jax.Array:
    """Fused Q^T C. The trailing dim of C is tiled/padded by the kernel."""
    route = _route("wy_apply", Y, T, C)
    if route == _R_ORACLE:
        return ref.wy_apply(Y, T, C)
    if route == _R_XLA:
        return _wy.wy_apply_xla(Y, T, C)
    m, b = Y.shape
    n = C.shape[1]
    bn = _block_n("wy_apply", (m, b, n), C.dtype, route, block_n,
                  DEFAULT_WY_BLOCK_N)
    sub = backend.sublane(Y.dtype)
    b_pad = backend.pad_to(b, backend.LANE)
    m_pad = backend.pad_to(m, sub)
    interp = route == _R_INTERPRET
    if (m_pad, b_pad) == (m, b):
        return _wy.wy_apply(Y, T, C, block_n=bn, interpret=interp)
    Y_p = jnp.pad(Y, ((0, m_pad - m), (0, b_pad - b)))
    T_p = jnp.pad(T, ((0, b_pad - b), (0, b_pad - b)))
    C_p = jnp.pad(C, ((0, m_pad - m), (0, 0)))
    out = _wy.wy_apply(Y_p, T_p, C_p, block_n=bn, interpret=interp)
    return out[:m]


def stacked_apply(Y2, T, C_top, C_bot, block_n: Optional[int] = None):
    """Fused trailing combine; returns (Ct_hat, Cb_hat, W)."""
    route = _route("stacked_apply", Y2, T, C_top, C_bot)
    if route == _R_ORACLE:
        return ref.stacked_apply(Y2, T, C_top, C_bot)
    if route == _R_XLA:
        return _stacked.stacked_apply_xla(Y2, T, C_top, C_bot)
    b = Y2.shape[0]
    n = C_top.shape[1]
    bn = _block_n("stacked_apply", (b, n), C_top.dtype, route, block_n,
                  DEFAULT_STACKED_BLOCK_N)
    b_pad = backend.pad_to(b, backend.LANE)
    interp = route == _R_INTERPRET
    if b_pad == b:
        return _stacked.stacked_apply(
            Y2, T, C_top, C_bot, block_n=bn, interpret=interp
        )
    bb = ((0, b_pad - b), (0, b_pad - b))
    rows = ((0, b_pad - b), (0, 0))
    ot, ob, W = _stacked.stacked_apply(
        jnp.pad(Y2, bb), jnp.pad(T, bb),
        jnp.pad(C_top, rows), jnp.pad(C_bot, rows),
        block_n=bn, interpret=interp,
    )
    return ot[:b], ob[:b], W[:b]


def panel_qr_apply(W: jax.Array, row_start=0, b: Optional[int] = None):
    """Fused leaf step: panel QR of ``W[:, :b]`` + WY-apply of the whole
    window + C' row extraction, one launch. Returns (Y, T, R, C, C_prime).

    Governed by the ``fused_sweep`` policy slot; the oracle route composes
    the unfused oracles.
    """
    from repro.kernels import fused_sweep as _fused

    if b is None:
        b = W.shape[1]
    route = _route("fused_sweep", W)
    if route == _R_ORACLE:
        return _fused.panel_qr_apply_ref(W, row_start, b)
    rs = jnp.asarray(row_start, jnp.int32)
    if route == _R_XLA:
        return _fused.panel_qr_apply_xla(W, rs, b)
    return _fused.panel_qr_apply(W, rs, b, interpret=route == _R_INTERPRET)
