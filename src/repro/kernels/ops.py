"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel bodies run as traced Python over VMEM-shaped blocks, which is how
they are validated against ``ref.py``. On TPU set ``interpret=False`` (the
default flips automatically based on the backend).

``use_kernels(False)`` (or the REPRO_NO_KERNELS env var) routes every call to
the pure-jnp oracle instead — the escape hatch the rest of the framework uses
for shapes outside the kernels' alignment contract.
"""
from __future__ import annotations

import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import panel_qr as _panel
from repro.kernels import stacked_qr as _stacked
from repro.kernels import wy_apply as _wy

_USE_KERNELS = os.environ.get("REPRO_NO_KERNELS", "0") != "1"


def use_kernels(flag: bool) -> None:
    global _USE_KERNELS
    _USE_KERNELS = flag


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def panel_qr(A: jax.Array, row_start=0) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(Y, T, R) of the masked Householder panel QR of A (m, b)."""
    if not _USE_KERNELS:
        return ref.panel_qr(A, row_start)
    return _panel.panel_qr(A, jnp.asarray(row_start, jnp.int32), interpret=_interpret())


def stacked_qr(R_top: jax.Array, R_bot: jax.Array):
    """(Y2, T, R) of the TSQR tree combine."""
    if not _USE_KERNELS:
        return ref.stacked_qr(R_top, R_bot)
    return _stacked.stacked_qr(R_top, R_bot, interpret=_interpret())


def wy_apply(Y: jax.Array, T: jax.Array, C: jax.Array, block_n: int = 256) -> jax.Array:
    """Fused Q^T C."""
    if not _USE_KERNELS:
        return ref.wy_apply(Y, T, C)
    return _wy.wy_apply(Y, T, C, block_n=block_n, interpret=_interpret())


def stacked_apply(Y2, T, C_top, C_bot, block_n: int = 512):
    """Fused trailing combine; returns (Ct_hat, Cb_hat, W)."""
    if not _USE_KERNELS:
        return ref.stacked_apply(Y2, T, C_top, C_bot)
    return _stacked.stacked_apply(Y2, T, C_top, C_bot, block_n=block_n, interpret=_interpret())
