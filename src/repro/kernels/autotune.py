"""Wall-clock block-shape autotuner for the kernel fast path.

CAQR's payoff is notoriously shape-sensitive (Demmel et al. 2008 tune panel
and block sizes per machine); this module does the equivalent for the Pallas
kernels: for each **cell** — an (op, geometry, dtype, engine) tuple — it
times every candidate block shape (median of ``reps`` wall-clock runs,
compile excluded) and records the winner.

Tunables per op (variant-dependent — see ``candidates``):
  * ``panel_qr`` / ``stacked_qr``: pallas/interpret variants tune
    ``lane_pad`` — the lane multiple the ops wrapper pads panel widths to
    (Mosaic is pinned to the full 128-lane VREG width; the interpreter,
    where padding is pure overhead, may prefer less). The ``xla`` engine
    has no padding contract; its knob is ``unroll``, the column-loop unroll
    factor (loop overhead dominates these small-body loops on CPU).
  * ``wy_apply`` / ``stacked_apply``: ``block_n`` — the trailing-dim column
    tile per grid program (pallas/interpret only; the xla engine is
    untiled).

Consultation: ``ops.py`` calls ``lookup(op, geometry, dtype, variant)`` on
every dispatch (cheap dict probe) and falls back to the static defaults when
the cell was never tuned. Tuning is explicit (``tune`` / ``tune_all`` — run
from ``tools/kernel_smoke.py`` or a user script), never implicit at call
time: a jitted sweep must not suddenly block on a timing loop.

Persistence: ``save``/``load`` round-trip the winners through a JSON cache::

    {"version": 1,
     "cells": {"<backend_fingerprint>": {
         "wy_apply|256x64x512|float32|interpret": {
             "params": {"block_n": 128}, "us": 812.4},
         ...}}}

keyed by ``backend.backend_fingerprint()`` (backend + device kind + jax
version). A cache file from another machine or after an upgrade is *valid
but inert*: foreign fingerprints are preserved on save and ignored on load,
which is the whole invalidation story — no staleness heuristics.
``REPRO_AUTOTUNE_CACHE=<path>`` names a cache to auto-load on first lookup.
"""
from __future__ import annotations

import json
import os
import statistics
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels import backend

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"

# In-memory winners for THIS process's fingerprint: cell key -> record
# {"params": {...}, "us": float}.
_CELLS: Dict[str, Dict] = {}
# Cells of other fingerprints, carried through load->save round-trips.
_FOREIGN: Dict[str, Dict[str, Dict]] = {}
_ENV_LOADED = False


def cell_key(op: str, geometry: Sequence[int], dtype, variant: str) -> str:
    """``op|geom|dtype|variant``; variant is the execution flavor the timing
    is valid for (``pallas``/``xla`` engine or ``interpret``)."""
    geom = "x".join(str(int(g)) for g in geometry)
    import jax.numpy as jnp

    return f"{op}|{geom}|{jnp.dtype(dtype).name}|{variant}"


def current_variant(op: str) -> str:
    """The flavor ``op`` would execute right now under the active policy."""
    mode = backend.kernel_mode(op)
    if mode == backend.MODE_COMPILED:
        return backend.compiled_engine(op)
    return mode  # interpret / oracle


def _ensure_env_loaded() -> None:
    global _ENV_LOADED
    if _ENV_LOADED:
        return
    _ENV_LOADED = True
    path = os.environ.get(CACHE_ENV, "").strip()
    if path and os.path.exists(path):
        load(path)


def lookup(op: str, geometry: Sequence[int], dtype, variant: Optional[str] = None
           ) -> Dict[str, int]:
    """Tuned params for the cell, or ``{}`` (use static defaults)."""
    _ensure_env_loaded()
    if not _CELLS:
        return {}
    if variant is None:
        variant = current_variant(op)
    rec = _CELLS.get(cell_key(op, geometry, dtype, variant))
    return dict(rec["params"]) if rec else {}


def clear() -> None:
    """Drop all in-memory winners (tests)."""
    global _ENV_LOADED
    _CELLS.clear()
    _FOREIGN.clear()
    _ENV_LOADED = True  # a cleared tuner stays cleared; load() re-fills


def candidates(op: str, variant: str) -> List[Dict[str, int]]:
    """The block-shape search space for one (op, variant)."""
    if op in ("panel_qr", "stacked_qr"):
        if variant == backend.ENGINE_XLA:
            # no padding contract; the knob is the column-loop unroll
            return [{"unroll": u} for u in (1, 2, 4)]
        if variant == backend.ENGINE_PALLAS:
            pads = (backend.LANE,)  # Mosaic wants full VREG lanes
        else:
            pads = (backend.SUBLANE, 32, backend.LANE)
        return [{"lane_pad": p} for p in pads]
    if op in ("wy_apply", "stacked_apply"):
        if variant == backend.ENGINE_XLA:
            return [{}]  # untiled: column tiling is a pallas-grid concept
        return [{"block_n": n} for n in (64, 128, 256, 512)]
    return [{}]  # fused_sweep: no tunables yet (whole window resident)


def _median_us(fn, reps: int) -> float:
    fn()  # compile + warm
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        samples.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(samples)


def _runner(op: str, geometry: Sequence[int], dtype, params: Dict[str, int]):
    """Build a nullary timed callable for one candidate: the real ops-layer
    dispatch with the candidate's block shape forced."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.RandomState(0)

    def arr(*shape):
        return jnp.asarray(rng.standard_normal(shape), dtype)

    if op == "panel_qr":
        m, b = geometry
        A = arr(m, b)
        return lambda: jax.block_until_ready(
            ops.panel_qr(A, 0, lane_pad=params.get("lane_pad"),
                         unroll=params.get("unroll")))
    if op == "stacked_qr":
        (b,) = geometry
        R1 = jnp.triu(arr(b, b))
        R2 = jnp.triu(arr(b, b))
        return lambda: jax.block_until_ready(
            ops.stacked_qr(R1, R2, lane_pad=params.get("lane_pad"),
                           unroll=params.get("unroll")))
    if op == "wy_apply":
        m, b, n = geometry
        Y, T, C = arr(m, b), jnp.triu(arr(b, b)), arr(m, n)
        return lambda: jax.block_until_ready(
            ops.wy_apply(Y, T, C, block_n=params.get("block_n")))
    if op == "stacked_apply":
        b, n = geometry
        Y2, T = jnp.triu(arr(b, b)), jnp.triu(arr(b, b))
        Ct, Cb = arr(b, n), arr(b, n)
        return lambda: jax.block_until_ready(
            ops.stacked_apply(Y2, T, Ct, Cb, block_n=params.get("block_n")))
    raise ValueError(f"no tuning runner for op {op!r}")


def tune(op: str, geometry: Sequence[int], dtype=None, reps: int = 5,
         variant: Optional[str] = None) -> Optional[Dict]:
    """Time every candidate for one cell and record the winner in memory.

    Returns the winning record ``{"params", "us"}``, or ``None`` when the
    active policy routes ``op`` to the oracle (nothing to tune)."""
    import jax.numpy as jnp

    if dtype is None:
        dtype = jnp.float32
    if variant is None:
        variant = current_variant(op)
    if variant == backend.MODE_ORACLE:
        return None
    best: Optional[Tuple[float, Dict[str, int]]] = None
    for params in candidates(op, variant):
        us = _median_us(_runner(op, geometry, dtype, params), reps)
        if best is None or us < best[0]:
            best = (us, params)
    record = {"params": best[1], "us": round(best[0], 2)}
    _ensure_env_loaded()
    _CELLS[cell_key(op, geometry, dtype, variant)] = record
    return record


# Representative cells: the bench geometry plus the small combine shapes the
# sweep actually issues.
DEFAULT_CELLS = (
    ("panel_qr", (256, 64)),
    ("stacked_qr", (64,)),
    ("wy_apply", (256, 64, 512)),
    ("stacked_apply", (64, 512)),
)


def tune_all(cells=DEFAULT_CELLS, dtype=None, reps: int = 5) -> Dict[str, Dict]:
    """Tune a set of cells; returns {cell_key: winner record}."""
    import jax.numpy as jnp

    if dtype is None:
        dtype = jnp.float32
    out = {}
    for op, geometry in cells:
        rec = tune(op, geometry, dtype=dtype, reps=reps)
        if rec is not None:
            out[cell_key(op, geometry, dtype, current_variant(op))] = rec
    return out


def _default_path() -> str:
    return os.environ.get(CACHE_ENV, "").strip() or os.path.join(
        os.path.expanduser("~"), ".cache", "repro_autotune.json")


def save(path: Optional[str] = None) -> str:
    """Persist all known winners (ours + foreign fingerprints) to JSON."""
    path = path or _default_path()
    cells = dict(_FOREIGN)
    if _CELLS:
        cells[backend.backend_fingerprint()] = _CELLS
    payload = {"version": 1, "cells": cells}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def load(path: Optional[str] = None) -> int:
    """Load a cache file; adopt only cells matching this process's backend
    fingerprint (foreign cells are kept for round-tripping, not consulted).
    Returns the number of cells adopted."""
    global _ENV_LOADED
    path = path or _default_path()
    with open(path) as f:
        payload = json.load(f)
    assert payload.get("version") == 1, payload.get("version")
    _ENV_LOADED = True
    fp = backend.backend_fingerprint()
    adopted = 0
    for fingerprint, cells in payload.get("cells", {}).items():
        if fingerprint == fp:
            _CELLS.update(cells)
            adopted += len(cells)
        else:
            _FOREIGN.setdefault(fingerprint, {}).update(cells)
    return adopted
