"""Backend policy for the Pallas kernels — the single source of truth.

Two independent decisions live here:

* ``interpret_default()`` — HOW a kernel runs when it runs: compiled Mosaic
  on TPU, ``interpret=True`` (traced-Python-over-VMEM-blocks) everywhere
  else. Kernel modules take ``interpret=None`` and resolve it here; nothing
  hardcodes ``interpret=True`` anymore.

* ``dispatch_enabled()`` — WHETHER the core hot path (``repro.core``) routes
  its panel/combine/apply operations through the kernels at all. Default:
  only on TPU, where the fused kernels beat XLA's op-by-op lowering. On CPU
  the interpret-mode kernels are a validation vehicle, not a fast path, so
  core stays on the pure-jnp implementations unless forced.

Overrides, strongest first:
  1. ``use_kernels(True/False)`` — programmatic (tests, benchmarks);
     ``use_kernels(None)`` restores the automatic policy.
  2. ``REPRO_NO_KERNELS=1``    — kill switch, wins over the backend default.
  3. ``REPRO_FORCE_KERNELS=1`` — force the core dispatch on (parity tests
     exercise the padded kernel path on CPU this way).

Note the decisions are read at *trace* time: flipping a flag does not
invalidate already-jitted callers. Tests flip flags before building jits.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

_OVERRIDE: Optional[bool] = None


def interpret_default() -> bool:
    """True everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve a kernel's ``interpret=None`` default against the backend."""
    return interpret_default() if interpret is None else interpret


def use_kernels(flag: Optional[bool]) -> None:
    """Force the core->kernel dispatch on/off; None = automatic policy."""
    global _OVERRIDE
    _OVERRIDE = flag


def dispatch_enabled() -> bool:
    """Should repro.core route through the Pallas kernels right now?"""
    if _OVERRIDE is not None:
        return _OVERRIDE
    if os.environ.get("REPRO_NO_KERNELS", "0") == "1":
        return False
    if os.environ.get("REPRO_FORCE_KERNELS", "0") == "1":
        return True
    return jax.default_backend() == "tpu"


def ops_kernels_enabled() -> bool:
    """Should ops.* run its Pallas kernel (vs. the jnp oracle)?

    Unlike the core dispatch, ops defaults to the kernel on every backend —
    interpret mode on CPU is how the kernels are validated. Shares the
    ``use_kernels`` override and the env kill switch with the core dispatch
    so the two layers can never disagree (both read at call/trace time).
    """
    if _OVERRIDE is not None:
        return _OVERRIDE
    return os.environ.get("REPRO_NO_KERNELS", "0") != "1"


# Alignment contract (f32 VREG/MXU tiling): panel rows in sublane multiples,
# panel widths in lane multiples. ``ops`` pads up to the contract and slices
# back, so callers never see it — but aligned shapes skip the copies.
SUBLANE = 8
LANE = 128


def pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult
