"""Backend policy for the Pallas kernels — the single source of truth.

Three independent decisions live here:

* ``kernel_mode(op)`` — HOW an op in ``repro.kernels.ops`` executes. A
  capability-probed three-way policy per op::

      compiled   the fast path. Engine ``pallas`` (a native, non-interpret
                 ``pallas_call``) on any backend that lowers it — probed
                 once per process per op by AOT-compiling a tiny instance —
                 with automatic fallback to engine ``xla`` (the same tile
                 program executed as plain compiled XLA, no interpreter
                 machinery) where lowering fails.
      interpret  the Pallas interpreter (traced-Python-over-VMEM-blocks).
                 Slow; the validation vehicle for the kernel programs and
                 the bit-compatibility gates. Never chosen automatically —
                 request it explicitly (tests, parity matrices).
      oracle     the pure-jnp reference in ``repro.kernels.ref``.

* ``compiled_engine(op)`` — which compiled engine ``compiled`` resolves to:
  ``pallas`` iff the per-op probe succeeded on this backend, else ``xla``.

* ``dispatch_enabled()`` — WHETHER the core hot path (``repro.core``) routes
  its panel/combine/apply operations through ``ops`` at all. Default: only
  on TPU, where the fused kernels beat XLA's op-by-op lowering. The ops
  layer itself runs its best compiled engine on every backend.

Overrides, strongest first:
  1. ``use_kernels(True/False)`` — programmatic (tests, benchmarks);
     ``use_kernels(None)`` restores the automatic policy. True forces the
     core dispatch on AND pins ops to its best kernel mode; False pins
     everything to the oracle.
  2. ``force_mode(mode, op=None)`` — programmatic per-op (or global) mode
     pin; ``force_mode(None)`` clears.
  3. ``REPRO_NO_KERNELS=1``    — kill switch, wins over the backend default.
  4. ``REPRO_KERNEL_MODE=compiled|interpret|oracle|auto`` — global mode, and
     ``REPRO_KERNEL_MODE_<OP>`` (e.g. ``REPRO_KERNEL_MODE_WY_APPLY``) per op.
  5. ``REPRO_FORCE_KERNELS=1`` — force the core dispatch on (parity tests
     exercise the padded kernel path on CPU this way).

Note the decisions are read at *trace* time: flipping a flag does not
invalidate already-jitted callers. Tests flip flags before building jits.

The autotune cache (``repro.kernels.autotune``) is keyed by
``backend_fingerprint()`` so tuned block shapes never leak across machines
or backend/jax upgrades.
"""
from __future__ import annotations

import os
import warnings
from typing import Dict, Optional

import jax

_OVERRIDE: Optional[bool] = None

# -- kernel modes ------------------------------------------------------------

MODE_COMPILED = "compiled"
MODE_INTERPRET = "interpret"
MODE_ORACLE = "oracle"
MODE_AUTO = "auto"
KERNEL_MODES = (MODE_COMPILED, MODE_INTERPRET, MODE_ORACLE)

ENGINE_PALLAS = "pallas"
ENGINE_XLA = "xla"

# Every op the ops layer dispatches (fused_sweep is the multi-point
# megakernel in repro.kernels.fused_sweep).
OPS = ("panel_qr", "stacked_qr", "wy_apply", "stacked_apply", "fused_sweep")

_MODE_OVERRIDE: Dict[str, str] = {}  # op (or "*") -> mode


def use_kernels(flag: Optional[bool]) -> None:
    """Force the core->kernel dispatch on/off; None = automatic policy."""
    global _OVERRIDE
    _OVERRIDE = flag


def force_mode(mode: Optional[str], op: Optional[str] = None) -> None:
    """Pin ``kernel_mode`` for one op (or all ops when ``op is None``).
    ``force_mode(None)`` / ``force_mode(None, op)`` clears the pin(s)."""
    key = "*" if op is None else op
    if mode is None:
        if op is None:
            _MODE_OVERRIDE.clear()
        else:
            _MODE_OVERRIDE.pop(key, None)
        return
    assert mode in KERNEL_MODES + (MODE_AUTO,), mode
    _MODE_OVERRIDE[key] = mode


def _env_mode(op: str) -> Optional[str]:
    for key in (f"REPRO_KERNEL_MODE_{op.upper()}", "REPRO_KERNEL_MODE"):
        val = os.environ.get(key, "").strip().lower()
        if val:
            if val not in KERNEL_MODES + (MODE_AUTO,):
                warnings.warn(f"{key}={val!r} is not one of "
                              f"{KERNEL_MODES + (MODE_AUTO,)}; ignoring")
                return None
            return val
    return None


def kernel_mode(op: str) -> str:
    """Resolve the execution mode for ``op``: compiled | interpret | oracle.

    Read at trace time by ``repro.kernels.ops``. ``auto`` (the default)
    resolves to ``compiled`` — the engine probe decides pallas vs xla.
    """
    assert op in OPS, op
    if _OVERRIDE is False:
        return MODE_ORACLE
    mode = _MODE_OVERRIDE.get(op, _MODE_OVERRIDE.get("*"))
    if _OVERRIDE is True and mode is None:
        return MODE_COMPILED
    if os.environ.get("REPRO_NO_KERNELS", "0") == "1" and mode is None:
        return MODE_ORACLE
    if mode is None:
        mode = _env_mode(op) or MODE_AUTO
    if mode == MODE_AUTO:
        return MODE_COMPILED
    return mode


# -- compiled-capability probe (once per process per op) ---------------------

_PROBE_CACHE: Dict[str, bool] = {}
_PROBE_ERRORS: Dict[str, str] = {}


def _probe_compiled(op: str) -> bool:
    """AOT-lower + compile a tiny aligned instance of ``op``'s Pallas kernel
    with ``interpret=False`` on the default backend. No execution — safe to
    call from inside an active trace (it opens its own)."""
    import jax.numpy as jnp

    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    try:
        if op == "panel_qr":
            from repro.kernels import panel_qr as m
            fn = lambda a, rs: m.panel_qr(a, rs, interpret=False)
            args = (s((136, 128), f32), s((), jnp.int32))
        elif op == "stacked_qr":
            from repro.kernels import stacked_qr as m
            fn = lambda a, b_: m.stacked_qr(a, b_, interpret=False)
            args = (s((128, 128), f32), s((128, 128), f32))
        elif op == "wy_apply":
            from repro.kernels import wy_apply as m
            fn = lambda y, t, c: m.wy_apply(y, t, c, block_n=128,
                                            interpret=False)
            args = (s((128, 128), f32), s((128, 128), f32), s((128, 128), f32))
        elif op == "stacked_apply":
            from repro.kernels import stacked_qr as m
            fn = lambda y2, t, ct, cb: m.stacked_apply(
                y2, t, ct, cb, block_n=128, interpret=False)
            args = (s((128, 128), f32),) * 4
        elif op == "fused_sweep":
            from repro.kernels import fused_sweep as m
            fn = lambda w: m.panel_qr_apply(w, 0, 8, interpret=False)
            args = (s((16, 16), f32),)
        else:  # pragma: no cover - OPS is closed
            return False
        jax.jit(fn).lower(*args).compile()
        return True
    except Exception as e:  # noqa: BLE001 - any lowering failure => no pallas
        _PROBE_ERRORS[op] = f"{type(e).__name__}: {e}"
        return False


def compiled_supported(op: str) -> bool:
    """Does this backend lower ``op``'s Pallas kernel natively? Probed once
    per process; ``probe_report()`` has the failure reasons."""
    if op not in _PROBE_CACHE:
        _PROBE_CACHE[op] = _probe_compiled(op)
    return _PROBE_CACHE[op]


def compiled_engine(op: str) -> str:
    """Which engine ``compiled`` mode runs for ``op``: ``pallas`` iff the
    probe passed, else ``xla`` (the tile program as plain compiled XLA)."""
    return ENGINE_PALLAS if compiled_supported(op) else ENGINE_XLA


def probe_report() -> Dict[str, Dict[str, str]]:
    """Probe every op; return {op: {supported, engine, error?}} — the
    compiled-kernel smoke tier (``tools/kernel_smoke.py``) prints this."""
    report = {}
    for op in OPS:
        ok = compiled_supported(op)
        entry = {"supported": ok, "engine": compiled_engine(op)}
        if not ok and op in _PROBE_ERRORS:
            entry["error"] = _PROBE_ERRORS[op]
        report[op] = entry
    return report


def reset_probe_cache() -> None:
    """Drop probe results (tests only — e.g. after monkeypatching)."""
    _PROBE_CACHE.clear()
    _PROBE_ERRORS.clear()


def backend_fingerprint() -> str:
    """Stable identity of (backend, device kind, jax version) — the autotune
    cache key, so tuned shapes never leak across machines or upgrades."""
    try:
        kind = jax.devices()[0].device_kind
    except Exception:  # noqa: BLE001 - no devices (docs builds)
        kind = "unknown"
    return f"{jax.default_backend()}:{kind}:jax-{jax.__version__}"


# -- legacy interpret seam (kept: kernel modules resolve interpret=None) -----


def interpret_default() -> bool:
    """True everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve a kernel's ``interpret=None`` default against the backend."""
    return interpret_default() if interpret is None else interpret


# -- core dispatch (whether repro.core routes through ops at all) ------------


def dispatch_enabled() -> bool:
    """Should repro.core route through the Pallas kernels right now?"""
    if _OVERRIDE is not None:
        return _OVERRIDE
    if os.environ.get("REPRO_NO_KERNELS", "0") == "1":
        return False
    if os.environ.get("REPRO_FORCE_KERNELS", "0") == "1":
        return True
    return jax.default_backend() == "tpu"


def ops_kernels_enabled() -> bool:
    """Should ops.* run a kernel engine (vs. the jnp oracle)?

    Compatibility shim over the per-op policy: True iff no op is pinned to
    the oracle globally. Shares the ``use_kernels`` override and the env
    kill switch with the core dispatch so the two layers can never disagree
    (both read at call/trace time).
    """
    return kernel_mode("panel_qr") != MODE_ORACLE


# Alignment contract (VREG/MXU tiling): panel rows in sublane multiples,
# panel widths in lane multiples. The contract belongs to the *pallas*
# engines (Mosaic tiles / the interpreter's block model); the xla engine
# runs at natural shapes. ``ops`` pads up to the contract and slices back,
# so callers never see it — but aligned shapes skip the copies. Sublane is
# dtype-dependent: (8, 128) packs f32, (16, 128) bf16.
SUBLANE = 8
LANE = 128


def sublane(dtype) -> int:
    """Second-to-last-dim tile multiple for ``dtype`` (f32: 8, bf16: 16)."""
    import jax.numpy as jnp

    return 16 if dtype == jnp.bfloat16 else SUBLANE


def pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult
