"""Pallas TPU kernel: Householder panel QR with compact-WY output.

The CAQR leaf hot-spot (LAPACK ``geqrt`` equivalent): factorize an (m, b)
panel tile entirely in VMEM, producing Y (unit-lower-trapezoidal Householder
vectors), T (upper triangular) and R.

TPU adaptation notes (vs. the CPU/GPU panel kernels the paper's MPI code
would call):
  * the whole tile is VMEM-resident — one HBM read of A, one write of
    (Y, T, R); the column loop does rank-1 updates on VREGs with no HBM
    traffic, which is what makes the panel latency- rather than
    bandwidth-bound on TPU;
  * the masked-pivot formulation (pivot row = row_start + j, rows above
    row_start frozen) avoids all dynamic slicing so every op is a fixed
    (m, b)-shaped vector op — friendly to the (8, 128) VREG lanes;
  * m, b should be multiples of (8, 128) for full lane utilization; the
    wrapper pads when they are not.

Working-set budget: A + Y (m*b each) + T, R (b*b) in f32.
m=2048, b=256 -> 2 * 2 MiB + 0.5 MiB < 16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def unrolled_loop(num_steps: int, body, init, unroll: int = 1):
    """``fori_loop(0, num_steps, body, init)`` with an ``unroll`` factor.

    ``unroll=1`` is the plain fori_loop (the conservative form the pallas
    kernel bodies lower); larger factors replicate the body inside a scan
    step — same operations in the same order, so results are unchanged, but
    the backend's per-iteration loop overhead is amortized. On CPU that
    overhead dominates these small-body column loops, which is what makes
    ``unroll`` the autotune knob for the ``xla`` engine (autotune.py).
    """
    if unroll == 1:
        return jax.lax.fori_loop(0, num_steps, body, init)
    return jax.lax.scan(
        lambda carry, j: (body(j, carry), None),
        init, jnp.arange(num_steps), unroll=unroll,
    )[0]


def panel_qr_math(A: jax.Array, row_start: jax.Array, *, num_cols: int,
                  unroll: int = 1):
    """The kernel's tile program on plain arrays: (Y, T, R) of the masked
    panel QR. Shared verbatim by the pallas kernel body and the ``xla``
    compiled engine (``panel_qr_xla``) so the two execute the same
    floating-point program (``unroll`` only changes loop scheduling, not
    the operation sequence)."""
    m, b = A.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, 1), 0)[:, 0]
    dtype = A.dtype

    def col_step(j, carry):
        A_, Y_, taus_ = carry
        pivot = row_start + j
        mask = rows >= pivot
        x = jnp.where(mask, A_[:, j], 0.0)
        x0 = x[pivot]
        sigma = jnp.sum(x * x) - x0 * x0
        norm_x = jnp.sqrt(x0 * x0 + sigma)
        sign = jnp.where(x0 >= 0, 1.0, -1.0).astype(dtype)
        beta = -sign * norm_x
        degenerate = norm_x <= jnp.asarray(1e-30, dtype)
        denom = jnp.where(degenerate, 1.0, x0 - beta)
        v = jnp.where(mask, x / denom, 0.0)
        v = v.at[pivot].set(1.0)
        tau = jnp.where(degenerate, 0.0, (beta - x0) / beta).astype(dtype)
        w = v @ A_  # (b,) — one MXU/VPU pass over the tile
        A_ = A_ - tau * v[:, None] * w[None, :]
        Y_ = Y_.at[:, j].set(v)
        taus_ = taus_.at[j].set(tau)
        return A_, Y_, taus_

    A_out, Y, taus = unrolled_loop(
        num_cols, col_step, (A, A * 0.0, A[0] * 0.0), unroll
    )

    # T forward recurrence over the Gram matrix (all VMEM-resident).
    G = Y.T @ Y
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, 1), 0)[:, 0]

    def t_step(j, T):
        g = jnp.where(cols < j, G[:, j], 0.0)
        col = -taus[j] * (T @ g)
        col = jnp.where(cols < j, col, 0.0)
        col = col.at[j].set(taus[j])
        return T.at[:, j].set(col)

    T = unrolled_loop(num_cols, t_step, G * 0.0, unroll)

    # R = rows [row_start, row_start + b) of the transformed tile.
    R_rows = jax.lax.dynamic_slice(A_out, (row_start, 0), (b, b))
    tri = cols[:, None] <= cols[None, :]
    return Y, T, jnp.where(tri, R_rows, 0.0)


def _panel_qr_kernel(rs_ref, a_ref, y_ref, t_ref, r_ref, *, num_cols: int):
    Y, T, R = panel_qr_math(a_ref[...], rs_ref[0], num_cols=num_cols)
    y_ref[...] = Y
    t_ref[...] = T
    r_ref[...] = R


@functools.partial(jax.jit, static_argnames=("unroll",))
def panel_qr_xla(A: jax.Array, row_start: jax.Array, *, unroll: int = 2):
    """The ``xla`` compiled engine: the tile program as plain compiled XLA —
    the fast path on backends whose Pallas can't lower natively (probed in
    ``backend``). No alignment contract: runs at natural shapes. ``unroll``
    is the engine's autotune knob (column-loop unroll factor)."""
    rs = jnp.asarray(row_start, jnp.int32)
    return panel_qr_math(A, rs, num_cols=A.shape[1], unroll=unroll)


@functools.partial(jax.jit, static_argnames=("interpret",))
def panel_qr(A: jax.Array, row_start: jax.Array, *, interpret: bool | None = None):
    """Pallas panel QR. Returns (Y, T, R) like ``ref.panel_qr``.

    A: (m, b) f32, m % 8 == 0 and b % 128 == 0 for full TPU tiling (the
    kernel itself is shape-generic; alignment is a performance contract —
    ``ops.panel_qr`` pads up to it).
    row_start: scalar int32 — rows above it are frozen (CAQR sweep).
    interpret: None resolves via ``backend.interpret_default()``.
    """
    from repro.kernels import backend
    interpret = backend.resolve_interpret(interpret)
    m, b = A.shape
    rs = jnp.asarray(row_start, jnp.int32).reshape((1,))
    kernel = functools.partial(_panel_qr_kernel, num_cols=b)
    grid_spec = pl.GridSpec(
        grid=(),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((m, b), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((m, b), lambda: (0, 0)),
            pl.BlockSpec((b, b), lambda: (0, 0)),
            pl.BlockSpec((b, b), lambda: (0, 0)),
        ],
    )
    Y, T, R = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m, b), A.dtype),
            jax.ShapeDtypeStruct((b, b), A.dtype),
            jax.ShapeDtypeStruct((b, b), A.dtype),
        ],
        interpret=interpret,
    )(rs, A)
    return Y, T, R
