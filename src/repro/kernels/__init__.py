"""Pallas TPU kernels for the CAQR compute hot-spots.

panel_qr   - Householder panel factorization (geqrt) in VMEM
stacked_qr - TSQR tree combine (tpqrt) + fused trailing combine
wy_apply   - fused compact-WY application C - Y (T^T (Y^T C))

ops.py exposes jit'd wrappers (interpret=True on CPU); ref.py holds the
pure-jnp oracles every kernel is validated against.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
