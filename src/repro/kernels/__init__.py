"""Pallas kernels for the CAQR compute hot-spots.

panel_qr    - Householder panel factorization (geqrt) in VMEM
stacked_qr  - TSQR tree combine (tpqrt) + fused trailing combine
wy_apply    - fused compact-WY application C - Y (T^T (Y^T C))
fused_sweep - whole-panel sweep megakernel + fused leaf (panel QR + apply)

ops.py is the dispatch seam ``repro.core`` routes through: wrappers that
resolve the per-op execution policy (compiled pallas / compiled xla /
interpret / oracle — backend.py probes what this backend can lower), pad
up to the pallas engines' alignment contract, consult the autotune.py
block-shape cache, and fall back to the pure-jnp oracles in ref.py.
See DESIGN.md §2 and §10.
"""
from repro.kernels import autotune, backend, ops, ref

__all__ = ["autotune", "backend", "ops", "ref"]
