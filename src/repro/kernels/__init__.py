"""Pallas TPU kernels for the CAQR compute hot-spots.

panel_qr   - Householder panel factorization (geqrt) in VMEM
stacked_qr - TSQR tree combine (tpqrt) + fused trailing combine
wy_apply   - fused compact-WY application C - Y (T^T (Y^T C))

ops.py is the dispatch seam ``repro.core`` routes through: jit'd wrappers
that pad up to the kernels' alignment contract and fall back to the
pure-jnp oracles in ref.py. backend.py holds the policy (when core
dispatches here at all; interpret=Mosaic on TPU, interpreter elsewhere).
See DESIGN.md §2.
"""
from repro.kernels import backend, ops, ref

__all__ = ["backend", "ops", "ref"]
