"""Fused multi-point sweep megakernel: a whole panel in one launch.

The orchestrator's unfused execution of panel ``k`` issues ``1 + 2L``
``sweep_step`` dispatches (leaf, L butterfly levels, L trailing levels),
each a handful of XLA ops — O(points * ops) launches per segment. This
module collapses all of panel ``k``'s points into ONE launch.

Why whole-panel and not per-point pairs: trailing level 0 consumes the
**complete** stacked butterfly ladder (``level_Y2`` = all L levels), so no
pairwise (tsqr-l, trailing-l) fusion is possible — the first legal fusion
boundary after the leaf is the end of the panel. The panel-``(k-1)``
deposit stays *outside* the kernel (it belongs to the segment that ends at
``(k, leaf)`` — DESIGN.md §9), so fused boundary states remain exactly the
unfused ones.

Bit-compatibility: the kernel body executes the *same* core entry points
(``householder_qr_masked``, ``ft_tsqr_level``, ``_leaf_apply``,
``trailing_combine_level``) over an embedded ``SimComm`` that the unfused
``sweep_step`` path executes — one floating-point program, two launch
granularities. The Pallas interpreter and the ``xla`` engine both trace
that identical jaxpr, so fused output is bitwise-identical to stepping
(regression-gated in ``tests/test_fused_sweep.py``, the same discipline
that gated windowed-vs-seed in PR 1). The one thing fusion must NOT do is
re-tile the window across grid programs — a column split of the *leaf QR*
would regroup its row reductions. The megakernel therefore runs as a
single program over the resident window (grid ``()``); window VMEM budget
is the caller's responsibility (the live window shrinks as the sweep
advances, so the worst case is panel 0).

Also here: ``panel_qr_apply`` — the per-lane fused leaf (panel QR +
WY-apply over the window + C' extraction in one ``pallas_call``), the
lighter fusion entry exposed through ``core.householder.panel_qr_apply``
for callers that do not run a full sweep (tolerance-gated like the other
kernels, since it uses the kernel tile math rather than the core program).

Routing lives under the ``fused_sweep`` policy slot (see
``backend.kernel_mode``); the Pallas engines embed ``SimComm`` and are
SimComm-only — under ``AxisComm`` (shard_map) the caller uses the direct
math path, which is comm-generic.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.panel_qr import panel_qr_math
from repro.kernels.wy_apply import wy_apply_math

# Kernel-output field order of the fused panel (matches the SweepState
# in-flight fields it refills; ``tops`` is recomputed statically outside
# the kernel — see ``_tops``).
FUSED_FIELDS = (
    "leaf_Y", "leaf_T", "R_leaf", "R_carry",
    "level_Y2", "level_T", "C_local", "C_prime",
    "Ws", "Cs_self", "Cs_buddy",
)


# -- whole-panel megakernel ---------------------------------------------------


def fused_panel_math(comm, window, k: int, *, b: int, m_loc_pad: int,
                     levels: int) -> Dict[str, jax.Array]:
    """Panel ``k``'s full point sequence (leaf + L tsqr + L trailing) as one
    traced program over ``comm`` — literally the ``sweep_step`` bodies
    concatenated, minus the deposit. Comm-generic: the megakernel embeds it
    over ``SimComm``; the shard_map path calls it directly."""
    from repro.core.caqr import panel_geometry
    from repro.core.householder import householder_qr_masked
    from repro.core.trailing import _leaf_apply, trailing_combine_level
    from repro.core.tsqr import DistTSQRFactors, ft_tsqr_level

    col0 = k * b
    t_lane = col0 // m_loc_pad
    _c0, _t, row_start, active = panel_geometry(comm, k, b, m_loc_pad)

    # (k, leaf) — window panel QR, active-masked
    panel = comm.map_local(lambda W: W[:, :b])(window)
    wy = comm.map_local(householder_qr_masked)(panel, row_start)
    leaf_Y = comm.where(active, wy.Y, jnp.zeros_like(wy.Y))
    leaf_T = comm.where(active, wy.T, jnp.zeros_like(wy.T))
    R_leaf = comm.where(active, wy.R, jnp.zeros_like(wy.R))

    # (k, tsqr, 0..L-1) — the butterfly ladder
    carry = R_leaf
    Y2s, Ts = [], []
    for lvl in range(levels):
        carry, Y2, T = ft_tsqr_level(comm, carry, lvl, t_lane, t_lane)
        Y2s.append(Y2)
        Ts.append(T)
    level_Y2 = jnp.stack(Y2s)
    level_T = jnp.stack(Ts)

    # (k, trailing, 0) prologue — leaf-apply the live window
    dist = DistTSQRFactors(leaf_Y, leaf_T, level_Y2, level_T, R_leaf)
    C_local, C_prime = _leaf_apply(comm, dist, window, row_start,
                                   active=active, skip_consumed=True)
    C_prime = comm.where(active, C_prime, jnp.zeros_like(C_prime))

    # (k, trailing, 0..L-1) — the combine tree
    Ws, Cs_self, Cs_buddy, tops = [], [], [], []
    for lvl in range(levels):
        out = trailing_combine_level(
            comm, C_prime, level_Y2[lvl], level_T[lvl], lvl, t_lane, t_lane)
        C_prime = out.C_prime
        Ws.append(out.W)
        Cs_self.append(out.C_self)
        Cs_buddy.append(out.C_buddy)
        tops.append(out.is_top)

    return {
        "leaf_Y": leaf_Y, "leaf_T": leaf_T,
        "R_leaf": R_leaf, "R_carry": carry,
        "level_Y2": level_Y2, "level_T": level_T,
        "C_local": C_local, "C_prime": C_prime,
        "Ws": jnp.stack(Ws), "Cs_self": jnp.stack(Cs_self),
        "Cs_buddy": jnp.stack(Cs_buddy), "tops": tuple(tops),
    }


def _tops(P: int, t_lane: int, levels: int):
    """The per-level ``is_top`` flags, replicated outside the kernel: they
    depend only on static geometry (``is_top = ((idx >> lvl) & 1) ==
    ((t_lane >> lvl) & 1)``), so the megakernel need not emit bools."""
    idx = jnp.arange(P)
    return tuple(
        ((idx >> lvl) & 1) == ((t_lane >> lvl) & 1) for lvl in range(levels)
    )


@functools.partial(jax.jit,
                   static_argnames=("k", "b", "m_loc_pad", "levels",
                                    "interpret"))
def fused_panel_pallas(window: jax.Array, *, k: int, b: int, m_loc_pad: int,
                       levels: int, interpret: Optional[bool] = None
                       ) -> Dict[str, jax.Array]:
    """The megakernel: one ``pallas_call`` over the resident (P, m, w)
    window, SimComm embedded in the kernel body. SimComm-layout only."""
    from repro.core.comm import SimComm

    from repro.kernels import backend

    interpret = backend.resolve_interpret(interpret)
    P, m, w = window.shape
    assert levels >= 1, levels
    L = levels
    dt = window.dtype
    shapes = {
        "leaf_Y": (P, m, b), "leaf_T": (P, b, b),
        "R_leaf": (P, b, b), "R_carry": (P, b, b),
        "level_Y2": (L, P, b, b), "level_T": (L, P, b, b),
        "C_local": (P, m, w), "C_prime": (P, b, w),
        "Ws": (L, P, b, w), "Cs_self": (L, P, b, w), "Cs_buddy": (L, P, b, w),
    }

    def kernel(win_ref, *out_refs):
        res = fused_panel_math(SimComm(P), win_ref[...], k,
                               b=b, m_loc_pad=m_loc_pad, levels=levels)
        for name, ref in zip(FUSED_FIELDS, out_refs):
            ref[...] = res[name]

    outs = pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct(shapes[f], dt) for f in FUSED_FIELDS],
        interpret=interpret,
    )(window)
    result = dict(zip(FUSED_FIELDS, outs))
    result["tops"] = _tops(P, (k * b) // m_loc_pad, levels)
    return result


# -- per-lane fused leaf: panel QR + WY apply + C' extraction -----------------


def panel_qr_apply_math(W: jax.Array, row_start: jax.Array, *, b: int):
    """Tile program: QR the first ``b`` columns, apply Q^T to the whole
    window, extract the C' rows. Returns (Y, T, R, C, C_prime)."""
    Y, T, R = panel_qr_math(W[:, :b], row_start, num_cols=b)
    C = wy_apply_math(Y, T, W)
    Cp = jax.lax.dynamic_slice_in_dim(C, row_start, b, axis=0)
    return Y, T, R, C, Cp


def _panel_qr_apply_kernel(rs_ref, w_ref, y_ref, t_ref, r_ref, c_ref, cp_ref,
                           *, b: int):
    Y, T, R, C, Cp = panel_qr_apply_math(w_ref[...], rs_ref[0], b=b)
    y_ref[...] = Y
    t_ref[...] = T
    r_ref[...] = R
    c_ref[...] = C
    cp_ref[...] = Cp


@functools.partial(jax.jit, static_argnames=("b", "interpret"))
def panel_qr_apply(W: jax.Array, row_start: jax.Array, b: int, *,
                   interpret: Optional[bool] = None):
    """One launch for the sweep's leaf step on one lane. W: (m, w), w >= b.

    interpret: None resolves via ``backend.interpret_default()``.
    """
    from repro.kernels import backend

    interpret = backend.resolve_interpret(interpret)
    m, w = W.shape
    rs = jnp.asarray(row_start, jnp.int32).reshape((1,))
    kernel = functools.partial(_panel_qr_apply_kernel, b=b)
    grid_spec = pl.GridSpec(
        grid=(),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((m, w), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((m, b), lambda: (0, 0)),
            pl.BlockSpec((b, b), lambda: (0, 0)),
            pl.BlockSpec((b, b), lambda: (0, 0)),
            pl.BlockSpec((m, w), lambda: (0, 0)),
            pl.BlockSpec((b, w), lambda: (0, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m, b), W.dtype),
            jax.ShapeDtypeStruct((b, b), W.dtype),
            jax.ShapeDtypeStruct((b, b), W.dtype),
            jax.ShapeDtypeStruct((m, w), W.dtype),
            jax.ShapeDtypeStruct((b, w), W.dtype),
        ],
        interpret=interpret,
    )(rs, W)


@functools.partial(jax.jit, static_argnames=("b",))
def panel_qr_apply_xla(W: jax.Array, row_start: jax.Array, b: int):
    """The ``xla`` compiled engine of the fused leaf (natural shapes)."""
    return panel_qr_apply_math(W, jnp.asarray(row_start, jnp.int32), b=b)


def panel_qr_apply_ref(W: jax.Array, row_start, b: int):
    """Oracle: the unfused composition of the pure core forms."""
    from repro.core import householder as hh

    rs = jnp.asarray(row_start, jnp.int32)
    wy = hh._householder_qr_masked(W[:, :b], rs)
    C = hh._apply_qt(wy.Y, wy.T, W)
    Cp = jax.lax.dynamic_slice_in_dim(C, rs, b, axis=0)
    return wy.Y, wy.T, wy.R, C, Cp
