"""Pure-jnp oracles for every Pallas kernel in this package.

These are thin adapters over ``repro.core.householder`` — the numerics the
whole system is validated against. Kernel tests sweep shapes/dtypes and
``assert_allclose`` kernel output against these.

They bind the ``_``-prefixed *pure* forms, never the public dispatchers:
the dispatchers route back into ``repro.kernels.ops`` when kernels are
enabled, and the oracle must stay kernel-free (it is also ``ops``'s own
fallback path).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import householder as hh

# (rtol, atol) for kernel-vs-oracle comparisons, keyed by dtype. The old
# hardcoded 3e-4 was f32-only: at bf16 (8 mantissa bits, eps ~= 7.8e-3) it
# made parity tests fail spuriously — or, with inputs small enough, pass
# without testing anything. Everything comparing a kernel against these
# oracles must go through ``tolerances``.
_TOLERANCES = {
    "float32": (3e-4, 3e-4),
    "bfloat16": (5e-2, 5e-2),
    "float16": (2e-2, 2e-2),
    "float64": (1e-12, 1e-12),
}


def tolerances(dtype) -> Tuple[float, float]:
    """(rtol, atol) appropriate for comparing kernel output against the
    oracle at ``dtype``. Unknown dtypes get the f32 pair."""
    return _TOLERANCES.get(jnp.dtype(dtype).name, _TOLERANCES["float32"])


def panel_qr(A: jax.Array, row_start) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(Y, T, R) of the masked Householder panel QR."""
    wy = hh._householder_qr_masked(A, jnp.asarray(row_start, jnp.int32))
    return wy.Y, wy.T, wy.R


def stacked_qr(R_top: jax.Array, R_bot: jax.Array):
    """(Y2, T, R) of the TSQR tree combine QR([R_top; R_bot])."""
    sq = hh._stacked_qr(R_top, R_bot)
    return sq.Y2, sq.T, sq.R


def wy_apply(Y: jax.Array, T: jax.Array, C: jax.Array) -> jax.Array:
    """Q^T C = C - Y (T^T (Y^T C))."""
    return hh._apply_qt(Y, T, C)


def stacked_apply(Y2: jax.Array, T: jax.Array, C_top: jax.Array, C_bot: jax.Array):
    """Trailing tree combine: returns (C_top_hat, C_bot_hat, W)."""
    sq = hh.StackedQR(Y2=Y2, T=T, R=T)
    return hh._stacked_apply_qt(sq, C_top, C_bot)
