"""Pallas TPU kernel: structured QR of two stacked upper triangles.

The TSQR tree-combine (LAPACK ``tpqrt`` analogue): QR of [R_top; R_bot] where
both are (b, b) upper triangular. The Householder vectors have the structure
Y = [I; Y2] with Y2 upper triangular, so the kernel emits only (Y2, T, R).

Entirely VMEM-resident (everything is b x b; b <= 256 -> < 1 MiB); the value
of the kernel is latency: the combine sits on the critical path of every
TSQR tree level, so one pallas_call replaces ~6 XLA ops and their HBM
round-trips.

Also provides the fused *trailing combine* kernel (paper Alg. 2 inner body):
    W         = T^T (C_top + Y2^T C_bot)
    C_top_hat = C_top - W
    C_bot_hat = C_bot - Y2 W
tiled over the trailing dimension n.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.panel_qr import unrolled_loop


def stacked_qr_math(R_top: jax.Array, R_bot: jax.Array, *, b: int,
                    unroll: int = 1):
    """The combine's tile program on plain arrays: (Y2, T, R) of
    QR([R_top; R_bot]). Shared by the pallas kernel body and the ``xla``
    compiled engine so both execute the same floating-point program."""
    # Build the 2b x b stack in VMEM; the masked column loop preserves the
    # triangular structure exactly (top block of Y is I, bottom is triu).
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, 1), 0)[:, 0]
    tri = cols[:, None] <= cols[None, :]
    S = jnp.concatenate(
        [jnp.where(tri, R_top, 0.0), jnp.where(tri, R_bot, 0.0)],
        axis=0,
    )
    m = 2 * b
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, 1), 0)[:, 0]
    dtype = S.dtype

    def col_step(j, carry):
        A_, Y_, taus_ = carry
        mask = rows >= j
        x = jnp.where(mask, A_[:, j], 0.0)
        x0 = x[j]
        sigma = jnp.sum(x * x) - x0 * x0
        norm_x = jnp.sqrt(x0 * x0 + sigma)
        sign = jnp.where(x0 >= 0, 1.0, -1.0).astype(dtype)
        beta = -sign * norm_x
        degenerate = norm_x <= jnp.asarray(1e-30, dtype)
        denom = jnp.where(degenerate, 1.0, x0 - beta)
        v = jnp.where(mask, x / denom, 0.0)
        v = v.at[j].set(1.0)
        tau = jnp.where(degenerate, 0.0, (beta - x0) / beta).astype(dtype)
        w = v @ A_
        A_ = A_ - tau * v[:, None] * w[None, :]
        Y_ = Y_.at[:, j].set(v)
        taus_ = taus_.at[j].set(tau)
        return A_, Y_, taus_

    A_out, Y, taus = unrolled_loop(b, col_step, (S, S * 0.0, S[0] * 0.0),
                                   unroll)

    G = Y.T @ Y

    def t_step(j, T):
        g = jnp.where(cols < j, G[:, j], 0.0)
        col = -taus[j] * (T @ g)
        col = jnp.where(cols < j, col, 0.0)
        col = col.at[j].set(taus[j])
        return T.at[:, j].set(col)

    T = unrolled_loop(b, t_step, G * 0.0, unroll)

    return (jnp.where(tri, Y[b:, :], 0.0), T, jnp.where(tri, A_out[:b, :], 0.0))


def _stacked_qr_kernel(rt_ref, rb_ref, y2_ref, t_ref, r_ref, *, b: int):
    Y2, T, R = stacked_qr_math(rt_ref[...], rb_ref[...], b=b)
    y2_ref[...] = Y2
    t_ref[...] = T
    r_ref[...] = R


@functools.partial(jax.jit, static_argnames=("unroll",))
def stacked_qr_xla(R_top: jax.Array, R_bot: jax.Array, *, unroll: int = 2):
    """The ``xla`` compiled engine for the tree combine (natural shapes);
    ``unroll`` is its autotune knob (column-loop unroll factor)."""
    return stacked_qr_math(R_top, R_bot, b=R_top.shape[0], unroll=unroll)


@functools.partial(jax.jit, static_argnames=("interpret",))
def stacked_qr(R_top: jax.Array, R_bot: jax.Array, *, interpret: bool | None = None):
    """(Y2, T, R) of QR([R_top; R_bot]); all (b, b).

    interpret: None resolves via ``backend.interpret_default()``.
    """
    from repro.kernels import backend
    interpret = backend.resolve_interpret(interpret)
    b = R_top.shape[0]
    kernel = functools.partial(_stacked_qr_kernel, b=b)
    spec = pl.BlockSpec((b, b), lambda: (0, 0))
    Y2, T, R = pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((b, b), R_top.dtype)] * 3,
        interpret=interpret,
    )(R_top, R_bot)
    return Y2, T, R


def stacked_apply_math(Y2, T, Ct, Cb):
    """The trailing-combine tile program (f32 accumulation) on plain
    arrays; returns (Ct_hat, Cb_hat, W) in ``Ct.dtype``."""
    inner = Ct + jnp.dot(Y2.T, Cb, preferred_element_type=jnp.float32)
    W = jnp.dot(T.T, inner, preferred_element_type=jnp.float32)
    ot = (Ct - W).astype(Ct.dtype)
    ob = (Cb - jnp.dot(Y2, W, preferred_element_type=jnp.float32)).astype(Ct.dtype)
    return ot, ob, W.astype(Ct.dtype)


def _stacked_apply_kernel(y2_ref, t_ref, ct_ref, cb_ref, ot_ref, ob_ref, w_ref):
    ot, ob, W = stacked_apply_math(y2_ref[...], t_ref[...], ct_ref[...],
                                   cb_ref[...])
    ot_ref[...] = ot.astype(ot_ref.dtype)
    ob_ref[...] = ob.astype(ob_ref.dtype)
    w_ref[...] = W.astype(w_ref.dtype)


@jax.jit
def stacked_apply_xla(Y2, T, C_top, C_bot):
    """The ``xla`` compiled engine for the fused trailing combine. Column
    tiling is dropped: every op here is column-parallel (all reductions run
    over rows), so the untiled call is the same floating-point program."""
    return stacked_apply_math(Y2, T, C_top, C_bot)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def stacked_apply(
    Y2: jax.Array,
    T: jax.Array,
    C_top: jax.Array,
    C_bot: jax.Array,
    *,
    block_n: int = 512,
    interpret: bool | None = None,
):
    """Fused trailing combine (paper Alg. 2 body). Returns (Ct_hat, Cb_hat, W).

    Y2, T: (b, b); C_top, C_bot: (b, n). Tiled over n.
    interpret: None resolves via ``backend.interpret_default()``.
    """
    from repro.kernels import backend
    interpret = backend.resolve_interpret(interpret)
    b, n = C_top.shape
    n_pad = (-n) % block_n
    if n_pad:
        C_top = jnp.pad(C_top, ((0, 0), (0, n_pad)))
        C_bot = jnp.pad(C_bot, ((0, 0), (0, n_pad)))
    n_total = n + n_pad
    grid = (n_total // block_n,)
    bspec = pl.BlockSpec((b, b), lambda j: (0, 0))
    cspec = pl.BlockSpec((b, block_n), lambda j: (0, j))
    ot, ob, W = pl.pallas_call(
        _stacked_apply_kernel,
        grid=grid,
        in_specs=[bspec, bspec, cspec, cspec],
        out_specs=[cspec, cspec, cspec],
        out_shape=[jax.ShapeDtypeStruct((b, n_total), C_top.dtype)] * 3,
        interpret=interpret,
    )(Y2, T, C_top, C_bot)
    if n_pad:
        return ot[:, :n], ob[:, :n], W[:, :n]
    return ot, ob, W
