"""Pallas TPU kernel: fused compact-WY application  C <- C - Y (T^T (Y^T C)).

This is the flop hot-spot of CAQR (the trailing-matrix update applies the
panel's Q^T to every trailing column) and of the CAQR-Muon optimizer. It is
two back-to-back GEMMs plus a rank-b update, fused so the C tile is read from
HBM once and written once.

Tiling: grid over column blocks of C. Per program:
    VMEM in : Y (m, b) [revisited every program — see note], T (b, b),
              C block (m, bn)
    compute : W1 = Y^T C    (b, bn)   MXU
              W  = T^T W1   (b, bn)   MXU
              out = C - Y W (m, bn)   MXU
    VMEM out: out block (m, bn)

Arithmetic intensity per C element: 2*(2b) flops / 8 bytes -> b/2 flops/byte;
for b=128 that is 64 f/B, comfortably compute-bound against TPU v5e's
~240 f/B ridge only for b >= ~480, i.e. the update is *memory*-bound at
b=128 — which is why fusing the three ops (one C pass instead of three)
is the right TPU shape for it.

m, bn should be multiples of (8, 128); b a multiple of 128 for MXU tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def wy_apply_math(Y, T, C):
    """The tile program on plain arrays (f32 accumulation); shared by the
    pallas kernel body and the ``xla`` compiled engine."""
    W1 = jnp.dot(Y.T, C, preferred_element_type=jnp.float32)
    W = jnp.dot(T.T, W1, preferred_element_type=jnp.float32)
    return (C - jnp.dot(Y, W, preferred_element_type=jnp.float32)).astype(C.dtype)


def _wy_apply_kernel(y_ref, t_ref, c_ref, o_ref):
    o_ref[...] = wy_apply_math(y_ref[...], t_ref[...], c_ref[...]).astype(
        o_ref.dtype
    )


@jax.jit
def wy_apply_xla(Y, T, C):
    """The ``xla`` compiled engine: untiled — the column grid only changes
    which columns a program instance touches, never a reduction grouping
    (all dots reduce over rows), so this is the same floating-point
    program as the tiled kernel."""
    return wy_apply_math(Y, T, C)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def wy_apply(
    Y: jax.Array,
    T: jax.Array,
    C: jax.Array,
    *,
    block_n: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused Q^T C. Shapes: Y (m, b), T (b, b), C (m, n); returns (m, n).

    n is padded up to a multiple of ``block_n`` internally.
    interpret: None resolves via ``backend.interpret_default()``.
    """
    from repro.kernels import backend
    interpret = backend.resolve_interpret(interpret)
    m, b = Y.shape
    mC, n = C.shape
    assert mC == m, (m, mC)
    n_pad = (-n) % block_n
    if n_pad:
        C = jnp.pad(C, ((0, 0), (0, n_pad)))
    n_total = n + n_pad
    grid = (n_total // block_n,)
    out = pl.pallas_call(
        _wy_apply_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, b), lambda j: (0, 0)),
            pl.BlockSpec((b, b), lambda j: (0, 0)),
            pl.BlockSpec((m, block_n), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n_total), C.dtype),
        interpret=interpret,
    )(Y, T, C)
    return out[:, :n] if n_pad else out
