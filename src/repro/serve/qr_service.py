"""QR-as-a-service: continuous batching of FT-CAQR *sweeps*.

The serving counterpart of the token engine (``repro.serve.engine``): many
concurrent ragged-shape factorization / least-squares requests multiplex
through ONE resident compiled ``sweep_step`` program. The cadence is the
decode engine's prefill/insert/generate loop transposed onto panel sweeps:

* **buckets** — every request ``(m, n)`` is zero-padded into one of a few
  compiled geometry buckets ``(m_loc, n_bucket)`` via ``block_row_layout``
  + PR 3's ``sweep_geometry``. Zero padding is exact (DESIGN.md §7), so
  the bucket embedding changes no tenant's answer; a handful of buckets
  bounds the number of compiled geometries the way shape buckets bound a
  serving engine's prefill shapes.
* **continuous batching at panel boundaries** — each :meth:`QRService.tick`
  advances every resident request by exactly one panel (one compiled
  segment of ``1 + 2*levels`` sweep points), then does the boundary work:
  detect/heal, retire, admit. New requests join the resident batch only at
  this boundary (the way new prompts join a decode batch between steps);
  finished requests retire their R / lstsq solution *early* — after
  ``ceil(k_req / b)`` panels, not the full bucket sweep — and free the
  slot.
* **one resident program** — all slots of all buckets dispatch through the
  single process-wide ``repro.ft.online.orchestrator.compiled_segment``
  runner; jax's jit cache specializes it per (bucket, cursor) treedef, so
  after one warm sweep per bucket NO new compilation happens under any
  traffic mix (:attr:`QRService.compiled_programs` counts the resident
  specializations; the serve bench asserts it stays flat).
* **mid-batch failures heal online** — a lane death (``kill_lane``) NaN-
  floods that lane's slice of *every* resident tenant's state. Each slot
  carries its own ``NaNSentinelDetector``; the boundary poll discovers the
  death and the same ``recover_lanes`` REBUILD the orchestrator uses heals
  each tenant from its XOR-buddy bundles — no request is dropped, and
  every retired R stays bitwise-identical to a failure-free solo
  ``caqr_factorize`` of the same bucket-padded matrix
  (``tests/test_serve.py``).

Least squares rides the factorization: a request with a right-hand side is
admitted as the augmented matrix ``[A | b]`` (the rhs columns sit beyond
the tenant's ``n_req`` in the bucket, so they are trailing-updated to
``Q^T b`` by the very panels that produce R), and retirement back-solves
``R1 x = (Q^T b)[:k]`` host-side — same semantics as ``caqr_lstsq``
including the wide-problem *basic* solution.

``drain_batched`` is the express static-batch path for offline bulk work:
group the queue by bucket and run each group through
``caqr_factorize_batched`` (one vmapped program per bucket) — identical
results, no mid-flight admission. The serve bench compares both modes.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.caqr import (
    block_row_layout,
    caqr_factorize_batched,
    sweep_geometry,
)
from repro.core.comm import SimComm
from repro.ft.driver import RecoveryEvent, obliterate_state, recover_lanes
from repro.ft.failures import prev_sweep_point
from repro.ft.online.detect import NaNSentinelDetector
from repro.ft.online.orchestrator import compiled_segment
from repro.ft.online.state import (
    SweepState,
    deposit_boundary,
    initial_sweep_state,
    panel_points,
)


@dataclasses.dataclass(frozen=True)
class QRRequest:
    """One tenant's problem: factorize ``A`` (and, with ``rhs``, solve
    min ||Ax - rhs||). Host numpy, any ragged shape that fits a bucket."""

    rid: str
    A: np.ndarray                       # (m, n)
    rhs: Optional[np.ndarray] = None    # (m, nrhs)

    @property
    def shape(self) -> Tuple[int, int]:
        return tuple(self.A.shape)

    @property
    def k(self) -> int:
        return min(self.A.shape)


@dataclasses.dataclass
class QRResult:
    """A retired request: the tenant-shaped R slice (and x for lstsq
    requests), plus the service telemetry the bench aggregates."""

    rid: str
    R: np.ndarray                       # (k_req, n_req)
    x: Optional[np.ndarray]             # (n_req, nrhs) or None
    bucket: Tuple[int, int]
    panels: int
    ticks_resident: int
    latency_s: float                    # submit -> retire (incl. queue wait)
    events: List[RecoveryEvent]         # REBUILDs that hit this tenant


@dataclasses.dataclass
class _Slot:
    req: QRRequest
    bucket: Tuple[int, int]
    state: SweepState
    detector: NaNSentinelDetector
    panels_needed: int
    panels_done: int = 0
    admitted_tick: int = 0
    events: List[RecoveryEvent] = dataclasses.field(default_factory=list)


class QRService:
    """Multi-tenant continuous-batching front end over the online sweep.

    Parameters
    ----------
    comm:
        ``SimComm(P)`` — the service drives jitted host segments, which
        (like the orchestrator's) require the SimComm layout.
    panel_width:
        b. One value service-wide: the segment size ``1 + 2*levels``
        depends only on P, so every bucket shares the one resident runner.
    buckets:
        The compiled geometry menu, ``(m_loc, n)`` pairs (per-lane rows,
        working columns incl. any rhs columns). A request picks the first
        bucket that fits (sorted by area — smallest sufficient bucket);
        submission raises if none fits.
    max_slots:
        Resident-batch capacity. Requests beyond it queue and are admitted
        as slots free up — admission is strictly FIFO.
    """

    def __init__(self, comm, panel_width: int = 4,
                 buckets: Sequence[Tuple[int, int]] = ((8, 12),),
                 max_slots: int = 8):
        assert isinstance(comm, SimComm), (
            "QRService drives jitted host segments (SimComm layout); the "
            "SPMD serving path would thread step_fn= like the orchestrator")
        self.comm = comm
        self.P = comm.axis_size()
        self.b = panel_width
        self.buckets = sorted(
            (tuple(bk) for bk in buckets), key=lambda bk: bk[0] * bk[1])
        for m_loc, n in self.buckets:
            assert m_loc >= 1 and n >= 1, (m_loc, n)
        self.max_slots = max_slots
        self.queue: List[QRRequest] = []
        self.slots: List[Optional[_Slot]] = [None] * max_slots
        self.results: Dict[str, QRResult] = {}
        self.tick_count = 0
        self._pending_kills: List[int] = []
        self._submit_t: Dict[str, float] = {}
        self._rid_counter = itertools.count()
        levels = self.P.bit_length() - 1
        self._points_per_panel = 1 + 2 * levels
        # THE resident program (shared with every SweepOrchestrator over
        # the same comm): one jitted segment runner, specialized by jax
        # per (bucket, cursor) treedef.
        self._segment = compiled_segment(comm, self._points_per_panel)

    # -- admission ---------------------------------------------------------

    def select_bucket(self, m: int, n_total: int) -> Tuple[int, int]:
        """Smallest bucket fitting an ``(m, n_total)`` problem (n_total
        counts rhs columns — they ride in the bucket's spare width)."""
        for m_loc, n_b in self.buckets:
            if m <= self.P * m_loc and n_total <= n_b:
                return (m_loc, n_b)
        raise ValueError(
            f"no bucket fits ({m}, {n_total}); buckets={self.buckets}")

    def submit(self, A: np.ndarray, rhs: Optional[np.ndarray] = None,
               rid: Optional[str] = None) -> str:
        """Enqueue a request; it joins the resident batch at the next
        panel boundary with a free slot. Returns the request id."""
        A = np.asarray(A, np.float32)
        assert A.ndim == 2, A.shape
        if rhs is not None:
            rhs = np.asarray(rhs, np.float32)
            assert rhs.shape[0] == A.shape[0], (A.shape, rhs.shape)
        if rid is None:
            rid = f"req{next(self._rid_counter)}"
        n_total = A.shape[1] + (0 if rhs is None else rhs.shape[1])
        self.select_bucket(A.shape[0], n_total)  # fail fast on misfit
        self._submit_t[rid] = time.perf_counter()
        self.queue.append(QRRequest(rid=rid, A=A, rhs=rhs))
        return rid

    def kill_lane(self, lane: int) -> None:
        """Schedule a lane death: at the next boundary, ``lane``'s slice of
        EVERY resident tenant's state is poisoned (the fail-stop model —
        one process dies, all tenants it hosted lose that block-row)."""
        assert 0 <= lane < self.P, lane
        self._pending_kills.append(lane)

    def _admit(self, req: QRRequest, slot_idx: int) -> None:
        n_req = req.A.shape[1]
        nrhs = 0 if req.rhs is None else req.rhs.shape[1]
        bucket = self.select_bucket(req.A.shape[0], n_req + nrhs)
        m_loc, n_b = bucket
        A_aug = req.A if req.rhs is None else np.concatenate(
            [req.A, req.rhs], axis=1)
        A0 = block_row_layout(jnp.asarray(A_aug), self.P, m_loc, n_b)
        state = initial_sweep_state(self.comm, A0, self.b)
        assert panel_points(state.geom) == self._points_per_panel
        panels_needed = -(-req.k // self.b)
        assert panels_needed <= state.geom.n_panels
        self.slots[slot_idx] = _Slot(
            req=req, bucket=bucket, state=state,
            detector=NaNSentinelDetector(), panels_needed=panels_needed,
            admitted_tick=self.tick_count)

    # -- the service cycle -------------------------------------------------

    def tick(self) -> List[QRResult]:
        """One service cycle: advance every resident slot one panel, then
        the boundary work — inject pending kills, detect + heal, retire
        finished tenants, admit queued requests into freed slots. Returns
        the requests retired this tick."""
        active = [s for s in self.slots if s is not None]
        # 1. advance: one compiled panel-segment per resident slot
        for slot in active:
            if slot.state.cursor is not None:
                slot.state = self._segment(slot.state)
            slot.panels_done += 1
        # 2. fault injection (the boundary is where deaths surface)
        kills, self._pending_kills = self._pending_kills, []
        for lane in kills:
            for slot in active:
                slot.state = obliterate_state(self.comm, slot.state, lane)
        # 3. detect + heal every tenant (same REBUILD as the orchestrator)
        for slot in active:
            newly = slot.detector.poll(self.comm, slot.state)
            if newly:
                self._heal(slot, newly)
        # 4. retire
        retired: List[QRResult] = []
        for i, slot in enumerate(self.slots):
            if slot is not None and slot.panels_done >= slot.panels_needed:
                retired.append(self._retire(slot))
                self.slots[i] = None
        # 5. admit (new tenants join at the panel boundary)
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                self._admit(self.queue.pop(0), i)
        self.tick_count += 1
        return retired

    def run_until_drained(self, max_ticks: int = 10_000) -> Dict[str, QRResult]:
        """Tick until the queue and every slot are empty."""
        for _ in range(max_ticks):
            if not self.queue and all(s is None for s in self.slots):
                return self.results
            self.tick()
        raise RuntimeError(f"service not drained after {max_ticks} ticks")

    @property
    def resident(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def compiled_programs(self) -> int:
        """Resident specializations of the one segment runner (per
        bucket-cursor treedef). Flat after warmup — the serve bench and
        tests assert steady-state traffic compiles nothing new."""
        return self._segment._cache_size()

    # -- recovery ----------------------------------------------------------

    def _heal(self, slot: _Slot, newly: List[int]) -> None:
        geom = slot.state.geom
        point = prev_sweep_point(
            slot.state.cursor, geom.n_panels, geom.levels)
        assert point is not None, (
            "death detected on a tenant that never ran a segment")
        dead = set(newly)
        slot.state, events = recover_lanes(
            self.comm, slot.state, sorted(newly), point, dead,
            sync=lambda s: jax.block_until_ready(
                jax.tree_util.tree_leaves(s)),
            on_recovered=slot.detector.revive)
        slot.events.extend(events)

    # -- retirement --------------------------------------------------------

    def _partial_R(self, state: SweepState, n_panels: int) -> np.ndarray:
        """Assemble the upper-trapezoidal R of the first ``n_panels``
        deposited panels (the early-retirement slice of ``assemble_R``:
        identical arithmetic, rows stop at the tenant's frontier)."""
        rows = jnp.stack(state.R_rows[:n_panels])  # (p, P, b, n_work)
        geom = state.geom
        R = rows.swapaxes(0, 1).reshape(
            self.P, n_panels * geom.b, geom.n_work)
        return np.asarray(jnp.triu(R)[0])  # replicated; lane 0's copy

    def _retire(self, slot: _Slot) -> QRResult:
        state, deposited = deposit_boundary(self.comm, slot.state)
        assert deposited >= slot.panels_needed, (deposited, slot.panels_needed)
        req = slot.req
        m_req, n_req = req.shape
        k_req = req.k
        R_full = self._partial_R(state, slot.panels_needed)
        R = R_full[:k_req, :n_req]
        x = None
        if req.rhs is not None:
            # the rhs columns were trailing-updated to Q^T b by the same
            # panels that deposited R: back-solve R1 x1 = (Q^T b)[:k]
            # (wide requests get the basic solution — caqr_lstsq semantics)
            nrhs = req.rhs.shape[1]
            Qtb = R_full[:k_req, n_req:n_req + nrhs]
            x1 = jax.scipy.linalg.solve_triangular(
                jnp.asarray(R[:, :k_req]), jnp.asarray(Qtb), lower=False)
            x = np.asarray(x1)
            if n_req > k_req:
                x = np.concatenate(
                    [x, np.zeros((n_req - k_req, nrhs), x.dtype)], axis=0)
        result = QRResult(
            rid=req.rid, R=R, x=x, bucket=slot.bucket,
            panels=slot.panels_needed,
            ticks_resident=self.tick_count - slot.admitted_tick + 1,
            latency_s=time.perf_counter() - self._submit_t.pop(req.rid),
            events=slot.events)
        self.results[req.rid] = result
        return result

    # -- the express static-batch path ------------------------------------

    def drain_batched(self) -> Dict[str, QRResult]:
        """Offline bulk mode: group the current queue by bucket and run
        each group through ``caqr_factorize_batched`` (one vmapped sweep
        per bucket — the batched bucket dispatch), bypassing the slot
        machinery. No mid-flight admission or failure handling; results
        match the continuous path (bitwise at small tiles — see
        ``tests/test_serve.py``)."""
        by_bucket: Dict[Tuple[int, int], List[QRRequest]] = {}
        queue, self.queue = self.queue, []
        for req in queue:
            nrhs = 0 if req.rhs is None else req.rhs.shape[1]
            bucket = self.select_bucket(
                req.A.shape[0], req.A.shape[1] + nrhs)
            by_bucket.setdefault(bucket, []).append(req)
        out: Dict[str, QRResult] = {}
        for (m_loc, n_b), reqs in by_bucket.items():
            stack = jnp.stack([
                block_row_layout(
                    jnp.asarray(r.A if r.rhs is None else np.concatenate(
                        [r.A, r.rhs], axis=1)),
                    self.P, m_loc, n_b)
                for r in reqs])
            res = caqr_factorize_batched(
                stack, self.comm, self.b, use_scan=False,
                collect_bundles=True)
            geom = sweep_geometry(self.P, m_loc, n_b, self.b)
            for i, req in enumerate(reqs):
                m_req, n_req = req.shape
                k_req = req.k
                # full-sweep R; rows past the tenant's frontier are below
                # its triangle, so the slice equals the early-retired one
                R_full = np.asarray(res.R[i, 0])
                R = R_full[:k_req, :n_req]
                x = None
                if req.rhs is not None:
                    nrhs = req.rhs.shape[1]
                    Qtb = R_full[:k_req, n_req:n_req + nrhs]
                    x1 = jax.scipy.linalg.solve_triangular(
                        jnp.asarray(R[:, :k_req]), jnp.asarray(Qtb),
                        lower=False)
                    x = np.asarray(x1)
                    if n_req > k_req:
                        x = np.concatenate(
                            [x, np.zeros((n_req - k_req, nrhs), x.dtype)],
                            axis=0)
                result = QRResult(
                    rid=req.rid, R=R, x=x, bucket=(m_loc, n_b),
                    panels=geom.n_panels, ticks_resident=1,
                    latency_s=time.perf_counter()
                    - self._submit_t.pop(req.rid),
                    events=[])
                self.results[req.rid] = result
                out[req.rid] = result
        return out
