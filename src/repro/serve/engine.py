"""Batched serving engine: prefill -> cached decode with sampling.

Static-batch engine (slots = batch rows): prefill a batch of prompts, then
step all slots together; finished slots (EOS or max length) keep decoding
into a sink but are masked from the outputs. Sliding-window layers convert
the prefill cache into rolling form (roll by S0 mod window) so decode's
``pos % window`` addressing lines up.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.models import attention as attn
from repro.models import transformer as tf


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0   # 0 = greedy
    eos_id: int = -1           # -1 = never stop early
    cache_len: int = 0         # 0 = prompt_len + max_new_tokens
    seed: int = 0


def _prefill_to_decode_caches(cfg: ModelConfig, caches, prompt_len: int,
                              cache_len: int, mixer: str = "G"):
    """Convert full prefill KV caches to decode layout: pad/crop each layer
    to ITS decode cache length — ``_layer_cache_len(cfg, mixer, cache_len)``,
    the sliding window for "L" layers, the global ``cache_len`` otherwise.
    Cropped (rolling) layers keep the last ``window`` entries rolled into
    ``pos % window`` order — decode's rolling addressing and masking assume
    ``S_cache == window``, so using the global ``cache_len`` as the window
    (the pre-fix behavior) corrupts an "L" layer whenever
    ``cache_len != sliding_window``. SSM/LRU states pass through."""
    tgt = _layer_cache_len(cfg, mixer, cache_len)

    def conv(c):
        if isinstance(c, attn.KVCache):
            # seq dim is axis -3 ((..., S, Kv, Dh)); a leading group axis may
            # be present when layers are scanned.
            S_full = c.k.shape[-3]
            nd = c.k.ndim
            if tgt >= S_full:
                pad = [(0, 0)] * nd
                pad[-3] = (0, tgt - S_full)
                return attn.KVCache(k=jnp.pad(c.k, pad), v=jnp.pad(c.v, pad))
            # rolling layer: keep the last `w` entries at pos%w slots, where
            # w is the LAYER's window, not the global cache_len
            w = tgt
            sl = (Ellipsis, slice(S_full - w, S_full), slice(None), slice(None))
            k = jnp.roll(c.k[sl], prompt_len % w, axis=-3)
            v = jnp.roll(c.v[sl], prompt_len % w, axis=-3)
            return attn.KVCache(k=k, v=v)
        return c

    return jax.tree_util.tree_map(
        conv, caches, is_leaf=lambda x: isinstance(x, (attn.KVCache,))
    )


def _layer_cache_len(cfg: ModelConfig, mixer: str, total_len: int) -> int:
    if mixer == "L":
        return min(cfg.sliding_window, total_len)
    return total_len


class Engine:
    def __init__(self, cfg: ModelConfig, params, scfg: Optional[ServeConfig] = None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg or ServeConfig()
        self._prefill = jax.jit(api.make_prefill(cfg))
        self._step = jax.jit(api.make_serve_step(cfg))

    def generate(self, prompts: np.ndarray, extras: Optional[Dict] = None) -> np.ndarray:
        """prompts: (B, S0) int32. Returns (B, max_new_tokens)."""
        cfg, scfg = self.cfg, self.scfg
        B, S0 = prompts.shape
        total = scfg.cache_len or (S0 + scfg.max_new_tokens)
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        enc_out = None
        if cfg.encoder is not None:
            enc_out = tf.encode(cfg, self.params, batch["enc_frames"])

        logits, caches = self._prefill(self.params, batch)

        # re-key prefill caches into decode layout per layer kind
        period, n_groups, n_rem = tf._groups(cfg)

        def relayout(c, mixer):
            if not isinstance(c, attn.KVCache):
                return c
            return _prefill_to_decode_caches(cfg, c, S0, total, mixer=mixer)

        # caches structure: {"groups": {l{i}: cache}, rem{r}: cache}
        new_caches = {}
        if caches.get("groups") is not None:
            g = {}
            for i in range(period):
                g[f"l{i}"] = relayout(caches["groups"][f"l{i}"], cfg.mixer_at(i))
            new_caches["groups"] = g
        for r in range(cfg.n_layers % period if cfg.scan_layers else cfg.n_layers):
            li = n_groups * period + r
            key = f"rem{r}"
            if key in caches:
                new_caches[key] = relayout(caches[key], cfg.mixer_at(li))
        caches = new_caches

        # emit-then-feed: out[:, t] is the prediction of position S0 + t,
        # starting with the prefill's own next-token prediction (the
        # previous feed-then-emit loop consumed it without emitting,
        # shifting every output one position late)
        key = jax.random.key(scfg.seed)
        lg = logits[:, -1]
        out: List[np.ndarray] = []
        done = np.zeros((B,), bool)
        for t in range(scfg.max_new_tokens):
            if scfg.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, lg / scfg.temperature)[:, None]
            else:
                tok = jnp.argmax(lg, axis=-1)[:, None]
            tok = tok.astype(jnp.int32)
            step_out = np.asarray(tok[:, 0])
            step_out = np.where(done, scfg.eos_id, step_out)
            out.append(step_out)
            if scfg.eos_id >= 0:
                done |= step_out == scfg.eos_id
                if done.all():
                    break
            if t == scfg.max_new_tokens - 1:
                break
            pos = jnp.asarray(S0 + t, jnp.int32)
            args = (self.params, tok, pos, caches)
            if cfg.encoder is not None:
                logits, caches = self._step(*args, enc_out)
            else:
                logits, caches = self._step(*args)
            lg = logits[:, -1]
        return np.stack(out, axis=1)
