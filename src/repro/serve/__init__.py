"""Serving engine: prefill + batched cached decode."""
from repro.serve.engine import Engine, ServeConfig
__all__ = ["Engine", "ServeConfig"]
