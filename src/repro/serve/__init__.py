"""Serving: the token engine (prefill + batched cached decode) and the
QR-as-a-service front end (continuous sweep batching, ``qr_service``)."""
from repro.serve.engine import Engine, ServeConfig
from repro.serve.qr_service import QRRequest, QRResult, QRService
__all__ = ["Engine", "ServeConfig", "QRRequest", "QRResult", "QRService"]
