"""Model-facing API: input specs per (arch x shape) cell and step builders.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no allocation) for every model input of a cell — the dry-run
lowers against these. Modality frontends are STUBS per the assignment:
whisper gets precomputed frame embeddings, pixtral precomputed patch
embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "long_500k skipped: arch has full-attention layers"
    return True, ""


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.vlm is not None:
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vlm.n_patches, cfg.d_model), cfg.jdtype
        )
    if cfg.encoder is not None:
        specs["enc_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder.n_frames, cfg.d_model), cfg.jdtype
        )
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    caches = jax.eval_shape(lambda: tf.init_caches(cfg, B, S))
    specs = {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "caches": caches,
    }
    if cfg.encoder is not None:
        specs["enc_out"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder.n_frames, cfg.d_model), cfg.jdtype
        )
    return specs


def param_specs(cfg: ModelConfig) -> Any:
    """Abstract parameter tree (no allocation)."""
    key = jax.eval_shape(lambda: jax.random.key(0))
    return jax.eval_shape(lambda k: tf.init_params(cfg, k), key)


def make_forward_loss(cfg: ModelConfig):
    def fl(params, batch):
        return tf.loss_fn(cfg, params, batch)

    return fl


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, token, pos, caches, enc_out=None):
        return tf.decode_step(cfg, params, caches, token, pos, enc_out=enc_out)

    return serve_step


def make_prefill(cfg: ModelConfig):
    def prefill(params, batch):
        hidden, caches, _ = tf.forward(
            cfg, params, batch["tokens"],
            patch_embeds=batch.get("patch_embeds"),
            enc_frames=batch.get("enc_frames"),
            mode="prefill",
        )
        logits = tf.logits_fn(cfg, params, hidden[:, -1:])
        return logits, caches

    return prefill
