"""Shared model components: norms, RoPE, activations, embeddings, init."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import ax


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def act_fn(name: str):
    if name == "swiglu" or name == "geglu":
        raise ValueError("gated activations are handled in the MLP")
    return {"gelu": jax.nn.gelu, "sq_relu": lambda x: jnp.square(jax.nn.relu(x)),
            "relu": jax.nn.relu, "silu": jax.nn.silu}[name]


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def embed(tokens: jax.Array, table: jax.Array, scale: bool = False) -> jax.Array:
    """Token embedding lookup; table (V, D) is vocab-sharded. The lookup is
    a gather over the sharded dim — the partitioner turns it into a masked
    local gather + all-reduce."""
    out = jnp.take(table, tokens, axis=0)
    if scale:
        out = out * jnp.asarray(table.shape[1] ** 0.5, out.dtype)
    return ax(out, "batch", "seq_shard", None)


def normal_init(key, shape, dtype, scale: float = 0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
