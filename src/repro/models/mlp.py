"""Channel mixers: gated / plain MLPs."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import ax
from repro.models.common import act_fn


class MLPParams(NamedTuple):
    w_in: jax.Array    # (D, F) — or gate proj for gated activations
    w_gate: jax.Array  # (D, F) — zeros-shaped (0,0) when unused
    w_out: jax.Array   # (F, D)


def mlp_forward(p: MLPParams, x: jax.Array, activation: str) -> jax.Array:
    if activation in ("swiglu", "geglu"):
        gate = x @ p.w_gate
        up = x @ p.w_in
        gate = ax(gate, "batch", None, "ff")
        up = ax(up, "batch", None, "ff")
        inner = jax.nn.silu(gate) * up if activation == "swiglu" else jax.nn.gelu(gate) * up
    else:
        inner = ax(act_fn(activation)(x @ p.w_in), "batch", None, "ff")
    return inner @ p.w_out
