"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses an associative scan (O(log S) depth); decode is a
single step. The block wraps the LRU in the Griffin recurrent-block layout:
in-proj (x, gate) -> temporal conv1d -> RG-LRU -> gated out-proj.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import ax

_C = 8.0  # the paper's fixed constant


class RGLRUParams(NamedTuple):
    w_in: jax.Array       # (D, 2*W)  -> (x branch, gate branch)
    conv_w: jax.Array     # (conv_width, W) depthwise
    w_a: jax.Array        # (W, W) recurrence-gate (block-diagonal in paper; dense here)
    b_a: jax.Array        # (W,)
    w_x: jax.Array        # (W, W) input-gate
    b_x: jax.Array        # (W,)
    a_param: jax.Array    # (W,)  Lambda
    w_out: jax.Array      # (W, D)


class RGLRUState(NamedTuple):
    h: jax.Array          # (B, W)
    conv: jax.Array       # (B, conv_width-1, W)


def _lru_scan(a: jax.Array, u: jax.Array, h0: Optional[jax.Array]):
    """h_t = a_t * h_{t-1} + u_t via associative scan over S. a,u: (B,S,W)."""

    def combine(x, y):
        a1, u1 = x
        a2, u2 = y
        return a1 * a2, a2 * u1 + u2

    if h0 is not None:
        # fold h0 in as a virtual first element
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        u = jnp.concatenate([h0[:, None], u], axis=1)
        _, h = jax.lax.associative_scan(combine, (a, u), axis=1)
        return h[:, 1:]
    _, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    return h


def rglru_forward(
    p: RGLRUParams,
    x: jax.Array,  # (B, S, D)
    *,
    state: Optional[RGLRUState] = None,
    return_state: bool = False,
):
    B, S, D = x.shape
    W = p.w_out.shape[0]
    xz = x @ p.w_in
    xb, gate = jnp.split(xz, 2, axis=-1)  # (B,S,W) each
    xb = ax(xb, "batch", None, "lru")

    # temporal depthwise conv
    cw = p.conv_w.shape[0]
    if state is not None:
        x_in = jnp.concatenate([state.conv, xb], axis=1)
    else:
        x_in = jnp.pad(xb, ((0, 0), (cw - 1, 0), (0, 0)))
    new_conv_tail = x_in[:, -(cw - 1):]
    acc = jnp.zeros_like(xb)
    for c in range(cw):
        acc = acc + x_in[:, c : c + S] * p.conv_w[c][None, None, :]
    xb = acc

    r = jax.nn.sigmoid(xb @ p.w_a + p.b_a)
    i = jax.nn.sigmoid(xb @ p.w_x + p.b_x)
    log_a = -_C * jax.nn.softplus(p.a_param.astype(jnp.float32)) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated_x = (i * xb).astype(jnp.float32)
    u = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x

    h0 = state.h.astype(jnp.float32) if state is not None else None
    if S == 1 and state is not None:
        h = (a[:, 0] * h0 + u[:, 0])[:, None]
    else:
        h = _lru_scan(a, u, h0)
    h = h.astype(x.dtype)

    out = (h * jax.nn.gelu(gate)) @ p.w_out
    if return_state:
        return out, RGLRUState(h=h[:, -1].astype(jnp.float32), conv=new_conv_tail)
    return out
