"""Mixture-of-Experts channel mixer with sort-based capacity dispatch.

Production-style TPU MoE (the shape MaxText/Mixtral implementations use):

  1. router logits (f32) -> top-k experts + normalized weights per token;
  2. dispatch: the (token, k) assignments are sorted by expert id; each
     token takes a slot ``position-in-expert`` computed from the sorted
     order (no (N, E) one-hot cumsum — O(N log N) instead of O(N*E));
     tokens beyond an expert's capacity are dropped (their combine weight
     contributes nothing — standard capacity-factor semantics);
  3. expert compute: gathered activations land in an (E, C, D) buffer and
     run through a batched-einsum gated MLP, sharded over the ``experts``
     (= model) mesh axis — expert parallelism;
  4. combine: results scatter back to (N, D) weighted by router weights.

The load-balancing auxiliary loss (switch-style) is returned alongside.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import ax


class MoEParams(NamedTuple):
    w_router: jax.Array  # (D, E)
    w_gate: jax.Array    # (E, D, F)
    w_in: jax.Array      # (E, D, F)
    w_out: jax.Array     # (E, F, D)


def moe_forward(
    p: MoEParams,
    x: jax.Array,          # (B, S, D)
    *,
    top_k: int,
    capacity_factor: float,
    activation: str = "swiglu",
    shards: int = 1,
) -> Tuple[jax.Array, jax.Array]:
    """shards > 1 dispatches per token-shard group (GSPMD-friendly): the
    (shards, E, C_loc, D) buffers shard over ('data', 'model', ...) and the
    scatter gains a sharded leading batch dim — without it, GSPMD computes
    per-device partial scatters into the *global* (E, C, D) buffer and
    all-reduces it (observed 154 TiB/device on kimi-k2 train_4k; see
    EXPERIMENTS.md §Perf). Per-group capacity is the per-device capacity
    real systems use anyway. shards must divide B*S."""
    B, S, D = x.shape
    E = p.w_router.shape[1]
    N = B * S
    if shards > 1:
        assert N % shards == 0, (N, shards)
        xg = x.reshape(shards, N // shards, D)
        xg = ax(xg, "batch", None, None)
        outs, aux = jax.vmap(
            lambda xs: _moe_group(p, xs, top_k=top_k,
                                  capacity_factor=capacity_factor,
                                  activation=activation, constrain=False)
        )(xg)
        out = ax(outs.reshape(B, S, D), "batch", None, None)
        return out, jnp.mean(aux)
    out, aux = _moe_group(p, x.reshape(N, D), top_k=top_k,
                          capacity_factor=capacity_factor,
                          activation=activation)
    return ax(out.reshape(B, S, D), "batch", None, None), aux


def _moe_group(
    p: MoEParams,
    xf: jax.Array,         # (N, D) one dispatch group's tokens
    *,
    top_k: int,
    capacity_factor: float,
    activation: str,
    constrain: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    N, D = xf.shape
    E = p.w_router.shape[1]

    # --- router (f32 for numerics) ---------------------------------------
    logits = (xf.astype(jnp.float32) @ p.w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (N, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)  # (N, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # switch-style load-balance aux loss
    density = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0
    )
    density_proxy = jnp.mean(probs, axis=0)
    aux_loss = jnp.sum(density * density_proxy) * (E * E) / E

    # --- dispatch ---------------------------------------------------------
    C = int(capacity_factor * N * top_k / E)
    C = max(C, 8)
    flat_expert = expert_ids.reshape(-1)            # (N*K,)
    flat_token = jnp.repeat(jnp.arange(N), top_k)   # (N*K,)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert)                # stable
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]
    # position within expert group = index - start of the group
    seg_start = jnp.searchsorted(sorted_expert, jnp.arange(E), side="left")
    pos_in_expert = jnp.arange(N * top_k) - seg_start[sorted_expert]
    keep = pos_in_expert < C

    # gather into (E, C, D) expert buffers
    buf = jnp.zeros((E, C, D), xf.dtype)
    src = jnp.where(keep[:, None], xf[sorted_token], 0)
    buf = buf.at[
        jnp.where(keep, sorted_expert, 0), jnp.where(keep, pos_in_expert, 0)
    ].add(jnp.where(keep[:, None], src, 0))
    if constrain:
        buf = ax(buf, "experts", None, None)

    # --- expert MLPs (batched einsum, EP-sharded) ---------------------------
    gate = jnp.einsum("ecd,edf->ecf", buf, p.w_gate)
    up = jnp.einsum("ecd,edf->ecf", buf, p.w_in)
    if constrain:
        gate = ax(gate, "experts", None, None)
    if activation == "swiglu":
        inner = jax.nn.silu(gate) * up
    elif activation == "geglu":
        inner = jax.nn.gelu(gate) * up
    else:
        inner = jnp.square(jax.nn.relu(gate))
    out_buf = jnp.einsum("ecf,efd->ecd", inner, p.w_out)
    if constrain:
        out_buf = ax(out_buf, "experts", None, None)

    # --- combine ------------------------------------------------------------
    picked = out_buf[
        jnp.where(keep, sorted_expert, 0), jnp.where(keep, pos_in_expert, 0)
    ]  # (N*K, D)
    picked = jnp.where(keep[:, None], picked, 0)
    contrib = picked * sorted_gate[:, None].astype(picked.dtype)
    out = jnp.zeros((N, D), xf.dtype).at[sorted_token].add(contrib.astype(xf.dtype))
    return out, aux_loss
