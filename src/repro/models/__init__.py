"""Model zoo: unified transformer assembly for all assigned families."""
from repro.models import api, attention, common, mlp, moe, rglru, ssm, transformer

__all__ = ["api", "attention", "common", "mlp", "moe", "rglru", "ssm", "transformer"]
