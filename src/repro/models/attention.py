"""Attention token mixers: GQA/MQA, RoPE, sliding window, softcaps.

Three execution paths:
  * ``full``     — plain einsum attention (short sequences); (B,H,S,S) logits.
  * ``chunked``  — blockwise streaming-softmax attention for long sequences:
    queries are processed in chunks; for each query chunk only the causally
    visible KV chunks are visited (triangular schedule — no masked-out flops,
    the outer loop is unrolled so every inner scan has a static length).
    Sliding-window attention visits only the chunks overlapping the window.
  * ``decode``   — one query token against a (possibly rolling) KV cache;
    softmax reductions run over the cache sequence dim, which may be sharded
    (long_500k: flash-decode style, the partitioner inserts the all-reduce).

All logits math in f32.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import ax
from repro.models.common import rope, softcap


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_cache, Kv, Dh)
    v: jax.Array  # (B, S_cache, Kv, Dh)
    # rolling caches (sliding-window layers): S_cache == window and writes
    # wrap modulo the window.


def _split_heads(q, k, v, n_kv: int):
    """q: (B,S,H,Dh) -> (B, Kv, G, S, Dh); k/v: (B,S,Kv,Dh) -> (B,Kv,S,Dh)."""
    B, S, H, Dh = q.shape
    G = H // n_kv
    q = q.reshape(B, S, n_kv, G, Dh).transpose(0, 2, 3, 1, 4)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    return q, k, v


def _sdpa_block(q, k, v, bias, cap: Optional[float], scale: float):
    """q (B,Kv,G,Sq,Dh), k/v (B,Kv,Skv,Dh), bias broadcastable (Sq,Skv)."""
    logits = jnp.einsum(
        "bkgsd,bktd->bkgst", q, k, preferred_element_type=jnp.float32
    ) * scale
    logits = softcap(logits, cap)
    logits = logits + bias
    return logits  # caller does the softmax variant it needs


def full_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    n_kv: int,
    causal: bool = True,
    window: Optional[int] = None,
    cap: Optional[float] = None,
) -> jax.Array:
    B, S, H, Dh = q.shape
    S_kv = k.shape[1]
    scale = Dh ** -0.5
    qh, kh, vh = _split_heads(q, k, v, n_kv)
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S_kv)[None, :]
    mask = jnp.ones((S, S_kv), jnp.bool_)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    bias = jnp.where(mask, 0.0, -1e30).astype(jnp.float32)
    logits = _sdpa_block(qh, kh, vh, bias, cap, scale)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, vh)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dh)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    n_kv: int,
    causal: bool = True,
    window: Optional[int] = None,
    cap: Optional[float] = None,
    q_chunk: int = 2048,
    kv_chunk: int = 2048,
    schedule: str = "tri",
) -> jax.Array:
    """Streaming-softmax attention over chunks.

    schedule='tri'  — triangular Python-unrolled schedule: only causally
        visible KV chunks are visited (no masked-out flops) and there is no
        while op, so the dry-run's cost analysis counts every block.
    schedule='scan' — double lax.scan (q outer, kv inner, masked): compact
        HLO and tight buffer reuse; used by the memory-compile variant and
        the production path.
    """
    B, S, H, Dh = q.shape
    assert S % q_chunk == 0 and S % kv_chunk == 0, (S, q_chunk, kv_chunk)
    scale = Dh ** -0.5
    qh, kh, vh = _split_heads(q, k, v, n_kv)  # (B,Kv,G,S,Dh), (B,Kv,S,Dh)
    n_q = S // q_chunk
    n_kvc = S // kv_chunk
    G = H // n_kv

    kc = kh.reshape(B, n_kv, n_kvc, kv_chunk, Dh)
    vc = vh.reshape(B, n_kv, n_kvc, kv_chunk, Dh)

    def block(carry, q_blk, q0, jk, k_blk, v_blk):
        m, l, acc = carry
        k0 = jk * kv_chunk
        qi = q0 + jnp.arange(q_chunk)[:, None]
        ki = k0 + jnp.arange(kv_chunk)[None, :]
        mask = jnp.ones((q_chunk, kv_chunk), jnp.bool_)
        if causal:
            mask = mask & (ki <= qi)
        if window is not None:
            mask = mask & (ki > qi - window)
        bias = jnp.where(mask, 0.0, -1e30).astype(jnp.float32)
        logits = _sdpa_block(q_blk, k_blk, v_blk, bias, cap, scale)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,bktd->bkgsd", p, v_blk.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new)

    def init_carry():
        return (
            jnp.full((B, n_kv, G, q_chunk), -1e30, jnp.float32),
            jnp.zeros((B, n_kv, G, q_chunk), jnp.float32),
            jnp.zeros((B, n_kv, G, q_chunk, Dh), jnp.float32),
        )

    if schedule == "scan":
        qb = qh.reshape(B, n_kv, G, n_q, q_chunk, Dh)

        def q_body(_, xs):
            q_blk, iq = xs
            q0 = iq * q_chunk

            def kv_body(carry, kv_xs):
                jk, k_blk, v_blk = kv_xs
                new = block(carry, q_blk, q0, jk, k_blk, v_blk)
                # skip fully-masked chunks cheaply: keep old carry when the
                # chunk is entirely beyond the causal front
                if causal:
                    beyond = jk * kv_chunk > q0 + q_chunk - 1
                    keep = lambda a, b: jnp.where(beyond, a, b)
                    new = tuple(keep(c, n) for c, n in zip(carry, new))
                return new, None

            (m, l, acc), _ = jax.lax.scan(
                kv_body, init_carry(),
                (jnp.arange(n_kvc), kc.transpose(2, 0, 1, 3, 4),
                 vc.transpose(2, 0, 1, 3, 4)),
            )
            return None, (acc / l[..., None]).astype(q.dtype)

        _, out_blocks = jax.lax.scan(
            q_body, None,
            (qb.transpose(3, 0, 1, 2, 4, 5), jnp.arange(n_q)),
        )
        # (n_q, B, Kv, G, q_chunk, Dh) -> (B, Kv, G, S, Dh)
        out = out_blocks.transpose(1, 2, 3, 0, 4, 5).reshape(B, n_kv, G, S, Dh)
        return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dh)

    outs = []
    for iq in range(n_q):
        q_blk = jax.lax.dynamic_slice_in_dim(qh, iq * q_chunk, q_chunk, axis=3)
        q0 = iq * q_chunk
        # visible kv chunk range (static per q chunk)
        hi = (q0 + q_chunk + kv_chunk - 1) // kv_chunk if causal else n_kvc
        lo = 0
        if window is not None:
            # earliest query in this chunk (q0) still sees keys > q0 - window
            lo = max(0, (q0 - window + 1) // kv_chunk)
        carry = init_carry()
        for jk in range(lo, hi):
            carry = block(carry, q_blk, q0, jnp.asarray(jk), kc[:, :, jk], vc[:, :, jk])
        m, l, acc = carry
        outs.append((acc / l[..., None]).astype(q.dtype))

    out = jnp.concatenate(outs, axis=3)  # (B,Kv,G,S,Dh)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dh)


def decode_attention(
    q1: jax.Array,            # (B, 1, H, Dh)
    cache: KVCache,
    pos: jax.Array,           # () current position (tokens already cached)
    *,
    n_kv: int,
    window: Optional[int] = None,
    cap: Optional[float] = None,
) -> jax.Array:
    """One-token attention against the cache (already containing this step's
    k/v at index pos % S_cache). Entries beyond pos are masked."""
    B, S_cache, Kv, Dh = cache.k.shape
    H = q1.shape[2]
    G = H // n_kv
    scale = Dh ** -0.5
    qh = q1.reshape(B, 1, n_kv, G, Dh).transpose(0, 2, 3, 1, 4)  # (B,Kv,G,1,Dh)
    kh = ax(cache.k.transpose(0, 2, 1, 3), "batch", "kv_heads", "kv_seq_shard", None)
    vh = ax(cache.v.transpose(0, 2, 1, 3), "batch", "kv_heads", "kv_seq_shard", None)

    idx = jnp.arange(S_cache)
    if window is None:
        valid = idx <= pos
    else:
        # rolling cache: all S_cache == window slots valid once warm
        valid = (idx <= pos) | (pos >= S_cache)
    bias = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)[None, :]
    logits = _sdpa_block(qh, kh, vh, bias, cap, scale)  # (B,Kv,G,1,S)
    probs = jax.nn.softmax(logits, axis=-1).astype(q1.dtype)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, vh)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, 1, H, Dh)


def cache_update(
    cache: KVCache, k_new: jax.Array, v_new: jax.Array, pos: jax.Array
) -> KVCache:
    """Write this step's k/v (B,1,Kv,Dh) at pos (modulo rolling window)."""
    S_cache = cache.k.shape[1]
    slot = pos % S_cache
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), slot, axis=1)
    return KVCache(k=k, v=v)


class AttnParams(NamedTuple):
    wq: jax.Array   # (D, H*Dh)
    wk: jax.Array   # (D, Kv*Dh)
    wv: jax.Array   # (D, Kv*Dh)
    wo: jax.Array   # (H*Dh, D)


def attn_forward(
    p: AttnParams,
    x: jax.Array,                 # (B, S, D)
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float,
    causal: bool = True,
    window: Optional[int] = None,
    cap: Optional[float] = None,
    positions: Optional[jax.Array] = None,
    use_rope: bool = True,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attn
    chunked: bool = False,
    q_chunk: int = 2048,
    kv_chunk: int = 2048,
    schedule: str = "scan",
) -> jax.Array:
    B, S, D = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = (x @ p.wq).reshape(B, S, n_heads, head_dim)
    q = ax(q, "batch", None, "heads", None)
    if kv_override is None:
        k = (x @ p.wk).reshape(B, S, n_kv, head_dim)
        v = (x @ p.wv).reshape(B, S, n_kv, head_dim)
        if use_rope:
            q = rope(q, positions, rope_theta)
            k = rope(k, positions, rope_theta)
    else:
        k, v = kv_override
        if use_rope:
            q = rope(q, positions, rope_theta)
    k = ax(k, "batch", None, "kv_heads", None)
    v = ax(v, "batch", None, "kv_heads", None)
    if chunked:
        o = chunked_attention(
            q, k, v, n_kv=n_kv, causal=causal, window=window, cap=cap,
            q_chunk=q_chunk, kv_chunk=kv_chunk, schedule=schedule,
        )
    else:
        o = full_attention(q, k, v, n_kv=n_kv, causal=causal, window=window, cap=cap)
    o = ax(o, "batch", None, "heads", None)
    return o.reshape(B, S, n_heads * head_dim) @ p.wo
