"""Unified LM assembly: heterogeneous layer stacks, scan-over-layers,
training forward, prefill, and cached decode for every assigned family.

Layer kinds come from ``ModelConfig.mixer_pattern`` / ``ffn_pattern``; the
stack is scanned over *pattern periods* (groups), so HLO size is O(period),
not O(n_layers) — 96-layer nemotron compiles the same graph size as a
2-layer model. Heterogeneous caches (KV / SSM / LRU) are pytrees stacked
over groups the same way.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ax
from repro.models import attention as attn
from repro.models import mlp as mlpm
from repro.models import moe as moem
from repro.models import rglru as rglrum
from repro.models import ssm as ssmm
from repro.models.common import embed, normal_init, rms_norm, softcap


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------


def _init_attn(key, cfg: ModelConfig, dtype) -> attn.AttnParams:
    D, H, Kv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hdim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return attn.AttnParams(
        wq=normal_init(k1, (D, H * Dh), dtype),
        wk=normal_init(k2, (D, Kv * Dh), dtype),
        wv=normal_init(k3, (D, Kv * Dh), dtype),
        wo=normal_init(k4, (H * Dh, D), dtype),
    )


def _init_mlp(key, cfg: ModelConfig, dtype) -> mlpm.MLPParams:
    D, F = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    gated = cfg.activation in ("swiglu", "geglu")
    return mlpm.MLPParams(
        w_in=normal_init(k1, (D, F), dtype),
        w_gate=normal_init(k2, (D, F), dtype) if gated else jnp.zeros((1, 1), dtype),
        w_out=normal_init(k3, (F, D), dtype),
    )


def _init_moe(key, cfg: ModelConfig, dtype) -> moem.MoEParams:
    D = cfg.d_model
    E, F = cfg.moe.n_experts, cfg.moe.d_ff_expert
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return moem.MoEParams(
        w_router=normal_init(k1, (D, E), jnp.float32),
        w_gate=normal_init(k2, (E, D, F), dtype),
        w_in=normal_init(k3, (E, D, F), dtype),
        w_out=normal_init(k4, (E, F, D), dtype),
    )


def _init_ssm(key, cfg: ModelConfig, dtype) -> ssmm.SSMParams:
    s = cfg.ssm
    D = cfg.d_model
    d_inner = s.expand * D
    H = d_inner // s.head_dim
    G, N = s.n_groups, s.d_state
    conv_dim = d_inner + 2 * G * N
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return ssmm.SSMParams(
        w_in=normal_init(k1, (D, 2 * d_inner + 2 * G * N + H), dtype),
        conv_w=normal_init(k2, (s.conv_width, conv_dim), dtype, scale=0.1),
        A_log=jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        Dskip=jnp.ones((H,), jnp.float32),
        dt_bias=jnp.zeros((H,), jnp.float32),
        norm_scale=jnp.zeros((d_inner,), dtype),
        w_out=normal_init(k3, (d_inner, D), dtype),
    )


def _init_rglru(key, cfg: ModelConfig, dtype) -> rglrum.RGLRUParams:
    r = cfg.rglru
    D = cfg.d_model
    W = r.lru_width or D
    ks = jax.random.split(key, 5)
    return rglrum.RGLRUParams(
        w_in=normal_init(ks[0], (D, 2 * W), dtype),
        conv_w=normal_init(ks[1], (r.conv_width, W), dtype, scale=0.1),
        w_a=normal_init(ks[2], (W, W), dtype),
        b_a=jnp.zeros((W,), dtype),
        w_x=normal_init(ks[3], (W, W), dtype),
        b_x=jnp.zeros((W,), dtype),
        a_param=jnp.ones((W,), jnp.float32) * 0.5,
        w_out=normal_init(ks[4], (W, D), dtype),
    )


def _init_layer(key, cfg: ModelConfig, mixer: str, ffn: str, cross: bool, dtype) -> Dict:
    D = cfg.d_model
    keys = jax.random.split(key, 4)
    lp: Dict[str, Any] = {"norm1": jnp.zeros((D,), dtype)}
    if mixer in ("G", "L"):
        lp["attn"] = _init_attn(keys[0], cfg, dtype)
    elif mixer == "M":
        lp["ssm"] = _init_ssm(keys[0], cfg, dtype)
    elif mixer == "R":
        lp["lru"] = _init_rglru(keys[0], cfg, dtype)
    if cross:
        lp["cross_norm"] = jnp.zeros((D,), dtype)
        lp["cross"] = _init_attn(keys[3], cfg, dtype)
    if ffn != "N":
        lp["norm2"] = jnp.zeros((D,), dtype)
        lp["ffn"] = (
            _init_moe(keys[1], cfg, dtype) if ffn == "E" else _init_mlp(keys[1], cfg, dtype)
        )
    if cfg.post_norms:
        lp["post_norm1"] = jnp.zeros((D,), dtype)
        if ffn != "N":
            lp["post_norm2"] = jnp.zeros((D,), dtype)
    return lp


def _groups(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(period, n_groups, n_rem): layers = n_groups*period + n_rem."""
    period = cfg.pattern_period if cfg.scan_layers else 1
    if not cfg.scan_layers:
        return 1, 0, cfg.n_layers
    return period, cfg.n_layers // period, cfg.n_layers % period


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    dtype = cfg.jdtype
    period, n_groups, n_rem = _groups(cfg)
    cross = cfg.encoder is not None
    keys = jax.random.split(key, 8)

    def group_params(k):
        ks = jax.random.split(k, period)
        return {
            f"l{i}": _init_layer(
                ks[i], cfg, cfg.mixer_at(i), cfg.ffn_at(i), cross, dtype
            )
            for i in range(period)
        }

    params: Dict[str, Any] = {
        "embed": normal_init(keys[0], (cfg.vocab, cfg.d_model), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if n_groups:
        gk = jax.random.split(keys[1], n_groups)
        params["groups"] = jax.vmap(group_params)(gk)
    for r in range(n_rem):
        li = n_groups * period + r
        params[f"rem{r}"] = _init_layer(
            jax.random.fold_in(keys[2], r), cfg, cfg.mixer_at(li), cfg.ffn_at(li),
            cross, dtype,
        )
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(keys[3], (cfg.d_model, cfg.vocab), dtype)
    if cfg.encoder is not None:
        ek = jax.random.split(keys[4], cfg.encoder.n_layers + 2)
        params["enc_pos"] = normal_init(ek[0], (cfg.encoder.n_frames, cfg.d_model), dtype)
        params["enc_final_norm"] = jnp.zeros((cfg.d_model,), dtype)
        params["enc_layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, "G", "D", False, dtype)
        )(jnp.stack(list(ek[1:-1])))
    return params


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------


def _apply_mixer(
    cfg: ModelConfig,
    mixer: str,
    lp: Dict,
    x: jax.Array,
    *,
    positions,
    mode: str,
    cache,
    pos,
    enc_kv=None,
):
    """Returns (out, new_cache)."""
    window = cfg.sliding_window if mixer == "L" else None
    S = x.shape[1]
    if mixer in ("G", "L"):
        if mode == "decode":
            p: attn.AttnParams = lp["attn"]
            B = x.shape[0]
            Dh, H, Kv = cfg.hdim, cfg.n_heads, cfg.n_kv_heads
            q = (x @ p.wq).reshape(B, 1, H, Dh)
            k = (x @ p.wk).reshape(B, 1, Kv, Dh)
            v = (x @ p.wv).reshape(B, 1, Kv, Dh)
            pp = jnp.full((B, 1), pos)
            q = attn.rope(q, pp, cfg.rope_theta)
            k = attn.rope(k, pp, cfg.rope_theta)
            new_cache = attn.cache_update(cache, k, v, pos)
            o = attn.decode_attention(
                q, new_cache, pos, n_kv=Kv, window=window, cap=cfg.attn_softcap
            )
            return o.reshape(B, 1, H * Dh) @ p.wo, new_cache
        chunked = S >= cfg.attn_chunk_threshold
        out = attn.attn_forward(
            lp["attn"], x,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hdim,
            rope_theta=cfg.rope_theta, causal=True, window=window,
            cap=cfg.attn_softcap, positions=positions, chunked=chunked,
            q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk,
            schedule=cfg.attn_schedule,
        )
        if mode == "prefill":
            p = lp["attn"]
            B = x.shape[0]
            k = (x @ p.wk).reshape(B, S, cfg.n_kv_heads, cfg.hdim)
            v = (x @ p.wv).reshape(B, S, cfg.n_kv_heads, cfg.hdim)
            k = attn.rope(k, positions, cfg.rope_theta)
            new_cache = attn.KVCache(k=k, v=v)
            return out, new_cache
        return out, None
    if mixer == "M":
        if mode == "decode" or mode == "prefill":
            out, new_state = ssmm.ssm_forward(
                lp["ssm"], x, d_model=cfg.d_model, ssm_cfg=cfg.ssm,
                state=cache, return_state=True,
            )
            return out, new_state
        return ssmm.ssm_forward(lp["ssm"], x, d_model=cfg.d_model, ssm_cfg=cfg.ssm), None
    if mixer == "R":
        if mode == "decode" or mode == "prefill":
            out, new_state = rglrum.rglru_forward(
                lp["lru"], x, state=cache, return_state=True
            )
            return out, new_state
        return rglrum.rglru_forward(lp["lru"], x), None
    raise ValueError(mixer)


def _apply_layer(
    cfg: ModelConfig,
    mixer: str,
    ffn: str,
    lp: Dict,
    x: jax.Array,
    *,
    positions,
    mode: str,
    cache,
    pos,
    enc_out=None,
):
    """Returns (x, new_cache, aux_loss)."""
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    h, new_cache = _apply_mixer(
        cfg, mixer, lp, h, positions=positions, mode=mode, cache=cache, pos=pos
    )
    if cfg.post_norms:
        h = rms_norm(h, lp["post_norm1"], cfg.norm_eps)
    x = x + h
    if "cross" in lp and enc_out is not None:
        hc = rms_norm(x, lp["cross_norm"], cfg.norm_eps)
        p: attn.AttnParams = lp["cross"]
        B, S, _ = hc.shape
        F = enc_out.shape[1]
        k = (enc_out @ p.wk).reshape(B, F, cfg.n_kv_heads, cfg.hdim)
        v = (enc_out @ p.wv).reshape(B, F, cfg.n_kv_heads, cfg.hdim)
        hc = attn.attn_forward(
            p, hc, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hdim,
            rope_theta=cfg.rope_theta, causal=False, positions=positions,
            use_rope=False, kv_override=(k, v),
        )
        x = x + hc
    aux = jnp.zeros((), jnp.float32)
    if ffn != "N":
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        if ffn == "E":
            h2, aux = moem.moe_forward(
                lp["ffn"], h2, top_k=cfg.moe.top_k,
                capacity_factor=cfg.moe.capacity_factor, activation=cfg.activation,
                shards=cfg.moe_shards,
            )
        else:
            h2 = mlpm.mlp_forward(lp["ffn"], h2, cfg.activation)
        if cfg.post_norms:
            h2 = rms_norm(h2, lp["post_norm2"], cfg.norm_eps)
        x = x + h2
    return ax(x, "batch", "seq_shard", None), new_cache, aux


# ---------------------------------------------------------------------------
# Stack application (scan over groups)
# ---------------------------------------------------------------------------


def _apply_stack(cfg, params, x, *, positions, mode, caches, pos, enc_out):
    period, n_groups, n_rem = _groups(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    def group_body(x, gp, gcache):
        new_caches = {}
        aux = jnp.zeros((), jnp.float32)
        for i in range(period):
            c = gcache.get(f"l{i}") if gcache else None
            x, nc, a = _apply_layer(
                cfg, cfg.mixer_at(i), cfg.ffn_at(i), gp[f"l{i}"], x,
                positions=positions, mode=mode, cache=c, pos=pos, enc_out=enc_out,
            )
            new_caches[f"l{i}"] = nc
            aux = aux + a
        return x, new_caches, aux

    have_caches = caches is not None
    if n_groups:
        K = cfg.remat_group if (mode == "train" and n_groups % cfg.remat_group == 0) else 1

        def super_body(x, gps, gcaches):
            """K consecutive layer-groups under one checkpoint span."""
            new_caches = None
            aux = jnp.zeros((), jnp.float32)
            for k in range(K):
                gp = jax.tree_util.tree_map(lambda t: t[k], gps)
                gc = (jax.tree_util.tree_map(lambda t: t[k], gcaches)
                      if gcaches is not None else None)
                x, nc, a = group_body(x, gp, gc)
                aux = aux + a
            return x, new_caches, aux

        def scan_body(carry, xs):
            x, aux_t = carry
            if have_caches:
                gp, gcache = xs
            else:
                gp, gcache = xs, None
            if K > 1:
                body = super_body
                if cfg.remat == "layer" and mode == "train":
                    body = jax.checkpoint(super_body)
                x, new_caches, aux = body(x, gp, gcache)
            else:
                body = group_body
                if cfg.remat == "layer" and mode == "train":
                    body = jax.checkpoint(group_body)
                x, new_caches, aux = body(x, gp, gcache)
            return (x, aux_t + aux), new_caches

        xs = (params["groups"], caches["groups"]) if have_caches else params["groups"]
        if K > 1:
            xs = jax.tree_util.tree_map(
                lambda t: t.reshape((n_groups // K, K) + t.shape[1:]), xs
            )
        (x, aux_total), new_group_caches = jax.lax.scan(
            scan_body, (x, aux_total), xs,
            unroll=(n_groups // K) if cfg.scan_unroll else 1,
        )
    else:
        new_group_caches = None

    new_caches = {"groups": new_group_caches}
    for r in range(n_rem):
        li = n_groups * period + r
        c = caches.get(f"rem{r}") if caches else None
        x, nc, a = _apply_layer(
            cfg, cfg.mixer_at(li), cfg.ffn_at(li), params[f"rem{r}"], x,
            positions=positions, mode=mode, cache=c, pos=pos, enc_out=enc_out,
        )
        new_caches[f"rem{r}"] = nc
        aux_total = aux_total + a
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def encode(cfg: ModelConfig, params, frames: jax.Array) -> jax.Array:
    """Whisper-style encoder over precomputed frame embeddings (stub
    frontend): frames (B, F, D)."""
    x = frames + params["enc_pos"][None]
    F = x.shape[1]
    positions = jnp.arange(F)[None]

    def body(x, lp):
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        h = attn.attn_forward(
            lp["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.hdim, rope_theta=cfg.rope_theta, causal=False,
            positions=positions, use_rope=False,
        )
        x = x + h
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + mlpm.mlp_forward(lp["ffn"], h2, "gelu")
        return x, None

    x, _ = jax.lax.scan(
        body, x, params["enc_layers"],
        unroll=cfg.encoder.n_layers if cfg.scan_unroll else 1,
    )
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def forward(
    cfg: ModelConfig,
    params,
    tokens: jax.Array,                     # (B, S)
    *,
    patch_embeds: Optional[jax.Array] = None,   # (B, n_patches, D) VLM stub
    enc_frames: Optional[jax.Array] = None,     # (B, F, D) audio stub
    mode: str = "train",
    caches=None,
    pos=None,
):
    """Returns (hidden (B,S,D), new_caches, aux_loss)."""
    x = embed(tokens, params["embed"], scale=cfg.embed_scale)
    if patch_embeds is not None:
        np_ = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x[:, np_:]], axis=1)
    enc_out = None
    if cfg.encoder is not None and enc_frames is not None:
        enc_out = encode(cfg, params, enc_frames)
    S = tokens.shape[1]
    positions = (
        jnp.arange(S)[None] if pos is None else jnp.full((1, S), pos)
    )
    x, new_caches, aux = _apply_stack(
        cfg, params, x, positions=positions, mode=mode, caches=caches,
        pos=pos, enc_out=enc_out,
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_caches, aux


def logits_fn(cfg: ModelConfig, params, hidden: jax.Array) -> jax.Array:
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"].T
    logits = jnp.einsum("bsd,vd->bsv", hidden, table.astype(hidden.dtype))
    logits = ax(logits, "batch", None, "vocab")
    return softcap(logits.astype(jnp.float32), cfg.logit_softcap)


def loss_fn(cfg: ModelConfig, params, batch: Dict) -> Tuple[jax.Array, Dict]:
    """Chunked-CE training loss. batch: tokens (B,S), labels (B,S) plus
    optional modality stubs."""
    hidden, _, aux = forward(
        cfg, params, batch["tokens"],
        patch_embeds=batch.get("patch_embeds"),
        enc_frames=batch.get("enc_frames"),
        mode="train",
    )
    B, S, D = hidden.shape
    labels = batch["labels"]
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"].T

    N = B * S
    hf = hidden.reshape(N, D)
    lf = labels.reshape(N)
    chunk = min(cfg.loss_chunk, N)
    n_chunks = max(N // chunk, 1)
    assert N % chunk == 0 or n_chunks == 1, (N, chunk)

    def ce_chunk(h, l):
        logits = jnp.einsum("nd,vd->nv", h, table.astype(h.dtype))
        logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
        logits = ax(logits, None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, l[:, None], axis=-1)[:, 0]
        return jnp.sum(lse - picked)

    if n_chunks == 1:
        total = ce_chunk(hf, lf)
    else:
        def body(acc, xs):
            h, l = xs
            return acc + ce_chunk(h, l), None
        total, _ = jax.lax.scan(
            body, jnp.zeros((), jnp.float32),
            (hf.reshape(n_chunks, chunk, D), lf.reshape(n_chunks, chunk)),
            unroll=n_chunks if cfg.scan_unroll else 1,
        )
    loss = total / N + 0.01 * aux
    return loss, {"ce": total / N, "aux": aux}


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, seq_len: int):
    """Zero caches for decode at cache length ``seq_len`` (sliding-window
    layers get a rolling cache of window size)."""
    period, n_groups, n_rem = _groups(cfg)
    dtype = cfg.jdtype

    def layer_cache(mixer):
        if mixer == "G":
            S_c = seq_len
        elif mixer == "L":
            S_c = min(cfg.sliding_window, seq_len)
        elif mixer == "M":
            s = cfg.ssm
            d_inner = s.expand * cfg.d_model
            H = d_inner // s.head_dim
            conv_dim = d_inner + 2 * s.n_groups * s.d_state
            return ssmm.SSMState(
                h=jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
                conv=jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
            )
        elif mixer == "R":
            W = (cfg.rglru.lru_width or cfg.d_model)
            return rglrum.RGLRUState(
                h=jnp.zeros((batch, W), jnp.float32),
                conv=jnp.zeros((batch, cfg.rglru.conv_width - 1, W), dtype),
            )
        else:
            raise ValueError(mixer)
        return attn.KVCache(
            k=jnp.zeros((batch, S_c, cfg.n_kv_heads, cfg.hdim), dtype),
            v=jnp.zeros((batch, S_c, cfg.n_kv_heads, cfg.hdim), dtype),
        )

    def group_caches(_):
        return {f"l{i}": layer_cache(cfg.mixer_at(i)) for i in range(period)}

    caches = {}
    if n_groups:
        caches["groups"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape).copy()
            if hasattr(x, "shape") else x,
            group_caches(0),
        )
    for r in range(n_rem):
        li = n_groups * period + r
        caches[f"rem{r}"] = layer_cache(cfg.mixer_at(li))
    return caches


def decode_step(
    cfg: ModelConfig,
    params,
    caches,
    token: jax.Array,   # (B, 1)
    pos: jax.Array,     # ()
    *,
    enc_out: Optional[jax.Array] = None,
):
    """One token of cached decoding. Returns (logits (B,1,V), new_caches)."""
    x = embed(token, params["embed"], scale=cfg.embed_scale)
    x, new_caches, _ = _apply_stack(
        cfg, params, x, positions=jnp.full((1, 1), pos), mode="decode",
        caches=caches, pos=pos, enc_out=enc_out,
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return logits_fn(cfg, params, x), new_caches
