"""Mamba2 SSD (state-space duality) block — attention-free token mixer.

Chunked SSD algorithm (Dao & Gu 2024, arXiv:2405.21060), matmul form:
within-chunk "attention-like" term + inter-chunk state recurrence carried by
a scan — this is the TPU-friendly formulation (all MXU work, O(S) memory).

Decode maintains the per-head state h (B, H, P, N) and the conv window.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import ax


class SSMParams(NamedTuple):
    w_in: jax.Array      # (D, d_inner*2 + 2*G*N + H)  fused input projection
    conv_w: jax.Array    # (conv_width, conv_dim) depthwise conv
    A_log: jax.Array     # (H,)
    Dskip: jax.Array     # (H,)
    dt_bias: jax.Array   # (H,)
    norm_scale: jax.Array  # (d_inner,)
    w_out: jax.Array     # (d_inner, D)


class SSMState(NamedTuple):
    h: jax.Array         # (B, H, P, N) SSD state
    conv: jax.Array      # (B, conv_width-1, conv_dim) conv tail


def _dims(cfg_d_model: int, ssm) -> Tuple[int, int, int, int, int]:
    d_inner = ssm.expand * cfg_d_model
    H = d_inner // ssm.head_dim
    return d_inner, H, ssm.head_dim, ssm.n_groups, ssm.d_state


def ssd_chunked(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H)   (post-softplus)
    A: jax.Array,    # (H,) negative
    Bm: jax.Array,   # (B, S, G, N)
    Cm: jax.Array,   # (B, S, G, N)
    chunk: int,
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), h_final (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G

    xc = x.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = jnp.repeat(Bm.reshape(Bsz, nc, chunk, G, N), rep, axis=3)  # (B,nc,c,H,N)
    Cc = jnp.repeat(Cm.reshape(Bsz, nc, chunk, G, N), rep, axis=3)

    dA = dtc * A[None, None, None, :]            # (B,nc,c,H) negative increments
    cums = jnp.cumsum(dA, axis=2)                 # within-chunk cumulative
    seg_end = cums[:, :, -1, :]                   # (B,nc,H) total chunk decay

    # within-chunk (lower-triangular "attention" with decay kernel)
    # L[s,t] = exp(cums[s] - cums[t]) for s >= t
    diff = cums[:, :, :, None, :] - cums[:, :, None, :, :]  # (B,nc,s,t,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    L = jnp.where(tri, jnp.exp(diff), 0.0)
    # scores[s,t] = C_s . B_t
    scores = jnp.einsum("bqchn,bqthn->bqcth", Cc, Bc.reshape(Bsz, nc, chunk, H, N))
    # y_intra[s] = sum_t L[s,t] * scores[s,t] * dt_t * x_t
    y_intra = jnp.einsum("bqcth,bqth,bqthp->bqchp", scores * L, dtc, xc)

    # chunk state contributions: state_c = sum_t exp(seg_end - cums[t]) dt_t B_t x_t^T
    decay_to_end = jnp.exp(seg_end[:, :, None, :] - cums)    # (B,nc,c,H)
    states = jnp.einsum(
        "bqth,bqth,bqthp,bqthn->bqhpn", decay_to_end, dtc, xc,
        Bc.reshape(Bsz, nc, chunk, H, N),
    )  # (B,nc,H,P,N)

    # inter-chunk recurrence over nc
    def scan_fn(h, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        h_new = h * jnp.exp(dec)[:, :, None, None] + st
        return h_new, h

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    seg = seg_end.transpose(1, 0, 2)  # (nc,B,H)
    sts = states.transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    h_final, h_prev = jax.lax.scan(scan_fn, h0, (sts, seg))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N) state entering chunk

    # inter-chunk output: y_inter[s] = exp(cums[s]) * C_s . h_prev
    y_inter = jnp.einsum(
        "bqchn,bqhpn->bqchp",
        jnp.exp(cums)[..., None] * Cc.reshape(Bsz, nc, chunk, H, N),
        h_prev.astype(Cc.dtype),
    )

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), h_final


def ssm_forward(
    p: SSMParams,
    x: jax.Array,   # (B, S, D)
    *,
    d_model: int,
    ssm_cfg,
    state: Optional[SSMState] = None,
    return_state: bool = False,
):
    """Full Mamba2 block: in-proj -> conv -> SSD -> gated norm -> out-proj."""
    B, S, D = x.shape
    d_inner, H, P, G, N = _dims(d_model, ssm_cfg)
    conv_dim = d_inner + 2 * G * N

    zxbcdt = x @ p.w_in
    z, xbc, dt_raw = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)

    # depthwise causal conv over (x, B, C) features
    cw = p.conv_w.shape[0]
    if state is not None:
        xbc_in = jnp.concatenate([state.conv, xbc], axis=1)
        new_conv_tail = xbc_in[:, -(cw - 1):]
    else:
        xbc_in = jnp.pad(xbc, ((0, 0), (cw - 1, 0), (0, 0)))
        new_conv_tail = xbc_in[:, -(cw - 1):]
    # depthwise causal conv as cw shifted multiply-adds (materializing the
    # (B, S, cw, conv_dim) window tensor costs GiBs at production shapes)
    acc = jnp.zeros_like(xbc)
    for c in range(cw):
        acc = acc + xbc_in[:, c : c + S] * p.conv_w[c][None, None, :]
    xbc_conv = jax.nn.silu(acc)

    xs, Bm, Cm = jnp.split(xbc_conv, [d_inner, d_inner + G * N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    xs = ax(xs, "batch", None, "ssm_heads", None)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p.dt_bias)  # (B,S,H)
    A = -jnp.exp(p.A_log.astype(jnp.float32))

    h0 = state.h if state is not None else None
    if S == 1 and state is not None:
        # decode fast path: one recurrence step, no chunking
        dA = jnp.exp(dt[:, 0] * A[None, :])                    # (B,H)
        inc = jnp.einsum(
            "bh,bhp,bhn->bhpn", dt[:, 0], xs[:, 0].astype(jnp.float32),
            jnp.repeat(Bm[:, 0], H // G, axis=1).astype(jnp.float32),
        )
        h_new = state.h * dA[:, :, None, None] + inc
        y = jnp.einsum(
            "bhn,bhpn->bhp", jnp.repeat(Cm[:, 0], H // G, axis=1).astype(jnp.float32),
            h_new,
        )[:, None]  # (B,1,H,P)
        y = y.astype(x.dtype)
        h_final = h_new
    else:
        y, h_final = ssd_chunked(xs, dt, A, Bm, Cm, min(ssm_cfg.chunk, S), h0)

    y = y + xs * p.Dskip[None, None, :, None]
    y = y.reshape(B, S, d_inner)
    # gated RMSNorm (mamba2 style)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * (1.0 + p.norm_scale.astype(jnp.float32))
    out = yf.astype(x.dtype) @ p.w_out
    if return_state:
        return out, SSMState(h=h_final, conv=new_conv_tail)
    return out
