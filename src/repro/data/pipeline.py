"""Deterministic synthetic data pipeline.

Requirements it satisfies (they are what make checkpoint/restart and REBUILD
recovery *exact*):
  * stateless addressing: batch(step) is a pure function of (seed, step) —
    replay after restore reproduces the byte-identical stream;
  * shard-aware: each host materializes only its slice (process_index based;
    a single-process run owns everything);
  * background prefetch with a bounded queue.

Two sources:
  * ``lm_synthetic`` — structured pseudo-text: a mixture of repeated n-grams
    and noise so a real model can actually reduce loss on it (used by the
    trainability integration test and the quickstart example);
  * ``uniform`` — pure uniform tokens (throughput/benchmark use).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "lm_synthetic"  # lm_synthetic | uniform
    ngram: int = 16             # period of the synthetic structure


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))


def make_batch(cfg: DataConfig, step: int, *, lo: int = 0, hi: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Batch rows [lo, hi) of global step ``step`` (host sharding)."""
    hi = cfg.global_batch if hi is None else hi
    rng = _batch_rng(cfg, step)
    B, S = cfg.global_batch, cfg.seq_len
    if cfg.kind == "uniform":
        toks = rng.integers(0, cfg.vocab, (B, S + 1), dtype=np.int64)
    else:
        # a fixed (per-seed) bank of n-grams, tiled with 5% per-step noise:
        # the base patterns are step-independent so the structure is
        # learnable in tens of steps, while the noise keeps batches distinct.
        base_rng = np.random.default_rng(np.random.SeedSequence([cfg.seed]))
        bank = base_rng.integers(0, cfg.vocab, (8, cfg.ngram), dtype=np.int64)
        pick = rng.integers(0, bank.shape[0], (B,))
        base = bank[pick]
        reps = (S + 1 + cfg.ngram - 1) // cfg.ngram
        toks = np.tile(base, (1, reps))[:, : S + 1]
        noise_mask = rng.random((B, S + 1)) < 0.05
        noise = rng.integers(0, cfg.vocab, (B, S + 1), dtype=np.int64)
        toks = np.where(noise_mask, noise, toks)
    toks = toks[lo:hi]
    return {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
    }


class Pipeline:
    """Prefetching iterator over deterministic steps; resumable via
    ``start_step`` (checkpoint restore passes the step it restored)."""

    def __init__(
        self,
        cfg: DataConfig,
        start_step: int = 0,
        prefetch: int = 2,
        process_index: int = 0,
        process_count: int = 1,
    ):
        self.cfg = cfg
        assert cfg.global_batch % process_count == 0
        per = cfg.global_batch // process_count
        self._lo = process_index * per
        self._hi = self._lo + per
        self._step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, step, lo=self._lo, hi=self._hi)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        while True:
            step, batch = self._q.get()
            if step < self._step:
                continue  # stale prefetch after a seek
            self._step = step + 1
            return step, batch

    def close(self):
        self._stop.set()
