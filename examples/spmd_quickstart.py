"""Production-path quickstart: the FT-CAQR sweep under shard_map.

Runs the same windowed FT sweep as ``examples/quickstart.py``, but on the
paper's native execution model: a 1-D device mesh, one process (lane) per
device, every exchange a real collective — then kills a lane mid-sweep,
REBUILDs it from its re-read input slice plus single-source buddy fetches,
and checks the result bit-for-bit against the single-device SimComm run of
the same schedule.

On a CPU host this forces a 4-device platform via XLA_FLAGS (must happen
before jax initializes — which is why the env var is set at the very top);
on a real TPU slice drop that line and the mesh spans the chips.

    PYTHONPATH=src python examples/spmd_quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import SimComm
from repro.ft import FailureSchedule, ft_caqr_sweep, sweep_point
from repro.launch.spmd_qr import ft_caqr_sweep_spmd, make_lane_mesh


def main():
    P, m_loc, n, b = 4, 6, 10, 4   # ragged: unaligned lanes + ragged panel
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((P * m_loc, n)), jnp.float32)

    mesh = make_lane_mesh(P)
    print(f"mesh: {mesh.shape} over {len(jax.devices())} devices")

    # kill lane 2 after panel 1's level-0 trailing combine
    sched = FailureSchedule(events={sweep_point(1, "trailing", 0): [2]})

    spmd = ft_caqr_sweep_spmd(A, b, schedule=sched, mesh=mesh)
    (event,) = spmd.events
    print(f"killed lane {event.lane} at {event.point}; REBUILD read "
          f"{len(event.reads)} artifacts from survivors {event.sources}")

    sim = ft_caqr_sweep(A.reshape(P, m_loc, n), SimComm(P), b, schedule=sched)
    for name, g, s in [
        ("R", spmd.R, sim.R),
        ("factors", spmd.factors, sim.factors),
        ("bundles", spmd.bundles, sim.bundles),
    ]:
        gl = jax.tree_util.tree_leaves(g)
        sl = jax.tree_util.tree_leaves(s)
        ok = all(np.array_equal(np.asarray(x), np.asarray(y))
                 for x, y in zip(gl, sl))
        print(f"{name}: shard_map == SimComm bitwise: {ok}")
        assert ok, name

    # the R is the R: cross-check against numpy at float tolerance
    R_np = np.linalg.qr(np.asarray(A), mode="r")
    sgn = np.sign(np.diag(R_np)) * np.sign(np.diag(np.asarray(spmd.R[0])))
    err = np.abs(np.asarray(spmd.R[0]) * sgn[:, None] - R_np).max()
    print(f"max |R - R_numpy| (sign-fixed): {err:.2e}")
    assert err < 1e-4
    print("SPMD quickstart OK")


if __name__ == "__main__":
    main()
