"""Fault-tolerant training demo: lanes die mid-run under each FT-MPI
semantics (paper SS II) and training continues — REBUILD provably
bit-identical to the failure-free run.

Run: PYTHONPATH=src python examples/failure_recovery_training.py
"""
import jax
import numpy as np

from repro.configs import get_smoke
from repro.data.pipeline import DataConfig
from repro.ft.failures import FailureSchedule
from repro.ft.semantics import Semantics
from repro.train import TrainConfig, Trainer

cfg = get_smoke("tinyllama-1.1b")
dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=7)

print("=== reference run (no failures) ===")
ref = Trainer(cfg, TrainConfig(steps=40, lr=8e-3, warmup=5, n_lanes=4,
                               diskless_every=5, log_every=10), dcfg)
ref.run()

print("\n=== REBUILD: lane 2 dies at step 23, restored from its buddy ===")
reb = Trainer(cfg, TrainConfig(steps=40, lr=8e-3, warmup=5, n_lanes=4,
                               diskless_every=5, log_every=10,
                               semantics=Semantics.REBUILD), dcfg)
reb.run(FailureSchedule(events={23: [2]}))
same = all(
    np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(ref.state.params),
                    jax.tree_util.tree_leaves(reb.state.params))
)
print(f"REBUILD final params bit-identical to failure-free run: {same}")

print("\n=== SHRINK: lane 1 dies at step 15, world shrinks to 3 lanes ===")
shr = Trainer(cfg, TrainConfig(steps=40, lr=8e-3, warmup=5, n_lanes=4,
                               diskless_every=5, log_every=10,
                               semantics=Semantics.SHRINK), dcfg)
hist = shr.run(FailureSchedule(events={15: [1]}))
print(f"continued with {hist[-1]['lanes']} lanes, final loss {hist[-1]['loss']:.4f}")
