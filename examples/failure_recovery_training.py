"""Fault-tolerant demos: the paper's algorithm and the training loop both
survive lane deaths under each FT-MPI semantics (paper §II), with REBUILD
provably bit-identical to the failure-free run.

Part 1 drives the paper's actual workload — the windowed FT-CAQR sweep —
under a failure schedule via ``repro.ft.driver``: lanes die at scheduled
tree levels of scheduled panels, each is rebuilt from its re-read initial
slice plus single-source buddy fetches, and the finished factorization is
checked bit-for-bit against the failure-free sweep.

Parts 2/3 show the same semantics on the training loop (REBUILD / SHRINK).

Run: PYTHONPATH=src python examples/failure_recovery_training.py [--steps N]
(--steps 8 is the CI smoke setting; default 40 shows real convergence)
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core import SimComm, caqr_factorize
from repro.data.pipeline import DataConfig
from repro.ft import FailureSchedule, ft_caqr_sweep, sweep_point
from repro.ft.semantics import Semantics
from repro.train import TrainConfig, Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=40)
args = ap.parse_args()

# === 1. FT-CAQR sweep: lanes die mid-factorization, REBUILD finishes =======
# b=4 / m_loc=8 tiles are the CPU-XLA bitwise-stable envelope (same
# geometry as examples/online_recovery.py), so the bit-identity below is
# asserted, not just printed
P, m_loc, n, b = 4, 8, 32, 4
rng = np.random.default_rng(0)
A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
comm = SimComm(P)

print(f"=== FT-CAQR sweep: {P*m_loc}x{n}, {n//b} panels, {P} lanes ===")
ref = caqr_factorize(A, comm, b, collect_bundles=True, use_scan=False)
schedule = FailureSchedule(events={
    sweep_point(2, "trailing", 1): [2],  # mid trailing-combine tree
    sweep_point(5, "tsqr", 0): [1],      # mid TSQR butterfly, later panel
    sweep_point(7, "leaf"): [2],         # same lane dies a second time
})
res = ft_caqr_sweep(A, comm, b, schedule=schedule)
for e in res.events:
    print(f"  death at panel {e.point[0]} ({e.point[1]} level {e.point[2]}): "
          f"lane {e.lane} rebuilt from survivors {e.sources} in "
          f"{e.elapsed_s*1e3:.0f}ms ({len(e.reads)} single-source fetches)")
identical = all(
    np.array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(
        jax.tree_util.tree_leaves((res.R, res.factors, res.bundles)),
        jax.tree_util.tree_leaves((ref.R, ref.factors, ref.bundles)),
    )
)
print(f"R + factors + bundles bit-identical to failure-free sweep: {identical}")
assert identical, "REBUILD must be bit-identical to the failure-free sweep"

# === 2. training under REBUILD =============================================
cfg = get_smoke("tinyllama-1.1b")
dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=7)
steps = args.steps
fail_step = max(1, steps // 2)

print(f"\n=== reference training run ({steps} steps, no failures) ===")
ref_tr = Trainer(cfg, TrainConfig(steps=steps, lr=8e-3, warmup=5, n_lanes=4,
                                  diskless_every=5, log_every=10), dcfg)
ref_tr.run()

print(f"\n=== REBUILD: lane 2 dies at step {fail_step}, "
      f"restored from its buddy ===")
reb = Trainer(cfg, TrainConfig(steps=steps, lr=8e-3, warmup=5, n_lanes=4,
                               diskless_every=5, log_every=10,
                               semantics=Semantics.REBUILD), dcfg)
reb.run(FailureSchedule(events={fail_step: [2]}))
same = all(
    np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(ref_tr.state.params),
                    jax.tree_util.tree_leaves(reb.state.params))
)
print(f"REBUILD final params bit-identical to failure-free run: {same}")

# === 3. training under SHRINK ==============================================
print(f"\n=== SHRINK: lane 1 dies at step {max(1, steps // 3)}, "
      f"world shrinks to 3 lanes ===")
shr = Trainer(cfg, TrainConfig(steps=steps, lr=8e-3, warmup=5, n_lanes=4,
                               diskless_every=5, log_every=10,
                               semantics=Semantics.SHRINK), dcfg)
hist = shr.run(FailureSchedule(events={max(1, steps // 3): [1]}))
print(f"continued with {hist[-1]['lanes']} lanes, "
      f"final loss {hist[-1]['loss']:.4f}")
