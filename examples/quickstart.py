"""Quickstart: FT-CAQR of a general matrix + recovery from a lane failure.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import SimComm, caqr_factorize, ft_tsqr
from repro.core import recovery as rec

# --- 1. QR of a general matrix, distributed over 8 lanes -------------------
P, m_loc, n, b = 8, 64, 256, 16
rng = np.random.default_rng(0)
A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)

res = caqr_factorize(A, SimComm(P), panel_width=b)
R = np.asarray(res.R[0])
R_ref = np.linalg.qr(np.asarray(A).reshape(-1, n), mode="r")
err = np.abs(np.abs(R) - np.abs(R_ref)).max() / np.abs(R_ref).max()
print(f"FT-CAQR of {P*m_loc}x{n} matrix on {P} lanes: |R - R_lapack| rel = {err:.2e}")
print(f"R replicated on all lanes: {bool(np.all(np.asarray(res.R) == R))}")

# --- 2. kill a lane mid-update; recover from ONE buddy ----------------------
comm = SimComm(P)
panel = jnp.asarray(rng.standard_normal((P, m_loc, b)), jnp.float32)
trailing = jnp.asarray(rng.standard_normal((P, m_loc, 32)), jnp.float32)
fac = ft_tsqr(panel, comm)
clean = rec.run_ft_trailing(trailing, fac, comm)
faulty = rec.run_ft_trailing(
    trailing, fac, comm, fail_at_level=1, failed_lane=3, A_stacked=trailing
)
print(f"recovery after killing lane 3 at tree level 1: "
      f"bitwise-equal={np.array_equal(np.asarray(clean), np.asarray(faulty))}")

# --- 3. the full sweep under a failure schedule (end-to-end REBUILD) --------
from repro.ft import FailureSchedule, ft_caqr_sweep, sweep_point

n_small = 64  # 4 panels, 3 tree levels
A_small = A[:, :, :n_small]
ref = caqr_factorize(A_small, SimComm(P), panel_width=b, use_scan=False,
                     collect_bundles=True)
schedule = FailureSchedule(events={
    sweep_point(1, "trailing", 2): [3],   # lane 3 dies mid trailing tree
    sweep_point(3, "tsqr", 0): [5],       # lane 5 dies mid TSQR, last panel
})
res_ft = ft_caqr_sweep(A_small, SimComm(P), panel_width=b, schedule=schedule)
print(f"sweep with {len(res_ft.events)} lane deaths: R bitwise-equal to "
      f"failure-free={np.array_equal(np.asarray(res_ft.R), np.asarray(ref.R))}")
for e in res_ft.events:
    print(f"  panel {e.point[0]} {e.point[1]} level {e.point[2]}: lane "
          f"{e.lane} rebuilt from survivors {e.sources} "
          f"({len(e.reads)} single-source fetches, {e.elapsed_s*1e3:.0f}ms)")
