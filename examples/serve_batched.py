"""Batched serving: train briefly, then serve batched requests through the
prefill + cached-decode engine (rolling caches on sliding-window archs).

Run: PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np

from repro.configs import get_smoke
from repro.data.pipeline import DataConfig, make_batch
from repro.serve import Engine, ServeConfig
from repro.train import TrainConfig, Trainer

cfg = get_smoke("tinyllama-1.1b")
dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=16, seed=0)
trainer = Trainer(cfg, TrainConfig(steps=120, lr=1e-2, warmup=10, n_lanes=2,
                                   log_every=40), dcfg)
trainer.run()

engine = Engine(cfg, trainer.state.params, ServeConfig(max_new_tokens=24))
# prompts drawn from the training distribution: the model should continue
# the periodic pattern
batch = make_batch(dcfg, step=10_000)
prompts = batch["tokens"][:4, :32]
out = engine.generate(prompts)
# greedy next-token semantics: out[:, t] is the model's prediction of
# position 32 + t, so it compares against tokens[:, 32 : 32 + len] with NO
# extra shift (the previous off-by-one compared predictions against the
# position after the one they predict, understating accuracy)
match = (out == np.asarray(batch["tokens"][:4, 32 : 32 + out.shape[1]])).mean()
print(f"generated {out.shape} tokens; continuation accuracy vs pattern: {match:.2f}")
print(out[0])
# the data is an ngram-16 pattern bank with 5% label noise: a trained model
# should track the period far above chance — fail loudly if generation
# regresses instead of printing a meaningless number
assert match >= 0.5, f"continuation accuracy {match:.2f} < 0.5"
print("continuation accuracy OK (>= 0.5)")
