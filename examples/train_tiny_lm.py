"""End-to-end driver: train a ~1M-param llama-family model on the
deterministic synthetic pipeline under the FT training runtime
(DESIGN.md §14) — the CAQR-Muon optimizer's orthogonalization sweeps run
through the fault-tolerant QR engine, and a lane is killed INSIDE one of
those optimizer-internal sweeps mid-run. The run heals in place via
REBUILD and finishes with params and loss curve bitwise-identical to a
failure-free reference, which this script asserts.

Run: PYTHONPATH=src python examples/train_tiny_lm.py [--steps 12]
     PYTHONPATH=src python examples/train_tiny_lm.py --plain   # legacy
                                   # Trainer path: in-jit TSQR orth, no
                                   # FT engine, no kill
"""
import argparse

import jax
import numpy as np

from repro.configs import get_smoke
from repro.data.pipeline import DataConfig
from repro.ft.semantics import Semantics
from repro.train import TrainConfig, Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=12)
ap.add_argument("--optimizer", default="caqr_muon",
                choices=["adamw", "caqr_muon"])
ap.add_argument("--plain", action="store_true",
                help="legacy Trainer path (optimizer-internal QR stays "
                     "in-jit; no FT engine, no kill demo)")
ap.add_argument("--kill-step", type=int, default=1,
                help="training step whose optimizer sweep gets the kill")
ap.add_argument("--kill-lane", type=int, default=2)
args = ap.parse_args()

cfg = get_smoke("tinyllama-1.1b")
dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=16, seed=0)

if args.plain:
    tcfg = TrainConfig(steps=args.steps, lr=1e-2, warmup=20, n_lanes=4,
                       diskless_every=10, log_every=25,
                       optimizer=args.optimizer)
    trainer = Trainer(cfg, tcfg, dcfg)
    hist = trainer.run()
    print(f"\nfinal loss {hist[-1]['loss']:.4f} "
          f"(start {hist[0]['loss']:.4f})")
    raise SystemExit(0)

from repro.train.ftrun import FTTrainer, StepSweepKiller  # noqa: E402

tcfg = TrainConfig(steps=args.steps, lr=1e-2, warmup=4, n_lanes=4,
                   diskless_every=5, log_every=5,
                   semantics=Semantics.REBUILD, optimizer=args.optimizer)

print("== failure-free reference ==")
ref = FTTrainer(cfg, tcfg, dcfg)
hist_ref = ref.run()

print(f"\n== same run, lane {args.kill_lane} killed inside the "
      f"optimizer-internal sweep of step {args.kill_step} ==")
killer = StepSweepKiller(at_step=args.kill_step, lane=args.kill_lane)
tr = FTTrainer(cfg, tcfg, dcfg, qr_fault_hooks=[killer])
hist = tr.run()

assert killer.fired, "the kill never landed inside an optimizer sweep"
step, task, point = killer.struck
print(f"\nkill struck step {step}, task {task}, sweep point {point}; "
      f"REBUILD healed it in place")

leaves = zip(jax.tree_util.tree_leaves(ref.state.params),
             jax.tree_util.tree_leaves(tr.state.params))
assert all(np.array_equal(np.asarray(a), np.asarray(b)) for a, b in leaves), \
    "killed-run params differ from failure-free"
assert [h["loss"] for h in hist_ref] == [h["loss"] for h in hist], \
    "killed-run loss curve differs from failure-free"
assert [h["step"] for h in hist] == list(range(tcfg.steps)), \
    "training-level rewind happened — the sweep-level heal should hide it"

print(f"final loss {hist[-1]['loss']:.4f} (start {hist[0]['loss']:.4f})")
print("params + loss curve bitwise-identical to failure-free; "
      "no training-level rewind")
