"""End-to-end driver: train a ~1M-param llama-family model for a few hundred
steps on the deterministic synthetic pipeline, with diskless checkpoints and
the CAQR-Muon (TSQR-orthogonalized) optimizer.

Run: PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]
"""
import argparse

from repro.configs import get_smoke
from repro.data.pipeline import DataConfig
from repro.train import TrainConfig, Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--optimizer", default="caqr_muon", choices=["adamw", "caqr_muon"])
args = ap.parse_args()

cfg = get_smoke("tinyllama-1.1b")
dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=16, seed=0)
tcfg = TrainConfig(steps=args.steps, lr=1e-2, warmup=20, n_lanes=4,
                   diskless_every=10, log_every=25, optimizer=args.optimizer)
trainer = Trainer(cfg, tcfg, dcfg)
hist = trainer.run()
print(f"\nfinal loss {hist[-1]['loss']:.4f} (start {hist[0]['loss']:.4f})")
