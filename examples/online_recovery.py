"""Online recovery demo: a lane dies at a wall-clock-chosen moment, the
NaN-sentinel detector *discovers* it (nothing is scripted into the traced
program), the orchestrator synthesizes the REBUILD, and the finished
factorization is bit-identical to the failure-free sweep.

This is the paper's actual execution model (§II): failures happen at
arbitrary runtime moments and survivors find out at the next collective —
contrast with ``examples/failure_recovery_training.py``, where deaths are
scheduled at trace time. The sweep runs as compiled ``sweep_step`` segments
under host control (``repro.ft.online``); between segments the host polls
the detector and repairs whatever it finds.

Also shown: suspending the factorization mid-sweep to an ``.npz``
(``repro.ckpt.save_sweep_state``) and resuming it in a fresh state machine.

Run: PYTHONPATH=src python examples/online_recovery.py [--after-ms N]
(--after-ms picks the wall-clock kill deadline; 0 = first boundary, the CI
smoke setting)
"""
import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import load_sweep_state, save_sweep_state
from repro.core import SimComm, caqr_factorize
from repro.ft import SweepOrchestrator
from repro.ft.online.detect import NaNSentinelDetector, WallClockKiller
from repro.ft.online.state import initial_sweep_state, sweep_step

ap = argparse.ArgumentParser()
ap.add_argument("--after-ms", type=float, default=0.0,
                help="wall-clock delay before the injected lane death")
args = ap.parse_args()

# b=4 tiles: the bitwise-equality envelope documented in DESIGN.md §8 —
# at larger tiles CPU XLA may reassociate batched gemms and REBUILD is
# then only numerically (not bitwise) identical
P, m_loc, n, b = 4, 8, 32, 4
rng = np.random.default_rng(0)
A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
comm = SimComm(P)

print(f"=== online FT-CAQR: {P*m_loc}x{n}, {n//b} panels, {P} lanes, "
      f"kill lane 2 after ~{args.after_ms:.0f}ms of wall clock ===")
ref = caqr_factorize(A, comm, b, collect_bundles=True, use_scan=False)

killer = WallClockKiller(after_s=args.after_ms / 1e3, lane=2)
orch = SweepOrchestrator(A, comm, b, detector=NaNSentinelDetector(),
                         fault_hooks=[killer])
res = orch.run()

print(f"ran {orch.segments_run} compiled segments; "
      f"death struck after point {killer.struck_at}")
for e in res.events:
    print(f"  detected at panel {e.point[0]} ({e.point[1]} level {e.point[2]}):"
          f" lane {e.lane} rebuilt from survivors {e.sources} in"
          f" {e.elapsed_s*1e3:.0f}ms ({len(e.reads)} single-source fetches)")
identical = all(
    np.array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(
        jax.tree_util.tree_leaves((res.R, res.factors, res.bundles)),
        jax.tree_util.tree_leaves((ref.R, ref.factors, ref.bundles)),
    )
)
print(f"R + factors + bundles bit-identical to failure-free sweep: {identical}")
assert identical and len(res.events) == 1

# === suspend / resume ======================================================
print("\n=== suspend mid-sweep, resume from the .npz ===")
state = initial_sweep_state(comm, A, b)
for _ in range(9):
    state = sweep_step(comm, state)
with tempfile.TemporaryDirectory() as d:
    path = save_sweep_state(os.path.join(d, "sweep"), state)
    kb = os.path.getsize(path) / 1024
    print(f"suspended at cursor {state.cursor} -> {kb:.0f} KiB on disk")
    resumed = SweepOrchestrator.from_state(load_sweep_state(path), comm).run()
same = all(
    np.array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree_util.tree_leaves(resumed.R),
                    jax.tree_util.tree_leaves(ref.R)))
print(f"resumed factorization bit-identical to uninterrupted run: {same}")
assert same
