#!/usr/bin/env python
"""Docs drift check: every code path README.md / DESIGN.md cite must exist.

Extracts backtick-quoted references of two kinds and resolves each against
the working tree:

* file paths (``src/repro/core/caqr.py``, ``benchmarks/run.py``,
  ``core/trailing.py`` — relative forms resolve by suffix anywhere under
  the repo);
* dotted module names (``repro.ft.driver`` -> ``src/repro/ft/driver.py``
  or a package directory).

Exit non-zero listing every dangling reference, so renames/deletions cannot
silently orphan the documentation. Run by ``tools/ci.sh``.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = ["README.md", "DESIGN.md"]

# Load-bearing sections: documentation a refactor must keep (referenced from
# code docstrings and tests). A heading rename/removal fails the gate.
REQUIRED_HEADINGS = {
    "README.md": [
        "## Shape support",
        "## Execution model: one program, two paths",
        "### Semantics support",
        "### Coded redundancy: the `f` knob",
        "## Serving: QR-as-a-service",
        "## Training: the FT runtime",
    ],
    "DESIGN.md": [
        "## 5. Recovery data-flow",
        "## 7. Ragged-panel geometry and padding semantics",
        "## 8. SPMD execution model",
        "## 9. Online recovery and the sweep state machine",
        "## 10. Kernel fast path",
        "## 11. Elastic execution",
        "## 12. Serving: QR-as-a-service",
        "## 13. Coded redundancy",
        "## 14. Fault-tolerant training runtime",
    ],
}

FILE_RE = re.compile(r"`([A-Za-z0-9_\-./]+\.(?:py|sh|json|md))`")
MODULE_RE = re.compile(r"`(repro(?:\.[A-Za-z_][A-Za-z0-9_]*)+)`")


def file_ok(token: str) -> bool:
    if (ROOT / token).exists():
        return True
    # relative citation (e.g. `core/trailing.py`): accept a unique-suffix
    # match anywhere in the tree
    name = token.lstrip("./")
    return any(
        str(p).endswith("/" + name)
        for p in ROOT.rglob(pathlib.Path(name).name)
    )


def module_ok(token: str) -> bool:
    rel = pathlib.Path("src", *token.split("."))
    return (ROOT / rel).is_dir() or (ROOT / rel.with_suffix(".py")).exists()


def main() -> int:
    missing = []
    for doc in DOCS:
        path = ROOT / doc
        if not path.exists():
            missing.append((doc, "(document itself missing)"))
            continue
        text = path.read_text()
        for tok in sorted(set(FILE_RE.findall(text))):
            if not file_ok(tok):
                missing.append((doc, tok))
        for tok in sorted(set(MODULE_RE.findall(text))):
            if not module_ok(tok):
                missing.append((doc, tok))
        for heading in REQUIRED_HEADINGS.get(doc, []):
            if not any(line.startswith(heading) for line in text.splitlines()):
                missing.append((doc, f"required section {heading!r}"))
    if missing:
        print("dangling documentation references:")
        for doc, tok in missing:
            print(f"  {doc}: {tok}")
        return 1
    print(f"docs check OK ({', '.join(DOCS)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
