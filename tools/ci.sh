#!/usr/bin/env bash
# CI entrypoint: docs check + tier-1 tests + example smoke + benchmark smoke.
#
# Test tiers (see also pytest.ini):
#   tier-1     the bare `python -m pytest -x -q` — deterministic tests only,
#              slow-marked tests excluded; must pass on a bare image.
#   slow       `-m slow`: subprocess SPMD cells + exhaustive kill matrices
#              (aligned AND ragged geometries); run via `tools/ci.sh --slow`.
#   property   the hypothesis-driven differential harnesses
#              (tests/test_general_shapes.py, tests/test_properties.py,
#              tests/test_elastic_properties.py).
#              They run inside tier-1 whenever hypothesis is importable; the
#              guard below makes a missing hypothesis a LOUD failure instead
#              of a silent skip, so the property tier cannot quietly vanish
#              from CI. Set CI_ALLOW_MISSING_HYPOTHESIS=1 to acknowledge an
#              image without it (the deterministic tiers still run).
#
#   tools/ci.sh          docs check (tools/check_docs.py), tier-1 pytest,
#                        end-to-end example smoke (quickstart + the FT
#                        driver/training demo), the SPMD smoke tier
#                        (examples/spmd_quickstart.py: shard_map FT sweep +
#                        kill on a forced 4-device host mesh, checked
#                        bitwise vs SimComm), the serve smoke tier
#                        (repro.launch.serve_qr: a QR-service traffic burst
#                        with a mid-batch lane kill, every retired R
#                        verified against numpy), the repro.ft
#                        docstring-example doctests, the compiled-kernel
#                        smoke tier
#                        (tools/kernel_smoke.py: capability probe report,
#                        compiled-dispatch parity vs the jnp oracles, and an
#                        autotune cache round-trip — loud skip when no op
#                        lowers native Pallas, an error under
#                        CI_REQUIRE_COMPILED_KERNELS=1), then
#                        `benchmarks/run.py --quick`, which
#                        also refreshes BENCH_core.json (incl. the `spmd`
#                        SimComm-vs-shard_map section)
#   tools/ci.sh --slow   additionally run the slow-marked tests
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== property-tier dependency check =="
if python -c "import hypothesis" 2>/dev/null; then
    echo "hypothesis present: property harnesses run in tier-1"
else
    echo "ERROR: hypothesis is not installed — the property tier" >&2
    echo "(tests/test_general_shapes.py, tests/test_properties.py," >&2
    echo "tests/test_elastic_properties.py)" >&2
    echo "would be silently skipped. Install hypothesis, or set" >&2
    echo "CI_ALLOW_MISSING_HYPOTHESIS=1 to acknowledge the gap." >&2
    if [[ "${CI_ALLOW_MISSING_HYPOTHESIS:-0}" != "1" ]]; then
        exit 1
    fi
    echo "CI_ALLOW_MISSING_HYPOTHESIS=1 set: continuing without the property tier"
fi

echo "== docs check =="
python tools/check_docs.py

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ "${1:-}" == "--slow" ]]; then
    echo "== slow tests =="
    python -m pytest -q -m slow
fi

echo "== example smoke =="
python examples/quickstart.py
python examples/failure_recovery_training.py --steps 8
python examples/online_recovery.py   # runtime-detected kill + suspend/resume

echo "== train smoke (FT training runtime: CAQR-Muon orthogonalization =="
echo "== through the FT-QR engine, a lane killed inside the =="
echo "== optimizer-internal sweep, params + loss curve asserted bitwise =="
echo "== vs failure-free) =="
python examples/train_tiny_lm.py --steps 6

echo "== SPMD smoke (shard_map FT sweep on a forced 4-device host mesh) =="
python examples/spmd_quickstart.py

echo "== serve smoke (QR-as-a-service traffic burst + mid-batch lane =="
echo "== kill; every retired R verified against numpy QR/lstsq) =="
python -m repro.launch.serve_qr --requests 8 --kill-lane 2 --kill-tick 2

echo "== multi-failure smoke (coded checksum lanes: a former XOR-buddy =="
echo "== pair killed simultaneously at runtime, healed by the joint GF =="
echo "== decode under MDSScheme(f=2), checked bitwise vs failure-free; =="
echo "== the same schedule must still raise under the XOR scheme) =="
python - <<'PYEOF'
import numpy as np, jax
from repro.core import SimComm
from repro.ft import (MDSScheme, UnrecoverableFailure, ft_caqr_sweep,
                      ft_caqr_sweep_online, sweep_point)
from repro.ft.online.detect import ScriptedKiller

P, m_loc, n, b = 4, 6, 10, 4
A = np.random.default_rng(3).standard_normal((P, m_loc, n)).astype(np.float32)
comm = SimComm(P)
pt = sweep_point(1, "trailing", 0)
free = ft_caqr_sweep(A, comm, b)
try:
    ft_caqr_sweep_online(A, comm, b,
                         fault_hooks=[ScriptedKiller({pt: [2, 3]})])
    raise SystemExit("XOR scheme recovered a buddy-pair double kill?!")
except UnrecoverableFailure:
    pass
got = ft_caqr_sweep_online(A, comm, b,
                           fault_hooks=[ScriptedKiller({pt: [2, 3]})],
                           scheme=MDSScheme(f=2))
for g, r in zip(jax.tree_util.tree_leaves((got.R, got.factors, got.bundles)),
                jax.tree_util.tree_leaves((free.R, free.factors, free.bundles))):
    assert np.array_equal(np.asarray(g), np.asarray(r)), "decode not bitwise"
assert all("coded.parity0" in e.reads for e in got.events)
print("multi-failure smoke OK: buddy-pair kill decoded bitwise, f=2")
PYEOF

echo "== repro.ft API doctest examples =="
python -m doctest src/repro/ft/driver.py src/repro/ft/failures.py \
    src/repro/ft/semantics.py && echo "doctests OK"

echo "== compiled-kernel smoke (probe report + dispatch parity + autotune =="
echo "== cache round-trip; CI_REQUIRE_COMPILED_KERNELS=1 to demand Pallas) =="
python tools/kernel_smoke.py

echo "== benchmark smoke (writes BENCH_core.json; fails loudly if the =="
echo "== online stepped overhead, the elastic SHRINK continuation, the =="
echo "== serve continuous-batching overhead, the coded-lane f=2 encode =="
echo "== overhead, or the train per-boundary cost regresses >25% over =="
echo "== the recorded baseline — and the train tier's async segments =="
echo "== and compiled probe must be strictly cheaper than their sync =="
echo "== counterparts; escapes: CI_ALLOW_ONLINE_REGRESSION=1 / =="
echo "== CI_ALLOW_ELASTIC_REGRESSION=1 / CI_ALLOW_SERVE_REGRESSION=1 / =="
echo "== CI_ALLOW_CODING_REGRESSION=1 / CI_ALLOW_TRAIN_REGRESSION=1) =="
python -m benchmarks.run --quick

echo "CI OK"
