#!/usr/bin/env bash
# CI entrypoint: docs check + tier-1 tests + example smoke + benchmark smoke.
#
#   tools/ci.sh          docs check (tools/check_docs.py), tier-1 pytest
#                        (slow-marked tests excluded by pytest.ini),
#                        end-to-end example smoke (quickstart + the FT
#                        driver/training demo), then `benchmarks/run.py
#                        --quick`, which also refreshes BENCH_core.json
#   tools/ci.sh --slow   additionally run the slow-marked tests
#                        (subprocess SPMD cells + exhaustive kill matrices)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== docs check =="
python tools/check_docs.py

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ "${1:-}" == "--slow" ]]; then
    echo "== slow tests =="
    python -m pytest -q -m slow
fi

echo "== example smoke =="
python examples/quickstart.py
python examples/failure_recovery_training.py --steps 8

echo "== benchmark smoke (writes BENCH_core.json) =="
python -m benchmarks.run --quick

echo "CI OK"
