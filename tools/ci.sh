#!/usr/bin/env bash
# CI entrypoint: tier-1 tests + benchmark smoke.
#
#   tools/ci.sh          tier-1 pytest (slow-marked tests excluded by
#                        pytest.ini) + `benchmarks/run.py --quick`, which
#                        also refreshes BENCH_core.json
#   tools/ci.sh --slow   additionally run the slow-marked tests
#                        (subprocess SPMD cells; need a newer jax)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ "${1:-}" == "--slow" ]]; then
    echo "== slow tests =="
    python -m pytest -q -m slow
fi

echo "== benchmark smoke (writes BENCH_core.json) =="
python -m benchmarks.run --quick

echo "CI OK"
