#!/usr/bin/env python
"""Compiled-kernel smoke tier (tools/ci.sh).

Three checks, in order:

1. **Capability probe report** — what ``backend._probe_compiled`` found for
   every op on this backend: which ops lower native Pallas, which fall back
   to the ``xla`` engine, and the probe error when they do. Purely
   informational, always printed.
2. **Compiled-dispatch parity** — run every op through the real ``ops``
   dispatch under the active policy (whatever engine ``compiled`` resolves
   to here) on an aligned and a ragged geometry, in f32 and bf16, and
   compare against the jnp oracle at ``ref.tolerances(dtype)``. This is the
   smoke guarantee that the fast path *computes the right thing* on this
   machine, whichever engine it got.
3. **Autotune cache round-trip** — tune one cell, save to a temp file,
   clear, load, and require the looked-up params to be identical (the
   persistence format and the fingerprint keying actually work).

When no op lowers native Pallas the tier prints a LOUD skip for the
pallas-engine half (the xla-engine parity still runs — that is the compiled
path CI actually exercises on CPU images). ``CI_REQUIRE_COMPILED_KERNELS=1``
turns that skip into an error for images that are supposed to have a
Mosaic/Triton toolchain. Exit codes: 0 OK / 1 failure (or required-but-
missing native Pallas).
"""
from __future__ import annotations

import os
import sys
import tempfile

import numpy as np


def main() -> int:
    import jax
    import jax.numpy as jnp

    from repro.kernels import autotune, backend, fused_sweep, ops, ref

    print(f"backend fingerprint: {backend.backend_fingerprint()}")
    report = backend.probe_report()
    native = [op for op, e in report.items() if e["supported"]]
    for op, entry in report.items():
        line = f"  {op:14s} engine={entry['engine']}"
        if not entry["supported"]:
            err = entry.get("error", "").splitlines()[0][:80]
            line += f"  (native pallas probe failed: {err})"
        print(line)

    if not native:
        print("LOUD SKIP: no op lowers native Pallas on this backend — the "
              "pallas engine is untested here; compiled dispatch runs via "
              "the xla engine below.")
        if os.environ.get("CI_REQUIRE_COMPILED_KERNELS") == "1":
            print("CI_REQUIRE_COMPILED_KERNELS=1: treating the skip as an "
                  "error (this image is supposed to lower Pallas).",
                  file=sys.stderr)
            return 1

    # -- compiled-dispatch parity vs oracle --------------------------------
    failures = []
    rng = np.random.default_rng(0)
    for dt in (jnp.float32, jnp.bfloat16):
        rtol, atol = ref.tolerances(dt)
        for m, b, n in ((64, 16, 96), (37, 12, 55)):  # aligned-ish + ragged
            A = jnp.asarray(rng.standard_normal((m, b)), dt)
            Y = jnp.asarray(rng.standard_normal((m, b)), dt) * 0.1
            T = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), dt)) * 0.1
            C = jnp.asarray(rng.standard_normal((m, n)), dt)
            R1 = jnp.asarray(np.linalg.qr(rng.standard_normal((m, b)))[1], dt)
            R2 = jnp.asarray(np.linalg.qr(rng.standard_normal((m, b)))[1], dt)
            Ct = jnp.asarray(rng.standard_normal((b, n)), dt)
            Cb = jnp.asarray(rng.standard_normal((b, n)), dt)
            W = jnp.asarray(rng.standard_normal((m, b + 8)), dt)
            pairs = [
                ("panel_qr", lambda: ops.panel_qr(A, 0),
                 lambda: ref.panel_qr(A, 0)),
                ("stacked_qr", lambda: ops.stacked_qr(R1, R2),
                 lambda: ref.stacked_qr(R1, R2)),
                ("wy_apply", lambda: ops.wy_apply(Y, T, C),
                 lambda: ref.wy_apply(Y, T, C)),
                ("stacked_apply", lambda: ops.stacked_apply(T, T, Ct, Cb),
                 lambda: ref.stacked_apply(T, T, Ct, Cb)),
                ("fused_sweep", lambda: ops.panel_qr_apply(W, 0, b),
                 lambda: fused_sweep.panel_qr_apply_ref(W, 0, b)),
            ]
            for op, k_fn, r_fn in pairs:
                mode = backend.kernel_mode(op)
                got, want = k_fn(), r_fn()
                for g, w in zip(jax.tree_util.tree_leaves(got),
                                jax.tree_util.tree_leaves(want)):
                    g = np.asarray(g, dtype=np.float32)
                    w = np.asarray(w, dtype=np.float32)
                    if not np.allclose(g, w, rtol=rtol, atol=atol):
                        failures.append(
                            f"{op} [{mode}] {jnp.dtype(dt).name} "
                            f"({m},{b},{n}): max err "
                            f"{np.abs(g - w).max():.2e} > {atol}")
                        break
    if failures:
        print("PARITY FAILURES:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    modes = {op: backend.kernel_mode(op) for op in backend.OPS}
    print(f"parity OK (modes: {modes})")

    # -- autotune cache round-trip -----------------------------------------
    # panel_qr has a non-trivial candidate set on every engine (unroll on
    # xla, lane_pad elsewhere), so the reloaded params are never vacuous.
    autotune.clear()
    rec = autotune.tune("panel_qr", (64, 16), reps=3)
    if rec is None:
        print("autotune round-trip skipped: policy routes panel_qr to the "
              "oracle (nothing to tune)")
        return 0
    key_params = autotune.lookup("panel_qr", (64, 16), jnp.float32)
    assert key_params, "tuned cell has no params — round-trip would be vacuous"
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "autotune.json")
        autotune.save(path)
        autotune.clear()
        assert autotune.lookup("panel_qr", (64, 16), jnp.float32) == {}
        adopted = autotune.load(path)
        reloaded = autotune.lookup("panel_qr", (64, 16), jnp.float32)
    if reloaded != key_params:
        print(f"autotune round-trip MISMATCH: {key_params!r} != {reloaded!r}",
              file=sys.stderr)
        return 1
    print(f"autotune round-trip OK ({adopted} cell(s), params {key_params})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
