"""Render the §Dry-run and §Roofline sections of EXPERIMENTS.md from the
dry-run artifacts. Run after the sweep:
    PYTHONPATH=src python experiments/make_report.py > experiments/roofline_tables.md
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.roofline import analyze  # noqa: E402

DRY = os.path.join(os.path.dirname(__file__), "dryrun")


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def main():
    recs = []
    for f in sorted(glob.glob(os.path.join(DRY, "*.json"))):
        rec = json.load(open(f))
        if rec.get("status") == "ok":
            recs.append(rec)

    print("### §Dry-run — lower+compile per cell (both meshes)\n")
    print("| cell | mesh | chips | args GiB/dev | peak GiB/dev (analytic) | "
          "HLO GFLOP/dev | coll GiB/dev | collective mix (AG/AR/RS/A2A/CP GiB) |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        c = r["collectives"]
        mix = "/".join(
            f"{c.get(k, 0)/2**30:.1f}"
            for k in ("all-gather", "all-reduce", "reduce-scatter",
                      "all-to-all", "collective-permute"))
        tag = f" [{r['tag']}]" if r.get("tag") else ""
        print(f"| {r['arch']} x {r['shape']}{tag} | {r['mesh']} | {r['n_chips']} "
              f"| {fmt_bytes(r['memory']['argument_bytes'])} "
              f"| {fmt_bytes(r['memory'].get('peak_bytes_analytic', r['memory']['peak_bytes_est']))} "
              f"| {r['cost']['flops_per_device']/1e9:.1f} "
              f"| {c.get('total_bytes', 0)/2**30:.2f} | {mix} |")

    print("\n### §Roofline — three terms per cell (single-pod, v5e constants)\n")
    print("| cell | compute s | memory s | collective s | dominant | "
          "useful-flop ratio | roofline fraction | lever |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["mesh"] != "single" or r.get("tag"):
            continue
        a = analyze(r)
        print(f"| {r['arch']} x {r['shape']} | {a['t_compute_s']:.4f} "
              f"| {a['t_memory_s']:.4f} | {a['t_collective_s']:.4f} "
              f"| **{a['dominant']}** | {a['useful_flop_ratio']:.3f} "
              f"| {a['roofline_fraction']:.3f} | {a['lever']} |")

    # perf-iteration artifacts (tagged)
    tagged = [r for r in recs if r.get("tag")]
    if tagged:
        print("\n### §Perf — tagged iteration artifacts\n")
        print("| tag | cell | peak GiB | GFLOP/dev | coll GiB/dev | dominant "
              "| roofline fraction |")
        print("|---|---|---|---|---|---|---|")
        for r in tagged:
            a = analyze(r)
            print(f"| {r['tag']} | {r['arch']} x {r['shape']} x {r['mesh']} "
                  f"| {a['peak_gib']:.2f} | {r['cost']['flops_per_device']/1e9:.1f} "
                  f"| {r['collectives'].get('total_bytes', 0)/2**30:.2f} "
                  f"| {a['dominant']} | {a['roofline_fraction']:.3f} |")


if __name__ == "__main__":
    main()
