"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> record.

Each iteration re-runs a dry-run cell with a config/rule/sharding override
and writes a tagged artifact to experiments/dryrun/. EXPERIMENTS.md §Perf
narrates the hypotheses and outcomes; experiments/make_report.py renders the
tagged table.

Run one iteration per invocation (fresh process => fresh 512-device init):
  PYTHONPATH=src python experiments/perf_iterations.py <iter_name>
  PYTHONPATH=src python experiments/perf_iterations.py --list
"""
import sys

sys.path.insert(0, "src")
from repro.launch import dryrun  # noqa: E402  (sets XLA_FLAGS first)

# Hillclimb cells (per the assignment's selection rule):
#   A: tinyllama-1.1b x train_4k   — most collective-bound dense baseline
#   C: kimi-k2 x train_4k          — worst roofline fraction (1T MoE)
#   D: caqr                        — the paper's own technique
ITERS = {
    # ---- A: tinyllama train_4k --------------------------------------------
    # A1 H: at 1.1B params the per-layer sequence-parallel all-gathers of the
    #       residual dominate; activations fit without SP -> turn SP off.
    "A1_no_seq_shard": (
        "tinyllama-1.1b", "train_4k", "single",
        dict(rule_overrides={"seq_shard": None}, tag="A1_no_seq_shard"),
    ),
    # A2 H: FSDP weight-gathers are pure overhead at this scale — params+opt
    #       fit replicated; ZeRO-0 removes per-layer all-gathers, leaving one
    #       grad all-reduce per step.
    "A2_no_fsdp": (
        "tinyllama-1.1b", "train_4k", "single",
        dict(rule_overrides={"seq_shard": None}, fsdp_override=None,
             tag="A2_no_fsdp"),
    ),
    # A3 H: with SP back ON but FSDP off, SP's gathers return: isolates the
    #       two effects (confirm/refute attribution).
    "A3_sp_only": (
        "tinyllama-1.1b", "train_4k", "single",
        dict(fsdp_override=None, tag="A3_sp_only"),
    ),
    # A4 H: (from A1-A3's refutations) the dominant collectives are the
    #       per-layer TP activation all-reduces + vocab-parallel CE — a 1.1B
    #       model does not need TP at all on 256 chips. Pure ZeRO-3 DP:
    #       batch over BOTH axes (1 sample/chip), params fully sharded,
    #       no TP -> expect order-of-magnitude collective reduction.
    "A4_pure_dp_zero3": (
        "tinyllama-1.1b", "train_4k", "single",
        dict(rule_overrides={"seq_shard": None, "batch": ("data", "model"),
                             "vocab": None, "heads": None, "kv_heads": None,
                             "ff": None, "experts": None, "ssm_heads": None,
                             "lru": None, "kv_seq_shard": None},
             fsdp_override=("data", "model"), tag="A4_pure_dp_zero3"),
    ),
    # ---- C: kimi-k2 train_4k ----------------------------------------------
    # C1 H: the global-capacity MoE scatter replicates the (E,C,D) buffers
    #       and all-reduces 154 TiB/device; per-data-shard dispatch
    #       (moe_shards=16) shards the buffers and kills the all-reduce.
    "C1_moe_sharded": (
        "kimi-k2-1t-a32b", "train_4k", "single",
        dict(overrides={"moe_shards": 16}, tag="C1_moe_sharded"),
    ),
    # C2 H: on top of C1, residual SP is a net loss for kimi (d_model=7168
    #       activations are modest vs its MoE comm) — measure SP off.
    "C2_moe_sharded_no_sp": (
        "kimi-k2-1t-a32b", "train_4k", "single",
        dict(overrides={"moe_shards": 16},
             rule_overrides={"seq_shard": None}, tag="C2_moe_sharded_no_sp"),
    ),
    # ---- D: the paper's CAQR workload --------------------------------------
    # D1 H: panel b=256 halves the panel count (and tree levels / exchanges)
    #       at ~2x flops per combine — net win if collective-bound.
    "D1_caqr_b256": ("caqr", None, "single", dict(panel=256, tag="D1_b256")),
    # D2 H: b=64 doubles panels: more exchanges, less compute per panel —
    #       expected regression (probe of the other direction).
    "D2_caqr_b64": ("caqr", None, "single", dict(panel=64, tag="D2_b64")),
}


def main():
    if len(sys.argv) < 2 or sys.argv[1] == "--list":
        for k in ITERS:
            print(k)
        return
    name = sys.argv[1]
    arch, shape, mesh, kw = ITERS[name]
    out = dryrun.OUT_DIR
    if arch == "caqr":
        dryrun.run_caqr_cell(mesh, out, panel=kw["panel"], tag=kw["tag"])
    else:
        dryrun.run_cell(arch, shape, mesh, out, **kw)


if __name__ == "__main__":
    main()
