"""FT training runtime (DESIGN.md §14): optimizer-internal FT-CAQR sweeps.

Gates the tentpole invariants:

* a lane killed *inside* the optimizer-internal sweep of a training step is
  healed in place — params and loss curve bitwise-identical to the
  failure-free run (caqr_muon routing and the PowerSGD bridge);
* async double-buffered segment execution is bitwise-identical to sync;
* a run suspended mid-factorization resumes across the checkpoint boundary
  bitwise-identically (sweep wire format v2 carries the MDS parity slots;
  v1 stays loadable and its parity-less resume window fails honestly).
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core.caqr import block_row_layout
from repro.core.comm import SimComm
from repro.data.pipeline import DataConfig
from repro.ft.coding import MDSScheme, UnrecoverableFailure
from repro.ft.failures import prev_sweep_point
from repro.ft.online.detect import NaNSentinelDetector, ScriptedKiller
from repro.ft.online.orchestrator import SweepOrchestrator
from repro.ft.semantics import Semantics
from repro.ckpt.sweep import load_sweep_state, save_sweep_state
from repro.train.loop import TrainConfig
from repro.train.ftrun import (
    FTRunConfig,
    FTTrainer,
    QREngine,
    StepSweepKiller,
    SuspendAfter,
    SuspendSweep,
    TrainingSuspended,
    plan_muon_tasks,
)


@pytest.fixture(scope="module", autouse=True)
def _drop_train_executables():
    """This module compiles full training steps (transformer fwd/bwd per
    optimizer, plus the Muon/PowerSGD programs) in-process — by far the
    largest executables in the tier-1 suite. Free them at teardown: left
    resident, the accumulated XLA compile state can crash a later module's
    first large compile (observed as a backend_compile segfault in
    test_online_recovery.py when the full suite runs in one process)."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="module")
def cfg():
    return get_smoke("tinyllama-1.1b")


@pytest.fixture(scope="module")
def dcfg(cfg):
    return DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=1)


def _tcfg(**kw):
    base = dict(steps=4, lr=1e-2, warmup=2, n_lanes=4, diskless_every=2,
                log_every=100, semantics=Semantics.REBUILD)
    base.update(kw)
    return TrainConfig(**base)


def _params_equal(a, b) -> bool:
    eq = jax.tree_util.tree_map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
        a, b)
    return all(jax.tree_util.tree_leaves(eq))


# -- engine unit behavior ----------------------------------------------------


def test_engine_q_is_orthonormal_and_ft():
    rng = np.random.default_rng(0)
    M = jnp.asarray(rng.standard_normal((128, 48)), jnp.float32)
    eng = QREngine(n_lanes=4, panel_width=16)
    Q = eng.orthonormalize(M)
    assert Q.shape == M.shape
    err = np.abs(np.asarray(Q.T @ Q) - np.eye(48)).max()
    assert err < 1e-4
    # killed-lane sweep returns the bitwise-identical Q
    killer = ScriptedKiller({(0, "trailing", 0): [2]})
    eng_k = QREngine(n_lanes=4, panel_width=16, fault_hooks=[killer])
    Qk = eng_k.orthonormalize(M)
    assert np.array_equal(np.asarray(Q), np.asarray(Qk))


def test_engine_async_matches_sync():
    rng = np.random.default_rng(1)
    M = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
    kill = {(1, "trailing", 0): [1]}
    Qs = QREngine(n_lanes=4, fault_hooks=[ScriptedKiller(kill)]) \
        .orthonormalize(M)
    Qa = QREngine(n_lanes=4, async_segments=True,
                  fault_hooks=[ScriptedKiller(kill)]).orthonormalize(M)
    assert np.array_equal(np.asarray(Qs), np.asarray(Qa))


def test_nonblocking_probe_matches_poll():
    rng = np.random.default_rng(2)
    M = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    comm = SimComm(4)
    from repro.ft.driver import obliterate_state
    from repro.ft.online.state import initial_sweep_state

    st = initial_sweep_state(comm, block_row_layout(M, 4), 16)
    st_dead = obliterate_state(comm, st, 3)
    det_poll, det_probe = NaNSentinelDetector(), NaNSentinelDetector()
    assert det_poll.poll(comm, st_dead) == [3]
    handle = det_probe.probe(comm, st_dead)
    assert det_probe.collect(comm, handle) == [3]
    # re-arm after revive, silent when healthy
    det_probe.revive(3)
    assert det_probe.collect(comm, det_probe.probe(comm, st)) == []


def test_task_planner_smoke_model(cfg):
    import repro.models.transformer as tf

    params = tf.init_params(cfg, jax.random.key(0))
    tasks = plan_muon_tasks(params, min_qr_size=8192)
    names = {t.name for t in tasks}
    # all FFN slices route; every routed slice shares the (128, 64) geometry
    assert any("ffn" in n for n in names)
    assert all((t.rows, t.cols) == (128, 64) for t in tasks)
    assert all(t.name.endswith(("#0", "#1")) for t in tasks)


# -- training bitwise identity ----------------------------------------------


def test_muon_kill_inside_sweep_bitwise(cfg, dcfg):
    tcfg = _tcfg(optimizer="caqr_muon")
    ref = FTTrainer(cfg, tcfg, dcfg)
    hist_ref = ref.run()

    killer = StepSweepKiller(at_step=2, lane=1)
    tr = FTTrainer(cfg, tcfg, dcfg, qr_fault_hooks=[killer])
    hist = tr.run()

    assert killer.fired and killer.struck[0] == 2
    assert _params_equal(ref.state.params, tr.state.params)
    assert [h["loss"] for h in hist_ref] == [h["loss"] for h in hist]
    # the kill healed inside the sweep: no training-level rewind happened
    assert [h["step"] for h in hist] == list(range(tcfg.steps))


def test_muon_async_segments_bitwise(cfg, dcfg):
    tcfg = _tcfg(optimizer="caqr_muon")
    killer_s = StepSweepKiller(at_step=1, lane=3)
    sync = FTTrainer(cfg, tcfg, dcfg, qr_fault_hooks=[killer_s])
    sync.run()
    killer_a = StepSweepKiller(at_step=1, lane=3)
    asyn = FTTrainer(cfg, tcfg, dcfg, FTRunConfig(async_segments=True),
                     qr_fault_hooks=[killer_a])
    asyn.run()
    assert killer_s.fired and killer_a.fired
    assert _params_equal(sync.state.params, asyn.state.params)


def test_powersgd_bridge_kill_bitwise(cfg, dcfg):
    tcfg = _tcfg(optimizer="adamw")
    fcfg = FTRunConfig(compression_rank=4, compression_min_size=4096)
    ref = FTTrainer(cfg, tcfg, dcfg, fcfg)
    hist_ref = ref.run()
    assert ref._tasks, "nothing routed through the bridge"

    killer = StepSweepKiller(at_step=1, lane=2)
    tr = FTTrainer(cfg, tcfg, dcfg,
                   FTRunConfig(compression_rank=4, compression_min_size=4096),
                   qr_fault_hooks=[killer])
    hist = tr.run()
    assert killer.fired
    assert _params_equal(ref.state.params, tr.state.params)
    assert [h["loss"] for h in hist_ref] == [h["loss"] for h in hist]


def test_powersgd_bridge_trains(cfg, dcfg):
    tcfg = _tcfg(optimizer="adamw", steps=8)
    tr = FTTrainer(cfg, tcfg, dcfg,
                   FTRunConfig(compression_rank=8,
                               compression_min_size=4096))
    hist = tr.run()
    assert hist[-1]["loss"] < hist[0]["loss"]


# -- suspend / resume across the checkpoint boundary -------------------------


def test_suspend_resume_bitwise(cfg, dcfg, tmp_path):
    tcfg = _tcfg(optimizer="caqr_muon", ckpt_dir=str(tmp_path))
    ref = FTTrainer(cfg, tcfg, dcfg)
    ref.run()

    tr = FTTrainer(cfg, tcfg, dcfg,
                   FTRunConfig(suspend_after_boundaries=290))
    with pytest.raises(TrainingSuspended) as exc:
        tr.run()
    assert 0 < exc.value.step < tcfg.steps

    resumed = FTTrainer.resume(cfg, tcfg, dcfg)
    assert resumed._pending_resume is not None
    assert resumed._pending_resume[0] == exc.value.task
    resumed.run()
    assert _params_equal(ref.state.params, resumed.state.params)


# -- sweep-state wire format v2 ----------------------------------------------


def _mid_sweep_state(scheme=None, boundaries=3):
    rng = np.random.default_rng(7)
    A0 = block_row_layout(
        jnp.asarray(rng.standard_normal((128, 64)), jnp.float32), 4)
    orch = SweepOrchestrator(A0, SimComm(4), 16, scheme=scheme,
                             boundary_hooks=[SuspendAfter(boundaries)])
    with pytest.raises(SuspendSweep) as exc:
        orch.run()
    return A0, exc.value.state


def _finish(state, **kw):
    return SweepOrchestrator.from_state(state, SimComm(4), **kw).run()


def test_wire_v1_still_loads_and_finishes(tmp_path):
    A0, st = _mid_sweep_state()
    ref = _finish(st)
    p = save_sweep_state(str(tmp_path / "v1"), st, version=1)
    res = _finish(load_sweep_state(p))
    assert np.array_equal(np.asarray(ref.R), np.asarray(res.R))


def test_wire_v2_mds_parity_survives_suspension(tmp_path):
    A0, st = _mid_sweep_state(scheme=MDSScheme(2))
    assert st.code is not None
    ref = _finish(st, scheme=MDSScheme(2))
    pt = prev_sweep_point(st.cursor, st.geom.n_panels, st.geom.levels)

    # v2 resume: an XOR-buddy PAIR died while suspended — joint decode from
    # the persisted parity slots, bitwise-identical completion
    p2 = save_sweep_state(str(tmp_path / "v2"), st)
    st2 = load_sweep_state(p2)
    assert st2.code is not None
    res = _finish(st2, scheme=MDSScheme(2),
                  fault_hooks=[ScriptedKiller({pt: [0, 1]})])
    assert np.array_equal(np.asarray(ref.R), np.asarray(res.R))

    # v1 resume: same deaths, no persisted parity — honestly unrecoverable
    # (this is exactly the re-encode vulnerability window v2 closes)
    p1 = save_sweep_state(str(tmp_path / "v1"), st, version=1)
    st1 = load_sweep_state(p1)
    assert st1.code is None
    with pytest.raises(UnrecoverableFailure):
        _finish(st1, scheme=MDSScheme(2),
                fault_hooks=[ScriptedKiller({pt: [0, 1]})])
