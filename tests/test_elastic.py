"""Elastic mid-sweep execution (``repro.ft.elastic``): SHRINK/BLANK
continuation, re-grow, speculative straggler recompute.

Oracle structure (DESIGN.md §11):

* vs the failure-free run — row re-hosting changes the reduction shapes,
  so elastic R matches within ``kernels.ref.tolerances`` after sign
  fixing (each epoch's TSQR may flip R-row signs);
* scheduled elastic vs online-detected elastic — shared
  ``ElasticController`` code, so **bitwise**;
* the acceptance matrix: mid-sweep SHRINK at *every* sweep point on the
  ragged P=4, m_loc=6, n=10, b=4 geometry finishes on 3 live lanes.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import SimComm, caqr_factorize
from repro.core.caqr import sweep_geometry
from repro.core.recovery import pairing_table, xor_buddy
from repro.core.tsqr import _levels, _xor_perm
from repro.ft import (
    FailureSchedule,
    Semantics,
    StragglerConfig,
    StragglerMonitor,
    StragglerPolicy,
    SweepOrchestrator,
    ft_caqr_sweep,
    ft_caqr_sweep_elastic,
    iter_sweep_points,
)
from repro.ft.elastic import (
    LaneWorld,
    ceil_pow2,
    floor_pow2,
    harvest_trailing,
    plan_transition,
)
from repro.ft.failures import UnrecoverableFailure
from repro.ft.online.detect import ScriptedKiller


def signfix(R):
    s = np.sign(np.diag(R))
    s = np.where(s == 0, 1.0, s)
    return R * s[:, None]


# the acceptance geometry: ragged rows (m_loc=6 pads to 8) and ragged
# columns (n=10 pads to 12), 3 panels, 2 butterfly levels
RP, RM_LOC, RN, RB = 4, 6, 10, 4
RGEOM = sweep_geometry(RP, RM_LOC, RN, RB)
R_POINTS = list(iter_sweep_points(RGEOM.n_panels, RGEOM.levels))


def _matrix(P, m_loc, n, seed=3):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)


@pytest.fixture(scope="module")
def ragged_reference():
    A = _matrix(RP, RM_LOC, RN)
    ref = caqr_factorize(A, SimComm(RP), RB, collect_bundles=True,
                         use_scan=False)
    return A, np.asarray(ref.R[0])


def _assert_close(R_elastic, R_ref):
    from repro.kernels import ref as kref

    rtol, atol = kref.tolerances(jnp.float32)
    np.testing.assert_allclose(
        signfix(np.asarray(R_elastic)), signfix(np.asarray(R_ref)),
        rtol=rtol, atol=atol)


# -- the acceptance matrix: SHRINK at every sweep point ----------------------


@pytest.mark.parametrize("point", R_POINTS, ids=[str(p) for p in R_POINTS])
@pytest.mark.parametrize("lane", [0, 1, 3])
def test_shrink_every_point_ragged(ragged_reference, point, lane):
    A, R_ref = ragged_reference
    sched = FailureSchedule(events={point: [lane]})
    res = ft_caqr_sweep_elastic(A, SimComm(RP), RB, schedule=sched,
                                semantics=Semantics.SHRINK)
    _assert_close(res.R, R_ref)
    # the world finished without the dead lane: 3 live lanes
    assert res.world.n_live == RP - 1
    assert [e.lane for e in res.events] == [lane]
    assert [t.kind for t in res.transitions] == ["shrink"]
    assert res.transitions[0].lanes == (lane,)


@pytest.mark.parametrize("point", R_POINTS[1::4], ids=str)
def test_blank_keeps_hole(ragged_reference, point):
    A, R_ref = ragged_reference
    sched = FailureSchedule(events={point: [2]})
    res = ft_caqr_sweep_elastic(A, SimComm(RP), RB, schedule=sched,
                                semantics=Semantics.BLANK)
    _assert_close(res.R, R_ref)
    (t,) = res.transitions
    assert t.kind == "blank"
    # BLANK keeps the world size; the hole is a masked no-op lane
    assert res.world.n_slots == RP
    assert res.world.live == (True, True, False, True)
    # the designated adopter is the XOR level-0 buddy
    assert t.adopter == xor_buddy(2, 0) == 3


# -- online path: bitwise vs the scheduled oracle ----------------------------


@pytest.mark.parametrize("point", R_POINTS, ids=[str(p) for p in R_POINTS])
@pytest.mark.parametrize("semantics", [Semantics.SHRINK, Semantics.BLANK],
                         ids=["shrink", "blank"])
def test_online_bitwise_vs_scheduled_oracle(ragged_reference, point,
                                            semantics):
    A, _ = ragged_reference
    sched = FailureSchedule(events={point: [1]})
    oracle = ft_caqr_sweep_elastic(A, SimComm(RP), RB, schedule=sched,
                                   semantics=semantics)
    online = SweepOrchestrator(
        A, SimComm(RP), RB, fault_hooks=[ScriptedKiller({point: [1]})],
        semantics=semantics,
    ).run()
    assert np.array_equal(np.asarray(oracle.R), np.asarray(online.R))
    assert len(online.events) == len(oracle.events) == 1
    assert online.events[0].point == oracle.events[0].point == tuple(point)
    assert [t.kind for t in online.transitions] == \
        [t.kind for t in oracle.transitions]
    assert online.world == oracle.world


def test_driver_semantics_delegation(ragged_reference):
    """``ft_caqr_sweep(semantics=SHRINK)`` routes to the elastic driver."""
    A, R_ref = ragged_reference
    sched = FailureSchedule(events={R_POINTS[4]: [3]})
    res = ft_caqr_sweep(A, SimComm(RP), RB, schedule=sched,
                        semantics=Semantics.SHRINK)
    _assert_close(res.R, R_ref)
    assert res.world.n_live == RP - 1


def test_failure_free_elastic_is_exact(ragged_reference):
    """No deaths -> one epoch, R exactly equal to the failure-free run."""
    A, R_ref = ragged_reference
    res = ft_caqr_sweep_elastic(A, SimComm(RP), RB,
                                semantics=Semantics.SHRINK)
    assert np.array_equal(np.asarray(res.R), R_ref)
    assert res.transitions == [] and res.events == []
    assert res.world.n_live == RP


# -- grow --------------------------------------------------------------------


def test_grow_rejoins_after_shrink(ragged_reference):
    A, R_ref = ragged_reference
    sched = FailureSchedule(events={R_POINTS[3]: [1]})
    res = ft_caqr_sweep_elastic(A, SimComm(RP), RB, schedule=sched,
                                semantics=Semantics.SHRINK,
                                grow_at=(1, "trailing", 1))
    _assert_close(res.R, R_ref)
    assert [t.kind for t in res.transitions] == ["shrink", "grow"]
    # the returning lane restores the live count
    assert res.world.n_live == RP
    # grow re-enters the pairing of the restored world size implicitly
    assert res.world.n_slots == ceil_pow2(res.world.n_live)


def test_grow_online_matches_scheduled(ragged_reference):
    A, _ = ragged_reference
    point, grow_pt = R_POINTS[2], (1, "trailing", 0)
    sched = FailureSchedule(events={point: [2]})
    oracle = ft_caqr_sweep_elastic(A, SimComm(RP), RB, schedule=sched,
                                   semantics=Semantics.SHRINK,
                                   grow_at=grow_pt)
    online = SweepOrchestrator(
        A, SimComm(RP), RB, fault_hooks=[ScriptedKiller({point: [2]})],
        semantics=Semantics.SHRINK, grow_at=grow_pt,
    ).run()
    assert np.array_equal(np.asarray(oracle.R), np.asarray(online.R))
    assert [t.kind for t in online.transitions] == ["shrink", "grow"]


# -- multiple deaths / edge worlds -------------------------------------------


def test_two_deaths_different_panels(ragged_reference):
    A, R_ref = ragged_reference
    sched = FailureSchedule(events={R_POINTS[1]: [3], R_POINTS[7]: [0]})
    res = ft_caqr_sweep_elastic(A, SimComm(RP), RB, schedule=sched,
                                semantics=Semantics.SHRINK)
    _assert_close(res.R, R_ref)
    # second kill addresses the *new* world's numbering (epoch restart)
    assert len(res.transitions) == 2
    assert res.world.n_live == 2


def test_buddy_pair_death_still_unrecoverable(ragged_reference):
    """Both members of an XOR pair dying at one point loses the bundle
    sources — elastic semantics cannot save that either."""
    A, _ = ragged_reference
    sched = FailureSchedule(events={R_POINTS[2]: [2, 3]})
    with pytest.raises(UnrecoverableFailure):
        ft_caqr_sweep_elastic(A, SimComm(RP), RB, schedule=sched,
                              semantics=Semantics.SHRINK)


def test_shrink_aligned_and_wide_shapes():
    for P, m_loc, n, b in [(4, 8, 32, 4), (2, 8, 8, 4), (4, 4, 24, 4)]:
        A = _matrix(P, m_loc, n, seed=11)
        ref = caqr_factorize(A, SimComm(P), b, collect_bundles=True,
                             use_scan=False)
        geom = sweep_geometry(P, m_loc, n, b)
        pts = list(iter_sweep_points(geom.n_panels, geom.levels))
        sched = FailureSchedule(events={pts[len(pts) // 2]: [1]})
        res = ft_caqr_sweep_elastic(A, SimComm(P), b, schedule=sched,
                                    semantics=Semantics.SHRINK)
        _assert_close(res.R, np.asarray(ref.R[0]))


# -- stragglers --------------------------------------------------------------


def _slow_lane_clock(slow):
    def clock(comm, state):
        P = comm.axis_size()
        return {i: (8.0 if i == slow and i < P else 1.0) for i in range(P)}

    return clock


def test_speculative_recompute_bitwise():
    """A persistently slow lane triggers speculative buddy recompute;
    the race winner is bitwise-identical to a blocking run — R and the
    full event ledger stay exactly the failure-free result."""
    P, m_loc, n, b = 4, 8, 32, 4
    A = _matrix(P, m_loc, n, seed=0)
    ref = caqr_factorize(A, SimComm(P), b, collect_bundles=True,
                         use_scan=False)
    mon = StragglerMonitor(P, StragglerConfig(
        threshold=1.4, patience=2, policy=StragglerPolicy.SPECULATE))
    orch = SweepOrchestrator(A, SimComm(P), b, straggler_monitor=mon,
                             lane_clock=_slow_lane_clock(2))
    res = orch.run()
    assert np.array_equal(np.asarray(res.R), np.asarray(ref.R))
    assert orch.speculations, "slow lane never triggered speculation"
    assert all(s.matched for s in orch.speculations)
    assert all(s.lane == 2 for s in orch.speculations)
    assert all(s.reads for s in orch.speculations)
    assert res.events == []  # speculation is not a death


def test_evict_escalates_to_shrink():
    P, m_loc, n, b = 4, 8, 32, 4
    A = _matrix(P, m_loc, n, seed=0)
    ref = caqr_factorize(A, SimComm(P), b, collect_bundles=True,
                         use_scan=False)
    mon = StragglerMonitor(P, StragglerConfig(
        threshold=1.4, patience=2, policy=StragglerPolicy.EVICT))

    def clock(comm, state):
        # only the first epoch's lane 2 is slow (evicted once); the
        # post-transition epoch has a wider m_loc_pad (adopted rows)
        P_now = comm.axis_size()
        slow = 2 if state.geom.m_loc == m_loc else -1
        return {i: (8.0 if i == slow else 1.0) for i in range(P_now)}

    orch = SweepOrchestrator(A, SimComm(P), b, straggler_monitor=mon,
                             lane_clock=clock)
    res = orch.run()
    _assert_close(res.R, np.asarray(ref.R[0]))
    assert [t.kind for t in res.transitions] == ["shrink"]
    assert res.world.n_live == P - 1


def test_speculate_escalate_after():
    P, m_loc, n, b = 4, 8, 32, 4
    A = _matrix(P, m_loc, n, seed=0)
    ref = caqr_factorize(A, SimComm(P), b, collect_bundles=True,
                         use_scan=False)
    mon = StragglerMonitor(P, StragglerConfig(
        threshold=1.4, patience=2, policy=StragglerPolicy.SPECULATE,
        escalate_after=2))

    def clock(comm, state):
        P_now = comm.axis_size()
        slow = 1 if state.geom.m_loc == m_loc else -1
        return {i: (8.0 if i == slow else 1.0) for i in range(P_now)}

    orch = SweepOrchestrator(A, SimComm(P), b, straggler_monitor=mon,
                             lane_clock=clock)
    res = orch.run()
    _assert_close(res.R, np.asarray(ref.R[0]))
    assert len(orch.speculations) >= 2
    assert [t.kind for t in res.transitions] == ["shrink"]


# -- plan / pairing unit coverage --------------------------------------------


def test_pairing_table_matches_butterfly():
    for P in (2, 4, 8, 16):
        table = pairing_table(P)
        assert len(table) == _levels(P)
        for s, perm in enumerate(table):
            assert perm == _xor_perm(P, s)
            assert all(dst == xor_buddy(src, s) for src, dst in perm)


def test_plan_shrink_pad_appends_to_buddy():
    world = LaneWorld(n_slots=4, live=(True,) * 4)
    sources, after, adopter = plan_transition(world, "shrink", (2,),
                                              policy="pad")
    assert adopter == 3  # xor level-0 buddy of 2
    # survivors [0,1,3] renumber compactly; the dead lane's rows are
    # appended to its adopter's slice; slot 3 is a zero-row ghost
    assert sources == [[0], [1], [3, 2], []]
    assert after.n_slots == 4 and after.live == (True, True, True, False)


def test_plan_shrink_fold_resplits():
    world = LaneWorld(n_slots=4, live=(True,) * 4)
    sources, after, _ = plan_transition(world, "shrink", (0,), policy="fold")
    assert after.n_slots == floor_pow2(3) == 2
    assert after.live == (True, True)
    assert sorted(x for src in sources for x in src) == [0, 1, 2, 3]


def test_plan_blank_keeps_hole():
    world = LaneWorld(n_slots=4, live=(True,) * 4)
    sources, after, adopter = plan_transition(world, "blank", (1,))
    assert adopter == 0
    assert sources == [[0, 1], [], [2], [3]]
    assert after.live == (True, False, True, True)


def test_harvest_covers_all_padded_rows(ragged_reference):
    """Every unconsumed padded row rides the harvest (pad rows can carry
    trailing-matrix content when m_loc < m_loc_pad) — coverage check of
    the frontier arithmetic."""
    from repro.ft.online.state import (
        deposit_boundary, initial_sweep_state, run_steps)

    A, _ = ragged_reference
    comm = SimComm(RP)
    state = run_steps(comm, initial_sweep_state(comm, A, RB),
                      1 + 2 * RGEOM.levels)  # one whole panel -> (1, leaf)
    state, r = deposit_boundary(comm, state)
    assert r == 1
    blocks, n_cols = harvest_trailing(state, r)
    assert n_cols == RN - RB
    cut = r * RB
    for i, blk in enumerate(blocks):
        consumed = min(max(cut - i * RGEOM.m_loc_pad, 0), RGEOM.m_loc_pad)
        assert blk.shape == (RGEOM.m_loc_pad - consumed, n_cols)
