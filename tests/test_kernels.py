"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


def _allclose(a, b, rtol=3e-4, atol=3e-4):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)


@pytest.mark.parametrize("m,b", [(32, 8), (64, 16), (256, 32), (128, 128)])
@pytest.mark.parametrize("row_start", [0, 8])
def test_panel_qr_sweep(rng, m, b, row_start):
    A = jnp.asarray(rng.standard_normal((m, b)), jnp.float32)
    _allclose(ops.panel_qr(A, row_start), ref.panel_qr(A, row_start))


@pytest.mark.parametrize("b", [8, 16, 64, 128])
def test_stacked_qr_sweep(rng, b):
    R1 = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), jnp.float32))
    R2 = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), jnp.float32))
    _allclose(ops.stacked_qr(R1, R2), ref.stacked_qr(R1, R2))


@pytest.mark.parametrize("m,b,n", [(64, 16, 48), (256, 32, 300), (128, 64, 64)])
def test_wy_apply_sweep(rng, m, b, n):
    Y = jnp.asarray(rng.standard_normal((m, b)), jnp.float32) * 0.1
    T = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), jnp.float32)) * 0.1
    C = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    _allclose(ops.wy_apply(Y, T, C, block_n=64), ref.wy_apply(Y, T, C))


@pytest.mark.parametrize("b,n", [(16, 40), (32, 128), (64, 96)])
def test_stacked_apply_sweep(rng, b, n):
    Y2 = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), jnp.float32)) * 0.1
    T = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), jnp.float32)) * 0.1
    Ct = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    Cb = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    _allclose(
        ops.stacked_apply(Y2, T, Ct, Cb, block_n=32),
        ref.stacked_apply(Y2, T, Ct, Cb),
    )


def test_kernel_panel_consistency_with_core(rng):
    """Kernel output plugs into the same WY algebra as the core path."""
    from repro.core.householder import apply_qt

    A = jnp.asarray(rng.standard_normal((96, 16)), jnp.float32)
    Y, T, R = ops.panel_qr(A, 0)
    QtA = apply_qt(Y, T, A)
    np.testing.assert_allclose(np.asarray(QtA[:16]), np.asarray(R), atol=3e-5)
    assert np.abs(np.asarray(QtA[16:])).max() < 3e-5
