"""Per-kernel shape sweeps vs the pure-jnp oracles, across execution routes.

The per-op policy (DESIGN.md §10) gives every op three executions: compiled
(engine ``pallas`` where the backend lowers it, else ``xla``), the Pallas
interpreter, and the jnp oracle. The sweeps here force each non-oracle mode
in turn and gate it against the oracle at ``ref.tolerances(dtype)``; the
ragged parity matrix adds odd/unaligned shapes and bf16. Native-pallas
cells run only where the capability probe passes (loud skip elsewhere).

Stacked-op inputs are QR-derived R factors, not raw ``triu`` of a Gaussian:
a random upper-triangular matrix is exponentially ill-conditioned (cond
~1e17 at b=64), which would turn an honest reduction-order difference
between two routes into O(1) output differences and gate nothing.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import backend, ops, ref

MODES = [backend.MODE_COMPILED, backend.MODE_INTERPRET]


@pytest.fixture(params=MODES)
def route(request):
    """Force every op to one execution mode; restore the automatic policy."""
    backend.force_mode(request.param)
    yield request.param
    backend.force_mode(None)


def _allclose(a, b, dtype=jnp.float32, scale=1.0):
    rtol, atol = ref.tolerances(dtype)
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32),
            rtol=rtol * scale, atol=atol * scale)


def _qr_factor(rng, b, dtype=jnp.float32):
    """A realistically-conditioned upper-triangular b x b R factor."""
    return jnp.asarray(
        np.linalg.qr(rng.standard_normal((2 * b, b)))[1], dtype)


@pytest.mark.parametrize("m,b", [(32, 8), (64, 16), (256, 32), (128, 128)])
@pytest.mark.parametrize("row_start", [0, 8])
def test_panel_qr_sweep(rng, route, m, b, row_start):
    A = jnp.asarray(rng.standard_normal((m, b)), jnp.float32)
    _allclose(ops.panel_qr(A, row_start), ref.panel_qr(A, row_start))


@pytest.mark.parametrize("b", [8, 16, 64, 128])
def test_stacked_qr_sweep(rng, route, b):
    R1 = _qr_factor(rng, b)
    R2 = _qr_factor(rng, b)
    _allclose(ops.stacked_qr(R1, R2), ref.stacked_qr(R1, R2))


@pytest.mark.parametrize("m,b,n", [(64, 16, 48), (256, 32, 300), (128, 64, 64)])
def test_wy_apply_sweep(rng, route, m, b, n):
    Y = jnp.asarray(rng.standard_normal((m, b)), jnp.float32) * 0.1
    T = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), jnp.float32)) * 0.1
    C = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    _allclose(ops.wy_apply(Y, T, C, block_n=64), ref.wy_apply(Y, T, C))


@pytest.mark.parametrize("b,n", [(16, 40), (32, 128), (64, 96)])
def test_stacked_apply_sweep(rng, route, b, n):
    Y2 = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), jnp.float32)) * 0.1
    T = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), jnp.float32)) * 0.1
    Ct = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    Cb = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    _allclose(
        ops.stacked_apply(Y2, T, Ct, Cb, block_n=32),
        ref.stacked_apply(Y2, T, Ct, Cb),
    )


# -- the parity matrix: route x dtype on odd/ragged shapes -------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("m,b,n", [(30, 12, 17), (9, 5, 11), (37, 12, 25)])
def test_parity_matrix_ragged(rng, route, dtype, m, b, n):
    """Every op, every non-oracle route, f32 AND bf16, at shapes that
    exercise the full padding contract (odd rows, unaligned widths)."""
    A = jnp.asarray(rng.standard_normal((m, b)), dtype)
    _allclose(ops.panel_qr(A, 0), ref.panel_qr(A, 0), dtype=dtype)

    R1, R2 = _qr_factor(rng, b, dtype), _qr_factor(rng, b, dtype)
    _allclose(ops.stacked_qr(R1, R2), ref.stacked_qr(R1, R2), dtype=dtype)

    Y = jnp.asarray(rng.standard_normal((m, b)), dtype) * 0.1
    T = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), dtype)) * 0.1
    C = jnp.asarray(rng.standard_normal((m, n)), dtype)
    _allclose(ops.wy_apply(Y, T, C), ref.wy_apply(Y, T, C), dtype=dtype)

    Ct = jnp.asarray(rng.standard_normal((b, n)), dtype)
    Cb = jnp.asarray(rng.standard_normal((b, n)), dtype)
    _allclose(ops.stacked_apply(T, T, Ct, Cb),
              ref.stacked_apply(T, T, Ct, Cb), dtype=dtype)

    from repro.kernels import fused_sweep as _fused

    W = jnp.asarray(rng.standard_normal((m, b + 7)), dtype)
    _allclose(ops.panel_qr_apply(W, 0, b),
              _fused.panel_qr_apply_ref(W, 0, b), dtype=dtype)


@pytest.mark.parametrize("op", backend.OPS)
def test_native_pallas_parity(rng, op):
    """The pallas engine itself, where this backend lowers it (skipped
    elsewhere — tools/kernel_smoke.py reports which, loudly)."""
    if not backend.compiled_supported(op):
        pytest.skip(f"backend does not lower native Pallas for {op}")
    backend.force_mode(backend.MODE_COMPILED, op)
    try:
        if op == "panel_qr":
            A = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
            _allclose(ops.panel_qr(A, 0), ref.panel_qr(A, 0))
        elif op == "stacked_qr":
            R1, R2 = _qr_factor(rng, 16), _qr_factor(rng, 16)
            _allclose(ops.stacked_qr(R1, R2), ref.stacked_qr(R1, R2))
        elif op == "wy_apply":
            Y = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32) * 0.1
            T = jnp.triu(jnp.asarray(rng.standard_normal((8, 8)),
                                     jnp.float32)) * 0.1
            C = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
            _allclose(ops.wy_apply(Y, T, C), ref.wy_apply(Y, T, C))
        elif op == "stacked_apply":
            T = jnp.triu(jnp.asarray(rng.standard_normal((8, 8)),
                                     jnp.float32)) * 0.1
            Ct = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
            Cb = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
            _allclose(ops.stacked_apply(T, T, Ct, Cb),
                      ref.stacked_apply(T, T, Ct, Cb))
        else:  # fused_sweep
            from repro.kernels import fused_sweep as _fused

            W = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
            _allclose(ops.panel_qr_apply(W, 0, 8),
                      _fused.panel_qr_apply_ref(W, 0, 8))
    finally:
        backend.force_mode(None, op)


def test_kernel_panel_consistency_with_core(rng):
    """Kernel output plugs into the same WY algebra as the core path."""
    from repro.core.householder import apply_qt

    A = jnp.asarray(rng.standard_normal((96, 16)), jnp.float32)
    Y, T, R = ops.panel_qr(A, 0)
    QtA = apply_qt(Y, T, A)
    np.testing.assert_allclose(np.asarray(QtA[:16]), np.asarray(R), atol=3e-5)
    assert np.abs(np.asarray(QtA[16:])).max() < 3e-5
