"""Coded checksum lanes (``repro.ft.coding``): survive ANY f simultaneous
failures, proven exhaustively.

The XOR-buddy redundancy recovers any single death but walls at a
buddy-pair double kill (``test_online_recovery.py`` pins that wall). The
MDS scheme removes it: ``f`` Vandermonde parity slots over GF(2^8) on the
raw bytes of the protected state let the boundary decode reconstruct any
``t <= f`` simultaneously-dead lanes jointly — bit-exactly, because GF
arithmetic on bit patterns is exact. The proof here is the exhaustive
multi-failure matrix: at P=8 EVERY lane pair (all 28, including every
former XOR-buddy pair) is killed at EVERY sweep point of the 14-point
enumeration under ``MDSScheme(f=2)``, and the finished factorization must
be bitwise-identical to the failure-free run, with the multi-source
decode ledger recorded per death. P=16 runs a spot tier inline and the
full 120-pair matrix under ``-m slow``.

Also gated here: the f=1 degeneration (``MDSScheme(f=1)`` routes single
deaths through the XOR path, so ledger and bits are IDENTICAL to
``XORPairScheme`` — the differential gate), the f+1 boundary
(``UnrecoverableFailure`` names the scheme's tolerance), the
monotonically-stronger property (t > f falls back to the per-lane XOR
loop, so nothing the old scheme recovered is lost), the shard_map leg,
and a property suite over random (P, f, kill set, sweep point) draws —
hypothesis-driven when available, a seeded deterministic grid otherwise
(this image has no hypothesis).
"""
import itertools
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import SimComm, sweep_geometry
from repro.ft import (
    FailureSchedule,
    MDSScheme,
    UnrecoverableFailure,
    XORPairScheme,
    ft_caqr_sweep,
    ft_caqr_sweep_online,
    iter_sweep_points,
    sweep_point,
)
from repro.ft.coding import (
    GF_EXP,
    GF_LOG,
    generator,
    gf_inv,
    gf_inv_matrix,
    gf_mul,
    pairing_table,
    xor_buddy,
)
from repro.ft.driver import obliterate_state
from repro.ft.online.detect import ScriptedKiller
from repro.ft.online.state import initial_sweep_state, sweep_step

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # the CI image ships without hypothesis
    HAVE_HYPOTHESIS = False

sys.path.insert(0, os.path.dirname(__file__))
from spmd_subprocess_util import run_forced_devices  # noqa: E402

# P=8 kill-matrix geometry: 2 panels x 7 points, 3 tree levels — every
# phase class (leaf, 3 tsqr ladder levels, 3 trailing levels) appears
P8, M8, N8, B8 = 8, 4, 8, 4
G8 = sweep_geometry(P8, M8, N8, B8)
POINTS8 = list(iter_sweep_points(G8.n_panels, G8.levels))
PAIRS8 = list(itertools.combinations(range(P8), 2))
BUDDY_PAIRS8 = sorted({tuple(sorted(p)) for lvl in pairing_table(P8)
                       for p in lvl})

P16, M16, N16, B16 = 16, 4, 8, 4
G16 = sweep_geometry(P16, M16, N16, B16)
POINTS16 = list(iter_sweep_points(G16.n_panels, G16.levels))
PAIRS16 = list(itertools.combinations(range(P16), 2))


def _matrix(P, m_loc, n, seed=7):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)


def _leaves(*trees):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(trees)]


def _assert_bitwise(got, ref, tag=""):
    for g, r in zip(_leaves(got.R, got.factors, got.bundles),
                    _leaves(ref.R, ref.factors, ref.bundles)):
        assert np.array_equal(g, r), f"{tag}: coded recovery is not bitwise"


def _online(A, P, b, kills, scheme, **kw):
    return ft_caqr_sweep_online(
        A, SimComm(P), b, fault_hooks=[ScriptedKiller(dict(kills))],
        scheme=scheme, **kw)


@pytest.fixture(scope="module")
def ref8():
    A = _matrix(P8, M8, N8)
    return A, ft_caqr_sweep(A, SimComm(P8), B8)


@pytest.fixture(scope="module")
def ref16():
    A = _matrix(P16, M16, N16)
    return A, ft_caqr_sweep(A, SimComm(P16), B16)


# -- the GF(2^8) algebra under the scheme -------------------------------------


def test_gf_field_axioms_spot():
    """The exp/log tables implement GF(2^8): spot-check associativity,
    distributivity over XOR, and multiplicative inverses on a seeded
    sample — the properties the decode's exactness argument stands on."""
    rng = np.random.default_rng(11)
    for _ in range(200):
        a, b, c = (int(x) for x in rng.integers(0, 256, 3))
        assert gf_mul(a, gf_mul(b, c)) == gf_mul(gf_mul(a, b), c)
        assert gf_mul(a, b ^ c) == gf_mul(a, b) ^ gf_mul(a, c)
        if a:
            assert gf_mul(a, gf_inv(a)) == 1
    assert GF_EXP[0] == 1 and GF_LOG[1] == 0


@pytest.mark.parametrize("f", [1, 2, 3])
def test_generator_every_submatrix_invertible(f):
    """The MDS property itself: row 0 is all-ones (the plain XOR checksum
    lane), and EVERY f-column submatrix of the f-row Vandermonde generator
    inverts exactly over GF — so any f erasures are decodable."""
    G = generator(f, P8)
    assert np.all(G[0] == 1)
    for cols in itertools.combinations(range(P8), f):
        M = G[:, list(cols)]
        inv = gf_inv_matrix(M)
        # GF matmul: prod[i,j] = XOR_k M[i,k] * inv[k,j]
        prod = np.zeros((f, f), np.uint8)
        for i in range(f):
            for j in range(f):
                acc = 0
                for k in range(f):
                    acc ^= gf_mul(int(M[i, k]), int(inv[k, j]))
                prod[i, j] = acc
        assert np.array_equal(prod, np.eye(f, dtype=np.uint8)), cols


def test_generator_rejects_oversize_world():
    with pytest.raises(ValueError):
        generator(2, 256)


def test_pairing_table_is_xor_buddy_algebra():
    """The canonical pairing home moved into the coding seam; the table is
    still exactly the per-level XOR-buddy involution."""
    for level, pairs in enumerate(pairing_table(P8)):
        seen = set()
        for a, b in pairs:
            assert xor_buddy(a, level) == b and xor_buddy(b, level) == a
            seen |= {a, b}
        assert seen == set(range(P8))


def test_encode_decode_round_trip_mid_sweep():
    """Byte-level seam check, no driver: encode a mid-sweep state, NaN two
    lanes with the real death mask, decode — every protected leaf restored
    bit for bit (uint/bool bookkeeping is untouched by design)."""
    comm = SimComm(P8)
    state = initial_sweep_state(comm, _matrix(P8, M8, N8), B8)
    for _ in range(5):
        state = sweep_step(comm, state)
    scheme = MDSScheme(f=2)
    encoded = scheme.refresh(comm, state)
    struck = encoded
    for lane in (2, 3):
        struck = obliterate_state(comm, struck, lane)
    decoded, reads = scheme.decode_lanes(comm, struck, [2, 3], {2, 3})
    for g, r in zip(_leaves(decoded), _leaves(state)):
        if np.issubdtype(r.dtype, np.floating):
            assert np.array_equal(g, r)
    assert reads == {"coded.parity0": P8, "coded.parity1": P8 + 1,
                     "coded.survivor0": 0, "coded.survivor1": 1,
                     "coded.survivor4": 4, "coded.survivor5": 5,
                     "coded.survivor6": 6, "coded.survivor7": 7}


# -- the exhaustive f=2 kill matrix at P=8 ------------------------------------


def _check_pair_kill(A, ref, P, b, pt, pair, scheme, points):
    got = _online(A, P, b, {pt: list(pair)}, scheme)
    _assert_bitwise(got, ref, tag=f"{pt} kill {pair}")
    assert [(e.point, e.lane) for e in got.events] == \
        [(pt, pair[0]), (pt, pair[1])]
    parity_keys = {f"coded.parity{j}": P + j for j in range(scheme.f)}
    survivors = {f"coded.survivor{i}": i
                 for i in range(P) if i not in pair}
    for e in got.events:
        # the multi-source decode ledger: every survivor + every parity
        # slot was read (contrast the XOR path's single-source entries)
        assert e.reads == {**parity_keys, **survivors}, (pt, pair)


@pytest.mark.parametrize("pt", POINTS8, ids=lambda p: f"{p[0]}-{p[1]}-{p[2]}")
def test_exhaustive_pair_kill_matrix_p8(ref8, pt):
    """THE tentpole gate: every one of the 28 lane pairs — every former
    XOR-buddy pair included — killed simultaneously at this sweep point,
    recovered by the joint GF decode, and the finished factorization is
    bitwise-identical to the failure-free run with the full multi-source
    ledger recorded. Parametrized over all 14 sweep points: 392 double
    kills total, zero tolerance."""
    A, ref = ref8
    scheme = MDSScheme(f=2)
    for pair in PAIRS8:
        _check_pair_kill(A, ref, P8, B8, pt, pair, scheme, POINTS8)


def test_former_buddy_pairs_walled_on_xor_p8(ref8):
    """Regression keep: under the default XOR scheme the SAME buddy-pair
    schedules still raise UnrecoverableFailure — the wall the coded lanes
    remove is real, not an artifact of the new tests."""
    A, _ = ref8
    pt = sweep_point(1, "trailing", 0)
    for pair in BUDDY_PAIRS8[:3]:
        with pytest.raises(UnrecoverableFailure):
            _online(A, P8, B8, {pt: list(pair)}, XORPairScheme())


def test_triple_kill_under_f3_p8(ref8):
    """f is a real knob: MDSScheme(f=3) decodes three simultaneous deaths
    — including a whole buddy *group* — bitwise."""
    A, ref = ref8
    scheme = MDSScheme(f=3)
    for pt, trip in [
        (sweep_point(0, "tsqr", 1), (0, 1, 2)),       # buddy pair + one
        (sweep_point(1, "trailing", 0), (2, 3, 7)),   # the acceptance pair
        (sweep_point(1, "leaf", 0), (4, 5, 6)),
    ]:
        got = _online(A, P8, B8, {pt: list(trip)}, scheme)
        _assert_bitwise(got, ref, tag=f"f3 {pt} {trip}")


def test_f_plus_one_deaths_name_the_boundary(ref8):
    """UnrecoverableFailure is now the f+1 boundary: t > f with no XOR
    escape raises an error that names the scheme's tolerance."""
    A, _ = ref8
    pt = sweep_point(1, "trailing", 0)
    with pytest.raises(UnrecoverableFailure, match="f=2"):
        _online(A, P8, B8, {pt: [0, 1, 2]}, MDSScheme(f=2))
    with pytest.raises(UnrecoverableFailure, match="f=1"):
        _online(A, P8, B8, {pt: [2, 3]}, MDSScheme(f=1))


def test_t_exceeding_f_still_falls_back_to_xor(ref8):
    """Monotonically stronger, never weaker: three simultaneous deaths
    under f=2 exceed the joint decode, but each dead lane still has a live
    XOR source, so the per-lane fallback recovers — exactly what the old
    scheme could do."""
    A, ref = ref8
    pt = sweep_point(0, "trailing", 0)
    got = _online(A, P8, B8, {pt: [0, 2, 4]}, MDSScheme(f=2))
    _assert_bitwise(got, ref, tag="xor fallback t=3>f=2")
    # the fallback ledger is the XOR single-source one, not the decode's
    assert all("coded.parity0" not in e.reads for e in got.events)


# -- P=16: spot tier-1, full matrix slow --------------------------------------


def test_pair_kill_spot_p16(ref16):
    """P=16 spot coverage at tier-1: a buddy pair, a cross-half pair, and
    the lowest/highest lanes, at one point of each phase class."""
    A, ref = ref16
    scheme = MDSScheme(f=2)
    for pt in [sweep_point(0, "leaf", 0), sweep_point(0, "tsqr", 2),
               sweep_point(1, "trailing", 1)]:
        for pair in [(4, 5), (0, 9), (0, 15)]:
            _check_pair_kill(A, ref, P16, B16, pt, pair, scheme, POINTS16)


@pytest.mark.slow
def test_exhaustive_pair_kill_matrix_p16(ref16):
    """The full 120-pair x every-sweep-point matrix at P=16 (slow tier)."""
    A, ref = ref16
    scheme = MDSScheme(f=2)
    for pt in POINTS16:
        for pair in PAIRS16:
            _check_pair_kill(A, ref, P16, B16, pt, pair, scheme, POINTS16)


# -- the f=1 differential gate: MDSScheme(f=1) == XORPairScheme ---------------


@pytest.mark.parametrize("shape", [
    ("aligned", 8, 16, 4), ("ragged", 6, 10, 4), ("wide", 4, 24, 4),
], ids=lambda s: s[0])
def test_mds_f1_bitwise_equals_xor(shape):
    """At f=1 the hybrid rule routes every single death through the XOR
    rebuild path, so MDSScheme(f=1) is indistinguishable from
    XORPairScheme — same bits AND same single-source read ledger — on
    aligned, ragged, and wide geometries, scheduled and online."""
    _, m_loc, n, b = shape
    P, comm = 4, SimComm(4)
    A = _matrix(4, m_loc, n, seed=5)
    n_panels = sweep_geometry(4, m_loc, n, b).n_panels
    pt = sweep_point(min(1, n_panels - 1), "trailing", 0)
    sched = FailureSchedule(events={pt: [2]})
    for tag, run in [
        ("scheduled", lambda s: ft_caqr_sweep(A, comm, b, schedule=sched,
                                              scheme=s)),
        ("online", lambda s: _online(A, P, b, {pt: [2]}, s)),
    ]:
        x = run(XORPairScheme())
        m = run(MDSScheme(f=1))
        _assert_bitwise(m, x, tag=f"f1-diff {tag}")
        assert [(e.point, e.lane, e.reads) for e in x.events] == \
            [(e.point, e.lane, e.reads) for e in m.events], tag
        # the f=1 ledger is single-source: no coded.* reads anywhere
        assert all(not k.startswith("coded.")
                   for e in m.events for k in e.reads), tag


def test_scheduled_equals_online_mds_acceptance():
    """The ISSUE acceptance schedule on the ragged 4-lane geometry: the
    former-buddy-pair kill that raises under XOR recovers under
    MDSScheme(f=2), and the scheduled (trace-time) run is bitwise-equal
    to the online (runtime-detected) one and to the failure-free sweep."""
    P, m_loc, n, b = 4, 6, 10, 4
    A = _matrix(P, m_loc, n, seed=3)
    comm = SimComm(P)
    pt = sweep_point(1, "trailing", 0)
    free = ft_caqr_sweep(A, comm, b)
    with pytest.raises(UnrecoverableFailure):
        ft_caqr_sweep(A, comm, b, schedule=FailureSchedule(events={pt: [2, 3]}))
    sched = ft_caqr_sweep(A, comm, b,
                          schedule=FailureSchedule(events={pt: [2, 3]}),
                          scheme=MDSScheme(f=2))
    onl = _online(A, P, b, {pt: [2, 3]}, MDSScheme(f=2))
    _assert_bitwise(sched, free, tag="scheduled vs free")
    _assert_bitwise(onl, free, tag="online vs free")
    assert [(e.point, e.lane, e.reads) for e in sched.events] == \
        [(e.point, e.lane, e.reads) for e in onl.events]


def test_mds_shard_map_differential():
    """The shard_map leg: the same buddy-pair kill under MDSScheme(f=2)
    on a 4-device mesh — scheduled trace AND online segments — matches
    the SimComm run leaf for leaf."""
    out = run_forced_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import SimComm
        from repro.ft import FailureSchedule, MDSScheme, ft_caqr_sweep, \\
            sweep_point
        from repro.ft.online.detect import ScriptedKiller
        from repro.launch.spmd_qr import (
            ft_caqr_sweep_online_spmd, ft_caqr_sweep_spmd, make_lane_mesh)

        P_, m_loc, n, b = 4, 6, 10, 4
        rng = np.random.default_rng(3)
        A = jnp.asarray(rng.standard_normal((P_ * m_loc, n)), jnp.float32)
        pt = sweep_point(1, "trailing", 0)
        sched = FailureSchedule(events={pt: [2, 3]})
        mesh = make_lane_mesh(4)
        sim = ft_caqr_sweep(A.reshape(P_, m_loc, n), SimComm(P_), b,
                            schedule=sched, scheme=MDSScheme(f=2))
        for tag, got in [
            ("scheduled", ft_caqr_sweep_spmd(
                A, b, schedule=sched, mesh=mesh, scheme=MDSScheme(f=2))),
            ("online", ft_caqr_sweep_online_spmd(
                A, b, mesh=mesh, fault_hooks=[ScriptedKiller({pt: [2, 3]})],
                scheme=MDSScheme(f=2))),
        ]:
            gl = jax.tree_util.tree_leaves((got.R, got.factors, got.bundles))
            sl = jax.tree_util.tree_leaves((sim.R, sim.factors, sim.bundles))
            assert len(gl) == len(sl)
            for g, s in zip(gl, sl):
                assert np.array_equal(np.asarray(g), np.asarray(s)), tag
            print("OK", tag)
        print("MDS_SPMD_OK")
    """, n_devices=4)
    assert "MDS_SPMD_OK" in out


# -- property suite: random (P, f, kill set, point) draws ---------------------

_PROP_REFS = {}


def _property_check(P, f, kill, pt_idx):
    """One property-suite draw: a kill set of size <= f at a drawn sweep
    point must finish bitwise-identical to the failure-free run."""
    m_loc, n, b = 4, 8, 4
    if P not in _PROP_REFS:
        A = _matrix(P, m_loc, n, seed=17 + P)
        _PROP_REFS[P] = (A, ft_caqr_sweep(A, SimComm(P), b))
    A, ref = _PROP_REFS[P]
    geom = sweep_geometry(P, m_loc, n, b)
    points = list(iter_sweep_points(geom.n_panels, geom.levels))
    pt = points[pt_idx % len(points)]
    got = _online(A, P, b, {pt: sorted(kill)}, MDSScheme(f=f))
    _assert_bitwise(got, ref, tag=f"prop P={P} f={f} {pt} kill={kill}")


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(data=st.data())
    def test_property_any_kill_set_within_f(data):
        P = data.draw(st.sampled_from([4, 8]))
        f = data.draw(st.integers(min_value=1, max_value=3))
        t = data.draw(st.integers(min_value=1, max_value=f))
        kill = data.draw(st.sets(st.integers(0, P - 1),
                                 min_size=t, max_size=t))
        pt_idx = data.draw(st.integers(min_value=0, max_value=30))
        _property_check(P, f, kill, pt_idx)

else:

    _GRID_RNG = np.random.default_rng(2026)
    _GRID = []
    for _P in (4, 8):
        for _f in (1, 2, 3):
            for _ in range(3):
                _t = int(_GRID_RNG.integers(1, _f + 1))
                _kill = tuple(sorted(_GRID_RNG.choice(_P, _t, replace=False)))
                _GRID.append((_P, _f, _kill, int(_GRID_RNG.integers(0, 31))))

    @pytest.mark.parametrize("P,f,kill,pt_idx", _GRID,
                             ids=[f"P{p}-f{f}-k{'_'.join(map(str, k))}"
                                  for p, f, k, _ in _GRID])
    def test_property_any_kill_set_within_f(P, f, kill, pt_idx):
        """Deterministic stand-in for the hypothesis suite (the image has
        no hypothesis): a seeded grid of 18 random draws over the same
        strategy space — any kill set of size <= f, anywhere in the sweep,
        finishes bitwise-identical to the failure-free run."""
        _property_check(P, f, [int(k) for k in kill], pt_idx)


def test_scheme_validation():
    with pytest.raises(ValueError):
        MDSScheme(f=0)
    with pytest.raises(ValueError):
        MDSScheme(f=9)
    assert MDSScheme(f=2).name == "mds" and XORPairScheme().f == 1
