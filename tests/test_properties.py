"""Hypothesis property tests on the system's invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not in this image")
from hypothesis import given, settings, strategies as st

from repro.core import SimComm, caqr_factorize, ft_tsqr, householder_qr, q_dense
from repro.core import recovery as rec
from repro.data.pipeline import DataConfig, make_batch

_SETTINGS = dict(max_examples=20, deadline=None)


@settings(**_SETTINGS)
@given(
    m_pow=st.integers(3, 6),
    n_pow=st.integers(1, 4),
    seed=st.integers(0, 2**16),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_qr_gram_invariant(m_pow, n_pow, seed, scale):
    """R^T R == A^T A for any well-formed input, across magnitudes."""
    m, n = 2**m_pow, 2**n_pow
    if n > m:
        n = m
    A = jnp.asarray(
        np.random.default_rng(seed).standard_normal((m, n)) * scale, jnp.float32
    )
    wy = householder_qr(A)
    G = np.asarray(A).T @ np.asarray(A)
    R = np.asarray(wy.R)
    tol = 5e-5 * max(np.abs(G).max(), 1e-30)
    assert np.abs(R.T @ R - G).max() <= tol * 64


@settings(**_SETTINGS)
@given(m_pow=st.integers(3, 5), n_pow=st.integers(1, 3), seed=st.integers(0, 2**16))
def test_q_orthogonality_invariant(m_pow, n_pow, seed):
    m, n = 2**m_pow, 2**n_pow
    A = jnp.asarray(
        np.random.default_rng(seed).standard_normal((m, n)), jnp.float32
    )
    wy = householder_qr(A)
    Q = np.asarray(q_dense(wy.Y, wy.T))
    assert np.abs(Q.T @ Q - np.eye(m)).max() < 1e-4


@settings(**_SETTINGS)
@given(
    p_pow=st.integers(1, 4),
    seed=st.integers(0, 2**16),
)
def test_ft_tsqr_replication_invariant(p_pow, seed):
    """Paper §III-B: after the butterfly, EVERY lane holds the identical R —
    for any power-of-two lane count."""
    P = 2**p_pow
    comm = SimComm(P)
    A = jnp.asarray(
        np.random.default_rng(seed).standard_normal((P, 16, 8)), jnp.float32
    )
    fac = ft_tsqr(A, comm)
    R = np.asarray(fac.R)
    assert np.all(R == R[0])


@settings(**_SETTINGS)
@given(
    failed=st.integers(0, 7),
    level=st.integers(0, 2),
    seed=st.integers(0, 2**16),
)
def test_recovery_invariant(failed, level, seed):
    """Any (lane, level) failure recovers exactly from one source."""
    P = 8
    comm = SimComm(P)
    g = np.random.default_rng(seed)
    A = jnp.asarray(g.standard_normal((P, 16, 4)), jnp.float32)
    C = jnp.asarray(g.standard_normal((P, 16, 8)), jnp.float32)
    fac = ft_tsqr(A, comm)
    clean = rec.run_ft_trailing(C, fac, comm)
    faulty = rec.run_ft_trailing(
        C, fac, comm, fail_at_level=level, failed_lane=failed, A_stacked=C
    )
    assert np.array_equal(np.asarray(clean), np.asarray(faulty))


@settings(**_SETTINGS)
@given(step=st.integers(0, 1000), seed=st.integers(0, 2**10))
def test_data_determinism_invariant(step, seed):
    """batch(seed, step) is a pure function — the property checkpoint/replay
    correctness rests on."""
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=4, seed=seed)
    b1 = make_batch(cfg, step)
    b2 = make_batch(cfg, step)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert np.array_equal(b1["labels"], b2["labels"])
    # shifted-by-one label structure
    full1 = np.concatenate([b1["tokens"], b1["labels"][:, -1:]], axis=1)
    assert np.array_equal(full1[:, 1:], b1["labels"])


@settings(max_examples=10, deadline=None)
@given(
    n_pow=st.integers(2, 4),
    seed=st.integers(0, 2**16),
)
def test_caqr_r_sign_canonical_invariant(n_pow, seed):
    """|diag| of CAQR's R matches LAPACK's for random matrices."""
    P, m_loc, b = 4, 16, 4
    n = 4 * 2**n_pow
    comm = SimComm(P)
    A = jnp.asarray(
        np.random.default_rng(seed).standard_normal((P, m_loc, n)), jnp.float32
    )
    res = caqr_factorize(A, comm, b)
    Rr = np.linalg.qr(np.asarray(A).reshape(-1, n), mode="r")
    d1 = np.abs(np.diag(np.asarray(res.R[0])))
    d2 = np.abs(np.diag(Rr))
    np.testing.assert_allclose(d1, d2, rtol=1e-3, atol=1e-4)
