"""Windowed right-looking sweep regression: bit-identical results.

The windowed sweep (``use_scan=False``, the default-windowed unrolled path)
must produce exactly — bit for bit — the R, panel factors and (live-window)
recovery bundles of the seed's full-width sweep, on tall and square, aligned
and kernel-unaligned shapes. The only permitted difference is the zeroed
dead-column region of the bundles (those columns were finished panels; they
need no recovery)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import SimComm, caqr_apply_qt, caqr_factorize


SHAPES = [
    (4, 16, 32, 4),    # tall
    (8, 16, 128, 8),   # square (full target-lane rotation + dead lanes)
    (8, 32, 64, 8),
    (4, 32, 128, 8),   # square, multi-panel per lane
    (2, 48, 48, 12),   # kernel-unaligned b, square
]


@pytest.mark.parametrize("P,m_loc,n,b", SHAPES)
def test_windowed_bit_identical_r(rng, P, m_loc, n, b):
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    full = caqr_factorize(A, comm, b, use_scan=False, windowed=False)
    win = caqr_factorize(A, comm, b, use_scan=False, windowed=True)
    assert np.array_equal(np.asarray(full.R), np.asarray(win.R))
    for f, w in zip(full.factors, win.factors):
        assert np.array_equal(np.asarray(f), np.asarray(w))


@pytest.mark.parametrize("P,m_loc,n,b", SHAPES[:3])
def test_windowed_bit_identical_bundles(rng, P, m_loc, n, b):
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    full = caqr_factorize(A, comm, b, collect_bundles=True,
                          use_scan=False, windowed=False)
    win = caqr_factorize(A, comm, b, collect_bundles=True,
                         use_scan=False, windowed=True)
    for name in ("W", "C_self", "C_buddy"):
        bw = np.asarray(getattr(win.bundles, name))
        bf = np.asarray(getattr(full.bundles, name))
        assert bw.shape == bf.shape
        for k in range(n // b):
            # live window identical, dead columns zeroed
            assert np.array_equal(bw[k][..., k * b:], bf[k][..., k * b:])
            assert not np.any(bw[k][..., :k * b])
    for name in ("Y2", "T", "self_was_top"):
        assert np.array_equal(
            np.asarray(getattr(win.bundles, name)),
            np.asarray(getattr(full.bundles, name)),
        )


def test_windowed_matches_scan_path(rng):
    """The compile-friendly scan sweep and the windowed unrolled sweep agree
    on R (the scan path is the seed oracle)."""
    P, m_loc, n, b = 8, 16, 64, 8
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    scan = caqr_factorize(A, comm, b, use_scan=True)
    win = caqr_factorize(A, comm, b, use_scan=False)
    np.testing.assert_allclose(
        np.asarray(scan.R), np.asarray(win.R), rtol=1e-6, atol=1e-6
    )


def test_windowed_against_lapack_and_gram(rng):
    P, m_loc, n, b = 8, 16, 64, 8
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    res = caqr_factorize(A, comm, b, use_scan=False)
    Af = np.asarray(A).reshape(-1, n)
    Rc = np.asarray(res.R[0])
    assert np.all(np.asarray(res.R) == Rc)  # FT broadcast property intact
    G = Af.T @ Af
    np.testing.assert_allclose(Rc.T @ Rc, G, atol=2e-3 * np.abs(G).max())


def test_windowed_implicit_q_replay(rng):
    """Factors from the windowed sweep replay correctly (orthogonality of
    the stored implicit Q is unchanged by the windowing)."""
    P, m_loc, n, b = 8, 16, 64, 8
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    res = caqr_factorize(A, comm, b, use_scan=False)
    QtA = caqr_apply_qt(A, res.factors, comm)
    Af = np.asarray(A).reshape(-1, n)
    Qf = np.asarray(QtA).reshape(-1, n)
    np.testing.assert_allclose(
        Qf.T @ Qf, Af.T @ Af, atol=2e-3 * np.abs(Af.T @ Af).max()
    )


def test_windowed_requires_unrolled():
    comm = SimComm(2)
    A = jnp.zeros((2, 8, 16), jnp.float32)
    with pytest.raises(AssertionError):
        caqr_factorize(A, comm, 4, use_scan=True, windowed=True)
