"""Online recovery: the reified sweep state machine under runtime detection.

The scheduled (trace-time ``FailureSchedule``) driver is the differential
oracle throughout: iterating ``sweep_step`` to completion must be
bit-identical to the monolithic sweep, and a *runtime-detected* kill —
poison injected at a segment boundary, discovered by the NaN-sentinel
probe, rebuilt by the orchestrator — must produce output bit-identical to
the same kill expressed as a trace-time schedule (and hence to the
failure-free sweep). Also covered: two failures in different panels, a
detector false-negative surfacing one segment late, suspend/persist/resume
through ``repro.ckpt`` (numpy round-trip), and the diskless snapshot store.
"""
import functools
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import SimComm, caqr_factorize, sweep_geometry
from repro.ckpt import load_sweep_state, save_sweep_state
from repro.ckpt.diskless import SweepStateStore
from repro.ft import (
    FailureSchedule,
    SweepOrchestrator,
    UnrecoverableFailure,
    ft_caqr_sweep,
    ft_caqr_sweep_online,
    iter_sweep_points,
    sweep_point,
)
from repro.ft.failures import LaneFailure, next_sweep_point, prev_sweep_point
from repro.ft.online.detect import (
    DelayedDetector,
    FailStopDetector,
    NaNSentinelDetector,
    ScriptedKiller,
    WallClockKiller,
)
from repro.ft.online.state import (
    finalize,
    initial_sweep_state,
    sweep_state_from_host,
    sweep_state_to_host,
    sweep_step,
)
from repro.ft.semantics import Semantics

# the PR-3 ragged geometry: unaligned lane heights AND a ragged last panel
RP, RM_LOC, RN, RB = 4, 6, 10, 4
RGEOM = sweep_geometry(RP, RM_LOC, RN, RB)
LEVELS = 2
R_POINTS = list(iter_sweep_points(RGEOM.n_panels, LEVELS))


def _matrix(P=RP, m_loc=RM_LOC, n=RN, seed=3):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)


def _leaves(*trees):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(trees)]


def _assert_bit_identical(got, ref):
    for g, r in zip(_leaves(got.R, got.factors, got.bundles),
                    _leaves(ref.R, ref.factors, ref.bundles)):
        assert np.array_equal(g, r), "online output differs from oracle"


def _assert_same_events(got, sched):
    assert [(e.point, e.lane, e.reads) for e in got.events] == \
        [(e.point, e.lane, e.reads) for e in sched.events]


@pytest.fixture(scope="module")
def ragged_reference():
    A = _matrix()
    ref = caqr_factorize(A, SimComm(RP), RB, collect_bundles=True,
                         use_scan=False)
    return A, ref


# -- the state machine itself ------------------------------------------------


@pytest.mark.parametrize("shape", [
    ("aligned", 8, 16, 4), ("ragged", RM_LOC, RN, RB), ("wide", 4, 24, 4),
], ids=lambda s: s[0])
def test_stepped_iteration_matches_monolithic(shape):
    """Iterating jitted sweep_step to completion + finalize == the
    monolithic windowed sweep, bit for bit, on every geometry class."""
    _, m_loc, n, b = shape
    comm = SimComm(4)
    A = _matrix(4, m_loc, n, seed=5)
    ref = caqr_factorize(A, comm, b, collect_bundles=True, use_scan=False)
    step = jax.jit(functools.partial(sweep_step, comm))
    s = initial_sweep_state(comm, A, b)
    points = []
    while s.cursor is not None:
        points.append(s.cursor)
        s = step(s)
    assert points == list(iter_sweep_points(s.geom.n_panels, LEVELS))
    R, factors, bundles = finalize(comm, s)
    for g, r in zip(_leaves(R, factors, bundles),
                    _leaves(ref.R, ref.factors, ref.bundles)):
        assert np.array_equal(g, r)


def test_cursor_arithmetic_round_trip():
    """next/prev sweep-point are inverse over the whole enumeration."""
    pts = R_POINTS
    for a, b_ in zip(pts, pts[1:] + [None]):
        assert next_sweep_point(a, RGEOM.n_panels, LEVELS) == b_
        assert prev_sweep_point(b_, RGEOM.n_panels, LEVELS) == a
    assert prev_sweep_point(pts[0], RGEOM.n_panels, LEVELS) is None


# -- orchestrator: failure-free + the online kill matrix ---------------------


def test_orchestrator_failure_free(ragged_reference):
    A, ref = ragged_reference
    got = SweepOrchestrator(A, SimComm(RP), RB).run()
    _assert_bit_identical(got, ref)
    assert got.events == []


@pytest.mark.parametrize("lane", [0, 1, 3])
@pytest.mark.parametrize("point", R_POINTS,
                         ids=lambda p: f"p{p[0]}-{p[1]}{p[2]}")
def test_online_kill_matrix_ragged(ragged_reference, point, lane):
    """Every phase/level/panel of the ragged sweep: a runtime kill at the
    boundary, discovered by the NaN sentinel, is bit-identical to the same
    kill as a trace-time FailureSchedule (and to failure-free)."""
    A, ref = ragged_reference
    got = ft_caqr_sweep_online(
        A, SimComm(RP), RB, fault_hooks=[ScriptedKiller({point: [lane]})])
    _assert_bit_identical(got, ref)
    sched = ft_caqr_sweep(A, SimComm(RP), RB,
                          schedule=FailureSchedule(events={point: [lane]}))
    _assert_same_events(got, sched)
    (event,) = got.events
    assert event.point == point and event.lane == lane
    assert all(src != lane for src in event.reads.values())


@pytest.mark.parametrize("geom", [
    ("aligned", 8, 16, 4, sweep_point(2, "trailing", 1), 2),
    ("wide", 4, 24, 4, sweep_point(2, "tsqr", 0), 1),
], ids=lambda g: g[0])
def test_online_kill_other_geometries(geom):
    _, m_loc, n, b, point, lane = geom
    comm = SimComm(4)
    A = _matrix(4, m_loc, n, seed=7)
    ref = caqr_factorize(A, comm, b, collect_bundles=True, use_scan=False)
    got = ft_caqr_sweep_online(
        A, comm, b, fault_hooks=[ScriptedKiller({point: [lane]})])
    _assert_bit_identical(got, ref)
    sched = ft_caqr_sweep(A, comm, b,
                          schedule=FailureSchedule(events={point: [lane]}))
    _assert_same_events(got, sched)


def test_online_two_failures_in_different_panels(ragged_reference):
    A, ref = ragged_reference
    kills = {sweep_point(0, "trailing", 1): [2], sweep_point(1, "tsqr", 0): [1]}
    got = ft_caqr_sweep_online(
        A, SimComm(RP), RB, fault_hooks=[ScriptedKiller(kills)])
    _assert_bit_identical(got, ref)
    sched = ft_caqr_sweep(A, SimComm(RP), RB,
                          schedule=FailureSchedule(events=kills))
    _assert_same_events(got, sched)
    assert len(got.events) == 2


def test_online_same_lane_dies_twice_same_panel(ragged_reference):
    """The lane dies mid-trailing, is rebuilt, and dies AGAIN one level
    later in the same panel. The rebuild must fully heal the lane — a
    stale NaN (e.g. the running tsqr R) would keep its sentinel dark and
    the second death would go undetected until survivors were
    contaminated (regression for exactly that bug)."""
    A, ref = ragged_reference
    kills = {sweep_point(1, "trailing", 0): [2],
             sweep_point(1, "trailing", 1): [2]}
    got = ft_caqr_sweep_online(
        A, SimComm(RP), RB, fault_hooks=[ScriptedKiller(kills)])
    _assert_bit_identical(got, ref)
    sched = ft_caqr_sweep(A, SimComm(RP), RB,
                          schedule=FailureSchedule(events=kills))
    _assert_same_events(got, sched)
    assert len(got.events) == 2


def test_rebuilt_state_carries_no_nan(ragged_reference):
    """After any REBUILD the state is NaN-free — the invariant the
    sentinel detector's re-arming relies on (checked via the deep scan at
    every boundary of a multi-death run)."""
    from repro.ft.online.detect import _deep_nan_lanes

    A, _ = ragged_reference
    comm = SimComm(RP)
    killer = ScriptedKiller({sweep_point(1, "trailing", 0): [2],
                             sweep_point(2, "tsqr", 1): [0]})
    seen_clean = []

    def audit(comm_, state):
        state = killer(comm_, state)
        seen_clean.append(True)
        return state

    orch = SweepOrchestrator(A, comm, RB, fault_hooks=[audit])
    orch.run()
    assert not _deep_nan_lanes(comm, orch.state)
    assert seen_clean


def test_online_simultaneous_non_buddy_deaths(ragged_reference):
    A, ref = ragged_reference
    point = sweep_point(1, "trailing", 0)
    got = ft_caqr_sweep_online(
        A, SimComm(RP), RB, fault_hooks=[ScriptedKiller({point: [0, 3]})])
    _assert_bit_identical(got, ref)
    assert len(got.events) == 2


def test_online_buddy_pair_death_is_unrecoverable():
    """Both members of a level-0 pair die at once: discovered at the same
    boundary, and the REBUILD honestly refuses (the single source is dead)."""
    A = _matrix()
    point = sweep_point(1, "trailing", 0)
    with pytest.raises(UnrecoverableFailure):
        ft_caqr_sweep_online(
            A, SimComm(RP), RB, fault_hooks=[ScriptedKiller({point: [2, 3]})])


def test_detector_false_negative_one_segment_late(ragged_reference):
    """The detector misses the death once; it surfaces one segment later
    (after the lane-local leaf segment) and recovery at the *later*
    boundary is bit-identical to a schedule that kills there."""
    A, ref = ragged_reference
    killer = ScriptedKiller({sweep_point(0, "trailing", 1): [2]})
    det = DelayedDetector(NaNSentinelDetector(), miss=1)
    got = ft_caqr_sweep_online(
        A, SimComm(RP), RB, detector=det, fault_hooks=[killer])
    _assert_bit_identical(got, ref)
    # attributed to the boundary where it was *found*, one point later
    late_point = sweep_point(1, "leaf")
    sched = ft_caqr_sweep(
        A, SimComm(RP), RB,
        schedule=FailureSchedule(events={late_point: [2]}))
    _assert_same_events(got, sched)
    assert got.events[0].point == late_point


def test_fail_stop_detector_report_delay(ragged_reference):
    """The injectable fail-stop detector: declared deaths surface after
    report_delay polls — delay 0 equals the sentinel path bitwise."""
    A, ref = ragged_reference
    point = sweep_point(1, "trailing", 1)
    det = FailStopDetector(report_delay=0)
    killer = ScriptedKiller({point: [3]})

    def kill_and_declare(comm, state):
        before = len(killer._fired)
        state = killer(comm, state)
        if len(killer._fired) > before:
            det.declare(3)
        return state

    got = ft_caqr_sweep_online(
        A, SimComm(RP), RB, detector=det, fault_hooks=[kill_and_declare])
    _assert_bit_identical(got, ref)
    assert [(e.point, e.lane) for e in got.events] == [(point, 3)]


def test_nan_sentinel_deep_scan(ragged_reference):
    """The deep (every-leaf) scan finds the same death the cheap sentinel
    probe does, end to end."""
    A, ref = ragged_reference
    point = sweep_point(2, "tsqr", 1)
    got = ft_caqr_sweep_online(
        A, SimComm(RP), RB, detector=NaNSentinelDetector(deep=True),
        fault_hooks=[ScriptedKiller({point: [1]})])
    _assert_bit_identical(got, ref)
    assert [(e.point, e.lane) for e in got.events] == [(point, 1)]


def test_segmented_execution_and_boundary_kill(ragged_reference):
    """segment_points > 1: fewer boundaries, same bits; a kill at a segment
    boundary recovers exactly like the scheduled oracle."""
    A, ref = ragged_reference
    orch = SweepOrchestrator(A, SimComm(RP), RB, segment_points=3)
    got = orch.run()
    _assert_bit_identical(got, ref)
    assert orch.segments_run == -(-len(R_POINTS) // 3)
    point = R_POINTS[2]  # just-completed at the first 3-point boundary
    got = ft_caqr_sweep_online(
        A, SimComm(RP), RB, segment_points=3,
        fault_hooks=[ScriptedKiller({point: [1]})])
    _assert_bit_identical(got, ref)
    sched = ft_caqr_sweep(A, SimComm(RP), RB,
                          schedule=FailureSchedule(events={point: [1]}))
    _assert_same_events(got, sched)


def test_abort_semantics_raises(ragged_reference):
    A, _ = ragged_reference
    point = sweep_point(0, "tsqr", 0)
    with pytest.raises(LaneFailure):
        ft_caqr_sweep_online(
            A, SimComm(RP), RB, semantics=Semantics.ABORT,
            fault_hooks=[ScriptedKiller({point: [1]})])


def test_wall_clock_killer(ragged_reference, fake_clock):
    """The unscripted demo path: the kill position is chosen by the clock;
    wherever it lands, the finished factorization is bit-identical. The
    injected fake clock (1s per boundary) makes the strike position
    deterministic — no dependence on host load."""
    A, ref = ragged_reference
    killer = WallClockKiller(after_s=3.0, lane=2, clock=fake_clock)
    got = ft_caqr_sweep_online(A, SimComm(RP), RB, fault_hooks=[killer])
    _assert_bit_identical(got, ref)
    # clock reads 0,1,2,3,... at consecutive boundaries: strike lands
    # exactly when 3.0s have "elapsed" — the 4th boundary, point index 3
    assert killer.struck_at == R_POINTS[3]
    assert [(e.point, e.lane) for e in got.events] == [(killer.struck_at, 2)]


# -- suspend / persist / resume ----------------------------------------------


def test_suspend_resume_npz_round_trip(tmp_path, ragged_reference):
    """Suspend mid-sweep to an .npz, reload (numpy-only round trip), resume
    in a fresh state machine: bit-identical finish. Exercises the
    repro.ckpt wire format the way a new process would."""
    A, ref = ragged_reference
    comm = SimComm(RP)
    s = initial_sweep_state(comm, A, RB)
    for _ in range(7):
        s = sweep_step(comm, s)
    cursor_at_save = s.cursor
    path = save_sweep_state(os.path.join(str(tmp_path), "mid_sweep"), s)

    # host-side inspection needs no device arrays at all
    host = load_sweep_state(path, to_device=False)
    assert host.cursor == cursor_at_save
    assert all(isinstance(x, np.ndarray)
               for x in jax.tree_util.tree_leaves(host))
    assert host.geom == s.geom

    # resume in a fresh orchestrator ("new process": only the file crosses)
    resumed = SweepOrchestrator.from_state(
        load_sweep_state(path), SimComm(RP)).run()
    _assert_bit_identical(resumed, ref)


def test_suspend_resume_with_failure_after_resume(tmp_path, ragged_reference):
    """A lane dies *after* the resume: the restored state carries every
    recovery bundle, so REBUILD still works and still matches the oracle."""
    A, ref = ragged_reference
    comm = SimComm(RP)
    s = initial_sweep_state(comm, A, RB)
    for _ in range(4):
        s = sweep_step(comm, s)
    path = save_sweep_state(os.path.join(str(tmp_path), "mid"), s)
    point = sweep_point(2, "trailing", 0)
    got = SweepOrchestrator.from_state(
        load_sweep_state(path), SimComm(RP),
        fault_hooks=[ScriptedKiller({point: [0]})]).run()
    _assert_bit_identical(got, ref)
    assert [(e.point, e.lane) for e in got.events] == [(point, 0)]


def test_host_wire_format_identity(ragged_reference):
    """to_host/from_host is the identity on arrays, cursor, and geometry."""
    A, _ = ragged_reference
    comm = SimComm(RP)
    s = initial_sweep_state(comm, A, RB)
    for _ in range(9):
        s = sweep_step(comm, s)
    s2 = sweep_state_from_host(sweep_state_to_host(s))
    assert s2.cursor == s.cursor and s2.geom == s.geom
    for a, b_ in zip(_leaves(s), _leaves(s2)):
        assert np.array_equal(a, b_)


def test_diskless_store_snapshot_and_restore(ragged_reference):
    """The orchestrator's persist hook: diskless snapshots every N
    boundaries; a successor restores the latest and finishes bitwise."""
    A, ref = ragged_reference
    store = SweepStateStore(keep=2)
    SweepOrchestrator(A, SimComm(RP), RB, store=store, persist_every=4).run()
    assert len(store) == 2
    assert store.restore().cursor is None  # final boundary also pushed
    mid = store.restore(back=1)
    assert mid.cursor is not None
    got = SweepOrchestrator.from_state(mid, SimComm(RP)).run()
    _assert_bit_identical(got, ref)


# -- slow tier: exhaustive online matrix on the aligned square sweep ---------


@pytest.mark.slow
@pytest.mark.parametrize("lane", range(4))
def test_online_kill_matrix_aligned_exhaustive(lane):
    P, m_loc, n, b = 4, 8, 16, 4
    A = _matrix(P, m_loc, n, seed=0)
    comm = SimComm(P)
    ref = caqr_factorize(A, comm, b, collect_bundles=True, use_scan=False)
    for point in iter_sweep_points(n // b, LEVELS):
        got = ft_caqr_sweep_online(
            A, comm, b, fault_hooks=[ScriptedKiller({point: [lane]})])
        for g, r in zip(_leaves(got.R, got.factors, got.bundles),
                        _leaves(ref.R, ref.factors, ref.bundles)):
            assert np.array_equal(g, r)
