"""Shared harness for multi-device SPMD tests: run a code snippet in a
subprocess with N forced host devices, so the main test process keeps
seeing one device (jax locks the device count at first init)."""
import os
import subprocess
import sys
import textwrap

_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_forced_devices(code: str, n_devices: int, timeout: int = 900) -> str:
    env = {**os.environ,
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={n_devices}",
           "PYTHONPATH": "src"}
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=timeout,
        cwd=_REPO_ROOT,
    )
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout
