"""Least-squares on FT-CAQR + straggler mitigation."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import SimComm
from repro.core.lstsq import caqr_lstsq
from repro.ft.stragglers import StragglerConfig, StragglerMonitor, StragglerPolicy


def test_caqr_lstsq_matches_numpy(rng):
    P, m_loc, n, b = 8, 32, 64, 8
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    bvec = jnp.asarray(rng.standard_normal((P, m_loc, 3)), jnp.float32)
    x = caqr_lstsq(A, bvec, SimComm(P), b)
    Af = np.asarray(A).reshape(-1, n)
    bf = np.asarray(bvec).reshape(-1, 3)
    x_ref, *_ = np.linalg.lstsq(Af, bf, rcond=None)
    np.testing.assert_allclose(np.asarray(x), x_ref, rtol=2e-3, atol=2e-3)


def test_caqr_lstsq_exact_on_consistent_system(rng):
    P, m_loc, n, b = 4, 16, 16, 4
    x_true = rng.standard_normal((n, 2)).astype(np.float32)
    A = rng.standard_normal((P * m_loc, n)).astype(np.float32)
    bvec = A @ x_true
    x = caqr_lstsq(
        jnp.asarray(A.reshape(P, m_loc, n)),
        jnp.asarray(bvec.reshape(P, m_loc, 2)),
        SimComm(P), b,
    )
    np.testing.assert_allclose(np.asarray(x), x_true, rtol=5e-3, atol=5e-3)


def test_straggler_detection_and_rebalance():
    mon = StragglerMonitor(4, StragglerConfig(threshold=1.4, patience=2))
    # lane 2 persistently 2x slower
    actions = []
    for _ in range(4):
        actions = mon.report({0: 1.0, 1: 1.05, 2: 2.2, 3: 0.95})
    assert actions == [2]
    shares = mon.rebalance(2)
    assert shares[2] < 1.0
    assert abs(sum(shares.values()) - 4.0) < 1e-6
    rows = mon.lane_rows(64)
    assert sum(rows.values()) == 64
    assert rows[2] < rows[0]


def test_straggler_no_false_positive():
    mon = StragglerMonitor(4)
    for _ in range(10):
        acts = mon.report({i: 1.0 + 0.05 * i for i in range(4)})
        assert acts == []
