"""Least-squares on FT-CAQR + straggler mitigation."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import SimComm, caqr_factorize
from repro.core.lstsq import caqr_lstsq
from repro.ft.stragglers import StragglerConfig, StragglerMonitor, StragglerPolicy


def test_caqr_lstsq_matches_numpy(rng):
    P, m_loc, n, b = 8, 32, 64, 8
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    bvec = jnp.asarray(rng.standard_normal((P, m_loc, 3)), jnp.float32)
    x = caqr_lstsq(A, bvec, SimComm(P), b)
    Af = np.asarray(A).reshape(-1, n)
    bf = np.asarray(bvec).reshape(-1, 3)
    x_ref, *_ = np.linalg.lstsq(Af, bf, rcond=None)
    np.testing.assert_allclose(np.asarray(x), x_ref, rtol=2e-3, atol=2e-3)


def test_caqr_lstsq_exact_on_consistent_system(rng):
    P, m_loc, n, b = 4, 16, 16, 4
    x_true = rng.standard_normal((n, 2)).astype(np.float32)
    A = rng.standard_normal((P * m_loc, n)).astype(np.float32)
    bvec = A @ x_true
    x = caqr_lstsq(
        jnp.asarray(A.reshape(P, m_loc, n)),
        jnp.asarray(bvec.reshape(P, m_loc, 2)),
        SimComm(P), b,
    )
    np.testing.assert_allclose(np.asarray(x), x_true, rtol=5e-3, atol=5e-3)


def test_caqr_lstsq_reuses_precomputed_factorization(rng):
    """Passing a precomputed CAQRResult skips the re-factorization and gives
    the bit-identical solve (one factorization, many right-hand sides)."""
    P, m_loc, n, b = 4, 16, 32, 4
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    res = caqr_factorize(A, comm, b)
    for k in range(2):
        bvec = jnp.asarray(rng.standard_normal((P, m_loc, 2)), jnp.float32)
        x_fresh = caqr_lstsq(A, bvec, comm, b)
        x_reuse = caqr_lstsq(A, bvec, comm, b, result=res)
        assert np.array_equal(np.asarray(x_fresh), np.asarray(x_reuse))


def test_caqr_lstsq_ragged_matches_numpy(rng):
    """Unaligned lanes + ragged last panel (the sweep_geometry path)."""
    P, m_loc, n, b = 4, 6, 10, 4
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    bvec = jnp.asarray(rng.standard_normal((P, m_loc, 3)), jnp.float32)
    x = caqr_lstsq(A, bvec, SimComm(P), b)
    x_ref, *_ = np.linalg.lstsq(
        np.asarray(A).reshape(-1, n), np.asarray(bvec).reshape(-1, 3),
        rcond=None,
    )
    np.testing.assert_allclose(np.asarray(x), x_ref, rtol=2e-3, atol=2e-3)


def test_caqr_lstsq_wide_basic_solution(rng):
    """Wide system (n > m): caqr_lstsq returns the *basic* solution of
    A = Q [R1 R2] — exact on a consistent system, trailing n-m components
    pinned to zero. This is deliberately NOT the minimum-norm solution
    (that needs a factorization of A^T); documented in lstsq.py/DESIGN.md."""
    P, m_loc, n, b = 2, 4, 12, 4
    m = P * m_loc
    x_true = rng.standard_normal((n, 2)).astype(np.float32)
    A = rng.standard_normal((m, n)).astype(np.float32)
    bvec = A @ x_true
    x = np.asarray(caqr_lstsq(
        jnp.asarray(A.reshape(P, m_loc, n)),
        jnp.asarray(bvec.reshape(P, m_loc, 2)),
        SimComm(P), b,
    ))
    assert x.shape == (n, 2)
    assert np.all(x[m:] == 0)  # basic solution: free components zeroed
    np.testing.assert_allclose(A @ x, bvec, rtol=0,
                               atol=5e-4 * np.abs(bvec).max())
    # the minimum-norm solution is strictly shorter — the documented gap
    x_mn, *_ = np.linalg.lstsq(A, bvec, rcond=None)
    assert np.linalg.norm(x_mn) <= np.linalg.norm(x) + 1e-4


def test_straggler_detection_and_rebalance():
    mon = StragglerMonitor(4, StragglerConfig(threshold=1.4, patience=2))
    # lane 2 persistently 2x slower
    actions = []
    for _ in range(4):
        actions = mon.report({0: 1.0, 1: 1.05, 2: 2.2, 3: 0.95})
    assert actions == [2]
    shares = mon.rebalance(2)
    assert shares[2] < 1.0
    assert abs(sum(shares.values()) - 4.0) < 1e-6
    rows = mon.lane_rows(64)
    assert sum(rows.values()) == 64
    assert rows[2] < rows[0]


def test_straggler_no_false_positive():
    mon = StragglerMonitor(4)
    for _ in range(10):
        acts = mon.report({i: 1.0 + 0.05 * i for i in range(4)})
        assert acts == []
