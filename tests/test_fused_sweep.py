"""Fused whole-panel megakernel vs the stepped sweep: the bitwise gates.

DESIGN.md §10's fusion contract: ``run_panel_fused`` executes all of panel
``k``'s points (leaf + L tsqr + L trailing) as one dispatch, and the
resulting boundary state — and therefore every downstream output — is
**bitwise identical** to iterating ``sweep_step`` over the same points,
because the megakernel body runs the same core entry points over the same
comm. Gated here at every panel boundary on aligned, ragged, and wide
``b = 4`` geometries, through the xla engine and the forced Pallas
interpreter, under the orchestrator (failure-free and with a runtime kill
at a panel boundary), and under ``shard_map`` on a forced 4-device mesh.
"""
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from spmd_subprocess_util import run_forced_devices

from repro.core import SimComm, caqr_factorize
from repro.ft import FailureSchedule, SweepOrchestrator, ft_caqr_sweep, sweep_point
from repro.ft.failures import PHASE_LEAF
from repro.ft.online.detect import ScriptedKiller
from repro.ft.online.state import (
    finalize,
    initial_sweep_state,
    panel_points,
    run_panel_fused,
    sweep_step,
)
from repro.kernels import backend

# (tag, P, m_loc, n, b) — the PR-3 geometry classes at the gate's b = 4
GEOMS = [
    ("aligned", 4, 8, 16, 4),
    ("ragged", 4, 6, 10, 4),
    ("wide", 4, 4, 40, 4),
]


def _matrix(P, m_loc, n, seed=3):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


def _assert_states_bitwise(got, want, tag):
    gl, wl = _leaves(got), _leaves(want)
    assert len(gl) == len(wl), tag
    for g, w in zip(gl, wl):
        assert g.shape == w.shape and g.dtype == w.dtype, tag
        assert np.array_equal(g, w), f"{tag}: fused boundary state differs"


@pytest.mark.parametrize("geom", GEOMS, ids=lambda g: g[0])
def test_fused_panel_bitwise_vs_stepped(geom):
    """Panel by panel: run_panel_fused == panel_points(geom) sweep_steps,
    bit for bit, at EVERY panel boundary — then identical finalize."""
    tag, P, m_loc, n, b = geom
    comm = SimComm(P)
    A = _matrix(P, m_loc, n)
    step = jax.jit(functools.partial(sweep_step, comm))
    fused = jax.jit(functools.partial(run_panel_fused, comm))
    s_stepped = initial_sweep_state(comm, A, b)
    s_fused = s_stepped
    pts = panel_points(s_stepped.geom)
    for k in range(s_stepped.geom.n_panels):
        assert s_fused.cursor == (k, PHASE_LEAF, 0)
        s_fused = fused(s_fused)
        for _ in range(pts):
            s_stepped = step(s_stepped)
        _assert_states_bitwise(s_fused, s_stepped, f"{tag}-panel{k}")
    assert s_fused.cursor is None
    _assert_states_bitwise(finalize(comm, s_fused),
                           finalize(comm, s_stepped), f"{tag}-final")


@pytest.mark.parametrize("mode", [backend.MODE_COMPILED,
                                  backend.MODE_INTERPRET])
def test_fused_routes_bitwise(mode):
    """Both non-oracle routes of the fused_sweep policy slot — the compiled
    engine and the forced Pallas interpreter (the SimComm-embedding
    megakernel) — are bitwise vs stepping on the ragged geometry."""
    _, P, m_loc, n, b = GEOMS[1]
    comm = SimComm(P)
    A = _matrix(P, m_loc, n, seed=7)
    s0 = initial_sweep_state(comm, A, b)
    pts = panel_points(s0.geom)
    s_stepped = s0
    for _ in range(pts):
        s_stepped = sweep_step(comm, s_stepped)
    backend.force_mode(mode, "fused_sweep")
    try:
        s_fused = run_panel_fused(comm, s0)
    finally:
        backend.force_mode(None, "fused_sweep")
    _assert_states_bitwise(s_fused, s_stepped, f"route-{mode}")


def test_fused_oracle_mode_falls_back_to_stepping():
    """oracle mode must not lose panels: run_panel_fused degrades to
    run_steps and still lands on the next leaf boundary."""
    _, P, m_loc, n, b = GEOMS[0]
    comm = SimComm(P)
    s0 = initial_sweep_state(comm, _matrix(P, m_loc, n), b)
    backend.force_mode(backend.MODE_ORACLE, "fused_sweep")
    try:
        s1 = run_panel_fused(comm, s0)
    finally:
        backend.force_mode(None, "fused_sweep")
    assert s1.cursor == (1, PHASE_LEAF, 0)


@pytest.mark.parametrize("geom", GEOMS, ids=lambda g: g[0])
def test_orchestrator_fused_failure_free(geom):
    """fused=True: same FTSweepResult as the monolithic sweep, with O(1)
    segments per panel (segments_run == n_panels, not sum of points)."""
    tag, P, m_loc, n, b = geom
    comm = SimComm(P)
    A = _matrix(P, m_loc, n)
    ref = caqr_factorize(A, comm, b, collect_bundles=True, use_scan=False)
    orch = SweepOrchestrator(A, comm, b, fused=True)
    got = orch.run()
    _assert_states_bitwise((got.R, got.factors, got.bundles),
                           (ref.R, ref.factors, ref.bundles), tag)
    assert got.events == []
    assert orch.segments_run == orch.state.geom.n_panels


def test_orchestrator_fused_kill_at_panel_boundary():
    """A runtime kill discovered at a fused (panel-end) boundary recovers
    bitwise-identically to the scheduled driver's kill at that point."""
    _, P, m_loc, n, b = GEOMS[1]
    comm = SimComm(P)
    A = _matrix(P, m_loc, n)
    levels = initial_sweep_state(comm, A, b).levels
    point = sweep_point(1, "trailing", levels - 1)  # a panel end
    ref = caqr_factorize(A, comm, b, collect_bundles=True, use_scan=False)
    orch = SweepOrchestrator(
        A, comm, b, fused=True,
        fault_hooks=[ScriptedKiller({point: [2]})])
    got = orch.run()
    _assert_states_bitwise((got.R, got.factors, got.bundles),
                           (ref.R, ref.factors, ref.bundles), "fused-kill")
    sched = ft_caqr_sweep(A, comm, b,
                          schedule=FailureSchedule(events={point: [2]}))
    assert [(e.point, e.lane, e.reads) for e in got.events] == \
        [(e.point, e.lane, e.reads) for e in sched.events]
    assert orch.segments_run == orch.state.geom.n_panels


def test_fused_resume_mid_panel_realigns():
    """A state resumed mid-panel (e.g. from a persisted stepped run) first
    steps to the next leaf boundary, then runs fused — still bitwise."""
    _, P, m_loc, n, b = GEOMS[0]
    comm = SimComm(P)
    A = _matrix(P, m_loc, n, seed=11)
    ref = caqr_factorize(A, comm, b, collect_bundles=True, use_scan=False)
    s = initial_sweep_state(comm, A, b)
    for _ in range(2):  # stop inside panel 0's butterfly ladder
        s = sweep_step(comm, s)
    assert s.cursor[1] != PHASE_LEAF
    got = SweepOrchestrator.from_state(s, comm, fused=True).run()
    _assert_states_bitwise((got.R, got.factors, got.bundles),
                           (ref.R, ref.factors, ref.bundles), "resume")


def test_fused_simcomm_matches_stepped_shard_map():
    """Cross-backend closure of the fusion claim: the fused SimComm sweep
    equals the UNFUSED shard_map sweep leaf-for-leaf (stepped SimComm ==
    stepped shard_map is §8's gate; fused == stepped SimComm is gated
    above; this pins the composition on the ragged geometry)."""
    out = run_forced_devices("""
        import functools
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import SimComm
        from repro.ft.online.state import (
            finalize, initial_sweep_state, run_panel_fused)
        from repro.launch.spmd_qr import make_lane_mesh, make_spmd_sweep_step

        P, m_loc, n, b = 4, 6, 10, 4
        rng = np.random.default_rng(3)
        A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
        comm = SimComm(P)

        fused = jax.jit(functools.partial(run_panel_fused, comm))
        s_f = initial_sweep_state(comm, A, b)
        while s_f.cursor is not None:
            s_f = fused(s_f)

        step = make_spmd_sweep_step(make_lane_mesh(P))
        s_s = initial_sweep_state(comm, A, b)
        while s_s.cursor is not None:
            s_s = step(s_s)

        for tag, a, b_ in (("state", s_f, s_s),
                           ("final", finalize(comm, s_f),
                            finalize(comm, s_s))):
            al = jax.tree_util.tree_leaves(a)
            bl = jax.tree_util.tree_leaves(b_)
            assert len(al) == len(bl), tag
            for x, y in zip(al, bl):
                x, y = np.asarray(x), np.asarray(y)
                assert x.shape == y.shape and x.dtype == y.dtype, tag
                assert np.array_equal(x, y), tag + ": leaf mismatch"
        print("FUSED_SPMD_OK")
    """, n_devices=4)
    assert "FUSED_SPMD_OK" in out
