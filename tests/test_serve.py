"""Serve-layer tests: the token engine's decode contract and the
QR-as-a-service continuous-batching front end (``repro.serve.qr_service``).

The qr_service acceptance oracle: every tenant's retired R must be
BITWISE-identical to a failure-free solo ``caqr_factorize`` of the same
bucket-padded matrix — whether the request drained alone, in a full
resident batch, joined mid-stream, or survived a mid-batch lane kill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import save as ckpt_save
from repro.configs import get_smoke
from repro.core import SimComm, block_row_layout, caqr_factorize
from repro.serve import Engine, QRService, ServeConfig
from repro.serve.engine import _prefill_to_decode_caches
from repro.models import api, attention as attn, transformer as tf

P = 4
B_PANEL = 4
BUCKET = (8, 14)  # (m_loc, n_bucket): fits m <= 32, n + nrhs <= 14


# -- qr_service --------------------------------------------------------------


def _solo_R(comm, A, rhs):
    """The acceptance oracle: a failure-free solo factorization of the
    tenant's bucket-padded (rhs-augmented) matrix, sliced to its shape."""
    A_aug = A if rhs is None else np.concatenate([A, rhs], axis=1)
    A0 = block_row_layout(jnp.asarray(A_aug), P, *BUCKET)
    res = caqr_factorize(A0, comm, B_PANEL, use_scan=False,
                         collect_bundles=True)
    k, n = min(A.shape), A.shape[1]
    return np.asarray(res.R[0])[:k, :n]


def _requests(rng, count=5):
    shapes = [(10, 6), (16, 12), (7, 10), (24, 9), (12, 12)][:count]
    out = []
    for i, (m, n) in enumerate(shapes):
        A = rng.standard_normal((m, n)).astype(np.float32)
        rhs = (rng.standard_normal((m, 2)).astype(np.float32)
               if i == 0 else None)
        out.append((A, rhs))
    return out


def test_qr_service_admission_retire_bitwise(rng):
    """Staggered admission under slot pressure: requests queue FIFO, join
    at panel boundaries, retire early, and every R is bitwise-solo."""
    comm = SimComm(P)
    svc = QRService(comm, panel_width=B_PANEL, buckets=[BUCKET], max_slots=2)
    reqs = _requests(rng)
    rids = [svc.submit(A, rhs) for A, rhs in reqs[:3]]
    svc.tick()
    assert svc.resident <= 2 and len(svc.queue) >= 1  # capacity respected
    rids += [svc.submit(A, rhs) for A, rhs in reqs[3:]]
    results = svc.run_until_drained()
    assert set(rids) == set(results)
    for rid, (A, rhs) in zip(rids, reqs):
        res = results[rid]
        assert res.R.shape == (min(A.shape), A.shape[1])
        np.testing.assert_array_equal(res.R, _solo_R(comm, A, rhs))
        assert res.panels == -(-min(A.shape) // B_PANEL)  # early retirement


def test_qr_service_kill_mid_batch_heals(rng):
    """A lane killed under load: every resident tenant is REBUILDed from
    its buddies and still retires the bitwise failure-free R."""
    comm = SimComm(P)
    svc = QRService(comm, panel_width=B_PANEL, buckets=[BUCKET], max_slots=4)
    reqs = _requests(rng)
    rids = [svc.submit(A, rhs) for A, rhs in reqs]
    svc.tick()   # admit + advance the first wave one panel
    svc.tick()
    svc.kill_lane(2)  # lands at the next boundary, mid-batch
    results = svc.run_until_drained()
    healed = sum(len(results[r].events) for r in rids)
    assert healed >= 1, "the kill was never detected/healed"
    for rid, (A, rhs) in zip(rids, reqs):
        np.testing.assert_array_equal(results[rid].R, _solo_R(comm, A, rhs))


def test_qr_service_lstsq(rng):
    """The rhs rides the bucket: retirement back-solves the same answer
    as numpy's dense lstsq."""
    comm = SimComm(P)
    svc = QRService(comm, panel_width=B_PANEL, buckets=[BUCKET], max_slots=2)
    A = rng.standard_normal((20, 8)).astype(np.float32)
    rhs = rng.standard_normal((20, 2)).astype(np.float32)
    rid = svc.submit(A, rhs)
    res = svc.run_until_drained()[rid]
    x_ref, *_ = np.linalg.lstsq(A.astype(np.float64),
                                rhs.astype(np.float64), rcond=None)
    np.testing.assert_allclose(res.x, x_ref, atol=1e-3)


def test_qr_service_drain_batched_matches_continuous(rng):
    """The express static-batch path (vmapped bucket dispatch) returns the
    same tenant answers as continuous batching."""
    comm = SimComm(P)
    reqs = _requests(rng)
    svc_c = QRService(comm, panel_width=B_PANEL, buckets=[BUCKET], max_slots=8)
    svc_b = QRService(comm, panel_width=B_PANEL, buckets=[BUCKET], max_slots=8)
    rids_c = [svc_c.submit(A, rhs) for A, rhs in reqs]
    rids_b = [svc_b.submit(A, rhs) for A, rhs in reqs]
    res_c = svc_c.run_until_drained()
    res_b = svc_b.drain_batched()
    for rc, rb in zip(rids_c, rids_b):
        np.testing.assert_allclose(res_b[rb].R, res_c[rc].R,
                                   rtol=1e-5, atol=1e-5)
        if res_c[rc].x is not None:
            np.testing.assert_allclose(res_b[rb].x, res_c[rc].x,
                                       rtol=1e-4, atol=1e-4)


def test_qr_service_no_new_compiles_at_steady_state(rng):
    """The resident-program claim: once one sweep per bucket has warmed the
    segment runner, further traffic (any admission order) compiles nothing."""
    comm = SimComm(P)
    svc = QRService(comm, panel_width=B_PANEL, buckets=[BUCKET], max_slots=3)
    for A, rhs in _requests(rng, 3):
        svc.submit(A, rhs)
    svc.run_until_drained()
    warm = svc.compiled_programs
    for A, rhs in _requests(rng, 5):  # second wave, staggered
        svc.submit(A, rhs)
        svc.tick()
    svc.run_until_drained()
    assert svc.compiled_programs == warm


# -- token engine ------------------------------------------------------------


def test_engine_greedy_determinism(rng):
    """temperature=0 decoding is a pure function of (params, prompts)."""
    cfg = get_smoke("tinyllama-1.1b")
    params = tf.init_params(cfg, jax.random.key(0))
    prompts = rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    engine = Engine(cfg, params, ServeConfig(max_new_tokens=6))
    out1 = engine.generate(prompts)
    out2 = engine.generate(prompts)
    np.testing.assert_array_equal(out1, out2)


def test_engine_eos_masking(rng):
    """A slot that hits EOS keeps decoding into a sink but every
    subsequent output position is masked to eos_id."""
    cfg = get_smoke("tinyllama-1.1b")
    params = tf.init_params(cfg, jax.random.key(0))
    prompts = rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    free = Engine(cfg, params, ServeConfig(max_new_tokens=8)).generate(prompts)
    eos = int(free[0, 2])  # a token row 0 emits mid-stream
    out = Engine(cfg, params, ServeConfig(max_new_tokens=8, eos_id=eos)
                 ).generate(prompts)
    for b in range(out.shape[0]):
        hits = np.flatnonzero(out[b] == eos)
        if hits.size:
            assert (out[b, hits[0]:] == eos).all(), out[b]
    assert (out[0] == eos).any()  # row 0 provably finished early


def test_prefill_decode_parity_sliding_window(rng):
    """Prefill->decode relayout parity on a sliding-window arch with the
    GLOBAL cache length (prompt + new > window): each "L" layer must be
    cropped to ITS window, not the global cache_len — greedy decode then
    reproduces the no-cache reference rollout exactly."""
    cfg = get_smoke("gemma2-2b")
    assert cfg.sliding_window and cfg.sliding_window < 24
    params = tf.init_params(cfg, jax.random.key(1))
    S0, steps = 24, 6
    prompts = rng.integers(0, cfg.vocab, (2, S0)).astype(np.int32)

    # no-cache reference: full forward re-run per generated token
    toks = jnp.asarray(prompts)
    ref = []
    for _ in range(steps):
        hidden, _, _ = tf.forward(cfg, params, toks)
        nxt = jnp.argmax(tf.logits_fn(cfg, params, hidden)[:, -1],
                         axis=-1).astype(jnp.int32)[:, None]
        ref.append(np.asarray(nxt[:, 0]))
        toks = jnp.concatenate([toks, nxt], axis=1)
    ref = np.stack(ref, axis=1)

    out = Engine(cfg, params, ServeConfig(max_new_tokens=steps)
                 ).generate(prompts)
    np.testing.assert_array_equal(out, ref)


def test_prefill_to_decode_layer_window_contract():
    """The module-level contract the serve engine relies on: an "L" layer
    converted with the global cache length lands at ITS window, in rolled
    pos%window order (the pre-fix code used the global length as the
    window, corrupting the addressing whenever they differ)."""
    cfg = get_smoke("gemma2-2b")
    w = cfg.sliding_window
    S0, total = 24, 30
    assert w < S0 < total
    k = jnp.arange(S0, dtype=jnp.float32).reshape(1, S0, 1, 1)
    cache = attn.KVCache(k=jnp.broadcast_to(k, (1, S0, 2, 4)),
                         v=jnp.broadcast_to(k, (1, S0, 2, 4)))
    out = _prefill_to_decode_caches(cfg, cache, S0, total, mixer="L")
    assert out.k.shape[-3] == w, (out.k.shape, w)
    # entry at slot p%w must hold position p, for the last w positions
    got = np.asarray(out.k[0, :, 0, 0])
    want = np.empty(w, np.float32)
    for p in range(S0 - w, S0):
        want[p % w] = p
    np.testing.assert_array_equal(got, want)
    # a global layer with the same call pads to the global length instead
    out_g = _prefill_to_decode_caches(cfg, cache, S0, total, mixer="G")
    assert out_g.k.shape[-3] == total


# -- checkpoint restore (the launch/serve.py fix) ----------------------------


def test_restore_params_roundtrip(tmp_path):
    """Params-only restore round-trips bitwise with NO optimizer skeleton;
    the old ``restore(ckpt, params, params)`` call (params tree passed as
    opt_like) cannot even address the saved optimizer npz."""
    params = {"emb": np.arange(12, dtype=np.float32).reshape(3, 4),
              "head": {"w": np.ones((4, 2), np.float32)}}
    opt = {"mu": jax.tree_util.tree_map(np.zeros_like, params),
           "count": np.int32(7)}
    ckpt_save.save(str(tmp_path), 3, params, opt, extra={"note": "t"})
    like = jax.tree_util.tree_map(np.zeros_like, params)
    got, manifest = ckpt_save.restore_params(str(tmp_path), like)
    assert manifest["step"] == 3
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(a, b)
    with pytest.raises(KeyError):
        # the bug this replaces: a params-shaped opt_like template
        ckpt_save.restore(str(tmp_path), like, like)
