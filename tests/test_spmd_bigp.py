"""Big-P CI tier: the SPMD kill matrix at P=16 and P=32 (ISSUE 7 satellite).

The P=4 differential gate (``tests/test_spmd_ft_driver.py``) exercises two
butterfly levels; lane counts of 16 and 32 add levels 2-4, where the XOR
pairing, the REBUILD single-source fetches, and the elastic pairing remap
all take paths a 4-lane world never reaches. Each test runs one subprocess
under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (see
``tests/spmd_subprocess_util.py``) covering its whole matrix — jax startup
dominates, so cells share the interpreter.

P=16 is the tier-1 spot check (a handful of kill points, one per phase,
plus one elastic SHRINK continuation on the folded 16->8 mesh). P=32 is
the fuller matrix and carries the ``slow`` marker (``tools/ci.sh --slow``).
"""
import pytest

from spmd_subprocess_util import run_forced_devices


def test_spmd_kill_matrix_p16():
    """Spot kills at P=16, one per phase including a deep butterfly level:
    scheduled shard_map bitwise-equal to SimComm; a runtime-detected kill
    under the elastic orchestrator finishes on the folded 8-lane mesh with
    R matching the failure-free reference within ``ref.tolerances``."""
    out = run_forced_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import SimComm
        from repro.ft import FailureSchedule, ft_caqr_sweep, sweep_point
        from repro.ft.online.detect import ScriptedKiller
        from repro.kernels.ref import tolerances
        from repro.launch.spmd_qr import (
            ft_caqr_sweep_elastic_spmd, ft_caqr_sweep_spmd, make_lane_mesh)

        P_, m_loc, n, b = 16, 4, 16, 4
        mesh = make_lane_mesh(P_)
        rng = np.random.default_rng(7)
        A = jnp.asarray(rng.standard_normal((P_ * m_loc, n)), jnp.float32)

        def compare(tag, sched):
            got = ft_caqr_sweep_spmd(A, b, schedule=sched, mesh=mesh)
            sim = ft_caqr_sweep(A.reshape(P_, m_loc, n), SimComm(P_), b,
                                schedule=sched)
            gl = jax.tree_util.tree_leaves((got.R, got.factors, got.bundles))
            sl = jax.tree_util.tree_leaves((sim.R, sim.factors, sim.bundles))
            assert len(gl) == len(sl)
            for g, s in zip(gl, sl):
                assert np.array_equal(np.asarray(g), np.asarray(s)), tag
            assert ([(e.point, e.lane, e.reads) for e in got.events]
                    == [(e.point, e.lane, e.reads) for e in sim.events]), tag
            print("OK", tag)

        # spot matrix: failure-free + one kill per phase, lanes spread
        # across the butterfly (level 3 pairs lane 9 with lane 1)
        for tag, sched in [
            ("p16-free", None),
            ("p16-leaf", FailureSchedule(
                events={sweep_point(0, "leaf"): [9]})),
            ("p16-tsqr-deep", FailureSchedule(
                events={sweep_point(1, "tsqr", 3): [14]})),
            ("p16-trail", FailureSchedule(
                events={sweep_point(2, "trailing", 1): [7]})),
        ]:
            compare(tag, sched)

        # elastic SHRINK on the SPMD path: runtime kill, fold 16 -> 8
        ref = ft_caqr_sweep(A.reshape(P_, m_loc, n), SimComm(P_), b)
        pt = sweep_point(1, "trailing", 0)
        res = ft_caqr_sweep_elastic_spmd(
            A, b, mesh=mesh, fault_hooks=[ScriptedKiller({pt: [11]})])
        # fold policy re-splits the 15 survivors' rows evenly over a
        # compact all-live floor-pow2 world
        assert res.world.n_slots == 8 and res.world.n_live == 8, res.world
        assert [t.kind for t in res.transitions] == ["shrink"]
        assert res.transitions[0].world_before.n_live == P_

        def signfix(R):
            s = np.sign(np.diag(np.asarray(R)))
            return np.asarray(R) * np.where(s == 0, 1.0, s)[:, None]

        rtol, atol = tolerances(jnp.float32)
        np.testing.assert_allclose(signfix(res.R), signfix(ref.R[0]),
                                   rtol=rtol, atol=atol)
        print("P16_OK")
    """, n_devices=16)
    assert "P16_OK" in out


@pytest.mark.slow
def test_spmd_kill_matrix_p32():
    """The fuller P=32 matrix: kills at every phase across panels and
    butterfly levels (including level 4, which only exists at P=32),
    a repeat-death schedule, a buddy-pair refusal, and an elastic SHRINK
    continuation on the folded 16-lane mesh."""
    out = run_forced_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import SimComm
        from repro.ft import (FailureSchedule, UnrecoverableFailure,
                              ft_caqr_sweep, sweep_point)
        from repro.ft.online.detect import ScriptedKiller
        from repro.kernels.ref import tolerances
        from repro.launch.spmd_qr import (
            ft_caqr_sweep_elastic_spmd, ft_caqr_sweep_spmd, make_lane_mesh)

        P_, m_loc, n, b = 32, 4, 24, 4
        mesh = make_lane_mesh(P_)
        rng = np.random.default_rng(11)
        A = jnp.asarray(rng.standard_normal((P_ * m_loc, n)), jnp.float32)

        def compare(tag, sched):
            got = ft_caqr_sweep_spmd(A, b, schedule=sched, mesh=mesh)
            sim = ft_caqr_sweep(A.reshape(P_, m_loc, n), SimComm(P_), b,
                                schedule=sched)
            gl = jax.tree_util.tree_leaves((got.R, got.factors, got.bundles))
            sl = jax.tree_util.tree_leaves((sim.R, sim.factors, sim.bundles))
            for g, s in zip(gl, sl):
                assert np.array_equal(np.asarray(g), np.asarray(s)), tag
            assert ([(e.point, e.lane, e.reads) for e in got.events]
                    == [(e.point, e.lane, e.reads) for e in sim.events]), tag
            print("OK", tag)

        cells = [("p32-free", None)]
        for k, phase, lvl, lane in [
            (0, "leaf", None, 17),
            (0, "tsqr", 0, 30),
            (1, "tsqr", 2, 5),
            (2, "tsqr", 4, 21),      # the P=32-only butterfly level
            (3, "trailing", 0, 12),
            (5, "trailing", 1, 31),
        ]:
            pt = (sweep_point(k, phase) if lvl is None
                  else sweep_point(k, phase, lvl))
            cells.append((f"p32-{k}-{phase}-{lvl}-{lane}",
                          FailureSchedule(events={pt: [lane]})))
        cells.append(("p32-repeat", FailureSchedule(events={
            sweep_point(1, "trailing", 0): [6],
            sweep_point(4, "trailing", 1): [6],
        })))
        for tag, sched in cells:
            compare(tag, sched)

        # buddy-pair death refuses at trace time, same as the simulator
        try:
            ft_caqr_sweep_spmd(A, b, mesh=mesh, schedule=FailureSchedule(
                events={sweep_point(2, "trailing", 0): [8, 9]}))
            raise AssertionError("buddy-pair death must refuse")
        except UnrecoverableFailure:
            print("OK p32-unrecoverable")

        # elastic SHRINK continuation: fold 32 -> 16 mid-sweep
        ref = ft_caqr_sweep(A.reshape(P_, m_loc, n), SimComm(P_), b)
        pt = sweep_point(2, "trailing", 0)
        res = ft_caqr_sweep_elastic_spmd(
            A, b, mesh=mesh, fault_hooks=[ScriptedKiller({pt: [19]})])
        assert res.world.n_slots == 16 and res.world.n_live == 16

        def signfix(R):
            s = np.sign(np.diag(np.asarray(R)))
            return np.asarray(R) * np.where(s == 0, 1.0, s)[:, None]

        rtol, atol = tolerances(jnp.float32)
        np.testing.assert_allclose(signfix(res.R), signfix(ref.R[0]),
                                   rtol=rtol, atol=atol)
        print("P32_OK")
    """, n_devices=32)
    assert "P32_OK" in out


_FTRUN_TRAIN_BODY = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_smoke
    from repro.data.pipeline import DataConfig
    from repro.ft.semantics import Semantics
    from repro.train.loop import TrainConfig
    from repro.train.ftrun import FTRunConfig, FTTrainer, StepSweepKiller

    cfg = get_smoke("tinyllama-1.1b")
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=1)
    tcfg = TrainConfig(steps=3, lr=1e-2, warmup=2, n_lanes=4,
                       diskless_every=2, log_every=100,
                       semantics=Semantics.REBUILD, optimizer="caqr_muon")

    def params_equal(a, b):
        eq = jax.tree_util.tree_map(
            lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
            a, b)
        return all(jax.tree_util.tree_leaves(eq))

    mesh_cfg = FTRunConfig(use_mesh=True)
    ref = FTTrainer(cfg, tcfg, dcfg, mesh_cfg)
    assert ref.engine.n_lanes == {lanes}, ref.engine.n_lanes
    hist_ref = ref.run()

    killer = StepSweepKiller(at_step=1, lane={kill_lane})
    tr = FTTrainer(cfg, tcfg, dcfg, FTRunConfig(use_mesh=True),
                   qr_fault_hooks=[killer])
    hist = tr.run()
    assert killer.fired, "kill never landed inside the optimizer sweep"
    assert params_equal(ref.state.params, tr.state.params)
    assert ([h["loss"] for h in hist_ref] == [h["loss"] for h in hist])
    print("mesh kill at", killer.struck)

    # SimComm engine at the same lane count is bitwise-equal to the
    # shard_map path (the online segment oracle, at training level)
    sim = FTTrainer(cfg, tcfg, dcfg, FTRunConfig(qr_lanes={lanes}))
    sim.run()
    assert params_equal(ref.state.params, sim.state.params)
    print("FTRUN_TRAIN_OK")
"""


def test_ftrun_train_kill_p16():
    """Tier-1 spot: the FT training runtime on a 16-lane QR mesh — a lane
    killed inside the optimizer-internal sweep at step 1 trains on to
    params and loss curve bitwise-identical to failure-free, and the
    shard_map engine matches the SimComm engine bitwise."""
    out = run_forced_devices(
        _FTRUN_TRAIN_BODY.format(lanes=16, kill_lane=11), n_devices=16)
    assert "FTRUN_TRAIN_OK" in out


@pytest.mark.slow
def test_ftrun_train_kill_p32():
    """P=32 training mesh (butterfly level 4 inside the optimizer)."""
    out = run_forced_devices(
        _FTRUN_TRAIN_BODY.format(lanes=32, kill_lane=21), n_devices=32,
        timeout=1800)
    assert "FTRUN_TRAIN_OK" in out


@pytest.mark.slow
def test_ftrun_train_kill_p48():
    """Non-power-of-two pod: 48 devices, and the runtime sizes its QR mesh
    to the largest power-of-two prefix (32 lanes) via ``pow2_lanes``."""
    out = run_forced_devices(
        _FTRUN_TRAIN_BODY.format(lanes=32, kill_lane=27), n_devices=48,
        timeout=1800)
    assert "FTRUN_TRAIN_OK" in out
