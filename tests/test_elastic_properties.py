"""Hypothesis property suite for elastic execution (ISSUE 7 satellite).

Strategies draw (geometry, kill sweep-point from ``ft.iter_sweep_points``,
semantics, re-grow point) and assert the three elastic invariants:

* SHRINK/BLANK/REBUILD all reproduce the failure-free R within
  ``repro.kernels.ref.tolerances`` (REBUILD bitwise, elastic sign-fixed —
  row re-hosting changes reduction shapes);
* event ledgers are consistent (one heal per kill, transition kinds match
  semantics, final world live-count is P minus unreplaced deaths);
* the scheduled-shrink differential oracle is **bitwise** identical to
  the online-detected path at the same point (shared controller code).
"""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not in this image")
from hypothesis import given, settings, strategies as st

from repro.core import SimComm, caqr_factorize
from repro.core.caqr import sweep_geometry
from repro.ft import (
    FailureSchedule,
    Semantics,
    SweepOrchestrator,
    ft_caqr_sweep,
    ft_caqr_sweep_elastic,
    iter_sweep_points,
)
from repro.ft.online.detect import ScriptedKiller
from repro.kernels.ref import tolerances

_SETTINGS = dict(max_examples=15, deadline=None)

# small geometries across the shape taxonomy: aligned, ragged rows,
# ragged cols, wide; b=4 tiles (the CPU-XLA bitwise-stable envelope)
_GEOMETRIES = [
    (2, 8, 8, 4),     # aligned, tall
    (4, 4, 12, 4),    # aligned, square-ish
    (4, 6, 10, 4),    # ragged rows + cols (the acceptance geometry)
    (2, 6, 16, 4),    # ragged rows, wide
]


def _signfix(R):
    s = np.sign(np.diag(R))
    s = np.where(s == 0, 1.0, s)
    return R * s[:, None]


def _close(Ra, Rb):
    rtol, atol = tolerances(jnp.float32)
    np.testing.assert_allclose(_signfix(np.asarray(Ra)),
                               _signfix(np.asarray(Rb)),
                               rtol=rtol, atol=atol)


def _case(geom_idx, point_frac, lane_frac, seed):
    P, m_loc, n, b = _GEOMETRIES[geom_idx]
    geom = sweep_geometry(P, m_loc, n, b)
    points = list(iter_sweep_points(geom.n_panels, geom.levels))
    point = points[int(point_frac * (len(points) - 1))]
    lane = int(lane_frac * (P - 1))
    A = jnp.asarray(
        np.random.default_rng(seed).standard_normal((P, m_loc, n)),
        jnp.float32)
    ref = caqr_factorize(A, SimComm(P), b, collect_bundles=True,
                         use_scan=False)
    return P, b, A, np.asarray(ref.R[0]), point, lane, points


@settings(**_SETTINGS)
@given(
    geom_idx=st.integers(0, len(_GEOMETRIES) - 1),
    point_frac=st.floats(0, 1),
    lane_frac=st.floats(0, 1),
    seed=st.integers(0, 2**16),
    semantics=st.sampled_from(
        [Semantics.SHRINK, Semantics.BLANK, Semantics.REBUILD]),
)
def test_any_semantics_reproduces_r(geom_idx, point_frac, lane_frac, seed,
                                    semantics):
    P, b, A, R_ref, point, lane, _ = _case(geom_idx, point_frac, lane_frac,
                                           seed)
    sched = FailureSchedule(events={point: [lane]})
    res = ft_caqr_sweep(A, SimComm(P), b, schedule=sched,
                        semantics=semantics)
    if semantics is Semantics.REBUILD:
        # the paper's guarantee is stronger: bitwise, replicated layout
        assert np.array_equal(np.asarray(res.R[0]), R_ref)
    else:
        _close(res.R, R_ref)
        assert res.world.n_live == P - 1
        kinds = [t.kind for t in res.transitions]
        assert kinds == [semantics.value]
    # ledger consistency: exactly one heal, at the drawn point and lane
    assert [(e.point, e.lane) for e in res.events] == [(tuple(point), lane)]


@settings(**_SETTINGS)
@given(
    geom_idx=st.integers(0, len(_GEOMETRIES) - 1),
    point_frac=st.floats(0, 1),
    lane_frac=st.floats(0, 1),
    seed=st.integers(0, 2**16),
)
def test_scheduled_oracle_bitwise_vs_online(geom_idx, point_frac, lane_frac,
                                            seed):
    P, b, A, _, point, lane, _ = _case(geom_idx, point_frac, lane_frac, seed)
    sched = FailureSchedule(events={point: [lane]})
    oracle = ft_caqr_sweep_elastic(A, SimComm(P), b, schedule=sched,
                                   semantics=Semantics.SHRINK)
    online = SweepOrchestrator(
        A, SimComm(P), b, fault_hooks=[ScriptedKiller({point: [lane]})],
        semantics=Semantics.SHRINK,
    ).run()
    assert np.array_equal(np.asarray(oracle.R), np.asarray(online.R))
    assert [(e.point, e.lane) for e in online.events] == \
        [(e.point, e.lane) for e in oracle.events]
    assert online.transitions == oracle.transitions
    assert online.world == oracle.world


@settings(**_SETTINGS)
@given(
    geom_idx=st.integers(0, len(_GEOMETRIES) - 1),
    point_frac=st.floats(0, 1),
    grow_frac=st.floats(0, 1),
    seed=st.integers(0, 2**16),
)
def test_regrow_reproduces_r(geom_idx, point_frac, grow_frac, seed):
    """Kill + re-grow at a drawn later point still reproduces R, and the
    returning lane restores the live count when the grow fires before the
    sweep ends."""
    P, b, A, R_ref, point, _, points = _case(geom_idx, point_frac, 0.99,
                                             seed)
    grow_at = points[int(grow_frac * (len(points) - 1))]
    sched = FailureSchedule(events={point: [P - 1]})
    res = ft_caqr_sweep_elastic(A, SimComm(P), b, schedule=sched,
                                semantics=Semantics.SHRINK, grow_at=grow_at)
    _close(res.R, R_ref)
    kinds = [t.kind for t in res.transitions]
    assert set(kinds) <= {"shrink", "grow"}
    # the drawn kill point addresses the running epoch: repeated grows can
    # re-partition epochs so the point never comes up — a kill fired
    # (events non-empty) always yields exactly one shrink transition
    if res.events:
        assert kinds.count("shrink") == 1
    if kinds and kinds[-1] == "grow":
        assert res.world.n_live == \
            res.transitions[-1].world_before.n_live + 1
