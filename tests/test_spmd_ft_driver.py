"""SimComm <-> shard_map differential gate for the FT sweep (tier-1).

The tentpole claim of the SPMD execution model (DESIGN.md §8): the
Comm-generic FT driver produces **bit-identical** R, per-panel factors,
recovery bundles, and post-REBUILD state whether it runs on the P-lane
simulator or under ``shard_map`` on a real device mesh — including
mid-sweep lane kills at every phase, on aligned, ragged, and wide
geometries.

Runs in a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_
count=4`` so the main test process keeps seeing one device; one subprocess
covers all geometries/schedules (jax startup dominates). The ragged
geometry is PR 3's ``P=4, m_loc=6, n=10, b=4`` — unaligned lane heights AND
a ragged last panel, the hardest padding case.
"""
from spmd_subprocess_util import run_forced_devices


def _run(code: str) -> str:
    return run_forced_devices(code, n_devices=4)


def test_ft_sweep_spmd_differential():
    """Failure-free + one kill per phase (leaf / mid-TSQR / mid-trailing),
    on ragged, aligned, and wide geometries: every leaf of the result pytree
    bitwise-equal between SimComm and the shard_map path, and the REBUILD
    read ledgers identical."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import SimComm
        from repro.ft import FailureSchedule, ft_caqr_sweep, sweep_point
        from repro.launch.spmd_qr import ft_caqr_sweep_spmd, make_lane_mesh

        mesh = make_lane_mesh(4)

        def compare(tag, m_loc, n, b, sched):
            P_ = 4
            rng = np.random.default_rng(3)
            A = jnp.asarray(rng.standard_normal((P_ * m_loc, n)), jnp.float32)
            got = ft_caqr_sweep_spmd(A, b, schedule=sched, mesh=mesh)
            sim = ft_caqr_sweep(A.reshape(P_, m_loc, n), SimComm(P_), b,
                                schedule=sched)
            gl = jax.tree_util.tree_leaves((got.R, got.factors, got.bundles))
            sl = jax.tree_util.tree_leaves((sim.R, sim.factors, sim.bundles))
            assert len(gl) == len(sl)
            for g, s in zip(gl, sl):
                g, s = np.asarray(g), np.asarray(s)
                assert g.shape == s.shape and g.dtype == s.dtype, tag
                assert np.array_equal(g, s), f"{tag}: leaf mismatch"
            assert ([(e.point, e.lane, e.reads) for e in got.events]
                    == [(e.point, e.lane, e.reads) for e in sim.events]), tag
            print("OK", tag)

        # ragged (PR 3 geometry): one kill per phase + failure-free
        for tag, sched in [
            ("ragged-free", None),
            ("ragged-leaf", FailureSchedule(events={sweep_point(0, "leaf"): [1]})),
            ("ragged-tsqr", FailureSchedule(events={sweep_point(1, "tsqr", 0): [2]})),
            ("ragged-trail", FailureSchedule(events={sweep_point(2, "trailing", 1): [3]})),
        ]:
            compare(tag, 6, 10, 4, sched)

        # aligned square sweep, repeat-death schedule
        compare("aligned-free", 8, 16, 4, None)
        compare("aligned-2kills", 8, 16, 4, FailureSchedule(events={
            sweep_point(0, "trailing", 0): [1],
            sweep_point(3, "trailing", 1): [1],
        }))

        # wide (n > P*m_loc): trailing-only R2 columns survive a kill
        compare("wide-kill", 4, 24, 4, FailureSchedule(events={
            sweep_point(2, "trailing", 1): [2],
        }))
        print("DIFFERENTIAL_OK")
    """)
    assert "DIFFERENTIAL_OK" in out


def test_ft_sweep_online_spmd_differential():
    """The online path on the production mesh: shard_map sweep_step
    segments + host-side NaN-sentinel detection. Failure-free stepped
    execution and a runtime-detected kill are both bitwise-identical to the
    trace-time-scheduled shard_map run AND to the SimComm run (the §9
    scheduled-vs-online equivalence, on real devices)."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import SimComm
        from repro.ft import FailureSchedule, ft_caqr_sweep, sweep_point
        from repro.ft.online.detect import ScriptedKiller
        from repro.launch.spmd_qr import (
            ft_caqr_sweep_online_spmd, ft_caqr_sweep_spmd, make_lane_mesh)

        mesh = make_lane_mesh(4)
        P_, m_loc, n, b = 4, 6, 10, 4   # the ragged PR-3 geometry
        rng = np.random.default_rng(3)
        A = jnp.asarray(rng.standard_normal((P_ * m_loc, n)), jnp.float32)

        def leaves(r):
            return [np.asarray(x) for x in
                    jax.tree_util.tree_leaves((r.R, r.factors, r.bundles))]

        def check(tag, got, sched, sim):
            for g, s, m in zip(leaves(got), leaves(sched), leaves(sim)):
                assert np.array_equal(g, s), f"{tag}: online != scheduled spmd"
                assert np.array_equal(g, m), f"{tag}: online != simcomm"
            assert ([(e.point, e.lane, e.reads) for e in got.events]
                    == [(e.point, e.lane, e.reads) for e in sched.events]), tag
            print("OK", tag)

        # failure-free: stepped shard_map == monolithic shard_map == SimComm
        check("online-free",
              ft_caqr_sweep_online_spmd(A, b, mesh=mesh),
              ft_caqr_sweep_spmd(A, b, mesh=mesh),
              ft_caqr_sweep(A.reshape(P_, m_loc, n), SimComm(P_), b))

        # runtime-detected kill == the same kill as a trace-time schedule
        pt = sweep_point(1, "trailing", 0)
        sched = FailureSchedule(events={pt: [3]})
        check("online-kill",
              ft_caqr_sweep_online_spmd(
                  A, b, mesh=mesh, fault_hooks=[ScriptedKiller({pt: [3]})]),
              ft_caqr_sweep_spmd(A, b, schedule=sched, mesh=mesh),
              ft_caqr_sweep(A.reshape(P_, m_loc, n), SimComm(P_), b,
                            schedule=sched))
        print("ONLINE_SPMD_OK")
    """)
    assert "ONLINE_SPMD_OK" in out


def test_ft_sweep_spmd_unrecoverable_at_trace_time():
    """A buddy-pair death is detected while tracing the shard_map program —
    the schedule is static data, so the SPMD path refuses before any device
    computes (same UnrecoverableFailure as the simulator)."""
    out = _run("""
        import numpy as np, jax.numpy as jnp
        from repro.ft import FailureSchedule, UnrecoverableFailure, sweep_point
        from repro.launch.spmd_qr import ft_caqr_sweep_spmd, make_lane_mesh
        A = jnp.asarray(np.random.default_rng(0)
                        .standard_normal((24, 10)), jnp.float32)
        sched = FailureSchedule(events={sweep_point(1, "trailing", 0): [2, 3]})
        try:
            ft_caqr_sweep_spmd(A, 4, schedule=sched, mesh=make_lane_mesh(4))
        except UnrecoverableFailure:
            print("UNRECOVERABLE_OK")
    """)
    assert "UNRECOVERABLE_OK" in out
