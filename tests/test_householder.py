"""Unit tests: Householder / compact-WY substrate."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    apply_q, apply_qt, householder_qr, householder_qr_masked, q_dense,
    stacked_apply_qt, stacked_qr,
)


def _signfix(R):
    s = np.sign(np.diag(R))
    s = np.where(s == 0, 1.0, s)
    return R * s[:, None]


@pytest.mark.parametrize("m,n", [(8, 4), (64, 16), (96, 32), (128, 128)])
def test_qr_matches_lapack(rng, m, n):
    A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    wy = householder_qr(A)
    Rr = np.linalg.qr(np.asarray(A), mode="r")
    np.testing.assert_allclose(
        _signfix(np.asarray(wy.R)), _signfix(Rr), rtol=2e-4, atol=2e-4
    )


def test_qt_a_is_r(rng):
    A = jnp.asarray(rng.standard_normal((96, 32)), jnp.float32)
    wy = householder_qr(A)
    QtA = apply_qt(wy.Y, wy.T, A)
    np.testing.assert_allclose(np.asarray(QtA[:32]), np.asarray(wy.R), atol=3e-5)
    assert np.abs(np.asarray(QtA[32:])).max() < 3e-5


def test_q_orthogonal(rng):
    A = jnp.asarray(rng.standard_normal((64, 24)), jnp.float32)
    wy = householder_qr(A)
    Q = np.asarray(q_dense(wy.Y, wy.T))
    np.testing.assert_allclose(Q.T @ Q, np.eye(64), atol=5e-6)


def test_q_qt_roundtrip(rng):
    A = jnp.asarray(rng.standard_normal((48, 16)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((48, 8)), jnp.float32)
    wy = householder_qr(A)
    back = apply_q(wy.Y, wy.T, apply_qt(wy.Y, wy.T, C))
    np.testing.assert_allclose(np.asarray(back), np.asarray(C), atol=5e-6)


def test_masked_respects_frozen_rows(rng):
    A = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    wy = householder_qr_masked(A, jnp.asarray(16))
    assert np.abs(np.asarray(wy.Y[:16])).max() == 0.0
    Rr = np.linalg.qr(np.asarray(A)[16:], mode="r")
    np.testing.assert_allclose(
        _signfix(np.asarray(wy.R)), _signfix(Rr), rtol=2e-4, atol=2e-4
    )


def test_degenerate_zero_matrix():
    A = jnp.zeros((32, 8), jnp.float32)
    wy = householder_qr(A)
    assert np.all(np.isfinite(np.asarray(wy.Y)))
    assert np.abs(np.asarray(wy.R)).max() == 0.0


def test_stacked_qr_structure(rng):
    b = 16
    R1 = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), jnp.float32))
    R2 = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), jnp.float32))
    sq = stacked_qr(R1, R2)
    # Y2 strictly upper triangular structure
    assert np.abs(np.tril(np.asarray(sq.Y2), -1)).max() == 0.0
    S = np.concatenate([np.asarray(R1), np.asarray(R2)])
    Rr = np.linalg.qr(S, mode="r")
    np.testing.assert_allclose(
        _signfix(np.asarray(sq.R)), _signfix(Rr), rtol=2e-4, atol=2e-4
    )
    # applying Q^T to the stack reproduces [R; 0]
    ct, cb, W = stacked_apply_qt(sq, R1, R2)
    np.testing.assert_allclose(np.asarray(ct), np.asarray(sq.R), atol=3e-5)
    assert np.abs(np.asarray(cb)).max() < 3e-5
