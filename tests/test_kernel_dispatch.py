"""The kernel-dispatch seam: padding fallback at unaligned shapes, parity of
the dispatched core entry points against the pure-jnp path, and the
backend-aware interpret default (satellites of the windowed-sweep PR)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import householder as hh
from repro.kernels import backend, ops, ref


@pytest.fixture
def forced_kernels():
    """Force the core->kernel dispatch on (padding path runs on CPU in
    interpret mode), restoring the automatic policy afterwards."""
    backend.use_kernels(True)
    yield
    backend.use_kernels(None)


def _allclose(a, b, rtol=None, atol=None, dtype=jnp.float32):
    trtol, tatol = ref.tolerances(dtype)
    rtol = trtol if rtol is None else rtol
    atol = tatol if atol is None else atol
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)


# --- ops-level padding: unaligned shapes (m % 8 != 0, b % 128 != 0) --------


@pytest.mark.parametrize("m,b,row_start", [(30, 12, 0), (52, 20, 8), (9, 5, 0)])
def test_panel_qr_unaligned_padding(rng, m, b, row_start):
    A = jnp.asarray(rng.standard_normal((m, b)), jnp.float32)
    _allclose(ops.panel_qr(A, row_start), ref.panel_qr(A, row_start))


@pytest.mark.parametrize("b", [5, 12, 30])
def test_stacked_qr_unaligned_padding(rng, b):
    R1 = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), jnp.float32))
    R2 = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), jnp.float32))
    _allclose(ops.stacked_qr(R1, R2), ref.stacked_qr(R1, R2))


@pytest.mark.parametrize("m,b,n", [(30, 12, 17), (44, 20, 50)])
def test_wy_apply_unaligned_padding(rng, m, b, n):
    Y = jnp.asarray(rng.standard_normal((m, b)), jnp.float32) * 0.1
    T = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), jnp.float32)) * 0.1
    C = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    _allclose(ops.wy_apply(Y, T, C, block_n=64), ref.wy_apply(Y, T, C))


@pytest.mark.parametrize("b,n", [(12, 20), (20, 33)])
def test_stacked_apply_unaligned_padding(rng, b, n):
    Y2 = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), jnp.float32)) * 0.1
    T = jnp.triu(jnp.asarray(rng.standard_normal((b, b)), jnp.float32)) * 0.1
    Ct = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    Cb = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    _allclose(
        ops.stacked_apply(Y2, T, Ct, Cb, block_n=32),
        ref.stacked_apply(Y2, T, Ct, Cb),
    )


def test_padding_matches_unpadded_kernel(rng):
    """Zero-padding to the alignment contract is exact in exact arithmetic
    (padded rows/columns only ever add zero terms to inner products and
    produce degenerate tau=0 reflectors); in floats the only difference is
    XLA regrouping reductions at the larger size, so padded vs direct kernel
    agree to roundoff."""
    m, b = 16, 8  # aligned rows, unaligned width -> pads to (136, 128)
    A = jnp.asarray(rng.standard_normal((m, b)), jnp.float32)
    from repro.kernels import panel_qr as _panel

    direct = _panel.panel_qr(A, jnp.asarray(0, jnp.int32))
    # the padding contract belongs to the pallas routes; the default
    # compiled/xla engine runs at natural shapes, so force interpret here
    backend.force_mode(backend.MODE_INTERPRET, "panel_qr")
    try:
        padded = ops.panel_qr(A, 0)
    finally:
        backend.force_mode(None, "panel_qr")
    _allclose(direct, padded, rtol=1e-5, atol=1e-5)


# --- core entry points dispatch through the kernels ------------------------


def test_core_dispatch_parity(rng, forced_kernels):
    """householder_qr_masked / stacked_qr / apply_qt / stacked_apply_qt give
    the same numbers with the kernel dispatch forced on."""
    A = jnp.asarray(rng.standard_normal((40, 12)), jnp.float32)
    rs = jnp.asarray(0, jnp.int32)
    wy_k = hh.householder_qr_masked(A, rs)
    wy_p = hh._householder_qr_masked(A, rs)
    _allclose(wy_k, wy_p, rtol=3e-4, atol=3e-4)

    R1 = jnp.triu(jnp.asarray(rng.standard_normal((12, 12)), jnp.float32))
    R2 = jnp.triu(jnp.asarray(rng.standard_normal((12, 12)), jnp.float32))
    _allclose(hh.stacked_qr(R1, R2), hh._stacked_qr(R1, R2))

    C = jnp.asarray(rng.standard_normal((40, 20)), jnp.float32)
    _allclose(hh.apply_qt(wy_p.Y, wy_p.T, C), hh._apply_qt(wy_p.Y, wy_p.T, C))

    sq = hh._stacked_qr(R1, R2)
    Ct = jnp.asarray(rng.standard_normal((12, 20)), jnp.float32)
    Cb = jnp.asarray(rng.standard_normal((12, 20)), jnp.float32)
    _allclose(hh.stacked_apply_qt(sq, Ct, Cb), hh._stacked_apply_qt(sq, Ct, Cb))


def test_dispatch_skips_lane_stacked_and_non_f32(rng, forced_kernels):
    """Explicitly lane-stacked (leading-axis) arrays and non-f32 calls stay
    on the pure path. (Vmapped call sites see 2-D per-lane tracers and DO
    dispatch — covered by test_forced_kernel_caqr_sweep_matches_pure.)"""
    Y3 = jnp.zeros((2, 8, 4), jnp.float32)
    assert not hh._kernel_dispatch(Y3)
    Yi = jnp.zeros((8, 4), jnp.int32)
    assert not hh._kernel_dispatch(Yi)
    assert hh._kernel_dispatch(jnp.zeros((8, 4), jnp.float32))
    under_vmap = []
    jax.vmap(lambda y: under_vmap.append(hh._kernel_dispatch(y)) or y)(Y3)
    assert under_vmap == [True]


def test_forced_kernel_caqr_sweep_matches_pure(rng):
    """The full windowed CAQR sweep through the kernel seam (padding path,
    interpret mode, vmapped under SimComm) matches the pure sweep."""
    from repro.core import SimComm, caqr_factorize

    P, m_loc, n, b = 4, 16, 32, 8
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    backend.use_kernels(True)
    try:
        R_k = np.asarray(caqr_factorize(A, comm, b, use_scan=False).R[0])
    finally:
        backend.use_kernels(None)
    backend.use_kernels(False)
    try:
        R_p = np.asarray(caqr_factorize(A, comm, b, use_scan=False).R[0])
    finally:
        backend.use_kernels(None)
    np.testing.assert_allclose(R_k, R_p, rtol=3e-4, atol=3e-4)


# --- backend-aware interpret default ---------------------------------------


def test_interpret_default_single_source_of_truth():
    expected = jax.default_backend() != "tpu"
    assert backend.interpret_default() is expected
    assert ops._interpret() is expected
    assert backend.resolve_interpret(None) is expected
    assert backend.resolve_interpret(True) is True
    assert backend.resolve_interpret(False) is False


def test_kernels_run_without_explicit_interpret(rng):
    """Kernel modules no longer hardcode interpret=True — calling them with
    the default must work on this (non-TPU) backend."""
    from repro.kernels import panel_qr as _panel
    from repro.kernels import stacked_qr as _stacked
    from repro.kernels import wy_apply as _wy

    A = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    Y, T, R = _panel.panel_qr(A, jnp.asarray(0, jnp.int32))
    assert R.shape == (8, 8)
    R1 = jnp.triu(jnp.asarray(rng.standard_normal((8, 8)), jnp.float32))
    Y2, T2, R2 = _stacked.stacked_qr(R1, R1)
    assert R2.shape == (8, 8)
    C = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    out = _wy.wy_apply(Y, T, C, block_n=8)
    assert out.shape == C.shape


# --- the per-op execution policy (DESIGN.md §10) ----------------------------


@pytest.fixture
def clean_policy(monkeypatch):
    """Start from the automatic policy with no env overrides; restore it."""
    for var in ("REPRO_NO_KERNELS", "REPRO_FORCE_KERNELS",
                "REPRO_KERNEL_MODE"):
        monkeypatch.delenv(var, raising=False)
    for op in backend.OPS:
        monkeypatch.delenv(f"REPRO_KERNEL_MODE_{op.upper()}", raising=False)
    backend.use_kernels(None)
    backend.force_mode(None)
    yield monkeypatch
    backend.use_kernels(None)
    backend.force_mode(None)


def test_auto_policy_is_compiled_everywhere(clean_policy):
    for op in backend.OPS:
        assert backend.kernel_mode(op) == backend.MODE_COMPILED


def test_env_global_and_per_op_mode(clean_policy):
    clean_policy.setenv("REPRO_KERNEL_MODE", "oracle")
    assert backend.kernel_mode("panel_qr") == backend.MODE_ORACLE
    # the per-op variable beats the global one
    clean_policy.setenv("REPRO_KERNEL_MODE_PANEL_QR", "interpret")
    assert backend.kernel_mode("panel_qr") == backend.MODE_INTERPRET
    assert backend.kernel_mode("wy_apply") == backend.MODE_ORACLE
    # 'auto' resolves back to compiled
    clean_policy.setenv("REPRO_KERNEL_MODE", "auto")
    assert backend.kernel_mode("wy_apply") == backend.MODE_COMPILED


def test_env_invalid_mode_warns_and_is_ignored(clean_policy):
    clean_policy.setenv("REPRO_KERNEL_MODE", "turbo")
    with pytest.warns(UserWarning, match="REPRO_KERNEL_MODE"):
        assert backend.kernel_mode("panel_qr") == backend.MODE_COMPILED


def test_force_mode_beats_env(clean_policy):
    clean_policy.setenv("REPRO_KERNEL_MODE", "oracle")
    backend.force_mode(backend.MODE_INTERPRET, "stacked_qr")
    assert backend.kernel_mode("stacked_qr") == backend.MODE_INTERPRET
    assert backend.kernel_mode("panel_qr") == backend.MODE_ORACLE
    backend.force_mode(None, "stacked_qr")
    assert backend.kernel_mode("stacked_qr") == backend.MODE_ORACLE


def test_no_kernels_env_beats_mode_env(clean_policy):
    clean_policy.setenv("REPRO_KERNEL_MODE", "compiled")
    clean_policy.setenv("REPRO_NO_KERNELS", "1")
    assert backend.kernel_mode("wy_apply") == backend.MODE_ORACLE
    assert not backend.dispatch_enabled()


def test_use_kernels_beats_everything(clean_policy):
    clean_policy.setenv("REPRO_NO_KERNELS", "1")
    backend.use_kernels(True)
    assert backend.kernel_mode("panel_qr") == backend.MODE_COMPILED
    assert backend.dispatch_enabled()
    backend.use_kernels(False)
    backend.force_mode(backend.MODE_COMPILED)  # still loses to use_kernels
    assert backend.kernel_mode("panel_qr") == backend.MODE_ORACLE
    assert not backend.dispatch_enabled()


def test_compiled_engine_follows_probe(clean_policy):
    """compiled resolves to pallas iff the capability probe passes; the
    probe result is cached per process and resettable for tests."""
    backend.reset_probe_cache()
    try:
        clean_policy.setattr(backend, "_probe_compiled", lambda op: True)
        assert backend.compiled_engine("panel_qr") == backend.ENGINE_PALLAS
        backend.reset_probe_cache()
        clean_policy.setattr(backend, "_probe_compiled", lambda op: False)
        assert backend.compiled_engine("panel_qr") == backend.ENGINE_XLA
        report = backend.probe_report()
        assert set(report) == set(backend.OPS)
        assert all(e["engine"] == backend.ENGINE_XLA
                   for e in report.values())
    finally:
        backend.reset_probe_cache()


def test_oracle_route_for_unsupported_dtype(clean_policy, rng):
    """Dtypes outside the kernel envelope silently take the oracle leg even
    in compiled mode (f64 here; the result IS the oracle's, bit for bit)."""
    A = jnp.asarray(rng.standard_normal((16, 8)))  # f32 by default
    A64 = jnp.asarray(np.asarray(A, np.float64))
    if A64.dtype != jnp.float64:
        pytest.skip("x64 disabled on this build")
    got = ops.panel_qr(A64, 0)
    want = ref.panel_qr(A64, 0)
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        assert np.array_equal(np.asarray(g), np.asarray(w))


def test_autotune_lookup_drives_dispatch(clean_policy, rng):
    """A tuned cell's params are consulted on dispatch (and cleared cells
    fall back to the static defaults) — numerics are unroll-invariant."""
    from repro.kernels import autotune

    A = jnp.asarray(rng.standard_normal((24, 6)), jnp.float32)
    autotune.clear()
    try:
        base = ops.panel_qr(A, 0)
        variant = autotune.current_variant("panel_qr")
        autotune._CELLS[autotune.cell_key(
            "panel_qr", A.shape, A.dtype, variant)] = {
                "params": {"unroll": 4}, "us": 1.0}
        tuned = ops.panel_qr(A, 0)
        for g, w in zip(jax.tree_util.tree_leaves(base),
                        jax.tree_util.tree_leaves(tuned)):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=3e-6, atol=3e-6)
    finally:
        autotune.clear()
