"""General-shape FT-CAQR: the differential property harness.

The paper is titled *Fault Tolerant QR Factorization for General Matrices* —
this file is where "general" is enforced. Every shape class the padded
``sweep_geometry`` unlocks (ragged last panel, unaligned lane heights, wide
matrices, degenerate tiny problems) is run differentially against
``numpy.linalg.qr`` (sign-fixed R), the Gram identity, and the implicit-Q
replay's orthogonality, on both sweep variants plus the batched front-end.

Two tiers live here:

* a deterministic case matrix that always runs (tier-1 — it must pass on a
  bare image);
* a hypothesis-driven harness drawing random ``(P, m_loc, n, b, scale)``
  tuples, which runs whenever hypothesis is importable. It is NOT hidden
  behind a silent module-level ``importorskip``: the deterministic tier
  keeps running without hypothesis, and ``tools/ci.sh`` fails loudly when
  hypothesis is absent so the property tier cannot silently vanish from CI.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    SimComm,
    caqr_apply_qt,
    caqr_apply_qt_batched,
    caqr_factorize,
    caqr_factorize_batched,
    pad_to_geometry,
    sweep_geometry,
)
from repro.core.lstsq import caqr_lstsq

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # noqa: SIM105 — ci.sh gates this; tier-1 keeps running
    HAVE_HYPOTHESIS = False


def _signfix(R):
    """Canonical row signs: multiply each row by the sign of its diagonal."""
    s = np.sign(np.diag(R))
    s = np.where(s == 0, 1.0, s)
    return R * s[:, None]


def _check_general_shape(P, m_loc, n, b, scale=1.0, seed=0, **kw):
    """The differential oracle: one general-shape factorization, checked
    against numpy's QR (sign-fixed), the Gram identity, and the replayed
    implicit Q's orthogonality."""
    rng = np.random.default_rng(seed)
    A = (rng.standard_normal((P, m_loc, n)) * scale).astype(np.float32)
    comm = SimComm(P)
    res = caqr_factorize(jnp.asarray(A), comm, b, **kw)

    Af = A.reshape(-1, n)
    K = min(P * m_loc, n)
    assert res.R.shape == (P, K, n)
    R = np.asarray(res.R[0])
    # FT broadcast property: R replicated bit-identically on every lane
    assert np.all(np.asarray(res.R) == R)

    # differential vs LAPACK (sign-fixed rows; both upper trapezoidal)
    R_ref = np.linalg.qr(Af, mode="r")
    tol = 5e-3 * max(np.abs(R_ref).max(), 1e-30)
    np.testing.assert_allclose(_signfix(R), _signfix(R_ref), rtol=0, atol=tol)

    # Gram identity: R^T R == A^T A (sign-independent)
    G = Af.T @ Af
    gtol = 3e-3 * max(np.abs(G).max(), 1e-30)
    np.testing.assert_allclose(R.T @ R, G, rtol=0, atol=gtol)

    # implicit-Q replay orthogonality: (Q^T A)^T (Q^T A) == A^T A — the
    # apply returns the padded-row layout; zero pad rows do not perturb
    # the Gram product
    QtA = np.asarray(caqr_apply_qt(jnp.asarray(A), res.factors, comm))
    Qf = QtA.reshape(-1, n)
    np.testing.assert_allclose(Qf.T @ Qf, G, rtol=0, atol=gtol)
    return res


# Deterministic case matrix (always runs): every shape class by name.
CASES = {
    "aligned-tall": (4, 8, 16, 4),
    "ragged-panel": (4, 8, 10, 4),       # n % b != 0
    "ragged-lanes": (4, 6, 8, 4),        # m_loc % b != 0
    "ragged-both": (4, 6, 10, 4),
    "wide": (4, 4, 40, 4),               # n > P*m_loc
    "wide-ragged": (4, 3, 21, 4),
    "square-unaligned": (2, 5, 10, 4),   # n == m, neither aligned
    "single-column": (2, 4, 1, 4),       # n = 1
    "b-wider-than-n": (2, 8, 3, 8),      # b > n
    "short-lanes": (4, 2, 6, 4),         # m_loc < b
}


@pytest.mark.parametrize("shape", CASES.values(), ids=CASES.keys())
def test_general_shapes_scan_sweep(shape):
    _check_general_shape(*shape)


@pytest.mark.parametrize(
    "shape",
    [CASES[k] for k in ("ragged-both", "wide-ragged", "short-lanes")],
    ids=["ragged-both", "wide-ragged", "short-lanes"],
)
def test_general_shapes_windowed_sweep(shape):
    """The unrolled windowed perf path handles the same general shapes."""
    _check_general_shape(*shape, use_scan=False)


def test_scales_do_not_break_raggedness():
    for scale in (1e-3, 1e3):
        _check_general_shape(4, 6, 10, 4, scale=scale, seed=7)


def test_ragged_equals_explicitly_padded_aligned_bitwise():
    """The contract behind the whole refactor, stated bitwise: factorizing a
    ragged matrix IS factorizing its zero-padded aligned embedding — same
    ops, same floats. (This is also what pins the aligned path to the seed:
    aligned inputs take the identical code with zero padding elided.)"""
    P, m_loc, n, b = 4, 6, 10, 4
    rng = np.random.default_rng(11)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    comm = SimComm(P)
    geom = sweep_geometry(P, m_loc, n, b)
    A_pad = pad_to_geometry(comm, A, geom)
    assert A_pad.shape == (P, geom.m_loc_pad, geom.n_work)

    ragged = caqr_factorize(A, comm, b, collect_bundles=True, use_scan=False)
    aligned = caqr_factorize(A_pad, comm, b, collect_bundles=True,
                             use_scan=False)
    # R: the ragged result is the [:k, :n] slice of the aligned assembly
    assert np.array_equal(
        np.asarray(ragged.R), np.asarray(aligned.R)[:, :geom.k, :n]
    )
    # factors and bundles: bit-identical trees (both live in padded space)
    for g, r in zip(
        jax.tree_util.tree_leaves((ragged.factors, ragged.bundles)),
        jax.tree_util.tree_leaves((aligned.factors, aligned.bundles)),
    ):
        assert np.array_equal(np.asarray(g), np.asarray(r))


def test_windowed_matches_scan_on_ragged(rng):
    P, m_loc, n, b = 4, 6, 10, 4
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    scan = caqr_factorize(A, comm, b, use_scan=True)
    win = caqr_factorize(A, comm, b, use_scan=False)
    np.testing.assert_allclose(
        np.asarray(scan.R), np.asarray(win.R), rtol=1e-6, atol=1e-6
    )


def test_batched_vmap_front_end(rng):
    """A stack of ragged problems through one vmapped sweep equals the
    per-problem loop, and the batched Q^T replay conforms."""
    batch, P, m_loc, n, b = 3, 4, 6, 10, 4
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((batch, P, m_loc, n)), jnp.float32)
    res = caqr_factorize_batched(A, comm, b)
    assert res.R.shape == (batch, P, min(P * m_loc, n), n)
    for i in range(batch):
        one = caqr_factorize(A[i], comm, b)
        np.testing.assert_allclose(
            np.asarray(res.R[i]), np.asarray(one.R), rtol=2e-5, atol=2e-5
        )
    QtA = caqr_apply_qt_batched(A, res.factors, comm)
    for i in range(batch):
        Qf = np.asarray(QtA[i]).reshape(-1, n)
        Af = np.asarray(A[i]).reshape(-1, n)
        G = Af.T @ Af
        np.testing.assert_allclose(
            Qf.T @ Qf, G, atol=3e-3 * np.abs(G).max()
        )


def test_sweep_geometry_invariants():
    """The static geometry rules the padding correctness rests on."""
    for P in (2, 4, 8):
        for m_loc in (1, 2, 5, 6, 8):
            for n in (1, 3, 10, 16, 40):
                for b in (1, 3, 4, 8):
                    g = sweep_geometry(P, m_loc, n, b)
                    assert g.m_loc_pad % b == 0 and g.m_loc_pad >= b
                    assert g.m_loc_pad >= m_loc
                    assert g.k == min(P * m_loc, n)
                    assert g.n_panels * b >= g.k
                    assert g.n_panels * b <= P * g.m_loc_pad
                    assert g.n_work >= max(n, g.n_panels * b)
                    if m_loc % b == 0 and n % b == 0 and n <= P * m_loc:
                        assert g.aligned


# ---------------------------------------------------------------------------
# Hypothesis tier: random shapes drawn from the full general-shape space.
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        p_pow=st.integers(1, 3),
        m_loc=st.integers(1, 12),
        n=st.integers(1, 24),
        b=st.sampled_from([1, 2, 3, 4, 8]),
        scale=st.sampled_from([1e-3, 1.0, 1e3]),
        seed=st.integers(0, 2**16),
    )
    def test_general_shape_differential_harness(p_pow, m_loc, n, b, scale, seed):
        """Random (m, n, b, P, scale) including ragged/wide/tiny degenerate
        shapes: sign-fixed R vs numpy, Gram identity, Q^T orthogonality."""
        _check_general_shape(2**p_pow, m_loc, n, b, scale=scale, seed=seed)

    @settings(max_examples=10, deadline=None)
    @given(
        m_loc=st.integers(2, 10),
        n=st.integers(1, 20),
        rhs=st.integers(1, 3),
        seed=st.integers(0, 2**16),
    )
    def test_lstsq_differential_harness(m_loc, n, rhs, seed):
        """caqr_lstsq vs numpy.linalg.lstsq on random general shapes (basic
        solution on wide problems: trailing components pinned to zero)."""
        P, b = 4, 4
        rng = np.random.default_rng(seed)
        A = rng.standard_normal((P, m_loc, n)).astype(np.float32)
        bv = rng.standard_normal((P, m_loc, rhs)).astype(np.float32)
        x = np.asarray(caqr_lstsq(jnp.asarray(A), jnp.asarray(bv),
                                  SimComm(P), b))
        K = min(P * m_loc, n)
        Af, bf = A.reshape(-1, n), bv.reshape(-1, rhs)
        if K == n:  # tall: unique LS solution
            x_ref, *_ = np.linalg.lstsq(Af, bf, rcond=None)
            np.testing.assert_allclose(x, x_ref, rtol=5e-2, atol=5e-3)
        else:  # wide: basic solution solves the system exactly
            assert np.all(x[K:] == 0)
            np.testing.assert_allclose(
                Af @ x, bf, rtol=0,
                atol=5e-4 * max(np.abs(bf).max(), 1.0),
            )
else:

    @pytest.mark.skip(reason="hypothesis not installed — deterministic tier "
                             "above still ran; tools/ci.sh fails loudly here")
    def test_general_shape_differential_harness():
        pass
