"""TSQR (baseline + FT butterfly) and trailing update (Alg 1 + Alg 2)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    SimComm, baseline_tsqr, ft_tsqr, ft_tsqr_q, local_tsqr, local_tsqr_q,
    trailing_update_baseline, trailing_update_ft, tsqr_orthonormalize,
)


def _signfix(R):
    s = np.sign(np.diag(R))
    s = np.where(s == 0, 1.0, s)
    return R * s[:, None]


@pytest.mark.parametrize("P,m_loc,b", [(2, 16, 8), (4, 32, 8), (8, 32, 16), (16, 16, 8)])
def test_ft_tsqr_r_replicated_and_correct(rng, P, m_loc, b):
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, b)), jnp.float32)
    fac = ft_tsqr(A, comm)
    # paper claim: every lane holds the bit-identical final R
    assert np.all(np.asarray(fac.R) == np.asarray(fac.R[0]))
    Rr = np.linalg.qr(np.asarray(A).reshape(-1, b), mode="r")
    np.testing.assert_allclose(
        _signfix(np.asarray(fac.R[0])), _signfix(Rr), rtol=3e-4, atol=3e-4
    )


def test_ft_tsqr_q_orthonormal_and_reconstructs(rng):
    P, m_loc, b = 8, 32, 16
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, b)), jnp.float32)
    fac = ft_tsqr(A, comm)
    Q = np.asarray(ft_tsqr_q(fac, comm)).reshape(-1, b)
    np.testing.assert_allclose(Q.T @ Q, np.eye(b), atol=5e-6)
    np.testing.assert_allclose(
        Q @ np.asarray(fac.R[0]), np.asarray(A).reshape(-1, b), atol=1e-4
    )


def test_baseline_tsqr_root_only(rng):
    P, m_loc, b = 8, 16, 8
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, b)), jnp.float32)
    fac = baseline_tsqr(A, comm)
    Rr = np.linalg.qr(np.asarray(A).reshape(-1, b), mode="r")
    np.testing.assert_allclose(
        _signfix(np.asarray(fac.R[0])), _signfix(Rr), rtol=3e-4, atol=3e-4
    )
    # non-root lanes carry zeros after the tree (they went idle)
    assert np.abs(np.asarray(fac.R[1:])).max() == 0.0
    # broadcast_r replicates the root's R (what FT gets structurally)
    fac_b = baseline_tsqr(A, comm, broadcast_r=True)
    assert np.all(np.asarray(fac_b.R) == np.asarray(fac_b.R[0]))


def test_local_chain_tsqr(rng):
    A = jnp.asarray(rng.standard_normal((256, 16)), jnp.float32)
    Q, R = tsqr_orthonormalize(A, 64)
    Qn = np.asarray(Q)
    np.testing.assert_allclose(Qn.T @ Qn, np.eye(16), atol=5e-6)
    np.testing.assert_allclose(np.asarray(Q @ R), np.asarray(A), atol=1e-4)


@pytest.mark.parametrize("P", [4, 8])
def test_trailing_ft_is_orthogonal_transform(rng, P):
    m_loc, b, n = 32, 8, 24
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, b)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    fac = ft_tsqr(A, comm)
    C_new, bundle, cpr = trailing_update_ft(C, fac, comm)
    Cf = np.asarray(C).reshape(-1, n)
    Cn = np.asarray(C_new).reshape(-1, n)
    np.testing.assert_allclose(Cn.T @ Cn, Cf.T @ Cf, rtol=3e-4, atol=1e-3)


def test_trailing_ft_r12_deposit(rng):
    """The top rows of the virtual result (Q^T C) land on the target lane."""
    P, m_loc, b, n = 8, 32, 16, 24
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, b)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    fac = ft_tsqr(A, comm)
    _, _, cpr = trailing_update_ft(C, fac, comm)
    Q = np.asarray(ft_tsqr_q(fac, comm)).reshape(-1, b)
    R12_ref = Q.T @ np.asarray(C).reshape(-1, n)
    np.testing.assert_allclose(np.asarray(cpr[P - 1]), R12_ref, atol=1e-4)


def test_trailing_baseline_matches_dense(rng):
    P, m_loc, b, n = 4, 16, 8, 12
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, b)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    fac = baseline_tsqr(A, comm)
    C_new = trailing_update_baseline(C, fac, comm)
    Cf = np.asarray(C).reshape(-1, n)
    Cn = np.asarray(C_new).reshape(-1, n)
    np.testing.assert_allclose(Cn.T @ Cn, Cf.T @ Cf, rtol=3e-4, atol=1e-3)


def test_alg2_equals_alg1_per_lane(rng):
    """Paper's central correctness claim: Algorithm 2 (with its verbatim
    retirement semantics) produces exactly Algorithm 1's per-lane outputs —
    the redundancy is in the retained bundles, not in changed results."""
    import jax.numpy as jnp2

    P, m_loc, b, n = 8, 16, 8, 20
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, b)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    # target=0 orients the butterfly's stacking the classical way
    # (receiver/survivor on top) so the factors match the baseline tree's.
    fac = ft_tsqr(A, comm, target=0)
    C_ft, _, _ = trailing_update_ft(
        C, fac, comm, target=jnp2.asarray(0), paper_semantics=True
    )
    C_bl = trailing_update_baseline(C, fac, comm)
    np.testing.assert_allclose(
        np.asarray(C_ft), np.asarray(C_bl), rtol=1e-4, atol=1e-4
    )


def test_butterfly_generalization_valid(rng):
    """The default full-butterfly variant differs per lane from Alg 1 on
    residual slots but is still an exact orthogonal reduction (same Gram,
    same R12 deposit at the root)."""
    P, m_loc, b, n = 8, 16, 8, 20
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, b)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    import jax.numpy as jnp2

    fac = ft_tsqr(A, comm)
    C_bf, _, cpr_bf = trailing_update_ft(C, fac, comm)
    fac0 = ft_tsqr(A, comm, target=0)
    C_pp, _, cpr_pp = trailing_update_ft(
        C, fac0, comm, target=jnp2.asarray(0), paper_semantics=True
    )
    # same R12 rows up to per-row signs (the two stackings differ by a
    # diagonal +-1): the butterfly deposits on lane P-1, the classical
    # survivor chain on lane 0.
    np.testing.assert_allclose(
        np.abs(np.asarray(cpr_bf[P - 1])), np.abs(np.asarray(cpr_pp[0])),
        atol=1e-3,
    )
    # both norm-preserving
    Cf = np.asarray(C).reshape(-1, n)
    for Cx in (C_bf, C_pp):
        Cn = np.asarray(Cx).reshape(-1, n)
        np.testing.assert_allclose(Cn.T @ Cn, Cf.T @ Cf, rtol=3e-4, atol=1e-3)
