"""FT-CAQR end-to-end + the paper's failure/recovery protocol."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    SimComm, caqr_apply_qt, caqr_factorize, ft_tsqr, trailing_update_ft,
)
from repro.core import recovery as rec


def _signfix(R):
    s = np.sign(np.diag(R))
    s = np.where(s == 0, 1.0, s)
    return R * s[:, None]


@pytest.mark.parametrize(
    "P,m_loc,n,b",
    [(4, 16, 32, 4), (8, 32, 64, 8), (8, 16, 128, 8), (4, 32, 128, 8)],
)
def test_caqr_matches_lapack(rng, P, m_loc, n, b):
    """Includes square cases (n == P*m_loc) where panels sweep across the
    full row ownership (target-lane rotation + dead-lane masking)."""
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    res = caqr_factorize(A, comm, b)
    Af = np.asarray(A).reshape(-1, n)
    Rr = np.linalg.qr(Af, mode="r")
    Rc = np.asarray(res.R[0])
    # R replicated on every lane (FT broadcast property)
    assert np.all(np.asarray(res.R) == Rc)
    scale = max(1.0, np.abs(Rr).max())
    np.testing.assert_allclose(
        _signfix(Rc) / scale, _signfix(Rr) / scale, atol=2e-5
    )
    # Gram identity: R^T R == A^T A (validity of R regardless of sign conv.)
    G = Af.T @ Af
    np.testing.assert_allclose(Rc.T @ Rc, G, atol=2e-3 * np.abs(G).max())


def test_caqr_implicit_q_replay(rng):
    """Replaying the stored factors against A itself must reproduce an
    orthogonally-transformed matrix with the same Gram (Q^T A)."""
    P, m_loc, n, b = 8, 16, 64, 8
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    res = caqr_factorize(A, comm, b)
    QtA = caqr_apply_qt(A, res.factors, comm)
    Af = np.asarray(A).reshape(-1, n)
    Qf = np.asarray(QtA).reshape(-1, n)
    np.testing.assert_allclose(
        Qf.T @ Qf, Af.T @ Af, atol=2e-3 * np.abs(Af.T @ Af).max()
    )


def test_caqr_tall(rng):
    P, m_loc, n, b = 8, 64, 32, 8
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    res = caqr_factorize(A, comm, b)
    Rr = np.linalg.qr(np.asarray(A).reshape(-1, n), mode="r")
    np.testing.assert_allclose(
        _signfix(np.asarray(res.R[0])), _signfix(Rr), rtol=3e-4, atol=3e-4
    )


# ---------------------------------------------------------------------------
# Recovery (paper §III-B / §III-C claims)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("level", [0, 1, 2])
@pytest.mark.parametrize("failed", [0, 3, 5, 7])
def test_single_source_recovery_exact(rng, level, failed):
    """Kill any lane after any level; rebuild from ONE buddy; the finished
    update must equal the failure-free run bit-for-bit."""
    P, m_loc, b, n = 8, 32, 8, 24
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, b)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    fac = ft_tsqr(A, comm)
    clean = rec.run_ft_trailing(C, fac, comm)
    faulty = rec.run_ft_trailing(
        C, fac, comm, fail_at_level=level, failed_lane=failed, A_stacked=C
    )
    assert np.array_equal(np.asarray(clean), np.asarray(faulty))


def test_recovery_reads_one_source_only(rng):
    """The reconstruction function receives the bundle and touches exactly
    one lane's slice of it."""
    P, m_loc, b, n = 8, 16, 8, 16
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, m_loc, b)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((P, m_loc, n)), jnp.float32)
    fac = ft_tsqr(A, comm)
    state = rec.trailing_begin(C, fac, comm)
    state, bundle = rec.trailing_level(state, fac, comm)
    failed, source = 2, 3  # buddies at level 0
    expected = state.C_prime[failed]
    # corrupt every OTHER lane's bundle: recovery must still be exact
    def poison(x):
        x = np.asarray(x).copy()
        for lane in range(P):
            if lane != source:
                x[lane] = np.nan
        return jnp.asarray(x)

    poisoned = rec.LevelBundle(
        W=poison(bundle.W), C_buddy=poison(bundle.C_buddy),
        Y2=poison(bundle.Y2), T=poison(bundle.T),
        buddy_was_top=bundle.buddy_was_top,
    )
    got = rec.recover_cprime(poisoned, failed, source)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expected), atol=1e-5)


def test_tsqr_r_recovery(rng):
    P = 8
    comm = SimComm(P)
    A = jnp.asarray(rng.standard_normal((P, 32, 8)), jnp.float32)
    fac = ft_tsqr(A, comm)
    # any single redundancy-group member supplies the failed lane's R
    got = rec.tsqr_recover_r(fac, failed=5, source=5 ^ 4)
    assert np.array_equal(np.asarray(got), np.asarray(fac.R[5]))
