"""End-to-end FT sweep driver: the paper's headline claim as a regression.

A lane dies at any panel, at any TSQR or trailing-combine tree level, is
respawned and rebuilt from its re-read initial slice plus single-source
buddy fetches — and the finished factorization (R, per-panel factors, AND
recovery bundles) is bit-identical to the failure-free windowed sweep.
Death is simulated by NaN-poisoning everything the lane holds, so any read
of dead state fails the bit-identity oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import SimComm, caqr_factorize, sweep_geometry
from repro.ft import (
    FailureSchedule,
    UnrecoverableFailure,
    ft_caqr_sweep,
    iter_sweep_points,
    sweep_point,
)

# square case: the sweep crosses row-ownership boundaries, so the kill
# matrix covers target-lane rotation and consumed (inactive) lanes too
P, M_LOC, N, B = 4, 8, 16, 4
N_PANELS, LEVELS = N // B, 2


def _matrix(P_=P, m_loc=M_LOC, n=N, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((P_, m_loc, n)), jnp.float32)


def _leaves(*trees):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(trees)]


def _assert_bit_identical(got, ref):
    for g, r in zip(
        _leaves(got.R, got.factors, got.bundles),
        _leaves(ref.R, ref.factors, ref.bundles),
    ):
        assert np.array_equal(g, r), "driver output differs from failure-free sweep"


@pytest.fixture(scope="module")
def reference():
    A = _matrix()
    ref = caqr_factorize(A, SimComm(P), B, collect_bundles=True, use_scan=False)
    return A, ref


def _all_points(n_panels=N_PANELS, levels=LEVELS):
    return list(iter_sweep_points(n_panels, levels))


def test_failure_free_driver_matches_windowed_sweep(reference):
    """With no schedule, the level-stepped driver IS the windowed sweep."""
    A, ref = reference
    got = ft_caqr_sweep(A, SimComm(P), B)
    _assert_bit_identical(got, ref)
    assert got.events == []


@pytest.mark.parametrize("lane", range(P))
@pytest.mark.parametrize(
    "point",
    _all_points(),
    ids=lambda p: f"p{p[0]}-{p[1]}{p[2]}",
)
def test_kill_matrix_single_source_rebuild(reference, point, lane):
    """Every lane x every phase/level x every panel: kill, rebuild from
    single-source buddy fetches, finish — bit-identical to failure-free."""
    A, ref = reference
    sched = FailureSchedule(events={point: [lane]})
    got = ft_caqr_sweep(A, SimComm(P), B, schedule=sched)
    _assert_bit_identical(got, ref)
    (event,) = got.events
    assert event.point == point and event.lane == lane
    # the single-source ledger: every artifact came from exactly one
    # surviving lane, never the failed one
    assert all(src != lane for src in event.reads.values())
    assert all(0 <= src < P for src in event.reads.values())
    # mid-tree deaths must actually fetch something
    if point[1] != "leaf" or point[0] > 0:
        assert event.reads, f"no fetches recorded for {point}"


def test_two_failures_in_different_panels(reference):
    A, ref = reference
    sched = FailureSchedule(events={
        sweep_point(0, "trailing", 1): [2],
        sweep_point(2, "tsqr", 0): [1],
    })
    got = ft_caqr_sweep(A, SimComm(P), B, schedule=sched)
    _assert_bit_identical(got, ref)
    assert [(e.point, e.lane) for e in got.events] == [
        ((0, "trailing", 1), 2), ((2, "tsqr", 0), 1),
    ]


def test_same_lane_dies_twice(reference):
    """A lane can die, be rebuilt, and die again panels later — the second
    REBUILD replays through state that itself contains recovered data."""
    A, ref = reference
    sched = FailureSchedule(events={
        sweep_point(0, "trailing", 0): [1],
        sweep_point(3, "trailing", 1): [1],
    })
    got = ft_caqr_sweep(A, SimComm(P), B, schedule=sched)
    _assert_bit_identical(got, ref)
    assert len(got.events) == 2


def test_simultaneous_non_buddy_deaths_recover(reference):
    A, ref = reference
    sched = FailureSchedule(events={sweep_point(1, "trailing", 0): [0, 3]})
    got = ft_caqr_sweep(A, SimComm(P), B, schedule=sched)
    _assert_bit_identical(got, ref)
    assert len(got.events) == 2


def test_buddy_pair_death_is_unrecoverable():
    """Both members of a level-0 pair die at once: the single source that
    holds the needed bundle is dead — the driver must say so, not fabricate."""
    A = _matrix()
    sched = FailureSchedule(events={sweep_point(1, "trailing", 0): [2, 3]})
    with pytest.raises(UnrecoverableFailure):
        ft_caqr_sweep(A, SimComm(P), B, schedule=sched)


def test_recovery_sources_are_tree_buddies(reference):
    """The ledger's sources are exactly the XOR-buddies the paper names:
    lane^1 for the TSQR ladder, lane^(1<<s) for level-s trailing state."""
    A, ref = reference
    lane, lvl = 2, 1
    sched = FailureSchedule(events={sweep_point(1, "trailing", lvl): [lane]})
    got = ft_caqr_sweep(A, SimComm(P), B, schedule=sched)
    _assert_bit_identical(got, ref)
    (event,) = got.events
    assert event.reads["tsqr.ladder"] == lane ^ 1
    assert event.reads[f"trailing.cprime@level{lvl}"] == lane ^ (1 << lvl)
    for s in range(lvl + 1):
        assert event.reads[f"trailing.bundle@level{s}"] == lane ^ (1 << s)
    # panel 0 is complete: its final C' came from the last-level buddy
    assert event.reads["panel0.cprime_final"] == lane ^ (1 << (LEVELS - 1))


@pytest.mark.parametrize("lane", [0, 3, 5, 7])
@pytest.mark.parametrize("point", [
    sweep_point(0, "trailing", 2),
    sweep_point(3, "tsqr", 2),
    sweep_point(7, "trailing", 1),
    sweep_point(5, "leaf"),
], ids=lambda p: f"p{p[0]}-{p[1]}{p[2]}")
def test_kill_matrix_p8_spot(point, lane):
    """Three-level tree (P=8), square sweep: deeper-buddy recovery paths."""
    P8, m8, n8, b8 = 8, 8, 32, 4
    A = _matrix(P8, m8, n8, seed=1)
    comm = SimComm(P8)
    ref = caqr_factorize(A, comm, b8, collect_bundles=True, use_scan=False)
    got = ft_caqr_sweep(A, comm, b8, schedule=FailureSchedule(events={point: [lane]}))
    _assert_bit_identical(got, ref)


# -- ragged geometry: the general-shape sweep under the same kill matrix ----
#
# P=4, m_loc=6, n=10, b=4: unaligned lane heights AND a ragged last panel —
# the padded sweep_geometry runs at (8, 12) with 3 panels, and every REBUILD
# (including re-reading the respawned lane's *padded* initial slice) must
# reproduce the failure-free general-shape sweep bit for bit.
RP, RM_LOC, RN, RB = 4, 6, 10, 4
RGEOM = sweep_geometry(RP, RM_LOC, RN, RB)
assert (RGEOM.m_loc_pad, RGEOM.n_work, RGEOM.n_panels) == (8, 12, 3)


@pytest.fixture(scope="module")
def ragged_reference():
    A = _matrix(RP, RM_LOC, RN, seed=3)
    ref = caqr_factorize(A, SimComm(RP), RB, collect_bundles=True,
                         use_scan=False)
    return A, ref


def test_failure_free_ragged_driver_matches_sweep(ragged_reference):
    A, ref = ragged_reference
    got = ft_caqr_sweep(A, SimComm(RP), RB)
    _assert_bit_identical(got, ref)
    assert got.events == []
    assert got.R.shape == (RP, RGEOM.k, RN)


@pytest.mark.parametrize("lane", [0, 1, 3])
@pytest.mark.parametrize("point", [
    sweep_point(0, "leaf"),
    sweep_point(0, "trailing", 1),
    sweep_point(1, "tsqr", 0),
    sweep_point(2, "trailing", 0),   # ragged last panel, mid-trailing
    sweep_point(2, "tsqr", 1),       # ragged last panel, deep butterfly
], ids=lambda p: f"p{p[0]}-{p[1]}{p[2]}")
def test_kill_matrix_ragged_spot(ragged_reference, point, lane):
    """Ragged-geometry spot kills (tier-1): single-source REBUILD over
    padded panels, bit-identical to the failure-free general-shape sweep."""
    A, ref = ragged_reference
    sched = FailureSchedule(events={point: [lane]})
    got = ft_caqr_sweep(A, SimComm(RP), RB, schedule=sched)
    _assert_bit_identical(got, ref)
    (event,) = got.events
    assert event.point == point and event.lane == lane
    assert all(src != lane for src in event.reads.values())


def test_kill_matrix_wide_spot():
    """Wide geometry (n > P*m_loc): the trailing-only R2 columns survive a
    mid-sweep death and REBUILD bit-identically too."""
    Pw, mw, nw, bw = 4, 4, 24, 4
    A = _matrix(Pw, mw, nw, seed=4)
    comm = SimComm(Pw)
    ref = caqr_factorize(A, comm, bw, collect_bundles=True, use_scan=False)
    sched = FailureSchedule(events={sweep_point(2, "trailing", 1): [2]})
    got = ft_caqr_sweep(A, comm, bw, schedule=sched)
    _assert_bit_identical(got, ref)
    assert got.R.shape == (Pw, Pw * mw, nw)  # [R1 R2]


@pytest.mark.slow
@pytest.mark.parametrize("lane", range(RP))
def test_kill_matrix_ragged_exhaustive(ragged_reference, lane):
    """Every lane x every phase/level x every (padded) panel of the ragged
    geometry (slow tier)."""
    A, ref = ragged_reference
    for pt in iter_sweep_points(RGEOM.n_panels, LEVELS):
        got = ft_caqr_sweep(
            A, SimComm(RP), RB, schedule=FailureSchedule(events={pt: [lane]})
        )
        _assert_bit_identical(got, ref)


@pytest.mark.slow
@pytest.mark.parametrize("lane", range(8))
def test_kill_matrix_p8_exhaustive(lane):
    """Full 3-level kill matrix on the tall P=8 case (slow tier)."""
    P8, m8, n8, b8 = 8, 16, 32, 4
    A = _matrix(P8, m8, n8, seed=2)
    comm = SimComm(P8)
    ref = caqr_factorize(A, comm, b8, collect_bundles=True, use_scan=False)
    for k in range(n8 // b8):
        for pt in (
            [sweep_point(k, "leaf")]
            + [sweep_point(k, ph, s) for s in range(3) for ph in ("tsqr", "trailing")]
        ):
            got = ft_caqr_sweep(
                A, comm, b8, schedule=FailureSchedule(events={pt: [lane]})
            )
            _assert_bit_identical(got, ref)
