"""Training loop + fault-tolerance integration tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt import diskless, save
from repro.configs import get_smoke
from repro.data.pipeline import DataConfig, Pipeline, make_batch
from repro.ft.failures import FailureSchedule
from repro.ft.semantics import Semantics
from repro.train import TrainConfig, Trainer


@pytest.fixture(scope="module")
def dcfg():
    cfg = get_smoke("tinyllama-1.1b")
    return DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=1)


def test_loss_decreases(dcfg):
    cfg = get_smoke("tinyllama-1.1b")
    tcfg = TrainConfig(steps=25, lr=1e-2, warmup=5, n_lanes=4, log_every=100)
    tr = Trainer(cfg, tcfg, dcfg)
    hist = tr.run()
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.8


@pytest.mark.parametrize("optimizer", ["adamw", "caqr_muon"])
def test_rebuild_is_bit_identical(dcfg, optimizer):
    cfg = get_smoke("tinyllama-1.1b")
    tcfg = TrainConfig(steps=20, lr=1e-2, warmup=5, n_lanes=4,
                       diskless_every=5, log_every=100,
                       semantics=Semantics.REBUILD, optimizer=optimizer)
    ref = Trainer(cfg, tcfg, dcfg)
    ref.run()
    failed = Trainer(cfg, tcfg, dcfg)
    failed.run(FailureSchedule(events={13: [2]}))
    for a, b in zip(jax.tree_util.tree_leaves(ref.state.params),
                    jax.tree_util.tree_leaves(failed.state.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_shrink_continues(dcfg):
    cfg = get_smoke("tinyllama-1.1b")
    tcfg = TrainConfig(steps=15, lr=1e-2, warmup=3, n_lanes=4, log_every=100,
                       semantics=Semantics.SHRINK)
    tr = Trainer(cfg, tcfg, dcfg)
    hist = tr.run(FailureSchedule(events={7: [1]}))
    assert hist[-1]["lanes"] == 3
    assert np.isfinite(hist[-1]["loss"])


def test_blank_continues(dcfg):
    cfg = get_smoke("tinyllama-1.1b")
    tcfg = TrainConfig(steps=12, lr=1e-2, warmup=3, n_lanes=4, log_every=100,
                       semantics=Semantics.BLANK)
    tr = Trainer(cfg, tcfg, dcfg)
    hist = tr.run(FailureSchedule(events={6: [0]}))
    assert hist[-1]["lanes"] == 3


def test_abort_raises(dcfg):
    cfg = get_smoke("tinyllama-1.1b")
    tcfg = TrainConfig(steps=10, n_lanes=4, log_every=100,
                       semantics=Semantics.ABORT)
    tr = Trainer(cfg, tcfg, dcfg)
    with pytest.raises(RuntimeError):
        tr.run(FailureSchedule(events={3: [1]}))


def test_disk_checkpoint_roundtrip(tmp_path, dcfg):
    cfg = get_smoke("tinyllama-1.1b")
    tcfg = TrainConfig(steps=6, n_lanes=2, log_every=100)
    tr = Trainer(cfg, tcfg, dcfg)
    tr.run()
    tag = save.save(str(tmp_path), 6, tr.state.params, tr.state.opt_state)
    assert save.latest_step(str(tmp_path)) == 6
    p2, o2, manifest = save.restore(str(tmp_path), tr.state.params, tr.state.opt_state)
    for a, b in zip(jax.tree_util.tree_leaves(tr.state.params),
                    jax.tree_util.tree_leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_parity_store_recovers(rng):
    st = diskless.ParityStore(8, group=4)
    states = [{"w": rng.standard_normal((16, 16)).astype(np.float32),
               "b": rng.standard_normal((16,)).astype(np.float32)}
              for _ in range(8)]
    st.push_group(states)
    for failed in (0, 3, 5):
        got = st.recover(failed)
        assert np.array_equal(got["w"], states[failed]["w"])
        assert np.array_equal(got["b"], states[failed]["b"])


def test_buddy_store_single_source(rng):
    st = diskless.BuddyStore(4)
    states = [{"x": np.full((4,), i, np.float32)} for i in range(4)]
    for lane, s in enumerate(states):
        st.push(lane, s)
    for failed in range(4):
        got = st.recover(failed)
        assert np.array_equal(got["x"], states[failed]["x"])


def test_pipeline_prefetch_and_resume(dcfg):
    p = Pipeline(dcfg, start_step=3, prefetch=2)
    step, batch = next(p)
    assert step == 3
    ref = make_batch(dcfg, 3, lo=0, hi=dcfg.global_batch)
    assert np.array_equal(batch["tokens"], ref["tokens"])
    step2, _ = next(p)
    assert step2 == 4
    p.close()


def test_powersgd_compresses_and_converges(rng):
    """Error-feedback PowerSGD-QR: compressed gradient converges to the true
    mean over iterations on a fixed problem."""
    from repro.optim import powersgd

    m, n, r = 64, 32, 4
    G = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    omega = jnp.asarray(rng.standard_normal((n, r)), jnp.float32)
    err = jnp.zeros((m, n), jnp.float32)
    applied = jnp.zeros((m, n), jnp.float32)
    T = 30
    for _ in range(T):
        G_hat, err, omega = powersgd.compress_reduce(G, omega, err, axis_name=None)
        applied = applied + G_hat
    # the error-feedback guarantee: sum of applied updates = T*G - err_T,
    # so the mean applied gradient converges to the true gradient
    mean_applied = np.asarray(applied) / T
    rel = np.linalg.norm(mean_applied - np.asarray(G)) / np.linalg.norm(np.asarray(G))
    assert rel < 0.2, rel
    # exact identity: applied + err == T * G
    np.testing.assert_allclose(
        np.asarray(applied + err), T * np.asarray(G), rtol=1e-3, atol=1e-2
    )


def test_caqr_muon_orthogonalizes(rng):
    from repro.optim.caqr_muon import _orth

    M = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    O = np.asarray(_orth(M))
    np.testing.assert_allclose(O.T @ O, np.eye(16), atol=1e-4)
    Mw = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
    Ow = np.asarray(_orth(Mw))
    np.testing.assert_allclose(Ow @ Ow.T, np.eye(16), atol=1e-4)
