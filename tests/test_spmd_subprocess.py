"""Multi-device SPMD tests (subprocess with 8 forced host devices so the
main test process keeps seeing one device)."""
import pytest

from spmd_subprocess_util import run_forced_devices


def _run(code: str) -> str:
    return run_forced_devices(code, n_devices=8)


@pytest.mark.slow
def test_shardmap_matches_simcomm():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core import AxisComm, SimComm, ft_tsqr, ft_tsqr_q
        from repro.core.caqr import caqr_factorize, caqr_factorize_spmd
        from repro.dist import compat
        # b=4 / m_loc=8 tiles: XLA lowers the per-lane and vmap-batched
        # gemms identically on CPU at this size, so the comparison is
        # bitwise (DESIGN.md section 8; larger tiles reassociate on CPU)
        Pn = 8
        mesh = compat.make_mesh((Pn,), ("x",))
        rng = np.random.default_rng(0)
        A = jnp.asarray(rng.standard_normal((Pn * 8, 32)), jnp.float32)
        def f(a):
            return caqr_factorize_spmd(a, "x", 4).R
        with compat.set_mesh(mesh):
            R = jax.jit(compat.shard_map(f, mesh, in_specs=P("x", None),
                                         out_specs=P()))(A)
        sim = caqr_factorize(A.reshape(Pn, 8, 32), SimComm(Pn), 4)
        assert np.array_equal(np.asarray(R), np.asarray(sim.R[0])), "mismatch"
        hlo = jax.jit(compat.shard_map(f, mesh, in_specs=P("x", None),
                                       out_specs=P())
                      ).lower(A).compile().as_text()
        assert "collective-permute" in hlo
        print("SPMD_OK")
    """)
    assert "SPMD_OK" in out


@pytest.mark.slow
def test_dryrun_cell_small_mesh():
    """A miniature dry-run: lower+compile a train cell on an 8-device
    (4 data x 2 model) mesh with a reduced config."""
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke
        from repro.dist import compat, params_sharding as psh, sharding as shd
        from repro.launch.mesh import make_small_mesh
        from repro.models import api
        from repro.optim.adamw import adamw
        from repro.optim.schedule import constant
        from repro.train.step import TrainState, make_train_step
        mesh = make_small_mesh(4, 2)
        cfg = dataclasses.replace(get_smoke("tinyllama-1.1b"), dtype="bfloat16")
        opt = adamw()
        step = make_train_step(cfg, opt, constant(1e-3))
        params_abs = api.param_specs(cfg)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        batch_abs = {
            "tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32),
        }
        state_abs = TrainState(params_abs, opt_abs, jax.ShapeDtypeStruct((), jnp.int32))
        p_sh = psh.tree_shardings(params_abs, mesh, "data")
        o_sh = psh.tree_shardings(opt_abs, mesh, "data")
        b_sh = psh.batch_shardings(batch_abs, mesh, "data")
        state_sh = TrainState(p_sh, o_sh, NamedSharding(mesh, P()))
        rules = {"batch": "data", "vocab": "model", "heads": "model",
                 "kv_heads": "model", "ff": "model", "experts": "model",
                 "ssm_heads": "model", "lru": "model", "seq_shard": None,
                 "kv_seq_shard": None}
        with compat.set_mesh(mesh), shd.use_rules(rules):
            compiled = jax.jit(step, in_shardings=(state_sh, b_sh),
                               out_shardings=(state_sh, None)).lower(
                state_abs, batch_abs).compile()
        ma = compiled.memory_analysis()
        assert ma.temp_size_in_bytes > 0
        ca = compiled.cost_analysis()
        if isinstance(ca, list):  # pre-0.5 jax returns [dict]
            ca = ca[0]
        assert ca.get("flops", 0) > 0
        print("DRYRUN_OK", int(ma.temp_size_in_bytes))
    """)
    assert "DRYRUN_OK" in out


@pytest.mark.slow
def test_elastic_shrink_reshard():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.ft import elastic
        mesh = elastic.make_data_model_mesh(4, 2)
        params = {"w": jnp.arange(64.0).reshape(8, 8)}
        sharded = elastic.reshard(params, mesh)
        small = elastic.shrink_mesh(mesh, dead_data_lane=1)
        assert small.devices.shape == (3, 2)
        resharded = elastic.reshard(sharded, small)
        assert np.array_equal(np.asarray(resharded["w"]), np.asarray(params["w"]))
        gb, per = elastic.rebalance_batch(16, 4, 3)
        assert gb == 15 and per == 5
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


@pytest.mark.slow
def test_pod_train_step_with_compression():
    """shard_map over 'pod' with PowerSGD-QR cross-pod gradient reduction."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_smoke
        from repro.data.pipeline import DataConfig, make_batch
        from repro.models import transformer as tf
        from repro.optim.adamw import adamw
        from repro.optim import powersgd
        from repro.optim.schedule import constant
        from repro.train.step import PodTrainState, make_pod_train_step
        from repro.dist import compat
        mesh = compat.make_mesh((2, 4), ("pod", "data"))
        cfg = get_smoke("tinyllama-1.1b")
        params = tf.init_params(cfg, jax.random.key(0))
        opt = adamw()
        psgd = powersgd.init_state(jax.random.key(1), params, rank=4)
        state = PodTrainState(params, opt.init(params), psgd,
                              jnp.zeros((), jnp.int32))
        step = make_pod_train_step(cfg, opt, constant(1e-3), mesh,
                                   compression_rank=4)
        dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0)
        b = {k: jnp.asarray(v) for k, v in make_batch(dcfg, 0).items()}
        with compat.set_mesh(mesh):
            state2, metrics = jax.jit(step)(state, b)
        assert np.isfinite(float(metrics["loss"]))
        # params changed and identical across pods (replicated out-spec)
        d = jax.tree_util.tree_leaves(state2.params)[3]
        assert np.all(np.isfinite(np.asarray(d, np.float32)))
        print("POD_OK", float(metrics["loss"]))
    """)
    assert "POD_OK" in out
