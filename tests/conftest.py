"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the real
(single) device; multi-device tests spawn subprocesses."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def signfix(R):
    import numpy as np
    s = np.sign(np.diag(R))
    s = np.where(s == 0, 1.0, s)
    return R * s[:, None]
