"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the real
(single) device; multi-device tests spawn subprocesses."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


class FakeClock:
    """Deterministic monotonic clock: each call advances by ``tick``
    seconds. Inject into any ``clock=`` seam (``WallClockKiller``) so
    wall-clock-driven tests strike at a schedule-deterministic boundary
    regardless of host load."""

    def __init__(self, tick=1.0, start=0.0):
        self.tick = tick
        self.now = start
        self.calls = 0

    def __call__(self):
        t = self.now
        self.now += self.tick
        self.calls += 1
        return t


@pytest.fixture
def fake_clock():
    return FakeClock()


def signfix(R):
    import numpy as np
    s = np.sign(np.diag(R))
    s = np.where(s == 0, 1.0, s)
    return R * s[:, None]
