"""The QR-powered optimizer layer (``repro.optim.caqr_muon`` /
``repro.optim.powersgd``) against numpy references.

Both modules route their orthonormalization through the paper's TSQR
(``tsqr_orthonormalize``), so these are consumer-level gates on the same
primitive the FT sweep factors with: CAQR-Muon's orthogonalized momentum
must satisfy the exact delta^T delta = lr^2 * scale^2 * I invariant (a
sign-robust statement of "the update is orthonormal", avoiding QR's
column-sign ambiguity), and PowerSGD's rank-r compression must be EXACT
on a gradient that is already rank r, with the error-feedback identity
G_hat + new_error == G + error holding to float tolerance in general.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim.adamw import Optimizer
from repro.optim.caqr_muon import MuonState, _orth, _orth2d, caqr_muon
from repro.optim.powersgd import (
    PowerSGDState,
    compress_reduce,
    compress_tree,
    init_state,
)


def _rng(seed=0):
    return np.random.default_rng(seed)


# -- the orthonormalizer ------------------------------------------------------


@pytest.mark.parametrize("shape", [(512, 16), (256, 64), (64, 64)],
                         ids=lambda s: f"{s[0]}x{s[1]}")
def test_orth2d_tall_is_orthonormal_basis(shape):
    """Tall/square input: Q has orthonormal columns spanning the input's
    column space (Q Q^T M == M up to float tolerance)."""
    m, n = shape
    M = jnp.asarray(_rng(1).standard_normal((m, n)), jnp.float32)
    Q = np.asarray(_orth2d(M))
    assert Q.shape == (m, n)
    np.testing.assert_allclose(Q.T @ Q, np.eye(n), atol=1e-4)
    np.testing.assert_allclose(Q @ (Q.T @ np.asarray(M)), np.asarray(M),
                               atol=5e-3)


def test_orth2d_wide_transposes():
    """Wide input orthonormalizes the transpose: rows are orthonormal."""
    M = jnp.asarray(_rng(2).standard_normal((16, 512)), jnp.float32)
    Q = np.asarray(_orth2d(M))
    assert Q.shape == (16, 512)
    np.testing.assert_allclose(Q @ Q.T, np.eye(16), atol=1e-4)


def test_orth_stacked_matches_per_slice():
    """A stacked (G, D, F) bank orthogonalizes per slice via vmap —
    identical to calling the 2-D path on each slice."""
    M = jnp.asarray(_rng(3).standard_normal((3, 128, 32)), jnp.float32)
    got = np.asarray(_orth(M))
    for g in range(3):
        np.testing.assert_array_equal(got[g], np.asarray(_orth2d(M[g])))


# -- CAQR-Muon ----------------------------------------------------------------


def _toy_params():
    r = _rng(4)
    return {
        "dense": jnp.asarray(r.standard_normal((128, 32)), jnp.float32),
        "embed": jnp.asarray(r.standard_normal((64, 16)), jnp.float32),
        "bias": jnp.asarray(r.standard_normal((32,)), jnp.float32),
    }


def test_caqr_muon_is_optimizer_and_inits_zero():
    opt = caqr_muon()
    assert isinstance(opt, Optimizer)
    params = _toy_params()
    state = opt.init(params)
    assert isinstance(state, MuonState)
    assert int(state.step) == 0
    assert all(not np.asarray(m).any()
               for m in jax.tree_util.tree_leaves(state.mom))


def test_caqr_muon_update_is_orthonormal_scaled():
    """The muon invariant: for a 2-D non-excluded param the update delta
    satisfies delta^T delta == lr^2 * scale^2 * I exactly up to float
    tolerance (scale = sqrt(max(1, m/n))), no matter the gradient —
    sign-robust, unlike comparing Q against a reference QR."""
    opt = caqr_muon(weight_decay=0.0)
    params = _toy_params()
    state = opt.init(params)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.asarray(_rng(5).standard_normal(p.shape), jnp.float32),
        params)
    lr = 0.01
    updates, state = opt.update(grads, state, params, lr)
    d = np.asarray(updates["dense"], np.float64)
    m, n = d.shape
    scale2 = max(1.0, m / n)
    np.testing.assert_allclose(d.T @ d, lr * lr * scale2 * np.eye(n),
                               atol=1e-8)
    assert int(state.step) == 1


def test_caqr_muon_excluded_params_take_adam_path():
    """'embed'-matching and 1-D params fall back to Adam scaling: on the
    first step the update is -lr * adam_scale * sign-ish(g) — verified
    against the closed-form numpy reference."""
    b1, b2, eps, ascale = 0.95, 0.95, 1e-8, 0.3
    opt = caqr_muon(b1=b1, adam_b2=b2, eps=eps, adam_scale=ascale)
    params = _toy_params()
    state = opt.init(params)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.asarray(_rng(6).standard_normal(p.shape), jnp.float32),
        params)
    lr = 0.01
    updates, _ = opt.update(grads, state, params, lr)
    for name in ("embed", "bias"):
        g = np.asarray(grads[name], np.float64)
        # step 1 closed form: m_hat = g, v_hat = g^2 (bias correction
        # cancels the (1-b) factors exactly)
        ref = -lr * ascale * g / (np.abs(g) + eps)
        np.testing.assert_allclose(np.asarray(updates[name]), ref, atol=1e-6)


def test_caqr_muon_momentum_accumulates():
    """Two identical gradient steps: muon momentum is a plain sum
    (m <- b1*m + g), adam momentum an EMA — both against numpy."""
    b1 = 0.9
    opt = caqr_muon(b1=b1)
    params = _toy_params()
    state = opt.init(params)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.asarray(_rng(7).standard_normal(p.shape), jnp.float32),
        params)
    _, s1 = opt.update(grads, state, params, 0.01)
    _, s2 = opt.update(grads, s1, params, 0.01)
    g_dense = np.asarray(grads["dense"], np.float64)
    np.testing.assert_allclose(np.asarray(s2.mom["dense"]),
                               (1 + b1) * g_dense, rtol=1e-5)
    g_bias = np.asarray(grads["bias"], np.float64)
    np.testing.assert_allclose(np.asarray(s2.mom["bias"]),
                               (1 - b1) * (1 + b1) * g_bias, rtol=1e-5)


# -- PowerSGD-QR --------------------------------------------------------------


def test_powersgd_exact_on_low_rank():
    """A gradient that IS rank r reconstructs exactly (to float
    tolerance): G = U V^T with U (m, r), V (n, r) and a sketch of rank r
    — compress_reduce returns G_hat == G and a ~zero error buffer."""
    m, n, r = 256, 64, 4
    rng = _rng(8)
    U = rng.standard_normal((m, r)).astype(np.float32)
    V = rng.standard_normal((n, r)).astype(np.float32)
    G = jnp.asarray(U @ V.T)
    omega = jnp.asarray(rng.standard_normal((n, r)).astype(np.float32))
    err0 = jnp.zeros((m, n), jnp.float32)
    G_hat, new_err, sketch = compress_reduce(G, omega, err0, axis_name=None)
    np.testing.assert_allclose(np.asarray(G_hat), np.asarray(G),
                               rtol=1e-3, atol=1e-3)
    assert np.max(np.abs(np.asarray(new_err))) < 1e-2
    assert sketch.shape == (n, r)


def test_powersgd_error_feedback_identity():
    """In general G_hat + new_error == G + error (nothing is lost, the
    residual is carried): the identity the compression's convergence
    argument rests on."""
    m, n, r = 128, 32, 4
    rng = _rng(9)
    G = jnp.asarray(rng.standard_normal((m, n)).astype(np.float32))
    err = jnp.asarray(0.1 * rng.standard_normal((m, n)).astype(np.float32))
    omega = jnp.asarray(rng.standard_normal((n, r)).astype(np.float32))
    G_hat, new_err, _ = compress_reduce(G, omega, err, axis_name=None)
    np.testing.assert_allclose(
        np.asarray(G_hat, np.float64) + np.asarray(new_err, np.float64),
        np.asarray(G, np.float64) + np.asarray(err, np.float64),
        atol=1e-5)


def test_powersgd_warm_start_converges_to_top_subspace():
    """Power iteration: re-feeding the returned sketch sharpens the
    rank-r filter — after a few rounds the captured energy approaches
    the optimal rank-r (SVD) energy."""
    m, n, r = 256, 64, 4
    rng = _rng(10)
    # spectrum with a clear top-r subspace
    U, _ = np.linalg.qr(rng.standard_normal((m, m)))
    V, _ = np.linalg.qr(rng.standard_normal((n, n)))
    s = np.array([10, 8, 6, 5] + [0.1] * (n - 4))
    G = jnp.asarray((U[:, :n] * s) @ V.T, jnp.float32)
    omega = jnp.asarray(rng.standard_normal((n, r)).astype(np.float32))
    err = jnp.zeros((m, n), jnp.float32)
    for _ in range(4):
        G_hat, _, sketch = compress_reduce(G, omega, err, axis_name=None)
        omega = sketch
    opt_energy = float(np.sum(s[:r] ** 2))
    got_energy = float(np.sum(np.asarray(G_hat, np.float64) ** 2))
    assert got_energy > 0.98 * opt_energy


def test_powersgd_init_and_tree_structure():
    """init_state/compress_tree: large 2-D leaves get real buffers and are
    compressed; small/1-D leaves pass through with size-0 placeholders and
    (with axis_name=None) come back unchanged."""
    params = {
        "big": jnp.zeros((128, 64), jnp.float32),      # 8192 >= 4096
        "small": jnp.zeros((8, 8), jnp.float32),
        "vec": jnp.zeros((100,), jnp.float32),
    }
    state = init_state(jax.random.PRNGKey(0), params, rank=4)
    assert isinstance(state, PowerSGDState)
    assert state.error["big"].shape == (128, 64)
    assert state.sketch["big"].shape == (64, 4)
    assert state.error["small"].shape == (0,)
    assert state.sketch["vec"].shape == (0,)

    rng = _rng(11)
    grads = {
        "big": jnp.asarray(rng.standard_normal((128, 64)), jnp.float32),
        "small": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32),
        "vec": jnp.asarray(rng.standard_normal((100,)), jnp.float32),
    }
    reduced, new_state = compress_tree(grads, state, axis_name=None, rank=4)
    np.testing.assert_array_equal(np.asarray(reduced["small"]),
                                  np.asarray(grads["small"]))
    np.testing.assert_array_equal(np.asarray(reduced["vec"]),
                                  np.asarray(grads["vec"]))
    assert reduced["big"].shape == (128, 64)
    # the compressed leaf obeys error feedback: G_hat + E_new == G
    np.testing.assert_allclose(
        np.asarray(reduced["big"], np.float64)
        + np.asarray(new_state.error["big"], np.float64),
        np.asarray(grads["big"], np.float64), atol=1e-5)
