"""Per-arch smoke tests (reduced configs of the same family, one device):
one forward/train step asserting output shapes + no NaNs, plus decode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, get_config, get_smoke
from repro.models import api
from repro.models import transformer as tf


def _batch(cfg, rng, B=2, S=16):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.vlm is not None:
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.vlm.n_patches, cfg.d_model)), jnp.float32)
    if cfg.encoder is not None:
        batch["enc_frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder.n_frames, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step(rng, arch):
    cfg = get_smoke(arch)
    B, S = 2, 32
    params = tf.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, rng, B, S)
    loss_fn = api.make_forward_loss(cfg)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True)
    )(params, batch)
    assert np.isfinite(float(loss))
    for g in jax.tree_util.tree_leaves(grads):
        assert np.all(np.isfinite(np.asarray(g, dtype=np.float32)))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_shapes(rng, arch):
    cfg = get_smoke(arch)
    B, S = 2, 16
    params = tf.init_params(cfg, jax.random.key(1))
    batch = _batch(cfg, rng, B, S)
    hidden, _, _ = tf.forward(cfg, params, batch["tokens"],
                              patch_embeds=batch.get("patch_embeds"),
                              enc_frames=batch.get("enc_frames"))
    assert hidden.shape == (B, S, cfg.d_model)
    logits = tf.logits_fn(cfg, params, hidden)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode(rng, arch):
    cfg = get_smoke(arch)
    B = 2
    params = tf.init_params(cfg, jax.random.key(2))
    caches = tf.init_caches(cfg, B, 24)
    enc_out = None
    if cfg.encoder is not None:
        frames = jnp.asarray(
            rng.standard_normal((B, cfg.encoder.n_frames, cfg.d_model)), jnp.float32)
        enc_out = tf.encode(cfg, params, frames)
    tok = jnp.ones((B, 1), jnp.int32)
    step = jax.jit(api.make_serve_step(cfg))
    for p in range(3):
        if enc_out is not None:
            logits, caches = step(params, tok, jnp.asarray(p, jnp.int32), caches, enc_out)
        else:
            logits, caches = step(params, tok, jnp.asarray(p, jnp.int32), caches)
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits)))


def test_prefill_decode_consistency(rng):
    """Greedy decode after prefill matches teacher-forced forward argmax."""
    cfg = get_smoke("tinyllama-1.1b")
    params = tf.init_params(cfg, jax.random.key(3))
    B, S0 = 1, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S0)), jnp.int32)
    # teacher-forced logits at the last position
    hidden, _, _ = tf.forward(cfg, params, toks)
    lg_full = tf.logits_fn(cfg, params, hidden)[:, -1]
    # prefill path
    prefill = api.make_prefill(cfg)
    lg_pre, _ = prefill(params, {"tokens": toks})
    np.testing.assert_allclose(
        np.asarray(lg_full), np.asarray(lg_pre[:, 0]), rtol=2e-4, atol=2e-4
    )
    # decode path: feed tokens one by one through the cache
    caches = tf.init_caches(cfg, B, S0 + 4)
    step = api.make_serve_step(cfg)
    for p in range(S0):
        lg_dec, caches = step(params, toks[:, p : p + 1], jnp.asarray(p, jnp.int32), caches)
    np.testing.assert_allclose(
        np.asarray(lg_full), np.asarray(lg_dec[:, 0]), rtol=3e-3, atol=3e-3
    )


def test_chunked_attention_matches_full(rng):
    from repro.models import attention as attn

    B, S, H, Kv, Dh = 2, 64, 8, 4, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Kv, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Kv, Dh)), jnp.float32)
    full = attn.full_attention(q, k, v, n_kv=Kv, causal=True)
    for schedule in ("tri", "scan"):
        ch = attn.chunked_attention(
            q, k, v, n_kv=Kv, causal=True, q_chunk=16, kv_chunk=16,
            schedule=schedule,
        )
        np.testing.assert_allclose(
            np.asarray(full), np.asarray(ch), rtol=2e-4, atol=2e-4
        )
    # sliding window agreement
    full_w = attn.full_attention(q, k, v, n_kv=Kv, causal=True, window=24)
    for schedule in ("tri", "scan"):
        ch_w = attn.chunked_attention(
            q, k, v, n_kv=Kv, causal=True, window=24, q_chunk=16, kv_chunk=16,
            schedule=schedule,
        )
        np.testing.assert_allclose(
            np.asarray(full_w), np.asarray(ch_w), rtol=2e-4, atol=2e-4
        )


def test_long_500k_support_flags():
    from repro.configs import get_shape
    long = get_shape("long_500k")
    expected_runs = {"mamba2-2.7b", "mixtral-8x22b", "recurrentgemma-9b"}
    runs = {a for a in ARCHS if api.supports_shape(get_config(a), long)[0]}
    assert runs == expected_runs


def test_full_configs_validate():
    for arch in ARCHS:
        cfg = get_config(arch)
        cfg.validate()
        # exact published numbers spot-check
        if arch == "kimi-k2-1t-a32b":
            assert cfg.moe.n_experts == 384 and cfg.moe.top_k == 8
            assert cfg.d_model == 7168 and cfg.n_layers == 61
        if arch == "nemotron-4-340b":
            assert cfg.d_model == 18432 and cfg.d_ff == 73728
